#!/usr/bin/env python3
"""Compare per-PR bench artifacts against the checked-in baseline.

The bench-smoke CI job runs every `cargo bench` target in smoke mode,
each writing a `BENCH_<bench>.json` artifact (schema: `{"bench": str,
"smoke": bool, "rows": [{"name", "threads", "ns_per_op", "mean",
"p50", "p95", "p99", "unit"}]}`; newer rows may additionally carry
"p999" and a "metrics" object — both optional so old baselines keep
validating). This script diffs those artifacts against the snapshot
under `rust/benches/baseline/`:

* a baseline file with no current counterpart, a malformed schema on
  either side, or a baseline row (name, threads) missing from the
  current run is an ERROR (exit 1) — a renamed or dropped row must be
  an explicit baseline refresh in the same PR;
* timing movement is a WARNING only (smoke-mode numbers on shared CI
  runners are too noisy to gate merges on): ns_per_op ratios outside
  [1/1.5, 1.5x] are flagged for a human to look at;
* rows present in the current run but not in the baseline are reported
  as informational — they become baseline rows at the next refresh;
* observability exports in the current run (`METRICS_*.json` metrics
  snapshots and `TRACE_*.json` Chrome traces, written by the serving
  bench) are schema-checked when present; they need no baseline
  counterpart and their absence is not an error here (the CI `ls`
  gate pins which ones must exist);
* with `--scrape SCRAPE.json --export FINAL.json` (both
  `tfgnn_metrics_v1` documents: a mid-run `/metrics.json` scrape from
  the live admin endpoint and the same process's end-of-run
  `--metrics-out` export), every metric key present in the scrape must
  also be present in the export — the live and offline surfaces share
  one registry, so a key seen live but missing from the export means
  they drifted apart (ERROR);
* with `--events JOURNAL.jsonl [...]`, each file is schema-checked as
  a `tfgnn_events_v1` training journal (written by `tfgnn train
  --events-out`): line 1 must be a `run_start` header with the schema
  tag, later records must be `step`/`eval`/`run_end`, step records
  must carry numeric step/epoch/loss/step_secs/data_wait_secs (loss
  may be JSON null — the writer nulls non-finite values), and the
  closing `run_end.steps` must match the number of step records. This
  mode works standalone: `--baseline`/`--current` are not required.

Stdlib only; no third-party imports.

Usage:
    python3 tools/bench_compare.py --baseline rust/benches/baseline --current rust
    python3 tools/bench_compare.py --baseline ... --current ... \
        --scrape SCRAPE.json --export METRICS_loadgen.json
    python3 tools/bench_compare.py --events EVENTS_a.jsonl EVENTS_b.jsonl
"""

import argparse
import json
import math
import sys
from pathlib import Path

# Timing-ratio band (current/baseline ns_per_op) outside which a row is
# flagged. Deliberately wide: smoke iterations on shared runners jitter.
SLOWDOWN = 1.5
SPEEDUP = 1.0 / 1.5

_MISSING = object()

ROW_FIELDS = {
    "name": str,
    "threads": int,
    "ns_per_op": (int, float, type(None)),
    "mean": (int, float),
    "p50": (int, float),
    "p95": (int, float),
    "p99": (int, float),
    "unit": str,
}

# Fields newer rows may carry; type-checked only when present so
# baselines predating them stay valid.
OPTIONAL_ROW_FIELDS = {
    "p999": (int, float),
    "metrics": dict,
}


class Report:
    def __init__(self):
        self.errors = []
        self.warnings = []

    def error(self, msg):
        self.errors.append(msg)
        print(f"ERROR: {msg}")

    def warn(self, msg):
        self.warnings.append(msg)
        print(f"WARN:  {msg}")


def load_doc(path, report):
    """Parse and schema-check one BENCH_*.json; None on any defect."""
    try:
        doc = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        report.error(f"{path}: unreadable or invalid JSON: {e}")
        return None
    if not isinstance(doc, dict):
        report.error(f"{path}: top level must be an object")
        return None
    ok = True
    if not isinstance(doc.get("bench"), str):
        report.error(f"{path}: missing or non-string 'bench'")
        ok = False
    if not isinstance(doc.get("smoke"), bool):
        report.error(f"{path}: missing or non-bool 'smoke'")
        ok = False
    rows = doc.get("rows")
    if not isinstance(rows, list):
        report.error(f"{path}: missing or non-array 'rows'")
        return None
    for i, row in enumerate(rows):
        if not isinstance(row, dict):
            report.error(f"{path}: rows[{i}] is not an object")
            ok = False
            continue
        for field, want in ROW_FIELDS.items():
            value = row.get(field, _MISSING)
            if value is _MISSING:
                report.error(f"{path}: rows[{i}] missing field '{field}'")
                ok = False
            elif not isinstance(value, want) or isinstance(value, bool):
                report.error(
                    f"{path}: rows[{i}].{field} has wrong type "
                    f"({type(value).__name__})"
                )
                ok = False
        for field, want in OPTIONAL_ROW_FIELDS.items():
            value = row.get(field, _MISSING)
            if value is _MISSING:
                continue
            if not isinstance(value, want) or isinstance(value, bool):
                report.error(
                    f"{path}: rows[{i}].{field} has wrong type "
                    f"({type(value).__name__})"
                )
                ok = False
    return doc if ok else None


def check_metrics_file(path, report):
    """Schema-check one METRICS_*.json (tfgnn_metrics_v1); returns the
    parsed document when structurally sound enough to compare, else
    None."""
    try:
        doc = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        report.error(f"{path}: unreadable or invalid JSON: {e}")
        return None
    if not isinstance(doc, dict):
        report.error(f"{path}: top level must be an object")
        return None
    if doc.get("schema") != "tfgnn_metrics_v1":
        report.error(f"{path}: 'schema' is not 'tfgnn_metrics_v1'")
    for section in ("counters", "gauges", "histograms"):
        if not isinstance(doc.get(section), dict):
            report.error(f"{path}: missing or non-object '{section}'")
            return None
    for name, value in doc["counters"].items():
        if not isinstance(value, int) or isinstance(value, bool):
            report.error(f"{path}: counters[{name!r}] is not an integer")
    for name, value in doc["gauges"].items():
        if not isinstance(value, int) or isinstance(value, bool):
            report.error(f"{path}: gauges[{name!r}] is not an integer")
    for name, h in doc["histograms"].items():
        if not isinstance(h, dict):
            report.error(f"{path}: histograms[{name!r}] is not an object")
            continue
        for field in ("count", "sum_micros", "nan_rejected"):
            v = h.get(field)
            if not isinstance(v, int) or isinstance(v, bool):
                report.error(
                    f"{path}: histograms[{name!r}].{field} is not an integer"
                )
        buckets = h.get("bucket_counts")
        if not isinstance(buckets, list) or not all(
            isinstance(b, int) and not isinstance(b, bool) for b in buckets
        ):
            report.error(
                f"{path}: histograms[{name!r}].bucket_counts is not an "
                "integer array"
            )
    return doc


def check_scrape_subset(scrape_path, export_path, report):
    """Every metric key in a live `/metrics.json` scrape must exist in
    the same process's end-of-run export (scraped ⊆ exported): both
    come from one registry, so a live-only key means the surfaces
    drifted."""
    scrape = check_metrics_file(scrape_path, report)
    export = check_metrics_file(export_path, report)
    if scrape is None or export is None:
        return
    checked = 0
    for section in ("counters", "gauges", "histograms"):
        want = set(scrape[section])
        have = set(export[section])
        checked += len(want)
        for name in sorted(want - have):
            report.error(
                f"{scrape_path.name}: {section}[{name!r}] was served by the "
                f"live admin endpoint but is missing from "
                f"{export_path.name} — live scrape and offline export "
                "drifted apart"
            )
    print(
        f"bench-compare: live scrape {scrape_path.name} ⊆ export "
        f"{export_path.name} checked ({checked} key(s))"
    )


def check_trace_file(path, report):
    """Schema-check one TRACE_*.json (Chrome trace_event format)."""
    try:
        doc = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        report.error(f"{path}: unreadable or invalid JSON: {e}")
        return
    if not isinstance(doc, dict):
        report.error(f"{path}: top level must be an object")
        return
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        report.error(f"{path}: missing or non-array 'traceEvents'")
        return
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            report.error(f"{path}: traceEvents[{i}] is not an object")
            return
        for field, want in (
            ("name", str), ("ph", str), ("ts", int), ("dur", int),
            ("pid", int), ("tid", int),
        ):
            value = ev.get(field)
            if not isinstance(value, want) or isinstance(value, bool):
                report.error(
                    f"{path}: traceEvents[{i}].{field} missing or wrong type"
                )
                return
        if ev["ph"] != "X":
            report.error(
                f"{path}: traceEvents[{i}].ph is {ev['ph']!r}, want 'X' "
                "(complete events)"
            )
            return


EVENT_KINDS = {"step", "eval", "run_end"}


def check_events_file(path, report):
    """Schema-check one `tfgnn_events_v1` training journal (JSONL)."""
    errors_before = len(report.errors)
    try:
        text = path.read_text()
    except OSError as e:
        report.error(f"{path}: unreadable: {e}")
        return
    records = []
    for lineno, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        try:
            rec = json.loads(line)
        except json.JSONDecodeError as e:
            report.error(f"{path}:{lineno}: invalid JSON record: {e}")
            return
        if not isinstance(rec, dict):
            report.error(f"{path}:{lineno}: record is not an object")
            return
        records.append((lineno, rec))
    if not records:
        report.error(f"{path}: empty journal (no run_start header)")
        return
    lineno, header = records[0]
    if header.get("kind") != "run_start":
        report.error(
            f"{path}:{lineno}: first record kind is "
            f"{header.get('kind')!r}, want 'run_start'"
        )
        return
    if header.get("schema") != "tfgnn_events_v1":
        report.error(f"{path}:{lineno}: 'schema' is not 'tfgnn_events_v1'")
    for field in ("arch", "engine", "task"):
        if not isinstance(header.get(field), str):
            report.error(
                f"{path}:{lineno}: run_start.{field} missing or non-string"
            )
    steps = 0
    saw_end = False
    for lineno, rec in records[1:]:
        kind = rec.get("kind")
        if kind not in EVENT_KINDS:
            report.error(f"{path}:{lineno}: unknown record kind {kind!r}")
            return
        if saw_end:
            report.error(f"{path}:{lineno}: record after run_end")
            return
        if kind == "step":
            steps += 1
            for field in ("step", "epoch"):
                v = rec.get(field)
                if not isinstance(v, int) or isinstance(v, bool):
                    report.error(
                        f"{path}:{lineno}: step.{field} is not an integer"
                    )
            # The writer serializes non-finite values as JSON null, so
            # null is schema-legal anywhere a number is.
            for field in ("loss", "step_secs", "data_wait_secs"):
                v = rec.get(field, _MISSING)
                if v is _MISSING or (
                    v is not None
                    and (not isinstance(v, (int, float)) or isinstance(v, bool))
                ):
                    report.error(
                        f"{path}:{lineno}: step.{field} missing or non-numeric"
                    )
        elif kind == "eval":
            if rec.get("split") not in ("val", "test"):
                report.error(
                    f"{path}:{lineno}: eval.split is {rec.get('split')!r}, "
                    "want 'val' or 'test'"
                )
            if not isinstance(rec.get("metrics"), dict):
                report.error(f"{path}:{lineno}: eval.metrics is not an object")
        else:
            saw_end = True
            v = rec.get("steps")
            if not isinstance(v, int) or isinstance(v, bool):
                report.error(f"{path}:{lineno}: run_end.steps is not an integer")
            elif v != steps:
                report.error(
                    f"{path}:{lineno}: run_end.steps={v} but the journal "
                    f"has {steps} step record(s)"
                )
    if not saw_end:
        report.error(f"{path}: no run_end record (run died mid-flight?)")
    if len(report.errors) == errors_before:
        print(f"bench-compare: events journal {path.name} OK ({steps} step(s))")


def row_key(row):
    return (row["name"], row["threads"])


def compare_file(base_path, cur_path, report):
    base = load_doc(base_path, report)
    cur = load_doc(cur_path, report)
    if base is None or cur is None:
        return
    cur_rows = {}
    for row in cur["rows"]:
        key = row_key(row)
        if key in cur_rows:
            report.error(f"{cur_path}: duplicate row {key}")
        cur_rows[key] = row

    missing = [row_key(r) for r in base["rows"] if row_key(r) not in cur_rows]
    for name, threads in missing:
        report.error(
            f"{cur_path.name}: baseline row ({name!r}, threads={threads}) "
            "missing from current run — refresh the baseline if this rename"
            "/removal is intentional"
        )

    extra = set(cur_rows) - {row_key(r) for r in base["rows"]}
    for name, threads in sorted(extra):
        print(f"note:  {cur_path.name}: new row ({name!r}, threads={threads}) "
              "not in baseline")

    if base["smoke"] != cur["smoke"]:
        report.warn(
            f"{cur_path.name}: smoke mode differs (baseline={base['smoke']}, "
            f"current={cur['smoke']}); skipping timing comparison"
        )
        return

    for row in base["rows"]:
        key = row_key(row)
        if key not in cur_rows:
            continue
        b, c = row["ns_per_op"], cur_rows[key]["ns_per_op"]
        if b is None or c is None or b <= 0 or c <= 0:
            continue
        if not (math.isfinite(b) and math.isfinite(c)):
            continue
        ratio = c / b
        if ratio > SLOWDOWN or ratio < SPEEDUP:
            direction = "slower" if ratio > 1 else "faster"
            report.warn(
                f"{cur_path.name}: {key[0]} (threads={key[1]}) is "
                f"{ratio:.2f}x {direction} than baseline "
                f"({b:.0f} -> {c:.0f} ns/op)"
            )


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", type=Path,
                    help="directory of checked-in BENCH_*.json snapshots")
    ap.add_argument("--current", type=Path,
                    help="directory of freshly produced BENCH_*.json files")
    ap.add_argument("--scrape", type=Path,
                    help="mid-run /metrics.json scrape from the live admin "
                         "endpoint (requires --export)")
    ap.add_argument("--export", type=Path,
                    help="end-of-run --metrics-out export from the same "
                         "process (requires --scrape)")
    ap.add_argument("--events", type=Path, nargs="+",
                    help="tfgnn_events_v1 training journal(s) to schema-"
                         "check; standalone mode — --baseline/--current "
                         "are not required")
    args = ap.parse_args()
    if (args.scrape is None) != (args.export is None):
        ap.error("--scrape and --export must be given together")
    if args.events is None and (args.baseline is None or args.current is None):
        ap.error("--baseline and --current are required unless --events "
                 "is given")

    report = Report()
    baselines = []
    if args.baseline is not None and args.current is not None:
        baselines = sorted(args.baseline.glob("BENCH_*.json"))
        if not baselines:
            report.error(f"no BENCH_*.json baselines under {args.baseline}")
        for base_path in baselines:
            cur_path = args.current / base_path.name
            if not cur_path.is_file():
                report.error(
                    f"{base_path.name}: baseline exists but the current run "
                    f"produced no {cur_path} — did a bench target disappear?"
                )
                continue
            compare_file(base_path, cur_path, report)

        # Observability exports: schema-checked when present, never
        # required here (the CI artifact `ls` pins existence).
        obs_checked = 0
        for path in sorted(args.current.glob("METRICS_*.json")):
            check_metrics_file(path, report)
            obs_checked += 1
        for path in sorted(args.current.glob("TRACE_*.json")):
            check_trace_file(path, report)
            obs_checked += 1
        if obs_checked:
            print(
                f"bench-compare: schema-checked {obs_checked} "
                "observability export(s)"
            )

    if args.scrape is not None:
        check_scrape_subset(args.scrape, args.export, report)

    for path in args.events or []:
        check_events_file(path, report)

    print(
        f"bench-compare: {len(baselines)} file(s), "
        f"{len(report.errors)} error(s), {len(report.warnings)} warning(s)"
    )
    return 1 if report.errors else 0


if __name__ == "__main__":
    sys.exit(main())
