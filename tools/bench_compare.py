#!/usr/bin/env python3
"""Compare per-PR bench artifacts against the checked-in baseline.

The bench-smoke CI job runs every `cargo bench` target in smoke mode,
each writing a `BENCH_<bench>.json` artifact (schema: `{"bench": str,
"smoke": bool, "rows": [{"name", "threads", "ns_per_op", "mean",
"p50", "p95", "p99", "unit"}]}`). This script diffs those artifacts
against the snapshot under `rust/benches/baseline/`:

* a baseline file with no current counterpart, a malformed schema on
  either side, or a baseline row (name, threads) missing from the
  current run is an ERROR (exit 1) — a renamed or dropped row must be
  an explicit baseline refresh in the same PR;
* timing movement is a WARNING only (smoke-mode numbers on shared CI
  runners are too noisy to gate merges on): ns_per_op ratios outside
  [1/1.5, 1.5x] are flagged for a human to look at;
* rows present in the current run but not in the baseline are reported
  as informational — they become baseline rows at the next refresh.

Stdlib only; no third-party imports.

Usage:
    python3 tools/bench_compare.py --baseline rust/benches/baseline --current rust
"""

import argparse
import json
import math
import sys
from pathlib import Path

# Timing-ratio band (current/baseline ns_per_op) outside which a row is
# flagged. Deliberately wide: smoke iterations on shared runners jitter.
SLOWDOWN = 1.5
SPEEDUP = 1.0 / 1.5

_MISSING = object()

ROW_FIELDS = {
    "name": str,
    "threads": int,
    "ns_per_op": (int, float, type(None)),
    "mean": (int, float),
    "p50": (int, float),
    "p95": (int, float),
    "p99": (int, float),
    "unit": str,
}


class Report:
    def __init__(self):
        self.errors = []
        self.warnings = []

    def error(self, msg):
        self.errors.append(msg)
        print(f"ERROR: {msg}")

    def warn(self, msg):
        self.warnings.append(msg)
        print(f"WARN:  {msg}")


def load_doc(path, report):
    """Parse and schema-check one BENCH_*.json; None on any defect."""
    try:
        doc = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        report.error(f"{path}: unreadable or invalid JSON: {e}")
        return None
    if not isinstance(doc, dict):
        report.error(f"{path}: top level must be an object")
        return None
    ok = True
    if not isinstance(doc.get("bench"), str):
        report.error(f"{path}: missing or non-string 'bench'")
        ok = False
    if not isinstance(doc.get("smoke"), bool):
        report.error(f"{path}: missing or non-bool 'smoke'")
        ok = False
    rows = doc.get("rows")
    if not isinstance(rows, list):
        report.error(f"{path}: missing or non-array 'rows'")
        return None
    for i, row in enumerate(rows):
        if not isinstance(row, dict):
            report.error(f"{path}: rows[{i}] is not an object")
            ok = False
            continue
        for field, want in ROW_FIELDS.items():
            value = row.get(field, _MISSING)
            if value is _MISSING:
                report.error(f"{path}: rows[{i}] missing field '{field}'")
                ok = False
            elif not isinstance(value, want) or isinstance(value, bool):
                report.error(
                    f"{path}: rows[{i}].{field} has wrong type "
                    f"({type(value).__name__})"
                )
                ok = False
    return doc if ok else None


def row_key(row):
    return (row["name"], row["threads"])


def compare_file(base_path, cur_path, report):
    base = load_doc(base_path, report)
    cur = load_doc(cur_path, report)
    if base is None or cur is None:
        return
    cur_rows = {}
    for row in cur["rows"]:
        key = row_key(row)
        if key in cur_rows:
            report.error(f"{cur_path}: duplicate row {key}")
        cur_rows[key] = row

    missing = [row_key(r) for r in base["rows"] if row_key(r) not in cur_rows]
    for name, threads in missing:
        report.error(
            f"{cur_path.name}: baseline row ({name!r}, threads={threads}) "
            "missing from current run — refresh the baseline if this rename"
            "/removal is intentional"
        )

    extra = set(cur_rows) - {row_key(r) for r in base["rows"]}
    for name, threads in sorted(extra):
        print(f"note:  {cur_path.name}: new row ({name!r}, threads={threads}) "
              "not in baseline")

    if base["smoke"] != cur["smoke"]:
        report.warn(
            f"{cur_path.name}: smoke mode differs (baseline={base['smoke']}, "
            f"current={cur['smoke']}); skipping timing comparison"
        )
        return

    for row in base["rows"]:
        key = row_key(row)
        if key not in cur_rows:
            continue
        b, c = row["ns_per_op"], cur_rows[key]["ns_per_op"]
        if b is None or c is None or b <= 0 or c <= 0:
            continue
        if not (math.isfinite(b) and math.isfinite(c)):
            continue
        ratio = c / b
        if ratio > SLOWDOWN or ratio < SPEEDUP:
            direction = "slower" if ratio > 1 else "faster"
            report.warn(
                f"{cur_path.name}: {key[0]} (threads={key[1]}) is "
                f"{ratio:.2f}x {direction} than baseline "
                f"({b:.0f} -> {c:.0f} ns/op)"
            )


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", required=True, type=Path,
                    help="directory of checked-in BENCH_*.json snapshots")
    ap.add_argument("--current", required=True, type=Path,
                    help="directory of freshly produced BENCH_*.json files")
    args = ap.parse_args()

    report = Report()
    baselines = sorted(args.baseline.glob("BENCH_*.json"))
    if not baselines:
        report.error(f"no BENCH_*.json baselines under {args.baseline}")
    for base_path in baselines:
        cur_path = args.current / base_path.name
        if not cur_path.is_file():
            report.error(
                f"{base_path.name}: baseline exists but the current run "
                f"produced no {cur_path} — did a bench target disappear?"
            )
            continue
        compare_file(base_path, cur_path, report)

    print(
        f"bench-compare: {len(baselines)} file(s), "
        f"{len(report.errors)} error(s), {len(report.warnings)} warning(s)"
    )
    return 1 if report.errors else 0


if __name__ == "__main__":
    sys.exit(main())
