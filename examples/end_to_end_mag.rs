//! **The end-to-end driver** (DESIGN.md §End-to-end validation).
//!
//! Reproduces the paper's §8 case study on synth-MAG, exercising every
//! layer of the stack in one run:
//!
//! 1. generate synth-MAG and shard it into the distributed store;
//! 2. run the Figure-6 sampling spec through Algorithm 1's
//!    leader/worker fleet (with injected transient failures) and write
//!    the subgraphs to shard files (Fig. 4 left half);
//! 3. stream the shards through shuffle → batch → merge → pad into the
//!    AOT train step (Fig. 4 right half), logging the loss curve;
//! 4. evaluate on the temporal validation/test splits (§8.1);
//! 5. print the Table-1-style summary row.
//!
//! Results are recorded in EXPERIMENTS.md. Run:
//! `make artifacts && cargo run --release --example end_to_end_mag [-- --epochs 8]`

use std::sync::Arc;

use tfgnn::coordinator::{run_sampling_to_shards, CoordinatorConfig};
use tfgnn::pipeline::{epoch_stream, DatasetProvider, PipelineConfig, ShardProvider};
use tfgnn::runner::MagEnv;
use tfgnn::runtime::batch::RootTask;
use tfgnn::runtime::Runtime;
use tfgnn::store::sharded::ShardedStore;
use tfgnn::synth::mag::Split;
use tfgnn::train::metrics::EpochMetrics;
use tfgnn::train::{Hyperparams, Trainer};
use tfgnn::util::cli::Args;

fn main() -> tfgnn::Result<()> {
    let args = Args::from_env();
    let epochs: usize = args.get_or("epochs", 8)?;
    let workers: usize = args.get_or("workers", 4)?;
    let dir = std::path::Path::new("artifacts");
    let t_total = std::time::Instant::now();

    // ---- stage 1+2: dataset + distributed sampling -------------------------
    let env = MagEnv::from_artifacts(dir)?;
    println!(
        "synth-MAG: {} papers / {} authors / {} total edges",
        env.store.node_count("paper")?,
        env.store.node_count("author")?,
        env.store.total_edges()
    );
    let train_seeds = env.dataset.papers_in_split(Split::Train);
    let sharded =
        Arc::new(ShardedStore::new(Arc::clone(&env.store), 16).with_failures(0.01, 99));
    let shard_dir = std::env::temp_dir().join(format!("tfgnn-e2e-mag-{}", std::process::id()));
    let coord = CoordinatorConfig { num_workers: workers, ..Default::default() };
    let t0 = std::time::Instant::now();
    let (shards, report) = run_sampling_to_shards(
        sharded,
        env.sampler.spec(),
        env.manifest.plan_seed()?,
        &train_seeds,
        &coord,
        &shard_dir,
        "train",
        8,
    )?;
    let sample_secs = t0.elapsed().as_secs_f64();
    println!(
        "sampled {} rooted subgraphs in {:.2}s ({:.0}/s, {} workers, {} RPCs, {} retried)",
        report.stats.subgraphs,
        sample_secs,
        report.stats.subgraphs as f64 / sample_secs,
        workers,
        report.stats.adjacency_rpcs,
        report.stats.retried_rpcs,
    );

    // ---- stage 3: train from shards ----------------------------------------
    let entry = env.manifest.model("mpnn")?.clone();
    let hp = Hyperparams::from_manifest(&env.manifest)?;
    let mut trainer = Trainer::new(Runtime::cpu()?, dir, &entry, RootTask::default(), hp)?;
    println!(
        "model mpnn: {} params, hp = lr {} dropout {} wd {}",
        entry.param_count, hp.learning_rate, hp.dropout, hp.weight_decay
    );
    let provider = Arc::new(ShardProvider::new(shards));
    let mut pipe = PipelineConfig::new(env.batch_size, env.pad.clone());
    pipe.shuffle_buffer = 8 * env.batch_size;
    pipe.shuffle_seed = 1234;
    pipe.prep_threads = 2;

    let val_seeds = env.dataset.papers_in_split(Split::Validation);
    let test_seeds = env.dataset.papers_in_split(Split::Test);
    println!("\nepoch |  train loss  train acc |   val loss   val acc | steps/s");
    let mut best_val = 0.0f64;
    let mut loss_curve: Vec<(u64, f64)> = Vec::new();
    for epoch in 0..epochs {
        let t_e = std::time::Instant::now();
        let stream = epoch_stream(
            Arc::clone(&provider) as Arc<dyn DatasetProvider>,
            pipe.clone(),
            epoch as u64,
        )?;
        let mut train = EpochMetrics::default();
        for padded in stream.iter() {
            let m = trainer.train_batch(&padded)?;
            train.add(m);
            loss_curve.push((trainer.steps_done, m.loss as f64));
        }
        drop(stream);
        let mut val = EpochMetrics::default();
        for padded in env.eval_batches(&val_seeds, None) {
            if let Some(p) = padded? {
                val.add(trainer.eval_batch(&p)?);
            }
        }
        best_val = best_val.max(val.accuracy());
        println!(
            "{epoch:>5} | {:>11.4} {:>9.4} | {:>10.4} {:>9.4} | {:>6.1}",
            train.loss(),
            train.accuracy(),
            val.loss(),
            val.accuracy(),
            train.steps as f64 / t_e.elapsed().as_secs_f64()
        );
    }

    // ---- stage 4: held-out test ---------------------------------------------
    let mut test = EpochMetrics::default();
    for padded in env.eval_batches(&test_seeds, None) {
        if let Some(p) = padded? {
            test.add(trainer.eval_batch(&p)?);
        }
    }

    // ---- loss curve + summary ------------------------------------------------
    println!("\nloss curve (every ~20 steps):");
    for (step, loss) in loss_curve.iter().step_by(20) {
        let bar = "#".repeat((loss * 12.0).min(72.0) as usize);
        println!("  step {step:>5}  {loss:>7.4}  {bar}");
    }
    println!("\n=== Table-1-style summary (synth-MAG) ===");
    println!("model          # params    validation    test");
    println!(
        "MPNN (tfgnn)   {:>8}      {:.4}        {:.4}",
        entry.param_count,
        best_val,
        test.accuracy()
    );
    println!(
        "\nchance = {:.4}; total wall time {:.1}s",
        1.0 / 20.0,
        t_total.elapsed().as_secs_f64()
    );
    std::fs::remove_dir_all(&shard_dir)?;
    println!("end_to_end_mag OK");
    Ok(())
}
