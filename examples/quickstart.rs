//! Quickstart: the data model and data-exchange API in five minutes.
//!
//! Walks the paper's own worked example (Figures 2–3, appendices
//! A.1–A.3): build the recommendation-system GraphTensor from pieces,
//! inspect its tensors, batch + merge two copies, pad to static shapes,
//! and run the broadcast/pool "user spending" computation.
//!
//! Run: `cargo run --release --example quickstart`

use tfgnn::graph::pad::{pad, PadSpec};
use tfgnn::graph::{batch::merge, Feature};
use tfgnn::ops::{
    broadcast_context_to_nodes, broadcast_node_to_edges, pool_edges_to_node,
    pool_nodes_to_context, Reduce, Tag,
};
use tfgnn::schema::{parse::to_text, recsys_example_schema};
use tfgnn::synth::recsys::recsys_example_graph;

fn main() -> tfgnn::Result<()> {
    // ---- 1. Schema (Figure 2a) -------------------------------------------
    let schema = recsys_example_schema();
    println!("=== GraphSchema (Fig. 2a) ===\n{}", to_text(&schema));

    // ---- 2. GraphTensor from pieces (A.2.2 / Fig. 3) ----------------------
    let graph = recsys_example_graph();
    graph.check_compatible_with_schema(&schema)?;
    println!("\n=== GraphTensor (Fig. 2b) ===");
    println!(
        "items: {} nodes, users: {} nodes, purchased: {} edges, is-friend: {} edges",
        graph.num_nodes("items")?,
        graph.num_nodes("users")?,
        graph.num_edges("purchased")?,
        graph.num_edges("is-friend")?
    );
    let users = graph.node_set("users")?;
    println!("users.age        = {:?}", users.feature("age")?.as_i64()?.1);
    let adj = &graph.edge_set("purchased")?.adjacency;
    println!("purchased.source = {:?}", adj.source);
    println!("purchased.target = {:?}", adj.target);
    // A.1: edge 4 links "flight" to "Yumiko".
    let cat = graph.node_set("items")?.feature("category")?.as_str()?;
    let name = users.feature("name")?.as_str()?;
    println!(
        "edge 4 links {:?} -> {:?}",
        cat[adj.source[4] as usize], name[adj.target[4] as usize]
    );

    // ---- 3. Broadcast / pool (A.3): total user spending --------------------
    println!("\n=== API level 2: user spending (A.3) ===");
    let price = graph.node_set("items")?.feature("price")?.clone();
    let latest: Vec<f32> = (0..6).map(|i| price.ragged_row_f32(i).unwrap()[0]).collect();
    println!("latest_price per item = {latest:?}");
    let latest = Feature::f32_vec(latest);
    let purchase_prices = broadcast_node_to_edges(&graph, "purchased", Tag::Source, &latest)?;
    let spending =
        pool_edges_to_node(&graph, "purchased", Tag::Target, Reduce::Sum, &purchase_prices)?;
    println!("total_user_spending   = {:?}", spending.as_f32()?.1);
    let max_spend = pool_nodes_to_context(&graph, "users", Reduce::Max, &spending)?;
    let max_bcast = broadcast_context_to_nodes(&graph, "users", &max_spend)?;
    let frac: Vec<f32> = spending
        .as_f32()?
        .1
        .iter()
        .zip(max_bcast.as_f32()?.1)
        .map(|(s, m)| s / m)
        .collect();
    println!("fraction of max       = {frac:?}");

    // ---- 4. Batch + merge (§3.2) -------------------------------------------
    println!("\n=== batching: merge 2 graphs into components ===");
    let merged = merge(&[graph.clone(), graph.clone()])?;
    println!(
        "merged: {} components, items {} users {} purchased {}",
        merged.num_components,
        merged.num_nodes("items")?,
        merged.num_nodes("users")?,
        merged.num_edges("purchased")?
    );
    let madj = &merged.edge_set("purchased")?.adjacency;
    println!("second copy's first edge: {} -> {} (indices shifted)", madj.source[7], madj.target[7]);

    // ---- 5. Fixed-size padding (§3.2, TPU/AOT path) ------------------------
    println!("\n=== padding to static shapes ===");
    let spec = PadSpec {
        node_caps: [("items".to_string(), 16), ("users".to_string(), 12)].into(),
        edge_caps: [("purchased".to_string(), 20), ("is-friend".to_string(), 8)].into(),
        component_cap: 4,
    };
    let padded = pad(&merged, &spec)?;
    println!(
        "padded: items {} users {} purchased {} ({} real components + 1 padding)",
        padded.graph.num_nodes("items")?,
        padded.graph.num_nodes("users")?,
        padded.graph.num_edges("purchased")?,
        padded.num_real_components
    );
    let mask = &padded.node_mask["users"];
    println!("users mask = {mask:?}");
    println!("\nquickstart OK");
    Ok(())
}
