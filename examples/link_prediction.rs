//! Link prediction on synth-MAG with the native engine: hold a seeded
//! fraction of `cites` out of the message-passing graph, train a
//! Hadamard-MLP pair scorer over pair subgraphs (positive + seeded
//! negatives co-sampled per example), report MRR / hits@k on the
//! held-out validation pairs, then serve a few pair scores through the
//! task server. No AOT artifacts, no Python.
//!
//! Run: `cargo run --release --example link_prediction [-- --steps 30]`
//! Pass `--config configs/mag_small_linkpred.json` for the full-size
//! config (the default scales it down for a quick demo).

use std::sync::Arc;

use tfgnn::ops::model_ref::{ModelConfig, TaskConfig};
use tfgnn::sampler::inmem::InMemorySampler;
use tfgnn::sampler::spec::mag_sampling_spec_scaled;
use tfgnn::serve::{serve_task, ServeConfig};
use tfgnn::synth::mag::{edge_holdout, generate, MagConfig};
use tfgnn::tasks::link_prediction::{pair_eval_batches, pair_example};
use tfgnn::tasks::{self, TaskOutput};
use tfgnn::train::metrics::EpochMetrics;
use tfgnn::train::native::{AdamConfig, NativeModel, NativeTrainer};
use tfgnn::util::cli::Args;

fn main() -> tfgnn::Result<()> {
    let args = Args::from_env();
    let steps: usize = args.get_or("steps", 30)?;
    let threads: usize = args.get_or("threads", 2)?;
    let batch = 4usize;

    // Task knobs — the same block configs/mag_small_linkpred.json
    // carries, scaled to the tiny demo graph.
    let task_cfg = TaskConfig {
        kind: "link_prediction".into(),
        edge_set: "cites".into(),
        readout: "hadamard".into(),
        mlp_dim: 16,
        loss: "softmax".into(),
        negatives: 4,
        hits_k: 3,
        holdout_fraction: 0.2,
        split_seed: 77,
        ..TaskConfig::default()
    };

    // Dataset + edge-holdout split: held-out cites edges disappear from
    // the message-passing store (no leakage) and become supervision.
    let mag = MagConfig::tiny();
    let ds = generate(&mag);
    let num_papers = mag.num_papers;
    let holdout = edge_holdout(&ds, &task_cfg.edge_set, task_cfg.holdout_fraction, task_cfg.split_seed)?;
    println!(
        "edge holdout over cites: {} train / {} val / {} test pairs",
        holdout.train.len(),
        holdout.val.len(),
        holdout.test.len()
    );
    let store = Arc::new(holdout.store);
    let spec = mag_sampling_spec_scaled(&store.schema, 0.25)?;
    let sampler = Arc::new(InMemorySampler::new(Arc::clone(&store), spec, 42)?);

    // Model + task from one config.
    let cfg = ModelConfig::for_mag(&mag, 16, 16, 2).with_task(task_cfg.clone());
    let model = NativeModel::init(cfg, 3)?;
    println!("mpnn trunk + hadamard pair head: {} params", model.param_elems());
    let task = tasks::build(&model.cfg)?;
    let adam = AdamConfig { lr: 0.01, ..AdamConfig::default() };
    let mut trainer = NativeTrainer::with_task(model, adam, Arc::clone(&task), threads);

    // Train over padded pair-subgraph batches.
    let probe: Vec<_> = holdout.train[..4.min(holdout.train.len())]
        .iter()
        .map(|&(u, v)| {
            pair_example(&sampler, u, v, num_papers, task_cfg.negatives, task_cfg.split_seed)
        })
        .collect::<tfgnn::Result<_>>()?;
    let pad = tfgnn::graph::pad::PadSpec::fit(&probe.iter().collect::<Vec<_>>(), batch, 2.5);
    let mut batches = Vec::new();
    for b in pair_eval_batches(
        Arc::clone(&sampler),
        holdout.train.clone(),
        batch,
        pad.clone(),
        task_cfg.negatives,
        task_cfg.split_seed,
        num_papers,
        None,
    ) {
        if let Some(p) = b? {
            batches.push(p);
        }
    }
    assert!(!batches.is_empty(), "no pair batch fit the pad spec");
    let mut first = 0.0f32;
    let mut last = EpochMetrics::default();
    for step in 0..steps {
        let m = trainer.train_batch(&batches[step % batches.len()])?;
        if step == 0 {
            first = m.loss;
        }
        if steps - step <= batches.len() {
            last.add(m); // final pass over the data
        }
    }
    println!(
        "train: loss {first:.4} -> {:.4} | mrr {:.4} | hits@{} {:.4} ({steps} steps)",
        last.loss(),
        last.mrr(),
        task_cfg.hits_k,
        last.hits_at_k()
    );

    // Validation MRR on held-out pairs the model never saw as edges.
    let mut val = EpochMetrics::default();
    for b in pair_eval_batches(
        Arc::clone(&sampler),
        holdout.val.clone(),
        batch,
        pad,
        task_cfg.negatives,
        task_cfg.split_seed,
        num_papers,
        None,
    ) {
        if let Some(p) = b? {
            val.add(trainer.eval_batch(&p)?);
        }
    }
    println!("val:   {val}");

    // Serve a few pair scores: a true held-out edge should (usually)
    // outscore a random non-edge.
    let model = Arc::new(trainer.model().clone());
    let handle = serve_task(model, sampler, task, ServeConfig::default());
    for &(u, v) in holdout.test.iter().take(3) {
        let w = (v + 1) % num_papers as u32;
        if w == u {
            continue; // no valid synthetic non-edge target for this pair
        }
        let pos = handle.predict(&[u, v])?;
        let neg = handle.predict(&[u, w])?;
        let (TaskOutput::LinkScore { score: sp }, TaskOutput::LinkScore { score: sn }) =
            (&pos.output, &neg.output)
        else {
            panic!("task server returned a non-link response");
        };
        println!("serve: score({u},{v}) = {sp:.3} (held-out edge) vs score({u},{w}) = {sn:.3}");
    }
    handle.shutdown();
    Ok(())
}
