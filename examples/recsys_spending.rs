//! Feature engineering on the recommendation graph (appendix A.3),
//! persisted through the on-disk record format.
//!
//! The paper's A.3 walkthrough: materialize `latest_price` with
//! `replace_features`, compute per-user spending with broadcast + pool,
//! compare to the per-component max via context ops — then round-trip
//! the engineered GraphTensor through shard files like the training
//! pipeline would.
//!
//! Run: `cargo run --release --example recsys_spending`

use tfgnn::graph::io::{ShardReader, ShardWriter};
use tfgnn::graph::Feature;
use tfgnn::ops::{
    broadcast_context_to_nodes, broadcast_node_to_edges, broadcast_pool_fused,
    pool_edges_to_node, pool_nodes_to_context, segment_softmax, softmax_weighted_pool_fused,
    Reduce, Tag,
};
use tfgnn::synth::recsys::recsys_example_graph;

fn main() -> tfgnn::Result<()> {
    let graph = recsys_example_graph();

    // ---- materialize latest_price (A.3 step 1) ----------------------------
    let price = graph.node_set("items")?.feature("price")?.clone();
    let latest: Vec<f32> = (0..graph.num_nodes("items")?)
        .map(|i| price.ragged_row_f32(i).unwrap()[0])
        .collect();
    let mut feats = graph.node_set("items")?.features.clone();
    feats.insert("latest_price".into(), Feature::f32_vec(latest));
    let graph = graph.replace_node_features("items", feats)?;
    println!(
        "latest_price = {:?}",
        graph.node_set("items")?.feature("latest_price")?.as_f32()?.1
    );

    // ---- spending via fused broadcast→pool (A.3 step 2) --------------------
    // The fused fast path gathers item prices straight into per-user
    // sums over the cached CSR view — no per-edge tensor.
    let latest = graph.node_set("items")?.feature("latest_price")?.clone();
    let spending =
        broadcast_pool_fused(&graph, "purchased", Tag::Source, Tag::Target, Reduce::Sum, &latest)?;
    // The unfused two-step sequence stays the bit-for-bit oracle; the
    // per-edge tensor it materializes is still wanted below for the
    // attention printout.
    let purchase_prices = broadcast_node_to_edges(&graph, "purchased", Tag::Source, &latest)?;
    let spending_oracle =
        pool_edges_to_node(&graph, "purchased", Tag::Target, Reduce::Sum, &purchase_prices)?;
    assert_eq!(spending, spending_oracle, "fused path == broadcast+pool oracle");
    let names = graph.node_set("users")?.feature("name")?.as_str()?.to_vec();
    println!("\nuser spending:");
    for (n, s) in names.iter().zip(spending.as_f32()?.1) {
        println!("  {n:<8} {s:>8.2}");
    }

    // ---- fraction of the per-graph max (A.3 step 3) ------------------------
    let max_spend = pool_nodes_to_context(&graph, "users", Reduce::Max, &spending)?;
    let back = broadcast_context_to_nodes(&graph, "users", &max_spend)?;
    println!("\nfraction of max spend:");
    for ((n, s), m) in names.iter().zip(spending.as_f32()?.1).zip(back.as_f32()?.1) {
        println!("  {n:<8} {:>6.3}", s / m);
    }

    // ---- attention-style softmax over each user's purchases ---------------
    let w = segment_softmax(&graph, "purchased", Tag::Target, &purchase_prices)?;
    println!("\nprice-weighted attention over purchases (per user):");
    let adj = &graph.edge_set("purchased")?.adjacency;
    let cats = graph.node_set("items")?.feature("category")?.as_str()?;
    for (e, alpha) in w.as_f32()?.1.iter().enumerate() {
        println!(
            "  {} -> {:<12} α = {alpha:.3}",
            names[adj.target[e] as usize], cats[adj.source[e] as usize]
        );
    }

    // ---- fused attention readout: price-weighted expected price ------------
    // softmax(logits) ⊙ item prices, pooled per user, in one fused pass.
    let expected = softmax_weighted_pool_fused(
        &graph,
        "purchased",
        Tag::Source,
        Tag::Target,
        &purchase_prices, // logits: one scalar per edge
        &latest,          // values: gathered from items
    )?;
    println!("\nattention-weighted expected purchase price (per user):");
    for (n, v) in names.iter().zip(expected.as_f32()?.1) {
        println!("  {n:<8} {v:>8.2}");
    }

    // ---- persist the engineered graph like the sampler would ---------------
    let dir = std::env::temp_dir().join(format!("tfgnn-recsys-{}", std::process::id()));
    std::fs::create_dir_all(&dir)?;
    let path = dir.join("engineered-00000-of-00001.gts");
    let mut writer = ShardWriter::create(&path)?;
    writer.write(&graph)?;
    writer.finish()?;
    let mut reader = ShardReader::open(&path)?;
    let back = reader.next()?.expect("one record");
    assert_eq!(back, graph, "record round-trips losslessly");
    println!(
        "\nwrote + re-read engineered graph ({} bytes) at {}",
        std::fs::metadata(&path)?.len(),
        path.display()
    );
    std::fs::remove_dir_all(&dir)?;
    println!("recsys_spending OK");
    Ok(())
}
