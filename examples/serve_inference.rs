//! Online inference with dynamic batching (paper §6.3).
//!
//! Loads the AOT `forward` program behind the request router, fires
//! concurrent client threads at it, and reports latency percentiles and
//! throughput per batching configuration — the serving half of the
//! system, with the in-memory sampler generating each request's
//! GraphTensor exactly as §6.3 describes.
//!
//! Run: `make artifacts && cargo run --release --example serve_inference`

use std::sync::Arc;
use std::time::Duration;

use tfgnn::runner::MagEnv;
use tfgnn::runtime::batch::RootTask;
use tfgnn::runtime::Runtime;
use tfgnn::sampler::SamplerConfig;
use tfgnn::serve::{serve, ServeConfig};
use tfgnn::synth::mag::Split;
use tfgnn::train::{Hyperparams, Trainer};
use tfgnn::util::stats::Summary;

fn main() -> tfgnn::Result<()> {
    let dir = std::path::Path::new("artifacts");
    let env = MagEnv::from_artifacts(dir)?;
    let entry = env.manifest.model("mpnn")?.clone();

    // Params: freshly initialized (a real deployment would load a
    // checkpoint; `tfgnn train --ckpt` + `--ckpt` here does that).
    let hp = Hyperparams::from_manifest(&env.manifest)?;
    let trainer = Trainer::new(Runtime::cpu()?, dir, &entry, RootTask::default(), hp)?;
    let params = trainer.params_to_host()?;
    drop(trainer);

    let seeds = env.dataset.papers_in_split(Split::Test);
    // (max_batch, wait, sampler threads): the third column turns on the
    // parallel wave sampler — the whole batch of roots expands
    // concurrently before padding.
    for (max_batch, max_wait_ms, threads) in
        [(1usize, 0u64, 1usize), (4, 2, 1), (8, 5, 1), (8, 5, 4)]
    {
        let handle = serve(
            dir,
            &entry,
            params.clone(),
            Arc::clone(&env.sampler),
            env.pad.clone(),
            RootTask::default(),
            ServeConfig {
                max_batch,
                max_wait: Duration::from_millis(max_wait_ms),
                sampler: SamplerConfig::with_threads(threads),
                ..ServeConfig::default()
            },
        )?;
        // Closed-loop clients: 4 threads × 16 requests each.
        let t0 = std::time::Instant::now();
        let mut latencies = Vec::new();
        std::thread::scope(|scope| {
            let mut joins = Vec::new();
            for c in 0..4usize {
                let handle = &handle;
                let seeds = &seeds;
                joins.push(scope.spawn(move || {
                    let mut lat = Vec::new();
                    for i in 0..16usize {
                        let seed = seeds[(c * 37 + i * 13) % seeds.len()];
                        let resp = handle.predict(seed).expect("prediction");
                        lat.push(resp.latency.as_secs_f64());
                    }
                    lat
                }));
            }
            for j in joins {
                latencies.extend(j.join().unwrap());
            }
        });
        let wall = t0.elapsed().as_secs_f64();
        let s = Summary::of(&latencies);
        let snap = handle.stats.snapshot();
        let (batches, reqs) = (snap.batches, snap.requests);
        println!(
            "max_batch={max_batch:<2} wait={max_wait_ms}ms threads={threads} | {reqs} reqs in {wall:.2}s \
             ({:.1} req/s) | latency p50 {:.1}ms p95 {:.1}ms | avg batch {:.2}",
            reqs as f64 / wall,
            s.p50 * 1e3,
            s.p95 * 1e3,
            reqs as f64 / batches as f64
        );
        handle.shutdown();
    }
    println!("serve_inference OK");
    Ok(())
}
