//! The convolution zoo on synth-MAG: train every model type — mpnn,
//! gcn, sage (max), gatv2 — for one epoch of batches with the native
//! engine and print the loss trajectory. No AOT artifacts, no Python:
//! everything runs on the pure-Rust GraphUpdate layer stack.
//!
//! Run: `cargo run --release --example model_zoo [-- --steps 30]`

use std::sync::Arc;

use tfgnn::graph::pad::{fit_or_skip, Padded, PadSpec};
use tfgnn::ops::model_ref::ModelConfig;
use tfgnn::runtime::batch::RootTask;
use tfgnn::sampler::inmem::InMemorySampler;
use tfgnn::sampler::spec::mag_sampling_spec_scaled;
use tfgnn::synth::mag::{generate, MagConfig, Split};
use tfgnn::train::native::{AdamConfig, NativeModel, NativeTrainer};
use tfgnn::util::cli::Args;

fn main() -> tfgnn::Result<()> {
    let args = Args::from_env();
    let steps: usize = args.get_or("steps", 30)?;
    let threads: usize = args.get_or("threads", 2)?;
    let batch = 4usize;

    // One shared dataset + sampler + padded-batch stream for all models.
    let mag = MagConfig::tiny();
    let ds = generate(&mag);
    let train_seeds = ds.papers_in_split(Split::Train);
    let store = Arc::new(ds.store);
    let spec = mag_sampling_spec_scaled(&store.schema, 0.25)?;
    let sampler = InMemorySampler::new(store, spec, 42)?;
    let probe: Vec<_> = train_seeds
        .iter()
        .take(12)
        .map(|&s| sampler.sample(s))
        .collect::<tfgnn::Result<_>>()?;
    let pad = PadSpec::fit(&probe.iter().collect::<Vec<_>>(), batch, 2.5);
    let mut batches: Vec<Padded> = Vec::new();
    let mut at = 0usize;
    while at + batch <= train_seeds.len() {
        let graphs: Vec<_> = train_seeds[at..at + batch]
            .iter()
            .map(|&s| sampler.sample(s))
            .collect::<tfgnn::Result<_>>()?;
        at += batch;
        if let Some(p) = fit_or_skip(&tfgnn::graph::batch::merge(&graphs)?, &pad) {
            batches.push(p);
        }
    }
    assert!(!batches.is_empty(), "no batch fit the pad spec");
    println!(
        "synth-MAG tiny: {} train papers -> {} padded batches of {batch}",
        train_seeds.len(),
        batches.len()
    );

    for (arch, reduce) in [("mpnn", "mean"), ("gcn", "mean"), ("sage", "max"), ("gatv2", "mean")]
    {
        let mut cfg = ModelConfig::for_mag(&mag, 16, 16, 2).with_arch(arch);
        cfg.sage_reduce = reduce.to_string();
        let model = NativeModel::init(cfg, 3)?;
        let params = model.param_elems();
        let adam = AdamConfig { lr: 0.01, ..AdamConfig::default() };
        let mut trainer = NativeTrainer::new(model, adam, RootTask::default(), threads);
        let mut first = 0.0f32;
        let mut last = 0.0f32;
        let mut correct = 0.0f32;
        let mut weight = 0.0f32;
        let t0 = std::time::Instant::now();
        for step in 0..steps {
            let m = trainer.train_batch(&batches[step % batches.len()])?;
            if step == 0 {
                first = m.loss;
            }
            last = m.loss;
            correct = m.correct;
            weight = m.weight;
        }
        println!(
            "{arch:<6} ({reduce:<4}) {params:>6} params | loss {first:.4} -> {last:.4} \
             | last-batch acc {:.2} | {steps} steps in {:.2}s",
            correct / weight.max(1.0),
            t0.elapsed().as_secs_f64()
        );
    }
    Ok(())
}
