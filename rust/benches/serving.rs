//! Bench: the production serving path under closed-loop load.
//!
//! Hosts a root-classification task server over a synth-MAG graph at
//! 1/2/8 batcher lanes and drives it with the closed-loop load
//! generator at stepped client concurrency (1/4/16). **Parity is
//! asserted before any timing**, per lane count: every probe request
//! must be answered bit-identically to a single-lane, single-request
//! oracle server — a fast wrong server never produces a row. Each
//! (lanes, concurrency) level lands a p50/p95/p99/p99.9 latency row and
//! each lane count a saturation-throughput row (with the metrics-
//! registry delta its load moved) in `BENCH_serving.json` for the
//! perf-tracking CI lane. The whole run records with observability on —
//! the parity gate therefore doubles as a live obs-on/off bit-parity
//! check — and exports `METRICS_serving.json` plus a Chrome-loadable
//! `TRACE_serving.json` on exit.
//!
//! Run: `cargo bench --bench serving`
//! (set `TFGNN_BENCH_SMOKE=1` for the short CI mode).

use std::sync::Arc;

use tfgnn::ops::model_ref::ModelConfig;
use tfgnn::sampler::inmem::InMemorySampler;
use tfgnn::sampler::spec::mag_sampling_spec_scaled;
use tfgnn::serve::loadgen::{self, LoadGenConfig};
use tfgnn::serve::{serve_task, ServeConfig, TaskServerHandle};
use tfgnn::synth::mag::{generate, MagConfig, Split};
use tfgnn::train::native::NativeModel;
use tfgnn::util::stats::{smoke, Bench, BenchReport, Summary};

fn main() {
    // Record metrics + spans for the whole run; exported at the end.
    tfgnn::obs::report::enable(Some("METRICS_serving.json"), Some("TRACE_serving.json"));
    // Workload: smoke mode shrinks the graph and model so the CI lane
    // finishes in seconds but still emits every row.
    let (papers, authors, hidden, layers) =
        if smoke() { (800, 1_200, 8, 1) } else { (4_000, 6_000, 32, 2) };
    let (probe_count, requests_per_client) = if smoke() { (16, 4) } else { (48, 16) };
    let mag = MagConfig {
        num_papers: papers,
        num_authors: authors,
        num_institutions: 100,
        num_fields: 60,
        ..MagConfig::default()
    };
    let ds = generate(&mag);
    let seeds = ds.papers_in_split(Split::Train);
    let store = Arc::new(ds.store.clone());
    let spec = mag_sampling_spec_scaled(&store.schema, 0.25).unwrap();
    let sampler = Arc::new(InMemorySampler::new(store, spec, 42).unwrap());

    let cfg = ModelConfig::for_mag(&mag, hidden, hidden, layers);
    // Analyzer gate: the benched model must be one `tfgnn check` would
    // accept — a rejected config times garbage.
    let diags = tfgnn::analysis::check_model(&cfg);
    assert!(diags.is_clean(), "analyzer rejected the bench model:\n{diags}");
    let task = tfgnn::tasks::build(&cfg).unwrap();
    let model = Arc::new(NativeModel::init(cfg, 7).unwrap());

    let probe: Vec<Vec<u32>> =
        seeds.iter().take(probe_count.min(seeds.len())).map(|&s| vec![s]).collect();
    assert!(!probe.is_empty(), "no probe seeds");

    let bench = Bench::from_env(1, 3);
    let mut report = BenchReport::new("serving");
    let lg = LoadGenConfig { concurrency: vec![1, 4, 16], requests_per_client };

    let make_server = |lanes: usize| -> TaskServerHandle {
        serve_task(
            Arc::clone(&model),
            Arc::clone(&sampler),
            Arc::clone(&task),
            ServeConfig { lanes, ..ServeConfig::default() },
        )
        .unwrap()
    };

    for lanes in [1usize, 2, 8] {
        let server = make_server(lanes);

        // ---- parity gate (must pass before any timing) -----------------
        // The oracle runs one lane with one-request waves: the simplest
        // possible execution order. Any batching/lane-count effect on
        // response bits would fail here.
        let oracle = serve_task(
            Arc::clone(&model),
            Arc::clone(&sampler),
            Arc::clone(&task),
            ServeConfig { lanes: 1, max_batch: 1, ..ServeConfig::default() },
        )
        .unwrap();
        loadgen::parity_gate(&server, &oracle, &probe).unwrap();
        oracle.shutdown();
        println!("# serve lanes={lanes}: parity gate passed ({} probes)", probe.len());

        // ---- timed levels ---------------------------------------------
        for _ in 0..bench.warmup {
            loadgen::run(&server, &probe, &lg).unwrap();
        }
        // Registry delta across this lane count's timed iterations: the
        // compact snapshot rides on the saturation row so the perf lane
        // can cross-check counters (waves, cache traffic) per PR.
        let before = tfgnn::obs::metrics().snapshot();
        let mut saturations = Vec::new();
        let mut last = None;
        for _ in 0..bench.iters.max(1) {
            let r = loadgen::run(&server, &probe, &lg).unwrap();
            saturations.push(r.saturation_throughput());
            last = Some(r);
        }
        let delta = tfgnn::obs::metrics().snapshot().delta_since(&before);
        let r = last.unwrap();
        for level in &r.levels {
            assert_eq!(level.failed, 0, "lanes={lanes}: unexpected request failures");
            report.row(
                "serve/latency",
                &format!("lanes={lanes} conc={}", level.concurrency),
                lanes,
                &level.latency,
                "s",
            );
        }
        report.row_with_metrics(
            "serve/saturation",
            &format!("lanes={lanes}"),
            lanes,
            &Summary::of(&saturations),
            "items/s",
            Some(delta.to_compact_json()),
        );
        server.shutdown();
    }

    let path = report.write().expect("write bench json");
    println!("\nwrote {}", path.display());
    tfgnn::obs::report::finish(Some("METRICS_serving.json"), Some("TRACE_serving.json"))
        .expect("write obs exports");
    println!("wrote METRICS_serving.json and TRACE_serving.json");
}
