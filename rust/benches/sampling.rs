//! Bench: the sampling engine (experiment A1 in DESIGN.md).
//!
//! Covers the in-memory CSR sampler (serial vs batch-parallel), the
//! Algorithm 1 shard-fanout engine vs its single-threaded oracle, a
//! seeds × fanout × threads grid, the price of resilience (failure
//! injection + retries), and the leader/worker coordinator. Every
//! parallel configuration is cross-checked against the serial oracle
//! (bit-for-bit GraphTensor equality) before it is timed, and every
//! row lands in `BENCH_sampling.json` for the perf-tracking CI lane.
//!
//! Run: `cargo bench --bench sampling`
//! (set `TFGNN_BENCH_SMOKE=1` for the short CI mode).

use std::sync::Arc;

use tfgnn::coordinator::{run_sampling, CoordinatorConfig};
use tfgnn::sampler::distributed::{sample_batch, sample_batch_parallel};
use tfgnn::sampler::inmem::InMemorySampler;
use tfgnn::sampler::spec::mag_sampling_spec_scaled;
use tfgnn::sampler::{RetryPolicy, SamplerConfig};
use tfgnn::store::sharded::ShardedStore;
use tfgnn::synth::mag::{generate, MagConfig};
use tfgnn::util::stats::{smoke, Bench, BenchReport};
use tfgnn::util::ThreadPool;

fn main() {
    // A MAG-sized synth graph, dense enough that sampling has real
    // work; smoke mode shrinks it so CI finishes in seconds.
    let (papers, authors, n_seeds) =
        if smoke() { (2_000, 3_000, 200) } else { (20_000, 30_000, 2_000) };
    let cfg = MagConfig {
        num_papers: papers,
        num_authors: authors,
        num_institutions: 500,
        num_fields: 200,
        ..MagConfig::default()
    };
    let ds = generate(&cfg);
    let store = Arc::new(ds.store);
    let spec = mag_sampling_spec_scaled(&store.schema, 0.25).unwrap();
    let seeds: Vec<u32> = (0..n_seeds as u32).collect();
    let bench = Bench::from_env(1, 5);
    let mut report = BenchReport::new("sampling");

    // ---- in-memory sampler: CSR fast path, serial vs batch-parallel ----
    println!("# in-memory sampler (§6.1.2): CSR fast path, 1..8 threads");
    let sampler = InMemorySampler::new(Arc::clone(&store), spec.clone(), 42).unwrap();
    let serial_out = sampler.sample_batch(&seeds, &SamplerConfig::default()).unwrap();
    let s = bench.throughput(seeds.len(), || {
        let _ = sampler.sample_batch(&seeds, &SamplerConfig::default()).unwrap();
    });
    report.row("sample/inmem", &format!("{n_seeds} seeds"), 1, &s, "items/s");
    let inmem_1t = s.mean;
    let mut inmem_8t = inmem_1t;
    for threads in [2usize, 4, 8] {
        let pool = ThreadPool::new(threads);
        let check = sampler.sample_batch_with_pool(&seeds, &pool).unwrap();
        assert_eq!(check, serial_out, "parallel batch == serial, threads={threads}");
        let s = bench.throughput(seeds.len(), || {
            let _ = sampler.sample_batch_with_pool(&seeds, &pool).unwrap();
        });
        report.row("sample/inmem", &format!("{n_seeds} seeds"), threads, &s, "items/s");
        if threads == 8 {
            inmem_8t = s.mean;
        }
    }
    println!("BENCH sample/inmem speedup 8t vs 1t: {:.2}x", inmem_8t / inmem_1t);

    // ---- Algorithm 1: shard-fanout engine vs serial oracle -------------
    println!("\n# Algorithm 1 over the sharded store: shard-fanout engine");
    let sharded = Arc::new(ShardedStore::new(Arc::clone(&store), 16));
    let (dist_serial, _) =
        sample_batch(&sharded, &spec, 42, &seeds, &RetryPolicy::default()).unwrap();
    assert_eq!(dist_serial, serial_out, "Algorithm 1 == in-memory sampler");
    let s = bench.throughput(seeds.len(), || {
        let _ = sample_batch(&sharded, &spec, 42, &seeds, &RetryPolicy::default()).unwrap();
    });
    report.row("sample/distributed", "shard fanout", 1, &s, "items/s");
    let dist_1t = s.mean;
    let mut dist_8t = dist_1t;
    for threads in [2usize, 4, 8] {
        let scfg = SamplerConfig::with_threads(threads);
        let pool = ThreadPool::new(threads);
        let (got, _) =
            sample_batch_parallel(&sharded, &spec, 42, &seeds, &scfg, Some(&pool)).unwrap();
        assert_eq!(got, dist_serial, "shard fanout == serial oracle, threads={threads}");
        let s = bench.throughput(seeds.len(), || {
            let _ = sample_batch_parallel(&sharded, &spec, 42, &seeds, &scfg, Some(&pool))
                .unwrap();
        });
        report.row("sample/distributed", "shard fanout", threads, &s, "items/s");
        if threads == 8 {
            dist_8t = s.mean;
        }
    }
    println!("BENCH sample/distributed speedup 8t vs 1t: {:.2}x", dist_8t / dist_1t);

    // ---- seeds × fanout × threads grid ---------------------------------
    println!("\n# seeds × fanout × threads grid (in-memory batch sampler)");
    let grid_seeds: &[usize] = if smoke() { &[64] } else { &[256, 1_024] };
    for &f in &[0.1f64, 0.25, 1.0] {
        let fspec = mag_sampling_spec_scaled(&store.schema, f).unwrap();
        let fsampler = InMemorySampler::new(Arc::clone(&store), fspec, 42).unwrap();
        for &n in grid_seeds {
            let ss: Vec<u32> = (0..n as u32).collect();
            let want = fsampler.sample_batch(&ss, &SamplerConfig::default()).unwrap();
            for threads in [1usize, 8] {
                let label = format!("fanout={f} seeds={n}");
                if threads == 1 {
                    let s = bench.throughput(n, || {
                        let _ =
                            fsampler.sample_batch(&ss, &SamplerConfig::default()).unwrap();
                    });
                    report.row("sample/grid", &label, 1, &s, "items/s");
                } else {
                    let pool = ThreadPool::new(threads);
                    let check = fsampler.sample_batch_with_pool(&ss, &pool).unwrap();
                    assert_eq!(check, want, "grid {label} threads={threads}");
                    let s = bench.throughput(n, || {
                        let _ = fsampler.sample_batch_with_pool(&ss, &pool).unwrap();
                    });
                    report.row("sample/grid", &label, threads, &s, "items/s");
                }
            }
        }
    }

    // ---- the price of resilience ---------------------------------------
    println!("\n# the price of resilience: transient shard failures + retries");
    for fail in [0.0f64, 0.05, 0.20] {
        let flaky =
            Arc::new(ShardedStore::new(Arc::clone(&store), 16).with_failures(fail, 99));
        let scfg = SamplerConfig {
            threads: 8,
            retry: RetryPolicy { max_attempts: 100 },
            ..SamplerConfig::default()
        };
        let pool = ThreadPool::new(8);
        let (got, _) =
            sample_batch_parallel(&flaky, &spec, 42, &seeds, &scfg, Some(&pool)).unwrap();
        assert_eq!(got, dist_serial, "identical output under rpc_fail={fail}");
        let s = bench.throughput(seeds.len(), || {
            let _ =
                sample_batch_parallel(&flaky, &spec, 42, &seeds, &scfg, Some(&pool)).unwrap();
        });
        report.row("sample/resilience", &format!("rpc_fail={fail}"), 8, &s, "items/s");
    }

    // ---- coordinator: leader/worker fleet, incl. crash requeue ---------
    // RPC-failure and worker-crash rates vary independently so the
    // crash-requeue cost is not confounded with RPC retry cost.
    println!("\n# coordinator (leader/worker fleet; last rows exercise crash requeue)");
    for (workers, fail, crash) in
        [(1usize, 0.0f64, 0.0f64), (4, 0.0, 0.0), (4, 0.0, 0.05), (4, 0.20, 0.10)]
    {
        let sharded2 = Arc::new(
            ShardedStore::new(Arc::clone(&store), 16).with_failures(fail, 99),
        );
        let coord = CoordinatorConfig {
            num_workers: workers,
            batch_size: 64,
            worker_crash_rate: crash,
            crash_seed: 5,
            max_item_attempts: 100,
            ..Default::default()
        };
        let s = bench.throughput(seeds.len(), || {
            let (_graphs, _report) =
                run_sampling(Arc::clone(&sharded2), &spec, 42, &seeds, &coord).unwrap();
        });
        report.row(
            "sample/coordinator",
            &format!("rpc_fail={fail} crash={crash}"),
            workers,
            &s,
            "items/s",
        );
    }

    let path = report.write().expect("write bench json");
    println!("\nwrote {}", path.display());
}
