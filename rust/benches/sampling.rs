//! Bench: Algorithm 1 distributed sampling (experiment A1 in
//! DESIGN.md) — subgraph throughput vs worker count, the cost of
//! resilience (failure injection + retries), and in-memory vs
//! distributed executor comparison.
//!
//! Run: `cargo bench --bench sampling`

use std::sync::Arc;

use tfgnn::coordinator::{run_sampling, CoordinatorConfig};
use tfgnn::sampler::inmem::InMemorySampler;
use tfgnn::sampler::spec::mag_sampling_spec_scaled;
use tfgnn::store::sharded::ShardedStore;
use tfgnn::synth::mag::{generate, MagConfig};
use tfgnn::util::stats::{print_row, Bench};

fn main() {
    // A denser graph than the training config so sampling has real work.
    let cfg = MagConfig {
        num_papers: 20_000,
        num_authors: 30_000,
        num_institutions: 500,
        num_fields: 200,
        ..MagConfig::default()
    };
    let ds = generate(&cfg);
    let store = Arc::new(ds.store);
    let spec = mag_sampling_spec_scaled(&store.schema, 0.25).unwrap();
    let seeds: Vec<u32> = (0..2_000).collect();
    let bench = Bench::new(1, 5);

    println!("# in-memory sampler (§6.1.2), single thread");
    let sampler = InMemorySampler::new(Arc::clone(&store), spec.clone(), 42).unwrap();
    let s = bench.throughput(seeds.len(), || {
        for &seed in &seeds {
            let _ = sampler.sample(seed).unwrap();
        }
    });
    print_row("sample/inmem", "2000 seeds", &s, "items/s");

    println!("\n# Algorithm 1 over the sharded store: scaling with workers");
    for workers in [1usize, 2, 4, 8] {
        let sharded = Arc::new(ShardedStore::new(Arc::clone(&store), 16));
        let coord = CoordinatorConfig { num_workers: workers, batch_size: 64, ..Default::default() };
        let spec2 = spec.clone();
        let seeds2 = seeds.clone();
        let s = bench.throughput(seeds.len(), move || {
            let (_graphs, _report) =
                run_sampling(Arc::clone(&sharded), &spec2, 42, &seeds2, &coord).unwrap();
        });
        print_row("sample/distributed", &format!("workers={workers}"), &s, "items/s");
    }

    println!("\n# the price of resilience: transient failures + worker crashes");
    for (fail, crash) in [(0.0, 0.0), (0.05, 0.0), (0.05, 0.05), (0.20, 0.10)] {
        let sharded = Arc::new(
            ShardedStore::new(Arc::clone(&store), 16).with_failures(fail, 99),
        );
        let coord = CoordinatorConfig {
            num_workers: 4,
            batch_size: 64,
            worker_crash_rate: crash,
            crash_seed: 5,
            max_item_attempts: 100,
            ..Default::default()
        };
        let spec2 = spec.clone();
        let seeds2 = seeds.clone();
        let s = bench.throughput(seeds.len(), move || {
            let (_g, _r) =
                run_sampling(Arc::clone(&sharded), &spec2, 42, &seeds2, &coord).unwrap();
        });
        print_row(
            "sample/resilience",
            &format!("rpc_fail={fail} crash={crash}"),
            &s,
            "items/s",
        );
    }
}
