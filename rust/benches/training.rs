//! Bench: the native training engine (train step throughput).
//!
//! Times `NativeTrainer::train_batch` — forward-with-tape, reverse-mode
//! backward, deterministic all-reduce, Adam — over pipeline-shaped
//! padded batches of a MAG-sized synth graph, at 1/2/4/8 replica
//! threads, plus the forward-only eval path. **Parity is asserted
//! before any timing**: the 1-thread trainer must match the serial
//! oracle bit-for-bit (params and loss), and the 8-thread loss must
//! match within 1e-5 relative. Every row lands in `BENCH_training.json`
//! for the perf-tracking CI lane; on a quiet 8-core box the 8-thread
//! row is expected ≥2× the serial row (recorded in ROADMAP.md).
//!
//! Run: `cargo bench --bench training`
//! (set `TFGNN_BENCH_SMOKE=1` for the short CI mode).

use std::sync::Arc;

use tfgnn::graph::pad::{fit_or_skip, Padded, PadSpec};
use tfgnn::obs::events::{EventJournal, StepEvent, Telemetry};
use tfgnn::ops::model_ref::ModelConfig;
use tfgnn::runtime::batch::RootTask;
use tfgnn::sampler::inmem::InMemorySampler;
use tfgnn::sampler::spec::mag_sampling_spec_scaled;
use tfgnn::synth::mag::{generate, MagConfig, Split};
use tfgnn::train::native::{train_step_oracle, Adam, AdamConfig, NativeModel, NativeTrainer};
use tfgnn::util::stats::{smoke, Bench, BenchReport};

fn rel_diff(a: f32, b: f32) -> f64 {
    let (a, b) = (a as f64, b as f64);
    (a - b).abs() / a.abs().max(b.abs()).max(1e-12)
}

fn main() {
    // Workload: smoke mode shrinks the graph, model and batch count so
    // the CI lane finishes in seconds but still emits every row.
    let (papers, authors, hidden, layers, n_batches) =
        if smoke() { (1_000, 1_500, 16, 1, 2) } else { (4_000, 6_000, 64, 2, 8) };
    let batch = 8usize;
    let mag = MagConfig {
        num_papers: papers,
        num_authors: authors,
        num_institutions: 200,
        num_fields: 120,
        ..MagConfig::default()
    };
    let ds = generate(&mag);
    let store = Arc::new(ds.store);
    let spec = mag_sampling_spec_scaled(&store.schema, 0.25).unwrap();
    let sampler = InMemorySampler::new(Arc::clone(&store), spec, 42).unwrap();
    let train_seeds = ds.papers_in_split(Split::Train);

    // Padded batches exactly as the pipeline would emit them.
    let probe: Vec<_> =
        train_seeds.iter().take(16).map(|&s| sampler.sample(s).unwrap()).collect();
    let pad = PadSpec::fit(&probe.iter().collect::<Vec<_>>(), batch, 2.0);
    let mut batches: Vec<Padded> = Vec::new();
    let mut at = 0usize;
    while batches.len() < n_batches && at + batch <= train_seeds.len() {
        let graphs: Vec<_> = train_seeds[at..at + batch]
            .iter()
            .map(|&s| sampler.sample(s).unwrap())
            .collect();
        at += batch;
        let merged = tfgnn::graph::batch::merge(&graphs).unwrap();
        if let Some(p) = fit_or_skip(&merged, &pad) {
            batches.push(p);
        }
    }
    assert!(!batches.is_empty(), "no batch fit the pad spec");
    let roots_per_pass: usize = batches.iter().map(|b| b.num_real_components).sum();

    let model_cfg = ModelConfig::for_mag(&mag, hidden, hidden, layers);
    // Analyzer gate: the benched architecture must be one `tfgnn check`
    // would accept — a rejected config times garbage.
    let diags = tfgnn::analysis::check_model(&model_cfg);
    assert!(diags.is_clean(), "analyzer rejected the bench model:\n{diags}");
    let task = RootTask::default();
    let adam = AdamConfig::default();
    let model0 = NativeModel::init(model_cfg, 3).unwrap();
    println!(
        "# native training engine: {} params, batch {batch}, {} prepared batches",
        model0.param_elems(),
        batches.len()
    );

    // ---- parity gates (must pass before any timing) --------------------
    let mut oracle_model = model0.clone();
    let mut oracle_opt = Adam::new(adam, &oracle_model.params);
    let m_oracle = train_step_oracle(&mut oracle_model, &mut oracle_opt, &batches[0], &task)
        .unwrap();
    let mut t1 = NativeTrainer::new(model0.clone(), adam, task.clone(), 1);
    let m1 = t1.train_batch(&batches[0]).unwrap();
    assert_eq!(
        m1.loss.to_bits(),
        m_oracle.loss.to_bits(),
        "1-thread loss == serial oracle, bit-for-bit"
    );
    for (name, a, b) in t1
        .model()
        .names
        .iter()
        .zip(&t1.model().params)
        .zip(&oracle_model.params)
        .map(|((n, a), b)| (n, a, b))
    {
        for (x, y) in a.data.iter().zip(&b.data) {
            assert_eq!(x.to_bits(), y.to_bits(), "param {name} diverged from oracle");
        }
    }
    let mut t8 = NativeTrainer::new(model0.clone(), adam, task.clone(), 8);
    let m8 = t8.train_batch(&batches[0]).unwrap();
    assert!(
        rel_diff(m1.loss, m8.loss) <= 1e-5,
        "8-thread loss {} vs serial {} (rel {})",
        m8.loss,
        m1.loss,
        rel_diff(m1.loss, m8.loss)
    );
    println!("# parity gates passed: 1t == oracle (bit), 8t loss within 1e-5");

    // ---- train-step throughput, 1..8 replica threads -------------------
    println!("\n# train step (forward+backward+all-reduce+Adam), items = roots/s");
    let bench = Bench::from_env(1, 5);
    let mut report = BenchReport::new("training");
    let mut serial_rate = 0.0f64;
    let mut rate_8t = 0.0f64;
    for threads in [1usize, 2, 4, 8] {
        let mut tr = NativeTrainer::new(model0.clone(), adam, task.clone(), threads);
        let s = bench.throughput(roots_per_pass, || {
            for b in &batches {
                tr.train_batch(b).unwrap();
            }
        });
        report.row(
            "train/native_step",
            &format!("batch={batch} hidden={hidden} layers={layers}"),
            threads,
            &s,
            "items/s",
        );
        if threads == 1 {
            serial_rate = s.mean;
        }
        if threads == 8 {
            rate_8t = s.mean;
        }
    }
    println!("BENCH train/native_step speedup 8t vs 1t: {:.2}x", rate_8t / serial_rate);

    // ---- train-step throughput with full telemetry ---------------------
    // Gradient probes + explosion sentinel + per-step journal append,
    // exactly as the runner's epoch loop drives them. The delta vs the
    // rows above is the whole observability overhead (f64 norm
    // accumulation + one JSONL write per step); the trained bits are
    // identical either way — pinned by tests/events.rs.
    println!("\n# train step with gradient probes + event journal");
    let journal_path = std::env::temp_dir()
        .join(format!("tfgnn_bench_events_{}.jsonl", std::process::id()));
    for threads in [1usize, 8] {
        let journal = Arc::new(EventJournal::create(&journal_path).unwrap());
        let mut tr = NativeTrainer::new(model0.clone(), adam, task.clone(), threads);
        tr.set_telemetry(Telemetry {
            grad_stats: true,
            grad_norm_limit: Some(1e9),
            flight: None,
            journal: None,
        });
        let mut step = 0u64;
        let s = bench.throughput(roots_per_pass, || {
            for b in &batches {
                let m = tr.train_batch(b).unwrap();
                let g = tr.take_grad_stats();
                let ev = StepEvent {
                    step,
                    epoch: 0,
                    split: "train",
                    loss: f64::from(m.loss),
                    examples: f64::from(m.weight),
                    task: &m.task,
                    step_secs: 0.0,
                    data_wait_secs: 0.0,
                    grad: g.as_ref(),
                }
                .to_event();
                journal.write(&ev).unwrap();
                step += 1;
            }
        });
        report.row(
            "train/native_step_telemetry",
            &format!("batch={batch} hidden={hidden} layers={layers}"),
            threads,
            &s,
            "items/s",
        );
    }
    let _ = std::fs::remove_file(&journal_path);

    // ---- eval (forward-only) throughput --------------------------------
    println!("\n# eval step (fused forward only)");
    for threads in [1usize, 8] {
        let tr = NativeTrainer::new(model0.clone(), adam, task.clone(), threads);
        let s = bench.throughput(roots_per_pass, || {
            for b in &batches {
                tr.eval_batch(b).unwrap();
            }
        });
        report.row(
            "train/native_eval",
            &format!("batch={batch} hidden={hidden} layers={layers}"),
            threads,
            &s,
            "items/s",
        );
    }

    let path = report.write().expect("write bench json");
    println!("\nwrote {}", path.display());
}
