//! Bench: the GraphUpdate layer zoo (forward / backward throughput per
//! model type).
//!
//! Times one `NativeTrainer` train step (forward-with-tape, reverse
//! sweep, all-reduce, Adam) and the forward-only eval path for **all
//! four model types** — mpnn, gcn, sage, gatv2 — over pipeline-shaped
//! padded batches of a synth-MAG graph, at 1 and 8 replica threads.
//! **Parity is asserted before any timing**: for every architecture the
//! 1-thread step must match the serial oracle bit-for-bit. Every row
//! lands in `BENCH_models.json` for the perf-tracking CI lane.
//!
//! Run: `cargo bench --bench model_layers`
//! (set `TFGNN_BENCH_SMOKE=1` for the short CI mode).

use std::sync::Arc;

use tfgnn::graph::pad::{fit_or_skip, Padded, PadSpec};
use tfgnn::ops::model_ref::ModelConfig;
use tfgnn::runtime::batch::RootTask;
use tfgnn::sampler::inmem::InMemorySampler;
use tfgnn::synth::mag::{generate, MagConfig, Split};
use tfgnn::train::native::{train_step_oracle, Adam, AdamConfig, NativeModel, NativeTrainer};
use tfgnn::util::stats::{smoke, Bench, BenchReport};

fn main() {
    let (papers, authors, hidden, layers, n_batches) =
        if smoke() { (800, 1_200, 8, 1, 1) } else { (2_000, 3_000, 32, 2, 4) };
    let batch = 8usize;
    let mag = MagConfig {
        num_papers: papers,
        num_authors: authors,
        num_institutions: 100,
        num_fields: 60,
        ..MagConfig::default()
    };
    let ds = generate(&mag);
    let store = Arc::new(ds.store);
    let spec = tfgnn::sampler::spec::mag_sampling_spec_scaled(&store.schema, 0.25).unwrap();
    let sampler = InMemorySampler::new(Arc::clone(&store), spec, 42).unwrap();
    let train_seeds = ds.papers_in_split(Split::Train);

    // Padded batches exactly as the pipeline would emit them.
    let probe: Vec<_> =
        train_seeds.iter().take(16).map(|&s| sampler.sample(s).unwrap()).collect();
    let pad = PadSpec::fit(&probe.iter().collect::<Vec<_>>(), batch, 2.0);
    let mut batches: Vec<Padded> = Vec::new();
    let mut at = 0usize;
    while batches.len() < n_batches && at + batch <= train_seeds.len() {
        let graphs: Vec<_> = train_seeds[at..at + batch]
            .iter()
            .map(|&s| sampler.sample(s).unwrap())
            .collect();
        at += batch;
        let merged = tfgnn::graph::batch::merge(&graphs).unwrap();
        if let Some(p) = fit_or_skip(&merged, &pad) {
            batches.push(p);
        }
    }
    assert!(!batches.is_empty(), "no batch fit the pad spec");
    let roots_per_pass: usize = batches.iter().map(|b| b.num_real_components).sum();

    let task = RootTask::default();
    let adam = AdamConfig::default();
    let bench = Bench::from_env(1, 5);
    let mut report = BenchReport::new("models");

    for arch in ["mpnn", "gcn", "sage", "gatv2"] {
        let cfg = ModelConfig::for_mag(&mag, hidden, hidden, layers).with_arch(arch);
        // Analyzer gate: every benched arch must be one `tfgnn check`
        // would accept — a rejected config times garbage.
        let diags = tfgnn::analysis::check_model(&cfg);
        assert!(diags.is_clean(), "{arch}: analyzer rejected the bench model:\n{diags}");
        let model0 = NativeModel::init(cfg, 3).unwrap();
        println!(
            "\n# {arch}: {} params, batch {batch}, {} prepared batches",
            model0.param_elems(),
            batches.len()
        );

        // ---- parity gate: 1-thread step == serial oracle, bit-for-bit.
        let mut oracle_model = model0.clone();
        let mut oracle_opt = Adam::new(adam, &oracle_model.params);
        let m_oracle =
            train_step_oracle(&mut oracle_model, &mut oracle_opt, &batches[0], &task).unwrap();
        let mut t1 = NativeTrainer::new(model0.clone(), adam, task.clone(), 1);
        let m1 = t1.train_batch(&batches[0]).unwrap();
        assert_eq!(
            m1.loss.to_bits(),
            m_oracle.loss.to_bits(),
            "{arch}: 1-thread loss == serial oracle, bit-for-bit"
        );
        for ((name, a), b) in
            t1.model().names.iter().zip(&t1.model().params).zip(&oracle_model.params)
        {
            for (x, y) in a.data.iter().zip(&b.data) {
                assert_eq!(x.to_bits(), y.to_bits(), "{arch}: param {name} diverged");
            }
        }
        println!("# {arch}: parity gate passed (1t == oracle, bit)");

        // ---- train step (forward + backward + all-reduce + Adam).
        for threads in [1usize, 8] {
            let mut tr = NativeTrainer::new(model0.clone(), adam, task.clone(), threads);
            let s = bench.throughput(roots_per_pass, || {
                for b in &batches {
                    tr.train_batch(b).unwrap();
                }
            });
            report.row(
                "model",
                &format!("{arch}_step batch={batch} hidden={hidden} layers={layers}"),
                threads,
                &s,
                "items/s",
            );
        }

        // ---- forward only (the serving/eval path).
        for threads in [1usize, 8] {
            let tr = NativeTrainer::new(model0.clone(), adam, task.clone(), threads);
            let s = bench.throughput(roots_per_pass, || {
                for b in &batches {
                    tr.eval_batch(b).unwrap();
                }
            });
            report.row(
                "model",
                &format!("{arch}_forward batch={batch} hidden={hidden} layers={layers}"),
                threads,
                &s,
                "items/s",
            );
        }
    }

    let path = report.write().expect("write bench json");
    println!("\nwrote {}", path.display());
}
