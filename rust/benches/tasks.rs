//! Bench: the task subsystem (per-objective train-step throughput).
//!
//! Times `NativeTrainer::train_batch` for each readout head — root
//! classification, link prediction (Hadamard-MLP + softmax over pair
//! subgraphs), graph regression (mean-pool + MSE) — over
//! pipeline-shaped padded batches of a synth-MAG graph, at 1/8 replica
//! threads. **Parity is asserted before any timing**, per task: the
//! 1-thread trainer must match the serial oracle bit-for-bit (params
//! and loss), and the 8-thread loss must match within 1e-5 relative.
//! Every row lands in `BENCH_tasks.json` for the perf-tracking CI
//! lane.
//!
//! Run: `cargo bench --bench tasks`
//! (set `TFGNN_BENCH_SMOKE=1` for the short CI mode).

use std::sync::Arc;

use tfgnn::graph::pad::{fit_or_skip, Padded, PadSpec};
use tfgnn::ops::model_ref::{ModelConfig, TaskConfig};
use tfgnn::sampler::inmem::InMemorySampler;
use tfgnn::sampler::spec::mag_sampling_spec_scaled;
use tfgnn::synth::mag::{edge_holdout, generate, MagConfig, MagDataset, Split};
use tfgnn::tasks::link_prediction::pair_example;
use tfgnn::train::native::{
    train_step_oracle_task, Adam, AdamConfig, NativeModel, NativeTrainer,
};
use tfgnn::util::stats::{smoke, Bench, BenchReport};

fn rel_diff(a: f32, b: f32) -> f64 {
    let (a, b) = (a as f64, b as f64);
    (a - b).abs() / a.abs().max(b.abs()).max(1e-12)
}

/// Padded seed-rooted batches (classification / regression examples).
fn seed_batches(
    ds: &MagDataset,
    sampler: &InMemorySampler,
    batch: usize,
    count: usize,
) -> Vec<Padded> {
    let seeds = ds.papers_in_split(Split::Train);
    let probe: Vec<_> = seeds.iter().take(16).map(|&s| sampler.sample(s).unwrap()).collect();
    let pad = PadSpec::fit(&probe.iter().collect::<Vec<_>>(), batch, 2.0);
    let mut out = Vec::new();
    let mut at = 0usize;
    while out.len() < count && at + batch <= seeds.len() {
        let graphs: Vec<_> =
            seeds[at..at + batch].iter().map(|&s| sampler.sample(s).unwrap()).collect();
        at += batch;
        if let Some(p) = fit_or_skip(&tfgnn::graph::batch::merge(&graphs).unwrap(), &pad) {
            out.push(p);
        }
    }
    assert!(!out.is_empty(), "no seed batch fit the pad spec");
    out
}

/// Padded pair-subgraph batches (link-prediction examples).
fn pair_batches(
    pairs: &[(u32, u32)],
    sampler: &InMemorySampler,
    num_papers: usize,
    negatives: usize,
    neg_seed: u64,
    batch: usize,
    count: usize,
) -> Vec<Padded> {
    let probe: Vec<_> = pairs
        .iter()
        .take(8)
        .map(|&(u, v)| pair_example(sampler, u, v, num_papers, negatives, neg_seed).unwrap())
        .collect();
    let pad = PadSpec::fit(&probe.iter().collect::<Vec<_>>(), batch, 2.0);
    let mut out = Vec::new();
    let mut at = 0usize;
    while out.len() < count && at + batch <= pairs.len() {
        let graphs: Vec<_> = pairs[at..at + batch]
            .iter()
            .map(|&(u, v)| pair_example(sampler, u, v, num_papers, negatives, neg_seed).unwrap())
            .collect();
        at += batch;
        if let Some(p) = fit_or_skip(&tfgnn::graph::batch::merge(&graphs).unwrap(), &pad) {
            out.push(p);
        }
    }
    assert!(!out.is_empty(), "no pair batch fit the pad spec");
    out
}

/// Parity gates for one (model config, batches) pair, then timed rows.
fn gate_and_time(
    report: &mut BenchReport,
    bench: &Bench,
    row: &str,
    detail: &str,
    cfg: &ModelConfig,
    batches: &[Padded],
) {
    let adam = AdamConfig::default();
    // Analyzer gate: every benched task head must be one `tfgnn check`
    // would accept — a rejected config times garbage.
    let diags = tfgnn::analysis::check_model(cfg);
    assert!(diags.is_clean(), "{row}: analyzer rejected the bench model:\n{diags}");
    let model0 = NativeModel::init(cfg.clone(), 3).unwrap();
    let task = tfgnn::tasks::build(cfg).unwrap();

    // ---- parity gates (must pass before any timing) --------------------
    let mut oracle_model = model0.clone();
    let mut oracle_opt = Adam::new(adam, &oracle_model.params);
    let m_oracle =
        train_step_oracle_task(&mut oracle_model, &mut oracle_opt, &batches[0], task.as_ref())
            .unwrap();
    let mut t1 = NativeTrainer::with_task(model0.clone(), adam, Arc::clone(&task), 1);
    let m1 = t1.train_batch(&batches[0]).unwrap();
    assert_eq!(
        m1.loss.to_bits(),
        m_oracle.loss.to_bits(),
        "{row}: 1-thread loss == serial oracle, bit-for-bit"
    );
    for ((name, a), b) in
        t1.model().names.iter().zip(&t1.model().params).zip(&oracle_model.params)
    {
        for (x, y) in a.data.iter().zip(&b.data) {
            assert_eq!(x.to_bits(), y.to_bits(), "{row}: param {name} diverged from oracle");
        }
    }
    let mut t8 = NativeTrainer::with_task(model0.clone(), adam, Arc::clone(&task), 8);
    let m8 = t8.train_batch(&batches[0]).unwrap();
    assert!(
        rel_diff(m1.loss, m8.loss) <= 1e-5,
        "{row}: 8-thread loss {} vs serial {} (rel {})",
        m8.loss,
        m1.loss,
        rel_diff(m1.loss, m8.loss)
    );
    println!("# {row}: parity gates passed (1t == oracle bit, 8t loss within 1e-5)");

    // ---- timed rows -----------------------------------------------------
    let examples_per_pass: usize = batches.iter().map(|b| b.num_real_components).sum();
    for threads in [1usize, 8] {
        let mut tr = NativeTrainer::with_task(model0.clone(), adam, Arc::clone(&task), threads);
        let s = bench.throughput(examples_per_pass, || {
            for b in batches {
                tr.train_batch(b).unwrap();
            }
        });
        report.row(row, detail, threads, &s, "items/s");
    }
}

fn main() {
    // Workload: smoke mode shrinks the graph, model and batch count so
    // the CI lane finishes in seconds but still emits every row.
    let (papers, authors, hidden, layers, n_batches) =
        if smoke() { (800, 1_200, 16, 1, 2) } else { (4_000, 6_000, 32, 2, 6) };
    let batch = 4usize;
    let mag = MagConfig {
        num_papers: papers,
        num_authors: authors,
        num_institutions: 100,
        num_fields: 60,
        ..MagConfig::default()
    };
    let ds = generate(&mag);

    let bench = Bench::from_env(1, 5);
    let mut report = BenchReport::new("tasks");
    let detail = format!("batch={batch} hidden={hidden} layers={layers}");

    // ---- root classification (the extracted historical objective) ------
    {
        let store = Arc::new(ds.store.clone());
        let spec = mag_sampling_spec_scaled(&store.schema, 0.25).unwrap();
        let sampler = InMemorySampler::new(store, spec, 42).unwrap();
        let batches = seed_batches(&ds, &sampler, batch, n_batches);
        let cfg = ModelConfig::for_mag(&mag, hidden, hidden, layers);
        println!("# task/root_step: {} batches", batches.len());
        gate_and_time(&mut report, &bench, "task/root_step", &detail, &cfg, &batches);
    }

    // ---- link prediction (pair subgraphs, hadamard + softmax) ----------
    {
        let tcfg = TaskConfig {
            kind: "link_prediction".into(),
            edge_set: "cites".into(),
            readout: "hadamard".into(),
            mlp_dim: hidden,
            loss: "softmax".into(),
            negatives: 3,
            hits_k: 3,
            holdout_fraction: 0.1,
            split_seed: 77,
            ..TaskConfig::default()
        };
        let holdout = edge_holdout(&ds, "cites", tcfg.holdout_fraction, tcfg.split_seed).unwrap();
        let store = Arc::new(holdout.store);
        let spec = mag_sampling_spec_scaled(&store.schema, 0.25).unwrap();
        let sampler = InMemorySampler::new(store, spec, 42).unwrap();
        let batches = pair_batches(
            &holdout.train,
            &sampler,
            mag.num_papers,
            tcfg.negatives,
            tcfg.split_seed,
            batch,
            n_batches,
        );
        let cfg = ModelConfig::for_mag(&mag, hidden, hidden, layers).with_task(tcfg);
        println!("# task/linkpred_step: {} batches", batches.len());
        gate_and_time(&mut report, &bench, "task/linkpred_step", &detail, &cfg, &batches);
    }

    // ---- graph regression (mean-pool + MSE) ----------------------------
    {
        let store = Arc::new(ds.store.clone());
        let spec = mag_sampling_spec_scaled(&store.schema, 0.25).unwrap();
        let sampler = InMemorySampler::new(store, spec, 42).unwrap();
        let batches = seed_batches(&ds, &sampler, batch, n_batches);
        let tcfg = TaskConfig {
            kind: "graph_regression".into(),
            target_feature: "year".into(),
            target_shift: 2010.0,
            target_scale: 0.1,
            ..TaskConfig::default()
        };
        let cfg = ModelConfig::for_mag(&mag, hidden, hidden, layers).with_task(tcfg);
        println!("# task/graphreg_step: {} batches", batches.len());
        gate_and_time(&mut report, &bench, "task/graphreg_step", &detail, &cfg, &batches);
    }

    let path = report.write().expect("write bench json");
    println!("\nwrote {}", path.display());
}
