//! Bench: the Fig. 4 training input pipeline (experiment F4 in
//! DESIGN.md) — per-stage throughput (sample, merge, pad) and the
//! end-to-end producer with/without the parallel prep pool and
//! backpressure, plus pipeline-vs-executor overlap if artifacts exist.
//!
//! Run: `make artifacts && cargo bench --bench pipeline`

use std::sync::Arc;

use tfgnn::graph::batch::merge;
use tfgnn::graph::pad::fit_or_skip;
use tfgnn::pipeline::{epoch_stream, DatasetProvider, PipelineConfig, SamplingProvider};
use tfgnn::runner::MagEnv;
use tfgnn::sampler::SamplerConfig;
use tfgnn::runtime::batch::RootTask;
use tfgnn::runtime::Runtime;
use tfgnn::synth::mag::Split;
use tfgnn::train::{Hyperparams, Trainer};
use tfgnn::util::stats::{print_row, Bench};

fn main() {
    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("pipeline bench needs `make artifacts`");
        return;
    }
    let env = MagEnv::from_artifacts(dir).unwrap();
    let seeds = env.dataset.papers_in_split(Split::Train);
    let bench = Bench::new(1, 5);

    // ---- stage costs --------------------------------------------------------
    println!("# per-stage costs (batch = {})", env.batch_size);
    let chunk: Vec<u32> = seeds[..env.batch_size].to_vec();
    let s = bench.throughput(env.batch_size, || {
        for &seed in &chunk {
            let _ = env.sampler.sample(seed).unwrap();
        }
    });
    print_row("stage/sample", "per graph", &s, "items/s");

    let graphs: Vec<_> = chunk.iter().map(|&s| env.sampler.sample(s).unwrap()).collect();
    let s = bench.run(|| {
        let _ = merge(&graphs).unwrap();
    });
    print_row("stage/merge", "per batch", &s, "s");
    let merged = merge(&graphs).unwrap();
    let s = bench.run(|| {
        let _ = fit_or_skip(&merged, &env.pad).unwrap();
    });
    print_row("stage/pad", "per batch", &s, "s");

    // ---- end-to-end producer -------------------------------------------------
    println!("\n# pipeline producer throughput (graphs/s), one epoch over {} seeds", seeds.len());
    for (prep_threads, sampler_threads) in
        [(0usize, 1usize), (2, 1), (4, 1), (2, 4), (4, 4)]
    {
        let mut provider =
            SamplingProvider::new(Arc::clone(&env.sampler), seeds.clone(), 7);
        provider.sampling = SamplerConfig::with_threads(sampler_threads);
        let provider = Arc::new(provider);
        let mut cfg = PipelineConfig::new(env.batch_size, env.pad.clone());
        cfg.shuffle_buffer = 64;
        cfg.prep_threads = prep_threads;
        let n = seeds.len();
        let s = bench.throughput(n, move || {
            let stream = epoch_stream(
                Arc::clone(&provider) as Arc<dyn DatasetProvider>,
                cfg.clone(),
                0,
            )
            .unwrap();
            let mut count = 0usize;
            for p in stream.iter() {
                count += p.num_real_components;
            }
            assert!(count > 0);
        });
        print_row(
            "pipeline/producer",
            &format!("prep_threads={prep_threads} sampler_threads={sampler_threads}"),
            &s,
            "items/s",
        );
    }

    // ---- pipeline + executor overlap -----------------------------------------
    println!("\n# train-step consumption vs pipeline production (Fig. 4 balance)");
    let entry = env.manifest.model("mpnn").unwrap().clone();
    let hp = Hyperparams::from_manifest(&env.manifest).unwrap();
    let mut trainer =
        Trainer::new(Runtime::cpu().unwrap(), dir, &entry, RootTask::default(), hp).unwrap();
    // Pure executor rate on one cached batch.
    let graphs: Vec<_> =
        seeds[..env.batch_size].iter().map(|&s| env.sampler.sample(s).unwrap()).collect();
    let padded = fit_or_skip(&merge(&graphs).unwrap(), &env.pad).unwrap();
    let s = bench.run(|| {
        let _ = trainer.train_batch(&padded).unwrap();
    });
    print_row("executor/train_step", "cached batch", &s, "s");
    let step_time = s.mean;

    // End-to-end: pipeline feeding the trainer.
    let provider = Arc::new(SamplingProvider::new(
        Arc::clone(&env.sampler),
        seeds[..48 * env.batch_size.min(seeds.len() / env.batch_size)].to_vec(),
        7,
    ));
    let mut cfg = PipelineConfig::new(env.batch_size, env.pad.clone());
    cfg.prep_threads = 2;
    let t0 = std::time::Instant::now();
    let stream = epoch_stream(provider, cfg, 0).unwrap();
    let mut steps = 0usize;
    for p in stream.iter() {
        trainer.train_batch(&p).unwrap();
        steps += 1;
    }
    let e2e = t0.elapsed().as_secs_f64() / steps as f64;
    println!(
        "BENCH pipeline/e2e overlap: {:.2} ms/step end-to-end vs {:.2} ms/step pure executor \
         (overhead {:.1}%)",
        e2e * 1e3,
        step_time * 1e3,
        (e2e / step_time - 1.0) * 100.0
    );
}
