//! **Table 1 reproduction** (experiment T1 in DESIGN.md).
//!
//! The paper's headline: a hyper-parameter-tuned MPNN with ~4.5× fewer
//! parameters matches/beats the higher-capacity attention model (HGT on
//! the OGB leaderboard; our `mha` baseline) on the venue-classification
//! task. This bench trains both models on synth-MAG over several seeds
//! and prints the same table rows: # params, validation, test (± std).
//!
//! The absolute numbers differ from the paper's (synthetic data, scaled
//! sizes); the *shape* — small tuned MPNN ≥ big attention model — is the
//! reproduced claim. Results are recorded in EXPERIMENTS.md §T1.
//!
//! Run: `make artifacts && cargo bench --bench table1_accuracy`
//! Defaults are a quick sanity pass (3 epochs × 1 seed); the full
//! EXPERIMENTS.md result uses TFGNN_T1_EPOCHS=8 TFGNN_T1_SEEDS=3.

use tfgnn::runner::{run, RunConfig};
use tfgnn::util::stats::fmt_mean_std;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn main() {
    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("table1 bench needs `make artifacts`");
        return;
    }
    let epochs = env_usize("TFGNN_T1_EPOCHS", 3);
    let n_seeds = env_usize("TFGNN_T1_SEEDS", 1);

    println!("# Table 1 (synth-MAG): tuned small MPNN vs high-capacity attention (HGT-like)");
    println!("# {epochs} epochs x {n_seeds} seeds per model\n");

    let mut rows = Vec::new();
    for arch in ["mha", "mpnn"] {
        let mut vals = Vec::new();
        let mut tests = Vec::new();
        let mut params = 0usize;
        for seed in 0..n_seeds {
            let mut cfg = RunConfig::new(dir, arch);
            cfg.epochs = epochs;
            cfg.shuffle_seed = 0x5eed + seed as u64;
            cfg.verbose = false;
            let report = run(&cfg).expect("run");
            params = report.param_count;
            vals.push(report.best_val_acc);
            tests.push(report.test.accuracy());
            println!(
                "  {arch} seed {seed}: val {:.4} test {:.4} ({:.1} steps/s)",
                report.best_val_acc,
                report.test.accuracy(),
                report.train_steps_per_sec
            );
        }
        rows.push((arch, params, fmt_mean_std(&vals), fmt_mean_std(&tests)));
    }

    println!("\nmodel              # params      validation          test");
    for (arch, params, val, test) in &rows {
        let label = match *arch {
            "mha" => "MHA (hgt-like)",
            _ => "MPNN (tf-gnn)",
        };
        println!("{label:<18} {params:>8}   {val:>16}   {test:>16}");
    }
    println!("\n(paper: HGT 26.8M val 0.5124 test 0.4982 | MPNN 5.89M val 0.5149 test 0.5027)");
}
