//! Micro-bench: API level 2 data-exchange ops (experiment µ in
//! DESIGN.md) — broadcast/pool/softmax cost vs edge count and feature
//! width, fused vs unfused message passing at 1..N threads, plus
//! merge/pad pipeline-stage costs. Rows land in `BENCH_graph_ops.json`
//! for the perf-tracking CI lane.
//!
//! Run: `cargo bench --bench graph_ops`
//! (set `TFGNN_BENCH_SMOKE=1` for the short CI mode).

use std::sync::Arc;

use tfgnn::graph::batch::merge;
use tfgnn::graph::pad::{pad, PadSpec};
use tfgnn::graph::{Adjacency, Context, EdgeSet, Feature, GraphTensor, NodeSet};
use tfgnn::ops::{
    broadcast_node_to_edges, broadcast_pool_fused, pool_edges_to_node, segment_softmax,
    softmax_weighted_pool_fused, ParallelOps, Reduce, Tag,
};
use tfgnn::util::rng::Rng;
use tfgnn::util::stats::{smoke, Bench, BenchReport};
use tfgnn::util::threadpool::ThreadPool;

fn bipartite(n_nodes: usize, n_edges: usize, dim: usize, rng: &mut Rng) -> GraphTensor {
    let a = NodeSet::new(vec![n_nodes]).with_feature(
        "h",
        Feature::f32_mat(dim, (0..n_nodes * dim).map(|_| rng.f32()).collect()),
    );
    let b = NodeSet::new(vec![n_nodes]).with_feature(
        "h",
        Feature::f32_mat(dim, (0..n_nodes * dim).map(|_| rng.f32()).collect()),
    );
    let e = EdgeSet::new(
        vec![n_edges],
        Adjacency {
            source_set: "a".into(),
            target_set: "b".into(),
            source: (0..n_edges).map(|_| rng.uniform(n_nodes) as u32).collect(),
            target: (0..n_edges).map(|_| rng.uniform(n_nodes) as u32).collect(),
        },
    );
    GraphTensor::from_pieces(
        Context::default(),
        [("a".to_string(), a), ("b".to_string(), b)].into(),
        [("e".to_string(), e)].into(),
    )
    .unwrap()
}

fn main() {
    let bench = Bench::from_env(3, 15);
    let mut rng = Rng::new(42);
    let mut report = BenchReport::new("graph_ops");

    println!("# broadcast / pool / softmax over one edge set");
    let base_sizes: &[(usize, usize, usize)] = if smoke() {
        &[(1_000, 10_000, 32)]
    } else {
        &[(1_000, 10_000, 32), (10_000, 100_000, 32), (10_000, 100_000, 128)]
    };
    for &(n_nodes, n_edges, dim) in base_sizes {
        let g = bipartite(n_nodes, n_edges, dim, &mut rng);
        let h = g.node_set("a").unwrap().feature("h").unwrap().clone();
        let label = format!("n={n_nodes} e={n_edges} d={dim}");

        let s = bench.throughput(n_edges, || {
            let _ = broadcast_node_to_edges(&g, "e", Tag::Source, &h).unwrap();
        });
        report.row("broadcast_node_to_edges", &label, 1, &s, "items/s");

        let on_edges = broadcast_node_to_edges(&g, "e", Tag::Source, &h).unwrap();
        for reduce in [Reduce::Sum, Reduce::Mean, Reduce::Max] {
            let s = bench.throughput(n_edges, || {
                let _ = pool_edges_to_node(&g, "e", Tag::Target, reduce, &on_edges).unwrap();
            });
            report.row(
                &format!("pool_edges_to_node/{}", reduce.name()),
                &label,
                1,
                &s,
                "items/s",
            );
        }

        let logits = Feature::f32_vec((0..n_edges).map(|_| rng.range_f32(-4.0, 4.0)).collect());
        let s = bench.throughput(n_edges, || {
            let _ = segment_softmax(&g, "e", Tag::Target, &logits).unwrap();
        });
        report.row("segment_softmax", &label, 1, &s, "items/s");
    }

    // ------------------------------------------------------------------
    // Fused broadcast→pool vs the unfused two-step sequence, serial and
    // sharded across the ThreadPool. The large setting is MAG-sized: a
    // sampled-subgraph epoch's worth of message passing (1M edges over
    // 100K nodes, d=32) — the acceptance workload of PR 1.
    // ------------------------------------------------------------------
    println!("\n# fused broadcast→pool message passing (vs unfused, 1..N threads)");
    let fused_sizes: &[(usize, usize, usize, &str)] = if smoke() {
        &[(10_000, 100_000, 32, "e=100K")]
    } else {
        &[(10_000, 100_000, 32, "e=100K"), (100_000, 1_000_000, 32, "mag-sized e=1M")]
    };
    for &(n_nodes, n_edges, dim, tag) in fused_sizes {
        let g = bipartite(n_nodes, n_edges, dim, &mut rng);
        let h = g.node_set("a").unwrap().feature("h").unwrap().clone();
        let label = format!("{tag} n={n_nodes} d={dim}");

        let s = bench.throughput(n_edges, || {
            let on_edges = broadcast_node_to_edges(&g, "e", Tag::Source, &h).unwrap();
            let _ = pool_edges_to_node(&g, "e", Tag::Target, Reduce::Sum, &on_edges).unwrap();
        });
        report.row("bp/sum/unfused", &label, 1, &s, "items/s");

        let s = bench.throughput(n_edges, || {
            let _ =
                broadcast_pool_fused(&g, "e", Tag::Source, Tag::Target, Reduce::Sum, &h).unwrap();
        });
        report.row("bp/sum/fused", &label, 1, &s, "items/s");

        for threads in [2usize, 4, 8] {
            let par = ParallelOps::new(Arc::new(ThreadPool::new(threads)));
            let s = bench.throughput(n_edges, || {
                let _ = par
                    .broadcast_pool_fused(&g, "e", Tag::Source, Tag::Target, Reduce::Sum, &h)
                    .unwrap();
            });
            report.row("bp/sum/fused", &label, threads, &s, "items/s");
        }

        // Attention: softmax over receiver groups + weighted pool.
        let logits = Feature::f32_vec((0..n_edges).map(|_| rng.range_f32(-4.0, 4.0)).collect());
        let s = bench.throughput(n_edges, || {
            let w = segment_softmax(&g, "e", Tag::Target, &logits).unwrap();
            let msgs = broadcast_node_to_edges(&g, "e", Tag::Source, &h).unwrap();
            let (mdims, mv) = msgs.as_f32().unwrap();
            let (_, wv) = w.as_f32().unwrap();
            let weighted = Feature::F32 {
                dims: mdims.to_vec(),
                data: mv.iter().enumerate().map(|(i, &x)| wv[i / dim] * x).collect(),
            };
            let _ = pool_edges_to_node(&g, "e", Tag::Target, Reduce::Sum, &weighted).unwrap();
        });
        report.row("attn/unfused", &label, 1, &s, "items/s");

        let s = bench.throughput(n_edges, || {
            let _ =
                softmax_weighted_pool_fused(&g, "e", Tag::Source, Tag::Target, &logits, &h)
                    .unwrap();
        });
        report.row("attn/fused", &label, 1, &s, "items/s");

        for threads in [4usize, 8] {
            let par = ParallelOps::new(Arc::new(ThreadPool::new(threads)));
            let s = bench.throughput(n_edges, || {
                let _ = par
                    .softmax_weighted_pool_fused(&g, "e", Tag::Source, Tag::Target, &logits, &h)
                    .unwrap();
            });
            report.row("attn/fused", &label, threads, &s, "items/s");
        }
    }

    println!("\n# batching stages: merge + pad (pipeline hot path)");
    for &batch_size in &[8usize, 32] {
        let graphs: Vec<GraphTensor> =
            (0..batch_size).map(|_| bipartite(200, 1_000, 64, &mut rng)).collect();
        let label = format!("batch={batch_size} n=200 e=1000 d=64");
        let s = bench.throughput(batch_size, || {
            let _ = merge(&graphs).unwrap();
        });
        report.row("merge", &label, 1, &s, "items/s");

        let merged = merge(&graphs).unwrap();
        let spec = PadSpec::fit(&graphs.iter().collect::<Vec<_>>(), batch_size, 1.3);
        let s = bench.throughput(batch_size, || {
            let _ = pad(&merged, &spec).unwrap();
        });
        report.row("pad", &label, 1, &s, "items/s");
    }

    let path = report.write().expect("write bench json");
    println!("\nwrote {}", path.display());
}
