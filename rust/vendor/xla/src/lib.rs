//! Offline stub of the `xla` crate (xla-rs 0.1.6), covering exactly the
//! API subset `tfgnn` uses.
//!
//! The build image does not vendor the real PJRT bindings (they bundle
//! `libxla_extension`, hundreds of MB of native code), so this crate
//! keeps the workspace compiling and testable offline:
//!
//! * host-side pieces ([`Literal`], buffers, shapes) are implemented
//!   for real — uploads, downloads and reshape round-trip correctly;
//! * anything that would need the XLA compiler or PJRT runtime
//!   ([`PjRtClient::compile`], [`PjRtLoadedExecutable::execute_b`])
//!   returns an [`Error`] explaining the stub, so callers degrade
//!   gracefully (the integration tests already skip when `artifacts/`
//!   is absent).
//!
//! Swapping in the real crate is a one-line change in `Cargo.toml`; no
//! `tfgnn` source references differ between the two.

use std::path::Path;

/// Error type mirroring `xla::Error` (an opaque message here).
#[derive(Debug)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn stub_err<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: XLA/PJRT is unavailable in this build (offline `xla` stub); \
         vendor the real xla-rs crate to execute AOT programs"
    )))
}

/// Primitive element types (subset + placeholders so matches on the
/// real crate's wider enum stay non-trivial).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S8,
    S32,
    S64,
    F16,
    F32,
    F64,
}

/// Rust-native scalar types that map onto an [`ElementType`].
pub trait NativeType: Copy {
    fn element_type() -> ElementType;
    fn write(values: &[Self], out: &mut Vec<u8>);
    fn read(bytes: &[u8]) -> Vec<Self>;
}

macro_rules! native {
    ($t:ty, $et:expr) => {
        impl NativeType for $t {
            fn element_type() -> ElementType {
                $et
            }
            fn write(values: &[Self], out: &mut Vec<u8>) {
                for v in values {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
            fn read(bytes: &[u8]) -> Vec<Self> {
                bytes
                    .chunks_exact(std::mem::size_of::<$t>())
                    .map(|c| <$t>::from_le_bytes(c.try_into().unwrap()))
                    .collect()
            }
        }
    };
}

native!(f32, ElementType::F32);
native!(i32, ElementType::S32);
native!(i64, ElementType::S64);

/// Dense array shape: element type + dimensions.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArrayShape {
    ty: ElementType,
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn ty(&self) -> ElementType {
        self.ty
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// A host-side literal: shape + raw little-endian bytes.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    shape: ArrayShape,
    bytes: Vec<u8>,
}

impl Literal {
    /// Rank-1 literal from a native slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        let mut bytes = Vec::with_capacity(std::mem::size_of::<T>() * data.len());
        T::write(data, &mut bytes);
        Literal {
            shape: ArrayShape { ty: T::element_type(), dims: vec![data.len() as i64] },
            bytes,
        }
    }

    /// Same data viewed under new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let new_count: i64 = dims.iter().product();
        let old_count: i64 = self.shape.dims.iter().product();
        if new_count != old_count {
            return Err(Error(format!(
                "reshape: {old_count} elements into dims {dims:?} ({new_count})"
            )));
        }
        Ok(Literal {
            shape: ArrayShape { ty: self.shape.ty, dims: dims.to_vec() },
            bytes: self.bytes.clone(),
        })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(self.shape.clone())
    }

    pub fn element_count(&self) -> usize {
        self.shape.dims.iter().product::<i64>() as usize
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if T::element_type() != self.shape.ty {
            return Err(Error(format!(
                "to_vec: literal is {:?}, requested {:?}",
                self.shape.ty,
                T::element_type()
            )));
        }
        Ok(T::read(&self.bytes))
    }

    /// Tuple literals never exist in the stub (they are produced only by
    /// program execution), so decomposition always fails.
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        stub_err("decompose_tuple")
    }
}

/// Parsed HLO module text (opaque; the stub only checks the file reads).
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    _text: String,
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| Error(format!("{}: {e}", path.as_ref().display())))?;
        Ok(HloModuleProto { _text: text })
    }
}

/// An XLA computation built from a proto.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    _proto: HloModuleProto,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _proto: proto.clone() }
    }
}

/// The PJRT client. Creation succeeds (host-side transfers work);
/// compilation requires the real runtime.
#[derive(Debug, Clone)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        stub_err("compile")
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        data: &[T],
        dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        let dims64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
        Ok(PjRtBuffer { literal: Literal::vec1(data).reshape(&dims64)? })
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        literal: &Literal,
    ) -> Result<PjRtBuffer> {
        Ok(PjRtBuffer { literal: literal.clone() })
    }
}

/// A device buffer (host memory in the stub).
#[derive(Debug, Clone)]
pub struct PjRtBuffer {
    literal: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.literal.clone())
    }
}

/// A compiled executable. Unconstructible through the stub (compile
/// fails), so execution is unreachable — but keeps call sites compiling.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        stub_err("execute_b")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let lit = lit.reshape(&[2, 3]).unwrap();
        let shape = lit.array_shape().unwrap();
        assert_eq!(shape.ty(), ElementType::F32);
        assert_eq!(shape.dims(), &[2, 3]);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(lit.to_vec::<i32>().is_err());
        assert!(lit.reshape(&[7]).is_err());
    }

    #[test]
    fn buffers_copy_through_host() {
        let client = PjRtClient::cpu().unwrap();
        let buf = client.buffer_from_host_buffer::<i64>(&[7, 8], &[2], None).unwrap();
        let lit = buf.to_literal_sync().unwrap();
        assert_eq!(lit.to_vec::<i64>().unwrap(), vec![7, 8]);
    }

    #[test]
    fn compile_reports_stub() {
        let client = PjRtClient::cpu().unwrap();
        let proto = HloModuleProto { _text: String::new() };
        let comp = XlaComputation::from_proto(&proto);
        let err = client.compile(&comp).unwrap_err();
        assert!(err.to_string().contains("stub"), "{err}");
    }
}
