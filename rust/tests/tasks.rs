//! Task subsystem: parity and learning tests (no artifacts needed —
//! everything here is pure Rust over the tiny synth MAG).
//!
//! The contracts asserted here gate the tasks bench (which re-checks
//! them before timing), for the two *new* objectives (root
//! classification's bit parity predates the subsystem and is pinned by
//! `tests/native_training.rs`, which passes unmodified):
//! * one `NativeTrainer` step at 1 thread is **bit-for-bit** the serial
//!   oracle (`train_step_oracle_task`) — loss and every parameter;
//! * the 4-thread loss trajectory matches serial within 1e-5 relative,
//!   and the per-step loss is bit-stable across thread counts;
//! * link prediction trains end-to-end with decreasing loss and a
//!   reported MRR; graph regression drives its MSE down;
//! * the shipped `configs/mag_small_linkpred.json` parses through the
//!   same config funnel every entry point uses.

use std::sync::Arc;

use tfgnn::graph::pad::{fit_or_skip, PadSpec, Padded};
use tfgnn::ops::model_ref::{ModelConfig, TaskConfig};
use tfgnn::sampler::inmem::InMemorySampler;
use tfgnn::sampler::spec::mag_sampling_spec_scaled;
use tfgnn::synth::mag::{edge_holdout, generate, MagConfig};
use tfgnn::tasks::link_prediction::pair_example;
use tfgnn::tasks::Task;
use tfgnn::train::native::{train_step_oracle_task, Adam, AdamConfig, NativeModel, NativeTrainer};

const BATCH: usize = 4;

fn rel_diff(a: f32, b: f32) -> f64 {
    let (a, b) = (a as f64, b as f64);
    (a - b).abs() / a.abs().max(b.abs()).max(1e-12)
}

fn linkpred_task_cfg(readout: &str, loss: &str) -> TaskConfig {
    TaskConfig {
        kind: "link_prediction".into(),
        edge_set: "cites".into(),
        readout: readout.into(),
        loss: loss.into(),
        margin: 1.0,
        negatives: 2,
        hits_k: 2,
        mlp_dim: 8,
        holdout_fraction: 0.25,
        split_seed: 9,
        ..TaskConfig::default()
    }
}

/// Pair-subgraph padded batches over the tiny MAG's edge holdout.
fn linkpred_batches(tcfg: &TaskConfig, count: usize) -> Vec<Padded> {
    let ds = generate(&MagConfig::tiny());
    let num_papers = ds.config.num_papers;
    let holdout = edge_holdout(&ds, &tcfg.edge_set, tcfg.holdout_fraction, tcfg.split_seed)
        .expect("holdout");
    let store = Arc::new(holdout.store);
    let spec = mag_sampling_spec_scaled(&store.schema, 0.2).unwrap();
    let sampler = InMemorySampler::new(store, spec, 3).unwrap();
    let example = |&(u, v): &(u32, u32)| {
        pair_example(&sampler, u, v, num_papers, tcfg.negatives, tcfg.split_seed).unwrap()
    };
    let probe: Vec<_> = holdout.train.iter().take(6).map(example).collect();
    let pad = PadSpec::fit(&probe.iter().collect::<Vec<_>>(), BATCH, 2.5);
    let mut out = Vec::new();
    let mut at = 0usize;
    while out.len() < count {
        assert!(
            at + BATCH <= holdout.train.len(),
            "could not assemble {count} fitting pair batches"
        );
        let graphs: Vec<_> = holdout.train[at..at + BATCH].iter().map(example).collect();
        at += BATCH;
        let merged = tfgnn::graph::batch::merge(&graphs).unwrap();
        if let Some(p) = fit_or_skip(&merged, &pad) {
            out.push(p);
        }
    }
    out
}

/// Seed-rooted padded batches (regression examples).
fn seed_batches(count: usize) -> Vec<Padded> {
    let ds = generate(&MagConfig::tiny());
    let store = Arc::new(ds.store);
    let spec = mag_sampling_spec_scaled(&store.schema, 0.2).unwrap();
    let sampler = InMemorySampler::new(store, spec, 3).unwrap();
    let probe: Vec<_> = (0..12u32).map(|s| sampler.sample(s).unwrap()).collect();
    let pad = PadSpec::fit(&probe.iter().collect::<Vec<_>>(), BATCH, 2.5);
    let mut out = Vec::new();
    let mut seed = 0u32;
    while out.len() < count {
        let graphs: Vec<_> =
            (0..BATCH).map(|i| sampler.sample(seed + i as u32).unwrap()).collect();
        seed += BATCH as u32;
        let merged = tfgnn::graph::batch::merge(&graphs).unwrap();
        if let Some(p) = fit_or_skip(&merged, &pad) {
            out.push(p);
        }
        assert!(seed < 120, "could not assemble {count} fitting batches");
    }
    out
}

fn regression_cfg() -> ModelConfig {
    let t = TaskConfig {
        kind: "graph_regression".into(),
        target_feature: "year".into(),
        target_shift: 2010.0,
        target_scale: 0.1,
        ..TaskConfig::default()
    };
    ModelConfig::for_mag(&MagConfig::tiny(), 8, 8, 2).with_task(t)
}

/// Shared parity harness: 1-thread == serial oracle bit-for-bit (loss,
/// metrics, every parameter, across consecutive steps), 4-thread loss
/// within 1e-5 rel with a bit-stable per-step loss.
fn assert_task_parity(cfg: &ModelConfig, batches: &[Padded], tag: &str) {
    let adam = AdamConfig::default();
    let task: Arc<dyn Task> = tfgnn::tasks::build(cfg).unwrap();
    let mut oracle_model = NativeModel::init(cfg.clone(), 11).unwrap();
    let mut oracle_opt = Adam::new(adam, &oracle_model.params);
    let mut t1 = NativeTrainer::with_task(
        NativeModel::init(cfg.clone(), 11).unwrap(),
        adam,
        Arc::clone(&task),
        1,
    );
    let mut serial_losses = Vec::new();
    for (step, b) in batches.iter().enumerate() {
        let mo =
            train_step_oracle_task(&mut oracle_model, &mut oracle_opt, b, task.as_ref()).unwrap();
        let mt = t1.train_batch(b).unwrap();
        assert_eq!(mt.loss.to_bits(), mo.loss.to_bits(), "{tag} step {step} loss");
        assert_eq!(mt.correct, mo.correct, "{tag} step {step} correct");
        assert_eq!(mt.weight, mo.weight, "{tag} step {step} weight");
        assert_eq!(mt.task, mo.task, "{tag} step {step} task metrics");
        for ((name, a), b) in
            t1.model().names.iter().zip(&t1.model().params).zip(&oracle_model.params)
        {
            for (x, y) in a.data.iter().zip(&b.data) {
                assert_eq!(x.to_bits(), y.to_bits(), "{tag} step {step} param {name}");
            }
        }
        serial_losses.push(mt.loss);
    }
    for threads in [2usize, 4] {
        let mut t = NativeTrainer::with_task(
            NativeModel::init(cfg.clone(), 11).unwrap(),
            adam,
            Arc::clone(&task),
            threads,
        );
        for (step, b) in batches.iter().enumerate() {
            let m = t.train_batch(b).unwrap();
            let d = rel_diff(m.loss, serial_losses[step]);
            assert!(
                d <= 1e-5,
                "{tag} threads={threads} step={step}: loss {} vs serial {} (rel {d:.2e})",
                m.loss,
                serial_losses[step]
            );
        }
        // Eval loss is bit-stable across thread counts (in-order sum).
        let e1 = NativeTrainer::with_task(
            NativeModel::init(cfg.clone(), 11).unwrap(),
            adam,
            Arc::clone(&task),
            1,
        )
        .eval_batch(&batches[0])
        .unwrap();
        let ep = NativeTrainer::with_task(
            NativeModel::init(cfg.clone(), 11).unwrap(),
            adam,
            Arc::clone(&task),
            threads,
        )
        .eval_batch(&batches[0])
        .unwrap();
        assert_eq!(e1.loss.to_bits(), ep.loss.to_bits(), "{tag} eval loss thread-stable");
    }
}

#[test]
fn link_prediction_parity_across_threads() {
    for (readout, loss) in [("dot", "softmax"), ("hadamard", "margin")] {
        let tcfg = linkpred_task_cfg(readout, loss);
        let batches = linkpred_batches(&tcfg, 3);
        let cfg = ModelConfig::for_mag(&MagConfig::tiny(), 8, 8, 2).with_task(tcfg);
        assert_task_parity(&cfg, &batches, &format!("linkpred/{readout}/{loss}"));
    }
}

#[test]
fn graph_regression_parity_across_threads() {
    let batches = seed_batches(3);
    assert_task_parity(&regression_cfg(), &batches, "graphreg");
}

/// Link prediction actually trains: over repeated passes the loss ends
/// clearly below its start and the model reports a real MRR that beats
/// the random-ranking baseline on its training pairs.
#[test]
fn link_prediction_trains_with_decreasing_loss_and_mrr() {
    let tcfg = linkpred_task_cfg("hadamard", "softmax");
    let batches = linkpred_batches(&tcfg, 4);
    let cfg = ModelConfig::for_mag(&MagConfig::tiny(), 8, 8, 2).with_task(tcfg.clone());
    let model = NativeModel::init(cfg.clone(), 13).unwrap();
    let task = tfgnn::tasks::build(&cfg).unwrap();
    let adam = AdamConfig { lr: 0.01, ..AdamConfig::default() };
    let mut trainer = NativeTrainer::with_task(model, adam, task, 2);
    let mut first = 0.0f32;
    let mut last = 0.0f32;
    let mut last_metrics = tfgnn::train::metrics::TaskMetrics::default();
    for step in 0..40 {
        let m = trainer.train_batch(&batches[step % batches.len()]).unwrap();
        if step == 0 {
            first = m.loss;
        }
        last = m.loss;
        last_metrics = m.task;
        assert!(m.loss.is_finite(), "step {step}: loss diverged");
        assert!(m.task.scored > 0.0, "step {step}: examples scored");
        assert!(m.task.rr_sum > 0.0, "step {step}: MRR reported");
    }
    assert!(last < 0.8 * first, "loss did not drop (first {first}, last {last})");
    // Candidates = 1 positive + 2 negatives → random MRR ≈ 0.61. After
    // 10 passes over 16 training pairs the model should rank its own
    // training pairs clearly better than chance.
    let mrr = last_metrics.rr_sum / last_metrics.scored;
    assert!(mrr > 0.65, "trained MRR {mrr} barely beats random (~0.61)");
}

/// Graph regression actually trains: the MSE trajectory is finite and
/// ends clearly below its start.
#[test]
fn graph_regression_trains_with_decreasing_mse() {
    let batches = seed_batches(4);
    let cfg = regression_cfg();
    let model = NativeModel::init(cfg.clone(), 13).unwrap();
    let task = tfgnn::tasks::build(&cfg).unwrap();
    let adam = AdamConfig { lr: 0.01, ..AdamConfig::default() };
    let mut trainer = NativeTrainer::with_task(model, adam, task, 2);
    let mut first = 0.0f32;
    let mut last = 0.0f32;
    for step in 0..40 {
        let m = trainer.train_batch(&batches[step % batches.len()]).unwrap();
        if step == 0 {
            first = m.loss;
        }
        last = m.loss;
        assert!(m.loss.is_finite(), "step {step}: loss diverged");
        assert!(m.task.se_sum >= 0.0 && m.task.scored > 0.0);
    }
    assert!(last < 0.8 * first, "MSE did not drop (first {first}, last {last})");
}

/// The shipped link-prediction config parses through the same funnel
/// every entry point uses, with the task block fully validated.
#[test]
fn shipped_linkpred_config_parses() {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("../configs/mag_small_linkpred.json");
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
    let cfg = ModelConfig::from_config(&tfgnn::util::json::Json::parse(&text).unwrap()).unwrap();
    assert_eq!(cfg.task.kind, "link_prediction");
    assert_eq!(cfg.task.edge_set, "cites");
    assert_eq!(cfg.task.readout, "hadamard");
    assert_eq!(cfg.task.negatives, 4);
    // The task builds and defines the Hadamard head over this config.
    let task = tfgnn::tasks::build(&cfg).unwrap();
    assert_eq!(task.name(), "link_prediction");
    let head = tfgnn::tasks::head_params(&cfg).unwrap();
    assert_eq!(head.iter().map(|h| h.name).collect::<Vec<_>>(), vec![
        "lp.w", "lp.b", "lp.v", "lp.c"
    ]);
}
