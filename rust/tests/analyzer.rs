//! Mutation corpus for the static analyzer (`tfgnn check`).
//!
//! Every shipped `configs/*.json` must pass the analyzer with zero
//! diagnostics, and every seeded text-level mutation must come back
//! with its expected stable `TFGNN0xx` code at its expected JSON path
//! — no false negatives on defects, no noise on clean configs. The
//! corpus also pins `docs/diagnostics.md` to the source-of-truth code
//! table in `analysis::diag`.

use std::collections::BTreeSet;

use tfgnn::analysis::diag::{codes, render_markdown, CODES};
use tfgnn::analysis::{analyze, analyze_against_checkpoint, Diagnostics, ModelPlan, Severity};
use tfgnn::runtime::HostTensor;
use tfgnn::util::json::Json;

const SHIPPED: &[&str] = &["mag_small.json", "mag_small_gatv2.json", "mag_small_linkpred.json"];

fn read(name: &str) -> String {
    let path = format!("../configs/{name}");
    std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("{path}: {e}"))
}

fn analyze_text(text: &str) -> Diagnostics {
    analyze(&Json::parse(text).expect("mutated config still parses"))
}

/// Apply a text-level mutation, insisting it actually applies — a
/// silently-no-op mutation would turn a corpus case into a vacuous
/// clean-config check.
fn mutate(base: &str, from: &str, to: &str) -> String {
    assert!(base.contains(from), "mutation source {from:?} not found in config text");
    base.replace(from, to)
}

#[test]
fn every_shipped_config_passes_clean() {
    for name in SHIPPED {
        let d = analyze_text(&read(name));
        assert!(d.is_empty(), "{name} should produce no diagnostics at all:\n{d}");
    }
}

/// One seeded defect: a text replacement on a shipped config and the
/// error code + JSON path the analyzer must report for it.
struct Case {
    name: &'static str,
    file: &'static str,
    from: &'static str,
    to: &'static str,
    code: &'static str,
    path: &'static str,
}

const S: &str = "mag_small.json";
const L: &str = "mag_small_linkpred.json";

#[rustfmt::skip]
const CASES: &[Case] = &[
    Case { name: "zero hidden width", file: S,
           from: r#""hidden_dim": 64"#, to: r#""hidden_dim": 0"#,
           code: codes::BAD_DIM, path: "$.model.hidden_dim" },
    Case { name: "zero layer count", file: S,
           from: r#""num_layers": 2"#, to: r#""num_layers": 0"#,
           code: codes::BAD_DIM, path: "$.model.num_layers" },
    Case { name: "zero message width", file: S,
           from: r#""message_dim": 64"#, to: r#""message_dim": 0"#,
           code: codes::BAD_DIM, path: "$.model.hidden_dim" },
    Case { name: "model key typo", file: S,
           from: r#""dropout""#, to: r#""dropoutt""#,
           code: codes::UNKNOWN_KEY, path: "$.model.dropoutt" },
    Case { name: "type and arch disagree", file: S,
           from: r#""arch": "mpnn""#, to: r#""arch": "gcn""#,
           code: codes::ARCH_CONFLICT, path: "$.model.type" },
    Case { name: "AOT arch without native type", file: S,
           from: "\"arch\": \"mpnn\",\n    \"type\": \"mpnn\",",
           to: "\"arch\": \"gatv2\",",
           code: codes::ARCH_CONFLICT, path: "$.model.arch" },
    Case { name: "unknown model type", file: L,
           from: r#""type": "mpnn","#, to: r#""type": "transformer","#,
           code: codes::UNKNOWN_ENUM, path: "$.model.type" },
    Case { name: "unknown sage reduction", file: L,
           from: r#""type": "mpnn","#, to: r#""type": "sage", "sage_reduce": "median","#,
           code: codes::UNKNOWN_ENUM, path: "$.model.sage_reduce" },
    Case { name: "update pools dangling edge set", file: S,
           from: r#"["cites", "written", "has_topic"]"#,
           to: r#"["cites", "written", "has_topic", "cities"]"#,
           code: codes::UNKNOWN_EDGE_SET, path: "$.model.updates.paper" },
    Case { name: "update pools an edge set twice", file: S,
           from: r#"["cites", "written", "has_topic"]"#,
           to: r#"["cites", "cites", "written", "has_topic"]"#,
           code: codes::DUPLICATE_POOL, path: "$.model.updates.paper" },
    Case { name: "receiver is the target endpoint", file: S,
           from: r#"["writes", "affiliated_with"]"#,
           to: r#"["written", "affiliated_with"]"#,
           code: codes::RECEIVER_NOT_SOURCE, path: "$.model.updates.author" },
    Case { name: "swapped schema endpoints", file: S,
           from: r#""writes": ["author", "paper"],"#,
           to: r#""writes": ["paper", "author"],"#,
           code: codes::RECEIVER_NOT_SOURCE, path: "$.model.updates.author" },
    Case { name: "edge set references unknown node set", file: S,
           from: r#""written": ["paper", "author"],"#,
           to: r#""written": ["paper", "reviewer"],"#,
           code: codes::UNKNOWN_NODE_SET, path: "$.schema.edge_sets.written" },
    Case { name: "unknown pair readout", file: L,
           from: r#""readout": "hadamard","#, to: r#""readout": "bilinear","#,
           code: codes::UNKNOWN_ENUM, path: "$.task.readout" },
    Case { name: "zero negatives", file: L,
           from: r#""negatives": 4,"#, to: r#""negatives": 0,"#,
           code: codes::BAD_TASK_KNOB, path: "$.task.negatives" },
    Case { name: "holdout fraction out of range", file: L,
           from: r#""holdout_fraction": 0.1,"#, to: r#""holdout_fraction": 1.5,"#,
           code: codes::BAD_TASK_KNOB, path: "$.task.holdout_fraction" },
    Case { name: "task key typo", file: L,
           from: r#""negatives""#, to: r#""negativs""#,
           code: codes::UNKNOWN_KEY, path: "$.task.negativs" },
    Case { name: "heterogeneous link-prediction edge set", file: L,
           from: r#""edge_set": "cites","#, to: r#""edge_set": "written","#,
           code: codes::BAD_TASK_KNOB, path: "$.task.edge_set" },
    Case { name: "unknown link-prediction edge set", file: L,
           from: r#""edge_set": "cites","#, to: r#""edge_set": "collabs","#,
           code: codes::UNKNOWN_EDGE_SET, path: "$.task.edge_set" },
    Case { name: "dataset feature width disagrees with schema", file: S,
           from: r#""feature_dim": 128,"#, to: r#""feature_dim": 64,"#,
           code: codes::SHAPE_MISMATCH, path: "$.dataset.feature_dim" },
    Case { name: "class count disagrees with dataset labels", file: S,
           from: "\"num_classes\": 20,\n    \"init_seed\"",
           to: "\"num_classes\": 7,\n    \"init_seed\"",
           code: codes::SHAPE_MISMATCH, path: "$.train.num_classes" },
    Case { name: "embedding table smaller than entity count", file: S,
           from: r#""cardinality": 200"#, to: r#""cardinality": 100"#,
           code: codes::SHAPE_MISMATCH,
           path: "$.schema.node_sets.institution.cardinality" },
    Case { name: "zero-width schema feature", file: S,
           from: r#""feat": 128"#, to: r#""feat": 0"#,
           code: codes::BAD_DIM, path: "$.schema.node_sets.paper.features.feat" },
    Case { name: "component cap cannot hold the batch", file: S,
           from: r#""component_cap": 9"#, to: r#""component_cap": 5"#,
           code: codes::PAD_SPEC, path: "$.pad.component_cap" },
    Case { name: "pad cap dropped for one edge set", file: S,
           from: "\"cites\": 80,\n      ", to: "",
           code: codes::PAD_SPEC, path: "$.pad.edge_caps" },
    Case { name: "zero sampling fan-out", file: S,
           from: r#""cites": 8,"#, to: r#""cites": 0,"#,
           code: codes::SAMPLING_SPEC, path: "$.sampling.sizes.cites" },
    Case { name: "sampling size dropped for a planned edge set", file: S,
           from: "\"affiliated_with\": 4,\n      \"has_topic\": 4",
           to: "\"affiliated_with\": 4",
           code: codes::SAMPLING_SPEC, path: "$.sampling.sizes" },
    Case { name: "dataset block missing a generator knob", file: S,
           from: r#""seed": 17"#, to: r#""seedling": 17"#,
           code: codes::CONFIG, path: "$.dataset.seed" },
    Case { name: "zero batch size", file: S,
           from: r#""batch_size": 8,"#, to: r#""batch_size": 0,"#,
           code: codes::BAD_DIM, path: "$.batch_size" },
    Case { name: "readout from a non-seed node set", file: S,
           from: "\"train\": {",
           to: "\"task\": {\"type\": \"root_classification\", \
                \"root_set\": \"institution\"},\n  \"train\": {",
           code: codes::UNREACHABLE_READOUT, path: "$.task.root_set" },
    Case { name: "readout from an undeclared node set", file: S,
           from: "\"train\": {",
           to: "\"task\": {\"type\": \"root_classification\", \
                \"root_set\": \"venue\"},\n  \"train\": {",
           code: codes::UNKNOWN_NODE_SET, path: "$.task.root_set" },
];

#[test]
fn mutation_corpus_each_defect_gets_its_code_and_path() {
    for c in CASES {
        let d = analyze_text(&mutate(&read(c.file), c.from, c.to));
        assert!(d.has_errors(), "{}: expected errors, got:\n{d}", c.name);
        let diag = d
            .find(c.code)
            .unwrap_or_else(|| panic!("{}: no {} diagnostic in:\n{d}", c.name, c.code));
        assert_eq!(diag.severity, Severity::Error, "{}", c.name);
        assert_eq!(diag.path, c.path, "{}: wrong path for {}", c.name, c.code);
    }
}

/// An edge set the model pools but the derived Figure-6 sampling plan
/// never expands: needs three coordinated edits (schema + updates +
/// pad cap), so it lives outside the single-replacement table.
#[test]
fn read_but_unsampled_edge_set_is_a_dead_set_error() {
    let text = mutate(
        &read(S),
        r#""cites": ["paper", "paper"],"#,
        "\"cites\": [\"paper\", \"paper\"],\n      \"cocites\": [\"paper\", \"paper\"],",
    );
    let text = mutate(
        &text,
        r#"["cites", "written", "has_topic"]"#,
        r#"["cites", "cocites", "written", "has_topic"]"#,
    );
    let text = mutate(&text, r#""cites": 80,"#, "\"cites\": 80,\n      \"cocites\": 8,");
    let d = analyze_text(&text);
    let diag = d.find(codes::DEAD_SET).unwrap_or_else(|| panic!("no TFGNN013 in:\n{d}"));
    assert_eq!(diag.severity, Severity::Error);
    assert_eq!(diag.path, "$.model.updates.paper");
    assert!(diag.message.contains("cocites"), "{}", diag.message);
}

/// Warnings report but never fail the gate: wasted fan-out, oversized
/// embedding tables, pad caps for unknown sets.
#[test]
fn warning_class_mutations_stay_clean() {
    let warning_cases: &[(&str, &str, &str, &str, &str)] = &[
        (
            "sampled but unread edge set",
            r#"["cites", "written", "has_topic"]"#,
            r#"["cites", "written"]"#,
            codes::DEAD_SET,
            "$.sampling.sizes.has_topic",
        ),
        (
            "oversized embedding table",
            r#""cardinality": 120"#,
            r#""cardinality": 500"#,
            codes::SHAPE_MISMATCH,
            "$.schema.node_sets.field_of_study.cardinality",
        ),
        (
            "pad cap for unknown node set",
            r#""paper": 512,"#,
            "\"paper\": 512,\n      \"venue\": 4,",
            codes::PAD_SPEC,
            "$.pad.node_caps.venue",
        ),
    ];
    for (name, from, to, code, path) in warning_cases {
        let d = analyze_text(&mutate(&read(S), from, to));
        let diag = d.find(code).unwrap_or_else(|| panic!("{name}: no {code} in:\n{d}"));
        assert_eq!(diag.severity, Severity::Warning, "{name}");
        assert_eq!(&diag.path, path, "{name}");
        assert!(d.is_clean(), "{name}: warnings must not fail the gate:\n{d}");
    }
}

#[test]
fn checkpoint_drift_is_flagged_and_a_faithful_one_is_clean() {
    let cfg = Json::parse(&read(S)).expect("config parses");
    let mut d = Diagnostics::default();
    let plan = ModelPlan::compile(&cfg, &mut d).expect("plan compiles");
    assert!(d.is_empty(), "{d}");
    let ckpt: Vec<(String, HostTensor)> = plan
        .params
        .iter()
        .map(|p| {
            (
                format!("param.{}", p.name),
                HostTensor::F32(vec![p.rows, p.cols], vec![0.0; p.rows * p.cols]),
            )
        })
        .collect();
    assert!(analyze_against_checkpoint(&cfg, &ckpt).is_empty(), "faithful checkpoint");
    let mut stale = ckpt.clone();
    stale.push(("param.l9.ghost.msg.w".into(), HostTensor::F32(vec![1, 1], vec![0.0])));
    let d = analyze_against_checkpoint(&cfg, &stale);
    let diag =
        d.find(codes::CHECKPOINT_MISMATCH).unwrap_or_else(|| panic!("no TFGNN016 in:\n{d}"));
    assert_eq!(diag.severity, Severity::Error);
    assert_eq!(diag.path, "$.model");
    assert!(diag.message.contains("l9.ghost.msg.w"), "{}", diag.message);
}

/// Builder validation catches duplicate pools before a plan ever
/// compiles, so the parameter-collision pass is exercised directly as
/// the defense-in-depth layer it is.
#[test]
fn param_collision_pass_flags_duplicate_names() {
    let cfg = Json::parse(&read(S)).expect("config parses");
    let mut d = Diagnostics::default();
    let mut plan = ModelPlan::compile(&cfg, &mut d).expect("plan compiles");
    assert!(d.is_empty(), "{d}");
    let first = plan.params[0].clone();
    plan.params.push(first);
    tfgnn::analysis::passes::param_pass(&plan, None, &mut d);
    let diag = d.find(codes::PARAM_COLLISION).unwrap_or_else(|| panic!("no TFGNN015 in:\n{d}"));
    assert_eq!(diag.path, "$.model");
}

/// Every released code appears somewhere in this corpus — a new code
/// without a corpus case is a hole in the no-false-negative story.
#[test]
fn corpus_covers_every_released_code() {
    let mut covered: BTreeSet<&str> = CASES.iter().map(|c| c.code).collect();
    covered.insert(codes::DEAD_SET); // read_but_unsampled_edge_set...
    covered.insert(codes::CHECKPOINT_MISMATCH); // checkpoint_drift...
    covered.insert(codes::PARAM_COLLISION); // param_collision_pass...
    for info in CODES {
        assert!(covered.contains(info.code), "{} has no corpus case", info.code);
    }
}

/// `docs/diagnostics.md` is generated from the code table — the two
/// must never drift.
#[test]
fn diagnostics_doc_matches_the_code_table() {
    let want = render_markdown();
    let got = std::fs::read_to_string("../docs/diagnostics.md")
        .expect("docs/diagnostics.md exists (generated from analysis::diag)");
    assert_eq!(
        got, want,
        "docs/diagnostics.md is stale — regenerate it from the table in \
         rust/src/analysis/diag.rs (render_markdown)"
    );
}
