//! Integration pins for the observability layer.
//!
//! Three contracts:
//!
//! * `docs/metrics.md` is byte-generated from the `METRICS` table in
//!   `obs::metrics` — the checked-in file and the code must agree.
//! * Enabling recording and tracing changes no observable bits:
//!   sampled subgraphs, trainer loss/params at 1/2/8 threads and
//!   served task outputs at 1/2/8 lanes are identical with
//!   observability off and on (the "inertness contract").
//! * The exported `METRICS_*.json` / `TRACE_*.json` artifacts match
//!   the schemas `tools/bench_compare.py` checks in CI.
//!
//! The recording/tracing switches are process-global, so every check
//! that toggles them lives in ONE `#[test]` — spreading them over
//! tests that the harness runs concurrently would race.

use std::sync::Arc;

use tfgnn::graph::pad::{fit_or_skip, PadSpec};
use tfgnn::graph::GraphTensor;
use tfgnn::obs::metrics::{names, MetricKind, MetricsSnapshot, METRICS, NUM_BUCKETS};
use tfgnn::ops::model_ref::ModelConfig;
use tfgnn::runtime::batch::RootTask;
use tfgnn::sampler::inmem::InMemorySampler;
use tfgnn::sampler::spec::mag_sampling_spec_scaled;
use tfgnn::serve::loadgen::{self, outputs_bit_identical, LoadGenConfig};
use tfgnn::serve::{serve_task, ServeConfig};
use tfgnn::synth::mag::{generate, MagConfig, Split};
use tfgnn::tasks::TaskOutput;
use tfgnn::train::native::{AdamConfig, NativeModel, NativeTrainer};
use tfgnn::util::json::Json;

#[test]
fn metrics_doc_matches_the_code_table() {
    let on_disk = std::fs::read_to_string("../docs/metrics.md")
        .expect("docs/metrics.md must exist (generated from the METRICS table)");
    assert_eq!(
        on_disk,
        tfgnn::obs::metrics::render_markdown(),
        "docs/metrics.md drifted from obs::metrics::METRICS; \
         regenerate it from render_markdown()"
    );
}

/// Six deterministic subgraphs off a fresh tiny-MAG sampler.
fn sampled_subgraphs() -> Vec<GraphTensor> {
    let mag = MagConfig::tiny();
    let ds = generate(&mag);
    let seeds = ds.papers_in_split(Split::Train);
    let store = Arc::new(ds.store);
    let spec = mag_sampling_spec_scaled(&store.schema, 0.2).unwrap();
    let sampler = InMemorySampler::new(store, spec, 3).unwrap();
    seeds.iter().take(6).map(|&s| sampler.sample(s).unwrap()).collect()
}

/// One train step on a fresh world; returns (loss bits, all param bits).
fn train_step_bits(threads: usize) -> (u32, Vec<u32>) {
    let mag = MagConfig::tiny();
    let ds = generate(&mag);
    let seeds = ds.papers_in_split(Split::Train);
    let store = Arc::new(ds.store);
    let spec = mag_sampling_spec_scaled(&store.schema, 0.2).unwrap();
    let sampler = InMemorySampler::new(store, spec, 3).unwrap();
    let batch = 4usize;
    let probe: Vec<_> = seeds.iter().take(8).map(|&s| sampler.sample(s).unwrap()).collect();
    let pad = PadSpec::fit(&probe.iter().collect::<Vec<_>>(), batch, 2.0);
    let graphs: Vec<_> = probe.iter().take(batch).cloned().collect();
    let merged = tfgnn::graph::batch::merge(&graphs).unwrap();
    let padded = fit_or_skip(&merged, &pad).expect("batch must fit its own pad spec");
    let cfg = ModelConfig::for_mag(&mag, 8, 8, 1);
    let model = NativeModel::init(cfg, 7).unwrap();
    let mut tr = NativeTrainer::new(model, AdamConfig::default(), RootTask::default(), threads);
    let m = tr.train_batch(&padded).unwrap();
    let bits =
        tr.model().params.iter().flat_map(|p| p.data.iter().map(|x| x.to_bits())).collect();
    (m.loss.to_bits(), bits)
}

/// Six served outputs off a fresh task server with `lanes` lanes.
fn served_outputs(lanes: usize) -> Vec<TaskOutput> {
    let mag = MagConfig::tiny();
    let ds = generate(&mag);
    let seeds = ds.papers_in_split(Split::Train);
    let store = Arc::new(ds.store);
    let spec = mag_sampling_spec_scaled(&store.schema, 0.2).unwrap();
    let sampler = Arc::new(InMemorySampler::new(store, spec, 3).unwrap());
    let cfg = ModelConfig::for_mag(&mag, 8, 8, 1);
    let task = tfgnn::tasks::build(&cfg).unwrap();
    let model = Arc::new(NativeModel::init(cfg, 7).unwrap());
    let handle =
        serve_task(model, sampler, task, ServeConfig { lanes, ..ServeConfig::default() })
            .unwrap();
    let outputs: Vec<TaskOutput> =
        seeds.iter().take(6).map(|&s| handle.predict(&[s]).unwrap().output).collect();
    handle.shutdown();
    outputs
}

#[test]
fn obs_on_changes_no_bits_and_exports_validate() {
    // ---- baseline: observability fully off -----------------------------
    tfgnn::obs::set_recording(false);
    tfgnn::obs::trace::set_enabled(false);

    let graphs_off = sampled_subgraphs();
    assert!(!graphs_off.is_empty());
    let train_off: Vec<_> = [1usize, 2, 8].iter().map(|&t| train_step_bits(t)).collect();
    let served_off: Vec<_> = [1usize, 2, 8].iter().map(|&l| served_outputs(l)).collect();

    // ---- same workloads with recording + tracing on --------------------
    tfgnn::obs::set_recording(true);
    tfgnn::obs::trace::set_enabled(true);
    let before = tfgnn::obs::metrics().snapshot();

    let graphs_on = sampled_subgraphs();
    let train_on: Vec<_> = [1usize, 2, 8].iter().map(|&t| train_step_bits(t)).collect();
    let served_on: Vec<_> = [1usize, 2, 8].iter().map(|&l| served_outputs(l)).collect();

    // A short concurrent closed loop so waves, queue depth and the
    // loadgen/level span all land in the export below.
    {
        let mag = MagConfig::tiny();
        let ds = generate(&mag);
        let seeds = ds.papers_in_split(Split::Train);
        let store = Arc::new(ds.store);
        let spec = mag_sampling_spec_scaled(&store.schema, 0.2).unwrap();
        let sampler = Arc::new(InMemorySampler::new(store, spec, 3).unwrap());
        let cfg = ModelConfig::for_mag(&mag, 8, 8, 1);
        let task = tfgnn::tasks::build(&cfg).unwrap();
        let model = Arc::new(NativeModel::init(cfg, 7).unwrap());
        let handle =
            serve_task(model, sampler, task, ServeConfig { lanes: 2, ..ServeConfig::default() })
                .unwrap();
        let lists: Vec<Vec<u32>> = seeds.iter().take(4).map(|&s| vec![s]).collect();
        let lg = LoadGenConfig { concurrency: vec![2], requests_per_client: 2 };
        loadgen::run(&handle, &lists, &lg).unwrap();
        handle.shutdown();
    }

    // ---- inertness: bit parity off vs on -------------------------------
    assert!(
        graphs_off == graphs_on,
        "sampled subgraphs changed with observability on"
    );
    for (&threads, ((loss_off, bits_off), (loss_on, bits_on))) in
        [1usize, 2, 8].iter().zip(train_off.iter().zip(&train_on))
    {
        assert_eq!(
            loss_off, loss_on,
            "trainer loss bits changed with observability on (threads={threads})"
        );
        assert!(
            bits_off == bits_on,
            "trainer param bits changed with observability on (threads={threads})"
        );
    }
    for (&lanes, (outs_off, outs_on)) in
        [1usize, 2, 8].iter().zip(served_off.iter().zip(&served_on))
    {
        assert_eq!(outs_off.len(), outs_on.len());
        for (a, b) in outs_off.iter().zip(outs_on) {
            assert!(
                outputs_bit_identical(a, b),
                "served output changed with observability on (lanes={lanes}): {a:?} != {b:?}"
            );
        }
    }

    // ---- the instrumentation actually moved ----------------------------
    // `>=` deltas only: other tests in this binary may run concurrently
    // and share the process-global registry.
    let delta = tfgnn::obs::metrics().snapshot().delta_since(&before);
    let counter = |n: &str| delta.counters.get(n).copied().unwrap_or(0);
    let hist_count =
        |n: &str| delta.histograms.get(n).map(|h| h.count).unwrap_or(0);
    assert!(counter(names::SAMPLER_SUBGRAPHS) >= 6, "sampler counter did not move");
    assert!(counter(names::TRAINER_STEPS) >= 3, "trainer counter did not move");
    assert!(counter(names::SERVE_REQUESTS) >= 18, "serve counter did not move");
    assert!(counter(names::SERVE_BATCHES) >= 1, "no waves counted");
    assert!(hist_count(names::TRAINER_FORWARD_SECONDS) >= 3, "forward timer silent");
    assert!(hist_count(names::SERVE_WAVE_SECONDS) >= 1, "wave timer silent");
    assert!(hist_count(names::SERVE_WAVE_SIZE) >= 1, "wave-size histogram silent");

    // ---- export and validate both artifact schemas ---------------------
    let dir = std::env::temp_dir();
    let mpath = dir.join(format!("tfgnn_obs_it_metrics_{}.json", std::process::id()));
    let tpath = dir.join(format!("tfgnn_obs_it_trace_{}.json", std::process::id()));
    let (m, t) =
        (mpath.to_string_lossy().to_string(), tpath.to_string_lossy().to_string());
    tfgnn::obs::report::finish(Some(m.as_str()), Some(t.as_str()))
        .expect("export obs artifacts");

    // Metrics: schema tag, round-trip, full table coverage, bucket shape.
    let mdoc = Json::parse(&std::fs::read_to_string(&m).expect("read metrics"))
        .expect("metrics export is valid JSON");
    assert_eq!(
        mdoc.get("schema").expect("schema").as_str().expect("str"),
        "tfgnn_metrics_v1"
    );
    let snap = MetricsSnapshot::from_json(&mdoc).expect("metrics schema");
    for def in METRICS {
        let present = match def.kind {
            MetricKind::Counter => snap.counters.contains_key(def.name),
            MetricKind::Gauge => snap.gauges.contains_key(def.name),
            MetricKind::Histogram => snap.histograms.contains_key(def.name),
        };
        assert!(present, "{} missing from the export", def.name);
    }
    for (name, h) in &snap.histograms {
        assert_eq!(h.buckets.len(), NUM_BUCKETS, "{name} bucket count");
    }
    assert!(snap.counters.get(names::TRAINER_STEPS).copied().unwrap_or(0) >= 3);
    // The renderer accepts what the exporter wrote.
    let text = tfgnn::obs::report::render_stats(&snap);
    assert!(text.contains(names::TRAINER_STEPS), "stats renderer dropped a hot counter");

    // Trace: Chrome trace_event complete events, per-thread tids, and
    // the spans this test just exercised.
    let tdoc = Json::parse(&std::fs::read_to_string(&t).expect("read trace"))
        .expect("trace export is valid JSON");
    let events = tdoc.get("traceEvents").expect("traceEvents").as_arr().expect("array");
    assert!(!events.is_empty(), "tracing was on: expected at least one span");
    let mut seen = std::collections::BTreeSet::new();
    for e in events {
        assert_eq!(e.get("ph").expect("ph").as_str().expect("str"), "X");
        assert_eq!(e.get("cat").expect("cat").as_str().expect("str"), "tfgnn");
        assert_eq!(e.get("pid").expect("pid").as_i64().expect("int"), 1);
        assert!(e.get("ts").expect("ts").as_i64().expect("int") >= 0);
        assert!(e.get("dur").expect("dur").as_i64().expect("int") >= 0);
        assert!(e.get("tid").expect("tid").as_i64().expect("int") >= 1);
        seen.insert(e.get("name").expect("name").as_str().expect("str").to_string());
    }
    assert!(seen.contains("serve/wave"), "no serve/wave span in trace; saw {seen:?}");
    assert!(
        tdoc.get("otherData")
            .expect("otherData")
            .get("dropped_events")
            .expect("dropped")
            .as_i64()
            .expect("int")
            >= 0
    );

    let _ = std::fs::remove_file(&mpath);
    let _ = std::fs::remove_file(&tpath);

    // Leave the process how we found it for any later test.
    tfgnn::obs::set_recording(false);
    tfgnn::obs::trace::set_enabled(false);
}
