//! Native training engine: parity and learning tests (no artifacts
//! needed — everything here is pure Rust over the tiny synth MAG).
//!
//! The contracts asserted here gate the training bench (which re-checks
//! them before timing):
//! * the native per-component forward is **bit-for-bit** the padded
//!   bit-level reference forward (`mpnn_forward_with_config`);
//! * one `NativeTrainer` step at 1 thread is **bit-for-bit** the serial
//!   oracle (`train_step_oracle`);
//! * the 8-thread loss trajectory matches serial within 1e-5 relative;
//! * training actually reduces the loss on the learnable synth task.

use std::sync::Arc;

use tfgnn::graph::pad::{fit_or_skip, Padded, PadSpec};
use tfgnn::ops::model_ref::{mpnn_forward_with_config, ModelConfig};
use tfgnn::runtime::batch::RootTask;
use tfgnn::sampler::inmem::InMemorySampler;
use tfgnn::sampler::spec::mag_sampling_spec_scaled;
use tfgnn::synth::mag::{generate, MagConfig};
use tfgnn::train::native::{train_step_oracle, Adam, AdamConfig, NativeModel, NativeTrainer};

const BATCH: usize = 4;

/// Tiny-MAG padded batches, shaped exactly like the pipeline's output.
fn tiny_batches(count: usize) -> Vec<Padded> {
    let ds = generate(&MagConfig::tiny());
    let store = Arc::new(ds.store);
    let spec = mag_sampling_spec_scaled(&store.schema, 0.2).unwrap();
    let sampler = InMemorySampler::new(store, spec, 3).unwrap();
    let probe: Vec<_> = (0..12u32).map(|s| sampler.sample(s).unwrap()).collect();
    let pad = PadSpec::fit(&probe.iter().collect::<Vec<_>>(), BATCH, 2.5);
    let mut out = Vec::new();
    let mut seed = 0u32;
    while out.len() < count {
        let graphs: Vec<_> =
            (0..BATCH).map(|i| sampler.sample(seed + i as u32).unwrap()).collect();
        seed += BATCH as u32;
        let merged = tfgnn::graph::batch::merge(&graphs).unwrap();
        if let Some(p) = fit_or_skip(&merged, &pad) {
            out.push(p);
        }
        assert!(seed < 120, "could not assemble {count} fitting batches");
    }
    out
}

fn tiny_model(seed: u64) -> NativeModel {
    let cfg = ModelConfig::for_mag(&MagConfig::tiny(), 8, 8, 2);
    NativeModel::init(cfg, seed).unwrap()
}

fn rel_diff(a: f32, b: f32) -> f64 {
    let (a, b) = (a as f64, b as f64);
    (a - b).abs() / a.abs().max(b.abs()).max(1e-12)
}

/// The native per-component forward must reproduce the padded-batch
/// bit-level reference exactly: every real root's logits row, bit for
/// bit. This is what makes the native engine a *trainer for the same
/// model* rather than a lookalike.
#[test]
fn native_forward_matches_padded_reference_bitexact() {
    let batches = tiny_batches(2);
    let model = tiny_model(7);
    let task = RootTask::default();
    let params = model.params_as_tensors();
    for (bi, padded) in batches.iter().enumerate() {
        // Reference: whole padded batch at once — one root row per
        // non-padding component slot (real roots first, then masked
        // padding slots pointing at the padding component).
        let num_roots = padded.graph.num_components - 1;
        let reference =
            mpnn_forward_with_config(&model.cfg, &params, padded, &task, num_roots)
                .unwrap();
        // Native: one component at a time, root = node 0.
        let mut comps = tfgnn::graph::batch::split(&padded.graph).unwrap();
        comps.truncate(padded.num_real_components);
        for (c, comp) in comps.iter().enumerate() {
            let native = model.forward_logits(comp, &task.root_set, &[0]).unwrap();
            assert_eq!(native.rows, 1);
            assert_eq!(native.cols, reference.cols);
            for (k, (x, y)) in native.data.iter().zip(reference.row(c)).enumerate() {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "batch {bi} component {c} logit {k}: native {x} vs reference {y}"
                );
            }
        }
    }
}

/// One step at 1 thread == the serial oracle, bit for bit: loss,
/// metrics, every parameter, and the Adam moments — across several
/// consecutive steps.
#[test]
fn one_thread_step_matches_serial_oracle_bitexact() {
    let batches = tiny_batches(3);
    let task = RootTask::default();
    let adam = AdamConfig::default();
    let mut oracle_model = tiny_model(11);
    let mut oracle_opt = Adam::new(adam, &oracle_model.params);
    let mut trainer = NativeTrainer::new(tiny_model(11), adam, task.clone(), 1);
    for (step, b) in batches.iter().enumerate() {
        let mo = train_step_oracle(&mut oracle_model, &mut oracle_opt, b, &task).unwrap();
        let mt = trainer.train_batch(b).unwrap();
        assert_eq!(mt.loss.to_bits(), mo.loss.to_bits(), "step {step} loss");
        assert_eq!(mt.correct, mo.correct, "step {step} correct");
        assert_eq!(mt.weight, mo.weight, "step {step} weight");
        for ((name, a), b) in
            trainer.model().names.iter().zip(&trainer.model().params).zip(&oracle_model.params)
        {
            for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "step {step} param {name}[{i}]");
            }
        }
        for (a, b) in trainer.opt.m.iter().zip(&oracle_opt.m) {
            for (x, y) in a.data.iter().zip(&b.data) {
                assert_eq!(x.to_bits(), y.to_bits(), "adam m state");
            }
        }
    }
}

/// Replica-parallel training drifts from serial only by the all-reduce
/// grouping: the loss trajectory over several steps stays within 1e-5
/// relative at 2, 4 and 8 threads.
#[test]
fn multi_thread_loss_matches_serial_within_1e5() {
    let batches = tiny_batches(3);
    let task = RootTask::default();
    let adam = AdamConfig::default();
    let serial_losses: Vec<f32> = {
        let mut t = NativeTrainer::new(tiny_model(5), adam, task.clone(), 1);
        batches.iter().map(|b| t.train_batch(b).unwrap().loss).collect()
    };
    for threads in [2usize, 4, 8] {
        let mut t = NativeTrainer::new(tiny_model(5), adam, task.clone(), threads);
        for (step, b) in batches.iter().enumerate() {
            let m = t.train_batch(b).unwrap();
            let d = rel_diff(m.loss, serial_losses[step]);
            assert!(
                d <= 1e-5,
                "threads={threads} step={step}: loss {} vs serial {} (rel {d:.2e})",
                m.loss,
                serial_losses[step]
            );
            assert_eq!(m.weight as usize, BATCH);
        }
    }
}

/// Every convolution of the zoo — not just the mpnn — trains through
/// the same replica machinery: one `NativeTrainer` step at 1 thread is
/// bit-for-bit the serial oracle (loss, metrics, every parameter), for
/// gcn, sage (mean and max) and gatv2.
#[test]
fn zoo_one_thread_step_matches_serial_oracle_bitexact() {
    let batches = tiny_batches(2);
    let task = RootTask::default();
    let adam = AdamConfig::default();
    for (arch, reduce) in [("gcn", "mean"), ("sage", "mean"), ("sage", "max"), ("gatv2", "mean")]
    {
        let mk = || {
            let mut cfg = ModelConfig::for_mag(&MagConfig::tiny(), 8, 8, 2).with_arch(arch);
            cfg.sage_reduce = reduce.to_string();
            NativeModel::init(cfg, 11).unwrap()
        };
        let mut oracle_model = mk();
        let mut oracle_opt = Adam::new(adam, &oracle_model.params);
        let mut trainer = NativeTrainer::new(mk(), adam, task.clone(), 1);
        for (step, b) in batches.iter().enumerate() {
            let mo = train_step_oracle(&mut oracle_model, &mut oracle_opt, b, &task).unwrap();
            let mt = trainer.train_batch(b).unwrap();
            assert_eq!(
                mt.loss.to_bits(),
                mo.loss.to_bits(),
                "{arch}/{reduce} step {step} loss"
            );
            for ((name, a), b) in trainer
                .model()
                .names
                .iter()
                .zip(&trainer.model().params)
                .zip(&oracle_model.params)
            {
                for (x, y) in a.data.iter().zip(&b.data) {
                    assert_eq!(x.to_bits(), y.to_bits(), "{arch}/{reduce} step {step} {name}");
                }
            }
        }
        // Multi-thread loss parity holds for the zoo too.
        let mut t4 = NativeTrainer::new(mk(), adam, task.clone(), 4);
        let mut t1 = NativeTrainer::new(mk(), adam, task.clone(), 1);
        for b in &batches {
            let a = t1.train_batch(b).unwrap();
            let p = t4.train_batch(b).unwrap();
            assert!(
                rel_diff(a.loss, p.loss) <= 1e-5,
                "{arch}/{reduce}: 4t loss {} vs serial {}",
                p.loss,
                a.loss
            );
        }
    }
}

/// The new convolutions actually train on the synth task: the loss
/// trajectory stays finite and ends clearly below its start.
#[test]
fn zoo_training_reduces_loss() {
    let batches = tiny_batches(4);
    let task = RootTask::default();
    let adam = AdamConfig { lr: 0.01, ..AdamConfig::default() };
    for arch in ["gcn", "sage", "gatv2"] {
        let cfg = ModelConfig::for_mag(&MagConfig::tiny(), 8, 8, 2).with_arch(arch);
        let model = NativeModel::init(cfg, 13).unwrap();
        let mut trainer = NativeTrainer::new(model, adam, task.clone(), 2);
        let mut first = 0.0f32;
        let mut last = 0.0f32;
        for step in 0..30 {
            let m = trainer.train_batch(&batches[step % batches.len()]).unwrap();
            if step == 0 {
                first = m.loss;
            }
            last = m.loss;
            assert!(m.loss.is_finite(), "{arch} step {step}: loss diverged");
        }
        assert!(last < 0.9 * first, "{arch}: loss did not drop (first {first}, last {last})");
    }
}

/// The engine actually learns: after a few dozen steps on the tiny
/// synth task the loss drops well below its starting point, and
/// training accuracy beats chance.
#[test]
fn training_reduces_loss_on_synth_mag() {
    let batches = tiny_batches(4);
    let task = RootTask::default();
    let adam = AdamConfig { lr: 0.01, ..AdamConfig::default() };
    let mut trainer = NativeTrainer::new(tiny_model(13), adam, task, 2);
    let mut first = 0.0f32;
    let mut last = 0.0f32;
    let mut last_correct = 0.0f32;
    for step in 0..40 {
        let m = trainer.train_batch(&batches[step % batches.len()]).unwrap();
        if step == 0 {
            first = m.loss;
        }
        last = m.loss;
        last_correct = m.correct;
        assert!(m.loss.is_finite(), "step {step}: loss diverged");
    }
    assert!(
        last < 0.7 * first,
        "loss did not drop: first {first}, last {last}"
    );
    // Tiny MAG has 4 classes; after training the model should beat the
    // 25% chance level on its training batch.
    assert!(last_correct >= 2.0, "correct {last_correct}/4 after training");
}
