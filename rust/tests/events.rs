//! Training-telemetry contracts: the `tfgnn_events_v1` step journal,
//! the gradient-health sentinels, and `tfgnn runs` summaries.
//!
//! The load-bearing assertions:
//! * **inertness** — training with the journal + gradient probes on is
//!   bit-identical (checkpoint bytes, per-epoch loss bits) to training
//!   with them off, for all three tasks at 1/2/8 trainer threads;
//! * **journal schema** — a runner-written journal is a valid
//!   `tfgnn_events_v1` document: `run_start` header first, only
//!   `step`/`eval`/`run_end` records after, step records carrying
//!   timing and gradient-norm fields, `run_end` last;
//! * **NaN sentinel** — an injected non-finite parameter makes the
//!   next step fail with a structured error naming the step and the
//!   offending tensor, leaves the optimizer state untouched, and
//!   drops a `tfgnn_incident_v1` dump embedding the journal tail;
//! * **explosion sentinel** — a tiny `grad_norm_limit` trips the same
//!   machinery with a `grad-explosion` trigger;
//! * **runs diff** — two journals diff to per-metric delta rows.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use tfgnn::graph::pad::{fit_or_skip, Padded, PadSpec};
use tfgnn::obs::events::{render_diff, EventJournal, RunSummary, Telemetry};
use tfgnn::obs::flight::FlightRecorder;
use tfgnn::ops::model_ref::ModelConfig;
use tfgnn::runner::{run, EngineKind, RunConfig, RunReport};
use tfgnn::sampler::inmem::InMemorySampler;
use tfgnn::sampler::spec::mag_sampling_spec_scaled;
use tfgnn::synth::mag::{generate, MagConfig};
use tfgnn::train::native::{AdamConfig, NativeModel, NativeTrainer};
use tfgnn::train::Hyperparams;
use tfgnn::util::json::Json;

fn temp_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("tfgnn_events_it_{tag}_{}", std::process::id()))
}

/// The runner tests' tiny config, with an optional `task` block
/// spliced in front of `train`.
fn config_text(task_block: &str) -> String {
    let base = r#"{
      "batch_size": 4,
      "dataset": {
        "num_papers": 120, "num_authors": 150, "num_institutions": 10,
        "num_fields": 12, "num_classes": 4, "num_communities": 4,
        "feature_dim": 16, "mean_citations": 4.0,
        "mean_authors_per_paper": 2.0, "mean_topics": 1.5,
        "community_coherence": 0.85, "label_coherence": 0.75,
        "feature_noise": 0.8, "year_min": 2010, "year_max": 2019,
        "seed": 17
      },
      "schema": {
        "node_sets": {
          "paper": {"features": {"feat": 16}},
          "author": {},
          "institution": {"id_embedding": true, "cardinality": 10},
          "field_of_study": {"id_embedding": true, "cardinality": 12}
        },
        "edge_sets": {
          "cites": ["paper", "paper"],
          "written": ["paper", "author"],
          "writes": ["author", "paper"],
          "affiliated_with": ["author", "institution"],
          "has_topic": ["paper", "field_of_study"]
        }
      },
      "sampling": {
        "plan_seed": 42,
        "sizes": {"cites": 3, "written": 2, "writes": 2,
                  "affiliated_with": 2, "has_topic": 2}
      },
      "pad": {
        "node_caps": {"paper": 128, "author": 80, "institution": 48,
                      "field_of_study": 56},
        "edge_caps": {"cites": 16, "written": 40, "writes": 80,
                      "affiliated_with": 80, "has_topic": 192},
        "component_cap": 5
      },
      "model": {
        "hidden_dim": 8, "message_dim": 8, "num_layers": 1,
        "updates": {"paper": ["cites", "written", "has_topic"],
                    "author": ["writes", "affiliated_with"]}
      },
      "train": {
        "num_classes": 4, "init_seed": 3, "learning_rate": 0.01,
        "weight_decay": 0.0001, "adam_beta1": 0.9,
        "adam_beta2": 0.999, "adam_eps": 1e-8
      }
    }"#;
    base.replace("\"train\": {", &format!("{task_block} \"train\": {{"))
}

/// Pair subgraphs merge 1 + 1 + negatives rooted expansions, so the
/// link-prediction variant scales the caps up and the batch down.
fn linkpred_config_text() -> String {
    config_text(
        r#""task": {"type": "link_prediction", "edge_set": "cites",
                    "readout": "hadamard", "mlp_dim": 8,
                    "negatives": 2, "hits_k": 2,
                    "holdout_fraction": 0.3, "split_seed": 9},"#,
    )
    .replace("\"batch_size\": 4,", "\"batch_size\": 2,")
    .replace(
        r#""node_caps": {"paper": 128, "author": 80, "institution": 48,"#,
        r#""node_caps": {"paper": 256, "author": 160, "institution": 96,"#,
    )
    .replace(r#""field_of_study": 56},"#, r#""field_of_study": 112},"#)
    .replace(
        r#""edge_caps": {"cites": 16, "written": 40, "writes": 80,"#,
        r#""edge_caps": {"cites": 48, "written": 96, "writes": 192,"#,
    )
    .replace(
        r#""affiliated_with": 80, "has_topic": 192},"#,
        r#""affiliated_with": 192, "has_topic": 448},"#,
    )
    .replace("\"component_cap\": 5", "\"component_cap\": 3")
}

fn regression_config_text() -> String {
    config_text(
        r#""task": {"type": "graph_regression", "target_feature": "year",
                    "target_shift": 2010.0, "target_scale": 0.1},"#,
    )
}

/// One short native run; `telemetry` turns on the journal, the
/// gradient probes (via a generous sentinel limit) and an incident
/// dir. Returns the report, the checkpoint bytes, and the journal path.
fn run_once(
    dir: &Path,
    config: &str,
    threads: usize,
    telemetry: bool,
    tag: &str,
) -> (RunReport, Vec<u8>, Option<PathBuf>) {
    let cfg_path = dir.join(format!("{tag}.json"));
    std::fs::write(&cfg_path, config).unwrap();
    let ckpt = dir.join(format!("{tag}.ckpt"));
    let mut cfg = RunConfig::new(dir, "mpnn");
    cfg.engine = EngineKind::Native;
    cfg.config_path = Some(cfg_path);
    cfg.epochs = 1;
    cfg.max_steps_per_epoch = Some(3);
    cfg.max_eval_batches = Some(1);
    cfg.trainer_threads = threads;
    cfg.checkpoint = Some(ckpt.clone());
    let events = if telemetry {
        let p = dir.join(format!("{tag}.jsonl"));
        cfg.events_out = Some(p.clone());
        cfg.grad_norm_limit = Some(1e9);
        cfg.incident_dir = Some(dir.join(format!("{tag}-incidents")));
        Some(p)
    } else {
        None
    };
    let report = run(&cfg).unwrap_or_else(|e| panic!("{tag}: {e}"));
    let bytes = std::fs::read(&ckpt).unwrap();
    (report, bytes, events)
}

/// The inertness contract: recording on vs off changes no trained bit.
/// Checkpoint bytes cover params + Adam moments + step; loss bits
/// cover the reported trajectory. All three tasks, 1/2/8 threads.
#[test]
fn events_and_probes_change_no_trained_bit_across_tasks_and_threads() {
    let dir = temp_dir("parity");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let tasks: [(&str, String); 3] = [
        ("root", config_text("")),
        ("reg", regression_config_text()),
        ("lp", linkpred_config_text()),
    ];
    for (task, config) in &tasks {
        for threads in [1usize, 2, 8] {
            let tag_off = format!("{task}-t{threads}-off");
            let tag_on = format!("{task}-t{threads}-on");
            let (rep_off, ckpt_off, _) = run_once(&dir, config, threads, false, &tag_off);
            let (rep_on, ckpt_on, events) = run_once(&dir, config, threads, true, &tag_on);
            assert_eq!(
                ckpt_off, ckpt_on,
                "{task} @ {threads} threads: telemetry changed checkpoint bytes"
            );
            for (a, b) in rep_off.epochs.iter().zip(&rep_on.epochs) {
                assert_eq!(
                    a.train.loss().to_bits(),
                    b.train.loss().to_bits(),
                    "{task} @ {threads} threads: telemetry changed the loss trajectory"
                );
            }
            // The journal itself is well-formed and step-complete.
            let s = RunSummary::from_path(&events.unwrap()).unwrap();
            assert_eq!(s.steps as usize, rep_on.epochs[0].train.steps, "{task} @ {threads}");
            assert!(s.end.is_some(), "{task} @ {threads}: missing run_end");
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Journal schema, record by record: header first (with the schema tag
/// and the task name), `run_end` last, and every step record carrying
/// loss, timing and gradient-norm fields.
#[test]
fn journal_records_follow_the_events_v1_schema() {
    let dir = temp_dir("schema");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let (_, _, events) = run_once(&dir, &config_text(""), 2, true, "schema");
    let text = std::fs::read_to_string(events.unwrap()).unwrap();
    let lines: Vec<&str> = text.lines().filter(|l| !l.trim().is_empty()).collect();
    assert!(lines.len() >= 4, "header + steps + evals + run_end: {text}");
    let first = Json::parse(lines[0]).unwrap();
    assert_eq!(first.get("schema").unwrap().as_str().unwrap(), "tfgnn_events_v1");
    assert_eq!(first.get("kind").unwrap().as_str().unwrap(), "run_start");
    assert_eq!(first.get("task").unwrap().as_str().unwrap(), "root_classification");
    assert!(first.get("param_count").unwrap().as_i64().unwrap() > 0);
    assert!((first.get("learning_rate").unwrap().as_f64().unwrap() - 0.01).abs() < 1e-12);
    let last = Json::parse(lines[lines.len() - 1]).unwrap();
    assert_eq!(last.get("kind").unwrap().as_str().unwrap(), "run_end");
    let mut steps = 0u64;
    let mut evals = Vec::new();
    for line in &lines[1..lines.len() - 1] {
        let rec = Json::parse(line).unwrap();
        match rec.get("kind").unwrap().as_str().unwrap() {
            "step" => {
                steps += 1;
                assert!(rec.get("loss").unwrap().as_f64().unwrap().is_finite());
                assert!(rec.get("step_secs").unwrap().as_f64().unwrap() >= 0.0);
                assert!(rec.get("data_wait_secs").unwrap().as_f64().unwrap() >= 0.0);
                assert!(rec.get("grad_norm").unwrap().as_f64().unwrap() > 0.0);
                assert!(rec.get("update_ratio").unwrap().as_f64().unwrap() > 0.0);
                assert!(!rec.get("layers").unwrap().as_obj().unwrap().is_empty());
                assert!(rec.get("metrics").unwrap().get("scored").is_ok());
            }
            "eval" => evals.push(rec.get("split").unwrap().as_str().unwrap().to_string()),
            other => panic!("unexpected record kind {other:?}"),
        }
    }
    assert_eq!(steps, last.get("steps").unwrap().as_i64().unwrap() as u64);
    assert!(evals.contains(&"val".to_string()), "{evals:?}");
    assert!(evals.contains(&"test".to_string()), "{evals:?}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Two real journals (same config, different learning rate) diff to
/// per-metric delta rows.
#[test]
fn runs_diff_reports_metric_deltas_between_real_journals() {
    let dir = temp_dir("diff");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let (_, _, a) = run_once(&dir, &config_text(""), 2, true, "base");
    let cfg_path = dir.join("fast.json");
    std::fs::write(&cfg_path, config_text("")).unwrap();
    let b_path = dir.join("fast.jsonl");
    let mut cfg = RunConfig::new(&dir, "mpnn");
    cfg.engine = EngineKind::Native;
    cfg.config_path = Some(cfg_path);
    cfg.epochs = 1;
    cfg.max_steps_per_epoch = Some(3);
    cfg.max_eval_batches = Some(1);
    cfg.trainer_threads = 2;
    cfg.events_out = Some(b_path.clone());
    cfg.hp = Some(Hyperparams { learning_rate: 0.05, dropout: 0.0, weight_decay: 1e-4 });
    run(&cfg).unwrap();
    let sa = RunSummary::from_path(&a.unwrap()).unwrap();
    let sb = RunSummary::from_path(&b_path).unwrap();
    let text = render_diff(&sa, &sb);
    assert!(text.contains("final train loss"), "{text}");
    assert!(text.contains(" -> "), "{text}");
    assert!(text.contains("best val accuracy"), "{text}");
    let _ = std::fs::remove_dir_all(&dir);
}

// ---- sentinel tests (direct trainer, poisoned model) ---------------------

const BATCH: usize = 4;

/// Tiny-MAG padded batches, shaped exactly like the pipeline's output
/// (the `tests/native_training.rs` helper).
fn tiny_batches(count: usize) -> Vec<Padded> {
    let ds = generate(&MagConfig::tiny());
    let store = Arc::new(ds.store);
    let spec = mag_sampling_spec_scaled(&store.schema, 0.2).unwrap();
    let sampler = InMemorySampler::new(store, spec, 3).unwrap();
    let probe: Vec<_> = (0..12u32).map(|s| sampler.sample(s).unwrap()).collect();
    let pad = PadSpec::fit(&probe.iter().collect::<Vec<_>>(), BATCH, 2.5);
    let mut out = Vec::new();
    let mut seed = 0u32;
    while out.len() < count {
        let graphs: Vec<_> =
            (0..BATCH).map(|i| sampler.sample(seed + i as u32).unwrap()).collect();
        seed += BATCH as u32;
        let merged = tfgnn::graph::batch::merge(&graphs).unwrap();
        if let Some(p) = fit_or_skip(&merged, &pad) {
            out.push(p);
        }
        assert!(seed < 120, "could not assemble {count} fitting batches");
    }
    out
}

fn poisoned_trainer(poison: bool, threads: usize) -> NativeTrainer {
    let cfg = ModelConfig::for_mag(&MagConfig::tiny(), 8, 8, 1);
    let mut model = NativeModel::init(cfg, 11).unwrap();
    if poison {
        // Poison the classification head — it participates in every
        // example's loss, so the backward pass is guaranteed to carry
        // the NaN into the gradients.
        let head = model
            .names
            .iter()
            .position(|n| n.contains("head"))
            .expect("classification head parameter");
        model.params[head].data[0] = f32::NAN;
    }
    let task = tfgnn::tasks::build(&model.cfg).unwrap();
    NativeTrainer::with_task(model, AdamConfig::default(), task, threads)
}

/// An injected NaN parameter trips the non-finite sentinel: structured
/// error naming step + tensor, optimizer untouched, and an incident
/// dump embedding the recent journal tail.
#[test]
fn nan_gradient_yields_structured_error_and_incident_dump() {
    let batches = tiny_batches(1);
    let dir = temp_dir("nan");
    let _ = std::fs::remove_dir_all(&dir);
    let journal = Arc::new(EventJournal::create(&dir.join("run.jsonl")).unwrap());
    // Seed the tail with a prior step record so the dump has history.
    journal
        .write(&tfgnn::util::json::obj(vec![
            ("kind", Json::Str("step".to_string())),
            ("step", Json::Int(41)),
        ]))
        .unwrap();
    let rec = FlightRecorder::with_min_interval(&dir.join("incidents"), Duration::ZERO);
    let flight = Arc::new(rec.unwrap());
    let mut t = poisoned_trainer(true, 2);
    t.set_telemetry(Telemetry {
        grad_stats: true,
        grad_norm_limit: None,
        flight: Some(Arc::clone(&flight)),
        journal: Some(Arc::clone(&journal)),
    });
    let err = t.train_batch(&batches[0]).expect_err("NaN gradient must fail the step");
    let msg = err.to_string();
    assert!(msg.contains("non-finite gradient"), "{msg}");
    assert!(msg.contains("step 0"), "error names the step: {msg}");
    assert!(msg.contains("tensor"), "error names the offending tensor: {msg}");
    assert_eq!(t.steps_done, 0, "the optimizer never ran");
    assert!(t.take_grad_stats().is_none(), "no stats published for a failed step");

    let dumps: Vec<PathBuf> = std::fs::read_dir(dir.join("incidents"))
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().is_some_and(|x| x == "json"))
        .collect();
    assert_eq!(dumps.len(), 1, "exactly one incident dump");
    let doc = Json::parse(&std::fs::read_to_string(&dumps[0]).unwrap()).unwrap();
    assert_eq!(doc.get("schema").unwrap().as_str().unwrap(), "tfgnn_incident_v1");
    assert_eq!(doc.get("trigger").unwrap().as_str().unwrap(), "grad-nonfinite");
    let events = doc.get("events").unwrap().as_arr().unwrap();
    assert_eq!(events.len(), 1, "the journal tail rode along");
    assert_eq!(events[0].get("step").unwrap().as_i64().unwrap(), 41);
    let _ = std::fs::remove_dir_all(&dir);
}

/// A tiny `grad_norm_limit` trips the explosion sentinel on a healthy
/// batch; a generous limit lets the same batch train and publishes
/// per-layer grad stats.
#[test]
fn explosion_sentinel_trips_on_tiny_limit_and_passes_on_generous_one() {
    let batches = tiny_batches(1);
    let dir = temp_dir("explode");
    let _ = std::fs::remove_dir_all(&dir);
    let rec = FlightRecorder::with_min_interval(&dir.join("incidents"), Duration::ZERO);
    let flight = Arc::new(rec.unwrap());
    let mut t = poisoned_trainer(false, 2);
    t.set_telemetry(Telemetry {
        grad_stats: false,
        grad_norm_limit: Some(1e-12),
        flight: Some(Arc::clone(&flight)),
        journal: None,
    });
    let err = t.train_batch(&batches[0]).expect_err("tiny limit must trip");
    let msg = err.to_string();
    assert!(msg.contains("exceeds limit"), "{msg}");
    assert!(msg.contains("step 0"), "{msg}");
    assert_eq!(t.steps_done, 0);
    let dump = std::fs::read_dir(dir.join("incidents"))
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .find(|p| p.extension().is_some_and(|x| x == "json"))
        .expect("explosion dump");
    let doc = Json::parse(&std::fs::read_to_string(&dump).unwrap()).unwrap();
    assert_eq!(doc.get("trigger").unwrap().as_str().unwrap(), "grad-explosion");

    let mut ok = poisoned_trainer(false, 2);
    ok.set_telemetry(Telemetry {
        grad_stats: true,
        grad_norm_limit: Some(1e9),
        flight: None,
        journal: None,
    });
    ok.train_batch(&batches[0]).expect("generous limit passes");
    let stats = ok.take_grad_stats().expect("probe results published");
    assert!(stats.grad_norm > 0.0 && stats.grad_norm.is_finite());
    assert!(stats.update_ratio > 0.0, "update ratio computed after the step");
    assert!(!stats.layers.is_empty(), "per-layer norms grouped");
    let _ = std::fs::remove_dir_all(&dir);
}
