//! Layer-subsystem gradient checks (no artifacts needed):
//!
//! * the GATv2 attention convolution's VJP against central finite
//!   differences — over its parameters AND both endpoint states — on
//!   graphs that include single-edge receivers and receivers with no
//!   edges at all (the all-masked case);
//! * a heterogeneous **two-edge-set** `GraphUpdate` (dense-featured
//!   receivers, id-embedded senders, one isolated receiver per edge
//!   set) gradchecked end-to-end through `NativeModel::backward` for
//!   every convolution of the zoo.
//!
//! Tolerances: these checks run through whole layers, so a ±h probe
//! can push downstream pre-activations across the relu kink (the
//! op-level tests in `train/native/grad.rs` control their inputs to
//! exclude that; a composed layer cannot). The kink's FD error is
//! bounded by h·O(per-element gradient) ≈ 1e-2, so the gate is 2e-2 —
//! still an order of magnitude below any structural mistake (a wrong
//! transpose, a dropped softmax term, a mis-routed segment are all
//! ≥ 1e-1).

use std::collections::BTreeMap;

use tfgnn::graph::{Adjacency, Context, EdgeSet, Feature, GraphTensor, NodeSet};
use tfgnn::layers::{ConvCtx, ConvDims, ConvInputs, ConvKind};
use tfgnn::ops::model_ref::{Mat, ModelConfig};
use tfgnn::train::native::NativeModel;
use tfgnn::util::rng::Rng;

const H: f32 = 1e-2;
const TOL: f64 = 2e-2;

fn rand_mat(rng: &mut Rng, rows: usize, cols: usize) -> Mat {
    Mat { rows, cols, data: (0..rows * cols).map(|_| rng.range_f32(-1.0, 1.0)).collect() }
}

/// Weighted-sum loss (f64 accumulation): dY is exactly `w`.
fn wsum(y: &Mat, w: &[f32]) -> f64 {
    y.data.iter().zip(w).map(|(&v, &wv)| v as f64 * wv as f64).sum()
}

fn assert_close(what: &str, analytic: f64, fd: f64) {
    let denom = analytic.abs().max(fd.abs()).max(1.0);
    assert!(
        (analytic - fd).abs() / denom <= TOL,
        "{what}: analytic {analytic} vs finite difference {fd}"
    );
}

/// A bipartite graph for the conv-level checks: receivers "r" (edge
/// SOURCE endpoint, 5 nodes) and senders "s" (TARGET endpoint, 4
/// nodes). Receiver 2 has exactly one incident edge; receivers 3 and 4
/// have none (all-masked).
fn attention_graph() -> (GraphTensor, ConvCtx) {
    let source = vec![0u32, 0, 1, 1, 1, 2];
    let target = vec![1u32, 3, 0, 2, 3, 2];
    let es = EdgeSet::new(
        vec![source.len()],
        Adjacency {
            source_set: "r".into(),
            target_set: "s".into(),
            source: source.clone(),
            target: target.clone(),
        },
    );
    let g = GraphTensor::from_pieces(
        Context::default(),
        [
            ("r".to_string(), NodeSet::new(vec![5])),
            ("s".to_string(), NodeSet::new(vec![4])),
        ]
        .into(),
        [("e".to_string(), es)].into(),
    )
    .unwrap();
    let dims = ConvDims { hidden: 3, message: 4, att: 2 };
    let ctx = ConvCtx {
        sidx: target.iter().map(|&v| v as i32).collect(),
        ridx: source.iter().map(|&v| v as i32).collect(),
        n_send: 4,
        n_recv: 5,
        dims,
    };
    (g, ctx)
}

/// Central finite differences through the GATv2 convolution: every
/// parameter tensor and both endpoint state matrices, against the
/// analytic backward, on the single-edge / empty-receiver graph.
#[test]
fn gradcheck_gatv2_attention_vjp() {
    let (g, ctx) = attention_graph();
    let dims = ctx.dims;
    let conv = ConvKind::Gatv2.conv();
    let mut rng = Rng::new(2024);
    let params: Vec<Mat> = conv
        .param_shapes(dims)
        .iter()
        .map(|s| rand_mat(&mut rng, s.rows, s.cols))
        .collect();
    let sender_h = rand_mat(&mut rng, ctx.n_send, dims.hidden);
    let receiver_h = rand_mat(&mut rng, ctx.n_recv, dims.hidden);
    let w = (0..ctx.n_recv * dims.message)
        .map(|_| rng.range_f32(-1.0, 1.0))
        .collect::<Vec<_>>();

    let loss_of = |params: &[Mat], sender: &Mat, receiver: &Mat| -> f64 {
        let prefs: Vec<&Mat> = params.iter().collect();
        let x = ConvInputs { g: &g, es: "e", sender_h: sender, receiver_h: receiver, ctx: &ctx };
        let (out, _saved) = conv.forward_tape(&x, &prefs).unwrap();
        wsum(&out, &w)
    };

    // Analytic gradients.
    let prefs: Vec<&Mat> = params.iter().collect();
    let x = ConvInputs { g: &g, es: "e", sender_h: &sender_h, receiver_h: &receiver_h, ctx: &ctx };
    let (out, saved) = conv.forward_tape(&x, &prefs).unwrap();
    assert_eq!((out.rows, out.cols), (5, dims.message));
    // Empty receivers pool to exactly zero.
    for r in [3usize, 4] {
        assert!(out.row(r).iter().all(|&v| v == 0.0), "receiver {r} has no edges");
    }
    let d_out = Mat { rows: out.rows, cols: out.cols, data: w.clone() };
    let mut grads: Vec<Mat> = params.iter().map(Mat::zeros_like).collect();
    let gidx: Vec<usize> = (0..params.len()).collect();
    let (d_sender, d_receiver) =
        conv.backward(&ctx, &saved, &d_out, &prefs, &mut grads, &gidx).unwrap();

    // FD over every element of every parameter.
    for (pi, shape) in conv.param_shapes(dims).iter().enumerate() {
        for ei in 0..params[pi].data.len() {
            let mut pp = params.clone();
            pp[pi].data[ei] += H;
            let mut pm = params.clone();
            pm[pi].data[ei] -= H;
            let fd = (loss_of(&pp, &sender_h, &receiver_h)
                - loss_of(&pm, &sender_h, &receiver_h))
                / (2.0 * H as f64);
            assert_close(
                &format!("gatv2 {}[{ei}]", shape.suffix),
                grads[pi].data[ei] as f64,
                fd,
            );
        }
    }
    // FD over both endpoint states.
    for ei in 0..sender_h.data.len() {
        let mut sp = sender_h.clone();
        sp.data[ei] += H;
        let mut sm = sender_h.clone();
        sm.data[ei] -= H;
        let fd =
            (loss_of(&params, &sp, &receiver_h) - loss_of(&params, &sm, &receiver_h))
                / (2.0 * H as f64);
        assert_close(&format!("gatv2 d_sender[{ei}]"), d_sender.data[ei] as f64, fd);
    }
    for ei in 0..receiver_h.data.len() {
        let mut rp = receiver_h.clone();
        rp.data[ei] += H;
        let mut rm = receiver_h.clone();
        rm.data[ei] -= H;
        let fd =
            (loss_of(&params, &sender_h, &rp) - loss_of(&params, &sender_h, &rm))
                / (2.0 * H as f64);
        assert_close(&format!("gatv2 d_receiver[{ei}]"), d_receiver.data[ei] as f64, fd);
    }
    // All-masked receivers (no incident edges) receive exactly zero
    // state gradient — nothing in the convolution touches them.
    assert!(d_receiver.row(3).iter().all(|&v| v == 0.0), "isolated receiver grads");
    assert!(d_receiver.row(4).iter().all(|&v| v == 0.0), "isolated receiver grads");
}

/// A heterogeneous two-node-set / two-edge-set schema: "user" nodes
/// carry a dense feature, "item" nodes an id-embedding; both edge sets
/// pool into "user". User 3 has no "buys" edges and user 2 exactly
/// one; "views" leaves users 2 and 3 isolated.
fn hetero_model_config(arch: &str) -> ModelConfig {
    let s = |x: &str| x.to_string();
    let mut updates = BTreeMap::new();
    updates.insert(s("user"), vec![s("buys"), s("views")]);
    let mut edge_endpoints = BTreeMap::new();
    edge_endpoints.insert(s("buys"), (s("user"), s("item")));
    edge_endpoints.insert(s("views"), (s("user"), s("item")));
    let node_order = vec![s("item"), s("user")];
    let mut id_embedding = BTreeMap::new();
    id_embedding.insert(s("item"), true);
    id_embedding.insert(s("user"), false);
    let mut features = BTreeMap::new();
    features.insert(s("item"), Vec::new());
    features.insert(s("user"), vec![s("feat")]);
    let mut feature_dims = BTreeMap::new();
    feature_dims.insert(s("item"), BTreeMap::new());
    feature_dims.insert(s("user"), [(s("feat"), 3usize)].into());
    let mut cardinality = BTreeMap::new();
    cardinality.insert(s("item"), 6usize);
    ModelConfig {
        arch: s(arch),
        hidden: 4,
        message: 4,
        att_dim: 3,
        sage_reduce: s("mean"),
        layers: 2,
        updates,
        edge_endpoints,
        node_order,
        id_embedding,
        features,
        feature_dims,
        cardinality,
        num_classes: 3,
        task: Default::default(),
    }
}

fn hetero_graph(rng: &mut Rng) -> GraphTensor {
    let users = NodeSet::new(vec![4]).with_feature(
        "feat",
        Feature::f32_mat(3, (0..4 * 3).map(|_| rng.range_f32(-1.0, 1.0)).collect()),
    );
    let items = NodeSet::new(vec![5]).with_feature("#id", Feature::i64_vec(vec![0, 2, 1, 4, 3]));
    let buys = EdgeSet::new(
        vec![4],
        Adjacency {
            source_set: "user".into(),
            target_set: "item".into(),
            source: vec![0, 0, 1, 2], // user 3 isolated, user 2 single-edge
            target: vec![1, 4, 0, 2],
        },
    );
    let views = EdgeSet::new(
        vec![4],
        Adjacency {
            source_set: "user".into(),
            target_set: "item".into(),
            source: vec![1, 1, 1, 0], // users 2 and 3 isolated
            target: vec![3, 3, 2, 0],
        },
    );
    GraphTensor::from_pieces(
        Context::default(),
        [("user".to_string(), users), ("item".to_string(), items)].into(),
        [("buys".to_string(), buys), ("views".to_string(), views)].into(),
    )
    .unwrap()
}

/// Finite differences through a whole heterogeneous 2-edge-set
/// GraphUpdate stack (2 rounds, id-embedding + dense encoder, root
/// readout) for every convolution of the zoo: probes of every
/// parameter tensor must match `NativeModel::backward`.
#[test]
fn gradcheck_heterogeneous_two_edge_set_graph_update() {
    let mut rng = Rng::new(4242);
    let g = hetero_graph(&mut rng);
    let roots = [0i32, 2];
    for arch in ["mpnn", "gcn", "sage", "gatv2"] {
        let model = NativeModel::init(hetero_model_config(arch), 17).unwrap();
        let w: Vec<f32> =
            (0..roots.len() * model.cfg.num_classes).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let loss_of = |m: &NativeModel| -> f64 {
            wsum(&m.forward_logits(&g, "user", &roots).unwrap(), &w)
        };
        let (logits, tape) = model.forward_tape(&g, "user", &roots).unwrap();
        assert_eq!((logits.rows, logits.cols), (2, 3), "{arch}");
        let dlogits = Mat { rows: 2, cols: 3, data: w.clone() };
        let mut grads = model.zeros_grads();
        model.backward(&g, &tape, &dlogits, "user", &mut grads).unwrap();

        let mut probed = 0usize;
        for (pi, name) in model.names.iter().enumerate() {
            let n = model.params[pi].data.len();
            // Deterministic probes: first, middle, last element.
            for ei in [0, n / 2, n - 1] {
                let mut mp = model.clone();
                mp.params[pi].data[ei] += H;
                let mut mm = model.clone();
                mm.params[pi].data[ei] -= H;
                let fd = (loss_of(&mp) - loss_of(&mm)) / (2.0 * H as f64);
                assert_close(
                    &format!("{arch} {name}[{ei}]"),
                    grads[pi].data[ei] as f64,
                    fd,
                );
                probed += 1;
            }
        }
        assert!(probed >= 3 * model.names.len(), "{arch}: probed {probed}");
    }
}

/// The two edge sets merge in sorted-name order ("buys" before
/// "views") — the determinism guarantee DESIGN.md documents. Swapping
/// the declaration order of the update's edge list must not change a
/// single output bit.
#[test]
fn hetero_merge_order_is_sorted_not_declaration_order() {
    let mut rng = Rng::new(7);
    let g = hetero_graph(&mut rng);
    for arch in ["mpnn", "gatv2"] {
        let a = NativeModel::init(hetero_model_config(arch), 3).unwrap();
        let mut cfg_swapped = hetero_model_config(arch);
        cfg_swapped
            .updates
            .insert("user".to_string(), vec!["views".to_string(), "buys".to_string()]);
        let b = NativeModel::init(cfg_swapped, 3).unwrap();
        assert_eq!(a.names, b.names, "{arch}: param creation order is sorted");
        let la = a.forward_logits(&g, "user", &[0, 2]).unwrap();
        let lb = b.forward_logits(&g, "user", &[0, 2]).unwrap();
        for (x, y) in la.data.iter().zip(&lb.data) {
            assert_eq!(x.to_bits(), y.to_bits(), "{arch}");
        }
    }
}
