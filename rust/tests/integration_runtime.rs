//! Integration: AOT artifacts ⇄ Rust runtime.
//!
//! These tests need `make artifacts` to have run (they skip otherwise,
//! so `cargo test` before the AOT build still passes). They are the
//! cross-language correctness seam: the same HLO programs the Python
//! side lowered are compiled on the PJRT CPU client and exercised from
//! Rust with real sampled batches.

use std::path::Path;
use std::sync::Arc;

use tfgnn::graph::pad::fit_or_skip;
use tfgnn::runner::MagEnv;
use tfgnn::runtime::batch::RootTask;
use tfgnn::runtime::manifest::Manifest;
use tfgnn::runtime::Runtime;
use tfgnn::train::{Hyperparams, Trainer};

fn artifacts() -> Option<&'static Path> {
    let p = Path::new("artifacts");
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

fn make_batches(env: &MagEnv, seeds: &[u32]) -> Vec<tfgnn::graph::pad::Padded> {
    seeds
        .chunks(env.batch_size)
        .filter(|c| c.len() == env.batch_size)
        .filter_map(|chunk| {
            let graphs: Vec<_> =
                chunk.iter().map(|&s| env.sampler.sample(s).unwrap()).collect();
            let merged = tfgnn::graph::batch::merge(&graphs).unwrap();
            fit_or_skip(&merged, &env.pad)
        })
        .collect()
}

#[test]
fn init_is_deterministic_and_matches_manifest() {
    let Some(dir) = artifacts() else { return };
    let manifest = Manifest::load(dir).unwrap();
    let entry = manifest.model("mpnn").unwrap();
    let rt = Runtime::cpu().unwrap();
    let init = rt.load_program(dir, entry.program("init").unwrap()).unwrap();
    let a = init.execute_literals(&[]).unwrap();
    let b = init.execute_literals(&[]).unwrap();
    assert_eq!(a.len(), init.spec.outputs.len());
    let mut total_params = 0usize;
    for (i, (la, lb)) in a.iter().zip(&b).enumerate() {
        let ha = tfgnn::runtime::literal_to_host(la).unwrap();
        let hb = tfgnn::runtime::literal_to_host(lb).unwrap();
        assert_eq!(ha, hb, "init output {i} must be deterministic");
        assert!(ha.matches(&init.spec.outputs[i]), "output {i} shape/dtype");
        total_params += ha.len();
    }
    assert_eq!(total_params, entry.param_count, "manifest param_count");
}

#[test]
fn training_reduces_loss_and_is_deterministic() {
    let Some(dir) = artifacts() else { return };
    let env = MagEnv::from_artifacts(dir).unwrap();
    let entry = env.manifest.model("mpnn").unwrap().clone();
    let hp = Hyperparams { learning_rate: 3e-3, dropout: 0.0, weight_decay: 0.0 };
    let seeds: Vec<u32> = env.dataset.papers_in_split(tfgnn::synth::mag::Split::Train);
    let batches = make_batches(&env, &seeds[..3 * env.batch_size]);
    assert!(!batches.is_empty(), "at least one batch fits the caps");

    let run = |n: usize| -> Vec<f32> {
        let rt = Runtime::cpu().unwrap();
        let mut trainer = Trainer::new(rt, dir, &entry, RootTask::default(), hp).unwrap();
        let mut losses = Vec::new();
        for _ in 0..n {
            for b in &batches {
                losses.push(trainer.train_batch(b).unwrap().loss);
            }
        }
        losses
    };
    let l1 = run(6);
    // Overfit a few batches: loss must drop substantially.
    let head: f32 = l1[..batches.len()].iter().sum::<f32>() / batches.len() as f32;
    let tail: f32 =
        l1[l1.len() - batches.len()..].iter().sum::<f32>() / batches.len() as f32;
    assert!(
        tail < head * 0.7,
        "loss did not drop: first-pass {head:.4} vs last-pass {tail:.4}"
    );
    // Determinism: rerunning the same schedule gives identical losses
    // (dropout is keyed by the step counter, data is fixed).
    let l2 = run(6);
    assert_eq!(l1, l2, "training must be bit-deterministic");
}

#[test]
fn eval_is_pure_and_counts_real_roots() {
    let Some(dir) = artifacts() else { return };
    let env = MagEnv::from_artifacts(dir).unwrap();
    let entry = env.manifest.model("mpnn").unwrap().clone();
    let hp = Hyperparams::from_manifest(&env.manifest).unwrap();
    let rt = Runtime::cpu().unwrap();
    let trainer = Trainer::new(rt, dir, &entry, RootTask::default(), hp).unwrap();
    let seeds = env.dataset.papers_in_split(tfgnn::synth::mag::Split::Validation);
    let batches = make_batches(&env, &seeds[..2 * env.batch_size]);
    for b in &batches {
        let m1 = trainer.eval_batch(b).unwrap();
        let m2 = trainer.eval_batch(b).unwrap();
        assert_eq!(m1.loss, m2.loss, "eval must not mutate state");
        assert_eq!(m1.weight as usize, env.batch_size, "all real roots counted");
        assert!(m1.correct >= 0.0 && m1.correct <= m1.weight);
        assert!(m1.loss.is_finite());
    }
}

#[test]
fn checkpoint_roundtrip_preserves_eval() {
    let Some(dir) = artifacts() else { return };
    let env = MagEnv::from_artifacts(dir).unwrap();
    let entry = env.manifest.model("mpnn").unwrap().clone();
    let hp = Hyperparams { learning_rate: 1e-3, dropout: 0.0, weight_decay: 0.0 };
    let seeds = env.dataset.papers_in_split(tfgnn::synth::mag::Split::Train);
    let batches = make_batches(&env, &seeds[..env.batch_size * 2]);
    let rt = Runtime::cpu().unwrap();
    let mut trainer = Trainer::new(rt, dir, &entry, RootTask::default(), hp).unwrap();
    for b in &batches {
        trainer.train_batch(b).unwrap();
    }
    let before = trainer.eval_batch(&batches[0]).unwrap();
    let params = trainer.params_to_host().unwrap();
    let ckpt = std::env::temp_dir().join(format!("tfgnn-it-{}.ckpt", std::process::id()));
    tfgnn::train::checkpoint::save(&ckpt, &params).unwrap();

    // Fresh trainer + restore: eval must match exactly.
    let rt2 = Runtime::cpu().unwrap();
    let mut restored = Trainer::new(rt2, dir, &entry, RootTask::default(), hp).unwrap();
    let loaded = tfgnn::train::checkpoint::load(&ckpt).unwrap();
    restored.params_from_host(&loaded).unwrap();
    let after = restored.eval_batch(&batches[0]).unwrap();
    assert_eq!(before.loss, after.loss);
    assert_eq!(before.correct, after.correct);
    std::fs::remove_file(&ckpt).unwrap();
}

#[test]
fn serving_returns_consistent_predictions() {
    let Some(dir) = artifacts() else { return };
    let env = MagEnv::from_artifacts(dir).unwrap();
    let entry = env.manifest.model("mpnn").unwrap().clone();
    let hp = Hyperparams::from_manifest(&env.manifest).unwrap();
    let trainer =
        Trainer::new(Runtime::cpu().unwrap(), dir, &entry, RootTask::default(), hp).unwrap();
    let params = trainer.params_to_host().unwrap();
    drop(trainer);

    let handle = tfgnn::serve::serve(
        dir,
        &entry,
        params,
        Arc::clone(&env.sampler),
        env.pad.clone(),
        RootTask::default(),
        tfgnn::serve::ServeConfig {
            max_batch: env.batch_size,
            max_wait: std::time::Duration::from_millis(2),
            // Exercise the parallel wave-sampling path end to end.
            sampler: tfgnn::sampler::SamplerConfig::with_threads(4),
            ..Default::default()
        },
    )
    .unwrap();
    let seeds = env.dataset.papers_in_split(tfgnn::synth::mag::Split::Test);
    // Same seed twice -> identical logits (deterministic sampler+model).
    let r1 = handle.predict(seeds[0]).unwrap();
    let r2 = handle.predict(seeds[0]).unwrap();
    assert_eq!(r1.logits, r2.logits);
    assert_eq!(r1.predicted, r2.predicted);
    assert!(r1.logits.len() > 1);
    // Burst of concurrent requests: all answered.
    let pending: Vec<_> = seeds[..12].iter().map(|&s| handle.submit(s)).collect();
    for rx in pending {
        let resp = rx.recv().unwrap().unwrap();
        assert!(resp.latency.as_secs_f64() < 60.0);
    }
    let served = handle.stats.snapshot().requests;
    assert!(served >= 14);
    handle.shutdown();
}

#[test]
fn aot_forward_matches_rust_reference() {
    // The strongest cross-language check: the AOT logits (Pallas kernel
    // -> jax -> HLO text -> PJRT) must match an independent pure-Rust
    // forward implementation to float tolerance, after real training.
    let Some(dir) = artifacts() else { return };
    let env = MagEnv::from_artifacts(dir).unwrap();
    let entry = env.manifest.model("mpnn").unwrap().clone();
    let hp = Hyperparams { learning_rate: 1e-3, dropout: 0.0, weight_decay: 0.0 };
    let seeds = env.dataset.papers_in_split(tfgnn::synth::mag::Split::Train);
    let batches = make_batches(&env, &seeds[..2 * env.batch_size]);
    let rt = Runtime::cpu().unwrap();
    let mut trainer = Trainer::new(rt, dir, &entry, RootTask::default(), hp).unwrap();
    // Train a couple of steps so params are non-trivial.
    for b in &batches {
        trainer.train_batch(b).unwrap();
    }
    let params = trainer.params_to_host().unwrap();

    // AOT forward via the serving path.
    let handle = tfgnn::serve::serve(
        dir,
        &entry,
        params.clone(),
        Arc::clone(&env.sampler),
        env.pad.clone(),
        RootTask::default(),
        tfgnn::serve::ServeConfig {
            max_batch: 1,
            max_wait: std::time::Duration::from_millis(0),
            ..Default::default()
        },
    )
    .unwrap();
    for &seed in &seeds[..4] {
        let resp = handle.predict(seed).unwrap();
        // Rust reference on the identical padded single-graph batch.
        let g = env.sampler.sample(seed).unwrap();
        let merged = tfgnn::graph::batch::merge(&[g]).unwrap();
        let padded = tfgnn::graph::pad::fit_or_skip(&merged, &env.pad).unwrap();
        let logits = tfgnn::ops::model_ref::mpnn_forward_reference(
            &env.manifest,
            &params,
            &padded,
            &RootTask::default(),
        )
        .unwrap();
        let want = logits.row(0);
        assert_eq!(resp.logits.len(), want.len());
        for (k, (a, b)) in resp.logits.iter().zip(want).enumerate() {
            assert!(
                (a - b).abs() < 1e-3 * (1.0 + b.abs()),
                "seed {seed} logit {k}: aot {a} vs rust {b}"
            );
        }
    }
    handle.shutdown();
}
