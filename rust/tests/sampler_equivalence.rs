//! Property tests for the sampling engine's determinism contract:
//! the shard-fanout parallel engine, the CSR in-memory fast path and
//! the single-threaded Algorithm 1 oracle must produce **identical
//! GraphTensors** for every (seed set, fanout, thread count, failure
//! rate) — the invariant DESIGN.md's sampling-engine section promises
//! and everything downstream (pipeline, serving, coordinator) leans on.

use std::sync::Arc;

use tfgnn::sampler::distributed::{sample_batch, sample_batch_parallel};
use tfgnn::sampler::inmem::InMemorySampler;
use tfgnn::sampler::spec::mag_sampling_spec_scaled;
use tfgnn::sampler::{RetryPolicy, SamplerConfig};
use tfgnn::store::sharded::ShardedStore;
use tfgnn::store::GraphStore;
use tfgnn::synth::mag::{generate, MagConfig};
use tfgnn::util::proptest::check;

fn store() -> Arc<GraphStore> {
    let ds = generate(&MagConfig::tiny());
    Arc::new(ds.store)
}

#[test]
fn prop_parallel_equals_serial_across_seeds_fanouts_threads() {
    let store = store();
    check("parallel sampler == serial oracle", 12, |rng| {
        // Random fanout scale, seed set and plan seed per case.
        let fanout = 0.05 + rng.f64() * 0.95;
        let spec = mag_sampling_spec_scaled(&store.schema, fanout).unwrap();
        let n_seeds = 1 + rng.uniform(30);
        let seeds: Vec<u32> = (0..n_seeds).map(|_| rng.uniform(120) as u32).collect();
        let plan_seed = rng.next_u64();
        let num_shards = 1 + rng.uniform(8);

        let sharded = Arc::new(ShardedStore::new(Arc::clone(&store), num_shards));
        let (want, _) =
            sample_batch(&sharded, &spec, plan_seed, &seeds, &RetryPolicy::default()).unwrap();

        // The in-memory CSR fast path agrees seed by seed.
        let inmem = InMemorySampler::new(Arc::clone(&store), spec.clone(), plan_seed).unwrap();
        for (k, &s) in seeds.iter().enumerate() {
            assert_eq!(want[k], inmem.sample(s).unwrap(), "inmem seed {s}");
        }

        for threads in [1usize, 2, 8] {
            let cfg = SamplerConfig::with_threads(threads);
            let (got, stats) =
                sample_batch_parallel(&sharded, &spec, plan_seed, &seeds, &cfg, None).unwrap();
            assert_eq!(got, want, "threads={threads} fanout={fanout:.2} seeds={n_seeds}");
            assert_eq!(stats.subgraphs, seeds.len());
        }
    });
}

#[test]
fn prop_parallel_equals_serial_under_injected_shard_failures() {
    let store = store();
    check("parallel sampler resilient == serial reliable", 10, |rng| {
        let fanout = 0.1 + rng.f64() * 0.6;
        let spec = mag_sampling_spec_scaled(&store.schema, fanout).unwrap();
        let seeds: Vec<u32> = (0..1 + rng.uniform(20)).map(|_| rng.uniform(120) as u32).collect();
        let plan_seed = rng.next_u64();
        let failure_rate = 0.1 + rng.f64() * 0.3;
        let failure_seed = rng.next_u64();

        let reliable = Arc::new(ShardedStore::new(Arc::clone(&store), 4));
        let (want, _) =
            sample_batch(&reliable, &spec, plan_seed, &seeds, &RetryPolicy::default()).unwrap();

        let flaky = Arc::new(
            ShardedStore::new(Arc::clone(&store), 4).with_failures(failure_rate, failure_seed),
        );
        for threads in [1usize, 2, 8] {
            let cfg = SamplerConfig {
                threads,
                retry: RetryPolicy { max_attempts: 200 },
                ..SamplerConfig::default()
            };
            let (got, _) =
                sample_batch_parallel(&flaky, &spec, plan_seed, &seeds, &cfg, None).unwrap();
            assert_eq!(
                got, want,
                "threads={threads} fail={failure_rate:.2}: retries must hide failures"
            );
        }
        let (_, _, injected) = flaky.total_requests();
        assert!(injected > 0, "failure injection actually fired");
    });
}
