//! One-off probe: distribution of merged-batch sizes vs the pad caps.
use std::sync::Arc;
use tfgnn::runner::MagEnv;

#[test]
#[ignore]
fn probe_batch_sizes() {
    let env = MagEnv::from_artifacts(std::path::Path::new("artifacts")).unwrap();
    let seeds = env.dataset.papers_in_split(tfgnn::synth::mag::Split::Train);
    let sampler = Arc::clone(&env.sampler);
    let mut maxes: std::collections::BTreeMap<String, usize> = Default::default();
    let bs = env.batch_size;
    for chunk in seeds.chunks(bs).take(60) {
        if chunk.len() < bs { continue; }
        let graphs: Vec<_> = chunk.iter().map(|&s| sampler.sample(s).unwrap()).collect();
        let merged = tfgnn::graph::batch::merge(&graphs).unwrap();
        for (name, ns) in &merged.node_sets {
            let e = maxes.entry(format!("node {name}")).or_default();
            *e = (*e).max(ns.total());
        }
        for (name, es) in &merged.edge_sets {
            let e = maxes.entry(format!("edge {name}")).or_default();
            *e = (*e).max(es.total());
        }
    }
    println!("max sizes over 60 batches of {bs}:");
    for (k, v) in &maxes { println!("  {k:<24} {v}"); }
    println!("caps: {:?} {:?}", env.pad.node_caps, env.pad.edge_caps);
}
