//! Concurrency contracts of the production serving path, pinned at
//! 1/2/8 batcher lanes (and exercised under the nightly TSan lane —
//! every test name is prefixed `serve_concurrency_` so the TSan filter
//! picks the whole file up).
//!
//! The contracts:
//! * admission control — a full queue rejects with a structured
//!   `Error::Overloaded`, never an unbounded backlog or a hang;
//! * drain-on-shutdown — every admitted request is answered, and
//!   submitting after shutdown gets a structured error on both handle
//!   types;
//! * determinism — responses are bit-identical at any lane count, and
//!   with the subgraph cache on or off, across hit/miss interleavings;
//! * hot-swap — a response always reflects exactly one model
//!   generation, even when the swap lands mid-load.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

use tfgnn::ops::model_ref::ModelConfig;
use tfgnn::sampler::inmem::InMemorySampler;
use tfgnn::sampler::spec::mag_sampling_spec_scaled;
use tfgnn::serve::loadgen::outputs_bit_identical;
use tfgnn::serve::{serve_native, serve_task, ServeConfig, TaskServerHandle};
use tfgnn::synth::mag::{generate, MagConfig, Split};
use tfgnn::tasks::TaskOutput;
use tfgnn::train::native::NativeModel;
use tfgnn::Error;

struct Env {
    sampler: Arc<InMemorySampler>,
    cfg: ModelConfig,
    seeds: Vec<u32>,
}

fn env() -> Env {
    let mag = MagConfig::tiny();
    let ds = generate(&mag);
    let seeds = ds.papers_in_split(Split::Train);
    let store = Arc::new(ds.store);
    let spec = mag_sampling_spec_scaled(&store.schema, 0.2).unwrap();
    let sampler = Arc::new(InMemorySampler::new(store, spec, 3).unwrap());
    let cfg = ModelConfig::for_mag(&mag, 8, 8, 1);
    Env { sampler, cfg, seeds }
}

fn task_server(env: &Env, model_seed: u64, serve_cfg: ServeConfig) -> TaskServerHandle {
    let task = tfgnn::tasks::build(&env.cfg).unwrap();
    let model = Arc::new(NativeModel::init(env.cfg.clone(), model_seed).unwrap());
    serve_task(model, Arc::clone(&env.sampler), task, serve_cfg).unwrap()
}

/// Admission control: saturate a tiny queue behind slow lanes and
/// check that overflow is rejected with `Error::Overloaded` while
/// every admitted request is still answered.
#[test]
fn serve_concurrency_overload_rejects_structurally() {
    let env = env();
    for lanes in [1usize, 2, 8] {
        let handle = task_server(
            &env,
            7,
            ServeConfig {
                lanes,
                max_batch: 1,
                max_wait: Duration::ZERO,
                queue_capacity: 2,
                // Slow waves make saturation deterministic: the submit
                // burst below finishes long before any lane frees a slot.
                wave_delay: Duration::from_millis(25),
                ..ServeConfig::default()
            },
        );
        let total = lanes + 2 + 6;
        let pending: Vec<_> = (0..total).map(|_| handle.submit(vec![env.seeds[0]])).collect();
        let (mut ok, mut rejected) = (0usize, 0usize);
        for rx in pending {
            match rx.recv().unwrap() {
                Ok(resp) => {
                    assert!(matches!(resp.output, TaskOutput::Classification { .. }));
                    ok += 1;
                }
                Err(Error::Overloaded(msg)) => {
                    assert!(msg.contains("queue full"), "lanes={lanes}: {msg}");
                    rejected += 1;
                }
                Err(e) => panic!("lanes={lanes}: unexpected error kind: {e}"),
            }
        }
        assert_eq!(ok + rejected, total, "lanes={lanes}: every request answered");
        assert!(rejected >= 1, "lanes={lanes}: expected at least one rejection");
        // The first push into the empty queue is always admitted, and
        // admitted requests must still be served.
        assert!(ok >= 1, "lanes={lanes}: admitted requests must still be served (ok={ok})");
        assert_eq!(
            handle.stats.snapshot().rejected,
            rejected as u64,
            "lanes={lanes}: stats.rejected matches observed rejections"
        );
        handle.shutdown();
    }
}

/// Drain-on-shutdown + submit-after-shutdown on the task handle, at
/// every lane count. (The root `ServerHandle` twin of this test lives
/// in the serve module's unit tests.)
#[test]
fn serve_concurrency_shutdown_drains_then_rejects() {
    let env = env();
    for lanes in [1usize, 2, 8] {
        let handle = task_server(
            &env,
            7,
            ServeConfig {
                lanes,
                max_batch: 2,
                max_wait: Duration::from_millis(50),
                ..ServeConfig::default()
            },
        );
        let pending: Vec<_> =
            (0..12).map(|i| handle.submit(vec![env.seeds[i % env.seeds.len()]])).collect();
        handle.shutdown();
        for (i, rx) in pending.into_iter().enumerate() {
            let resp = rx
                .recv()
                .unwrap_or_else(|_| panic!("lanes={lanes}: request {i} dropped"))
                .unwrap_or_else(|e| panic!("lanes={lanes}: request {i} failed: {e}"));
            assert!(matches!(resp.output, TaskOutput::Classification { .. }));
        }
        // Post-shutdown submissions get a structured error, not a hang.
        let err = handle.predict(&[env.seeds[0]]).unwrap_err();
        assert!(
            err.to_string().contains("shut down"),
            "lanes={lanes}: want shutdown error, got {err}"
        );
    }
}

/// Per-response determinism across lane counts: 2- and 8-lane servers
/// answer bit-identically to the single-lane oracle, and out-of-range
/// seed ids stay per-request structured errors.
#[test]
fn serve_concurrency_lane_parity_bit_identical() {
    let env = env();
    let oracle =
        task_server(&env, 7, ServeConfig { lanes: 1, max_batch: 1, ..ServeConfig::default() });
    let probe: Vec<Vec<u32>> = env.seeds.iter().take(8).map(|&s| vec![s]).collect();
    let mut want: HashMap<Vec<u32>, TaskOutput> = HashMap::new();
    for seeds in &probe {
        want.insert(seeds.clone(), oracle.predict(seeds).unwrap().output);
    }
    for lanes in [2usize, 8] {
        let server = task_server(&env, 7, ServeConfig { lanes, ..ServeConfig::default() });
        // Hammer from several client threads so waves really overlap.
        std::thread::scope(|s| {
            for c in 0..4 {
                let server = &server;
                let probe = &probe;
                let want = &want;
                s.spawn(move || {
                    for round in 0..3 {
                        for seeds in probe.iter().skip((c + round) % probe.len()) {
                            let resp = server.predict(seeds).unwrap();
                            assert!(
                                outputs_bit_identical(&resp.output, &want[seeds]),
                                "lanes={lanes}: {seeds:?} diverged from oracle"
                            );
                        }
                    }
                });
            }
        });
        // Seed-id bounds check stays a per-request error at any lane count.
        assert!(server.predict(&[u32::MAX]).is_err(), "lanes={lanes}");
        assert!(server.predict(&[env.seeds[0]]).is_ok(), "lanes={lanes}: server survives");
        server.shutdown();
    }
    oracle.shutdown();
}

/// Property: cache-on and cache-off responses are bit-identical across
/// hit/miss/eviction interleavings. A tiny capacity over a wider key
/// population forces all three cache events while concurrent clients
/// shuffle the access order.
#[test]
fn serve_concurrency_cache_on_off_bit_identical() {
    let env = env();
    let cache_off =
        task_server(&env, 7, ServeConfig { lanes: 1, max_batch: 1, ..ServeConfig::default() });
    let keys: Vec<Vec<u32>> = env.seeds.iter().take(12).map(|&s| vec![s]).collect();
    let mut want: HashMap<Vec<u32>, TaskOutput> = HashMap::new();
    for seeds in &keys {
        want.insert(seeds.clone(), cache_off.predict(seeds).unwrap().output);
    }
    for lanes in [1usize, 2, 8] {
        let cached = task_server(
            &env,
            7,
            ServeConfig { lanes, cache_capacity: 4, ..ServeConfig::default() },
        );
        std::thread::scope(|s| {
            for c in 0..4usize {
                let cached = &cached;
                let keys = &keys;
                let want = &want;
                s.spawn(move || {
                    // Deterministic per-client LCG walk: lots of repeats
                    // (hits) interleaved with fresh keys (misses) that
                    // overflow capacity 4 (evictions).
                    let mut x = (c as u64) * 2654435761 + 12345;
                    for _ in 0..40 {
                        x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                        let seeds = &keys[(x >> 33) as usize % keys.len()];
                        let resp = cached.predict(seeds).unwrap();
                        assert!(
                            outputs_bit_identical(&resp.output, &want[seeds]),
                            "lanes={lanes}: cached response for {seeds:?} diverged"
                        );
                    }
                });
            }
        });
        // A sequential tail makes every counter deterministic: walking
        // all 12 keys forces ≥ 8 evictions past capacity 4 no matter
        // what the concurrent phase left resident, and a back-to-back
        // repeat of one key with nothing else in flight must hit.
        for seeds in &keys {
            let resp = cached.predict(seeds).unwrap();
            assert!(outputs_bit_identical(&resp.output, &want[seeds]), "lanes={lanes}");
        }
        cached.predict(&keys[0]).unwrap();
        cached.predict(&keys[0]).unwrap();
        let snap = cached.stats.snapshot();
        let (hits, misses) = (snap.cache_hits, snap.cache_misses);
        assert!(hits > 0, "lanes={lanes}: no cache hits (misses={misses})");
        assert!(misses > 0, "lanes={lanes}: no cache misses");
        assert!(
            snap.cache_evictions > 0,
            "lanes={lanes}: no evictions despite 12 keys over capacity 4"
        );
        assert_eq!(snap.cache_lookups(), hits + misses, "lanes={lanes}: lookup identity");
        cached.shutdown();
    }
    // The cache-off server counted nothing.
    let off = cache_off.stats.snapshot();
    assert_eq!(off.cache_hits, 0);
    assert_eq!(off.cache_misses, 0);
    assert_eq!(off.cache_lookups(), 0);
    cache_off.shutdown();
}

/// Hot-swap mid-load: every response reflects exactly one model
/// generation — bit-identical to the old model's oracle if tagged
/// generation 1, to the new model's oracle if tagged generation 2 —
/// never a mix.
#[test]
fn serve_concurrency_hot_swap_never_mixes_generations() {
    let env = env();
    let probe: Vec<Vec<u32>> = env.seeds.iter().take(6).map(|&s| vec![s]).collect();
    // Oracles for both weight sets.
    let oracle_a =
        task_server(&env, 7, ServeConfig { lanes: 1, max_batch: 1, ..ServeConfig::default() });
    let oracle_b =
        task_server(&env, 8, ServeConfig { lanes: 1, max_batch: 1, ..ServeConfig::default() });
    let mut want: HashMap<Vec<u32>, (TaskOutput, TaskOutput)> = HashMap::new();
    for seeds in &probe {
        want.insert(
            seeds.clone(),
            (oracle_a.predict(seeds).unwrap().output, oracle_b.predict(seeds).unwrap().output),
        );
    }
    // The two weight sets must actually differ, or the test is vacuous.
    let (a0, b0) = &want[&probe[0]];
    assert!(!outputs_bit_identical(a0, b0), "seeds 7 and 8 initialized identical models?");
    oracle_a.shutdown();
    oracle_b.shutdown();

    for lanes in [2usize, 8] {
        let server = task_server(&env, 7, ServeConfig { lanes, ..ServeConfig::default() });
        std::thread::scope(|s| {
            for c in 0..4usize {
                let server = &server;
                let probe = &probe;
                let want = &want;
                s.spawn(move || {
                    for i in 0..30 {
                        let seeds = &probe[(c + i) % probe.len()];
                        let resp = server.predict(seeds).unwrap();
                        let (a, b) = &want[seeds];
                        match resp.generation {
                            1 => assert!(
                                outputs_bit_identical(&resp.output, a),
                                "lanes={lanes}: gen-1 response diverged from model A"
                            ),
                            2 => assert!(
                                outputs_bit_identical(&resp.output, b),
                                "lanes={lanes}: gen-2 response diverged from model B"
                            ),
                            g => panic!("lanes={lanes}: unexpected generation {g}"),
                        }
                    }
                });
            }
            // Swap mid-load from the scope's own thread (the scope
            // joins it, so a failed swap panics the test).
            let server = &server;
            let env = &env;
            s.spawn(move || {
                std::thread::sleep(Duration::from_millis(5));
                let next = Arc::new(NativeModel::init(env.cfg.clone(), 8).unwrap());
                let generation = server.swap_model(next).unwrap();
                assert_eq!(generation, 2);
            });
        });
        assert_eq!(server.generation(), 2, "lanes={lanes}");
        assert_eq!(server.stats.snapshot().swaps, 1, "lanes={lanes}");
        // Post-swap requests serve generation 2 exclusively.
        let resp = server.predict(&probe[0]).unwrap();
        assert_eq!(resp.generation, 2, "lanes={lanes}");
        assert!(outputs_bit_identical(&resp.output, &want[&probe[0]].1), "lanes={lanes}");
        server.shutdown();
    }
}

/// The checkpoint codec path of hot-swap: `param.`-prefixed tensor
/// names round-trip through `swap_checkpoint`, and a shape-mismatched
/// replacement is rejected whole without touching the served model.
#[test]
fn serve_concurrency_swap_checkpoint_codec_and_validation() {
    let env = env();
    let server = task_server(&env, 7, ServeConfig { lanes: 2, ..ServeConfig::default() });
    let probe: Vec<Vec<u32>> = env.seeds.iter().take(4).map(|&s| vec![s]).collect();

    // Write model B's weights as a checkpoint with the AOT runtime's
    // `param.` name prefix, then swap the server onto it.
    let model_b = NativeModel::init(env.cfg.clone(), 8).unwrap();
    let tensors: Vec<_> =
        model_b.params_as_tensors().into_iter().map(|(n, t)| (format!("param.{n}"), t)).collect();
    let dir = std::env::temp_dir().join(format!("tfgnn-swap-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let ckpt = dir.join("model_b.tfgc");
    tfgnn::train::checkpoint::save(&ckpt, &tensors).unwrap();
    let generation = server.swap_checkpoint(&ckpt).unwrap();
    assert_eq!(generation, 2);

    // Served outputs now match a from-scratch model-B oracle.
    let oracle_b =
        task_server(&env, 8, ServeConfig { lanes: 1, max_batch: 1, ..ServeConfig::default() });
    for seeds in &probe {
        let got = server.predict(seeds).unwrap();
        assert_eq!(got.generation, 2);
        assert!(outputs_bit_identical(&got.output, &oracle_b.predict(seeds).unwrap().output));
    }
    oracle_b.shutdown();

    // A wrong-architecture replacement is rejected all-or-nothing.
    let mag = MagConfig::tiny();
    let wide = ModelConfig::for_mag(&mag, 16, 16, 1);
    let wrong = Arc::new(NativeModel::init(wide, 9).unwrap());
    assert!(server.swap_model(wrong).is_err());
    assert_eq!(server.generation(), 2, "failed swap must not bump the generation");
    let still = server.predict(&probe[0]).unwrap();
    assert_eq!(still.generation, 2);

    // AOT handles have no swappable slot: `serve_native` does, so use
    // the root handle only for the shutdown-error twin check here.
    let root = serve_native(
        Arc::new(NativeModel::init(env.cfg.clone(), 7).unwrap()),
        Arc::clone(&env.sampler),
        tfgnn::runtime::batch::RootTask::default(),
        ServeConfig::default(),
    )
    .unwrap();
    root.shutdown();
    let err = root.predict(env.seeds[0]).unwrap_err();
    assert!(err.to_string().contains("shut down"), "{err}");

    server.shutdown();
    std::fs::remove_dir_all(&dir).unwrap();
}
