//! Integration: the full Fig. 4 pipeline, distributed-sampler edition.
//!
//! synth-MAG → sharded store → Algorithm 1 leader/worker sampling →
//! shard files on disk → ShardProvider pipeline → AOT training →
//! accuracy better than chance. Exercises every layer together.

use std::path::Path;
use std::sync::Arc;

use tfgnn::coordinator::{run_sampling_to_shards, CoordinatorConfig};
use tfgnn::pipeline::{epoch_stream, DatasetProvider, PipelineConfig, ShardProvider};
use tfgnn::runner::MagEnv;
use tfgnn::runtime::batch::RootTask;
use tfgnn::runtime::Runtime;
use tfgnn::store::sharded::ShardedStore;
use tfgnn::synth::mag::Split;
use tfgnn::train::metrics::EpochMetrics;
use tfgnn::train::{Hyperparams, Trainer};

fn artifacts() -> Option<&'static Path> {
    let p = Path::new("artifacts");
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

#[test]
fn full_pipeline_samples_trains_and_beats_chance() {
    let Some(dir) = artifacts() else { return };
    let env = MagEnv::from_artifacts(dir).unwrap();
    let tmp = std::env::temp_dir().join(format!("tfgnn-e2e-{}", std::process::id()));
    std::fs::create_dir_all(&tmp).unwrap();

    // Stage 1: distributed sampling with injected RPC failures AND
    // worker crashes — the resilience path must still produce exact
    // results (cross-checked against the in-memory sampler elsewhere).
    let train_seeds = env.dataset.papers_in_split(Split::Train);
    let subset = &train_seeds[..320.min(train_seeds.len())];
    let sharded = Arc::new(
        ShardedStore::new(Arc::clone(&env.store), 8).with_failures(0.05, 99),
    );
    let cfg = CoordinatorConfig {
        num_workers: 4,
        batch_size: 16,
        worker_crash_rate: 0.1,
        crash_seed: 5,
        max_item_attempts: 40,
        ..Default::default()
    };
    let (set, report) = run_sampling_to_shards(
        sharded,
        env.sampler.spec(),
        env.manifest.plan_seed().unwrap(),
        subset,
        &cfg,
        &tmp,
        "train",
        4,
    )
    .unwrap();
    assert_eq!(report.stats.subgraphs, subset.len());
    assert!(report.stats.retried_rpcs > 0, "RPC failures exercised");
    assert_eq!(set.count().unwrap(), subset.len());

    // Stage 2: stream the shards through the padding pipeline into the
    // AOT trainer.
    let provider = Arc::new(ShardProvider::new(set));
    let mut pipe = PipelineConfig::new(env.batch_size, env.pad.clone());
    pipe.shuffle_buffer = 32;
    pipe.shuffle_seed = 11;
    let entry = env.manifest.model("mpnn").unwrap().clone();
    let hp = Hyperparams { learning_rate: 2e-3, dropout: 0.1, weight_decay: 1e-5 };
    let mut trainer =
        Trainer::new(Runtime::cpu().unwrap(), dir, &entry, RootTask::default(), hp).unwrap();

    let mut first_epoch = EpochMetrics::default();
    let mut last_epoch = EpochMetrics::default();
    let epochs = 6;
    for epoch in 0..epochs {
        let stream = epoch_stream(
            Arc::clone(&provider) as Arc<dyn DatasetProvider>,
            pipe.clone(),
            epoch,
        )
        .unwrap();
        let mut metrics = EpochMetrics::default();
        for padded in stream.iter() {
            metrics.add(trainer.train_batch(&padded).unwrap());
        }
        assert!(metrics.steps > 0, "pipeline produced batches");
        if epoch == 0 {
            first_epoch = metrics.clone();
        }
        if epoch == epochs - 1 {
            last_epoch = metrics.clone();
        }
    }
    assert!(
        last_epoch.loss() < first_epoch.loss(),
        "training loss must decrease: {:.4} -> {:.4}",
        first_epoch.loss(),
        last_epoch.loss()
    );

    // Stage 3: validation accuracy clearly better than chance
    // (20 classes -> 5%).
    let val_seeds = env.dataset.papers_in_split(Split::Validation);
    let mut val = EpochMetrics::default();
    for padded in env.eval_batches(&val_seeds, Some(12)) {
        if let Some(p) = padded.unwrap() {
            val.add(trainer.eval_batch(&p).unwrap());
        }
    }
    assert!(val.examples() > 0);
    let chance = 1.0 / 20.0;
    assert!(
        val.accuracy() > 3.0 * chance,
        "val accuracy {:.4} not above chance {chance}",
        val.accuracy()
    );

    std::fs::remove_dir_all(&tmp).unwrap();
}
