//! Live-introspection contracts: the admin endpoint, the health
//! watchdog, request deadlines and the incident flight recorder.
//!
//! The load-bearing assertions:
//! * **scrape inertness** — a scraper hammering every admin path while
//!   a loadgen run is in flight never changes the served bits (two
//!   identical servers, one scraped and one not, stay bit-identical);
//! * **/healthz flips to 503** for a wedged lane (injected via the
//!   `debug_stall` test hook) and recovers to 200 when the lane
//!   finishes its wave;
//! * **deadlines are deterministic** — an already-expired budget is
//!   answered `DeadlineExceeded` at any lane count, counted in
//!   `deadline_expired`, and never executed (`requests` stays 0); the
//!   in-queue expiry path behaves the same behind a stalled lane;
//! * **flight recorder** — a failed batch with `incident_dir` set
//!   leaves a parseable `tfgnn_incident_v1` dump on disk;
//! * **depth conservation** — after a loadgen run with rejections the
//!   per-server queue depth returns to exactly zero.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use tfgnn::ops::model_ref::ModelConfig;
use tfgnn::sampler::inmem::InMemorySampler;
use tfgnn::sampler::spec::mag_sampling_spec_scaled;
use tfgnn::serve::loadgen::{self, outputs_bit_identical, LoadGenConfig};
use tfgnn::serve::{serve_task, ServeConfig, TaskServerHandle};
use tfgnn::synth::mag::{generate, MagConfig, Split};
use tfgnn::train::native::NativeModel;
use tfgnn::Error;

struct Env {
    sampler: Arc<InMemorySampler>,
    cfg: ModelConfig,
    seeds: Vec<u32>,
}

fn env() -> Env {
    let mag = MagConfig::tiny();
    let ds = generate(&mag);
    let seeds = ds.papers_in_split(Split::Train);
    let store = Arc::new(ds.store);
    let spec = mag_sampling_spec_scaled(&store.schema, 0.2).unwrap();
    let sampler = Arc::new(InMemorySampler::new(store, spec, 3).unwrap());
    let cfg = ModelConfig::for_mag(&mag, 8, 8, 1);
    Env { sampler, cfg, seeds }
}

fn task_server(env: &Env, model_seed: u64, serve_cfg: ServeConfig) -> TaskServerHandle {
    let task = tfgnn::tasks::build(&env.cfg).unwrap();
    let model = Arc::new(NativeModel::init(env.cfg.clone(), model_seed).unwrap());
    serve_task(model, Arc::clone(&env.sampler), task, serve_cfg).unwrap()
}

/// Minimal HTTP/1.0 GET; returns (status, body).
fn http_get(addr: SocketAddr, path: &str) -> (u16, String) {
    let mut s = TcpStream::connect(addr).unwrap();
    write!(s, "GET {path} HTTP/1.0\r\nHost: t\r\n\r\n").unwrap();
    let mut text = String::new();
    s.read_to_string(&mut text).unwrap();
    let status = text.split_whitespace().nth(1).unwrap_or("0").parse().unwrap_or(0);
    let body = text.split_once("\r\n\r\n").map(|(_, b)| b.to_string()).unwrap_or_default();
    (status, body)
}

/// Poll `path` until `want(status)` holds or the timeout elapses;
/// returns the final (status, body).
fn poll_until(
    addr: SocketAddr,
    path: &str,
    timeout: Duration,
    want: impl Fn(u16) -> bool,
) -> (u16, String) {
    let t0 = Instant::now();
    loop {
        let (status, body) = http_get(addr, path);
        if want(status) || t0.elapsed() > timeout {
            return (status, body);
        }
        std::thread::sleep(Duration::from_millis(20));
    }
}

fn temp_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("tfgnn_admin_live_{tag}_{}", std::process::id()))
}

/// Inertness under scrape: two identical servers — one with the admin
/// endpoint on and a scraper hammering every path mid-load, one with
/// no admin at all — answer every probe bit-identically. Also checks
/// that the scraped Prometheus body carries the serve counters.
#[test]
fn admin_scrape_under_load_never_changes_served_bits() {
    let env = env();
    let cfg = |admin: bool| ServeConfig {
        lanes: 2,
        admin_addr: admin.then(|| "127.0.0.1:0".to_string()),
        ..ServeConfig::default()
    };
    let scraped = task_server(&env, 7, cfg(true));
    let quiet = task_server(&env, 7, cfg(false));
    let addr = scraped.admin_addr().expect("admin endpoint configured");
    assert!(quiet.admin_addr().is_none(), "admin is off by default");

    let lists: Vec<Vec<u32>> = env.seeds.iter().take(8).map(|&s| vec![s]).collect();
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = Arc::clone(&stop);
    let poller = std::thread::spawn(move || {
        let mut scrapes = 0usize;
        while !stop2.load(Ordering::SeqCst) {
            for path in ["/metrics", "/metrics.json", "/healthz", "/tracez", "/statusz", "/"] {
                let (status, _) = http_get(addr, path);
                assert!(status == 200 || status == 503, "{path}: status {status}");
                scrapes += 1;
            }
        }
        scrapes
    });

    let lg = LoadGenConfig { concurrency: vec![1, 4], requests_per_client: 6 };
    loadgen::run(&scraped, &lists, &lg).unwrap();

    stop.store(true, Ordering::SeqCst);
    let scrapes = poller.join().unwrap();
    assert!(scrapes > 0, "the poller must actually have scraped mid-load");

    // Bit-parity: the scraped server answers exactly like the quiet one.
    for seeds in &lists {
        let got = scraped.predict(seeds).unwrap();
        let want = quiet.predict(seeds).unwrap();
        assert!(
            outputs_bit_identical(&got.output, &want.output),
            "scraping changed served bits for seeds {seeds:?}"
        );
    }

    // The live exposition carries the serve metrics, including the
    // always-registered deadline counter.
    let (status, body) = http_get(addr, "/metrics");
    assert_eq!(status, 200);
    assert!(body.contains("serve_requests_total"), "{body}");
    assert!(body.contains("serve_deadline_expired_total"), "{body}");

    scraped.shutdown();
    quiet.shutdown();
    // The endpoint goes away with the server.
    assert!(TcpStream::connect(addr).is_err() || http_get_closed(addr));
}

/// After shutdown the listener is gone; a connect may still succeed
/// briefly on some stacks, but reads must fail. Helper keeps the
/// assertion above readable.
fn http_get_closed(addr: SocketAddr) -> bool {
    let Ok(mut s) = TcpStream::connect(addr) else { return true };
    let _ = write!(s, "GET / HTTP/1.0\r\n\r\n");
    let mut text = String::new();
    s.read_to_string(&mut text).map(|_| text.is_empty()).unwrap_or(true)
}

/// A wedged lane (injected stall far above the watchdog threshold)
/// flips `/healthz` to 503 naming the lane, and the verdict recovers
/// to 200 once the lane finishes its wave.
#[test]
fn healthz_reports_503_for_a_wedged_lane_and_recovers() {
    let env = env();
    let handle = task_server(
        &env,
        7,
        ServeConfig {
            lanes: 1,
            max_batch: 1,
            max_wait: Duration::ZERO,
            admin_addr: Some("127.0.0.1:0".to_string()),
            watchdog_threshold: Duration::from_millis(60),
            // The single lane sleeps 700ms at the start of every wave:
            // mid-wave it is wedged by any 60ms threshold.
            debug_stall: Some((0, Duration::from_millis(700))),
            ..ServeConfig::default()
        },
    );
    let addr = handle.admin_addr().unwrap();
    let (status, body) = http_get(addr, "/healthz");
    assert_eq!(status, 200, "idle server is healthy: {body}");

    let rx1 = handle.submit(vec![env.seeds[0]]);
    let rx2 = handle.submit(vec![env.seeds[1]]);
    let (status, body) = poll_until(addr, "/healthz", Duration::from_secs(5), |s| s == 503);
    assert_eq!(status, 503, "wedged lane must flip healthz: {body}");
    assert!(body.contains("unhealthy"), "{body}");
    assert!(body.contains("lane 0 wedged"), "{body}");

    // Both requests are still answered (the lane is slow, not dead)...
    rx1.recv().unwrap().unwrap();
    rx2.recv().unwrap().unwrap();
    // ...and the verdict recovers once the lane is idle again.
    let (status, body) = poll_until(addr, "/healthz", Duration::from_secs(5), |s| s == 200);
    assert_eq!(status, 200, "idle lane must recover: {body}");
    // The watchdog recorded the trip (checker thread runs because the
    // admin endpoint is on).
    assert!(handle.health().trips >= 1, "trip must be counted");
    handle.shutdown();
}

/// An already-expired budget is answered `DeadlineExceeded` at any
/// lane count — counted, depth-neutral, and never executed.
#[test]
fn deadline_expiry_is_deterministic_at_every_lane_count() {
    let env = env();
    for lanes in [1usize, 2, 8] {
        let handle = task_server(&env, 7, ServeConfig { lanes, ..ServeConfig::default() });
        let n = 6usize;
        for i in 0..n {
            let rx = handle
                .submit_with_deadline(vec![env.seeds[i % env.seeds.len()]], Some(Duration::ZERO));
            match rx.recv().unwrap() {
                Err(Error::DeadlineExceeded(msg)) => {
                    assert!(msg.contains("never"), "lanes={lanes}: {msg}")
                }
                other => panic!("lanes={lanes}: want DeadlineExceeded, got {other:?}"),
            }
        }
        let snap = handle.stats.snapshot();
        assert_eq!(snap.deadline_expired, n as u64, "lanes={lanes}");
        assert_eq!(snap.requests, 0, "lanes={lanes}: expired requests never executed");
        assert_eq!(snap.queue_depth, 0, "lanes={lanes}: depth stays balanced");
        // A request with headroom still serves normally.
        let resp = handle
            .submit_with_deadline(vec![env.seeds[0]], Some(Duration::from_secs(30)))
            .recv()
            .unwrap()
            .unwrap();
        assert_eq!(resp.seeds, vec![env.seeds[0]]);
        assert!(handle.stats.snapshot().requests >= 1, "lanes={lanes}");
        handle.shutdown();
    }
}

/// In-queue expiry: a request whose budget runs out while it waits
/// behind a stalled lane is expired by the lane (not at admission) and
/// still never reaches the model.
#[test]
fn deadline_expires_in_queue_behind_a_stalled_lane() {
    let env = env();
    let handle = task_server(
        &env,
        7,
        ServeConfig {
            lanes: 1,
            max_batch: 1,
            max_wait: Duration::ZERO,
            // Every wave takes >= 400ms, so the second request's 50ms
            // budget is long gone when the lane reaches it.
            debug_stall: Some((0, Duration::from_millis(400))),
            ..ServeConfig::default()
        },
    );
    let rx_ok = handle.submit(vec![env.seeds[0]]);
    let rx_late = handle.submit_with_deadline(vec![env.seeds[1]], Some(Duration::from_millis(50)));
    rx_ok.recv().unwrap().unwrap();
    match rx_late.recv().unwrap() {
        Err(Error::DeadlineExceeded(msg)) => assert!(msg.contains("in queue"), "{msg}"),
        other => panic!("want in-queue DeadlineExceeded, got {other:?}"),
    }
    let snap = handle.stats.snapshot();
    assert_eq!(snap.requests, 1, "only the first request executed");
    assert_eq!(snap.deadline_expired, 1);
    assert_eq!(snap.queue_depth, 0, "expiry is depth-neutral");
    handle.shutdown();
}

/// A failed batch on a server with `incident_dir` set leaves a
/// parseable `tfgnn_incident_v1` dump behind.
#[test]
fn flight_recorder_dumps_on_a_failed_batch() {
    let env = env();
    let dir = temp_dir("flight");
    let _ = std::fs::remove_dir_all(&dir);
    let handle = task_server(
        &env,
        7,
        ServeConfig { incident_dir: Some(dir.clone()), ..ServeConfig::default() },
    );
    // Out-of-range seed: the sampler fails the request, the wave is
    // counted failed, and the lane triggers a flight dump.
    let err = handle.predict(&[9_999_999]).unwrap_err();
    assert!(!matches!(err, Error::Overloaded(_) | Error::DeadlineExceeded(_)), "{err}");
    handle.shutdown();

    let dumps: Vec<PathBuf> = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().is_some_and(|x| x == "json"))
        .collect();
    assert!(!dumps.is_empty(), "expected an incident dump in {}", dir.display());
    let doc =
        tfgnn::util::json::Json::parse(&std::fs::read_to_string(&dumps[0]).unwrap()).unwrap();
    assert_eq!(doc.get("schema").unwrap().as_str().unwrap(), "tfgnn_incident_v1");
    assert_eq!(doc.get("trigger").unwrap().as_str().unwrap(), "failed-batch");
    assert_eq!(
        doc.get("metrics").unwrap().get("schema").unwrap().as_str().unwrap(),
        "tfgnn_metrics_v1"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// `/statusz` surfaces the watchdog's last checker-evaluation
/// timestamp (null until the first tick, then a real unix time) and
/// the flight recorder's suppressed-dump tally (null when no recorder
/// is armed, an integer when one is).
#[test]
fn statusz_reports_watchdog_eval_time_and_flight_suppression() {
    let env = env();
    let dir = temp_dir("statusz");
    let _ = std::fs::remove_dir_all(&dir);
    let handle = task_server(
        &env,
        7,
        ServeConfig {
            admin_addr: Some("127.0.0.1:0".to_string()),
            incident_dir: Some(dir.clone()),
            watchdog_threshold: Duration::from_millis(50),
            ..ServeConfig::default()
        },
    );
    let addr = handle.admin_addr().unwrap();
    // The checker thread stamps its first evaluation within a few
    // ticks; poll /statusz until the field turns non-null.
    let t0 = Instant::now();
    let doc = loop {
        let (status, body) = http_get(addr, "/statusz");
        assert_eq!(status, 200, "{body}");
        let doc = tfgnn::util::json::Json::parse(&body).unwrap();
        let stamped = doc.get("watchdog_last_eval_unix_secs").unwrap().as_i64().is_ok();
        if stamped || t0.elapsed() > Duration::from_secs(5) {
            break doc;
        }
        std::thread::sleep(Duration::from_millis(20));
    };
    let stamp = doc.get("watchdog_last_eval_unix_secs").unwrap().as_i64().unwrap();
    assert!(stamp > 0, "checker stamped a real unix time");
    // Flight recorder armed, nothing suppressed yet: integer zero, not
    // null.
    assert_eq!(doc.get("flight_suppressed").unwrap().as_i64().unwrap(), 0);
    handle.shutdown();
    let _ = std::fs::remove_dir_all(&dir);

    // Without an incident dir the suppression tally reports null.
    let quiet = task_server(
        &env,
        7,
        ServeConfig { admin_addr: Some("127.0.0.1:0".to_string()), ..ServeConfig::default() },
    );
    let (status, body) = http_get(quiet.admin_addr().unwrap(), "/statusz");
    assert_eq!(status, 200);
    let doc = tfgnn::util::json::Json::parse(&body).unwrap();
    assert!(matches!(doc.get("flight_suppressed").unwrap(), tfgnn::util::json::Json::Null));
    quiet.shutdown();
}

/// Queue-depth conservation around the Overloaded reject path: after a
/// loadgen run that provokes rejections, the per-server depth is back
/// to exactly zero and every request has exactly one outcome.
#[test]
fn queue_depth_returns_to_zero_after_rejections() {
    let env = env();
    let handle = task_server(
        &env,
        7,
        ServeConfig {
            lanes: 1,
            max_batch: 1,
            max_wait: Duration::ZERO,
            queue_capacity: 2,
            wave_delay: Duration::from_millis(15),
            ..ServeConfig::default()
        },
    );
    let lists: Vec<Vec<u32>> = env.seeds.iter().take(6).map(|&s| vec![s]).collect();
    let lg = LoadGenConfig { concurrency: vec![8], requests_per_client: 6 };
    let report = loadgen::run(&handle, &lists, &lg).unwrap();
    let level = &report.levels[0];
    let total = 8 * 6;
    assert_eq!(
        level.ok + level.rejected + level.deadline + level.failed,
        total,
        "every request has exactly one outcome"
    );
    let snap = handle.stats.snapshot();
    assert!(snap.rejected > 0, "the tiny queue must reject under an 8-client burst");
    assert_eq!(
        snap.queue_depth, 0,
        "depth must return to zero: rejected requests are never admitted, \
         admitted ones are replied exactly once"
    );
    handle.shutdown();
    assert_eq!(handle.stats.snapshot().queue_depth, 0, "still zero after drain");
}
