//! Graph-level regression: context-style mean-pool readout with MSE
//! loss over per-component scalar targets.
//!
//! Per component: mean-pool the final states of the configured node
//! set (the whole component — a context readout, not a root gather),
//! apply the scalar linear head `reg.w`/`reg.b`, and regress onto the
//! root node's target feature (e.g. the paper's publication `year`),
//! normalized as `(t − shift) · scale` so raw-unit targets (years)
//! don't blow up the loss scale. Backward composes the FD-checked
//! [`grad::segment_mean_vjp`] / [`grad::matmul_vjp`] / [`grad::mse`]
//! rules and seeds the trunk's reverse sweep.

use crate::graph::{Feature, GraphTensor};
use crate::ops::model_ref::Mat;
use crate::train::metrics::TaskMetrics;
use crate::train::native::{grad, NativeModel};
use crate::{Error, Result};

use super::{Task, TaskOutput, TaskStep};

/// The graph-regression task binding.
#[derive(Debug, Clone)]
pub struct GraphRegression {
    /// Node set whose states are mean-pooled (also carries the target).
    pub node_set: String,
    /// Scalar target feature on the root node (node 0).
    pub target_feature: String,
    /// Target normalization: `t_norm = (t − shift) · scale`.
    pub shift: f32,
    pub scale: f32,
}

impl GraphRegression {
    /// The component's normalized scalar target, read off the root
    /// node's feature (i64 or f32).
    fn read_target(&self, g: &GraphTensor) -> Result<f32> {
        let ns = g.node_set(&self.node_set)?;
        if ns.total() == 0 {
            return Err(Error::Graph(format!(
                "component has no {:?} root node",
                self.node_set
            )));
        }
        let raw = match ns.feature(&self.target_feature)? {
            Feature::I64 { dims, data } if dims.is_empty() => data[0] as f32,
            Feature::F32 { dims, data } if dims.is_empty() => data[0],
            other => {
                return Err(Error::Feature(format!(
                    "regression target {}/{} is not a scalar-per-node feature \
                     (dtype {:?}, {} dims) — want scalar i64 or f32",
                    self.node_set,
                    self.target_feature,
                    other.dtype(),
                    match other {
                        Feature::I64 { dims, .. } | Feature::F32 { dims, .. } => dims.len(),
                        _ => 0,
                    }
                )));
            }
        };
        Ok((raw - self.shift) * self.scale)
    }

    /// Mean-pool + scalar head over final states; returns the
    /// prediction and the pooled row (the head's backward input).
    fn predict(
        &self,
        model: &NativeModel,
        h: &std::collections::BTreeMap<String, Mat>,
        n: usize,
    ) -> Result<(f32, Mat)> {
        let h_ns = h.get(&self.node_set).ok_or_else(|| {
            Error::Graph(format!("unknown regression node set {:?}", self.node_set))
        })?;
        let seg = vec![0i32; n];
        let pooled = grad::segment_mean_fwd(h_ns, &seg, 1);
        let w = model.param("reg.w")?;
        let b = model.param("reg.b")?;
        let mut z = pooled.matmul(w);
        z.add_bias(&b.data);
        Ok((z.data[0], pooled))
    }

    fn metrics_of(pred: f32, target: f32) -> TaskMetrics {
        // The squared error is computed in f32 like the loss (so a
        // single example's se_sum equals its loss bit-for-bit) and
        // *accumulated* in f64.
        let e = pred - target;
        TaskMetrics {
            se_sum: (e * e) as f64,
            ae_sum: e.abs() as f64,
            scored: 1.0,
            ..TaskMetrics::default()
        }
    }
}

impl Task for GraphRegression {
    fn name(&self) -> &'static str {
        "graph_regression"
    }

    fn step_grad(
        &self,
        model: &NativeModel,
        g: &GraphTensor,
        grads: &mut [Mat],
    ) -> Result<TaskStep> {
        let target = self.read_target(g)?;
        let n = g.node_set(&self.node_set)?.total();
        let (h, trunk) = model.forward_states_tape(g)?;
        let (pred, pooled) = self.predict(model, &h, n)?;
        let (loss, dpred) = grad::mse(pred, target);
        let dz = Mat { rows: 1, cols: 1, data: vec![dpred] };
        let w = model.param("reg.w")?;
        let (dpooled, dw) = grad::matmul_vjp(&pooled, w, &dz);
        grads[model.idx("reg.w")?].add_assign(&dw);
        grads[model.idx("reg.b")?]
            .add_assign(&Mat { rows: 1, cols: 1, data: grad::bias_vjp(&dz) });
        let seg = vec![0i32; n];
        let d_ns = grad::segment_mean_vjp(&seg, 1, &dpooled);
        let mut dh = model.zero_state_grads(g)?;
        dh.get_mut(&self.node_set)
            .ok_or_else(|| {
                Error::Graph(format!("state grads missing node set {:?}", self.node_set))
            })?
            .add_assign(&d_ns);
        model.backward_states(g, &trunk, dh, grads)?;
        Ok(TaskStep { loss: loss as f64, metrics: Self::metrics_of(pred, target) })
    }

    fn step_eval(&self, model: &NativeModel, g: &GraphTensor) -> Result<TaskStep> {
        let target = self.read_target(g)?;
        let n = g.node_set(&self.node_set)?.total();
        let h = model.forward_states(g)?;
        let (pred, _pooled) = self.predict(model, &h, n)?;
        let (loss, _dpred) = grad::mse(pred, target);
        Ok(TaskStep { loss: loss as f64, metrics: Self::metrics_of(pred, target) })
    }

    /// Predict the target in its *unnormalized* scale.
    fn infer(&self, model: &NativeModel, g: &GraphTensor) -> Result<TaskOutput> {
        let n = g.node_set(&self.node_set)?.total();
        if n == 0 {
            return Err(Error::Graph(format!(
                "regression request subgraph has no {:?} nodes",
                self.node_set
            )));
        }
        let h = model.forward_states(g)?;
        let (pred, _pooled) = self.predict(model, &h, n)?;
        Ok(TaskOutput::Regression { value: pred / self.scale + self.shift })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::model_ref::{ModelConfig, TaskConfig};
    use crate::sampler::inmem::InMemorySampler;
    use crate::sampler::spec::mag_sampling_spec_scaled;
    use crate::synth::mag::{generate, MagConfig};
    use crate::util::rng::Rng;
    use std::sync::Arc;

    fn setup() -> (NativeModel, GraphRegression, GraphTensor) {
        let ds = generate(&MagConfig::tiny());
        let store = Arc::new(ds.store);
        let spec = mag_sampling_spec_scaled(&store.schema, 0.2).unwrap();
        let sampler = InMemorySampler::new(store, spec, 3).unwrap();
        let g = sampler.sample(1).unwrap();
        let t = TaskConfig {
            kind: "graph_regression".into(),
            target_feature: "year".into(),
            target_shift: 2010.0,
            target_scale: 0.1,
            ..TaskConfig::default()
        };
        let cfg = ModelConfig::for_mag(&MagConfig::tiny(), 8, 8, 1).with_task(t);
        let model = NativeModel::init(cfg, 5).unwrap();
        let task = GraphRegression {
            node_set: "paper".into(),
            target_feature: "year".into(),
            shift: 2010.0,
            scale: 0.1,
        };
        (model, task, g)
    }

    #[test]
    fn eval_and_grad_losses_agree_bitexact() {
        let (model, task, g) = setup();
        let eval = task.step_eval(&model, &g).unwrap();
        let mut grads = model.zeros_grads();
        let step = task.step_grad(&model, &g, &mut grads).unwrap();
        assert_eq!((eval.loss as f32).to_bits(), (step.loss as f32).to_bits());
        assert_eq!(eval.metrics, step.metrics);
        assert!(step.loss.is_finite());
        assert!(grads.iter().any(|m| m.data.iter().any(|&v| v != 0.0)));
        // MSE identity: loss == se_sum for a single example.
        assert!((step.loss - step.metrics.se_sum).abs() < 1e-12);
    }

    /// End-to-end gradcheck through trunk + mean-pool + scalar head.
    #[test]
    fn gradcheck_graph_regression_end_to_end() {
        let (model, task, g) = setup();
        let loss_of = |m: &NativeModel| -> f64 { task.step_eval(m, &g).unwrap().loss };
        let mut grads = model.zeros_grads();
        task.step_grad(&model, &g, &mut grads).unwrap();
        let mut rng = Rng::new(31);
        let h = 1e-2f32;
        let mut checked = 0usize;
        for (pi, name) in model.names.iter().enumerate() {
            let n_elems = model.params[pi].data.len();
            if n_elems == 0 {
                continue;
            }
            for _ in 0..2.min(n_elems) {
                let ei = rng.uniform(n_elems);
                let mut mp = model.clone();
                mp.params[pi].data[ei] += h;
                let mut mm = model.clone();
                mm.params[pi].data[ei] -= h;
                let fd = (loss_of(&mp) - loss_of(&mm)) / (2.0 * h as f64);
                let an = grads[pi].data[ei] as f64;
                let denom = an.abs().max(fd.abs()).max(1.0);
                assert!(
                    (an - fd).abs() / denom <= 1e-2,
                    "{name}[{ei}]: analytic {an} vs fd {fd}"
                );
                checked += 1;
            }
        }
        assert!(checked >= 10, "probed {checked} elements");
    }

    #[test]
    fn infer_unnormalizes_the_prediction() {
        let (model, task, g) = setup();
        let TaskOutput::Regression { value } = task.infer(&model, &g).unwrap() else {
            panic!("wrong output shape");
        };
        assert!(value.is_finite());
        let h = model.forward_states(&g).unwrap();
        let n = g.node_set("paper").unwrap().total();
        let (pred, _) = task.predict(&model, &h, n).unwrap();
        assert!((value - (pred / 0.1 + 2010.0)).abs() < 1e-3);
    }

    #[test]
    fn bad_targets_are_structured_errors() {
        let (model, task, g) = setup();
        // A scalar i64 feature (#id) is a valid target.
        let ids = GraphRegression { target_feature: "#id".into(), ..task.clone() };
        assert!(ids.step_eval(&model, &g).is_ok());
        // A *non-scalar* feature must be rejected by name, not silently
        // regressed onto its first flattened element ("feat" is [n, 16]).
        let vector = GraphRegression { target_feature: "feat".into(), ..task.clone() };
        let err = vector.step_eval(&model, &g).expect_err("vector target");
        assert!(err.to_string().contains("scalar"), "{err}");
        // A missing feature errors too.
        let missing = GraphRegression { target_feature: "no_such".into(), ..task };
        assert!(missing.step_eval(&model, &g).is_err());
    }
}
