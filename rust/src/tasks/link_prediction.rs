//! Link prediction over a held-out edge split — the sampled-subgraph
//! production workload of "Scalable GNN Training: The Case for
//! Sampling" (Serafini & Guan, 2021).
//!
//! **Example shape.** One training example is a *pair subgraph*: the
//! rooted expansions of the positive pair `(u, v)` **and** of K
//! deterministic negatives, merged into one GraphTensor by
//! [`InMemorySampler::sample_seeds`] with the seed list pinned first in
//! the seed node set — node 0 is `u`, node 1 the positive `v`, nodes
//! `2..2+K` the negatives. Co-sampling the negatives is what makes
//! their *final* (message-passed) states exist in the same component,
//! so scoring stays a pure per-component function and every engine
//! invariant (1-thread == serial oracle bit parity, deterministic
//! all-reduce) carries over unchanged.
//!
//! **Negative-sampling determinism.** Negatives are seeded-uniform
//! draws keyed by `(split_seed, u, v)` — fixed at sampling time, never
//! at step time, so an example's loss is a pure function of the pair
//! and the parameters. The candidate count rides in the context
//! feature [`CANDS_FEATURE`] (per component, survives merge/pad).
//!
//! **Readout.** `dot` scores `⟨h_u, h_c⟩` (parameter-free);
//! `hadamard` scores `relu((h_u ∘ h_c)·W + b)·v + c` (an MLP over the
//! element-wise product). Loss is softmax cross-entropy with the
//! positive at index 0, or a pairwise margin hinge. Metrics: MRR and
//! hits@k over the candidate list (rank ties count against the
//! positive only on strict score superiority).
//!
//! The supervision pairs come from [`crate::synth::mag::edge_holdout`]:
//! a seeded fraction of an edge set is removed from the
//! message-passing store entirely (no leakage) and partitioned into
//! train/validation/test pairs.

use std::sync::Arc;

use crate::graph::pad::{fit_or_skip, PadSpec, Padded};
use crate::graph::{Feature, GraphTensor};
use crate::layers::row_mat;
use crate::ops::model_ref::{Mat, TaskConfig};
use crate::pipeline::DatasetProvider;
use crate::sampler::inmem::InMemorySampler;
use crate::train::metrics::TaskMetrics;
use crate::train::native::{grad, NativeModel};
use crate::util::rng::{mix64, Rng};
use crate::{Error, Result};

use super::{Task, TaskOutput, TaskStep};

/// Context feature carrying the per-component candidate count
/// (1 positive + K negatives), written by [`pair_example`].
pub const CANDS_FEATURE: &str = "lp_cands";

/// Pair scorer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Readout {
    /// `s = ⟨h_u, h_c⟩` — parameter-free.
    Dot,
    /// `s = relu((h_u ∘ h_c)·W + b)·v + c` — the Hadamard MLP
    /// (`lp.w`/`lp.b`/`lp.v`/`lp.c`).
    Hadamard,
}

/// Candidate loss.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LinkLoss {
    /// Softmax cross-entropy, positive at index 0 — reuses the
    /// FD-checked [`grad::softmax_xent_masked`].
    Softmax,
    /// Pairwise hinge `Σ max(0, margin − s_pos + s_neg)` — the
    /// FD-checked [`grad::margin_rank`].
    Margin(f32),
}

/// The link-prediction task binding.
#[derive(Debug, Clone)]
pub struct LinkPrediction {
    /// The (homogeneous) node set pairs are scored within.
    pub node_set: String,
    pub readout: Readout,
    pub loss: LinkLoss,
    pub hits_k: usize,
}

/// Saved readout activations for the backward pass.
struct ReadoutSaved {
    /// `[cands, hidden]` gathered source rows (h_u repeated).
    a: Mat,
    /// `[cands, hidden]` gathered candidate rows.
    b: Mat,
    /// Hadamard-MLP intermediates (None for dot).
    mlp: Option<MlpSaved>,
}

struct MlpSaved {
    /// `[cands, hidden]` element-wise product.
    x: Mat,
    /// `[cands, m]` pre-relu hidden layer.
    z1: Mat,
    /// `[cands, m]` post-relu hidden layer.
    hmid: Mat,
}

impl LinkPrediction {
    /// Build from a validated config (`node_set` is the edge set's
    /// homogeneous endpoint, resolved by [`super::build`]).
    pub fn from_config(node_set: String, t: &TaskConfig) -> Result<LinkPrediction> {
        let readout = match t.readout.as_str() {
            "dot" => Readout::Dot,
            "hadamard" => Readout::Hadamard,
            other => {
                return Err(Error::Schema(format!(
                    "task.readout {other:?} unknown (want dot|hadamard)"
                )));
            }
        };
        let loss = match t.loss.as_str() {
            "softmax" => LinkLoss::Softmax,
            "margin" => LinkLoss::Margin(t.margin),
            other => {
                return Err(Error::Schema(format!(
                    "task.loss {other:?} unknown (want softmax|margin)"
                )));
            }
        };
        Ok(LinkPrediction { node_set, readout, loss, hits_k: t.hits_k })
    }

    /// Node count + candidate count of one example component.
    fn shape_of(&self, g: &GraphTensor) -> Result<(usize, usize)> {
        let n = g.node_set(&self.node_set)?.total();
        let (_, c) = g.context.feature(CANDS_FEATURE)?.as_i64()?;
        let cands = c[0] as usize;
        if cands < 2 {
            return Err(Error::Graph(format!(
                "link-prediction example has {cands} candidates — needs the \
                 positive plus at least one negative (is the store too small \
                 for task.negatives?)"
            )));
        }
        if n < 1 + cands {
            return Err(Error::Graph(format!(
                "link-prediction example has {n} {:?} nodes for 1 source + \
                 {cands} candidates — pair seeds were not pinned first",
                self.node_set
            )));
        }
        Ok((n, cands))
    }

    /// Score source row 0 against candidate rows `cand_idx`, saving the
    /// backward intermediates. The float sequence is identical on the
    /// fused (eval) and taped (train) trunk paths.
    fn readout_fwd(
        &self,
        model: &NativeModel,
        h_ns: &Mat,
        src_idx: &[i32],
        cand_idx: &[i32],
    ) -> Result<(Vec<f32>, ReadoutSaved)> {
        let a = h_ns.gather(src_idx);
        let b = h_ns.gather(cand_idx);
        match self.readout {
            Readout::Dot => {
                let scores = grad::row_dot_fwd(&a, &b);
                Ok((scores, ReadoutSaved { a, b, mlp: None }))
            }
            Readout::Hadamard => {
                let x = grad::hadamard_fwd(&a, &b);
                let w = model.param("lp.w")?;
                let bb = model.param("lp.b")?;
                let mut z1 = x.matmul(w);
                z1.add_bias(&bb.data);
                let mut hmid = z1.clone();
                hmid.relu();
                let v = model.param("lp.v")?;
                let c = model.param("lp.c")?;
                let mut s = hmid.matmul(v);
                s.add_bias(&c.data);
                let scores = s.data;
                Ok((scores, ReadoutSaved { a, b, mlp: Some(MlpSaved { x, z1, hmid }) }))
            }
        }
    }

    /// VJP of [`Self::readout_fwd`]: accumulates `lp.*` gradients (for
    /// the Hadamard MLP) and returns `(da, db)` — gradients on the
    /// gathered source/candidate rows.
    fn readout_vjp(
        &self,
        model: &NativeModel,
        saved: &ReadoutSaved,
        dscores: &[f32],
        grads: &mut [Mat],
    ) -> Result<(Mat, Mat)> {
        match (&self.readout, &saved.mlp) {
            (Readout::Dot, _) => Ok(grad::row_dot_vjp(&saved.a, &saved.b, dscores)),
            (Readout::Hadamard, Some(mlp)) => {
                let ds = Mat { rows: dscores.len(), cols: 1, data: dscores.to_vec() };
                let v = model.param("lp.v")?;
                let (dhmid, dv) = grad::matmul_vjp(&mlp.hmid, v, &ds);
                grads[model.idx("lp.v")?].add_assign(&dv);
                grads[model.idx("lp.c")?].add_assign(&row_mat(grad::bias_vjp(&ds)));
                let dz1 = grad::relu_vjp(&mlp.z1, &dhmid);
                let w = model.param("lp.w")?;
                let (dx, dw) = grad::matmul_vjp(&mlp.x, w, &dz1);
                grads[model.idx("lp.w")?].add_assign(&dw);
                grads[model.idx("lp.b")?].add_assign(&row_mat(grad::bias_vjp(&dz1)));
                Ok(grad::hadamard_vjp(&saved.a, &saved.b, &dx))
            }
            (Readout::Hadamard, None) => {
                Err(Error::Runtime("hadamard backward without saved MLP tape".into()))
            }
        }
    }

    /// Loss and `∂L/∂scores` over the candidate list (positive first).
    fn loss_grad(&self, scores: &[f32]) -> (f64, Vec<f32>) {
        match self.loss {
            LinkLoss::Softmax => {
                let logits = Mat { rows: 1, cols: scores.len(), data: scores.to_vec() };
                let x = grad::softmax_xent_masked(&logits, &[0], &[1.0]);
                (x.total_ce as f64, x.dlogits.data)
            }
            LinkLoss::Margin(m) => {
                let (l, d) = grad::margin_rank(scores, m);
                (l as f64, d)
            }
        }
    }

    /// Rank of the positive among the candidates (1-based; a negative
    /// outranks only on a strictly greater score) and the derived
    /// metric sums.
    fn rank_metrics(&self, scores: &[f32]) -> TaskMetrics {
        let rank = 1 + scores[1..].iter().filter(|&&s| s > scores[0]).count();
        TaskMetrics {
            correct: if rank == 1 { 1.0 } else { 0.0 },
            rr_sum: 1.0 / rank as f64,
            hits_sum: if rank <= self.hits_k { 1.0 } else { 0.0 },
            scored: 1.0,
            ..TaskMetrics::default()
        }
    }

    fn states_of<'h>(
        &self,
        h: &'h std::collections::BTreeMap<String, Mat>,
    ) -> Result<&'h Mat> {
        h.get(&self.node_set).ok_or_else(|| {
            Error::Graph(format!("unknown link-prediction node set {:?}", self.node_set))
        })
    }
}

impl Task for LinkPrediction {
    fn name(&self) -> &'static str {
        "link_prediction"
    }

    fn step_grad(
        &self,
        model: &NativeModel,
        g: &GraphTensor,
        grads: &mut [Mat],
    ) -> Result<TaskStep> {
        let (n, cands) = self.shape_of(g)?;
        let (h, trunk) = model.forward_states_tape(g)?;
        let h_ns = self.states_of(&h)?;
        let src_idx = vec![0i32; cands];
        let cand_idx: Vec<i32> = (1..=cands as i32).collect();
        let (scores, saved) = self.readout_fwd(model, h_ns, &src_idx, &cand_idx)?;
        let (loss, dscores) = self.loss_grad(&scores);
        let metrics = self.rank_metrics(&scores);
        let (da, db) = self.readout_vjp(model, &saved, &dscores, grads)?;
        let mut d_ns = grad::gather_vjp(&src_idx, n, &da);
        d_ns.add_assign(&grad::gather_vjp(&cand_idx, n, &db));
        let mut dh = model.zero_state_grads(g)?;
        dh.get_mut(&self.node_set)
            .ok_or_else(|| {
                Error::Graph(format!("state grads missing node set {:?}", self.node_set))
            })?
            .add_assign(&d_ns);
        model.backward_states(g, &trunk, dh, grads)?;
        Ok(TaskStep { loss, metrics })
    }

    fn step_eval(&self, model: &NativeModel, g: &GraphTensor) -> Result<TaskStep> {
        let (_n, cands) = self.shape_of(g)?;
        let h = model.forward_states(g)?;
        let h_ns = self.states_of(&h)?;
        let src_idx = vec![0i32; cands];
        let cand_idx: Vec<i32> = (1..=cands as i32).collect();
        let (scores, _saved) = self.readout_fwd(model, h_ns, &src_idx, &cand_idx)?;
        let (loss, _dscores) = self.loss_grad(&scores);
        Ok(TaskStep { loss, metrics: self.rank_metrics(&scores) })
    }

    /// Score the requested pair: the subgraph was sampled from seeds
    /// `[source, target]`, so the pair sits at rows 0 and 1.
    fn infer(&self, model: &NativeModel, g: &GraphTensor) -> Result<TaskOutput> {
        let n = g.node_set(&self.node_set)?.total();
        if n < 2 {
            return Err(Error::Graph(format!(
                "link-prediction request subgraph has {n} {:?} nodes — want the \
                 (source, target) pair pinned at rows 0 and 1",
                self.node_set
            )));
        }
        let h = model.forward_states(g)?;
        let h_ns = self.states_of(&h)?;
        let (scores, _saved) = self.readout_fwd(model, h_ns, &[0], &[1])?;
        Ok(TaskOutput::LinkScore { score: scores[0] })
    }
}

/// Deterministic seeded-uniform negatives for the pair `(u, v)`:
/// `min(k, n-2)` distinct node ids excluding both endpoints, keyed by
/// `(seed, u, v)` — the same pair always draws the same negatives.
pub fn pair_negatives(u: u32, v: u32, num_nodes: usize, k: usize, seed: u64) -> Vec<u32> {
    let want = k.min(num_nodes.saturating_sub(2));
    let mut rng = Rng::new(mix64(seed, mix64(u as u64, v as u64)));
    let mut out = Vec::with_capacity(want);
    let mut seen = std::collections::HashSet::with_capacity(want + 2);
    seen.insert(u);
    seen.insert(v);
    while out.len() < want {
        let cand = rng.uniform(num_nodes) as u32;
        if seen.insert(cand) {
            out.push(cand);
        }
    }
    out
}

/// Sample one link-prediction example: the pair subgraph of
/// `[u, v, negatives…]` with the candidate count recorded in the
/// [`CANDS_FEATURE`] context feature.
pub fn pair_example(
    sampler: &InMemorySampler,
    u: u32,
    v: u32,
    num_nodes: usize,
    negatives: usize,
    neg_seed: u64,
) -> Result<GraphTensor> {
    if u == v {
        return Err(Error::Sampler(format!("degenerate link-prediction pair ({u}, {u})")));
    }
    let mut seeds = vec![u, v];
    seeds.extend(pair_negatives(u, v, num_nodes, negatives, neg_seed));
    let mut g = sampler.sample_seeds(&seeds)?;
    g.context
        .features
        .insert(CANDS_FEATURE.into(), Feature::i64_vec(vec![(seeds.len() - 1) as i64]));
    Ok(g)
}

/// A [`DatasetProvider`] over supervision pairs: reshuffles the pair
/// list per epoch (like the seed provider) and yields one pair
/// subgraph per example. With `sampling.threads > 1` the stage fans
/// out in waves of `sampling.chunk_size` pairs over a pool the
/// epoch iterator owns — examples are independent and negatives are
/// RNG-keyed per pair, so the stream is bit-for-bit the serial one.
pub struct PairProvider {
    pub sampler: Arc<InMemorySampler>,
    pub pairs: Vec<(u32, u32)>,
    pub shuffle_seed: u64,
    /// Negatives per positive (co-sampled into the example).
    pub negatives: usize,
    /// Negative-sampling key (the task's `split_seed`).
    pub neg_seed: u64,
    /// Cardinality of the scored node set.
    pub num_nodes: usize,
    /// Sampling-stage execution knobs (threads, wave size) — the same
    /// role `SamplingProvider::sampling` plays for seed streams.
    pub sampling: crate::sampler::SamplerConfig,
}

/// Wave-parallel pair-sampling iterator (the pair analog of the
/// pipeline's `ParallelSampleIter`). Owns its pool; dropping the epoch
/// stream drops the pool and joins the workers.
struct ParallelPairIter {
    sampler: Arc<InMemorySampler>,
    pool: crate::util::threadpool::ThreadPool,
    pairs: std::vec::IntoIter<(u32, u32)>,
    chunk: usize,
    negatives: usize,
    neg_seed: u64,
    num_nodes: usize,
    buf: std::collections::VecDeque<Result<GraphTensor>>,
}

impl Iterator for ParallelPairIter {
    type Item = Result<GraphTensor>;

    fn next(&mut self) -> Option<Result<GraphTensor>> {
        if self.buf.is_empty() {
            let wave: Vec<(u32, u32)> = self.pairs.by_ref().take(self.chunk).collect();
            if wave.is_empty() {
                return None;
            }
            let sampler = Arc::clone(&self.sampler);
            let (negatives, neg_seed, num_nodes) =
                (self.negatives, self.neg_seed, self.num_nodes);
            self.buf = self
                .pool
                .map(wave, move |(u, v)| {
                    pair_example(&sampler, u, v, num_nodes, negatives, neg_seed)
                })
                .into();
        }
        self.buf.pop_front()
    }
}

impl DatasetProvider for PairProvider {
    fn get_dataset(
        &self,
        epoch: u64,
    ) -> Result<Box<dyn Iterator<Item = Result<GraphTensor>> + Send>> {
        let mut pairs = self.pairs.clone();
        let mut rng = Rng::new(self.shuffle_seed ^ epoch.wrapping_mul(0x9E3779B97F4A7C15));
        rng.shuffle(&mut pairs);
        let (negatives, neg_seed, num_nodes) = (self.negatives, self.neg_seed, self.num_nodes);
        if self.sampling.parallel() {
            return Ok(Box::new(ParallelPairIter {
                sampler: Arc::clone(&self.sampler),
                pool: crate::util::threadpool::ThreadPool::new(self.sampling.threads),
                pairs: pairs.into_iter(),
                chunk: self.sampling.chunk_size.max(1),
                negatives,
                neg_seed,
                num_nodes,
                buf: std::collections::VecDeque::new(),
            }));
        }
        let sampler = Arc::clone(&self.sampler);
        Ok(Box::new(pairs.into_iter().map(move |(u, v)| {
            pair_example(&sampler, u, v, num_nodes, negatives, neg_seed)
        })))
    }

    fn len_hint(&self) -> Option<usize> {
        Some(self.pairs.len())
    }
}

/// Batch up a pair list for evaluation (merge + fit-or-skip), mirroring
/// `MagEnv::eval_batches` for seed lists. Trailing partial batches are
/// dropped, like the training pipeline's `drop_remainder`.
#[allow(clippy::too_many_arguments)]
pub fn pair_eval_batches(
    sampler: Arc<InMemorySampler>,
    pairs: Vec<(u32, u32)>,
    batch: usize,
    pad: PadSpec,
    negatives: usize,
    neg_seed: u64,
    num_nodes: usize,
    limit: Option<usize>,
) -> impl Iterator<Item = Result<Option<Padded>>> {
    let n = limit.map(|l| l * batch).unwrap_or(usize::MAX);
    let chunks: Vec<Vec<(u32, u32)>> = pairs
        .into_iter()
        .take(n)
        .collect::<Vec<_>>()
        .chunks(batch)
        .filter(|c| c.len() == batch)
        .map(|c| c.to_vec())
        .collect();
    chunks.into_iter().map(move |chunk| {
        let graphs = chunk
            .iter()
            .map(|&(u, v)| pair_example(&sampler, u, v, num_nodes, negatives, neg_seed))
            .collect::<Result<Vec<_>>>()?;
        let merged = crate::graph::batch::merge(&graphs)?;
        Ok(fit_or_skip(&merged, &pad))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::model_ref::ModelConfig;
    use crate::sampler::spec::mag_sampling_spec_scaled;
    use crate::synth::mag::{edge_holdout, generate, MagConfig};
    use crate::util::rng::Rng as TestRng;

    fn linkpred_cfg(readout: &str, loss: &str) -> ModelConfig {
        let t = TaskConfig {
            kind: "link_prediction".into(),
            readout: readout.into(),
            loss: loss.into(),
            margin: 1.0,
            negatives: 3,
            hits_k: 2,
            mlp_dim: 6,
            ..TaskConfig::default()
        };
        ModelConfig::for_mag(&MagConfig::tiny(), 8, 8, 1).with_task(t)
    }

    fn setup(readout: &str, loss: &str) -> (NativeModel, LinkPrediction, GraphTensor) {
        let ds = generate(&MagConfig::tiny());
        let num_papers = ds.config.num_papers;
        let holdout = edge_holdout(&ds, "cites", 0.2, 9).unwrap();
        let store = Arc::new(holdout.store);
        let spec = mag_sampling_spec_scaled(&store.schema, 0.2).unwrap();
        let sampler = InMemorySampler::new(store, spec, 3).unwrap();
        let (u, v) = holdout.train[0];
        let g = pair_example(&sampler, u, v, num_papers, 3, 9).unwrap();
        let cfg = linkpred_cfg(readout, loss);
        let model = NativeModel::init(cfg.clone(), 11).unwrap();
        let task = LinkPrediction::from_config("paper".into(), &cfg.task).unwrap();
        (model, task, g)
    }

    #[test]
    fn pair_negatives_are_deterministic_and_exclusive() {
        let a = pair_negatives(3, 17, 100, 8, 42);
        let b = pair_negatives(3, 17, 100, 8, 42);
        assert_eq!(a, b, "same (seed, u, v) draws the same negatives");
        assert_eq!(a.len(), 8);
        let set: std::collections::HashSet<_> = a.iter().collect();
        assert_eq!(set.len(), 8, "distinct");
        assert!(!a.contains(&3) && !a.contains(&17), "endpoints excluded");
        let c = pair_negatives(3, 18, 100, 8, 42);
        assert_ne!(a, c, "different pair, different draws");
        // Clamped when the universe is tiny.
        assert_eq!(pair_negatives(0, 1, 2, 5, 7).len(), 0);
        assert_eq!(pair_negatives(0, 1, 3, 5, 7), vec![2]);
    }

    #[test]
    fn pair_example_pins_seeds_first() {
        let (_model, _task, g) = setup("dot", "softmax");
        let (_, ids) =
            g.node_set("paper").unwrap().feature("#id").unwrap().as_i64().unwrap();
        let (_, cands) = g.context.feature(CANDS_FEATURE).unwrap().as_i64().unwrap();
        assert_eq!(cands[0], 4, "positive + 3 negatives");
        assert!(ids.len() >= 5, "source + candidates all present");
        let head: std::collections::HashSet<_> = ids[..5].iter().collect();
        assert_eq!(head.len(), 5, "seed ids distinct and pinned first");
    }

    #[test]
    fn eval_and_grad_losses_agree_bitexact() {
        for (readout, loss) in [("dot", "softmax"), ("dot", "margin"), ("hadamard", "softmax")] {
            let (model, task, g) = setup(readout, loss);
            let eval = task.step_eval(&model, &g).unwrap();
            let mut grads = model.zeros_grads();
            let step = task.step_grad(&model, &g, &mut grads).unwrap();
            assert_eq!(
                (eval.loss as f32).to_bits(),
                (step.loss as f32).to_bits(),
                "{readout}/{loss}: fused eval loss == taped train loss"
            );
            assert_eq!(eval.metrics, step.metrics);
            assert!(step.loss.is_finite());
            assert!(
                grads.iter().any(|m| m.data.iter().any(|&v| v != 0.0)),
                "{readout}/{loss}: gradients flowed"
            );
        }
    }

    /// End-to-end gradcheck through trunk + readout: finite differences
    /// on a scattering of parameters across every role must match
    /// step_grad, for both readouts and both losses.
    #[test]
    fn gradcheck_link_prediction_end_to_end() {
        for (readout, loss) in [("dot", "softmax"), ("hadamard", "margin")] {
            let (model, task, g) = setup(readout, loss);
            let loss_of = |m: &NativeModel| -> f64 { task.step_eval(m, &g).unwrap().loss };
            let mut grads = model.zeros_grads();
            task.step_grad(&model, &g, &mut grads).unwrap();
            let mut rng = TestRng::new(77);
            let h = 1e-2f32;
            let mut checked = 0usize;
            for (pi, name) in model.names.iter().enumerate() {
                let n_elems = model.params[pi].data.len();
                if n_elems == 0 {
                    continue;
                }
                for _ in 0..2.min(n_elems) {
                    let ei = rng.uniform(n_elems);
                    let mut mp = model.clone();
                    mp.params[pi].data[ei] += h;
                    let mut mm = model.clone();
                    mm.params[pi].data[ei] -= h;
                    let fd = (loss_of(&mp) - loss_of(&mm)) / (2.0 * h as f64);
                    let an = grads[pi].data[ei] as f64;
                    let denom = an.abs().max(fd.abs()).max(1.0);
                    // Same whole-model tolerance rationale as
                    // gradcheck_full_model_backward: parameter
                    // perturbations can cross relu/hinge kinks the
                    // op-level tests exclude by construction.
                    assert!(
                        (an - fd).abs() / denom <= 1e-2,
                        "{readout}/{loss} {name}[{ei}]: analytic {an} vs fd {fd}"
                    );
                    checked += 1;
                }
            }
            assert!(checked >= 10, "{readout}/{loss}: probed {checked} elements");
        }
    }

    #[test]
    fn rank_metrics_count_strict_superiority() {
        let task = LinkPrediction {
            node_set: "paper".into(),
            readout: Readout::Dot,
            loss: LinkLoss::Softmax,
            hits_k: 2,
        };
        // Positive wins outright.
        let m = task.rank_metrics(&[2.0, 1.0, 0.0]);
        assert_eq!(m.correct, 1.0);
        assert_eq!(m.rr_sum, 1.0);
        assert_eq!(m.hits_sum, 1.0);
        // One strictly better negative, one tie: rank 2 (ties don't
        // outrank).
        let m = task.rank_metrics(&[1.0, 3.0, 1.0]);
        assert_eq!(m.correct, 0.0);
        assert_eq!(m.rr_sum, 0.5);
        assert_eq!(m.hits_sum, 1.0, "rank 2 ≤ k 2");
        // Dead last among 4 candidates.
        let m = task.rank_metrics(&[0.0, 1.0, 2.0, 3.0]);
        assert_eq!(m.rr_sum, 0.25);
        assert_eq!(m.hits_sum, 0.0);
        assert_eq!(m.scored, 1.0);
    }

    /// The wave-parallel pair-sampling stage must feed the pipeline the
    /// exact same example stream (order and bits) as serial — the same
    /// contract the seed provider's parallel stage honors.
    #[test]
    fn parallel_pair_stream_matches_serial() {
        let ds = generate(&MagConfig::tiny());
        let num_papers = ds.config.num_papers;
        let holdout = edge_holdout(&ds, "cites", 0.25, 9).unwrap();
        let store = Arc::new(holdout.store);
        let spec = mag_sampling_spec_scaled(&store.schema, 0.2).unwrap();
        let sampler = Arc::new(InMemorySampler::new(store, spec, 3).unwrap());
        let provider = |threads: usize| PairProvider {
            sampler: Arc::clone(&sampler),
            pairs: holdout.train.clone(),
            shuffle_seed: 5,
            negatives: 2,
            neg_seed: 9,
            num_nodes: num_papers,
            sampling: crate::sampler::SamplerConfig {
                threads,
                chunk_size: 7,
                ..crate::sampler::SamplerConfig::default()
            },
        };
        let serial: Vec<GraphTensor> = provider(1)
            .get_dataset(0)
            .unwrap()
            .collect::<Result<Vec<_>>>()
            .unwrap();
        assert_eq!(serial.len(), holdout.train.len());
        for threads in [2usize, 4] {
            let par: Vec<GraphTensor> = provider(threads)
                .get_dataset(0)
                .unwrap()
                .collect::<Result<Vec<_>>>()
                .unwrap();
            assert_eq!(par, serial, "threads={threads}: order and bits preserved");
        }
        // Epochs reshuffle the pair order.
        let e1: Vec<GraphTensor> = provider(2)
            .get_dataset(1)
            .unwrap()
            .collect::<Result<Vec<_>>>()
            .unwrap();
        assert_ne!(e1, serial, "different epochs reshuffled");
    }

    #[test]
    fn infer_scores_a_bare_pair() {
        let (model, task, _g) = setup("dot", "softmax");
        let ds = generate(&MagConfig::tiny());
        let holdout = edge_holdout(&ds, "cites", 0.2, 9).unwrap();
        let store = Arc::new(holdout.store);
        let spec = mag_sampling_spec_scaled(&store.schema, 0.2).unwrap();
        let sampler = InMemorySampler::new(store, spec, 3).unwrap();
        let (u, v) = holdout.val[0];
        let g = sampler.sample_seeds(&[u, v]).unwrap();
        let TaskOutput::LinkScore { score } = task.infer(&model, &g).unwrap() else {
            panic!("wrong output shape");
        };
        assert!(score.is_finite());
    }
}
