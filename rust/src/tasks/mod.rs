//! Task subsystem: interchangeable readout heads over the shared GNN
//! trunk (the paper's orchestration-layer *tasks*, §5 / A.5).
//!
//! The TF-GNN Runner composes a model from a trunk (the GraphUpdate
//! stack) and a **task** — a readout head with its own loss and
//! metrics: node classification, link prediction, graph-level
//! prediction. This module is that family for the native engine:
//!
//! * [`Task`] — the trait: per-component forward + loss + tape-seeding
//!   backward ([`Task::step_grad`]), the forward-only twin
//!   ([`Task::step_eval`]), and the serve-time response
//!   ([`Task::infer`]);
//! * [`RootClassification`] — the original objective, extracted from
//!   the trainer verbatim: masked softmax cross-entropy over the root
//!   node's logits (bit-for-bit the pre-subsystem path — pinned by
//!   `tests/native_training.rs`);
//! * [`LinkPrediction`] — scores (source, target) node pairs of a
//!   held-out edge split via a dot or Hadamard-MLP readout over the
//!   pair subgraph's final states, with deterministic seeded-uniform
//!   negatives co-sampled into the subgraph, softmax or margin loss,
//!   and MRR / hits@k metrics;
//! * [`GraphRegression`] — context-level mean-pool readout with MSE
//!   loss over per-component scalar targets.
//!
//! **Engine invariants.** A task's step is a pure function of one
//! component's GraphTensor and the model parameters: no cross-component
//! state, no RNG at step time (link-prediction negatives are fixed at
//! sampling time, keyed by the pair). That is what keeps every task
//! inside the trainer's determinism contract — 1-thread == serial
//! oracle bit-for-bit, in-order loss summation bit-stable across
//! thread counts, ≤1e-5 rel multi-thread parameter drift.
//!
//! Task selection flows from the config `task` block
//! ([`crate::ops::model_ref::TaskConfig`], validated in the same
//! funnel as the `model` block): [`head_params`] tells
//! [`NativeModel::init`](crate::train::native::NativeModel::init)
//! which readout parameters to create (the default task reproduces the
//! historical `head.w`/`head.b` draws on the same RNG stream), and
//! [`build`] turns the config into the executable [`Task`].

pub mod graph_regression;
pub mod link_prediction;
pub mod root_classification;

pub use graph_regression::GraphRegression;
pub use link_prediction::{LinkPrediction, PairProvider};
pub use root_classification::RootClassification;

use std::sync::Arc;

pub use crate::train::metrics::TaskMetrics;

use crate::train::metrics::EpochMetrics;

use crate::analysis::diag::{codes, Diagnostic};
use crate::graph::GraphTensor;
use crate::ops::model_ref::{Mat, ModelConfig};
use crate::train::native::NativeModel;
use crate::Result;

/// One scored example's contribution to a training/eval step.
#[derive(Debug, Clone)]
pub struct TaskStep {
    /// Unnormalized per-example loss (summed in component order by the
    /// trainer, as f64 — the thread-count-stable loss contract).
    pub loss: f64,
    /// Per-example metric sums (see [`TaskMetrics`]).
    pub metrics: TaskMetrics,
}

/// A task-shaped serving response.
#[derive(Debug, Clone)]
pub enum TaskOutput {
    /// Root classification: the root's logits row and argmax class.
    Classification { logits: Vec<f32>, predicted: usize },
    /// Link prediction: the score of the requested (source, target)
    /// pair (higher = more likely an edge).
    LinkScore { score: f32 },
    /// Graph regression: the predicted target in the *unnormalized*
    /// scale of the configured target feature.
    Regression { value: f32 },
}

/// One readout-head parameter tensor, created by
/// [`NativeModel::init`](crate::train::native::NativeModel::init)
/// after the trunk's parameters (creation order defines the RNG
/// stream, so the list order is part of the checkpoint contract).
#[derive(Debug, Clone, Copy)]
pub struct HeadParam {
    pub name: &'static str,
    pub rows: usize,
    pub cols: usize,
    /// Biases initialize to zero (no RNG draw); weights Glorot-uniform.
    pub zero_init: bool,
}

/// One interchangeable training objective: readout from final hidden
/// states → loss + output-grad for the tape → per-batch metrics →
/// serve-time response.
///
/// Contract (asserted by `tests/tasks.rs` and `benches/tasks.rs`):
/// * `step_grad` and `step_eval` compute the **same loss bits** for the
///   same component and parameters (the trunk's fused/taped paths are
///   bit-equal; the readout runs the identical float sequence);
/// * `step_grad`'s parameter gradients are the exact VJP of the loss,
///   composed from the finite-difference-checked rules of
///   [`crate::train::native::grad`];
/// * a step never draws randomness and never looks outside its
///   component — the replica sharding of
///   [`crate::train::native::NativeTrainer`] stays deterministic.
pub trait Task: Send + Sync {
    fn name(&self) -> &'static str;

    /// Forward + loss + backward over one component, accumulating
    /// parameter gradients into `grads` (parallel to `model.params`).
    fn step_grad(
        &self,
        model: &NativeModel,
        g: &GraphTensor,
        grads: &mut [Mat],
    ) -> Result<TaskStep>;

    /// Forward-only loss + metrics over one component (fused trunk
    /// path).
    fn step_eval(&self, model: &NativeModel, g: &GraphTensor) -> Result<TaskStep>;

    /// Serve-time response for one request subgraph (sampled from the
    /// request's seed list — `[root]` for root tasks, `[source,
    /// target]` for link prediction).
    fn infer(&self, model: &NativeModel, g: &GraphTensor) -> Result<TaskOutput>;
}

/// The readout-head parameters a config's task owns, in creation
/// order. Root classification reproduces the historical
/// `head.w`/`head.b` pair (same shapes, same Glorot/zero split), so
/// existing mpnn checkpoints and the init RNG stream are preserved
/// bit-for-bit.
pub fn head_params(cfg: &ModelConfig) -> Result<Vec<HeadParam>> {
    let t = &cfg.task;
    Ok(match t.kind.as_str() {
        "root_classification" => vec![
            HeadParam { name: "head.w", rows: cfg.hidden, cols: cfg.num_classes, zero_init: false },
            HeadParam { name: "head.b", rows: 1, cols: cfg.num_classes, zero_init: true },
        ],
        "link_prediction" => match t.readout.as_str() {
            "dot" => Vec::new(),
            "hadamard" => {
                let m = if t.mlp_dim == 0 { cfg.message } else { t.mlp_dim };
                vec![
                    HeadParam { name: "lp.w", rows: cfg.hidden, cols: m, zero_init: false },
                    HeadParam { name: "lp.b", rows: 1, cols: m, zero_init: true },
                    HeadParam { name: "lp.v", rows: m, cols: 1, zero_init: false },
                    HeadParam { name: "lp.c", rows: 1, cols: 1, zero_init: true },
                ]
            }
            other => {
                return Err(Diagnostic::error(
                    codes::UNKNOWN_ENUM,
                    "$.task.readout",
                    format!("task.readout {other:?} unknown (want dot|hadamard)"),
                )
                .into_error());
            }
        },
        "graph_regression" => vec![
            HeadParam { name: "reg.w", rows: cfg.hidden, cols: 1, zero_init: false },
            HeadParam { name: "reg.b", rows: 1, cols: 1, zero_init: true },
        ],
        other => {
            return Err(Diagnostic::error(
                codes::UNKNOWN_ENUM,
                "$.task.type",
                format!(
                    "task.type {other:?} unknown (want \
                     root_classification|link_prediction|graph_regression)"
                ),
            )
            .into_error());
        }
    })
}

/// The *named* summary means a task reports for one split — what the
/// event journal's `eval` records and `tfgnn runs` carry (the mirror
/// of the [`EpochMetrics`] Display tails). Unknown kinds fall back to
/// accuracy, the metric every task accumulates.
pub fn summary_metrics(kind: &str, m: &EpochMetrics) -> Vec<(&'static str, f64)> {
    match kind {
        "link_prediction" => {
            vec![("accuracy", m.accuracy()), ("mrr", m.mrr()), ("hits_at_k", m.hits_at_k())]
        }
        "graph_regression" => vec![("mse", m.mse()), ("mae", m.mae())],
        _ => vec![("accuracy", m.accuracy())],
    }
}

/// Build the executable task from a validated config.
pub fn build(cfg: &ModelConfig) -> Result<Arc<dyn Task>> {
    let t = &cfg.task;
    match t.kind.as_str() {
        "root_classification" => {
            if !cfg.node_order.iter().any(|s| s == &t.root_set) {
                return Err(Diagnostic::error(
                    codes::UNKNOWN_NODE_SET,
                    "$.task.root_set",
                    format!("task.root_set {:?} is not a node set of the schema", t.root_set),
                )
                .into_error());
            }
            Ok(Arc::new(RootClassification {
                root_set: t.root_set.clone(),
                label_feature: t.label_feature.clone(),
            }))
        }
        "link_prediction" => {
            let (src, tgt) = cfg.edge_endpoints.get(&t.edge_set).ok_or_else(|| {
                Diagnostic::error(
                    codes::UNKNOWN_EDGE_SET,
                    "$.task.edge_set",
                    format!("task.edge_set {:?} is not an edge set of the schema", t.edge_set),
                )
                .into_error()
            })?;
            if src != tgt {
                return Err(Diagnostic::error(
                    codes::BAD_TASK_KNOB,
                    "$.task.edge_set",
                    format!(
                        "task.edge_set {:?} connects {src:?}→{tgt:?} — link prediction \
                         currently scores pairs within one node set (homogeneous edge sets)",
                        t.edge_set
                    ),
                )
                .into_error());
            }
            Ok(Arc::new(LinkPrediction::from_config(src.clone(), t)?))
        }
        "graph_regression" => {
            if !cfg.node_order.iter().any(|s| s == &t.root_set) {
                return Err(Diagnostic::error(
                    codes::UNKNOWN_NODE_SET,
                    "$.task.root_set",
                    format!("task.root_set {:?} is not a node set of the schema", t.root_set),
                )
                .into_error());
            }
            Ok(Arc::new(GraphRegression {
                node_set: t.root_set.clone(),
                target_feature: t.target_feature.clone(),
                shift: t.target_shift,
                scale: t.target_scale,
            }))
        }
        other => Err(Diagnostic::error(
            codes::UNKNOWN_ENUM,
            "$.task.type",
            format!(
                "task.type {other:?} unknown (want \
                 root_classification|link_prediction|graph_regression)"
            ),
        )
        .into_error()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::model_ref::TaskConfig;
    use crate::synth::mag::MagConfig;

    fn mag_cfg() -> ModelConfig {
        ModelConfig::for_mag(&MagConfig::tiny(), 8, 8, 1)
    }

    #[test]
    fn default_task_head_matches_historical_layout() {
        let cfg = mag_cfg();
        let head = head_params(&cfg).unwrap();
        assert_eq!(head.len(), 2);
        assert_eq!(head[0].name, "head.w");
        assert_eq!((head[0].rows, head[0].cols), (8, cfg.num_classes));
        assert!(!head[0].zero_init);
        assert_eq!(head[1].name, "head.b");
        assert!(head[1].zero_init);
        assert_eq!(build(&cfg).unwrap().name(), "root_classification");
    }

    #[test]
    fn link_prediction_heads_depend_on_readout() {
        let t = TaskConfig {
            kind: "link_prediction".into(),
            readout: "dot".into(),
            ..TaskConfig::default()
        };
        let cfg = mag_cfg().with_task(t.clone());
        assert!(head_params(&cfg).unwrap().is_empty(), "dot readout is parameter-free");
        assert_eq!(build(&cfg).unwrap().name(), "link_prediction");

        let t = TaskConfig { readout: "hadamard".into(), mlp_dim: 6, ..t };
        let cfg = mag_cfg().with_task(t);
        let head = head_params(&cfg).unwrap();
        assert_eq!(
            head.iter().map(|h| h.name).collect::<Vec<_>>(),
            vec!["lp.w", "lp.b", "lp.v", "lp.c"]
        );
        assert_eq!((head[0].rows, head[0].cols), (8, 6));
        assert_eq!((head[2].rows, head[2].cols), (6, 1));
    }

    #[test]
    fn build_rejects_bad_bindings() {
        // Unknown edge set.
        let t = TaskConfig {
            kind: "link_prediction".into(),
            edge_set: "ghost".into(),
            ..TaskConfig::default()
        };
        let err = build(&mag_cfg().with_task(t)).expect_err("unknown edge set");
        assert!(err.to_string().contains("ghost"), "{err}");
        // Heterogeneous edge set (paper → author).
        let t = TaskConfig {
            kind: "link_prediction".into(),
            edge_set: "written".into(),
            ..TaskConfig::default()
        };
        let err = build(&mag_cfg().with_task(t)).expect_err("heterogeneous edge set");
        assert!(err.to_string().contains("homogeneous"), "{err}");
        // Unknown root set.
        let t = TaskConfig { root_set: "venue".into(), ..TaskConfig::default() };
        let err = build(&mag_cfg().with_task(t)).expect_err("unknown root set");
        assert!(err.to_string().contains("venue"), "{err}");
        // Unknown kind (defense in depth behind the parser).
        let t = TaskConfig { kind: "frobnicate".into(), ..TaskConfig::default() };
        assert!(build(&mag_cfg().with_task(t.clone())).is_err());
        assert!(head_params(&mag_cfg().with_task(t)).is_err());
    }

    #[test]
    fn summary_metrics_are_named_per_task() {
        use crate::train::StepMetrics;
        let mut m = EpochMetrics::default();
        m.add(StepMetrics {
            loss: 1.0,
            correct: 1.0,
            weight: 2.0,
            task: TaskMetrics {
                correct: 1.0,
                rr_sum: 1.0,
                hits_sum: 2.0,
                se_sum: 0.5,
                ae_sum: 1.0,
                scored: 2.0,
            },
        });
        let names = |kind: &str| {
            summary_metrics(kind, &m).iter().map(|&(k, _)| k).collect::<Vec<_>>()
        };
        assert_eq!(names("root_classification"), vec!["accuracy"]);
        assert_eq!(names("link_prediction"), vec!["accuracy", "mrr", "hits_at_k"]);
        assert_eq!(names("graph_regression"), vec!["mse", "mae"]);
        assert_eq!(names("unknown"), vec!["accuracy"], "fallback");
        let lp = summary_metrics("link_prediction", &m);
        assert!((lp[1].1 - 0.5).abs() < 1e-9, "mrr is rr_sum/scored");
    }

    #[test]
    fn regression_head_is_a_scalar_readout() {
        let t = TaskConfig { kind: "graph_regression".into(), ..TaskConfig::default() };
        let cfg = mag_cfg().with_task(t);
        let head = head_params(&cfg).unwrap();
        assert_eq!(head.iter().map(|h| h.name).collect::<Vec<_>>(), vec!["reg.w", "reg.b"]);
        assert_eq!((head[0].rows, head[0].cols), (8, 1));
        assert_eq!(build(&cfg).unwrap().name(), "graph_regression");
    }
}
