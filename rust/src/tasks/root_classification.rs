//! Root-node multiclass classification — the original objective,
//! extracted from `train/native/trainer.rs` behind the [`Task`] trait.
//!
//! Per component: forward to the root's logits (`head.w`/`head.b`
//! linear readout over the root state, node 0 of the root set — the
//! sampler's "seed first" convention), masked softmax cross-entropy
//! against the root's label feature, backward through the head and
//! trunk. The float-op sequence is exactly the pre-subsystem
//! trainer's, so mpnn logits and per-step losses remain **bit-for-bit**
//! equal to the padded reference / serial oracle (pinned by
//! `tests/native_training.rs`, which predates this module and passes
//! unmodified).

use crate::graph::GraphTensor;
use crate::ops::model_ref::Mat;
use crate::train::metrics::TaskMetrics;
use crate::train::native::grad::softmax_xent_masked;
use crate::train::native::NativeModel;
use crate::{Error, Result};

use super::{Task, TaskOutput, TaskStep};

/// The root-classification task binding: which node set carries the
/// roots and which feature their labels.
#[derive(Debug, Clone)]
pub struct RootClassification {
    pub root_set: String,
    pub label_feature: String,
}

impl Default for RootClassification {
    fn default() -> RootClassification {
        RootClassification { root_set: "paper".into(), label_feature: "labels".into() }
    }
}

impl RootClassification {
    /// Read and range-check the component's root label. A label outside
    /// the model's class range is a structured error (the loss op
    /// asserts on its contract; a bad label here usually means
    /// `train.num_classes` and `dataset.num_classes` disagree in the
    /// run config, which must not abort a replica thread mid-training).
    fn read_label(&self, model: &NativeModel, g: &GraphTensor) -> Result<i32> {
        let ns = g.node_set(&self.root_set)?;
        if ns.total() == 0 {
            return Err(Error::Graph(format!(
                "component has no {:?} root node",
                self.root_set
            )));
        }
        let (_, data) = ns.feature(&self.label_feature)?.as_i64()?;
        let label = data[0];
        let c = model.cfg.num_classes;
        if label < 0 || label as usize >= c {
            return Err(Error::Graph(format!(
                "root label {label} outside model's {c} classes — do \
                 train.num_classes and dataset.num_classes agree in the config?"
            )));
        }
        Ok(label as i32)
    }

    fn metrics_of(x: &crate::train::native::grad::XentGrad) -> TaskMetrics {
        TaskMetrics {
            correct: x.correct as f64,
            scored: x.weight as f64,
            ..TaskMetrics::default()
        }
    }
}

impl Task for RootClassification {
    fn name(&self) -> &'static str {
        "root_classification"
    }

    fn step_grad(
        &self,
        model: &NativeModel,
        g: &GraphTensor,
        grads: &mut [Mat],
    ) -> Result<TaskStep> {
        let label = self.read_label(model, g)?;
        let (logits, tape) = model.forward_tape(g, &self.root_set, &[0])?;
        let x = softmax_xent_masked(&logits, &[label], &[1.0]);
        model.backward(g, &tape, &x.dlogits, &self.root_set, grads)?;
        Ok(TaskStep { loss: x.total_ce as f64, metrics: Self::metrics_of(&x) })
    }

    fn step_eval(&self, model: &NativeModel, g: &GraphTensor) -> Result<TaskStep> {
        let label = self.read_label(model, g)?;
        let logits = model.forward_logits(g, &self.root_set, &[0])?;
        let x = softmax_xent_masked(&logits, &[label], &[1.0]);
        Ok(TaskStep { loss: x.total_ce as f64, metrics: Self::metrics_of(&x) })
    }

    fn infer(&self, model: &NativeModel, g: &GraphTensor) -> Result<TaskOutput> {
        let logits = model.forward_logits(g, &self.root_set, &[0])?;
        let predicted = logits
            .row(0)
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
            .map(|(i, _)| i)
            .unwrap_or(0);
        Ok(TaskOutput::Classification { logits: logits.data, predicted })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::model_ref::ModelConfig;
    use crate::sampler::inmem::InMemorySampler;
    use crate::sampler::spec::mag_sampling_spec_scaled;
    use crate::synth::mag::{generate, MagConfig};
    use std::sync::Arc;

    fn setup() -> (NativeModel, GraphTensor) {
        let ds = generate(&MagConfig::tiny());
        let store = Arc::new(ds.store);
        let spec = mag_sampling_spec_scaled(&store.schema, 0.2).unwrap();
        let sampler = InMemorySampler::new(store, spec, 3).unwrap();
        let g = sampler.sample(0).unwrap();
        let cfg = ModelConfig::for_mag(&MagConfig::tiny(), 8, 8, 2);
        (NativeModel::init(cfg, 7).unwrap(), g)
    }

    /// The extracted task computes exactly the pre-subsystem sequence:
    /// step_eval's loss equals the inline forward+xent bits, and
    /// step_grad reports the same loss as step_eval (fused == taped
    /// trunk contract).
    #[test]
    fn step_matches_inline_xent_bitexact() {
        let (model, g) = setup();
        let task = RootClassification::default();
        let label = task.read_label(&model, &g).unwrap();
        let logits = model.forward_logits(&g, "paper", &[0]).unwrap();
        let want = softmax_xent_masked(&logits, &[label], &[1.0]);
        let eval = task.step_eval(&model, &g).unwrap();
        assert_eq!((eval.loss as f32).to_bits(), want.total_ce.to_bits());
        assert_eq!(eval.metrics.correct, want.correct);
        assert_eq!(eval.metrics.scored, 1.0);
        let mut grads = model.zeros_grads();
        let step = task.step_grad(&model, &g, &mut grads).unwrap();
        assert_eq!((step.loss as f32).to_bits(), want.total_ce.to_bits());
        assert!(grads.iter().any(|m| m.data.iter().any(|&v| v != 0.0)), "grads flowed");
    }

    #[test]
    fn infer_returns_argmax_class() {
        let (model, g) = setup();
        let task = RootClassification::default();
        let out = task.infer(&model, &g).unwrap();
        let TaskOutput::Classification { logits, predicted } = out else {
            panic!("wrong output shape");
        };
        assert_eq!(logits.len(), model.cfg.num_classes);
        let want = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        assert_eq!(predicted, want);
    }

    #[test]
    fn missing_root_and_bad_label_are_structured_errors() {
        let (model, g) = setup();
        let task = RootClassification { root_set: "institution".into(), ..Default::default() };
        // Institutions may be absent from this subgraph; force the
        // empty case by using a set the sampler never fills: build a
        // task against a node set with zero nodes in g, if any.
        if g.num_nodes("institution").unwrap() == 0 {
            let err = task.step_eval(&model, &g).expect_err("no root node");
            assert!(err.to_string().contains("root node"), "{err}");
        }
        // Out-of-range label: shrink the model's class count.
        let mut cfg = ModelConfig::for_mag(&MagConfig::tiny(), 8, 8, 1);
        cfg.num_classes = 1; // tiny MAG labels run 0..4
        let small = NativeModel::init(cfg, 7).unwrap();
        let task = RootClassification::default();
        // Find a graph whose root label is ≥ 1.
        let ds = generate(&MagConfig::tiny());
        let bad = ds.labels.iter().position(|&l| l >= 1).unwrap() as u32;
        let store = Arc::new(ds.store);
        let spec = mag_sampling_spec_scaled(&store.schema, 0.2).unwrap();
        let sampler = InMemorySampler::new(store, spec, 3).unwrap();
        let gbad = sampler.sample(bad).unwrap();
        let err = task.step_eval(&small, &gbad).expect_err("bad label");
        assert!(err.to_string().contains("num_classes"), "{err}");
    }
}
