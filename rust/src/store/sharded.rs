//! Sharded, RPC-shaped view of a [`GraphStore`] with failure injection.
//!
//! The distributed sampler's workers (§6.1.1, Algorithm 1) never touch
//! the `GraphStore` directly; they issue [`ShardedStore::neighbors`]
//! and [`ShardedStore::lookup_features`] requests, which are routed to
//! the shard owning each node (hash partitioning, like the paper's
//! storage substrate). Each shard tracks request counters, and an
//! injectable failure rate makes a fraction of requests fail
//! transiently — exercising the retry path that backs the paper's
//! resilience claim versus Graph-Learn (§7: "TF-GNN samples a large
//! graph into subgraphs using a resilient distributed system").
//!
//! The façade is fully thread-safe (counters and the failure stream
//! are atomics), which is what lets the shard-fanout engine
//! ([`crate::sampler::distributed::sample_batch_parallel`]) group a
//! whole frontier by [`ShardedStore::shard_of`] and issue every
//! shard's lookups concurrently. Failure injection decides only
//! *whether* a request fails, never what it returns, so the failure
//! draw order being scheduling-dependent under concurrency cannot
//! leak into sampled results — retries always converge to the
//! failure-free answer.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use super::GraphStore;
use crate::util::rng::mix64;
use crate::{Error, Result};

/// Per-shard service statistics.
#[derive(Debug, Default)]
pub struct ShardStats {
    pub adjacency_requests: AtomicU64,
    pub feature_requests: AtomicU64,
    pub injected_failures: AtomicU64,
}

/// Hash-partitioned store façade.
pub struct ShardedStore {
    store: Arc<GraphStore>,
    pub num_shards: usize,
    pub stats: Vec<ShardStats>,
    /// Probability that any single request fails transiently.
    failure_rate: f64,
    /// Deterministic failure stream (seeded); uses a counter so the
    /// failure pattern is reproducible but uncorrelated with keys.
    failure_seed: u64,
    failure_counter: AtomicU64,
}

impl ShardedStore {
    pub fn new(store: Arc<GraphStore>, num_shards: usize) -> ShardedStore {
        assert!(num_shards > 0);
        ShardedStore {
            store,
            num_shards,
            stats: (0..num_shards).map(|_| ShardStats::default()).collect(),
            failure_rate: 0.0,
            failure_seed: 0,
            failure_counter: AtomicU64::new(0),
        }
    }

    /// Enable transient failure injection.
    pub fn with_failures(mut self, rate: f64, seed: u64) -> ShardedStore {
        self.failure_rate = rate;
        self.failure_seed = seed;
        self
    }

    pub fn store(&self) -> &GraphStore {
        &self.store
    }

    /// Which shard owns `node` of `set`?
    pub fn shard_of(&self, set: &str, node: u32) -> usize {
        let mut h: u64 = 0xcbf29ce484222325;
        for b in set.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        (mix64(h, node as u64) % self.num_shards as u64) as usize
    }

    fn maybe_fail(&self, shard: usize) -> Result<()> {
        if self.failure_rate > 0.0 {
            let n = self.failure_counter.fetch_add(1, Ordering::Relaxed);
            let r = mix64(self.failure_seed, n) as f64 / u64::MAX as f64;
            if r < self.failure_rate {
                self.stats[shard].injected_failures.fetch_add(1, Ordering::Relaxed);
                return Err(Error::Sampler(format!(
                    "transient shard failure (shard {shard}, injected)"
                )));
            }
        }
        Ok(())
    }

    /// Out-neighbors of `node` along `edge_set` — one "RPC".
    pub fn neighbors(&self, edge_set: &str, node: u32) -> Result<&[u32]> {
        let ec = self.store.edge_column(edge_set)?;
        let shard = self.shard_of(&ec.source_set, node);
        self.stats[shard].adjacency_requests.fetch_add(1, Ordering::Relaxed);
        self.maybe_fail(shard)?;
        Ok(ec.neighbors(node))
    }

    /// Feature rows for a batch of nodes of one set — one "RPC" per
    /// owning shard (the batch is grouped by shard, as a real
    /// distributed lookup would be).
    pub fn lookup_features(
        &self,
        node_set: &str,
        nodes: &[u32],
    ) -> Result<std::collections::BTreeMap<String, crate::graph::Feature>> {
        let nc = self.store.node_column(node_set)?;
        // Group by shard to count per-shard load faithfully.
        let mut shards_hit = vec![false; self.num_shards];
        for &n in nodes {
            shards_hit[self.shard_of(node_set, n)] = true;
        }
        let mut first_hit = 0;
        for (shard, hit) in shards_hit.iter().enumerate() {
            if *hit {
                self.stats[shard].feature_requests.fetch_add(1, Ordering::Relaxed);
                first_hit = shard;
            }
        }
        // One failure draw per gather: the scatter-gather is one logical
        // RPC from the caller's perspective, so its retry loop converges
        // for any per-call failure rate p (p^attempts), instead of
        // compounding across shards (1-(1-p)^shards per attempt would
        // make batched lookups unrecoverable at modest p).
        self.maybe_fail(first_hit)?;
        Ok(nc.gather(nodes))
    }

    /// Aggregate counters (for benches / EXPERIMENTS.md).
    pub fn total_requests(&self) -> (u64, u64, u64) {
        let adj = self.stats.iter().map(|s| s.adjacency_requests.load(Ordering::Relaxed)).sum();
        let feat = self.stats.iter().map(|s| s.feature_requests.load(Ordering::Relaxed)).sum();
        let fail = self.stats.iter().map(|s| s.injected_failures.load(Ordering::Relaxed)).sum();
        (adj, feat, fail)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::tiny_store;

    #[test]
    fn routes_and_counts() {
        let s = ShardedStore::new(Arc::new(tiny_store()), 4);
        let n = s.neighbors("ab", 0).unwrap();
        assert_eq!(n.len(), 2);
        let feats = s.lookup_features("a", &[0, 1, 2]).unwrap();
        assert!(feats.contains_key("x"));
        let (adj, feat, fail) = s.total_requests();
        assert_eq!(adj, 1);
        assert!(feat >= 1);
        assert_eq!(fail, 0);
    }

    #[test]
    fn shard_assignment_balanced_and_stable() {
        let s = ShardedStore::new(Arc::new(tiny_store()), 8);
        let mut counts = vec![0usize; 8];
        for n in 0..8000u32 {
            let sh = s.shard_of("paper", n);
            assert_eq!(sh, s.shard_of("paper", n), "stable");
            counts[sh] += 1;
        }
        for &c in &counts {
            assert!((c as i64 - 1000).abs() < 200, "balanced: {counts:?}");
        }
        // Different sets hash differently.
        assert_ne!(
            (0..100).map(|n| s.shard_of("a", n)).collect::<Vec<_>>(),
            (0..100).map(|n| s.shard_of("b", n)).collect::<Vec<_>>()
        );
    }

    #[test]
    fn failure_injection_deterministic() {
        let run = |seed: u64| {
            let s = ShardedStore::new(Arc::new(tiny_store()), 2).with_failures(0.5, seed);
            (0..64).map(|_| s.neighbors("ab", 0).is_err()).collect::<Vec<_>>()
        };
        let a = run(7);
        let b = run(7);
        let c = run(8);
        assert_eq!(a, b, "same seed, same failures");
        assert_ne!(a, c, "different seed, different failures");
        assert!(a.iter().any(|&x| x), "some failures at 50%");
        assert!(a.iter().any(|&x| !x), "some successes at 50%");
    }

    #[test]
    fn zero_failure_rate_never_fails() {
        let s = ShardedStore::new(Arc::new(tiny_store()), 2);
        for _ in 0..100 {
            s.neighbors("ab", 2).unwrap();
        }
        let (_, _, fail) = s.total_requests();
        assert_eq!(fail, 0);
    }
}
