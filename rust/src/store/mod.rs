//! In-memory heterogeneous graph store — the substrate under sampling.
//!
//! The paper's distributed sampler (§6.1.1) runs over graph data held in
//! a distributed key-value/columnar substrate (at Google: Bigtable-like
//! storage queried by a FlumeJava pipeline). This module provides the
//! equivalent: [`GraphStore`] holds the full heterogeneous graph in
//! columnar form with CSR adjacency per edge set; [`sharded`] wraps it
//! in an RPC-shaped, failure-injectable sharded service that the
//! distributed sampler's workers query.

pub mod sharded;

use std::collections::BTreeMap;

use crate::graph::{Adjacency, Context, EdgeSet, Feature, GraphTensor, NodeSet};
use crate::schema::{DType, GraphSchema};
use crate::{Error, Result};

/// Columnar node features for one node set.
#[derive(Debug, Clone, Default)]
pub struct NodeColumn {
    pub count: usize,
    /// Dense f32 features: name → (per-item dim, flat data).
    pub f32s: BTreeMap<String, (usize, Vec<f32>)>,
    /// Dense i64 features: name → (per-item dim, flat data).
    pub i64s: BTreeMap<String, (usize, Vec<i64>)>,
}

impl NodeColumn {
    pub fn new(count: usize) -> NodeColumn {
        NodeColumn { count, ..Default::default() }
    }

    pub fn add_f32(&mut self, name: &str, dim: usize, data: Vec<f32>) -> Result<()> {
        if data.len() != self.count * dim.max(1) {
            return Err(Error::Feature(format!(
                "column {name:?}: {} values for {} nodes × dim {dim}",
                data.len(),
                self.count
            )));
        }
        self.f32s.insert(name.to_string(), (dim, data));
        Ok(())
    }

    pub fn add_i64(&mut self, name: &str, dim: usize, data: Vec<i64>) -> Result<()> {
        if data.len() != self.count * dim.max(1) {
            return Err(Error::Feature(format!(
                "column {name:?}: {} values for {} nodes × dim {dim}",
                data.len(),
                self.count
            )));
        }
        self.i64s.insert(name.to_string(), (dim, data));
        Ok(())
    }

    /// Gather rows for `nodes` into a [`Feature`] map.
    pub fn gather(&self, nodes: &[u32]) -> BTreeMap<String, Feature> {
        let mut out = BTreeMap::new();
        for (name, (dim, data)) in &self.f32s {
            let d = (*dim).max(1);
            let mut rows = Vec::with_capacity(nodes.len() * d);
            for &n in nodes {
                let n = n as usize;
                rows.extend_from_slice(&data[n * d..(n + 1) * d]);
            }
            let dims = if *dim == 0 { vec![] } else { vec![*dim] };
            out.insert(name.clone(), Feature::F32 { dims, data: rows });
        }
        for (name, (dim, data)) in &self.i64s {
            let d = (*dim).max(1);
            let mut rows = Vec::with_capacity(nodes.len() * d);
            for &n in nodes {
                let n = n as usize;
                rows.extend_from_slice(&data[n * d..(n + 1) * d]);
            }
            let dims = if *dim == 0 { vec![] } else { vec![*dim] };
            out.insert(name.clone(), Feature::I64 { dims, data: rows });
        }
        out
    }
}

/// CSR adjacency for one edge set, indexed by source node.
#[derive(Debug, Clone)]
pub struct EdgeColumn {
    pub source_set: String,
    pub target_set: String,
    /// `offsets[s]..offsets[s+1]` indexes `targets` for source node `s`.
    pub offsets: Vec<usize>,
    pub targets: Vec<u32>,
}

impl EdgeColumn {
    /// Build CSR from an (unsorted) edge list.
    pub fn from_edge_list(
        source_set: &str,
        target_set: &str,
        num_source_nodes: usize,
        edges: &[(u32, u32)],
    ) -> EdgeColumn {
        let mut degree = vec![0usize; num_source_nodes];
        for &(s, _) in edges {
            degree[s as usize] += 1;
        }
        let mut offsets = Vec::with_capacity(num_source_nodes + 1);
        let mut running = 0usize;
        offsets.push(running);
        for d in &degree {
            running += d;
            offsets.push(running);
        }
        let mut targets = vec![0u32; edges.len()];
        let mut cursor = offsets.clone();
        for &(s, t) in edges {
            let s = s as usize;
            targets[cursor[s]] = t;
            cursor[s] += 1;
        }
        EdgeColumn {
            source_set: source_set.to_string(),
            target_set: target_set.to_string(),
            offsets,
            targets,
        }
    }

    pub fn num_edges(&self) -> usize {
        self.targets.len()
    }

    /// Out-neighbors of `node`.
    pub fn neighbors(&self, node: u32) -> &[u32] {
        let n = node as usize;
        &self.targets[self.offsets[n]..self.offsets[n + 1]]
    }

    pub fn out_degree(&self, node: u32) -> usize {
        let n = node as usize;
        self.offsets[n + 1] - self.offsets[n]
    }

    /// Reverse this edge set (target becomes source) — used to derive
    /// e.g. `written` from `writes` as §8's schema does.
    pub fn reversed(&self, num_target_nodes: usize) -> EdgeColumn {
        let mut edges = Vec::with_capacity(self.num_edges());
        for s in 0..self.offsets.len() - 1 {
            for &t in self.neighbors(s as u32) {
                edges.push((t, s as u32));
            }
        }
        EdgeColumn::from_edge_list(&self.target_set, &self.source_set, num_target_nodes, &edges)
    }
}

/// The full heterogeneous graph in columnar + CSR form.
#[derive(Debug, Clone)]
pub struct GraphStore {
    pub schema: GraphSchema,
    pub nodes: BTreeMap<String, NodeColumn>,
    pub edges: BTreeMap<String, EdgeColumn>,
}

impl GraphStore {
    pub fn new(schema: GraphSchema) -> GraphStore {
        GraphStore { schema, nodes: BTreeMap::new(), edges: BTreeMap::new() }
    }

    pub fn node_count(&self, set: &str) -> Result<usize> {
        self.nodes
            .get(set)
            .map(|c| c.count)
            .ok_or_else(|| Error::Graph(format!("store has no node set {set:?}")))
    }

    pub fn edge_column(&self, set: &str) -> Result<&EdgeColumn> {
        self.edges
            .get(set)
            .ok_or_else(|| Error::Graph(format!("store has no edge set {set:?}")))
    }

    pub fn node_column(&self, set: &str) -> Result<&NodeColumn> {
        self.nodes
            .get(set)
            .ok_or_else(|| Error::Graph(format!("store has no node set {set:?}")))
    }

    /// Consistency checks: edge endpoints within node counts, schema
    /// agreement on endpoint sets, dtypes of columns declared.
    pub fn validate(&self) -> Result<()> {
        self.schema.validate()?;
        for (name, ec) in &self.edges {
            let spec = self.schema.edge_set(name)?;
            if spec.source != ec.source_set || spec.target != ec.target_set {
                return Err(Error::Schema(format!(
                    "edge column {name:?} endpoints disagree with schema"
                )));
            }
            let n_src = self.node_count(&ec.source_set)?;
            let n_tgt = self.node_count(&ec.target_set)?;
            if ec.offsets.len() != n_src + 1 {
                return Err(Error::Graph(format!(
                    "edge column {name:?}: offsets len {} != {} + 1",
                    ec.offsets.len(),
                    n_src
                )));
            }
            if let Some(&bad) = ec.targets.iter().find(|&&t| (t as usize) >= n_tgt) {
                return Err(Error::Graph(format!(
                    "edge column {name:?}: target {bad} out of range {n_tgt}"
                )));
            }
        }
        for (name, nc) in &self.nodes {
            let spec = self.schema.node_set(name)?;
            for (fname, fspec) in &spec.features {
                let declared_dim = fspec.dense_elems();
                let found = match fspec.dtype {
                    DType::F32 => nc.f32s.get(fname).map(|(d, _)| (*d).max(1)),
                    DType::I64 => nc.i64s.get(fname).map(|(d, _)| (*d).max(1)),
                    DType::Str => continue, // store keeps numeric columns only
                };
                match (declared_dim, found) {
                    (Some(want), Some(have)) if want == have => {}
                    (Some(want), Some(have)) => {
                        return Err(Error::Feature(format!(
                            "column {name}/{fname}: dim {have} != schema {want}"
                        )))
                    }
                    (_, None) => {
                        return Err(Error::Feature(format!(
                            "column {name}/{fname} declared in schema but missing in store"
                        )))
                    }
                    (None, _) => {}
                }
            }
        }
        Ok(())
    }

    /// Total edges across edge sets (bench reporting).
    pub fn total_edges(&self) -> usize {
        self.edges.values().map(|e| e.num_edges()).sum()
    }

    /// Export the *whole* store as a single-component GraphTensor — the
    /// "small scale: no sampling" path (§6.1.3).
    pub fn to_graph_tensor(&self) -> Result<GraphTensor> {
        let mut node_sets = BTreeMap::new();
        for (name, nc) in &self.nodes {
            let all: Vec<u32> = (0..nc.count as u32).collect();
            let mut ns = NodeSet::new(vec![nc.count]);
            ns.features = nc.gather(&all);
            node_sets.insert(name.clone(), ns);
        }
        let mut edge_sets = BTreeMap::new();
        for (name, ec) in &self.edges {
            let mut source = Vec::with_capacity(ec.num_edges());
            let mut target = Vec::with_capacity(ec.num_edges());
            for s in 0..ec.offsets.len() - 1 {
                for &t in ec.neighbors(s as u32) {
                    source.push(s as u32);
                    target.push(t);
                }
            }
            edge_sets.insert(
                name.clone(),
                EdgeSet::new(
                    vec![source.len()],
                    Adjacency {
                        source_set: ec.source_set.clone(),
                        target_set: ec.target_set.clone(),
                        source,
                        target,
                    },
                ),
            );
        }
        GraphTensor::from_pieces(Context::default(), node_sets, edge_sets)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schema::{EdgeSetSpec, FeatureSpec, Metadata, NodeSetSpec};

    pub fn tiny_schema() -> GraphSchema {
        let mut a = NodeSetSpec::default();
        a.features.insert("x".into(), FeatureSpec::f32(&[2]));
        let b = NodeSetSpec::default();
        GraphSchema::default()
            .with_node_set("a", a)
            .with_node_set("b", b)
            .with_edge_set(
                "ab",
                EdgeSetSpec {
                    source: "a".into(),
                    target: "b".into(),
                    features: BTreeMap::new(),
                    metadata: Metadata::default(),
                },
            )
    }

    pub fn tiny_store() -> GraphStore {
        let mut store = GraphStore::new(tiny_schema());
        let mut a = NodeColumn::new(3);
        a.add_f32("x", 2, vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0]).unwrap();
        store.nodes.insert("a".into(), a);
        store.nodes.insert("b".into(), NodeColumn::new(2));
        store.edges.insert(
            "ab".into(),
            EdgeColumn::from_edge_list("a", "b", 3, &[(0, 1), (0, 0), (2, 1)]),
        );
        store
    }

    #[test]
    fn csr_construction() {
        let s = tiny_store();
        let ec = s.edge_column("ab").unwrap();
        assert_eq!(ec.num_edges(), 3);
        assert_eq!(ec.out_degree(0), 2);
        assert_eq!(ec.out_degree(1), 0);
        assert_eq!(ec.out_degree(2), 1);
        let mut n0 = ec.neighbors(0).to_vec();
        n0.sort();
        assert_eq!(n0, vec![0, 1]);
        assert_eq!(ec.neighbors(2), &[1]);
    }

    #[test]
    fn reverse_edges() {
        let s = tiny_store();
        let rev = s.edge_column("ab").unwrap().reversed(2);
        assert_eq!(rev.source_set, "b");
        assert_eq!(rev.num_edges(), 3);
        let mut from_b1 = rev.neighbors(1).to_vec();
        from_b1.sort();
        assert_eq!(from_b1, vec![0, 2]); // b1 was target of a0 and a2
        assert_eq!(rev.neighbors(0), &[0]);
    }

    #[test]
    fn double_reverse_is_identity() {
        let s = tiny_store();
        let ec = s.edge_column("ab").unwrap();
        let back = ec.reversed(2).reversed(3);
        assert_eq!(back.offsets, ec.offsets);
        let mut a: Vec<_> = back.targets.clone();
        let mut b: Vec<_> = ec.targets.clone();
        a.sort();
        b.sort();
        assert_eq!(a, b);
    }

    #[test]
    fn gather_features() {
        let s = tiny_store();
        let feats = s.node_column("a").unwrap().gather(&[2, 0]);
        let (dims, data) = feats["x"].as_f32().unwrap();
        assert_eq!(dims, &[2]);
        assert_eq!(data, &[4.0, 5.0, 0.0, 1.0]);
    }

    #[test]
    fn validate_catches_problems() {
        let s = tiny_store();
        s.validate().unwrap();
        // Missing declared column.
        let mut bad = s.clone();
        bad.nodes.get_mut("a").unwrap().f32s.remove("x");
        assert!(bad.validate().is_err());
        // Out-of-range target.
        let mut bad = s.clone();
        bad.edges.get_mut("ab").unwrap().targets[0] = 99;
        assert!(bad.validate().is_err());
        // Wrong dim.
        let mut bad = s;
        let col = bad.nodes.get_mut("a").unwrap();
        col.f32s.insert("x".into(), (3, vec![0.0; 9]));
        assert!(bad.validate().is_err());
    }

    #[test]
    fn to_graph_tensor_full_export() {
        let s = tiny_store();
        let g = s.to_graph_tensor().unwrap();
        assert_eq!(g.num_nodes("a").unwrap(), 3);
        assert_eq!(g.num_nodes("b").unwrap(), 2);
        assert_eq!(g.num_edges("ab").unwrap(), 3);
        g.validate().unwrap();
        let (dims, _) = g.node_set("a").unwrap().feature("x").unwrap().as_f32().unwrap();
        assert_eq!(dims, &[2]);
    }

    #[test]
    fn scalar_i64_column() {
        let mut store = tiny_store();
        store.nodes.get_mut("a").unwrap().add_i64("label", 0, vec![5, 6, 7]).unwrap();
        let feats = store.node_column("a").unwrap().gather(&[1]);
        let (dims, data) = feats["label"].as_i64().unwrap();
        assert!(dims.is_empty());
        assert_eq!(data, &[6]);
    }
}

#[cfg(test)]
pub use tests::{tiny_schema, tiny_store};
