//! Static model-plan analysis — `tfgnn check`.
//!
//! Compiles (schema × sampling spec × model config × task block) into
//! the typed plan IR of [`plan`] and runs the passes of [`passes`]
//! over it, **without touching any graph data**: shape inference,
//! dead-set detection, seed→readout reachability, and
//! parameter-namespace/checkpoint compatibility. Defects come back as
//! structured [`Diagnostic`]s — stable `TFGNN0xx` code, severity, JSON
//! path, fix hint (the full code reference lives in
//! [`diag::CODES`] / `docs/diagnostics.md`).
//!
//! Entry points:
//! * [`analyze`] / [`analyze_against_checkpoint`] — full analysis of a
//!   run-config document (what the `tfgnn check` CLI runs);
//! * [`check_config`] — the fail-fast gate `run_native` calls before
//!   building anything, so the runner rejects a bad config with the
//!   *same* diagnostics the CLI prints;
//! * [`check_model`] — the model-level subset over an already-parsed
//!   [`ModelConfig`], for serving paths where the raw document is gone.

pub mod diag;
pub mod passes;
pub mod plan;

pub use diag::{Diagnostic, Diagnostics, Severity};
pub use plan::ModelPlan;

use crate::ops::model_ref::ModelConfig;
use crate::runtime::HostTensor;
use crate::util::json::Json;
use crate::Result;

/// Run the full pass suite over a run-config document.
pub fn analyze(cfg: &Json) -> Diagnostics {
    analyze_impl(cfg, None)
}

/// [`analyze`], plus checkpoint compatibility against `checkpoint`
/// (the `train::checkpoint` codec's named tensors).
pub fn analyze_against_checkpoint(
    cfg: &Json,
    checkpoint: &[(String, HostTensor)],
) -> Diagnostics {
    analyze_impl(cfg, Some(checkpoint))
}

fn analyze_impl(cfg: &Json, checkpoint: Option<&[(String, HostTensor)]>) -> Diagnostics {
    let mut d = Diagnostics::default();
    if let Some(plan) = ModelPlan::compile(cfg, &mut d) {
        passes::shape_pass(&plan, &mut d);
        passes::dead_set_pass(&plan, &mut d);
        passes::reachability_pass(&plan, &mut d);
        passes::param_pass(&plan, checkpoint, &mut d);
    }
    d
}

/// The model-level subset over an already-parsed config — what the
/// serving entry points gate on (no sampling/pad/dataset document
/// available there).
pub fn check_model(cfg: &ModelConfig) -> Diagnostics {
    let mut d = Diagnostics::default();
    if let Some(plan) = ModelPlan::compile_model_only(cfg, &mut d) {
        passes::shape_pass(&plan, &mut d);
        passes::dead_set_pass(&plan, &mut d);
        passes::reachability_pass(&plan, &mut d);
        passes::param_pass(&plan, None, &mut d);
    }
    d
}

/// Fail-fast gate for run entry points: `Ok(())` on an error-free
/// config, else an error listing every diagnostic line the CLI would
/// print.
pub fn check_config(cfg: &Json) -> Result<()> {
    analyze(cfg).into_result()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::model_ref::TaskConfig;
    use crate::synth::mag::MagConfig;

    #[test]
    fn check_model_passes_the_mag_zoo() {
        let base = ModelConfig::for_mag(&MagConfig::tiny(), 8, 8, 1);
        for arch in ["mpnn", "gcn", "sage", "gatv2"] {
            let d = check_model(&base.clone().with_arch(arch));
            assert!(d.is_clean(), "{arch}:\n{d}");
        }
        for task in [
            TaskConfig::default(),
            TaskConfig { kind: "link_prediction".into(), ..TaskConfig::default() },
            TaskConfig { kind: "graph_regression".into(), ..TaskConfig::default() },
        ] {
            let d = check_model(&base.clone().with_task(task.clone()));
            assert!(d.is_clean(), "{}:\n{d}", task.kind);
        }
    }

    #[test]
    fn check_model_rejects_bad_arch() {
        let cfg = ModelConfig::for_mag(&MagConfig::tiny(), 8, 8, 1).with_arch("transformer");
        let d = check_model(&cfg);
        assert!(d.has_errors());
        assert!(d.find(diag::codes::UNKNOWN_ENUM).is_some(), "{d}");
    }

    #[test]
    fn check_config_message_carries_code_and_path() {
        let cfg = crate::util::json::Json::parse("{}").expect("json");
        let err = check_config(&cfg).expect_err("empty config");
        let msg = err.to_string();
        assert!(msg.contains("TFGNN001"), "{msg}");
        assert!(msg.contains("$.model"), "{msg}");
    }
}
