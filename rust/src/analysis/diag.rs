//! Structured diagnostics: stable codes, severity, JSON path, fix hint.
//!
//! Every config defect the static analyzer (or any config-parsing
//! funnel) can report carries a stable `TFGNN0xx` code from the
//! [`CODES`] table — the single source of truth `docs/diagnostics.md`
//! is generated from (see [`render_markdown`]; pinned by
//! `tests/analyzer.rs`). A [`Diagnostic`] names the code, a severity,
//! the JSON path of the offending config value (`$.model.att_dim`
//! style) and a human message.
//!
//! The config funnels in `ops::model_ref` / `layers::builder` / `tasks`
//! keep their `Result<_, crate::Error>` signatures: a diagnostic
//! converts to an error with [`Diagnostic::into_error`], which appends
//! a machine-readable ` [TFGNN0xx @ path]` suffix to the message, and
//! [`Diagnostic::from_error`] recovers the structure — so the CLI
//! `tfgnn check`, `run_native` and `serve_native` all emit identical
//! diagnostics without duplicating a single check.

use crate::{Error, Result};

/// Diagnostic severity. Errors fail `tfgnn check` (and the entry-point
/// gates); warnings are reported but do not fail.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Severity {
    Warning,
    Error,
}

impl Severity {
    pub fn name(&self) -> &'static str {
        match self {
            Severity::Warning => "warning",
            Severity::Error => "error",
        }
    }
}

/// Stable diagnostic codes. Codes are append-only: a released code
/// never changes meaning (tooling and CI grep for them).
pub mod codes {
    /// Malformed config document: missing required key/block, wrong
    /// JSON type, bad edge-set endpoint list.
    pub const CONFIG: &str = "TFGNN001";
    /// Unknown key in a `model`/`task` block (typo defense).
    pub const UNKNOWN_KEY: &str = "TFGNN002";
    /// `model.type` vs `model.arch` conflict, or an AOT-only `arch`
    /// used without an explicit native `type`.
    pub const ARCH_CONFLICT: &str = "TFGNN003";
    /// Unknown enum value (`model.type`, `sage_reduce`, `task.type`,
    /// `task.readout`, `task.loss`, …).
    pub const UNKNOWN_ENUM: &str = "TFGNN004";
    /// Zero or invalid dimension (widths, layer count, feature dims,
    /// embedding cardinality, class count).
    pub const BAD_DIM: &str = "TFGNN005";
    /// Invalid task knob (negatives, hits_k, holdout fraction, margin,
    /// target scale, heterogeneous link-prediction edge set).
    pub const BAD_TASK_KNOB: &str = "TFGNN006";
    /// Reference to an edge set the schema does not declare.
    pub const UNKNOWN_EDGE_SET: &str = "TFGNN007";
    /// Reference to a node set the schema does not declare.
    pub const UNKNOWN_NODE_SET: &str = "TFGNN008";
    /// An update pools an edge set whose SOURCE endpoint is not the
    /// updated node set (the rooted-subgraph direction convention).
    pub const RECEIVER_NOT_SOURCE: &str = "TFGNN009";
    /// An update pools the same edge set twice.
    pub const DUPLICATE_POOL: &str = "TFGNN010";
    /// Forward shape inference found a dimension mismatch (schema
    /// feature dims vs dataset, class counts, embedding tables).
    pub const SHAPE_MISMATCH: &str = "TFGNN011";
    /// Pad spec problem: missing caps, or a component cap too small
    /// for the batch size.
    pub const PAD_SPEC: &str = "TFGNN012";
    /// Dead set: an edge set the sampler fetches but no GraphUpdate
    /// reads (warning), or one the model reads but the sampling plan
    /// never provides (error — every step would pool zero messages).
    pub const DEAD_SET: &str = "TFGNN013";
    /// The task's readout set is unreachable from the sampling seeds.
    pub const UNREACHABLE_READOUT: &str = "TFGNN014";
    /// Two parameters would be created under the same name.
    pub const PARAM_COLLISION: &str = "TFGNN015";
    /// Checkpoint incompatibility: missing/extra/mis-shaped parameter
    /// vs what this config's model would create.
    pub const CHECKPOINT_MISMATCH: &str = "TFGNN016";
    /// Sampling spec problem: missing/zero fan-out sizes, or a plan
    /// that does not compose over the schema.
    pub const SAMPLING_SPEC: &str = "TFGNN017";
}

/// One row of the code reference (drives `docs/diagnostics.md`).
pub struct CodeInfo {
    pub code: &'static str,
    pub title: &'static str,
    pub summary: &'static str,
    pub hint: &'static str,
}

/// The full stable code table, in code order.
pub const CODES: &[CodeInfo] = &[
    CodeInfo {
        code: codes::CONFIG,
        title: "malformed config",
        summary: "A required key or block is missing, has the wrong JSON type, \
                  or an edge set's endpoint list is not `[source, target]`.",
        hint: "Compare against a shipped `configs/*.json`; every run config needs \
               `schema`, `model`, `train`, `sampling`, `pad` and `batch_size`.",
    },
    CodeInfo {
        code: codes::UNKNOWN_KEY,
        title: "unknown key",
        summary: "A `model` or `task` block carries a key the engine does not \
                  know — typos must not silently fall back to defaults.",
        hint: "Check the spelling against the known-key list in the message.",
    },
    CodeInfo {
        code: codes::ARCH_CONFLICT,
        title: "architecture conflict",
        summary: "`model.type` and `model.arch` disagree, or a non-mpnn `arch` \
                  was given without an explicit native `model.type`.",
        hint: "Keep one key: `model.type` selects the native convolution zoo \
               (mpnn|gcn|sage|gatv2).",
    },
    CodeInfo {
        code: codes::UNKNOWN_ENUM,
        title: "unknown enum value",
        summary: "An enumerated config value is outside its vocabulary \
                  (`model.type`, `model.sage_reduce`, `task.type`, \
                  `task.readout`, `task.loss`).",
        hint: "The message lists the accepted values.",
    },
    CodeInfo {
        code: codes::BAD_DIM,
        title: "bad dimension",
        summary: "A width, layer count, feature dimension, embedding \
                  cardinality or class count is zero or unusable.",
        hint: "All model widths and schema dims must be positive integers.",
    },
    CodeInfo {
        code: codes::BAD_TASK_KNOB,
        title: "bad task knob",
        summary: "A task hyper-knob is out of range (negatives, hits_k, \
                  holdout_fraction, margin, target_scale), or the \
                  link-prediction edge set is heterogeneous.",
        hint: "See the `task` block reference in DESIGN.md for valid ranges.",
    },
    CodeInfo {
        code: codes::UNKNOWN_EDGE_SET,
        title: "unknown edge set",
        summary: "The config references an edge set the schema does not \
                  declare (in `model.updates`, `task.edge_set`, or \
                  `sampling.sizes`).",
        hint: "Declare the edge set under `schema.edge_sets`, or fix the name.",
    },
    CodeInfo {
        code: codes::UNKNOWN_NODE_SET,
        title: "unknown node set",
        summary: "The config references a node set the schema does not \
                  declare (e.g. `task.root_set`).",
        hint: "Declare the node set under `schema.node_sets`, or fix the name.",
    },
    CodeInfo {
        code: codes::RECEIVER_NOT_SOURCE,
        title: "receiver is not the source endpoint",
        summary: "An update pools an edge set whose SOURCE endpoint is not \
                  the updated node set — the engine's convolutions receive at \
                  the source (the rooted-subgraph sampling direction).",
        hint: "Pool the reverse edge set instead, or swap the endpoints in \
               `schema.edge_sets`.",
    },
    CodeInfo {
        code: codes::DUPLICATE_POOL,
        title: "duplicate pool",
        summary: "An update pools the same edge set twice, which would create \
                  two parameter tensors under one name.",
        hint: "List each edge set at most once per `model.updates` entry.",
    },
    CodeInfo {
        code: codes::SHAPE_MISMATCH,
        title: "shape mismatch",
        summary: "Forward shape inference found a dimension conflict: a schema \
                  feature width disagrees with the dataset, `train.num_classes` \
                  disagrees with the dataset's label space, or an embedding \
                  table is smaller than the entity count it must index.",
        hint: "The message names both sides of the mismatch; make them agree.",
    },
    CodeInfo {
        code: codes::PAD_SPEC,
        title: "pad spec problem",
        summary: "`pad.node_caps`/`pad.edge_caps` do not cover every schema \
                  set, or `pad.component_cap` cannot hold a full batch plus \
                  the padding component.",
        hint: "Every schema set needs a cap; `component_cap` must be at least \
               `batch_size + 1`.",
    },
    CodeInfo {
        code: codes::DEAD_SET,
        title: "dead set",
        summary: "An edge set is sampled but never read by any GraphUpdate \
                  (wasted fan-out — warning), or read by an update but never \
                  provided by the sampling plan (every step would silently \
                  pool zero messages — error).",
        hint: "Align `sampling.sizes` with the union of `model.updates` lists.",
    },
    CodeInfo {
        code: codes::UNREACHABLE_READOUT,
        title: "unreachable readout",
        summary: "The task reads out from a node set the sampling plan cannot \
                  reach from its seeds (root readouts must target the seed \
                  node set; link-prediction pairs must live on it).",
        hint: "Point `task.root_set`/`task.edge_set` at the sampling seed \
               node set, or extend the sampling plan.",
    },
    CodeInfo {
        code: codes::PARAM_COLLISION,
        title: "parameter name collision",
        summary: "Two parameter tensors would be created under the same \
                  `l{L}.{node_set}.{edge_set}.{suffix}` name.",
        hint: "Usually a duplicate-pool or naming-scheme bug; the message \
               names the colliding parameter.",
    },
    CodeInfo {
        code: codes::CHECKPOINT_MISMATCH,
        title: "checkpoint mismatch",
        summary: "The checkpoint's parameter inventory disagrees with what \
                  this config's model would create: a missing name, a stale \
                  extra name, or a shape conflict.",
        hint: "Retrain, or fix the config so its architecture matches the \
               checkpoint's (`tfgnn check --against-checkpoint` lists every \
               difference).",
    },
    CodeInfo {
        code: codes::SAMPLING_SPEC,
        title: "sampling spec problem",
        summary: "`sampling.sizes` is missing an edge set the plan needs, a \
                  fan-out is zero, or the plan does not compose over the \
                  schema's endpoints.",
        hint: "Give every edge set of the plan a positive fan-out size.",
    },
];

/// Look up a code's table row.
pub fn code_info(code: &str) -> Option<&'static CodeInfo> {
    CODES.iter().find(|c| c.code == code)
}

/// One reported defect.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    /// Stable `TFGNN0xx` code (see [`CODES`]).
    pub code: &'static str,
    pub severity: Severity,
    /// JSON path of the offending value, `$.model.att_dim` style.
    pub path: String,
    pub message: String,
    /// Optional fix hint (defaults to the code table's hint).
    pub hint: Option<String>,
}

impl Diagnostic {
    pub fn error(
        code: &'static str,
        path: impl Into<String>,
        message: impl Into<String>,
    ) -> Diagnostic {
        Diagnostic {
            code,
            severity: Severity::Error,
            path: path.into(),
            message: message.into(),
            hint: None,
        }
    }

    pub fn warning(
        code: &'static str,
        path: impl Into<String>,
        message: impl Into<String>,
    ) -> Diagnostic {
        Diagnostic {
            code,
            severity: Severity::Warning,
            path: path.into(),
            message: message.into(),
            hint: None,
        }
    }

    pub fn with_hint(mut self, hint: impl Into<String>) -> Diagnostic {
        self.hint = Some(hint.into());
        self
    }

    /// The fix hint: this diagnostic's own, else the code table's.
    pub fn hint(&self) -> &str {
        match &self.hint {
            Some(h) => h,
            None => code_info(self.code).map(|c| c.hint).unwrap_or(""),
        }
    }

    /// Convert to the crate error type, keeping the structure
    /// recoverable: the message gains a ` [TFGNN0xx @ path]` suffix
    /// that [`Diagnostic::from_error`] parses back.
    pub fn into_error(self) -> Error {
        Error::Schema(format!("{} [{} @ {}]", self.message, self.code, self.path))
    }

    /// Recover a diagnostic from an error produced by
    /// [`Diagnostic::into_error`]; any other error becomes a
    /// [`codes::CONFIG`] diagnostic at `$`.
    pub fn from_error(e: &Error) -> Diagnostic {
        let m = match e {
            Error::Schema(m)
            | Error::Graph(m)
            | Error::Feature(m)
            | Error::Sampler(m)
            | Error::Pipeline(m)
            | Error::Runtime(m)
            | Error::Codec(m)
            | Error::Xla(m)
            | Error::Overloaded(m)
            | Error::DeadlineExceeded(m) => m.clone(),
            Error::Io(e) => e.to_string(),
        };
        if let Some(open) = m.rfind(" [TFGNN") {
            if let Some(stripped) = m[open..].strip_prefix(" [") {
                if let Some(body) = stripped.strip_suffix(']') {
                    if let Some((code, path)) = body.split_once(" @ ") {
                        if let Some(info) = code_info(code) {
                            return Diagnostic::error(info.code, path, m[..open].to_string());
                        }
                    }
                }
            }
        }
        Diagnostic::error(codes::CONFIG, "$", m)
    }
}

impl std::fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} [{}] at {}: {}",
            self.severity.name(),
            self.code,
            self.path,
            self.message
        )?;
        let hint = self.hint();
        if !hint.is_empty() {
            write!(f, "\n  hint: {hint}")?;
        }
        Ok(())
    }
}

/// An ordered collection of diagnostics from one analysis run.
#[derive(Debug, Default)]
pub struct Diagnostics {
    diags: Vec<Diagnostic>,
}

impl Diagnostics {
    pub fn push(&mut self, d: Diagnostic) {
        self.diags.push(d);
    }

    pub fn iter(&self) -> impl Iterator<Item = &Diagnostic> {
        self.diags.iter()
    }

    pub fn len(&self) -> usize {
        self.diags.len()
    }

    /// No diagnostics at all (not even warnings).
    pub fn is_empty(&self) -> bool {
        self.diags.is_empty()
    }

    /// No errors (warnings allowed) — the gate `run_native`/serving use.
    pub fn is_clean(&self) -> bool {
        !self.has_errors()
    }

    pub fn has_errors(&self) -> bool {
        self.diags.iter().any(|d| d.severity == Severity::Error)
    }

    pub fn error_count(&self) -> usize {
        self.diags.iter().filter(|d| d.severity == Severity::Error).count()
    }

    /// First diagnostic carrying `code`, if any.
    pub fn find(&self, code: &str) -> Option<&Diagnostic> {
        self.diags.iter().find(|d| d.code == code)
    }

    /// `Ok(())` if error-free, else the first error as a
    /// [`crate::Error`] whose message carries every error line —
    /// this is what makes the entry-point gates print the same content
    /// as `tfgnn check`.
    pub fn into_result(self) -> Result<()> {
        if self.is_clean() {
            return Ok(());
        }
        let lines: Vec<String> = self
            .diags
            .iter()
            .filter(|d| d.severity == Severity::Error)
            .map(|d| d.to_string())
            .collect();
        Err(Error::Schema(format!(
            "config check failed with {} error(s):\n{}",
            lines.len(),
            lines.join("\n")
        )))
    }
}

impl std::fmt::Display for Diagnostics {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for d in &self.diags {
            writeln!(f, "{d}")?;
        }
        Ok(())
    }
}

/// Generate `docs/diagnostics.md` from the code table (pinned to the
/// checked-in file by `tests/analyzer.rs`).
pub fn render_markdown() -> String {
    let mut out = String::new();
    out.push_str("# `tfgnn check` diagnostic codes\n\n");
    out.push_str(
        "Generated from the single source-of-truth table in \
         `rust/src/analysis/diag.rs` — edit that table, not this file \
         (`tests/analyzer.rs` pins the two together).\n\n",
    );
    out.push_str(
        "Every code is stable: once released its meaning never changes. \
         Diagnostics carry a severity (errors fail `tfgnn check`, \
         `run_native` and `serve_native`; warnings are report-only), the \
         JSON path of the offending config value, and a fix hint.\n\n",
    );
    for c in CODES {
        out.push_str(&format!("## {} — {}\n\n", c.code, c.title));
        out.push_str(&format!("{}\n\n", collapse_ws(c.summary)));
        out.push_str(&format!("**Fix:** {}\n\n", collapse_ws(c.hint)));
    }
    out
}

/// Collapse the multi-line string-literal indentation of the table's
/// text into single-space prose.
fn collapse_ws(s: &str) -> String {
    s.split_whitespace().collect::<Vec<_>>().join(" ")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codes_are_unique_sorted_and_well_formed() {
        for w in CODES.windows(2) {
            assert!(w[0].code < w[1].code, "{} vs {}", w[0].code, w[1].code);
        }
        for c in CODES {
            assert!(c.code.starts_with("TFGNN"), "{}", c.code);
            assert_eq!(c.code.len(), 8, "{}", c.code);
            assert!(!c.title.is_empty() && !c.summary.is_empty() && !c.hint.is_empty());
        }
        assert_eq!(CODES.len(), 17);
    }

    #[test]
    fn error_roundtrip_preserves_structure() {
        let d = Diagnostic::error(codes::BAD_DIM, "$.model.hidden_dim", "hidden_dim is 0");
        let e = d.clone().into_error();
        let msg = e.to_string();
        assert!(msg.contains("hidden_dim is 0"), "{msg}");
        assert!(msg.contains("TFGNN005"), "{msg}");
        let back = Diagnostic::from_error(&e);
        assert_eq!(back.code, codes::BAD_DIM);
        assert_eq!(back.path, "$.model.hidden_dim");
        assert_eq!(back.message, "hidden_dim is 0");
    }

    #[test]
    fn foreign_errors_become_config_diagnostics() {
        let e = Error::Runtime("no manifest".into());
        let d = Diagnostic::from_error(&e);
        assert_eq!(d.code, codes::CONFIG);
        assert_eq!(d.path, "$");
        assert!(d.message.contains("no manifest"));
    }

    #[test]
    fn diagnostics_gate_on_errors_only() {
        let mut ds = Diagnostics::default();
        ds.push(Diagnostic::warning(codes::DEAD_SET, "$.sampling.sizes.x", "unused"));
        assert!(ds.is_clean());
        assert!(!ds.is_empty());
        assert!(ds.into_result().is_ok());
        let mut ds = Diagnostics::default();
        ds.push(Diagnostic::error(codes::BAD_DIM, "$.model.hidden_dim", "zero"));
        ds.push(Diagnostic::warning(codes::DEAD_SET, "$.x", "unused"));
        assert_eq!(ds.error_count(), 1);
        assert!(ds.find(codes::BAD_DIM).is_some());
        let err = ds.into_result().err().map(|e| e.to_string()).unwrap_or_default();
        assert!(err.contains("1 error"), "{err}");
        assert!(err.contains("TFGNN005"), "{err}");
        assert!(!err.contains("TFGNN013"), "warnings stay out of the gate: {err}");
    }

    #[test]
    fn markdown_covers_every_code() {
        let md = render_markdown();
        for c in CODES {
            assert!(md.contains(c.code), "{} missing", c.code);
        }
        assert!(md.starts_with("# `tfgnn check` diagnostic codes"));
    }
}
