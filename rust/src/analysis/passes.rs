//! Analysis passes over the compiled [`ModelPlan`].
//!
//! Each pass is a pure function `(&ModelPlan, &mut Diagnostics)` (plus
//! an optional checkpoint for the parameter pass). They check the plan
//! IR only — no graph data, no tensors — so the whole suite runs in
//! microseconds at every entry point:
//!
//! * [`shape_pass`] — forward shape inference cross-checks: feature
//!   widths vs the dataset, class counts, embedding cardinalities vs
//!   entity counts, pad caps vs batch size;
//! * [`dead_set_pass`] — edge sets the model reads but the sampling
//!   plan never provides (error: every update would pool zero
//!   messages, silently), and sets sampled but never read (warning:
//!   wasted fan-out);
//! * [`reachability_pass`] — the task's readout must live on the
//!   sampling seed node set (roots are interned seeds-first, so a
//!   non-seed readout reads an arbitrary node, silently);
//! * [`param_pass`] — parameter-namespace collisions, and the full
//!   name/shape inventory against an optional checkpoint.

use std::collections::BTreeSet;

use super::diag::{codes, Diagnostic, Diagnostics};
use super::plan::ModelPlan;
use crate::runtime::HostTensor;

/// Shape inference cross-checks (see module docs).
pub fn shape_pass(plan: &ModelPlan, d: &mut Diagnostics) {
    for node in &plan.nodes {
        for (fname, dim) in &node.features {
            if *dim == 0 {
                d.push(Diagnostic::error(
                    codes::BAD_DIM,
                    format!("$.schema.node_sets.{}.features.{fname}", node.name),
                    format!("feature {}/{fname} has no dimension", node.name),
                ));
            }
        }
        if node.id_embedding && node.features.is_empty() {
            match node.cardinality {
                None => d.push(Diagnostic::error(
                    codes::BAD_DIM,
                    format!("$.schema.node_sets.{}.cardinality", node.name),
                    format!("id-embedding set {:?} has no cardinality", node.name),
                )),
                Some(0) => d.push(Diagnostic::error(
                    codes::BAD_DIM,
                    format!("$.schema.node_sets.{}.cardinality", node.name),
                    format!("id-embedding set {:?} has cardinality 0", node.name),
                )),
                Some(_) => {}
            }
        }
    }
    if plan.cfg.task.kind == "root_classification" && plan.cfg.num_classes == 0 {
        d.push(Diagnostic::error(
            codes::BAD_DIM,
            "$.train.num_classes",
            "train.num_classes is 0 — the classification head would be empty",
        ));
    }
    if let Some(ds) = &plan.dataset {
        if let Some(fd) = ds.feature_dim {
            if let Some(node) = plan.nodes.iter().find(|n| n.name == "paper") {
                if let Some((_, dim)) = node.features.iter().find(|(f, _)| f == "feat") {
                    if *dim != fd && *dim != 0 {
                        d.push(Diagnostic::error(
                            codes::SHAPE_MISMATCH,
                            "$.dataset.feature_dim",
                            format!(
                                "dataset generates paper.feat with dim {fd}, but the \
                                 schema declares {dim} — the encoder would reject \
                                 every batch"
                            ),
                        ));
                    }
                }
            }
        }
        if let Some(nc) = ds.num_classes {
            if nc != plan.cfg.num_classes && plan.cfg.task.kind == "root_classification" {
                d.push(Diagnostic::error(
                    codes::SHAPE_MISMATCH,
                    "$.train.num_classes",
                    format!(
                        "train.num_classes is {} but the dataset labels {nc} classes",
                        plan.cfg.num_classes
                    ),
                ));
            }
        }
        for (set, count) in
            [("institution", ds.num_institutions), ("field_of_study", ds.num_fields)]
        {
            let (Some(count), Some(node)) =
                (count, plan.nodes.iter().find(|n| n.name == set))
            else {
                continue;
            };
            let Some(card) = node.cardinality else { continue };
            let path = format!("$.schema.node_sets.{set}.cardinality");
            if card < count {
                d.push(Diagnostic::error(
                    codes::SHAPE_MISMATCH,
                    path,
                    format!(
                        "embedding table for {set:?} has {card} rows but the dataset \
                         generates {count} entities — ids past the table would fault"
                    ),
                ));
            } else if card > count {
                d.push(Diagnostic::warning(
                    codes::SHAPE_MISMATCH,
                    path,
                    format!(
                        "embedding table for {set:?} has {card} rows for only \
                         {count} entities ({} rows never trained)",
                        card - count
                    ),
                ));
            }
        }
    }
    if let Some(pad) = &plan.pad {
        if let Some(batch) = plan.batch_size {
            if pad.component_cap < batch + 1 {
                d.push(Diagnostic::error(
                    codes::PAD_SPEC,
                    "$.pad.component_cap",
                    format!(
                        "pad.component_cap {} cannot hold a batch of {batch} plus \
                         the padding component (need ≥ {})",
                        pad.component_cap,
                        batch + 1
                    ),
                ));
            }
        }
        for node in &plan.nodes {
            if !pad.node_caps.contains_key(&node.name) {
                d.push(Diagnostic::error(
                    codes::PAD_SPEC,
                    "$.pad.node_caps",
                    format!("pad.node_caps has no cap for node set {:?}", node.name),
                ));
            }
        }
        for edge in &plan.edges {
            if !pad.edge_caps.contains_key(&edge.name) {
                d.push(Diagnostic::error(
                    codes::PAD_SPEC,
                    "$.pad.edge_caps",
                    format!("pad.edge_caps has no cap for edge set {:?}", edge.name),
                ));
            }
        }
        let node_names: BTreeSet<&str> = plan.nodes.iter().map(|n| n.name.as_str()).collect();
        let edge_names: BTreeSet<&str> = plan.edges.iter().map(|e| e.name.as_str()).collect();
        for set in pad.node_caps.keys().filter(|s| !node_names.contains(s.as_str())) {
            d.push(Diagnostic::warning(
                codes::PAD_SPEC,
                format!("$.pad.node_caps.{set}"),
                format!("pad cap for unknown node set {set:?}"),
            ));
        }
        for set in pad.edge_caps.keys().filter(|s| !edge_names.contains(s.as_str())) {
            d.push(Diagnostic::warning(
                codes::PAD_SPEC,
                format!("$.pad.edge_caps.{set}"),
                format!("pad cap for unknown edge set {set:?}"),
            ));
        }
    }
}

/// Dead-set detection (see module docs).
pub fn dead_set_pass(plan: &ModelPlan, d: &mut Diagnostics) {
    let Some(sample) = &plan.sample else { return };
    let sampled: BTreeSet<&str> = sample.sampled_edge_sets().into_iter().collect();
    let mut read: BTreeSet<&str> = BTreeSet::new();
    for (node_set, edge_list) in &plan.cfg.updates {
        for es in edge_list {
            read.insert(es.as_str());
            if !sampled.contains(es.as_str()) {
                d.push(Diagnostic::error(
                    codes::DEAD_SET,
                    format!("$.model.updates.{node_set}"),
                    format!(
                        "update of {node_set:?} pools edge set {es:?}, which the \
                         sampling plan never fetches — every step would pool zero \
                         messages, silently"
                    ),
                ));
            }
        }
    }
    for es in sampled.difference(&read) {
        d.push(Diagnostic::warning(
            codes::DEAD_SET,
            format!("$.sampling.sizes.{es}"),
            format!(
                "edge set {es:?} is sampled but no GraphUpdate reads it \
                 (wasted fan-out)"
            ),
        ));
    }
    // Node sets that contribute nothing: no initial state, no update,
    // not an endpoint of any pooled edge set.
    let read_endpoints: BTreeSet<&str> = plan
        .edges
        .iter()
        .filter(|e| read.contains(e.name.as_str()))
        .flat_map(|e| [e.source.as_str(), e.target.as_str()])
        .collect();
    for node in &plan.nodes {
        if node.features.is_empty()
            && !node.id_embedding
            && !plan.cfg.updates.contains_key(&node.name)
            && !read_endpoints.contains(node.name.as_str())
        {
            d.push(Diagnostic::warning(
                codes::DEAD_SET,
                format!("$.schema.node_sets.{}", node.name),
                format!(
                    "node set {:?} carries no features or embedding, receives no \
                     update, and borders no pooled edge set",
                    node.name
                ),
            ));
        }
    }
}

/// Seed → readout reachability (see module docs).
pub fn reachability_pass(plan: &ModelPlan, d: &mut Diagnostics) {
    let t = &plan.cfg.task;
    let node_names: BTreeSet<&str> = plan.nodes.iter().map(|n| n.name.as_str()).collect();
    match t.kind.as_str() {
        "root_classification" | "graph_regression" => {
            if !node_names.contains(t.root_set.as_str()) {
                d.push(Diagnostic::error(
                    codes::UNKNOWN_NODE_SET,
                    "$.task.root_set",
                    format!("task.root_set {:?} is not a node set of the schema", t.root_set),
                ));
                return;
            }
            if let Some(sample) = &plan.sample {
                if t.root_set != sample.seed_node_set {
                    d.push(Diagnostic::error(
                        codes::UNREACHABLE_READOUT,
                        "$.task.root_set",
                        format!(
                            "task reads out from {:?} but the sampling plan seeds \
                             {:?} — roots are interned seeds-first, so the readout \
                             would pick up an arbitrary node",
                            t.root_set, sample.seed_node_set
                        ),
                    ));
                }
            }
        }
        "link_prediction" => {
            let Some(edge) = plan.edges.iter().find(|e| e.name == t.edge_set) else {
                d.push(Diagnostic::error(
                    codes::UNKNOWN_EDGE_SET,
                    "$.task.edge_set",
                    format!("task.edge_set {:?} is not an edge set of the schema", t.edge_set),
                ));
                return;
            };
            if edge.source != edge.target {
                d.push(Diagnostic::error(
                    codes::BAD_TASK_KNOB,
                    "$.task.edge_set",
                    format!(
                        "task.edge_set {:?} connects {:?}→{:?} — link prediction \
                         currently scores pairs within one node set (homogeneous \
                         edge sets)",
                        t.edge_set, edge.source, edge.target
                    ),
                ));
                return;
            }
            if let Some(sample) = &plan.sample {
                if edge.source != sample.seed_node_set {
                    d.push(Diagnostic::error(
                        codes::UNREACHABLE_READOUT,
                        "$.task.edge_set",
                        format!(
                            "link-prediction pairs live on {:?} but the sampling \
                             plan seeds {:?} — the pair endpoints would never be \
                             the interned seeds",
                            edge.source, sample.seed_node_set
                        ),
                    ));
                }
            }
        }
        // Unknown kinds are the config funnel's diagnostic.
        _ => {}
    }
}

/// Parameter-namespace checks (see module docs). `checkpoint` entries
/// are the `train::checkpoint` codec's: model parameters under a
/// `param.` prefix, optimizer state under `adam_m.`/`adam_v.`/`step`.
pub fn param_pass(
    plan: &ModelPlan,
    checkpoint: Option<&[(String, HostTensor)]>,
    d: &mut Diagnostics,
) {
    let mut seen: BTreeSet<&str> = BTreeSet::new();
    for p in &plan.params {
        if !seen.insert(p.name.as_str()) {
            d.push(Diagnostic::error(
                codes::PARAM_COLLISION,
                "$.model",
                format!("parameter {:?} would be created twice", p.name),
            ));
        }
    }
    let Some(ckpt) = checkpoint else { return };
    let prefixed = ckpt.iter().any(|(n, _)| n.starts_with("param."));
    let mut stored: std::collections::BTreeMap<&str, &[usize]> =
        std::collections::BTreeMap::new();
    for (name, t) in ckpt {
        if let Some(p) = name.strip_prefix("param.") {
            stored.insert(p, t.shape());
        } else if !prefixed
            && !name.starts_with("adam_m.")
            && !name.starts_with("adam_v.")
            && name != "step"
        {
            // Bare parameter lists (e.g. `params_as_tensors` dumps).
            stored.insert(name.as_str(), t.shape());
        }
    }
    for p in &plan.params {
        match stored.remove(p.name.as_str()) {
            None => d.push(Diagnostic::error(
                codes::CHECKPOINT_MISMATCH,
                "$.model",
                format!(
                    "checkpoint is missing parameter {:?} (expected [{}, {}])",
                    p.name, p.rows, p.cols
                ),
            )),
            Some(shape) => {
                if shape != [p.rows, p.cols] {
                    d.push(Diagnostic::error(
                        codes::CHECKPOINT_MISMATCH,
                        "$.model",
                        format!(
                            "parameter {:?} has shape {shape:?} in the checkpoint \
                             but this config would create [{}, {}]",
                            p.name, p.rows, p.cols
                        ),
                    ));
                }
            }
        }
    }
    for (name, shape) in stored {
        d.push(Diagnostic::error(
            codes::CHECKPOINT_MISMATCH,
            "$.model",
            format!(
                "checkpoint carries stale parameter {name:?} {shape:?}, which this \
                 config would not create"
            ),
        ));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    /// The plan.rs test fixture with a text-level mutation applied.
    fn plan_from(mutate: impl Fn(String) -> String) -> (Option<ModelPlan>, Diagnostics) {
        let base = r#"{
            "name": "pass_test", "batch_size": 4,
            "dataset": {
                "num_papers": 80, "num_authors": 60, "num_institutions": 10,
                "num_fields": 12, "num_classes": 4, "num_communities": 4,
                "feature_dim": 16, "mean_citations": 3.0,
                "mean_authors_per_paper": 2.0, "mean_topics": 2.0,
                "community_coherence": 0.9, "label_coherence": 0.9,
                "feature_noise": 0.5, "year_min": 2010, "year_max": 2014,
                "seed": 7
            },
            "schema": {
                "node_sets": {
                    "paper": {"features": {"feat": 16}},
                    "author": {},
                    "institution": {"id_embedding": true, "cardinality": 10},
                    "field_of_study": {"id_embedding": true, "cardinality": 12}
                },
                "edge_sets": {
                    "cites": ["paper", "paper"],
                    "written": ["paper", "author"],
                    "writes": ["author", "paper"],
                    "affiliated_with": ["author", "institution"],
                    "has_topic": ["paper", "field_of_study"]
                }
            },
            "sampling": {
                "plan_seed": 42,
                "sizes": {"cites": 3, "written": 2, "writes": 2,
                          "affiliated_with": 2, "has_topic": 2}
            },
            "pad": {
                "node_caps": {"paper": 64, "author": 48, "institution": 16,
                              "field_of_study": 32},
                "edge_caps": {"cites": 48, "written": 48, "writes": 48,
                              "affiliated_with": 48, "has_topic": 64},
                "component_cap": 5
            },
            "model": {
                "type": "mpnn", "hidden_dim": 8, "message_dim": 8,
                "num_layers": 1,
                "updates": {
                    "paper": ["cites", "written", "has_topic"],
                    "author": ["writes", "affiliated_with"]
                }
            },
            "train": {"num_classes": 4, "init_seed": 3, "learning_rate": 0.001,
                      "weight_decay": 0.0, "adam_beta1": 0.9, "adam_beta2": 0.999,
                      "adam_eps": 1e-8, "epochs": 1}
        }"#;
        let cfg = Json::parse(&mutate(base.to_string())).expect("mutated config parses");
        let mut d = Diagnostics::default();
        let plan = ModelPlan::compile(&cfg, &mut d);
        if let Some(p) = &plan {
            shape_pass(p, &mut d);
            dead_set_pass(p, &mut d);
            reachability_pass(p, &mut d);
            param_pass(p, None, &mut d);
        }
        (plan, d)
    }

    #[test]
    fn clean_fixture_is_clean() {
        let (plan, d) = plan_from(|s| s);
        assert!(plan.is_some());
        assert!(d.is_empty(), "{d}");
    }

    #[test]
    fn zero_feature_dim_flagged() {
        let (_, d) = plan_from(|s| s.replace("\"feat\": 16", "\"feat\": 0"));
        let diag = d.find(codes::BAD_DIM).expect("TFGNN005");
        assert_eq!(diag.path, "$.schema.node_sets.paper.features.feat");
    }

    #[test]
    fn zero_cardinality_flagged() {
        let (_, d) = plan_from(|s| s.replace("\"cardinality\": 10", "\"cardinality\": 0"));
        let diag = d.find(codes::BAD_DIM).expect("TFGNN005");
        assert_eq!(diag.path, "$.schema.node_sets.institution.cardinality");
    }

    #[test]
    fn dataset_feature_dim_mismatch_flagged() {
        let (_, d) = plan_from(|s| s.replace("\"feature_dim\": 16", "\"feature_dim\": 32"));
        let diag = d.find(codes::SHAPE_MISMATCH).expect("TFGNN011");
        assert_eq!(diag.path, "$.dataset.feature_dim");
    }

    #[test]
    fn num_classes_mismatch_flagged() {
        let (_, d) = plan_from(|s| {
            s.replace("\"num_classes\": 4, \"init_seed\"", "\"num_classes\": 7, \"init_seed\"")
        });
        let diag = d.find(codes::SHAPE_MISMATCH).expect("TFGNN011");
        assert_eq!(diag.path, "$.train.num_classes");
    }

    #[test]
    fn small_embedding_table_is_an_error_large_a_warning() {
        let (_, d) = plan_from(|s| s.replace("\"cardinality\": 10", "\"cardinality\": 6"));
        let diag = d.find(codes::SHAPE_MISMATCH).expect("TFGNN011");
        assert_eq!(diag.severity, super::super::diag::Severity::Error);
        assert!(diag.message.contains("6 rows"), "{}", diag.message);

        let (_, d) = plan_from(|s| s.replace("\"cardinality\": 10", "\"cardinality\": 30"));
        let diag = d.find(codes::SHAPE_MISMATCH).expect("TFGNN011");
        assert_eq!(diag.severity, super::super::diag::Severity::Warning);
        assert!(d.is_clean(), "oversized tables must not fail the gate:\n{d}");
    }

    #[test]
    fn component_cap_must_hold_the_batch() {
        let (_, d) = plan_from(|s| s.replace("\"component_cap\": 5", "\"component_cap\": 4"));
        let diag = d.find(codes::PAD_SPEC).expect("TFGNN012");
        assert_eq!(diag.path, "$.pad.component_cap");
    }

    #[test]
    fn missing_pad_cap_flagged() {
        let (_, d) = plan_from(|s| s.replace("\"institution\": 16,", ""));
        let diag = d.find(codes::PAD_SPEC).expect("TFGNN012");
        assert_eq!(diag.path, "$.pad.node_caps");
        assert!(diag.message.contains("institution"), "{}", diag.message);
    }

    #[test]
    fn read_but_unsampled_edge_set_is_an_error() {
        // Add a schema edge set the model pools but the Figure-6
        // sampling program never expands.
        let (_, d) = plan_from(|s| {
            s.replace(
                "\"cites\": [\"paper\", \"paper\"],",
                "\"cites\": [\"paper\", \"paper\"],\n\"cocites\": [\"paper\", \"paper\"],",
            )
            .replace(
                "[\"cites\", \"written\", \"has_topic\"]",
                "[\"cites\", \"cocites\", \"written\", \"has_topic\"]",
            )
            .replace(
                "\"edge_caps\": {\"cites\": 48,",
                "\"edge_caps\": {\"cocites\": 8, \"cites\": 48,",
            )
        });
        let diag = d.find(codes::DEAD_SET).expect("TFGNN013");
        assert_eq!(diag.severity, super::super::diag::Severity::Error);
        assert_eq!(diag.path, "$.model.updates.paper");
        assert!(diag.message.contains("cocites"), "{}", diag.message);
    }

    #[test]
    fn sampled_but_unread_edge_set_is_a_warning() {
        let (_, d) = plan_from(|s| {
            s.replace("[\"cites\", \"written\", \"has_topic\"]", "[\"cites\", \"written\"]")
        });
        let diag = d.find(codes::DEAD_SET).expect("TFGNN013");
        assert_eq!(diag.severity, super::super::diag::Severity::Warning);
        assert_eq!(diag.path, "$.sampling.sizes.has_topic");
        assert!(d.is_clean(), "wasted fan-out must not fail the gate:\n{d}");
    }

    #[test]
    fn non_seed_root_set_is_unreachable_readout() {
        let (_, d) = plan_from(|s| {
            s.replace(
                "\"train\":",
                "\"task\": {\"type\": \"root_classification\", \"root_set\": \"institution\"},\n\"train\":",
            )
        });
        let diag = d.find(codes::UNREACHABLE_READOUT).expect("TFGNN014");
        assert_eq!(diag.path, "$.task.root_set");
    }

    #[test]
    fn unknown_root_set_flagged() {
        let (_, d) = plan_from(|s| {
            s.replace(
                "\"train\":",
                "\"task\": {\"type\": \"root_classification\", \"root_set\": \"venue\"},\n\"train\":",
            )
        });
        let diag = d.find(codes::UNKNOWN_NODE_SET).expect("TFGNN008");
        assert_eq!(diag.path, "$.task.root_set");
    }

    #[test]
    fn heterogeneous_link_prediction_edge_set_flagged() {
        let (_, d) = plan_from(|s| {
            s.replace(
                "\"train\":",
                "\"task\": {\"type\": \"link_prediction\", \"edge_set\": \"written\"},\n\"train\":",
            )
        });
        let diag = d.find(codes::BAD_TASK_KNOB).expect("TFGNN006");
        assert_eq!(diag.path, "$.task.edge_set");
        assert!(diag.message.contains("homogeneous"), "{}", diag.message);
    }

    #[test]
    fn checkpoint_mismatches_flagged() {
        let (plan, mut d) = plan_from(|s| s);
        let plan = plan.expect("plan");
        assert!(d.is_empty(), "{d}");
        // A faithful inventory with one dropped, one renamed, and one
        // reshaped parameter.
        let mut ckpt: Vec<(String, HostTensor)> = plan
            .params
            .iter()
            .map(|p| {
                (
                    format!("param.{}", p.name),
                    HostTensor::F32(vec![p.rows, p.cols], vec![0.0; p.rows * p.cols]),
                )
            })
            .collect();
        ckpt.retain(|(n, _)| n != "param.head.b"); // missing
        ckpt.push(("param.l9.ghost.msg.w".into(), HostTensor::F32(vec![1, 1], vec![0.0]))); // stale
        for (n, t) in ckpt.iter_mut() {
            if n == "param.head.w" {
                *t = HostTensor::F32(vec![8, 9], vec![0.0; 72]); // reshaped
            }
        }
        ckpt.push(("step".into(), HostTensor::I64(vec![1], vec![5]))); // ignored
        ckpt.push(("adam_m.head.w".into(), HostTensor::F32(vec![8, 4], vec![0.0; 32]))); // ignored
        param_pass(&plan, Some(&ckpt), &mut d);
        let msgs: Vec<&str> = d
            .iter()
            .filter(|x| x.code == codes::CHECKPOINT_MISMATCH)
            .map(|x| x.message.as_str())
            .collect();
        assert_eq!(msgs.len(), 3, "{msgs:?}");
        assert!(msgs.iter().any(|m| m.contains("missing parameter \"head.b\"")), "{msgs:?}");
        assert!(msgs.iter().any(|m| m.contains("stale parameter \"l9.ghost.msg.w\"")), "{msgs:?}");
        assert!(msgs.iter().any(|m| m.contains("\"head.w\" has shape [8, 9]")), "{msgs:?}");
    }

    #[test]
    fn matching_checkpoint_is_clean() {
        let (plan, mut d) = plan_from(|s| s);
        let plan = plan.expect("plan");
        let ckpt: Vec<(String, HostTensor)> = plan
            .params
            .iter()
            .map(|p| {
                (
                    format!("param.{}", p.name),
                    HostTensor::F32(vec![p.rows, p.cols], vec![0.0; p.rows * p.cols]),
                )
            })
            .collect();
        param_pass(&plan, Some(&ckpt), &mut d);
        assert!(d.is_empty(), "{d}");
    }
}
