//! The typed plan IR: what a config *would* build, without building it.
//!
//! [`ModelPlan::compile`] runs the same funnels the engine itself runs
//! — [`ModelConfig::from_config`], [`ModelBuilder::from_config`],
//! [`head_params`], [`mag_sampling_spec_sized`] — but instead of
//! tensors it produces a symbolic description: per-node-set feature
//! widths, per-edge-set endpoints, the per-layer convolution
//! applications with their inferred input/output widths, the full
//! expected parameter table (name → shape, exactly the names
//! [`NativeModel::init`](crate::train::native::NativeModel::init)
//! would create, in the same order), and the sampling plan's
//! edge-set/node-set coverage. The passes in [`super::passes`] then
//! check this IR without ever touching graph data.

use std::collections::BTreeMap;

use super::diag::{codes, Diagnostic, Diagnostics};
use crate::layers::{ConvDims, ModelBuilder};
use crate::ops::model_ref::ModelConfig;
use crate::sampler::spec::mag_sampling_spec_sized;
use crate::schema::{EdgeSetSpec, GraphSchema, Metadata, NodeSetSpec};
use crate::util::json::Json;

/// One node set's symbolic shape: its dense feature widths and/or its
/// id-embedding cardinality.
#[derive(Debug, Clone)]
pub struct NodePlan {
    pub name: String,
    /// (feature name, per-item dim), in encoder order.
    pub features: Vec<(String, usize)>,
    pub id_embedding: bool,
    pub cardinality: Option<usize>,
}

/// One edge set's endpoints (source = receiver under the
/// rooted-subgraph convention).
#[derive(Debug, Clone)]
pub struct EdgePlan {
    pub name: String,
    pub source: String,
    pub target: String,
}

/// One convolution application of the unrolled layer stack, with its
/// inferred widths — the forward shape-inference record.
#[derive(Debug, Clone)]
pub struct ConvPlan {
    pub layer: usize,
    /// The updated (receiving) node set.
    pub node_set: String,
    pub edge_set: String,
    /// Node-state width entering the convolution.
    pub in_dim: usize,
    /// Convolution output width (what the next-state MLP concatenates).
    pub out_dim: usize,
}

/// One expected parameter tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamPlan {
    pub name: String,
    pub rows: usize,
    pub cols: usize,
}

/// One sampling op of the derived plan.
#[derive(Debug, Clone)]
pub struct SampleStep {
    pub edge_set: String,
    pub size: usize,
    /// Node set the op produces (the edge set's target endpoint).
    pub produced: String,
}

/// The derived sampling plan's coverage.
#[derive(Debug, Clone)]
pub struct SamplePlan {
    pub seed_node_set: String,
    pub steps: Vec<SampleStep>,
}

impl SamplePlan {
    /// Edge sets the plan expands through.
    pub fn sampled_edge_sets(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.steps.iter().map(|s| s.edge_set.as_str()).collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Node sets reachable from the seeds under the plan.
    pub fn reachable_node_sets(&self) -> Vec<&str> {
        let mut v: Vec<&str> = vec![self.seed_node_set.as_str()];
        for s in &self.steps {
            v.push(s.produced.as_str());
        }
        v.sort_unstable();
        v.dedup();
        v
    }
}

/// The padding contract of the config.
#[derive(Debug, Clone)]
pub struct PadPlan {
    pub node_caps: BTreeMap<String, usize>,
    pub edge_caps: BTreeMap<String, usize>,
    pub component_cap: usize,
}

/// What the synthetic dataset block promises (the cross-check targets
/// for the schema's widths).
#[derive(Debug, Clone, Default)]
pub struct DatasetPlan {
    pub feature_dim: Option<usize>,
    pub num_classes: Option<usize>,
    pub num_institutions: Option<usize>,
    pub num_fields: Option<usize>,
}

/// Keys every `dataset` block must carry (the synth generator's full
/// parameter vocabulary — `Manifest::mag_config` requires all of them
/// at run time, so their absence is a config error now, not later).
const DATASET_KEYS: &[&str] = &[
    "num_papers",
    "num_authors",
    "num_institutions",
    "num_fields",
    "num_classes",
    "num_communities",
    "feature_dim",
    "mean_citations",
    "mean_authors_per_paper",
    "mean_topics",
    "community_coherence",
    "label_coherence",
    "feature_noise",
    "year_min",
    "year_max",
    "seed",
];

/// The compiled plan.
#[derive(Debug, Clone)]
pub struct ModelPlan {
    pub cfg: ModelConfig,
    pub nodes: Vec<NodePlan>,
    pub edges: Vec<EdgePlan>,
    pub convs: Vec<ConvPlan>,
    /// The full expected parameter table, in creation order — name for
    /// name and shape for shape what `NativeModel::init` would build.
    pub params: Vec<ParamPlan>,
    pub sample: Option<SamplePlan>,
    pub pad: Option<PadPlan>,
    pub dataset: Option<DatasetPlan>,
    pub batch_size: Option<usize>,
}

impl ModelPlan {
    /// Compile a raw run-config document into the plan IR, collecting
    /// diagnostics along the way. Returns `None` when the config is too
    /// broken to plan at all (the collected diagnostics say why).
    pub fn compile(cfg: &Json, d: &mut Diagnostics) -> Option<ModelPlan> {
        ModelPlan::compile_inner(cfg, d, true)
    }

    /// `require_pipeline` demands the run-pipeline blocks (`sampling`,
    /// `pad`, `dataset`, `batch_size`) on top of the model-level ones —
    /// true for run configs, false for serve-time model checks.
    fn compile_inner(cfg: &Json, d: &mut Diagnostics, require_pipeline: bool) -> Option<ModelPlan> {
        if cfg.as_obj().is_err() {
            d.push(Diagnostic::error(codes::CONFIG, "$", "config document is not a JSON object"));
            return None;
        }
        let mut missing = false;
        for key in ["schema", "model", "train"] {
            if cfg.opt(key).is_none() {
                d.push(Diagnostic::error(
                    codes::CONFIG,
                    format!("$.{key}"),
                    format!("config is missing its {key:?} block"),
                ));
                missing = true;
            }
        }
        if missing {
            return None;
        }
        let mc = match ModelConfig::from_config(cfg) {
            Ok(mc) => mc,
            Err(e) => {
                d.push(Diagnostic::from_error(&e));
                return None;
            }
        };
        let builder = match ModelBuilder::from_config(&mc) {
            Ok(b) => b,
            Err(e) => {
                d.push(Diagnostic::from_error(&e));
                return None;
            }
        };
        let conv = builder.conv();
        let dims = ConvDims { hidden: mc.hidden, message: mc.message, att: mc.att_dim };

        let nodes: Vec<NodePlan> = mc
            .node_order
            .iter()
            .map(|set| NodePlan {
                name: set.clone(),
                features: mc.features[set]
                    .iter()
                    .map(|f| {
                        (f.clone(), mc.feature_dims[set].get(f).copied().unwrap_or(0))
                    })
                    .collect(),
                id_embedding: mc.id_embedding.get(set).copied().unwrap_or(false),
                cardinality: mc.cardinality.get(set).copied(),
            })
            .collect();
        let edges: Vec<EdgePlan> = mc
            .edge_endpoints
            .iter()
            .map(|(name, (source, target))| EdgePlan {
                name: name.clone(),
                source: source.clone(),
                target: target.clone(),
            })
            .collect();
        let mut endpoints_ok = true;
        for e in &edges {
            for (role, set) in [("source", &e.source), ("target", &e.target)] {
                if !mc.node_order.contains(set) {
                    d.push(Diagnostic::error(
                        codes::UNKNOWN_NODE_SET,
                        format!("$.schema.edge_sets.{}", e.name),
                        format!(
                            "edge set {:?} {role} references unknown node set {set:?}",
                            e.name
                        ),
                    ));
                    endpoints_ok = false;
                }
            }
        }

        // Per-layer shape inference: every convolution reads `hidden`
        // and emits `out_dim`; the next-state MLP consumes
        // `hidden + Σ out_dim` back down to `hidden` — exactly the
        // width chain `NativeModel::init` bakes into its shapes.
        let mut convs = Vec::new();
        let mut params = Vec::new();
        for node in &nodes {
            if !node.features.is_empty() {
                for (fname, dim) in &node.features {
                    params.push(ParamPlan {
                        name: format!("enc.{}.{fname}.w", node.name),
                        rows: *dim,
                        cols: mc.hidden,
                    });
                }
                params.push(ParamPlan {
                    name: format!("enc.{}.{}.b", node.name, node.features[0].0),
                    rows: 1,
                    cols: mc.hidden,
                });
            } else if node.id_embedding {
                if let Some(card) = node.cardinality {
                    params.push(ParamPlan {
                        name: format!("emb.{}", node.name),
                        rows: card,
                        cols: mc.hidden,
                    });
                }
                // A missing cardinality is the shape pass's diagnostic.
            }
        }
        for layer in 0..mc.layers {
            for (node_set, edge_list) in &mc.updates {
                let mut edge_names: Vec<&String> = edge_list.iter().collect();
                edge_names.sort();
                for es in &edge_names {
                    convs.push(ConvPlan {
                        layer,
                        node_set: node_set.clone(),
                        edge_set: (*es).clone(),
                        in_dim: mc.hidden,
                        out_dim: conv.out_dim(dims),
                    });
                    for shape in conv.param_shapes(dims) {
                        params.push(ParamPlan {
                            name: format!("l{layer}.{node_set}.{es}.{}", shape.suffix),
                            rows: shape.rows,
                            cols: shape.cols,
                        });
                    }
                }
                let in_dim = mc.hidden + edge_names.len() * conv.out_dim(dims);
                params.push(ParamPlan {
                    name: format!("l{layer}.{node_set}.next.w"),
                    rows: in_dim,
                    cols: mc.hidden,
                });
                params.push(ParamPlan {
                    name: format!("l{layer}.{node_set}.next.b"),
                    rows: 1,
                    cols: mc.hidden,
                });
            }
        }
        match crate::tasks::head_params(&mc) {
            Ok(head) => {
                for hp in head {
                    params.push(ParamPlan {
                        name: hp.name.to_string(),
                        rows: hp.rows,
                        cols: hp.cols,
                    });
                }
            }
            Err(e) => d.push(Diagnostic::from_error(&e)),
        }

        if !require_pipeline {
            return Some(ModelPlan {
                cfg: mc,
                nodes,
                edges,
                convs,
                params,
                sample: None,
                pad: None,
                dataset: None,
                batch_size: None,
            });
        }
        let sample = if endpoints_ok {
            derive_sample_plan(cfg, &mc, d)
        } else {
            None
        };
        let pad = compile_pad(cfg, d);
        let dataset = compile_dataset(cfg, d);
        let batch_size = match cfg.opt("batch_size") {
            Some(v) => match v.as_usize() {
                Ok(0) => {
                    d.push(Diagnostic::error(
                        codes::BAD_DIM,
                        "$.batch_size",
                        "batch_size is 0",
                    ));
                    None
                }
                Ok(b) => Some(b),
                Err(_) => {
                    d.push(Diagnostic::error(
                        codes::CONFIG,
                        "$.batch_size",
                        "batch_size must be a positive integer",
                    ));
                    None
                }
            },
            None => {
                d.push(Diagnostic::error(
                    codes::CONFIG,
                    "$.batch_size",
                    "config is missing batch_size",
                ));
                None
            }
        };

        Some(ModelPlan { cfg: mc, nodes, edges, convs, params, sample, pad, dataset, batch_size })
    }

    /// Plan IR for an already-parsed [`ModelConfig`] — the raw document
    /// is gone by serve time, so this compiles the model-level subset
    /// (no sampling/pad/dataset cross-checks).
    pub fn compile_model_only(mc: &ModelConfig, d: &mut Diagnostics) -> Option<ModelPlan> {
        let doc = model_config_as_json(mc);
        ModelPlan::compile_inner(&doc, d, false)
    }
}

/// Re-render a parsed [`ModelConfig`] as a minimal config document so
/// the one compile path serves both entry points. Sampling, pad and
/// dataset blocks are absent on purpose: serve-time checks are
/// model-level only.
fn model_config_as_json(mc: &ModelConfig) -> Json {
    use crate::util::json::obj;
    let mut node_sets = BTreeMap::new();
    for set in &mc.node_order {
        let mut m = BTreeMap::new();
        let dims = &mc.feature_dims[set];
        if !dims.is_empty() {
            m.insert(
                "features".to_string(),
                Json::Obj(
                    dims.iter().map(|(k, v)| (k.clone(), Json::Int(*v as i64))).collect(),
                ),
            );
        }
        if mc.id_embedding.get(set).copied().unwrap_or(false) {
            m.insert("id_embedding".to_string(), Json::Bool(true));
        }
        if let Some(c) = mc.cardinality.get(set) {
            m.insert("cardinality".to_string(), Json::Int(*c as i64));
        }
        node_sets.insert(set.clone(), Json::Obj(m));
    }
    let edge_sets: BTreeMap<String, Json> = mc
        .edge_endpoints
        .iter()
        .map(|(k, (s, t))| {
            (k.clone(), Json::Arr(vec![Json::Str(s.clone()), Json::Str(t.clone())]))
        })
        .collect();
    let updates: BTreeMap<String, Json> = mc
        .updates
        .iter()
        .map(|(k, v)| {
            (k.clone(), Json::Arr(v.iter().map(|e| Json::Str(e.clone())).collect()))
        })
        .collect();
    let t = &mc.task;
    obj(vec![
        (
            "schema",
            obj(vec![
                ("node_sets", Json::Obj(node_sets)),
                ("edge_sets", Json::Obj(edge_sets)),
            ]),
        ),
        (
            "model",
            obj(vec![
                ("type", Json::Str(mc.arch.clone())),
                ("hidden_dim", Json::Int(mc.hidden as i64)),
                ("message_dim", Json::Int(mc.message as i64)),
                ("att_dim", Json::Int(mc.att_dim as i64)),
                ("sage_reduce", Json::Str(mc.sage_reduce.clone())),
                ("num_layers", Json::Int(mc.layers as i64)),
                ("updates", Json::Obj(updates)),
            ]),
        ),
        ("train", obj(vec![("num_classes", Json::Int(mc.num_classes as i64))])),
        (
            "task",
            obj(vec![
                ("type", Json::Str(t.kind.clone())),
                ("root_set", Json::Str(t.root_set.clone())),
                ("label_feature", Json::Str(t.label_feature.clone())),
                ("edge_set", Json::Str(t.edge_set.clone())),
                ("readout", Json::Str(t.readout.clone())),
                ("loss", Json::Str(t.loss.clone())),
                ("margin", Json::Num(t.margin as f64)),
                ("negatives", Json::Int(t.negatives as i64)),
                ("hits_k", Json::Int(t.hits_k as i64)),
                ("holdout_fraction", Json::Num(t.holdout_fraction)),
                ("split_seed", Json::Int(t.split_seed as i64)),
                ("mlp_dim", Json::Int(t.mlp_dim as i64)),
                ("target_feature", Json::Str(t.target_feature.clone())),
                ("target_shift", Json::Num(t.target_shift as f64)),
                ("target_scale", Json::Num(t.target_scale as f64)),
            ]),
        ),
        ("batch_size", Json::Int(1)),
    ])
}

/// Derive the sampling plan the runner would build: the Figure-6
/// program over a minimal schema, sized by `$.sampling.sizes` — the
/// exact derivation `run_native` performs, so a failure here is a
/// failure there.
fn derive_sample_plan(cfg: &Json, mc: &ModelConfig, d: &mut Diagnostics) -> Option<SamplePlan> {
    let Some(sampling) = cfg.opt("sampling") else {
        d.push(Diagnostic::error(
            codes::CONFIG,
            "$.sampling",
            "config is missing its \"sampling\" block",
        ));
        return None;
    };
    let Some(sizes_json) = sampling.opt("sizes") else {
        d.push(Diagnostic::error(
            codes::CONFIG,
            "$.sampling.sizes",
            "sampling block is missing its \"sizes\" map",
        ));
        return None;
    };
    let Ok(sizes_obj) = sizes_json.as_obj() else {
        d.push(Diagnostic::error(
            codes::CONFIG,
            "$.sampling.sizes",
            "sampling.sizes must be an object of per-edge-set fan-outs",
        ));
        return None;
    };
    let mut sizes: BTreeMap<String, usize> = BTreeMap::new();
    let mut bad = false;
    for (es, v) in sizes_obj {
        let path = format!("$.sampling.sizes.{es}");
        match v.as_usize() {
            Ok(0) => {
                d.push(Diagnostic::error(
                    codes::SAMPLING_SPEC,
                    path,
                    format!("sampling size for edge set {es:?} is 0"),
                ));
                bad = true;
            }
            Ok(k) => {
                if !mc.edge_endpoints.contains_key(es) {
                    d.push(Diagnostic::warning(
                        codes::SAMPLING_SPEC,
                        path,
                        format!("sampling size for edge set {es:?} not in the schema"),
                    ));
                }
                sizes.insert(es.clone(), k);
            }
            Err(_) => {
                d.push(Diagnostic::error(
                    codes::SAMPLING_SPEC,
                    path,
                    format!("sampling size for edge set {es:?} must be a positive integer"),
                ));
                bad = true;
            }
        }
    }
    if bad {
        return None;
    }
    // A minimal schema: just enough structure for spec derivation.
    let mut schema = GraphSchema::default();
    for set in &mc.node_order {
        schema = schema.with_node_set(set, NodeSetSpec::default());
    }
    for (name, (source, target)) in &mc.edge_endpoints {
        schema = schema.with_edge_set(
            name,
            EdgeSetSpec {
                source: source.clone(),
                target: target.clone(),
                features: BTreeMap::new(),
                metadata: Metadata::default(),
            },
        );
    }
    match mag_sampling_spec_sized(&schema, &sizes) {
        Ok(spec) => {
            let steps = spec
                .ops
                .iter()
                .map(|op| {
                    let produced = schema
                        .edge_sets
                        .get(&op.edge_set)
                        .map(|e| e.target.clone())
                        .unwrap_or_default();
                    SampleStep { edge_set: op.edge_set.clone(), size: op.sample_size, produced }
                })
                .collect();
            Some(SamplePlan { seed_node_set: spec.seed_node_set, steps })
        }
        Err(e) => {
            d.push(
                Diagnostic::error(
                    codes::SAMPLING_SPEC,
                    "$.sampling.sizes",
                    format!("sampling plan does not compose over this schema: {e}"),
                )
                .with_hint(
                    "the runner derives the paper's Figure-6 program (seed paper, \
                     expand cites/written/writes/affiliated_with/has_topic); every \
                     edge set it expands needs a fan-out size and matching endpoints",
                ),
            );
            None
        }
    }
}

fn compile_pad(cfg: &Json, d: &mut Diagnostics) -> Option<PadPlan> {
    let Some(pad) = cfg.opt("pad") else {
        d.push(Diagnostic::error(
            codes::CONFIG,
            "$.pad",
            "config is missing its \"pad\" block",
        ));
        return None;
    };
    let caps = |key: &str, d: &mut Diagnostics| -> Option<BTreeMap<String, usize>> {
        let path = format!("$.pad.{key}");
        match pad.opt(key) {
            None => {
                d.push(Diagnostic::error(
                    codes::PAD_SPEC,
                    path,
                    format!("pad block is missing {key:?}"),
                ));
                None
            }
            Some(v) => match v.as_obj() {
                Ok(m) => {
                    let mut out = BTreeMap::new();
                    for (set, cap) in m {
                        match cap.as_usize() {
                            Ok(c) => {
                                out.insert(set.clone(), c);
                            }
                            Err(_) => d.push(Diagnostic::error(
                                codes::PAD_SPEC,
                                format!("{path}.{set}"),
                                format!("pad cap for {set:?} must be a non-negative integer"),
                            )),
                        }
                    }
                    Some(out)
                }
                Err(_) => {
                    d.push(Diagnostic::error(
                        codes::PAD_SPEC,
                        path,
                        format!("pad.{key} must be an object of per-set caps"),
                    ));
                    None
                }
            },
        }
    };
    let node_caps = caps("node_caps", d);
    let edge_caps = caps("edge_caps", d);
    let component_cap = match pad.opt("component_cap").map(|v| v.as_usize()) {
        Some(Ok(c)) => Some(c),
        Some(Err(_)) => {
            d.push(Diagnostic::error(
                codes::PAD_SPEC,
                "$.pad.component_cap",
                "pad.component_cap must be a positive integer",
            ));
            None
        }
        None => {
            d.push(Diagnostic::error(
                codes::PAD_SPEC,
                "$.pad.component_cap",
                "pad block is missing \"component_cap\"",
            ));
            None
        }
    };
    Some(PadPlan {
        node_caps: node_caps?,
        edge_caps: edge_caps?,
        component_cap: component_cap?,
    })
}

fn compile_dataset(cfg: &Json, d: &mut Diagnostics) -> Option<DatasetPlan> {
    let Some(ds) = cfg.opt("dataset") else {
        d.push(Diagnostic::error(
            codes::CONFIG,
            "$.dataset",
            "config is missing its \"dataset\" block",
        ));
        return None;
    };
    for key in DATASET_KEYS {
        if ds.opt(key).is_none() {
            d.push(Diagnostic::error(
                codes::CONFIG,
                format!("$.dataset.{key}"),
                format!("dataset block is missing {key:?}"),
            ));
        }
    }
    let u = |key: &str| ds.opt(key).and_then(|v| v.as_usize().ok());
    Some(DatasetPlan {
        feature_dim: u("feature_dim"),
        num_classes: u("num_classes"),
        num_institutions: u("num_institutions"),
        num_fields: u("num_fields"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::train::native::NativeModel;

    fn shipped_like_config() -> Json {
        // Structurally identical to configs/mag_small.json, tiny dims.
        Json::parse(
            r#"{
            "name": "plan_test", "batch_size": 4,
            "dataset": {
                "num_papers": 80, "num_authors": 60, "num_institutions": 10,
                "num_fields": 12, "num_classes": 4, "num_communities": 4,
                "feature_dim": 16, "mean_citations": 3.0,
                "mean_authors_per_paper": 2.0, "mean_topics": 2.0,
                "community_coherence": 0.9, "label_coherence": 0.9,
                "feature_noise": 0.5, "year_min": 2010, "year_max": 2014,
                "seed": 7
            },
            "schema": {
                "node_sets": {
                    "paper": {"features": {"feat": 16}},
                    "author": {},
                    "institution": {"id_embedding": true, "cardinality": 10},
                    "field_of_study": {"id_embedding": true, "cardinality": 12}
                },
                "edge_sets": {
                    "cites": ["paper", "paper"],
                    "written": ["paper", "author"],
                    "writes": ["author", "paper"],
                    "affiliated_with": ["author", "institution"],
                    "has_topic": ["paper", "field_of_study"]
                }
            },
            "sampling": {
                "plan_seed": 42,
                "sizes": {"cites": 3, "written": 2, "writes": 2,
                          "affiliated_with": 2, "has_topic": 2}
            },
            "pad": {
                "node_caps": {"paper": 64, "author": 48, "institution": 16,
                              "field_of_study": 32},
                "edge_caps": {"cites": 48, "written": 48, "writes": 48,
                              "affiliated_with": 48, "has_topic": 64},
                "component_cap": 5
            },
            "model": {
                "type": "mpnn", "hidden_dim": 8, "message_dim": 8,
                "num_layers": 2,
                "updates": {
                    "paper": ["cites", "written", "has_topic"],
                    "author": ["writes", "affiliated_with"]
                }
            },
            "train": {"num_classes": 4, "init_seed": 3, "learning_rate": 0.001,
                      "weight_decay": 0.0, "adam_beta1": 0.9, "adam_beta2": 0.999,
                      "adam_eps": 1e-8, "epochs": 1}
        }"#,
        )
        .expect("test config parses")
    }

    #[test]
    fn clean_config_compiles_without_diagnostics() {
        let mut d = Diagnostics::default();
        let plan = ModelPlan::compile(&shipped_like_config(), &mut d);
        assert!(d.is_empty(), "unexpected diagnostics:\n{d}");
        let plan = plan.expect("plan");
        assert_eq!(plan.batch_size, Some(4));
        assert_eq!(plan.pad.as_ref().map(|p| p.component_cap), Some(5));
        let sample = plan.sample.as_ref().expect("sample plan");
        assert_eq!(sample.seed_node_set, "paper");
        assert_eq!(
            sample.sampled_edge_sets(),
            vec!["affiliated_with", "cites", "has_topic", "writes", "written"]
        );
        assert_eq!(
            sample.reachable_node_sets(),
            vec!["author", "field_of_study", "institution", "paper"]
        );
        // 2 layers × (paper: 3 convs + author: 2 convs) applications.
        assert_eq!(plan.convs.len(), 10);
        assert!(plan.convs.iter().all(|c| c.in_dim == 8 && c.out_dim == 8));
    }

    #[test]
    fn param_table_matches_native_model_init_exactly() {
        let cfg = shipped_like_config();
        let mut d = Diagnostics::default();
        let plan = ModelPlan::compile(&cfg, &mut d).expect("plan");
        assert!(d.is_empty(), "{d}");
        let model = NativeModel::init(ModelConfig::from_config(&cfg).expect("cfg"), 3)
            .expect("model");
        let expected: Vec<ParamPlan> = model
            .names
            .iter()
            .zip(&model.params)
            .map(|(n, p)| ParamPlan { name: n.clone(), rows: p.rows, cols: p.cols })
            .collect();
        assert_eq!(plan.params, expected);
    }

    #[test]
    fn model_only_compile_covers_the_zoo() {
        let mc = ModelConfig::for_mag(&crate::synth::mag::MagConfig::tiny(), 8, 8, 1);
        for arch in ["mpnn", "gcn", "sage", "gatv2"] {
            let mc = mc.clone().with_arch(arch);
            let mut d = Diagnostics::default();
            let plan = ModelPlan::compile_model_only(&mc, &mut d);
            assert!(d.is_empty(), "{arch}:\n{d}");
            let plan = plan.expect("plan");
            let model = NativeModel::init(mc, 3).expect("model");
            assert_eq!(
                plan.params.iter().map(|p| p.name.as_str()).collect::<Vec<_>>(),
                model.names.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
                "{arch}"
            );
        }
    }

    #[test]
    fn missing_blocks_are_config_errors() {
        let mut d = Diagnostics::default();
        assert!(ModelPlan::compile(&Json::parse("{}").expect("json"), &mut d).is_none());
        assert!(d.find(codes::CONFIG).is_some());
        assert!(d.has_errors());
        let paths: Vec<&str> = d.iter().map(|x| x.path.as_str()).collect();
        assert!(paths.contains(&"$.schema"), "{paths:?}");
        assert!(paths.contains(&"$.model"), "{paths:?}");
        assert!(paths.contains(&"$.train"), "{paths:?}");
    }

    #[test]
    fn dangling_endpoint_is_unknown_node_set() {
        let text = shipped_like_config()
            .to_string()
            .replace("\"written\":[\"paper\",\"author\"]", "\"written\":[\"paper\",\"reviewer\"]");
        let cfg = Json::parse(&text).expect("json");
        let mut d = Diagnostics::default();
        let _ = ModelPlan::compile(&cfg, &mut d);
        let diag = d.find(codes::UNKNOWN_NODE_SET).expect("TFGNN008");
        assert_eq!(diag.path, "$.schema.edge_sets.written");
        assert!(diag.message.contains("reviewer"), "{}", diag.message);
    }
}
