//! # tfgnn-rs — TF-GNN reproduced as a Rust + JAX + Pallas pipeline
//!
//! Reproduction of *"TF-GNN: Graph Neural Networks in TensorFlow"*
//! (Ferludin et al., 2022) as a three-layer system:
//!
//! * **Layer 3 (this crate)** — the heterogeneous graph data model
//!   ([`schema`], [`graph`]), data-exchange ops ([`ops`]), the
//!   composable GraphUpdate layer zoo ([`layers`]), the multi-objective
//!   task heads ([`tasks`]), the sharded graph store ([`store`]),
//!   rooted-subgraph sampling ([`sampler`], [`coordinator`]), the
//!   streaming input pipeline ([`pipeline`]), the AOT runtime
//!   ([`runtime`]), training ([`train`]), orchestration ([`runner`]),
//!   inference serving ([`serve`]), the static model-plan analyzer
//!   ([`analysis`], the `tfgnn check` subcommand) and the unified
//!   observability layer ([`obs`]: metrics registry, tracing spans,
//!   `tfgnn stats`).
//! * **Layer 2** — the heterogeneous GNN models (MPNN, GCN, R-GCN,
//!   GraphSAGE, GATv2, MultiHeadAttention, HGT baseline) written in JAX
//!   under `python/compile/`, lowered once to HLO text.
//! * **Layer 1** — Pallas kernels for the message-passing hot spot
//!   (`python/compile/kernels/`), verified against a pure-jnp oracle.
//!
//! Python never runs on the training or serving path: `make artifacts`
//! lowers the numeric programs once, and the Rust binary is
//! self-contained afterwards.
//!
//! See `DESIGN.md` for the paper → module inventory and the experiment
//! index, and `EXPERIMENTS.md` for reproduced results.

pub mod analysis;
pub mod coordinator;
pub mod graph;
pub mod layers;
pub mod obs;
pub mod ops;
pub mod pipeline;
pub mod runner;
pub mod runtime;
pub mod sampler;
pub mod schema;
pub mod serve;
pub mod store;
pub mod synth;
pub mod tasks;
pub mod train;
pub mod util;

/// Crate-wide error type (hand-rolled `Display`/`Error` impls — the
/// image is offline, so proc-macro crates like `thiserror` are out).
#[derive(Debug)]
pub enum Error {
    /// Schema validation or lookup failure.
    Schema(String),
    /// GraphTensor structural invariant violated.
    Graph(String),
    /// Feature missing / wrong dtype / wrong shape.
    Feature(String),
    /// Sampling plan or execution failure.
    Sampler(String),
    /// Input pipeline failure.
    Pipeline(String),
    /// AOT artifact / PJRT runtime failure.
    Runtime(String),
    /// (De)serialization failure.
    Codec(String),
    /// I/O failure.
    Io(std::io::Error),
    /// XLA/PJRT failure.
    Xla(String),
    /// Admission control: the serving queue is full and the request
    /// was rejected instead of queued (see `serve::batcher`). Clients
    /// should back off and retry.
    Overloaded(String),
    /// The request's deadline passed before a lane executed it; the
    /// request was answered without ever reaching the model (see
    /// `serve::ServeConfig::default_deadline_ms`).
    DeadlineExceeded(String),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Schema(m) => write!(f, "schema error: {m}"),
            Error::Graph(m) => write!(f, "graph error: {m}"),
            Error::Feature(m) => write!(f, "feature error: {m}"),
            Error::Sampler(m) => write!(f, "sampler error: {m}"),
            Error::Pipeline(m) => write!(f, "pipeline error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Codec(m) => write!(f, "codec error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Xla(m) => write!(f, "xla error: {m}"),
            Error::Overloaded(m) => write!(f, "overloaded: {m}"),
            Error::DeadlineExceeded(m) => write!(f, "deadline exceeded: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result type.
pub type Result<T> = std::result::Result<T, Error>;
