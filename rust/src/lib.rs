//! # tfgnn-rs — TF-GNN reproduced as a Rust + JAX + Pallas pipeline
//!
//! Reproduction of *"TF-GNN: Graph Neural Networks in TensorFlow"*
//! (Ferludin et al., 2022) as a three-layer system:
//!
//! * **Layer 3 (this crate)** — the heterogeneous graph data model
//!   ([`schema`], [`graph`]), data-exchange ops ([`ops`]), the sharded
//!   graph store ([`store`]), rooted-subgraph sampling ([`sampler`],
//!   [`coordinator`]), the streaming input pipeline ([`pipeline`]), the
//!   AOT runtime ([`runtime`]), training ([`train`]), orchestration
//!   ([`runner`]) and inference serving ([`serve`]).
//! * **Layer 2** — the heterogeneous GNN models (MPNN, GCN, R-GCN,
//!   GraphSAGE, GATv2, MultiHeadAttention, HGT baseline) written in JAX
//!   under `python/compile/`, lowered once to HLO text.
//! * **Layer 1** — Pallas kernels for the message-passing hot spot
//!   (`python/compile/kernels/`), verified against a pure-jnp oracle.
//!
//! Python never runs on the training or serving path: `make artifacts`
//! lowers the numeric programs once, and the Rust binary is
//! self-contained afterwards.
//!
//! See `DESIGN.md` for the paper → module inventory and the experiment
//! index, and `EXPERIMENTS.md` for reproduced results.

pub mod coordinator;
pub mod graph;
pub mod ops;
pub mod pipeline;
pub mod runner;
pub mod runtime;
pub mod sampler;
pub mod schema;
pub mod serve;
pub mod store;
pub mod synth;
pub mod train;
pub mod util;

/// Crate-wide error type.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// Schema validation or lookup failure.
    #[error("schema error: {0}")]
    Schema(String),
    /// GraphTensor structural invariant violated.
    #[error("graph error: {0}")]
    Graph(String),
    /// Feature missing / wrong dtype / wrong shape.
    #[error("feature error: {0}")]
    Feature(String),
    /// Sampling plan or execution failure.
    #[error("sampler error: {0}")]
    Sampler(String),
    /// Input pipeline failure.
    #[error("pipeline error: {0}")]
    Pipeline(String),
    /// AOT artifact / PJRT runtime failure.
    #[error("runtime error: {0}")]
    Runtime(String),
    /// (De)serialization failure.
    #[error("codec error: {0}")]
    Codec(String),
    /// I/O failure.
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
    /// XLA/PJRT failure.
    #[error("xla error: {0}")]
    Xla(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result type.
pub type Result<T> = std::result::Result<T, Error>;
