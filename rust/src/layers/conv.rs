//! The convolution zoo: four interchangeable [`Convolution`]
//! implementations over one edge set.
//!
//! Every convolution keeps the module-level contract (see
//! [`crate::layers`]): `forward` runs the fused kernels of
//! [`crate::ops::fused`] with no `[num_edges, …]` intermediates where
//! the architecture allows, `forward_tape` re-expresses the same math
//! as the staged op sequence the VJP rules of
//! [`crate::train::native::grad`] invert — and the two are bit-for-bit
//! identical (property-tested below across random graphs, including
//! isolated receivers and self-loop edge sets).

use crate::graph::Feature;
use crate::ops::model_ref::{edge_conv_fused, edge_conv_tape, Mat};
use crate::ops::{broadcast_pool_fused, softmax_weighted_pool_fused, Reduce, Tag};
use crate::train::native::grad;
use crate::{Error, Result};

use super::{row_mat, ConvCtx, ConvInputs, ConvSaved, Convolution, ParamShape};

/// Wrap node-level state as the dense feature the fused kernels eat.
fn state_feature(h: &Mat) -> Feature {
    Feature::f32_mat(h.cols, h.data.clone())
}

/// Unwrap a fused kernel's output back into a row-major matrix.
fn feature_to_mat(f: Feature, rows: usize, cols: usize) -> Result<Mat> {
    let Feature::F32 { data, .. } = f else {
        return Err(Error::Feature("fused kernel returned a non-f32 feature".into()));
    };
    debug_assert_eq!(data.len(), rows * cols);
    Ok(Mat { rows, cols, data })
}

fn saved_mismatch(conv: &str) -> Error {
    Error::Runtime(format!("{conv} backward fed another convolution's tape entry"))
}

/// Split a two-part `concat_cols_vjp` result without panicking.
fn two_parts(parts: Vec<Mat>) -> Result<(Mat, Mat)> {
    let mut it = parts.into_iter();
    match (it.next(), it.next()) {
        (Some(a), Some(b)) => Ok((a, b)),
        _ => Err(Error::Runtime("concat VJP did not produce two parts".into())),
    }
}

/// The original architecture as a registered convolution: per-edge
/// message MLP `relu(W·[sender ‖ receiver] + b)`, sum-pooled to the
/// receiver. Parameter names (`msg.w` / `msg.b`) and both forward
/// paths are exactly the pre-refactor model's, so an mpnn stack built
/// from this conv reproduces the AOT bit-level reference bit-for-bit.
pub struct MpnnConv;

impl Convolution for MpnnConv {
    fn name(&self) -> &'static str {
        "mpnn"
    }

    fn param_shapes(&self, d: super::ConvDims) -> Vec<ParamShape> {
        vec![
            ParamShape::weight("msg.w", 2 * d.hidden, d.message),
            ParamShape::bias("msg.b", d.message),
        ]
    }

    fn forward(&self, x: &ConvInputs, p: &[&Mat]) -> Result<Mat> {
        Ok(edge_conv_fused(
            x.sender_h,
            x.receiver_h,
            &x.ctx.sidx,
            &x.ctx.ridx,
            p[0],
            &p[1].data,
            x.ctx.n_recv,
        ))
    }

    fn forward_tape(&self, x: &ConvInputs, p: &[&Mat]) -> Result<(Mat, ConvSaved)> {
        let (pooled, saved) = edge_conv_tape(
            x.sender_h,
            x.receiver_h,
            &x.ctx.sidx,
            &x.ctx.ridx,
            p[0],
            &p[1].data,
            x.ctx.n_recv,
        );
        Ok((pooled, ConvSaved::Mpnn(saved)))
    }

    fn backward(
        &self,
        ctx: &ConvCtx,
        saved: &ConvSaved,
        d_out: &Mat,
        p: &[&Mat],
        grads: &mut [Mat],
        gidx: &[usize],
    ) -> Result<(Mat, Mat)> {
        let ConvSaved::Mpnn(s) = saved else {
            return Err(saved_mismatch("mpnn"));
        };
        // pool → relu → bias → matmul → concat-split → two gathers.
        let d_msg = grad::segment_sum_vjp(&ctx.ridx, d_out);
        let dz = grad::relu_vjp(&s.z_msg, &d_msg);
        let (dx_edge, dw) = grad::matmul_vjp(&s.x_edge, p[0], &dz);
        grads[gidx[0]].add_assign(&dw);
        grads[gidx[1]].add_assign(&row_mat(grad::bias_vjp(&dz)));
        let h = ctx.dims.hidden;
        let (d_sender_g, d_receiver_g) = two_parts(grad::concat_cols_vjp(&[h, h], &dx_edge))?;
        Ok((
            grad::gather_vjp(&ctx.sidx, ctx.n_send, &d_sender_g),
            grad::gather_vjp(&ctx.ridx, ctx.n_recv, &d_receiver_g),
        ))
    }
}

/// GCN-style convolution: mean-pool the neighbor (sender) states per
/// receiver, then one linear + relu. The fast path is a single fused
/// broadcast→mean-pool pass (no per-edge tensor at any point).
pub struct GcnConv;

impl Convolution for GcnConv {
    fn name(&self) -> &'static str {
        "gcn"
    }

    fn param_shapes(&self, d: super::ConvDims) -> Vec<ParamShape> {
        vec![
            ParamShape::weight("gcn.w", d.hidden, d.message),
            ParamShape::bias("gcn.b", d.message),
        ]
    }

    fn fast_path_needs_indices(&self) -> bool {
        false // forward runs on the CSR view alone
    }

    fn forward(&self, x: &ConvInputs, p: &[&Mat]) -> Result<Mat> {
        let pooled = broadcast_pool_fused(
            x.g,
            x.es,
            Tag::Target,
            Tag::Source,
            Reduce::Mean,
            &state_feature(x.sender_h),
        )?;
        let x_pool = feature_to_mat(pooled, x.ctx.n_recv, x.ctx.dims.hidden)?;
        let mut z = x_pool.matmul(p[0]);
        z.add_bias(&p[1].data);
        z.relu();
        Ok(z)
    }

    fn forward_tape(&self, x: &ConvInputs, p: &[&Mat]) -> Result<(Mat, ConvSaved)> {
        let x_edge = x.sender_h.gather(&x.ctx.sidx);
        let x_pool = grad::segment_mean_fwd(&x_edge, &x.ctx.ridx, x.ctx.n_recv);
        let mut z = x_pool.matmul(p[0]);
        z.add_bias(&p[1].data);
        let mut out = z.clone();
        out.relu();
        Ok((out, ConvSaved::Gcn { x_pool, z }))
    }

    fn backward(
        &self,
        ctx: &ConvCtx,
        saved: &ConvSaved,
        d_out: &Mat,
        p: &[&Mat],
        grads: &mut [Mat],
        gidx: &[usize],
    ) -> Result<(Mat, Mat)> {
        let ConvSaved::Gcn { x_pool, z } = saved else {
            return Err(saved_mismatch("gcn"));
        };
        let dz = grad::relu_vjp(z, d_out);
        let (dx_pool, dw) = grad::matmul_vjp(x_pool, p[0], &dz);
        grads[gidx[0]].add_assign(&dw);
        grads[gidx[1]].add_assign(&row_mat(grad::bias_vjp(&dz)));
        let d_x_edge = grad::segment_mean_vjp(&ctx.ridx, ctx.n_recv, &dx_pool);
        let d_sender = grad::gather_vjp(&ctx.sidx, ctx.n_send, &d_x_edge);
        // The receiver state does not enter a GCN convolution (only the
        // following node update concatenates it).
        Ok((d_sender, Mat::zeros(ctx.n_recv, ctx.dims.hidden)))
    }
}

/// GraphSAGE convolution: `[self ‖ aggregated neighbors]` through one
/// linear + relu, with mean or max neighbor aggregation. Max routes
/// gradients along the saved per-`(receiver, column)` argmax.
pub struct SageConv {
    pub max: bool,
}

impl Convolution for SageConv {
    fn name(&self) -> &'static str {
        "sage"
    }

    fn param_shapes(&self, d: super::ConvDims) -> Vec<ParamShape> {
        vec![
            ParamShape::weight("sage.w", 2 * d.hidden, d.message),
            ParamShape::bias("sage.b", d.message),
        ]
    }

    fn fast_path_needs_indices(&self) -> bool {
        false // forward runs on the CSR view alone
    }

    fn forward(&self, x: &ConvInputs, p: &[&Mat]) -> Result<Mat> {
        let reduce = if self.max { Reduce::Max } else { Reduce::Mean };
        let pooled = broadcast_pool_fused(
            x.g,
            x.es,
            Tag::Target,
            Tag::Source,
            reduce,
            &state_feature(x.sender_h),
        )?;
        let agg = feature_to_mat(pooled, x.ctx.n_recv, x.ctx.dims.hidden)?;
        let x_cat = Mat::concat_cols(&[x.receiver_h, &agg]);
        let mut z = x_cat.matmul(p[0]);
        z.add_bias(&p[1].data);
        z.relu();
        Ok(z)
    }

    fn forward_tape(&self, x: &ConvInputs, p: &[&Mat]) -> Result<(Mat, ConvSaved)> {
        let x_edge = x.sender_h.gather(&x.ctx.sidx);
        let (agg, argmax) = if self.max {
            let (a, am) = grad::segment_max_fwd(&x_edge, &x.ctx.ridx, x.ctx.n_recv);
            (a, Some(am))
        } else {
            (grad::segment_mean_fwd(&x_edge, &x.ctx.ridx, x.ctx.n_recv), None)
        };
        let x_cat = Mat::concat_cols(&[x.receiver_h, &agg]);
        let mut z = x_cat.matmul(p[0]);
        z.add_bias(&p[1].data);
        let mut out = z.clone();
        out.relu();
        Ok((out, ConvSaved::Sage { x_cat, z, argmax }))
    }

    fn backward(
        &self,
        ctx: &ConvCtx,
        saved: &ConvSaved,
        d_out: &Mat,
        p: &[&Mat],
        grads: &mut [Mat],
        gidx: &[usize],
    ) -> Result<(Mat, Mat)> {
        let ConvSaved::Sage { x_cat, z, argmax } = saved else {
            return Err(saved_mismatch("sage"));
        };
        let dz = grad::relu_vjp(z, d_out);
        let (dx_cat, dw) = grad::matmul_vjp(x_cat, p[0], &dz);
        grads[gidx[0]].add_assign(&dw);
        grads[gidx[1]].add_assign(&row_mat(grad::bias_vjp(&dz)));
        let h = ctx.dims.hidden;
        let (d_receiver, d_agg) = two_parts(grad::concat_cols_vjp(&[h, h], &dx_cat))?;
        let d_x_edge = match argmax {
            Some(am) => grad::segment_max_vjp(am, ctx.sidx.len(), &d_agg),
            None => grad::segment_mean_vjp(&ctx.ridx, ctx.n_recv, &d_agg),
        };
        let d_sender = grad::gather_vjp(&ctx.sidx, ctx.n_send, &d_x_edge);
        Ok((d_sender, d_receiver))
    }
}

/// GATv2-style attention convolution. Per edge, a two-layer scorer
/// over the gathered `[sender ‖ receiver]` pair —
/// `e = relu(W_att·x + b_att) · v_att` — with the nonlinearity *inside*
/// the scorer (the GATv2 fix to GAT's static attention; relu stands in
/// for LeakyReLU, the one slope this op vocabulary carries). Logits
/// softmax per receiver and weight a sum of value-projected sender
/// states. The fast path hands the softmax + weighted pooling to
/// [`softmax_weighted_pool_fused`]; the taped path runs
/// [`grad::segment_softmax_pool_fwd`], its bit-equal on-tape twin.
pub struct Gatv2Conv;

impl Convolution for Gatv2Conv {
    fn name(&self) -> &'static str {
        "gatv2"
    }

    fn param_shapes(&self, d: super::ConvDims) -> Vec<ParamShape> {
        vec![
            ParamShape::weight("att.w", 2 * d.hidden, d.att),
            ParamShape::bias("att.b", d.att),
            ParamShape::weight("att.v", d.att, 1),
            ParamShape::weight("val.w", d.hidden, d.message),
            ParamShape::bias("val.b", d.message),
        ]
    }

    fn forward(&self, x: &ConvInputs, p: &[&Mat]) -> Result<Mat> {
        let d = x.ctx.dims;
        let mut vals = x.sender_h.matmul(p[3]);
        vals.add_bias(&p[4].data);
        let sender_g = x.sender_h.gather(&x.ctx.sidx);
        let receiver_g = x.receiver_h.gather(&x.ctx.ridx);
        let x_edge = Mat::concat_cols(&[&sender_g, &receiver_g]);
        let mut s = x_edge.matmul(p[0]);
        s.add_bias(&p[1].data);
        s.relu();
        let e = s.matmul(p[2]); // [num_edges, 1] attention logits
        let out = softmax_weighted_pool_fused(
            x.g,
            x.es,
            Tag::Target,
            Tag::Source,
            &Feature::f32_vec(e.data),
            &Feature::f32_mat(d.message, vals.data),
        )?;
        feature_to_mat(out, x.ctx.n_recv, d.message)
    }

    fn forward_tape(&self, x: &ConvInputs, p: &[&Mat]) -> Result<(Mat, ConvSaved)> {
        let mut vals = x.sender_h.matmul(p[3]);
        vals.add_bias(&p[4].data);
        let sender_g = x.sender_h.gather(&x.ctx.sidx);
        let receiver_g = x.receiver_h.gather(&x.ctx.ridx);
        let x_edge = Mat::concat_cols(&[&sender_g, &receiver_g]);
        let mut s_pre = x_edge.matmul(p[0]);
        s_pre.add_bias(&p[1].data);
        let mut s = s_pre.clone();
        s.relu();
        let e = s.matmul(p[2]);
        let vals_edge = vals.gather(&x.ctx.sidx);
        let (out, weights) =
            grad::segment_softmax_pool_fwd(&e.data, &vals_edge, &x.ctx.ridx, x.ctx.n_recv);
        Ok((
            out,
            ConvSaved::Gatv2 {
                sender_h: x.sender_h.clone(),
                x_edge,
                s_pre,
                weights,
                vals_edge,
            },
        ))
    }

    fn backward(
        &self,
        ctx: &ConvCtx,
        saved: &ConvSaved,
        d_out: &Mat,
        p: &[&Mat],
        grads: &mut [Mat],
        gidx: &[usize],
    ) -> Result<(Mat, Mat)> {
        let ConvSaved::Gatv2 { sender_h, x_edge, s_pre, weights, vals_edge } = saved else {
            return Err(saved_mismatch("gatv2"));
        };
        // Softmax-weighted pool → (logit path, value path).
        let (dlogits, d_vals_edge) =
            grad::segment_softmax_pool_vjp(weights, vals_edge, &ctx.ridx, d_out);
        // Value path: edge rows → sender nodes → value projection.
        let d_vals = grad::gather_vjp(&ctx.sidx, ctx.n_send, &d_vals_edge);
        let (d_sender_vals, d_val_w) = grad::matmul_vjp(sender_h, p[3], &d_vals);
        grads[gidx[3]].add_assign(&d_val_w);
        grads[gidx[4]].add_assign(&row_mat(grad::bias_vjp(&d_vals)));
        // Logit path: attention vector → relu → scorer MLP.
        let d_e = Mat { rows: ctx.sidx.len(), cols: 1, data: dlogits };
        let mut s = s_pre.clone();
        s.relu();
        let (d_s, d_att_v) = grad::matmul_vjp(&s, p[2], &d_e);
        grads[gidx[2]].add_assign(&d_att_v);
        let d_s_pre = grad::relu_vjp(s_pre, &d_s);
        let (d_x_edge, d_att_w) = grad::matmul_vjp(x_edge, p[0], &d_s_pre);
        grads[gidx[0]].add_assign(&d_att_w);
        grads[gidx[1]].add_assign(&row_mat(grad::bias_vjp(&d_s_pre)));
        // Endpoint gathers, plus the value-path sender contribution.
        let h = ctx.dims.hidden;
        let (d_sender_g, d_receiver_g) = two_parts(grad::concat_cols_vjp(&[h, h], &d_x_edge))?;
        let mut d_sender = grad::gather_vjp(&ctx.sidx, ctx.n_send, &d_sender_g);
        d_sender.add_assign(&d_sender_vals);
        let d_receiver = grad::gather_vjp(&ctx.ridx, ctx.n_recv, &d_receiver_g);
        Ok((d_sender, d_receiver))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Adjacency, Context, EdgeSet, GraphTensor, NodeSet};
    use crate::layers::{ConvDims, ConvKind};
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    /// A two-node-set graph: receivers "r" (edge SOURCE endpoint) and
    /// senders "s" (edge TARGET endpoint) — the model's sampling
    /// direction. Isolated receivers are likely at these sizes.
    fn random_bipartite(
        rng: &mut Rng,
        n_recv: usize,
        n_send: usize,
        n_edges: usize,
    ) -> GraphTensor {
        let es = EdgeSet::new(
            vec![n_edges],
            Adjacency {
                source_set: "r".into(),
                target_set: "s".into(),
                source: (0..n_edges).map(|_| rng.uniform(n_recv) as u32).collect(),
                target: (0..n_edges).map(|_| rng.uniform(n_send) as u32).collect(),
            },
        );
        GraphTensor::from_pieces(
            Context::default(),
            [
                ("r".to_string(), NodeSet::new(vec![n_recv])),
                ("s".to_string(), NodeSet::new(vec![n_send])),
            ]
            .into(),
            [("e".to_string(), es)].into(),
        )
        .unwrap()
    }

    fn rand_mat(rng: &mut Rng, rows: usize, cols: usize) -> Mat {
        Mat {
            rows,
            cols,
            data: (0..rows * cols)
                .map(|_| if rng.chance(0.15) { 0.0 } else { rng.range_f32(-1.5, 1.5) })
                .collect(),
        }
    }

    const KINDS: [ConvKind; 5] =
        [ConvKind::Mpnn, ConvKind::Gcn, ConvKind::SageMean, ConvKind::SageMax, ConvKind::Gatv2];

    /// The subsystem's core property: for every convolution, the fused
    /// fast path and the taped op sequence agree bit-for-bit — outputs,
    /// shapes, isolated receivers and all. (For mpnn this re-proves the
    /// edge_conv fusion property through the trait; for gcn/sage it
    /// pins the fused broadcast→pool against gather+segment ops; for
    /// gatv2 it pins softmax_weighted_pool_fused against its on-tape
    /// twin segment_softmax_pool_fwd.)
    #[test]
    fn prop_forward_matches_forward_tape_bitexact() {
        check("conv fast == tape for the whole zoo", 30, |rng| {
            let n_recv = 1 + rng.uniform(10);
            let n_send = 1 + rng.uniform(10);
            let n_edges = rng.uniform(30);
            let dims = ConvDims {
                hidden: 1 + rng.uniform(5),
                message: 1 + rng.uniform(5),
                att: 1 + rng.uniform(4),
            };
            let g = random_bipartite(rng, n_recv, n_send, n_edges);
            let adj = &g.edge_set("e").unwrap().adjacency;
            let ctx = ConvCtx {
                sidx: adj.target.iter().map(|&v| v as i32).collect(),
                ridx: adj.source.iter().map(|&v| v as i32).collect(),
                n_send,
                n_recv,
                dims,
            };
            let sender_h = rand_mat(rng, n_send, dims.hidden);
            let receiver_h = rand_mat(rng, n_recv, dims.hidden);
            for kind in KINDS {
                let conv = kind.conv();
                let params: Vec<Mat> = conv
                    .param_shapes(dims)
                    .iter()
                    .map(|s| rand_mat(rng, s.rows, s.cols))
                    .collect();
                let prefs: Vec<&Mat> = params.iter().collect();
                let x = ConvInputs {
                    g: &g,
                    es: "e",
                    sender_h: &sender_h,
                    receiver_h: &receiver_h,
                    ctx: &ctx,
                };
                let fast = conv.forward(&x, &prefs).unwrap();
                let (taped, _saved) = conv.forward_tape(&x, &prefs).unwrap();
                assert_eq!(fast.rows, n_recv, "{}", conv.name());
                assert_eq!(fast.cols, conv.out_dim(dims), "{}", conv.name());
                assert_eq!(taped.rows, fast.rows);
                assert_eq!(taped.cols, fast.cols);
                for (i, (a, b)) in fast.data.iter().zip(&taped.data).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "{} element {i}: fast {a} vs tape {b}",
                        conv.name()
                    );
                }
            }
        });
    }

    /// Backward accepts only its own tape entry and produces
    /// correctly-shaped state gradients with parameter gradients
    /// accumulated in place.
    #[test]
    fn backward_shapes_and_tape_type_guard() {
        let mut rng = Rng::new(71);
        let dims = ConvDims { hidden: 3, message: 4, att: 2 };
        let g = random_bipartite(&mut rng, 4, 5, 12);
        let adj = &g.edge_set("e").unwrap().adjacency;
        let ctx = ConvCtx {
            sidx: adj.target.iter().map(|&v| v as i32).collect(),
            ridx: adj.source.iter().map(|&v| v as i32).collect(),
            n_send: 5,
            n_recv: 4,
            dims,
        };
        let sender_h = rand_mat(&mut rng, 5, dims.hidden);
        let receiver_h = rand_mat(&mut rng, 4, dims.hidden);
        for kind in KINDS {
            let conv = kind.conv();
            let params: Vec<Mat> = conv
                .param_shapes(dims)
                .iter()
                .map(|s| rand_mat(&mut rng, s.rows, s.cols))
                .collect();
            let prefs: Vec<&Mat> = params.iter().collect();
            let x = ConvInputs {
                g: &g,
                es: "e",
                sender_h: &sender_h,
                receiver_h: &receiver_h,
                ctx: &ctx,
            };
            let (out, saved) = conv.forward_tape(&x, &prefs).unwrap();
            let d_out = rand_mat(&mut rng, out.rows, out.cols);
            let mut grads: Vec<Mat> = params.iter().map(Mat::zeros_like).collect();
            let gidx: Vec<usize> = (0..params.len()).collect();
            let (d_send, d_recv) =
                conv.backward(&ctx, &saved, &d_out, &prefs, &mut grads, &gidx).unwrap();
            assert_eq!((d_send.rows, d_send.cols), (5, dims.hidden), "{}", conv.name());
            assert_eq!((d_recv.rows, d_recv.cols), (4, dims.hidden), "{}", conv.name());
            assert!(
                grads.iter().any(|gm| gm.data.iter().any(|&v| v != 0.0)),
                "{}: no parameter gradient accumulated",
                conv.name()
            );
            // Feeding another conv's saved state is a structured error.
            let wrong = if matches!(kind, ConvKind::Mpnn) {
                ConvSaved::Gcn { x_pool: Mat::zeros(4, dims.hidden), z: Mat::zeros(4, dims.message) }
            } else {
                ConvSaved::Mpnn(crate::ops::model_ref::EdgeConvSaved {
                    x_edge: Mat::zeros(12, 2 * dims.hidden),
                    z_msg: Mat::zeros(12, dims.message),
                })
            };
            assert!(conv.backward(&ctx, &wrong, &d_out, &prefs, &mut grads, &gidx).is_err());
        }
    }

    /// Self-loop edge sets (source set == target set) flow through the
    /// fused paths with the distinct-tag gather (the fused kernels'
    /// `gather_self` shortcut must NOT trigger).
    #[test]
    fn self_loop_edge_set_matches_tape() {
        let mut rng = Rng::new(13);
        let n = 6usize;
        let n_edges = 14usize;
        let es = EdgeSet::new(
            vec![n_edges],
            Adjacency {
                source_set: "n".into(),
                target_set: "n".into(),
                source: (0..n_edges).map(|_| rng.uniform(n) as u32).collect(),
                target: (0..n_edges).map(|_| rng.uniform(n) as u32).collect(),
            },
        );
        let g = GraphTensor::from_pieces(
            Context::default(),
            [("n".to_string(), NodeSet::new(vec![n]))].into(),
            [("e".to_string(), es)].into(),
        )
        .unwrap();
        let dims = ConvDims { hidden: 4, message: 3, att: 2 };
        let adj = &g.edge_set("e").unwrap().adjacency;
        let ctx = ConvCtx {
            sidx: adj.target.iter().map(|&v| v as i32).collect(),
            ridx: adj.source.iter().map(|&v| v as i32).collect(),
            n_send: n,
            n_recv: n,
            dims,
        };
        let h = rand_mat(&mut rng, n, dims.hidden);
        for kind in KINDS {
            let conv = kind.conv();
            let params: Vec<Mat> = conv
                .param_shapes(dims)
                .iter()
                .map(|s| rand_mat(&mut rng, s.rows, s.cols))
                .collect();
            let prefs: Vec<&Mat> = params.iter().collect();
            let x = ConvInputs { g: &g, es: "e", sender_h: &h, receiver_h: &h, ctx: &ctx };
            let fast = conv.forward(&x, &prefs).unwrap();
            let (taped, _) = conv.forward_tape(&x, &prefs).unwrap();
            for (a, b) in fast.data.iter().zip(&taped.data) {
                assert_eq!(a.to_bits(), b.to_bits(), "{}", conv.name());
            }
        }
    }
}
