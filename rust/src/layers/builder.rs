//! `ModelBuilder`: from the `"model"` block of a run config to a
//! validated convolution stack.
//!
//! [`ModelBuilder::from_config`] is the single place the layer
//! subsystem's structural invariants are enforced — unknown `type`,
//! zero `num_layers`, zero widths, and updates that pool an edge set
//! whose SOURCE endpoint is not the updated node set are all
//! structured [`crate::Error::Schema`]s — each tagged with a stable
//! `TFGNN0xx` code and JSON path via
//! [`crate::analysis::diag::Diagnostic`] — never panics (property-tested
//! below). [`NativeModel::init`](crate::train::native::NativeModel::init)
//! funnels through it, so every entry point — `tfgnn train --engine
//! native --config`, serving, tests, benches — gets the same checks.

use crate::analysis::diag::{codes, Diagnostic};
use crate::ops::model_ref::ModelConfig;
use crate::Result;

use super::{ConvKind, Convolution};

/// The validated stack recipe read off a [`ModelConfig`].
#[derive(Debug, Clone, Copy)]
pub struct ModelBuilder {
    pub kind: ConvKind,
}

impl ModelBuilder {
    /// Validate the model block of `cfg` into a buildable stack.
    pub fn from_config(cfg: &ModelConfig) -> Result<ModelBuilder> {
        let kind = ConvKind::parse(&cfg.arch, &cfg.sage_reduce)?;
        if cfg.layers == 0 {
            return Err(Diagnostic::error(
                codes::BAD_DIM,
                "$.model.num_layers",
                "model.num_layers is 0 — a GraphUpdate stack needs at least one round",
            )
            .into_error());
        }
        if cfg.hidden == 0 || cfg.message == 0 {
            return Err(Diagnostic::error(
                codes::BAD_DIM,
                "$.model.hidden_dim",
                format!(
                    "model widths must be positive (hidden_dim {}, message_dim {})",
                    cfg.hidden, cfg.message
                ),
            )
            .into_error());
        }
        if kind == ConvKind::Gatv2 && cfg.att_dim == 0 {
            return Err(Diagnostic::error(
                codes::BAD_DIM,
                "$.model.att_dim",
                "model.att_dim is 0 — the gatv2 scorer needs a positive width",
            )
            .into_error());
        }
        // Receiver-is-SOURCE convention: every updated node set must be
        // the SOURCE endpoint of each edge set it pools — exactly once
        // (a duplicate would create two parameter tensors under one
        // name, of which only the last is ever trained or restored).
        for (node_set, edges) in &cfg.updates {
            let mut seen = std::collections::BTreeSet::new();
            for es in edges {
                if !seen.insert(es.as_str()) {
                    return Err(Diagnostic::error(
                        codes::DUPLICATE_POOL,
                        format!("$.model.updates.{node_set}"),
                        format!("update for {node_set:?} pools edge set {es:?} twice"),
                    )
                    .into_error());
                }
                let (src, _tgt) = cfg.edge_endpoints.get(es).ok_or_else(|| {
                    Diagnostic::error(
                        codes::UNKNOWN_EDGE_SET,
                        format!("$.model.updates.{node_set}"),
                        format!("update pools unknown edge set {es:?}"),
                    )
                    .into_error()
                })?;
                if src != node_set {
                    return Err(Diagnostic::error(
                        codes::RECEIVER_NOT_SOURCE,
                        format!("$.model.updates.{node_set}"),
                        format!(
                            "update for {node_set:?} pools {es:?}, whose source is {src:?} \
                             (receiver must be the SOURCE endpoint)"
                        ),
                    )
                    .into_error());
                }
            }
        }
        Ok(ModelBuilder { kind })
    }

    /// The convolution every edge set of the stack runs.
    pub fn conv(&self) -> &'static dyn Convolution {
        self.kind.conv()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::synth::mag::MagConfig;
    use crate::util::json::Json;
    use crate::util::proptest::check;

    /// A minimal valid config document for one model type.
    fn config_text(model_block: &str) -> String {
        format!(
            r#"{{
              "model": {model_block},
              "schema": {{
                "node_sets": {{
                  "paper": {{"features": {{"feat": 16}}}},
                  "venue": {{"id_embedding": true, "cardinality": 5}}
                }},
                "edge_sets": {{"cites": ["paper", "paper"],
                               "at": ["paper", "venue"]}}
              }},
              "train": {{"num_classes": 3}}
            }}"#
        )
    }

    fn builder_of(model_block: &str) -> crate::Result<ModelBuilder> {
        let cfg = ModelConfig::from_config(&Json::parse(&config_text(model_block))?)?;
        ModelBuilder::from_config(&cfg)
    }

    /// All four model types round-trip config → builder → conv and
    /// back to the same kind.
    #[test]
    fn all_four_types_round_trip() {
        for (ty, extra, kind) in [
            ("mpnn", "", ConvKind::Mpnn),
            ("gcn", "", ConvKind::Gcn),
            ("sage", r#", "sage_reduce": "mean""#, ConvKind::SageMean),
            ("sage", r#", "sage_reduce": "max""#, ConvKind::SageMax),
            ("gatv2", r#", "att_dim": 4"#, ConvKind::Gatv2),
        ] {
            let block = format!(
                r#"{{"type": "{ty}", "hidden_dim": 8, "message_dim": 8, "num_layers": 2,
                     "updates": {{"paper": ["cites", "at"]}}{extra}}}"#
            );
            let b = builder_of(&block).unwrap();
            assert_eq!(b.kind, kind, "{ty}{extra}");
            assert_eq!(b.conv().name(), kind.name());
            // The parsed kind survives a serialize→reparse of the
            // document (Json is deterministic).
            let doc = Json::parse(&config_text(&block)).unwrap();
            let reparsed = Json::parse(&doc.to_string()).unwrap();
            let cfg2 = ModelConfig::from_config(&reparsed).unwrap();
            assert_eq!(ModelBuilder::from_config(&cfg2).unwrap().kind, kind);
        }
    }

    /// Property: corrupting the model block — unknown type, a missing
    /// required field, zero layers/widths, a bad sage_reduce — always
    /// yields a structured error, never a panic. (`check` fails the
    /// property on any panic.)
    #[test]
    fn prop_corrupt_model_blocks_are_structured_errors() {
        check("corrupt model block -> Err, no panic", 60, |rng| {
            let required = ["type", "hidden_dim", "message_dim", "num_layers", "updates"];
            let corruption = rng.uniform(5);
            let block = match corruption {
                // Unknown type string (random identifier).
                0 => {
                    let junk: String =
                        (0..1 + rng.uniform(8)).map(|_| (b'a' + rng.uniform(26) as u8) as char).collect();
                    format!(
                        r#"{{"type": "{junk}x", "hidden_dim": 8, "message_dim": 8,
                             "num_layers": 1, "updates": {{"paper": ["cites"]}}}}"#
                    )
                }
                // A required field deleted.
                1 => {
                    let drop = required[1 + rng.uniform(required.len() - 1)];
                    let fields = [
                        ("hidden_dim", r#""hidden_dim": 8"#),
                        ("message_dim", r#""message_dim": 8"#),
                        ("num_layers", r#""num_layers": 1"#),
                        ("updates", r#""updates": {"paper": ["cites"]}"#),
                    ];
                    let kept: Vec<&str> = fields
                        .iter()
                        .filter(|(name, _)| *name != drop)
                        .map(|(_, text)| *text)
                        .collect();
                    format!(r#"{{"type": "mpnn", {}}}"#, kept.join(", "))
                }
                // Zero layers.
                2 => r#"{"type": "gcn", "hidden_dim": 8, "message_dim": 8,
                         "num_layers": 0, "updates": {"paper": ["cites"]}}"#
                    .to_string(),
                // Zero width (hidden, message, or gatv2 att_dim).
                3 => match rng.uniform(3) {
                    0 => r#"{"type": "mpnn", "hidden_dim": 0, "message_dim": 8,
                             "num_layers": 1, "updates": {"paper": ["cites"]}}"#
                        .to_string(),
                    1 => r#"{"type": "sage", "hidden_dim": 8, "message_dim": 0,
                             "num_layers": 1, "updates": {"paper": ["cites"]}}"#
                        .to_string(),
                    _ => r#"{"type": "gatv2", "att_dim": 0, "hidden_dim": 8,
                             "message_dim": 8, "num_layers": 1,
                             "updates": {"paper": ["cites"]}}"#
                        .to_string(),
                },
                // Bad sage_reduce / update of a non-SOURCE endpoint /
                // unknown edge set / duplicate edge set.
                _ => match rng.uniform(4) {
                    0 => r#"{"type": "sage", "sage_reduce": "median", "hidden_dim": 8,
                             "message_dim": 8, "num_layers": 1,
                             "updates": {"paper": ["cites"]}}"#
                        .to_string(),
                    1 => r#"{"type": "mpnn", "hidden_dim": 8, "message_dim": 8,
                             "num_layers": 1, "updates": {"venue": ["at"]}}"#
                        .to_string(),
                    2 => r#"{"type": "mpnn", "hidden_dim": 8, "message_dim": 8,
                             "num_layers": 1, "updates": {"paper": ["ghost"]}}"#
                        .to_string(),
                    _ => r#"{"type": "mpnn", "hidden_dim": 8, "message_dim": 8,
                             "num_layers": 1,
                             "updates": {"paper": ["cites", "cites"]}}"#
                        .to_string(),
                },
            };
            let result = builder_of(&block);
            assert!(result.is_err(), "corruption {corruption} must be rejected: {block}");
            // And the error is one of ours, with a printable message.
            let msg = result.err().unwrap().to_string();
            assert!(!msg.is_empty());
        });
    }

    /// Unknown keys in the `model` block are rejected by name through
    /// the same funnel the builder validates — a typo like `att_dims`
    /// must not silently fall back to defaults.
    #[test]
    fn unknown_model_and_task_keys_are_rejected() {
        // Valid baseline.
        let ok = r#"{"type": "gatv2", "att_dim": 4, "hidden_dim": 8, "message_dim": 8,
                     "num_layers": 1, "updates": {"paper": ["cites"]}}"#;
        assert!(builder_of(ok).is_ok());
        // Typo'd att_dim.
        let typo = ok.replace("att_dim", "att_dims");
        let err = builder_of(&typo).expect_err("att_dims must be rejected");
        let msg = err.to_string();
        assert!(msg.contains("att_dims"), "error names the key: {msg}");
        // A task block with a typo'd key is rejected the same way.
        let cfg_text = config_text(ok).replace(
            r#""train": {"num_classes": 3}"#,
            r#""task": {"type": "link_prediction", "negativs": 4},
               "train": {"num_classes": 3}"#,
        );
        let err = ModelConfig::from_config(&Json::parse(&cfg_text).unwrap())
            .expect_err("task typo must be rejected");
        assert!(err.to_string().contains("negativs"), "{err}");
        // And a valid task block flows through to the parsed config.
        let cfg_text = config_text(ok).replace(
            r#""train": {"num_classes": 3}"#,
            r#""task": {"type": "graph_regression", "target_feature": "year"},
               "train": {"num_classes": 3}"#,
        );
        let cfg = ModelConfig::from_config(&Json::parse(&cfg_text).unwrap()).unwrap();
        assert_eq!(cfg.task.kind, "graph_regression");
        assert_eq!(cfg.task.target_feature, "year");
        assert!(ModelBuilder::from_config(&cfg).is_ok(), "builder is task-agnostic");
    }

    /// A built model's conv kind (validated here) drives the parameter
    /// naming.
    #[test]
    fn build_produces_arch_specific_params() {
        use crate::train::native::NativeModel;
        let mag = MagConfig::tiny();
        let cfg = ModelConfig::for_mag(&mag, 8, 8, 1).with_arch("gatv2");
        assert_eq!(ModelBuilder::from_config(&cfg).unwrap().kind, ConvKind::Gatv2);
        let model = NativeModel::init(cfg, 5).unwrap();
        assert!(model.names.iter().any(|n| n == "l0.paper.cites.att.w"));
        assert!(model.names.iter().any(|n| n == "l0.paper.cites.att.v"));
        assert!(model.names.iter().any(|n| n == "l0.paper.cites.val.w"));
        assert!(model.names.iter().all(|n| !n.contains("msg.w")), "no mpnn params in a gatv2 model");
        let gcn =
            NativeModel::init(ModelConfig::for_mag(&mag, 8, 8, 1).with_arch("gcn"), 5).unwrap();
        assert!(gcn.names.iter().any(|n| n == "l0.author.writes.gcn.w"));
    }
}
