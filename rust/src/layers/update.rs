//! `NodeSetUpdate` / `GraphUpdate`: composing per-edge-set
//! convolutions into whole-graph rounds over a heterogeneous schema.
//!
//! One [`GraphUpdate`] round mirrors the paper's Keras `GraphUpdate`
//! layer: every node set named in `ModelConfig::updates` receives a
//! *node set update* — one [`Convolution`] per pooled edge set, their
//! outputs merged as `[h_self ‖ pooled…]` through the next-state MLP
//! (`l{layer}.{node_set}.next.w/b`) — while all other node sets pass
//! their state through unchanged.
//!
//! **Merge order determinism.** Node sets update in sorted name order
//! (`updates` is a `BTreeMap`) and each update pools its edge sets in
//! sorted edge-set-name order; the concat therefore has a fixed column
//! layout and the whole round is a fixed float-op sequence (see the
//! module docs of [`crate::layers`]). The backward walks the exact
//! reverse.

use std::collections::BTreeMap;

use crate::graph::GraphTensor;
use crate::ops::model_ref::{node_update, Mat, ModelConfig, NodeUpdateSaved};
use crate::train::native::grad;
use crate::{Error, Result};

use super::{row_mat, ConvCtx, ConvDims, ConvInputs, ConvSaved, Convolution};

/// Mutable gradient accumulator for one node set. `dh_prev` is seeded
/// for every set in `node_order`, so a miss means the tape and config
/// disagree — a structured error, never a panic.
fn state_grad<'m>(dh_prev: &'m mut BTreeMap<String, Mat>, set: &str) -> Result<&'m mut Mat> {
    dh_prev
        .get_mut(set)
        .ok_or_else(|| Error::Graph(format!("state grads missing node set {set:?}")))
}

/// One convolution application on the tape: index context + saved
/// activations, plus the names needed to route gradients and look
/// parameters back up.
#[derive(Debug, Clone)]
pub struct EdgeTape {
    pub es: String,
    pub send_set: String,
    pub ctx: ConvCtx,
    pub saved: ConvSaved,
}

/// One node set's update on the tape.
#[derive(Debug, Clone)]
pub struct UpdateTape {
    /// Per pooled edge set, in sorted edge-set-name order (the forward
    /// order).
    pub edges: Vec<EdgeTape>,
    pub node: NodeUpdateSaved,
}

/// One full round: node set → its update's tape.
pub type LayerTape = BTreeMap<String, UpdateTape>;

/// A borrowed view of the model for one round of updates: the config,
/// the convolution, and the flat parameter list with its name index.
pub struct GraphUpdate<'a> {
    pub cfg: &'a ModelConfig,
    pub conv: &'a dyn Convolution,
    pub params: &'a [Mat],
    pub index: &'a BTreeMap<String, usize>,
}

impl<'a> GraphUpdate<'a> {
    pub fn dims(&self) -> ConvDims {
        ConvDims {
            hidden: self.cfg.hidden,
            message: self.cfg.message,
            att: self.cfg.att_dim,
        }
    }

    fn idx(&self, name: &str) -> Result<usize> {
        self.index
            .get(name)
            .copied()
            .ok_or_else(|| Error::Runtime(format!("graph update: no param {name:?}")))
    }

    fn param(&self, name: &str) -> Result<&'a Mat> {
        Ok(&self.params[self.idx(name)?])
    }

    /// This convolution's parameter refs + flat indices for one
    /// `(layer, node set, edge set)`, in `param_shapes` order.
    fn conv_params(
        &self,
        layer: usize,
        node_set: &str,
        es: &str,
    ) -> Result<(Vec<&'a Mat>, Vec<usize>)> {
        let shapes = self.conv.param_shapes(self.dims());
        let mut mats = Vec::with_capacity(shapes.len());
        let mut idxs = Vec::with_capacity(shapes.len());
        for s in &shapes {
            let i = self.idx(&format!("l{layer}.{node_set}.{es}.{}", s.suffix))?;
            mats.push(&self.params[i]);
            idxs.push(i);
        }
        Ok((mats, idxs))
    }

    /// The sorted edge list + per-edge index context of one node set's
    /// update (shared by both forward paths). With `with_indices`
    /// false (the fast path of a CSR-only conv) the O(num_edges)
    /// `sidx`/`ridx` vectors stay empty.
    #[allow(clippy::type_complexity)]
    fn edge_ctxs(
        &self,
        g: &GraphTensor,
        node_set: &str,
        edge_list: &[String],
        with_indices: bool,
    ) -> Result<Vec<(String, String, ConvCtx)>> {
        let n_recv = g.num_nodes(node_set)?;
        let mut edge_names: Vec<&String> = edge_list.iter().collect();
        edge_names.sort();
        let mut out = Vec::with_capacity(edge_names.len());
        for es in edge_names {
            let adj = &g.edge_set(es)?.adjacency;
            let send_set = &self.cfg.edge_endpoints[es.as_str()].1;
            let (sidx, ridx) = if with_indices {
                (
                    adj.target.iter().map(|&v| v as i32).collect(),
                    adj.source.iter().map(|&v| v as i32).collect(),
                )
            } else {
                (Vec::new(), Vec::new())
            };
            out.push((
                es.clone(),
                send_set.clone(),
                ConvCtx {
                    sidx,
                    ridx,
                    n_send: g.num_nodes(send_set)?,
                    n_recv,
                    dims: self.dims(),
                },
            ))
        }
        Ok(out)
    }

    /// One fused (tape-free) round: returns the next per-node-set
    /// states. Pass-through sets carry their state forward.
    pub fn forward(
        &self,
        g: &GraphTensor,
        h: &BTreeMap<String, Mat>,
        layer: usize,
    ) -> Result<BTreeMap<String, Mat>> {
        let mut new_h: BTreeMap<String, Mat> = h
            .iter()
            .filter(|(set, _)| !self.cfg.updates.contains_key(*set))
            .map(|(set, m)| (set.clone(), m.clone()))
            .collect();
        let with_indices = self.conv.fast_path_needs_indices();
        for (node_set, edge_list) in &self.cfg.updates {
            let mut pooled = Vec::new();
            for (es, send_set, ctx) in self.edge_ctxs(g, node_set, edge_list, with_indices)? {
                let (mats, _idxs) = self.conv_params(layer, node_set, &es)?;
                let x = ConvInputs {
                    g,
                    es: &es,
                    sender_h: &h[send_set.as_str()],
                    receiver_h: &h[node_set.as_str()],
                    ctx: &ctx,
                };
                pooled.push(self.conv.forward(&x, &mats)?);
            }
            let mut parts: Vec<&Mat> = vec![&h[node_set.as_str()]];
            parts.extend(pooled.iter());
            let (next, _saved) = node_update(
                &parts,
                self.param(&format!("l{layer}.{node_set}.next.w"))?,
                &self.param(&format!("l{layer}.{node_set}.next.b"))?.data,
            );
            new_h.insert(node_set.clone(), next);
        }
        Ok(new_h)
    }

    /// One round recording the tape. Bit-for-bit the same states as
    /// [`Self::forward`] (each convolution's tape path is bit-equal to
    /// its fused path — the trait contract).
    pub fn forward_tape(
        &self,
        g: &GraphTensor,
        h: &BTreeMap<String, Mat>,
        layer: usize,
    ) -> Result<(BTreeMap<String, Mat>, LayerTape)> {
        let mut new_h: BTreeMap<String, Mat> = h
            .iter()
            .filter(|(set, _)| !self.cfg.updates.contains_key(*set))
            .map(|(set, m)| (set.clone(), m.clone()))
            .collect();
        let mut tape: LayerTape = BTreeMap::new();
        for (node_set, edge_list) in &self.cfg.updates {
            let mut pooled = Vec::new();
            let mut edges = Vec::new();
            for (es, send_set, ctx) in self.edge_ctxs(g, node_set, edge_list, true)? {
                let (mats, _idxs) = self.conv_params(layer, node_set, &es)?;
                let x = ConvInputs {
                    g,
                    es: &es,
                    sender_h: &h[send_set.as_str()],
                    receiver_h: &h[node_set.as_str()],
                    ctx: &ctx,
                };
                let (p, saved) = self.conv.forward_tape(&x, &mats)?;
                pooled.push(p);
                edges.push(EdgeTape { es, send_set, ctx, saved });
            }
            let mut parts: Vec<&Mat> = vec![&h[node_set.as_str()]];
            parts.extend(pooled.iter());
            let (next, node_saved) = node_update(
                &parts,
                self.param(&format!("l{layer}.{node_set}.next.w"))?,
                &self.param(&format!("l{layer}.{node_set}.next.b"))?.data,
            );
            tape.insert(node_set.clone(), UpdateTape { edges, node: node_saved });
            new_h.insert(node_set.clone(), next);
        }
        Ok((new_h, tape))
    }

    /// Reverse of one round: given `dh` (state gradients flowing into
    /// this round's *outputs*), accumulate parameter gradients into
    /// `grads` and return the state gradients for the previous round's
    /// outputs. Walks node sets and edge sets in the exact reverse of
    /// the forward's float-op sequence.
    pub fn backward(
        &self,
        tape: &LayerTape,
        layer: usize,
        dh: &BTreeMap<String, Mat>,
        grads: &mut [Mat],
    ) -> Result<BTreeMap<String, Mat>> {
        let cfg = self.cfg;
        let mut dh_prev: BTreeMap<String, Mat> = BTreeMap::new();
        for set in &cfg.node_order {
            if tape.contains_key(set) {
                dh_prev.insert(set.clone(), dh[set].zeros_like());
            } else {
                // Pass-through: new_h[set] was a clone of h[set].
                dh_prev.insert(set.clone(), dh[set].clone());
            }
        }
        for (node_set, ut) in tape {
            let d_next = &dh[node_set];
            // relu → bias → matmul of the next-state MLP.
            let dz = grad::relu_vjp(&ut.node.z, d_next);
            let w_next_idx = self.idx(&format!("l{layer}.{node_set}.next.w"))?;
            let (dx_cat, d_w_next) =
                grad::matmul_vjp(&ut.node.x_cat, &self.params[w_next_idx], &dz);
            grads[w_next_idx].add_assign(&d_w_next);
            grads[self.idx(&format!("l{layer}.{node_set}.next.b"))?]
                .add_assign(&row_mat(grad::bias_vjp(&dz)));
            // Split the concat back into [h_self ‖ pooled…].
            let dims = self.dims();
            let mut widths = vec![cfg.hidden];
            widths.extend(std::iter::repeat(self.conv.out_dim(dims)).take(ut.edges.len()));
            let mut pieces = grad::concat_cols_vjp(&widths, &dx_cat);
            let d_pooled_list = pieces.split_off(1);
            state_grad(&mut dh_prev, node_set)?.add_assign(&pieces[0]);
            // Each convolution, in forward (sorted) order.
            for (et, d_pooled) in ut.edges.iter().zip(&d_pooled_list) {
                let (mats, idxs) = self.conv_params(layer, node_set, &et.es)?;
                let (d_sender, d_receiver) =
                    self.conv.backward(&et.ctx, &et.saved, d_pooled, &mats, grads, &idxs)?;
                state_grad(&mut dh_prev, &et.send_set)?.add_assign(&d_sender);
                state_grad(&mut dh_prev, node_set)?.add_assign(&d_receiver);
            }
        }
        Ok(dh_prev)
    }
}
