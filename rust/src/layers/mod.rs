//! Composable GraphUpdate layers — the config-driven convolution zoo.
//!
//! The paper's centerpiece API is the Keras layer family
//! `GraphUpdate` / `NodeSetUpdate` / `Convolution` (§5, API Level 3):
//! interchangeable per-edge-set convolutions composed into per-node-set
//! updates over a heterogeneous schema. This module is that family for
//! the native Rust engine, on top of the fused kernels of
//! [`crate::ops::fused`] and the reverse-mode rules of
//! [`crate::train::native::grad`]:
//!
//! * [`Convolution`] — the layer trait: a fused fast `forward`, a
//!   bit-identical `forward_tape` saving activations, and a `backward`
//!   composing op VJPs (each finite-difference checked);
//! * [`conv`] — the zoo: [`conv::MpnnConv`] (the original architecture,
//!   bit-for-bit the pre-refactor model), [`conv::GcnConv`] (mean-pool
//!   then linear), [`conv::SageConv`] (self ‖ pooled neighbors, mean or
//!   max), [`conv::Gatv2Conv`] (two-layer attention scorer +
//!   softmax-weighted pooling via
//!   [`softmax_weighted_pool_fused`](crate::ops::softmax_weighted_pool_fused));
//! * [`update`] — [`update::GraphUpdate`]: walks every updated node set
//!   of the schema, runs one convolution per pooled edge set, and
//!   merges the results through the next-state MLP;
//! * [`builder`] — [`builder::ModelBuilder`]: validates the `"model"`
//!   block of a run config (`type`, `num_layers`, dims) into a
//!   [`ConvKind`] the trainable model is built from.
//!
//! **Determinism contract.** Node sets update in sorted
//! (`BTreeMap`) name order and each update pools its edge sets in
//! sorted edge-set-name order; within one convolution every float
//! accumulation folds in ascending edge-id order (the CSR row order —
//! see `graph::csr`). A model forward is therefore a fixed sequence of
//! float operations: bit-stable across runs, thread counts and the
//! fused/taped path split.
//!
//! **Direction convention.** The receiver of every convolution is the
//! edge set's SOURCE endpoint and the sender its TARGET endpoint (the
//! rooted-subgraph sampling direction), validated at model build time.

pub mod builder;
pub mod conv;
pub mod update;

pub use builder::ModelBuilder;
pub use update::{EdgeTape, GraphUpdate, LayerTape, UpdateTape};

use crate::analysis::diag::{codes, Diagnostic};
use crate::graph::GraphTensor;
use crate::ops::model_ref::{EdgeConvSaved, Mat};
use crate::Result;

/// Which convolution the stack runs on every edge set — the parsed,
/// validated form of the config's `model.type`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ConvKind {
    /// The original hardwired architecture: per-edge message MLP over
    /// `[sender ‖ receiver]`, relu, sum-pool.
    Mpnn,
    /// GCN-style: mean-pool neighbor states, then a linear + relu.
    Gcn,
    /// GraphSAGE: `[self ‖ mean-pooled neighbors]` through linear + relu.
    SageMean,
    /// GraphSAGE with max-pool neighbor aggregation.
    SageMax,
    /// GATv2-style attention: two-layer scorer on `[sender ‖ receiver]`
    /// per edge, per-receiver softmax, weighted sum of value-projected
    /// sender states.
    Gatv2,
}

impl ConvKind {
    /// Parse the config's `model.type` (+ `model.sage_reduce`) pair.
    pub fn parse(arch: &str, sage_reduce: &str) -> Result<ConvKind> {
        match arch {
            "mpnn" => Ok(ConvKind::Mpnn),
            "gcn" => Ok(ConvKind::Gcn),
            "sage" => match sage_reduce {
                "mean" => Ok(ConvKind::SageMean),
                "max" => Ok(ConvKind::SageMax),
                other => Err(Diagnostic::error(
                    codes::UNKNOWN_ENUM,
                    "$.model.sage_reduce",
                    format!("model.sage_reduce {other:?} unknown (want mean|max)"),
                )
                .into_error()),
            },
            "gatv2" => Ok(ConvKind::Gatv2),
            other => Err(Diagnostic::error(
                codes::UNKNOWN_ENUM,
                "$.model.type",
                format!("model type {other:?} unknown (want mpnn|gcn|sage|gatv2)"),
            )
            .into_error()),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ConvKind::Mpnn => "mpnn",
            ConvKind::Gcn => "gcn",
            ConvKind::SageMean | ConvKind::SageMax => "sage",
            ConvKind::Gatv2 => "gatv2",
        }
    }

    /// The convolution implementation (stateless shared values).
    pub fn conv(&self) -> &'static dyn Convolution {
        static MPNN: conv::MpnnConv = conv::MpnnConv;
        static GCN: conv::GcnConv = conv::GcnConv;
        static SAGE_MEAN: conv::SageConv = conv::SageConv { max: false };
        static SAGE_MAX: conv::SageConv = conv::SageConv { max: true };
        static GATV2: conv::Gatv2Conv = conv::Gatv2Conv;
        match self {
            ConvKind::Mpnn => &MPNN,
            ConvKind::Gcn => &GCN,
            ConvKind::SageMean => &SAGE_MEAN,
            ConvKind::SageMax => &SAGE_MAX,
            ConvKind::Gatv2 => &GATV2,
        }
    }
}

/// The width vocabulary a convolution's parameter shapes are drawn
/// from, read off the [`ModelConfig`](crate::ops::model_ref::ModelConfig).
#[derive(Debug, Clone, Copy)]
pub struct ConvDims {
    /// Node-state width (input of every convolution).
    pub hidden: usize,
    /// Convolution output width (what the node update concatenates).
    pub message: usize,
    /// GATv2 attention hidden width.
    pub att: usize,
}

/// One parameter tensor a convolution owns per `(layer, node set,
/// edge set)` — named `l{layer}.{node_set}.{edge_set}.{suffix}` in the
/// model's flat parameter list, created in `param_shapes` order.
#[derive(Debug, Clone, Copy)]
pub struct ParamShape {
    pub suffix: &'static str,
    pub rows: usize,
    pub cols: usize,
    /// Biases initialize to zero (no RNG draw); weights are
    /// Glorot-uniform.
    pub zero_init: bool,
}

impl ParamShape {
    pub fn weight(suffix: &'static str, rows: usize, cols: usize) -> ParamShape {
        ParamShape { suffix, rows, cols, zero_init: false }
    }

    pub fn bias(suffix: &'static str, cols: usize) -> ParamShape {
        ParamShape { suffix, rows: 1, cols, zero_init: true }
    }
}

/// The index-side context of one convolution application, saved on the
/// tape (everything `backward` needs besides the [`ConvSaved`]
/// activations).
#[derive(Debug, Clone)]
pub struct ConvCtx {
    /// Sender gather indices (the edge set's TARGET endpoint), one per
    /// edge. Left empty on the tape-free fast path when the conv's
    /// [`Convolution::fast_path_needs_indices`] is false.
    pub sidx: Vec<i32>,
    /// Receiver gather/pool indices (the edge set's SOURCE endpoint);
    /// same emptiness rule as `sidx`.
    pub ridx: Vec<i32>,
    pub n_send: usize,
    pub n_recv: usize,
    pub dims: ConvDims,
}

/// Everything a convolution forward reads: the live graph (for the
/// fused kernels' CSR views), the endpoint states, and the index
/// context.
pub struct ConvInputs<'a> {
    pub g: &'a GraphTensor,
    pub es: &'a str,
    pub sender_h: &'a Mat,
    pub receiver_h: &'a Mat,
    pub ctx: &'a ConvCtx,
}

/// Saved forward activations of one convolution — the per-conv tape
/// entry, consumed exactly once by the matching `backward`.
#[derive(Debug, Clone)]
pub enum ConvSaved {
    Mpnn(EdgeConvSaved),
    Gcn {
        /// `[n_recv, hidden]` mean-pooled neighbor states.
        x_pool: Mat,
        /// `[n_recv, message]` pre-relu output.
        z: Mat,
    },
    Sage {
        /// `[n_recv, 2·hidden]` concatenated `[self ‖ aggregated]`.
        x_cat: Mat,
        /// `[n_recv, message]` pre-relu output.
        z: Mat,
        /// Winning edge row per `(receiver, column)` for max
        /// aggregation (`None` for mean).
        argmax: Option<Vec<i32>>,
    },
    Gatv2 {
        /// `[n_send, hidden]` sender states (input of the value
        /// projection).
        sender_h: Mat,
        /// `[num_edges, 2·hidden]` gathered `[sender ‖ receiver]`.
        x_edge: Mat,
        /// `[num_edges, att]` pre-relu scorer hidden layer.
        s_pre: Mat,
        /// Per-edge softmax weights.
        weights: Vec<f32>,
        /// `[num_edges, message]` gathered value rows.
        vals_edge: Mat,
    },
}

/// One interchangeable per-edge-set convolution.
///
/// Contract (asserted by tests in [`conv`]):
/// * `forward` and `forward_tape` produce **bit-identical** outputs —
///   the fast path may fuse (no per-edge intermediates) but must fold
///   floats in the same order as the taped sequence;
/// * `backward` is the exact VJP of `forward_tape`, composed from the
///   finite-difference-checked rules of [`crate::train::native::grad`];
///   it accumulates parameter gradients into `grads[gidx[k]]` (indices
///   parallel to `param_shapes`) and returns
///   `(d_sender_h, d_receiver_h)` — `[n_send, hidden]` and
///   `[n_recv, hidden]` state gradients for the previous layer.
pub trait Convolution: Sync {
    fn name(&self) -> &'static str;

    /// Parameter tensors per `(layer, node set, edge set)`, in creation
    /// order.
    fn param_shapes(&self, d: ConvDims) -> Vec<ParamShape>;

    /// Output width (all shipped convolutions emit `message`).
    fn out_dim(&self, d: ConvDims) -> usize {
        d.message
    }

    /// Whether the fused fast path reads `ctx.sidx`/`ctx.ridx`. Convs
    /// that run entirely on the graph's CSR views (gcn, sage) return
    /// false so the tape-free forward skips materializing O(num_edges)
    /// index vectors per edge set per layer. `forward_tape` always
    /// receives real indices (the backward needs them).
    fn fast_path_needs_indices(&self) -> bool {
        true
    }

    /// Fast forward (fused where available): `[n_recv, out_dim]`.
    /// `p` holds the conv's parameters in `param_shapes` order.
    fn forward(&self, x: &ConvInputs, p: &[&Mat]) -> Result<Mat>;

    /// Tape forward: same bits as `forward`, plus saved activations.
    fn forward_tape(&self, x: &ConvInputs, p: &[&Mat]) -> Result<(Mat, ConvSaved)>;

    /// Reverse sweep for one convolution (see trait docs).
    fn backward(
        &self,
        ctx: &ConvCtx,
        saved: &ConvSaved,
        d_out: &Mat,
        p: &[&Mat],
        grads: &mut [Mat],
        gidx: &[usize],
    ) -> Result<(Mat, Mat)>;
}

/// A 1×n gradient row (bias gradients come back as flat vectors).
pub(crate) fn row_mat(v: Vec<f32>) -> Mat {
    Mat { rows: 1, cols: v.len(), data: v }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_kind_parses_and_names() {
        assert_eq!(ConvKind::parse("mpnn", "mean").unwrap(), ConvKind::Mpnn);
        assert_eq!(ConvKind::parse("gcn", "mean").unwrap(), ConvKind::Gcn);
        assert_eq!(ConvKind::parse("sage", "mean").unwrap(), ConvKind::SageMean);
        assert_eq!(ConvKind::parse("sage", "max").unwrap(), ConvKind::SageMax);
        assert_eq!(ConvKind::parse("gatv2", "mean").unwrap(), ConvKind::Gatv2);
        assert!(ConvKind::parse("gat", "mean").is_err());
        assert!(ConvKind::parse("sage", "min").is_err());
        for k in [ConvKind::Mpnn, ConvKind::Gcn, ConvKind::SageMean, ConvKind::Gatv2] {
            assert_eq!(k.conv().name(), k.name());
        }
        assert_eq!(ConvKind::SageMax.conv().name(), "sage");
    }

    #[test]
    fn param_shapes_follow_dims() {
        let d = ConvDims { hidden: 8, message: 6, att: 4 };
        for k in
            [ConvKind::Mpnn, ConvKind::Gcn, ConvKind::SageMean, ConvKind::SageMax, ConvKind::Gatv2]
        {
            let shapes = k.conv().param_shapes(d);
            assert!(!shapes.is_empty(), "{}", k.name());
            for s in &shapes {
                assert!(s.rows > 0 && s.cols > 0, "{} {}", k.name(), s.suffix);
                if s.zero_init {
                    assert_eq!(s.rows, 1, "biases are rows of width cols");
                }
            }
            assert_eq!(k.conv().out_dim(d), d.message);
        }
        // The mpnn shapes are pinned: they name the pre-refactor
        // checkpoint entries.
        let mpnn = ConvKind::Mpnn.conv().param_shapes(d);
        assert_eq!(mpnn.len(), 2);
        assert_eq!(mpnn[0].suffix, "msg.w");
        assert_eq!((mpnn[0].rows, mpnn[0].cols), (16, 6));
        assert_eq!(mpnn[1].suffix, "msg.b");
    }
}
