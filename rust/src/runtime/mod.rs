//! AOT runtime: load `artifacts/*.hlo.txt` on the PJRT CPU client and
//! execute them from the training/serving hot path.
//!
//! Python never runs here: [`manifest::Manifest`] (written once by
//! `python/compile/aot.py`) tells us each program's file and its ordered
//! input/output tensors; [`Program`] compiles the HLO text and executes
//! it; [`batch`] marshals a padded GraphTensor batch into the `feat.*` /
//! `ids.*` / `edge.*` / `root.*` argument slots.
//!
//! State handling: PJRT (via the `xla` crate, 0.1.6) returns program
//! results as ONE tuple buffer, and exposes no buffer-level untuple, so
//! model/optimizer state crosses each step as [`xla::Literal`]s:
//! execute → fetch tuple → `decompose_tuple` → feed the pieces back in.
//! On the CPU client this is a host-side memcpy per step (measured in
//! EXPERIMENTS.md §Perf); the batch tensors are built fresh per step
//! anyway.

pub mod batch;
pub mod manifest;

use std::path::Path;

use manifest::{ProgramSpec, TensorSpec};

use crate::{Error, Result};

/// Host-side tensor matching one manifest slot.
#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    F32(Vec<usize>, Vec<f32>),
    I32(Vec<usize>, Vec<i32>),
    I64(Vec<usize>, Vec<i64>),
}

impl HostTensor {
    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32(s, _) | HostTensor::I32(s, _) | HostTensor::I64(s, _) => s,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32(_, d) => d.len(),
            HostTensor::I32(_, d) => d.len(),
            HostTensor::I64(_, d) => d.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype_name(&self) -> &'static str {
        match self {
            HostTensor::F32(..) => "f32",
            HostTensor::I32(..) => "i32",
            HostTensor::I64(..) => "i64",
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32(_, d) => Ok(d),
            other => Err(Error::Runtime(format!("expected f32, got {}", other.dtype_name()))),
        }
    }

    /// Check against a manifest slot.
    pub fn matches(&self, spec: &TensorSpec) -> bool {
        self.dtype_name() == spec.dtype && self.shape() == spec.shape.as_slice()
    }
}

/// The PJRT client (one per process).
pub struct Runtime {
    pub client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        Ok(Runtime { client: xla::PjRtClient::cpu()? })
    }

    /// Load + compile one program from an artifacts directory.
    pub fn load_program(&self, dir: &Path, spec: &ProgramSpec) -> Result<Program> {
        let path = dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| Error::Runtime(format!("{}: {e}", path.display())))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        Ok(Program { exe, client: self.client.clone(), spec: spec.clone() })
    }

    /// Upload a host tensor to the device.
    pub fn upload(&self, t: &HostTensor) -> Result<xla::PjRtBuffer> {
        let buf = match t {
            HostTensor::F32(shape, data) => {
                self.client.buffer_from_host_buffer::<f32>(data, shape, None)?
            }
            HostTensor::I32(shape, data) => {
                self.client.buffer_from_host_buffer::<i32>(data, shape, None)?
            }
            HostTensor::I64(shape, data) => {
                self.client.buffer_from_host_buffer::<i64>(data, shape, None)?
            }
        };
        Ok(buf)
    }

    /// Download a device buffer to the host.
    pub fn download(&self, buf: &xla::PjRtBuffer) -> Result<HostTensor> {
        let lit = buf.to_literal_sync()?;
        literal_to_host(&lit)
    }
}

pub fn literal_to_host(lit: &xla::Literal) -> Result<HostTensor> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    match shape.ty() {
        xla::ElementType::F32 => Ok(HostTensor::F32(dims, lit.to_vec::<f32>()?)),
        xla::ElementType::S32 => Ok(HostTensor::I32(dims, lit.to_vec::<i32>()?)),
        xla::ElementType::S64 => Ok(HostTensor::I64(dims, lit.to_vec::<i64>()?)),
        other => Err(Error::Runtime(format!("unsupported literal type {other:?}"))),
    }
}

/// One compiled AOT program.
pub struct Program {
    exe: xla::PjRtLoadedExecutable,
    client: xla::PjRtClient,
    pub spec: ProgramSpec,
}

impl Program {
    /// Execute with literal arguments; returns output literals in
    /// manifest order (the lowered programs return one tuple, which is
    /// decomposed here).
    ///
    /// NOTE: this deliberately avoids `PjRtLoadedExecutable::execute`
    /// (literal args): the crate's C shim `release()`s the input
    /// buffers it creates per call and never frees them — ~state-size
    /// leaked per step, which OOMed long training runs (§Perf). We
    /// upload to caller-owned `PjRtBuffer`s (freed on drop) and call
    /// `execute_b` instead.
    pub fn execute_literals(&self, args: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        if args.len() != self.spec.inputs.len() {
            return Err(Error::Runtime(format!(
                "{}: {} args for {} input slots",
                self.spec.file,
                args.len(),
                self.spec.inputs.len()
            )));
        }
        let bufs: Vec<xla::PjRtBuffer> = args
            .iter()
            .map(|lit| self.client.buffer_from_host_literal(None, lit).map_err(Into::into))
            .collect::<Result<Vec<_>>>()?;
        let refs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
        let mut out = self.exe.execute_b(&refs)?;
        let buffers = out
            .pop()
            .ok_or_else(|| Error::Runtime("no execution outputs".into()))?;
        self.untuple(buffers)
    }

    fn untuple(&self, buffers: Vec<xla::PjRtBuffer>) -> Result<Vec<xla::Literal>> {
        if buffers.len() == 1 {
            let mut lit = buffers[0].to_literal_sync()?;
            let parts = if self.spec.outputs.len() == 1 {
                // Still a 1-tuple (lowered with return_tuple=True).
                lit.decompose_tuple().unwrap_or_else(|_| vec![lit])
            } else {
                lit.decompose_tuple()?
            };
            if parts.len() != self.spec.outputs.len() {
                return Err(Error::Runtime(format!(
                    "{}: {} outputs for {} output slots",
                    self.spec.file,
                    parts.len(),
                    self.spec.outputs.len()
                )));
            }
            return Ok(parts);
        }
        if buffers.len() != self.spec.outputs.len() {
            return Err(Error::Runtime(format!(
                "{}: {} outputs for {} output slots",
                self.spec.file,
                buffers.len(),
                self.spec.outputs.len()
            )));
        }
        buffers.iter().map(|b| b.to_literal_sync().map_err(Into::into)).collect()
    }

    /// Execute with host tensors; validates against the manifest and
    /// returns host tensors. Convenience for init/eval/tests.
    pub fn execute_host(&self, _rt: &Runtime, args: &[HostTensor]) -> Result<Vec<HostTensor>> {
        for (i, (a, spec)) in args.iter().zip(&self.spec.inputs).enumerate() {
            if !a.matches(spec) {
                return Err(Error::Runtime(format!(
                    "{}: arg {i} ({}) has dtype/shape {}{:?}, manifest wants {}{:?}",
                    self.spec.file,
                    spec.name,
                    a.dtype_name(),
                    a.shape(),
                    spec.dtype,
                    spec.shape
                )));
            }
        }
        let lits: Vec<xla::Literal> =
            args.iter().map(host_to_literal).collect::<Result<Vec<_>>>()?;
        let refs: Vec<&xla::Literal> = lits.iter().collect();
        let outs = self.execute_literals(&refs)?;
        outs.iter().map(literal_to_host).collect()
    }

    /// Index of a named input slot.
    pub fn input_index(&self, name: &str) -> Result<usize> {
        self.spec
            .inputs
            .iter()
            .position(|t| t.name == name)
            .ok_or_else(|| Error::Runtime(format!("{}: no input slot {name:?}", self.spec.file)))
    }

    /// Index of a named output slot.
    pub fn output_index(&self, name: &str) -> Result<usize> {
        self.spec
            .outputs
            .iter()
            .position(|t| t.name == name)
            .ok_or_else(|| Error::Runtime(format!("{}: no output slot {name:?}", self.spec.file)))
    }
}

/// Convert a host tensor to an XLA literal.
pub fn host_to_literal(t: &HostTensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
    let lit = match t {
        HostTensor::F32(_, data) => xla::Literal::vec1(data).reshape(&dims)?,
        HostTensor::I32(_, data) => xla::Literal::vec1(data).reshape(&dims)?,
        HostTensor::I64(_, data) => xla::Literal::vec1(data).reshape(&dims)?,
    };
    Ok(lit)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_spec_matching() {
        let t = HostTensor::F32(vec![2, 3], vec![0.0; 6]);
        let spec = TensorSpec { name: "x".into(), shape: vec![2, 3], dtype: "f32".into() };
        assert!(t.matches(&spec));
        let spec_i = TensorSpec { name: "x".into(), shape: vec![2, 3], dtype: "i32".into() };
        assert!(!t.matches(&spec_i));
        let spec_s = TensorSpec { name: "x".into(), shape: vec![6], dtype: "f32".into() };
        assert!(!t.matches(&spec_s));
    }
}
