//! Marshal a padded GraphTensor batch into AOT argument slots.
//!
//! The manifest names batch inputs `feat.<set>.<name>`, `ids.<set>`,
//! `edge.<set>.src|tgt`, `root.idx`, `root.labels`, `root.mask`; this
//! module fills each slot from a [`Padded`] batch:
//!
//! * features come straight from the padded node sets (f32, flattened);
//! * `ids.*` is the `#id` feature cast to i32 (embedding-table keys);
//! * edge slots are the adjacency index arrays (i32);
//! * the root of component `c` is node 0 of the root node set in that
//!   component (the sampler's "seed first" convention), so `root.idx[c]`
//!   is the prefix sum of the root set's component sizes; labels are
//!   read off the root set's label feature at those indices; the mask
//!   is 1 for real components.

use crate::graph::pad::Padded;
use crate::runtime::manifest::TensorSpec;
use crate::runtime::HostTensor;
use crate::{Error, Result};

/// Task binding: which node set carries the roots and labels.
#[derive(Debug, Clone)]
pub struct RootTask {
    pub root_set: String,
    pub label_feature: String,
}

impl Default for RootTask {
    fn default() -> RootTask {
        RootTask { root_set: "paper".into(), label_feature: "labels".into() }
    }
}

/// Root indices (flat, per non-padding-capable component slot).
pub fn root_indices(padded: &Padded, root_set: &str, num_roots: usize) -> Result<Vec<i32>> {
    let ns = padded.graph.node_set(root_set)?;
    let mut prefix = Vec::with_capacity(ns.sizes.len());
    let mut acc = 0usize;
    for &s in &ns.sizes {
        prefix.push(acc);
        acc += s;
    }
    // Real components point at their root; padding slots point at the
    // padding component's first node (masked out in the loss).
    let pad_start = prefix.last().copied().unwrap_or(0);
    let mut out = Vec::with_capacity(num_roots);
    for c in 0..num_roots {
        if c < padded.num_real_components {
            out.push(prefix[c] as i32);
        } else {
            out.push(pad_start as i32);
        }
    }
    Ok(out)
}

/// Build the tensor for one named batch slot.
pub fn build_slot(padded: &Padded, task: &RootTask, spec: &TensorSpec) -> Result<HostTensor> {
    let name = spec.name.as_str();
    let g = &padded.graph;
    let parts: Vec<&str> = name.split('.').collect();
    let tensor = match parts.as_slice() {
        ["feat", set, feat] => {
            let f = g.node_set(set)?.feature(feat)?;
            let (_, data) = f.as_f32()?;
            HostTensor::F32(spec.shape.clone(), data.to_vec())
        }
        ["ids", set] => {
            let f = g.node_set(set)?.feature("#id")?;
            let (_, data) = f.as_i64()?;
            HostTensor::I32(spec.shape.clone(), data.iter().map(|&x| x as i32).collect())
        }
        ["edge", set, "src"] => {
            let es = g.edge_set(set)?;
            HostTensor::I32(
                spec.shape.clone(),
                es.adjacency.source.iter().map(|&x| x as i32).collect(),
            )
        }
        ["edge", set, "tgt"] => {
            let es = g.edge_set(set)?;
            HostTensor::I32(
                spec.shape.clone(),
                es.adjacency.target.iter().map(|&x| x as i32).collect(),
            )
        }
        ["root", "idx"] => {
            let num_roots = spec.shape[0];
            HostTensor::I32(spec.shape.clone(), root_indices(padded, &task.root_set, num_roots)?)
        }
        ["root", "labels"] => {
            let num_roots = spec.shape[0];
            let idx = root_indices(padded, &task.root_set, num_roots)?;
            let f = g.node_set(&task.root_set)?.feature(&task.label_feature)?;
            let (_, labels) = f.as_i64()?;
            HostTensor::I32(
                spec.shape.clone(),
                idx.iter().map(|&i| labels[i as usize] as i32).collect(),
            )
        }
        ["root", "mask"] => {
            let num_roots = spec.shape[0];
            let mut mask = vec![0.0f32; num_roots];
            for m in mask.iter_mut().take(padded.num_real_components.min(num_roots)) {
                *m = 1.0;
            }
            HostTensor::F32(spec.shape.clone(), mask)
        }
        _ => return Err(Error::Runtime(format!("unknown batch slot {name:?}"))),
    };
    if tensor.len() != spec.elems() {
        return Err(Error::Runtime(format!(
            "slot {name:?}: built {} elems, manifest wants {:?} = {}",
            tensor.len(),
            spec.shape,
            spec.elems()
        )));
    }
    Ok(tensor)
}

/// Build every batch slot of a program's input list (slots whose names
/// are batch-like; param/adam/step slots are skipped).
pub fn build_batch(
    padded: &Padded,
    task: &RootTask,
    inputs: &[TensorSpec],
) -> Result<Vec<(usize, HostTensor)>> {
    let mut out = Vec::new();
    for (i, spec) in inputs.iter().enumerate() {
        if is_batch_slot(&spec.name) {
            out.push((i, build_slot(padded, task, spec)?));
        }
    }
    Ok(out)
}

/// Is this input slot part of the per-step batch (vs params/opt state)?
pub fn is_batch_slot(name: &str) -> bool {
    name.starts_with("feat.")
        || name.starts_with("ids.")
        || name.starts_with("edge.")
        || name.starts_with("root.")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::pad::{pad, PadSpec};
    use crate::sampler::inmem::InMemorySampler;
    use crate::sampler::spec::mag_sampling_spec_scaled;
    use crate::synth::mag::{generate, MagConfig};
    use std::sync::Arc;

    fn make_padded() -> Padded {
        let ds = generate(&MagConfig::tiny());
        let store = Arc::new(ds.store);
        let spec = mag_sampling_spec_scaled(&store.schema, 0.2).unwrap();
        let sampler = InMemorySampler::new(store, spec, 3).unwrap();
        let graphs: Vec<_> = (0..4).map(|s| sampler.sample(s).unwrap()).collect();
        let merged = crate::graph::batch::merge(&graphs).unwrap();
        let padspec = PadSpec::fit(&graphs.iter().collect::<Vec<_>>(), 4, 2.0);
        pad(&merged, &padspec).unwrap()
    }

    fn spec(name: &str, shape: Vec<usize>, dtype: &str) -> TensorSpec {
        TensorSpec { name: name.into(), shape, dtype: dtype.into() }
    }

    #[test]
    fn root_indices_are_component_starts() {
        let p = make_padded();
        let idx = root_indices(&p, "paper", 5).unwrap();
        assert_eq!(idx[0], 0);
        let sizes = &p.graph.node_set("paper").unwrap().sizes;
        assert_eq!(idx[1], sizes[0] as i32);
        assert_eq!(idx[2], (sizes[0] + sizes[1]) as i32);
        // Padding slot points at the padding component start.
        let pad_start: usize = sizes[..4].iter().sum();
        assert_eq!(idx[4], pad_start as i32);
    }

    #[test]
    fn root_labels_match_seed_labels() {
        let ds = generate(&MagConfig::tiny());
        let p = make_padded();
        let labels_spec = spec("root.labels", vec![5], "i32");
        let t = build_slot(&p, &RootTask::default(), &labels_spec).unwrap();
        let HostTensor::I32(_, labels) = t else { panic!() };
        // Roots are seeds 0..4 in order (no shuffling in make_padded).
        let (_, seed_ids) = p.graph.context.feature("seed").unwrap().as_i64().unwrap();
        for c in 0..4 {
            assert_eq!(labels[c] as i64, ds.labels[seed_ids[c] as usize], "component {c}");
        }
    }

    #[test]
    fn mask_marks_real_components() {
        let p = make_padded();
        let t = build_slot(&p, &RootTask::default(), &spec("root.mask", vec![6], "f32")).unwrap();
        let HostTensor::F32(_, mask) = t else { panic!() };
        assert_eq!(mask, vec![1.0, 1.0, 1.0, 1.0, 0.0, 0.0]);
    }

    #[test]
    fn edge_and_feat_slots() {
        let p = make_padded();
        let n_cites = p.graph.num_edges("cites").unwrap();
        let t = build_slot(
            &p,
            &RootTask::default(),
            &spec("edge.cites.src", vec![n_cites], "i32"),
        )
        .unwrap();
        assert_eq!(t.len(), n_cites);
        let n_paper = p.graph.num_nodes("paper").unwrap();
        let t = build_slot(
            &p,
            &RootTask::default(),
            &spec("feat.paper.feat", vec![n_paper, 16], "f32"),
        )
        .unwrap();
        assert_eq!(t.len(), n_paper * 16);
        let t = build_slot(
            &p,
            &RootTask::default(),
            &spec("ids.institution", vec![p.graph.num_nodes("institution").unwrap()], "i32"),
        )
        .unwrap();
        assert_eq!(t.dtype_name(), "i32");
    }

    #[test]
    fn wrong_shape_rejected() {
        let p = make_padded();
        let bad = spec("edge.cites.src", vec![99999], "i32");
        assert!(build_slot(&p, &RootTask::default(), &bad).is_err());
        let unknown = spec("bogus.slot", vec![1], "f32");
        assert!(build_slot(&p, &RootTask::default(), &unknown).is_err());
    }

    #[test]
    fn batch_slot_classification() {
        assert!(is_batch_slot("feat.paper.feat"));
        assert!(is_batch_slot("root.mask"));
        assert!(is_batch_slot("edge.cites.src"));
        assert!(is_batch_slot("ids.institution"));
        assert!(!is_batch_slot("param.head.w"));
        assert!(!is_batch_slot("adam_m.head.w"));
        assert!(!is_batch_slot("step"));
    }
}
