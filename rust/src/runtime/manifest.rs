//! `artifacts/manifest.json` — the AOT calling convention.
//!
//! Written by `python/compile/aot.py`; consumed here so the Rust side
//! never hard-codes a program signature. See DESIGN.md §AOT interface.

use std::collections::BTreeMap;
use std::path::Path;

use crate::util::json::Json;
use crate::{Error, Result};

/// One tensor slot (input or output) of a program.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String, // "f32" | "i32" | "i64"
}

impl TensorSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    fn from_json(v: &Json) -> Result<TensorSpec> {
        Ok(TensorSpec {
            name: v.get("name")?.as_str()?.to_string(),
            shape: v
                .get("shape")?
                .as_arr()?
                .iter()
                .map(|d| d.as_usize())
                .collect::<Result<Vec<_>>>()?,
            dtype: v.get("dtype")?.as_str()?.to_string(),
        })
    }
}

/// One lowered program (init / train_step / eval_step / forward).
#[derive(Debug, Clone)]
pub struct ProgramSpec {
    pub file: String,
    pub sha256: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

impl ProgramSpec {
    /// Input slots whose name starts with `prefix`, with their indices.
    pub fn inputs_with_prefix(&self, prefix: &str) -> Vec<(usize, &TensorSpec)> {
        self.inputs
            .iter()
            .enumerate()
            .filter(|(_, t)| t.name.starts_with(prefix))
            .collect()
    }
}

/// One model architecture's programs.
#[derive(Debug, Clone)]
pub struct ModelEntry {
    pub arch: String,
    pub hidden_dim: usize,
    pub message_dim: usize,
    pub num_layers: usize,
    pub param_count: usize,
    pub programs: BTreeMap<String, ProgramSpec>,
}

impl ModelEntry {
    pub fn program(&self, name: &str) -> Result<&ProgramSpec> {
        self.programs
            .get(name)
            .ok_or_else(|| Error::Runtime(format!("model has no program {name:?}")))
    }
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub config: Json,
    pub models: BTreeMap<String, ModelEntry>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Runtime(format!(
                "{}: {e} — run `make artifacts` first",
                path.display()
            ))
        })?;
        Manifest::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let v = Json::parse(text)?;
        let mut models = BTreeMap::new();
        for (arch, entry) in v.get("models")?.as_obj()? {
            let mut programs = BTreeMap::new();
            for (pname, p) in entry.get("programs")?.as_obj()? {
                let inputs = p
                    .get("inputs")?
                    .as_arr()?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect::<Result<Vec<_>>>()?;
                let outputs = p
                    .get("outputs")?
                    .as_arr()?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect::<Result<Vec<_>>>()?;
                programs.insert(
                    pname.clone(),
                    ProgramSpec {
                        file: p.get("file")?.as_str()?.to_string(),
                        sha256: p
                            .opt("sha256")
                            .and_then(|s| s.as_str().ok())
                            .unwrap_or("")
                            .to_string(),
                        inputs,
                        outputs,
                    },
                );
            }
            models.insert(
                arch.clone(),
                ModelEntry {
                    arch: entry.get("arch")?.as_str()?.to_string(),
                    hidden_dim: entry.get("hidden_dim")?.as_usize()?,
                    message_dim: entry.get("message_dim")?.as_usize()?,
                    num_layers: entry.get("num_layers")?.as_usize()?,
                    param_count: entry.get("param_count")?.as_usize()?,
                    programs,
                },
            );
        }
        Ok(Manifest { config: v.get("config")?.clone(), models })
    }

    pub fn model(&self, arch: &str) -> Result<&ModelEntry> {
        self.models
            .get(arch)
            .ok_or_else(|| Error::Runtime(format!("manifest has no model {arch:?}")))
    }

    /// Paths in `config.pad` as a [`crate::graph::pad::PadSpec`].
    pub fn pad_spec(&self) -> Result<crate::graph::pad::PadSpec> {
        let pad = self.config.get("pad")?;
        let mut node_caps = std::collections::BTreeMap::new();
        for (k, v) in pad.get("node_caps")?.as_obj()? {
            node_caps.insert(k.clone(), v.as_usize()?);
        }
        let mut edge_caps = std::collections::BTreeMap::new();
        for (k, v) in pad.get("edge_caps")?.as_obj()? {
            edge_caps.insert(k.clone(), v.as_usize()?);
        }
        Ok(crate::graph::pad::PadSpec {
            node_caps,
            edge_caps,
            component_cap: pad.get("component_cap")?.as_usize()?,
        })
    }

    /// The dataset config as a [`crate::synth::mag::MagConfig`].
    pub fn mag_config(&self) -> Result<crate::synth::mag::MagConfig> {
        let d = self.config.get("dataset")?;
        Ok(crate::synth::mag::MagConfig {
            num_papers: d.get("num_papers")?.as_usize()?,
            num_authors: d.get("num_authors")?.as_usize()?,
            num_institutions: d.get("num_institutions")?.as_usize()?,
            num_fields: d.get("num_fields")?.as_usize()?,
            num_classes: d.get("num_classes")?.as_usize()?,
            num_communities: d.get("num_communities")?.as_usize()?,
            feature_dim: d.get("feature_dim")?.as_usize()?,
            mean_citations: d.get("mean_citations")?.as_f64()?,
            mean_authors_per_paper: d.get("mean_authors_per_paper")?.as_f64()?,
            mean_topics: d.get("mean_topics")?.as_f64()?,
            community_coherence: d.get("community_coherence")?.as_f64()?,
            label_coherence: d.get("label_coherence")?.as_f64()?,
            feature_noise: d.get("feature_noise")?.as_f64()? as f32,
            year_min: d.get("year_min")?.as_i64()?,
            year_max: d.get("year_max")?.as_i64()?,
            seed: d.get("seed")?.as_i64()? as u64,
        })
    }

    /// Per-edge-set sampling sizes from `config.sampling.sizes`.
    pub fn sampling_sizes(&self) -> Result<std::collections::BTreeMap<String, usize>> {
        let s = self.config.get("sampling")?.get("sizes")?;
        let mut out = std::collections::BTreeMap::new();
        for (k, v) in s.as_obj()? {
            out.insert(k.clone(), v.as_usize()?);
        }
        Ok(out)
    }

    pub fn batch_size(&self) -> Result<usize> {
        self.config.get("batch_size")?.as_usize()
    }

    pub fn plan_seed(&self) -> Result<u64> {
        Ok(self.config.get("sampling")?.get("plan_seed")?.as_i64()? as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "config": {
        "batch_size": 2,
        "pad": {"node_caps": {"a": 4}, "edge_caps": {"e": 8}, "component_cap": 3},
        "sampling": {"plan_seed": 42, "sizes": {"e": 4}}
      },
      "models": {
        "mpnn": {
          "arch": "mpnn", "hidden_dim": 8, "message_dim": 8, "num_layers": 1,
          "param_count": 123,
          "programs": {
            "init": {"file": "x_init.hlo.txt", "inputs": [],
                     "outputs": [{"name": "param.w", "shape": [2, 2], "dtype": "f32"}]},
            "train_step": {"file": "x_train.hlo.txt",
              "inputs": [{"name": "param.w", "shape": [2, 2], "dtype": "f32"},
                         {"name": "step", "shape": [], "dtype": "i32"},
                         {"name": "edge.e.src", "shape": [8], "dtype": "i32"}],
              "outputs": [{"name": "loss", "shape": [], "dtype": "f32"}]}
          }
        }
      }
    }"#;

    #[test]
    fn parse_manifest() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let model = m.model("mpnn").unwrap();
        assert_eq!(model.param_count, 123);
        let ts = model.program("train_step").unwrap();
        assert_eq!(ts.inputs.len(), 3);
        assert_eq!(ts.inputs[2].name, "edge.e.src");
        assert_eq!(ts.inputs[2].shape, vec![8]);
        assert_eq!(ts.outputs[0].dtype, "f32");
        assert!(model.program("missing").is_err());
        assert!(m.model("hgt").is_err());
    }

    #[test]
    fn pad_spec_extraction() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let pad = m.pad_spec().unwrap();
        assert_eq!(pad.node_caps["a"], 4);
        assert_eq!(pad.edge_caps["e"], 8);
        assert_eq!(pad.component_cap, 3);
        assert_eq!(m.batch_size().unwrap(), 2);
        assert_eq!(m.plan_seed().unwrap(), 42);
        assert_eq!(m.sampling_sizes().unwrap()["e"], 4);
    }

    #[test]
    fn prefix_lookup() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let ts = m.model("mpnn").unwrap().program("train_step").unwrap();
        let params = ts.inputs_with_prefix("param.");
        assert_eq!(params.len(), 1);
        assert_eq!(params[0].0, 0);
        assert_eq!(ts.inputs_with_prefix("edge.").len(), 1);
    }

    #[test]
    fn real_manifest_if_present() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return; // `make artifacts` not run yet
        }
        let m = Manifest::load(&dir).unwrap();
        let mpnn = m.model("mpnn").unwrap();
        for prog in ["init", "train_step", "eval_step", "forward"] {
            let p = mpnn.program(prog).unwrap();
            assert!(dir.join(&p.file).exists(), "{}", p.file);
        }
        // Table 1 premise: mha bigger than mpnn.
        let mha = m.model("mha").unwrap();
        assert!(mha.param_count > 2 * mpnn.param_count);
    }
}
