//! `GraphSchema` — the declarative heterogeneous data model (paper §3.1).
//!
//! A schema names the node sets, edge sets (with their source/target
//! node sets) and context features of a heterogeneous graph, and for
//! every feature its dtype and per-item shape. `GraphTensor` values
//! ([`crate::graph`]) are validated against a schema, exactly as
//! TF-GNN validates parsed `tf.train.Example` records.
//!
//! The paper serializes schemas as protocol buffers; this reproduction
//! uses a JSON text format (see [`parse`]) carrying the same content,
//! including the `metadata { filename, cardinality }` annotations used
//! by the sampler (§8, appendix A.6.1).

pub mod parse;

use std::collections::BTreeMap;

use crate::{Error, Result};

/// Feature element type. TF-GNN supports int, float and string features
/// (§3.1); we mirror that with i64 / f32 / UTF-8 string.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I64,
    Str,
}

impl DType {
    pub fn name(&self) -> &'static str {
        match self {
            DType::F32 => "float32",
            DType::I64 => "int64",
            DType::Str => "string",
        }
    }

    pub fn from_name(s: &str) -> Result<DType> {
        match s {
            "float32" | "DT_FLOAT" | "f32" => Ok(DType::F32),
            "int64" | "DT_INT64" | "i64" => Ok(DType::I64),
            "string" | "DT_STRING" | "str" => Ok(DType::Str),
            other => Err(Error::Schema(format!("unknown dtype {other:?}"))),
        }
    }
}

/// Per-item feature shape: the `[f1, …, fk]` dims of §3.1. `None` marks
/// a ragged dimension (variable length per item), rendered as `null` in
/// the text format — TF-GNN's `tf.RaggedTensor` case.
pub type FeatureShape = Vec<Option<usize>>;

/// Declaration of a single feature.
#[derive(Debug, Clone, PartialEq)]
pub struct FeatureSpec {
    pub dtype: DType,
    pub shape: FeatureShape,
}

impl FeatureSpec {
    pub fn f32(dims: &[usize]) -> FeatureSpec {
        FeatureSpec { dtype: DType::F32, shape: dims.iter().map(|&d| Some(d)).collect() }
    }

    pub fn i64(dims: &[usize]) -> FeatureSpec {
        FeatureSpec { dtype: DType::I64, shape: dims.iter().map(|&d| Some(d)).collect() }
    }

    pub fn string() -> FeatureSpec {
        FeatureSpec { dtype: DType::Str, shape: vec![] }
    }

    /// A rank-1 ragged float feature (`[None]` per item).
    pub fn ragged_f32() -> FeatureSpec {
        FeatureSpec { dtype: DType::F32, shape: vec![None] }
    }

    /// Is any dimension ragged?
    pub fn is_ragged(&self) -> bool {
        self.shape.iter().any(|d| d.is_none())
    }

    /// Number of scalar elements per item, if fully dense.
    pub fn dense_elems(&self) -> Option<usize> {
        self.shape.iter().try_fold(1usize, |acc, d| d.map(|d| acc * d))
    }
}

/// Source metadata for a node/edge set (appendix A.6.1): where the raw
/// entities live and how many there are. The sampler and synthetic
/// generators fill these in.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Metadata {
    pub filename: Option<String>,
    pub cardinality: Option<u64>,
}

/// Declaration of a node set and its features.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct NodeSetSpec {
    pub features: BTreeMap<String, FeatureSpec>,
    pub metadata: Metadata,
}

/// Declaration of an edge set: its endpoint node sets and its features.
#[derive(Debug, Clone, PartialEq)]
pub struct EdgeSetSpec {
    pub source: String,
    pub target: String,
    pub features: BTreeMap<String, FeatureSpec>,
    pub metadata: Metadata,
}

/// The full heterogeneous graph schema (§3.1).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct GraphSchema {
    pub context: BTreeMap<String, FeatureSpec>,
    pub node_sets: BTreeMap<String, NodeSetSpec>,
    pub edge_sets: BTreeMap<String, EdgeSetSpec>,
}

impl GraphSchema {
    /// Structural validation: every edge set references declared node
    /// sets; names are non-empty.
    pub fn validate(&self) -> Result<()> {
        for (name, es) in &self.edge_sets {
            if name.is_empty() {
                return Err(Error::Schema("empty edge set name".into()));
            }
            for (role, set) in [("source", &es.source), ("target", &es.target)] {
                if !self.node_sets.contains_key(set) {
                    return Err(Error::Schema(format!(
                        "edge set {name:?} {role} references unknown node set {set:?}"
                    )));
                }
            }
        }
        if self.node_sets.keys().any(|k| k.is_empty()) {
            return Err(Error::Schema("empty node set name".into()));
        }
        Ok(())
    }

    pub fn node_set(&self, name: &str) -> Result<&NodeSetSpec> {
        self.node_sets
            .get(name)
            .ok_or_else(|| Error::Schema(format!("unknown node set {name:?}")))
    }

    pub fn edge_set(&self, name: &str) -> Result<&EdgeSetSpec> {
        self.edge_sets
            .get(name)
            .ok_or_else(|| Error::Schema(format!("unknown edge set {name:?}")))
    }

    /// Edge sets incident to `node_set` as the given endpoint role.
    pub fn edge_sets_into(&self, node_set: &str) -> Vec<&str> {
        self.edge_sets
            .iter()
            .filter(|(_, es)| es.target == node_set)
            .map(|(k, _)| k.as_str())
            .collect()
    }

    pub fn edge_sets_from(&self, node_set: &str) -> Vec<&str> {
        self.edge_sets
            .iter()
            .filter(|(_, es)| es.source == node_set)
            .map(|(k, _)| k.as_str())
            .collect()
    }

    /// Builder-style helpers used by generators and tests.
    pub fn with_node_set(mut self, name: &str, spec: NodeSetSpec) -> Self {
        self.node_sets.insert(name.to_string(), spec);
        self
    }

    pub fn with_edge_set(mut self, name: &str, spec: EdgeSetSpec) -> Self {
        self.edge_sets.insert(name.to_string(), spec);
        self
    }

    pub fn with_context_feature(mut self, name: &str, spec: FeatureSpec) -> Self {
        self.context.insert(name.to_string(), spec);
        self
    }
}

/// The recommendation-system example schema from Figure 2a, used across
/// tests and the `recsys_spending` example.
pub fn recsys_example_schema() -> GraphSchema {
    let mut items = NodeSetSpec::default();
    items.features.insert("category".into(), FeatureSpec::string());
    items.features.insert("price".into(), FeatureSpec::ragged_f32());
    let mut users = NodeSetSpec::default();
    users.features.insert("name".into(), FeatureSpec::string());
    users.features.insert("age".into(), FeatureSpec::i64(&[]));
    users.features.insert("country".into(), FeatureSpec::i64(&[]));
    GraphSchema::default()
        .with_node_set("items", items)
        .with_node_set("users", users)
        .with_edge_set(
            "purchased",
            EdgeSetSpec {
                source: "items".into(),
                target: "users".into(),
                features: BTreeMap::new(),
                metadata: Metadata::default(),
            },
        )
        .with_edge_set(
            "is-friend",
            EdgeSetSpec {
                source: "users".into(),
                target: "users".into(),
                features: BTreeMap::new(),
                metadata: Metadata::default(),
            },
        )
        .with_context_feature("scores", FeatureSpec::f32(&[4]))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recsys_schema_validates() {
        let s = recsys_example_schema();
        s.validate().unwrap();
        assert_eq!(s.node_sets.len(), 2);
        assert_eq!(s.edge_sets.len(), 2);
        assert_eq!(s.edge_set("purchased").unwrap().source, "items");
        assert_eq!(s.edge_set("is-friend").unwrap().target, "users");
    }

    #[test]
    fn bad_edge_reference_rejected() {
        let s = GraphSchema::default().with_edge_set(
            "e",
            EdgeSetSpec {
                source: "missing".into(),
                target: "also_missing".into(),
                features: BTreeMap::new(),
                metadata: Metadata::default(),
            },
        );
        assert!(s.validate().is_err());
    }

    #[test]
    fn incident_edge_sets() {
        let s = recsys_example_schema();
        assert_eq!(s.edge_sets_into("users"), vec!["is-friend", "purchased"]);
        assert_eq!(s.edge_sets_from("items"), vec!["purchased"]);
        assert_eq!(s.edge_sets_from("users"), vec!["is-friend"]);
        assert!(s.edge_sets_into("items").is_empty());
    }

    #[test]
    fn feature_spec_helpers() {
        assert!(FeatureSpec::ragged_f32().is_ragged());
        assert!(!FeatureSpec::f32(&[128]).is_ragged());
        assert_eq!(FeatureSpec::f32(&[128]).dense_elems(), Some(128));
        assert_eq!(FeatureSpec::f32(&[3, 4]).dense_elems(), Some(12));
        assert_eq!(FeatureSpec::ragged_f32().dense_elems(), None);
        assert_eq!(FeatureSpec::i64(&[]).dense_elems(), Some(1));
    }

    #[test]
    fn dtype_names_roundtrip() {
        for d in [DType::F32, DType::I64, DType::Str] {
            assert_eq!(DType::from_name(d.name()).unwrap(), d);
        }
        // Protobuf-style names accepted for compatibility with A.6.1.
        assert_eq!(DType::from_name("DT_FLOAT").unwrap(), DType::F32);
        assert!(DType::from_name("complex128").is_err());
    }
}
