//! Schema text format: JSON carrying the same content as the paper's
//! protobuf `GraphSchema` (appendix A.6.1).
//!
//! ```json
//! {
//!   "node_sets": {
//!     "paper": {
//!       "features": {"feat": {"dtype": "float32", "shape": [128]}},
//!       "metadata": {"filename": "nodes-paper.rec@397", "cardinality": 736389}
//!     }
//!   },
//!   "edge_sets": {
//!     "cites": {"source": "paper", "target": "paper"}
//!   },
//!   "context": {"seconds": {"dtype": "int64", "shape": [1]}}
//! }
//! ```
//!
//! Ragged dims are `null` in the shape array.

use std::collections::BTreeMap;

use super::{DType, EdgeSetSpec, FeatureSpec, GraphSchema, Metadata, NodeSetSpec};
use crate::util::json::{obj, Json};
use crate::Result;

/// Serialize a schema to pretty JSON text.
pub fn to_text(schema: &GraphSchema) -> String {
    schema_to_json(schema).to_pretty()
}

/// Parse a schema from JSON text and validate it.
pub fn from_text(text: &str) -> Result<GraphSchema> {
    let v = Json::parse(text)?;
    let schema = schema_from_json(&v)?;
    schema.validate()?;
    Ok(schema)
}

/// Read a schema from a file path.
pub fn read_schema(path: &std::path::Path) -> Result<GraphSchema> {
    let text = std::fs::read_to_string(path)?;
    from_text(&text)
}

/// Write a schema to a file path.
pub fn write_schema(schema: &GraphSchema, path: &std::path::Path) -> Result<()> {
    std::fs::write(path, to_text(schema))?;
    Ok(())
}

pub fn schema_to_json(schema: &GraphSchema) -> Json {
    let node_sets = Json::Obj(
        schema
            .node_sets
            .iter()
            .map(|(k, ns)| {
                let mut fields = vec![("features", features_to_json(&ns.features))];
                if let Some(m) = metadata_to_json(&ns.metadata) {
                    fields.push(("metadata", m));
                }
                (k.clone(), obj(fields))
            })
            .collect(),
    );
    let edge_sets = Json::Obj(
        schema
            .edge_sets
            .iter()
            .map(|(k, es)| {
                let mut fields = vec![
                    ("source", Json::Str(es.source.clone())),
                    ("target", Json::Str(es.target.clone())),
                    ("features", features_to_json(&es.features)),
                ];
                if let Some(m) = metadata_to_json(&es.metadata) {
                    fields.push(("metadata", m));
                }
                (k.clone(), obj(fields))
            })
            .collect(),
    );
    obj(vec![
        ("context", features_to_json(&schema.context)),
        ("node_sets", node_sets),
        ("edge_sets", edge_sets),
    ])
}

pub fn schema_from_json(v: &Json) -> Result<GraphSchema> {
    let mut schema = GraphSchema::default();
    if let Some(ctx) = v.opt("context") {
        schema.context = features_from_json(ctx)?;
    }
    if let Some(ns) = v.opt("node_sets") {
        for (name, spec) in ns.as_obj()? {
            let features = match spec.opt("features") {
                Some(f) => features_from_json(f)?,
                None => BTreeMap::new(),
            };
            let metadata = metadata_from_json(spec.opt("metadata"))?;
            schema.node_sets.insert(name.clone(), NodeSetSpec { features, metadata });
        }
    }
    if let Some(es) = v.opt("edge_sets") {
        for (name, spec) in es.as_obj()? {
            let features = match spec.opt("features") {
                Some(f) => features_from_json(f)?,
                None => BTreeMap::new(),
            };
            schema.edge_sets.insert(
                name.clone(),
                EdgeSetSpec {
                    source: spec.get("source")?.as_str()?.to_string(),
                    target: spec.get("target")?.as_str()?.to_string(),
                    features,
                    metadata: metadata_from_json(spec.opt("metadata"))?,
                },
            );
        }
    }
    Ok(schema)
}

fn features_to_json(features: &BTreeMap<String, FeatureSpec>) -> Json {
    Json::Obj(
        features
            .iter()
            .map(|(k, f)| {
                let shape = Json::Arr(
                    f.shape
                        .iter()
                        .map(|d| match d {
                            Some(n) => Json::Int(*n as i64),
                            None => Json::Null,
                        })
                        .collect(),
                );
                (
                    k.clone(),
                    obj(vec![("dtype", Json::Str(f.dtype.name().into())), ("shape", shape)]),
                )
            })
            .collect(),
    )
}

fn features_from_json(v: &Json) -> Result<BTreeMap<String, FeatureSpec>> {
    let mut out = BTreeMap::new();
    for (name, spec) in v.as_obj()? {
        let dtype = DType::from_name(spec.get("dtype")?.as_str()?)?;
        let mut shape = Vec::new();
        if let Some(dims) = spec.opt("shape") {
            for d in dims.as_arr()? {
                match d {
                    Json::Null => shape.push(None),
                    other => shape.push(Some(other.as_usize()?)),
                }
            }
        }
        out.insert(name.clone(), FeatureSpec { dtype, shape });
    }
    Ok(out)
}

fn metadata_to_json(m: &Metadata) -> Option<Json> {
    if m.filename.is_none() && m.cardinality.is_none() {
        return None;
    }
    let mut fields = Vec::new();
    if let Some(f) = &m.filename {
        fields.push(("filename", Json::Str(f.clone())));
    }
    if let Some(c) = m.cardinality {
        fields.push(("cardinality", Json::Int(c as i64)));
    }
    Some(obj(fields))
}

fn metadata_from_json(v: Option<&Json>) -> Result<Metadata> {
    let Some(v) = v else { return Ok(Metadata::default()) };
    Ok(Metadata {
        filename: match v.opt("filename") {
            Some(f) => Some(f.as_str()?.to_string()),
            None => None,
        },
        cardinality: match v.opt("cardinality") {
            Some(c) => Some(c.as_i64()? as u64),
            None => None,
        },
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schema::recsys_example_schema;

    #[test]
    fn roundtrip_recsys() {
        let s = recsys_example_schema();
        let text = to_text(&s);
        let s2 = from_text(&text).unwrap();
        assert_eq!(s, s2);
    }

    #[test]
    fn roundtrip_with_metadata() {
        let mut s = recsys_example_schema();
        s.node_sets.get_mut("items").unwrap().metadata = Metadata {
            filename: Some("nodes-items.rec@4".into()),
            cardinality: Some(123456),
        };
        let s2 = from_text(&to_text(&s)).unwrap();
        assert_eq!(s, s2);
    }

    #[test]
    fn ragged_dims_as_null() {
        let s = recsys_example_schema();
        let text = to_text(&s);
        assert!(text.contains("null"), "ragged price dim serialized as null: {text}");
    }

    #[test]
    fn parse_mag_like_schema() {
        // Condensed version of appendix A.6.1.
        let text = r#"{
          "node_sets": {
            "paper": {"features": {
               "feat": {"dtype": "float32", "shape": [128]},
               "labels": {"dtype": "int64", "shape": [1]},
               "year": {"dtype": "int64", "shape": [1]}},
               "metadata": {"filename": "nodes-paper.rec@397", "cardinality": 736389}},
            "author": {"features": {}, "metadata": {"cardinality": 1134649}},
            "institution": {"features": {}},
            "field_of_study": {"features": {}}
          },
          "edge_sets": {
            "cites": {"source": "paper", "target": "paper"},
            "writes": {"source": "author", "target": "paper"},
            "affiliated_with": {"source": "author", "target": "institution"},
            "has_topic": {"source": "paper", "target": "field_of_study"}
          }
        }"#;
        let s = from_text(text).unwrap();
        assert_eq!(s.node_sets.len(), 4);
        assert_eq!(s.edge_sets.len(), 4);
        assert_eq!(s.node_set("paper").unwrap().features["feat"].dense_elems(), Some(128));
        assert_eq!(s.node_set("paper").unwrap().metadata.cardinality, Some(736389));
        assert_eq!(s.edge_set("writes").unwrap().target, "paper");
    }

    #[test]
    fn invalid_schema_text_rejected() {
        assert!(from_text("{").is_err());
        assert!(from_text(r#"{"edge_sets": {"e": {"source": "x", "target": "y"}}}"#).is_err());
        assert!(
            from_text(r#"{"node_sets": {"n": {"features": {"f": {"dtype": "quaternion"}}}}}"#)
                .is_err()
        );
    }

    #[test]
    fn file_roundtrip() {
        let s = recsys_example_schema();
        let dir = std::env::temp_dir().join(format!("tfgnn-schema-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("schema.json");
        write_schema(&s, &path).unwrap();
        let s2 = read_schema(&path).unwrap();
        assert_eq!(s, s2);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
