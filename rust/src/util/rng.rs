//! Deterministic pseudo-random number generation.
//!
//! All stochastic behaviour in the library (synthetic graph generation,
//! sampling strategies, shuffling, parameter sweeps) flows through
//! [`Rng`], a PCG-XSH-RR 64/32 generator seeded via SplitMix64. Being
//! fully deterministic per seed makes every experiment in
//! EXPERIMENTS.md reproducible bit-for-bit.

/// SplitMix64 step — used for seeding and cheap hash mixing.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Stateless 64-bit mix of two values — used to derive per-entity seeds
/// (e.g. per-node feature noise) without carrying RNG state around.
#[inline]
pub fn mix64(a: u64, b: u64) -> u64 {
    let mut s = a ^ b.rotate_left(32) ^ 0x9E3779B97F4A7C15;
    splitmix64(&mut s)
}

/// PCG-XSH-RR 64/32: small, fast, statistically solid.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Rng {
    /// Create a generator from a seed. Distinct seeds give independent
    /// streams (seed also perturbs the increment).
    pub fn new(seed: u64) -> Rng {
        let mut sm = seed;
        let state = splitmix64(&mut sm);
        let inc = splitmix64(&mut sm) | 1;
        let mut rng = Rng { state, inc };
        rng.next_u32(); // advance away from the seed-correlated state
        rng
    }

    /// Derive an independent child stream (for per-worker RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(mix64(self.next_u64(), tag))
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, n)` via Lemire's multiply-shift with rejection.
    #[inline]
    pub fn uniform(&mut self, n: usize) -> usize {
        assert!(n > 0, "uniform(0)");
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let (hi, lo) = mul_hi_lo(x, n);
            if lo < n {
                // Rejection zone: the low 2^64 % n values are over-represented.
                let threshold = n.wrapping_neg() % n;
                if lo < threshold {
                    continue;
                }
            }
            return hi as usize;
        }
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Geometric-ish power-law degree helper: samples from a Zipf-like
    /// distribution over `[1, max]` with exponent `alpha` via inverse
    /// transform on the continuous approximation.
    pub fn zipf(&mut self, max: usize, alpha: f64) -> usize {
        debug_assert!(alpha > 1.0);
        let u = self.f64();
        let m = max as f64;
        let a1 = 1.0 - alpha;
        let x = ((m.powf(a1) - 1.0) * u + 1.0).powf(1.0 / a1);
        (x as usize).clamp(1, max)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.uniform(i + 1);
            items.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (k <= n). Uses Floyd's
    /// algorithm: O(k) expected, no allocation of the full range.
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_distinct: k={k} > n={n}");
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.uniform(j + 1);
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        out
    }

    /// Sample `k` items from `[0, n)` **with** replacement.
    pub fn sample_with_replacement(&mut self, n: usize, k: usize) -> Vec<usize> {
        (0..k).map(|_| self.uniform(n)).collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.uniform(items.len())]
    }
}

#[inline]
fn mul_hi_lo(a: u64, b: u64) -> (u64, u64) {
    let wide = (a as u128) * (b as u128);
    ((wide >> 64) as u64, wide as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn distinct_seeds_distinct_streams() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_in_range_and_covers() {
        let mut rng = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = rng.uniform(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit");
    }

    #[test]
    fn uniform_roughly_uniform() {
        let mut rng = Rng::new(4);
        let mut counts = [0usize; 8];
        let n = 80_000;
        for _ in 0..n {
            counts[rng.uniform(8)] += 1;
        }
        for &c in &counts {
            let expect = n / 8;
            assert!(
                (c as i64 - expect as i64).unsigned_abs() < (expect / 10) as u64,
                "bucket count {c} vs {expect}"
            );
        }
    }

    #[test]
    fn f32_bounds_and_mean() {
        let mut rng = Rng::new(5);
        let mut sum = 0.0f64;
        for _ in 0..10_000 {
            let x = rng.f32();
            assert!((0.0..1.0).contains(&x));
            sum += x as f64;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(6);
        let n = 50_000;
        let (mut s, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = rng.normal() as f64;
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(8);
        let mut v: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>(), "shuffle changed order");
    }

    #[test]
    fn sample_distinct_properties() {
        let mut rng = Rng::new(9);
        for n in [1usize, 5, 20, 100] {
            for k in [0usize, 1, n / 2, n] {
                let s = rng.sample_distinct(n, k);
                assert_eq!(s.len(), k);
                let set: std::collections::HashSet<_> = s.iter().collect();
                assert_eq!(set.len(), k, "distinct");
                assert!(s.iter().all(|&x| x < n));
            }
        }
    }

    #[test]
    fn zipf_bounds() {
        let mut rng = Rng::new(10);
        for _ in 0..1000 {
            let z = rng.zipf(50, 2.0);
            assert!((1..=50).contains(&z));
        }
        // Head should be heavier than tail.
        let mut head = 0;
        let mut tail = 0;
        for _ in 0..5000 {
            let z = rng.zipf(50, 2.0);
            if z <= 5 {
                head += 1;
            } else if z > 25 {
                tail += 1;
            }
        }
        assert!(head > tail * 3, "head {head} tail {tail}");
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(11);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
