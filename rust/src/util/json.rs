//! Minimal JSON parser and serializer.
//!
//! Used for the AOT artifact manifest (`artifacts/manifest.json`), the
//! schema text format, checkpoint metadata and bench reports. Supports
//! the full JSON grammar (RFC 8259) minus `\u` surrogate pairs beyond
//! the BMP; numbers are parsed as `f64` with an exact-`i64` fast path.

use std::collections::BTreeMap;
use std::fmt;

use crate::{Error, Result};

/// A JSON value. Object keys are kept sorted (BTreeMap) so serialization
/// is deterministic — important for golden tests and checksums.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    /// Integer fast path: round-trips i64 exactly.
    Int(i64),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(Error::Codec(format!(
                "trailing data at byte {} of JSON document",
                p.i
            )));
        }
        Ok(v)
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Int(i) => out.push_str(&i.to_string()),
            Json::Num(n) => {
                if n.is_finite() {
                    // Shortest repr that round-trips through f64.
                    let s = format!("{n}");
                    out.push_str(&s);
                    if !s.contains('.') && !s.contains('e') && !s.contains('E') {
                        out.push_str(".0");
                    }
                } else {
                    // JSON has no Inf/NaN; emit null like most encoders.
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (k, item) in items.iter().enumerate() {
                    if k > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (k, (key, val)) in map.iter().enumerate() {
                    if k > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, key);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    val.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }

    // ---- typed accessors -------------------------------------------------

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            other => Err(Error::Codec(format!("expected object, got {}", other.kind()))),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            other => Err(Error::Codec(format!("expected array, got {}", other.kind()))),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => Err(Error::Codec(format!("expected string, got {}", other.kind()))),
        }
    }

    pub fn as_i64(&self) -> Result<i64> {
        match self {
            Json::Int(i) => Ok(*i),
            Json::Num(n) if n.fract() == 0.0 && n.abs() < 9e15 => Ok(*n as i64),
            other => Err(Error::Codec(format!("expected integer, got {}", other.kind()))),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let i = self.as_i64()?;
        usize::try_from(i).map_err(|_| Error::Codec(format!("expected usize, got {i}")))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Int(i) => Ok(*i as f64),
            Json::Num(n) => Ok(*n),
            other => Err(Error::Codec(format!("expected number, got {}", other.kind()))),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => Err(Error::Codec(format!("expected bool, got {}", other.kind()))),
        }
    }

    /// Object field lookup with a path-aware error.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| Error::Codec(format!("missing key {key:?}")))
    }

    /// Optional object field.
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    fn kind(&self) -> &'static str {
        match self {
            Json::Null => "null",
            Json::Bool(_) => "bool",
            Json::Int(_) | Json::Num(_) => "number",
            Json::Str(_) => "string",
            Json::Arr(_) => "array",
            Json::Obj(_) => "object",
        }
    }
}

/// Convenience: build a `Json::Obj` from pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Convenience: `Json::Arr` of strings.
pub fn str_arr<S: AsRef<str>>(items: &[S]) -> Json {
    Json::Arr(items.iter().map(|s| Json::Str(s.as_ref().to_string())).collect())
}

/// Convenience: `Json::Arr` of usize.
pub fn usize_arr(items: &[usize]) -> Json {
    Json::Arr(items.iter().map(|&u| Json::Int(u as i64)).collect())
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Codec(format!("JSON parse error at byte {}: {}", self.i, msg))
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected character {:?}", c as char))),
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("unterminated escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            s.push(
                                char::from_u32(code)
                                    .ok_or_else(|| self.err("surrogate \\u escape"))?,
                            );
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                _ => {
                    // Re-consume as UTF-8: back up and take the full char.
                    self.i -= 1;
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid UTF-8"))?;
                    let Some(ch) = rest.chars().next() else {
                        return Err(self.err("truncated string"));
                    };
                    if (ch as u32) < 0x20 {
                        return Err(self.err("control character in string"));
                    }
                    s.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.i += 1;
        }
        let mut is_float = false;
        if self.peek() == Some(b'.') {
            is_float = true;
            self.i += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            is_float = true;
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        // The scanned span is ASCII digits/sign/dot/exponent only.
        let text = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| self.err("bad number"))?;
        if !is_float {
            if let Ok(i) = text.parse::<i64>() {
                return Ok(Json::Int(i));
            }
        }
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Int(42));
        assert_eq!(Json::parse("-7").unwrap(), Json::Int(-7));
        assert_eq!(Json::parse("1.5").unwrap(), Json::Num(1.5));
        assert_eq!(Json::parse("1e3").unwrap(), Json::Num(1000.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[2].get("b").unwrap(), &Json::Null);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""a\nb\t\"q\" A \\""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"q\" A \\");
    }

    #[test]
    fn parse_unicode_passthrough() {
        let v = Json::parse("\"héllo ☃\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo ☃");
    }

    #[test]
    fn roundtrip() {
        let cases = [
            r#"{"a":[1,2.5,null,true],"b":{"c":"d\ne"}}"#,
            "[]",
            "{}",
            r#"[[[1]]]"#,
            r#"{"x":-0.25}"#,
        ];
        for c in cases {
            let v = Json::parse(c).unwrap();
            let v2 = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, v2, "case {c}");
        }
    }

    #[test]
    fn pretty_roundtrip() {
        let v = Json::parse(r#"{"a":[1,2],"b":"x"}"#).unwrap();
        let v2 = Json::parse(&v.to_pretty()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn deterministic_key_order() {
        let v = Json::parse(r#"{"z":1,"a":2}"#).unwrap();
        assert_eq!(v.to_string(), r#"{"a":2,"z":1}"#);
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["{", "[1,", "tru", "\"x", "{\"a\" 1}", "1 2", "{,}", ""] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn float_int_distinction() {
        assert_eq!(Json::parse("3").unwrap().as_f64().unwrap(), 3.0);
        assert_eq!(Json::parse("3.0").unwrap().as_f64().unwrap(), 3.0);
        assert!(Json::parse("3.5").unwrap().as_i64().is_err());
        // Float that is integral can still be read as i64 (manifest leniency).
        assert_eq!(Json::parse("3.0").unwrap().as_i64().unwrap(), 3);
    }

    #[test]
    fn num_serialization_keeps_float_marker() {
        assert_eq!(Json::Num(2.0).to_string(), "2.0");
        assert_eq!(Json::Int(2).to_string(), "2");
    }
}
