//! A small fixed-size thread pool over `std::sync::mpsc`.
//!
//! Used by the distributed sampler's worker fleet, the pipeline's
//! parallel parse stage, and the sweep harness. Supports fire-and-forget
//! jobs, scoped parallel-map with result collection, and clean shutdown
//! on drop.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size worker pool.
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    in_flight: Arc<AtomicUsize>,
}

impl ThreadPool {
    /// Spawn `n` workers (n >= 1).
    pub fn new(n: usize) -> ThreadPool {
        assert!(n > 0, "ThreadPool::new(0)");
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let in_flight = Arc::new(AtomicUsize::new(0));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let in_flight = Arc::clone(&in_flight);
                std::thread::Builder::new()
                    .name(format!("tfgnn-pool-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                job();
                                in_flight.fetch_sub(1, Ordering::Release);
                            }
                            Err(_) => break, // sender dropped: shutdown
                        }
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, in_flight }
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Enqueue a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.in_flight.fetch_add(1, Ordering::Acquire);
        self.tx
            .as_ref()
            .expect("pool already shut down")
            .send(Box::new(f))
            .expect("pool workers gone");
    }

    /// Block until all submitted jobs have completed.
    pub fn wait_idle(&self) {
        while self.in_flight.load(Ordering::Acquire) > 0 {
            std::thread::yield_now();
        }
    }

    /// Parallel map: applies `f` to each item, preserving order.
    ///
    /// `f` must be `Sync` because multiple workers call it concurrently.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        let f = Arc::new(f);
        let (rtx, rrx): (Sender<(usize, R)>, Receiver<(usize, R)>) = channel();
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let rtx = rtx.clone();
            self.execute(move || {
                let r = f(item);
                // Receiver may be gone if the caller panicked; ignore.
                let _ = rtx.send((i, r));
            });
        }
        drop(rtx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, r) = rrx.recv().expect("worker died before sending result");
            out[i] = Some(r);
        }
        out.into_iter().map(|o| o.unwrap()).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close channel -> workers exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect::<Vec<usize>>(), |x| x * x);
        assert_eq!(out, (0..50).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn map_empty() {
        let pool = ThreadPool::new(2);
        let out: Vec<usize> = pool.map(Vec::<usize>::new(), |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                std::thread::sleep(std::time::Duration::from_millis(1));
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // must block until queue drained by workers…
        // NOTE: drop closes the channel; already-queued jobs still run
        // because workers drain the channel before seeing disconnect.
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn parallel_speedup_smoke() {
        // Not a perf assertion, just exercises concurrency paths.
        let pool = ThreadPool::new(8);
        let out = pool.map((0..64).collect::<Vec<u64>>(), |x| {
            let mut acc = x;
            for _ in 0..1000 {
                acc = acc.wrapping_mul(6364136223846793005).wrapping_add(1);
            }
            acc
        });
        assert_eq!(out.len(), 64);
    }
}
