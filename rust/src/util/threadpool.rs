//! A small fixed-size thread pool over `std::sync::mpsc`.
//!
//! Used by the distributed sampler's worker fleet, the pipeline's
//! parallel parse stage, the fused parallel graph ops
//! (`ops::ParallelOps`), and the sweep harness. Supports fire-and-forget
//! jobs, scoped parallel-map with result collection (panics in the
//! mapped closure propagate to the caller), and clean shutdown on drop.
//!
//! Panic safety: a panicking job must neither kill its worker thread
//! nor leak an `in_flight` increment — otherwise `wait_idle()` blocks
//! forever and `map()` sees its result channel die. Jobs therefore run
//! under `catch_unwind`, and the in-flight count is decremented by a
//! drop guard that runs even while unwinding. The count lives behind a
//! `Mutex` paired with a `Condvar`, so `wait_idle` blocks instead of
//! spinning on `yield_now` (the earlier atomic-counter design also had
//! its `fetch_add`/`fetch_sub` orderings inverted; the lock supersedes
//! that entirely).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, SendError, Sender};
use std::sync::{Arc, Condvar, Mutex, PoisonError};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Job accounting shared between the pool handle and its workers.
struct Shared {
    in_flight: Mutex<usize>,
    idle: Condvar,
}

/// Decrements `in_flight` when dropped — also during a panic unwind, so
/// a panicking job can never strand `wait_idle`.
struct InFlightGuard(Arc<Shared>);

impl Drop for InFlightGuard {
    fn drop(&mut self) {
        // The count is plain arithmetic, so a poisoned lock's data is
        // still coherent — take it rather than double-panicking inside
        // a drop during unwind.
        let mut n = self.0.in_flight.lock().unwrap_or_else(PoisonError::into_inner);
        *n -= 1;
        if *n == 0 {
            self.0.idle.notify_all();
        }
    }
}

/// Fixed-size worker pool.
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    shared: Arc<Shared>,
}

impl ThreadPool {
    /// Spawn `n` workers (n >= 1).
    pub fn new(n: usize) -> ThreadPool {
        assert!(n > 0, "ThreadPool::new(0)");
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let shared = Arc::new(Shared { in_flight: Mutex::new(0), idle: Condvar::new() });
        let workers = (0..n)
            .filter_map(|i| {
                let rx = Arc::clone(&rx);
                let shared = Arc::clone(&shared);
                // A failed spawn (resource exhaustion) just shrinks the
                // pool; `execute` falls back to running inline if every
                // spawn failed, so jobs still complete.
                std::thread::Builder::new()
                    .name(format!("tfgnn-pool-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard =
                                rx.lock().unwrap_or_else(PoisonError::into_inner);
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                let _guard = InFlightGuard(Arc::clone(&shared));
                                // Swallow the panic here so the worker
                                // survives; `map` re-raises it in the
                                // caller via its result channel.
                                let _ = catch_unwind(AssertUnwindSafe(job));
                            }
                            Err(_) => break, // sender dropped: shutdown
                        }
                    })
                    .ok()
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, shared }
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Enqueue a job. A panic inside the job is caught on the worker
    /// (fire-and-forget jobs have nowhere to surface it). If no worker
    /// can take the job (all spawns failed), it runs inline here — the
    /// job and its in-flight accounting still happen.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        crate::obs_counter!(crate::obs::metrics::names::THREADPOOL_JOBS).inc();
        *self.shared.in_flight.lock().unwrap_or_else(PoisonError::into_inner) += 1;
        // Only while recording does the job get wrapped with queue-wait
        // and execute timing (plus a span) — the disabled path stays
        // exactly one boxed closure with no clock reads.
        let job: Job = if crate::obs::recording() {
            let queued_at = std::time::Instant::now();
            Box::new(move || {
                crate::obs_histogram!(
                    crate::obs::metrics::names::THREADPOOL_QUEUE_WAIT_SECONDS
                )
                .record(queued_at.elapsed().as_secs_f64());
                let _span = crate::span!("pool/job");
                let _exec = crate::obs::timed(crate::obs_histogram!(
                    crate::obs::metrics::names::THREADPOOL_EXECUTE_SECONDS
                ));
                f();
            })
        } else {
            Box::new(f)
        };
        let rejected = match self.tx.as_ref() {
            Some(tx) => tx.send(job).err().map(|SendError(job)| job),
            None => Some(job),
        };
        if let Some(job) = rejected {
            let _guard = InFlightGuard(Arc::clone(&self.shared));
            let _ = catch_unwind(AssertUnwindSafe(job));
        }
    }

    /// Block until all submitted jobs have completed (including jobs
    /// that panicked).
    pub fn wait_idle(&self) {
        let mut n = self.shared.in_flight.lock().unwrap_or_else(PoisonError::into_inner);
        while *n > 0 {
            n = self.shared.idle.wait(n).unwrap_or_else(PoisonError::into_inner);
        }
    }

    /// Parallel map: applies `f` to each item, preserving order.
    ///
    /// `f` must be `Sync` because multiple workers call it concurrently.
    /// If `f` panics on any item, the panic is re-raised here (after all
    /// results have been collected) and the pool remains usable.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        let f = Arc::new(f);
        type Slot<R> = (usize, std::thread::Result<R>);
        let (rtx, rrx): (Sender<Slot<R>>, Receiver<Slot<R>>) = channel();
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let rtx = rtx.clone();
            self.execute(move || {
                let r = catch_unwind(AssertUnwindSafe(|| f(item)));
                // Receiver may be gone if the caller panicked; ignore.
                let _ = rtx.send((i, r));
            });
        }
        drop(rtx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        let mut panic_payload = None;
        for _ in 0..n {
            // Every job sends exactly one result (workers survive job
            // panics, and jobs the queue rejects run inline), so a dead
            // channel just means the results are exhausted.
            match rrx.recv() {
                Ok((i, Ok(r))) => out[i] = Some(r),
                Ok((_, Err(payload))) => panic_payload = Some(payload),
                Err(_) => break,
            }
        }
        if let Some(payload) = panic_payload {
            std::panic::resume_unwind(payload);
        }
        out.into_iter().flatten().collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close channel -> workers exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect::<Vec<usize>>(), |x| x * x);
        assert_eq!(out, (0..50).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn map_empty() {
        let pool = ThreadPool::new(2);
        let out: Vec<usize> = pool.map(Vec::<usize>::new(), |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                std::thread::sleep(std::time::Duration::from_millis(1));
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // must block until queue drained by workers…
        // NOTE: drop closes the channel; already-queued jobs still run
        // because workers drain the channel before seeing disconnect.
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn parallel_speedup_smoke() {
        // Not a perf assertion, just exercises concurrency paths.
        let pool = ThreadPool::new(8);
        let out = pool.map((0..64).collect::<Vec<u64>>(), |x| {
            let mut acc = x;
            for _ in 0..1000 {
                acc = acc.wrapping_mul(6364136223846793005).wrapping_add(1);
            }
            acc
        });
        assert_eq!(out.len(), 64);
    }

    /// Regression: a panicking job used to kill its worker without
    /// decrementing `in_flight`, hanging `wait_idle` forever.
    #[test]
    fn panicking_job_does_not_hang_wait_idle() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        for i in 0..20 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                if i % 4 == 0 {
                    panic!("job {i} exploded");
                }
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle(); // must return despite 5 panics
        assert_eq!(counter.load(Ordering::SeqCst), 15);
        // All workers survived; the pool is still fully usable.
        let out = pool.map(vec![1usize, 2, 3], |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    /// The observability wrap (jobs counter always; timing while
    /// recording) must never change what `map` returns.
    #[test]
    fn instrumentation_is_inert_for_map_results() {
        let jobs = crate::obs::metrics::global()
            .counter(crate::obs::metrics::names::THREADPOOL_JOBS);
        let before = jobs.get();
        let pool = ThreadPool::new(2);
        crate::obs::set_recording(true);
        let on = pool.map((0..16).collect::<Vec<u64>>(), |x| x.wrapping_mul(3));
        crate::obs::set_recording(false);
        let off = pool.map((0..16).collect::<Vec<u64>>(), |x| x.wrapping_mul(3));
        assert_eq!(on, off, "recording must not change results");
        assert!(jobs.get() >= before + 32, "every job counts");
    }

    /// Regression: `map` used to die with "worker died before sending
    /// result" when `f` panicked; now the panic propagates to the
    /// caller and the pool survives.
    #[test]
    fn map_propagates_panic_and_pool_survives() {
        let pool = ThreadPool::new(3);
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.map((0..10).collect::<Vec<usize>>(), |x| {
                if x == 7 {
                    panic!("boom at {x}");
                }
                x
            })
        }));
        assert!(r.is_err(), "map must re-raise the closure panic");
        pool.wait_idle();
        let out = pool.map((0..10).collect::<Vec<usize>>(), |x| x * 2);
        assert_eq!(out.len(), 10);
    }
}
