//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional
//! arguments, with typed accessors and a generated usage string. Used by
//! `rust/src/main.rs` and the examples.

use std::collections::BTreeMap;

use crate::{Error, Result};

/// Parsed command line: positionals plus `--key [value]` options.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    options: BTreeMap<String, Vec<String>>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut args = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if rest.is_empty() {
                    // `--` terminates option parsing.
                    args.positional.extend(it.by_ref());
                    break;
                }
                let (key, inline_val) = match rest.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                let val = match inline_val {
                    Some(v) => Some(v),
                    None => {
                        // Take the next token as a value unless it looks
                        // like another option.
                        match it.peek() {
                            Some(next) if !next.starts_with("--") => it.next(),
                            _ => None,
                        }
                    }
                };
                let entry = args.options.entry(key).or_default();
                if let Some(v) = val {
                    entry.push(v);
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    /// Parse the process arguments.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Was `--key` present (with or without a value)?
    pub fn flag(&self, key: &str) -> bool {
        self.options.contains_key(key)
    }

    /// Last value of `--key`, if any.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).and_then(|v| v.last()).map(|s| s.as_str())
    }

    /// All values of a repeated `--key`.
    pub fn get_all(&self, key: &str) -> Vec<&str> {
        self.options
            .get(key)
            .map(|v| v.iter().map(|s| s.as_str()).collect())
            .unwrap_or_default()
    }

    /// Required string option.
    pub fn req(&self, key: &str) -> Result<&str> {
        self.get(key)
            .ok_or_else(|| Error::Pipeline(format!("missing required option --{key}")))
    }

    /// Typed option with a default.
    pub fn get_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s
                .parse::<T>()
                .map_err(|_| Error::Pipeline(format!("bad value for --{key}: {s:?}"))),
        }
    }

    /// First positional (typically the subcommand), if any.
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    /// Positionals after the subcommand.
    pub fn rest(&self) -> &[String] {
        if self.positional.is_empty() {
            &[]
        } else {
            &self.positional[1..]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|t| t.to_string()))
    }

    #[test]
    fn positional_and_flags() {
        let a = parse("train --steps 100 --verbose --out=dir/x");
        assert_eq!(a.subcommand(), Some("train"));
        assert_eq!(a.get("steps"), Some("100"));
        assert!(a.flag("verbose"));
        assert_eq!(a.get("out"), Some("dir/x"));
    }

    #[test]
    fn typed_defaults() {
        let a = parse("x --n 5");
        assert_eq!(a.get_or("n", 0usize).unwrap(), 5);
        assert_eq!(a.get_or("m", 7usize).unwrap(), 7);
        assert!(a.get_or::<usize>("n", 0).is_ok());
        let b = parse("x --n five");
        assert!(b.get_or::<usize>("n", 0).is_err());
    }

    #[test]
    fn flag_followed_by_option() {
        let a = parse("run --fast --steps 3");
        assert!(a.flag("fast"));
        assert_eq!(a.get("steps"), Some("3"));
        // --fast consumed no value because --steps starts with --.
        assert_eq!(a.get("fast"), None);
    }

    #[test]
    fn repeated_options() {
        let a = parse("x --dim 1 --dim 2 --dim 3");
        assert_eq!(a.get_all("dim"), vec!["1", "2", "3"]);
        assert_eq!(a.get("dim"), Some("3"));
    }

    #[test]
    fn double_dash_terminator() {
        let a = parse("x --k v -- --not-an-option pos2");
        assert_eq!(a.positional, vec!["x", "--not-an-option", "pos2"]);
    }

    #[test]
    fn required_missing() {
        let a = parse("x");
        assert!(a.req("needed").is_err());
    }

    #[test]
    fn negative_number_as_value() {
        // A value starting with '-' (not '--') is consumed as a value.
        let a = parse("x --lr -0.5");
        assert_eq!(a.get("lr"), Some("-0.5"));
    }
}
