//! Miniature property-testing harness (proptest is unavailable offline).
//!
//! [`check`] runs a property against many deterministically generated
//! random cases; on failure it reports the seed of the failing case so
//! the exact input can be replayed with [`replay`]. Generators are plain
//! closures over [`Rng`], composing via ordinary Rust.
//!
//! ```no_run
//! // (no_run: doctest binaries don't get the workspace rpath to
//! //  libxla_extension's bundled libstdc++ on this image)
//! use tfgnn::util::proptest::check;
//! check("reverse twice is identity", 200, |rng| {
//!     let n = rng.uniform(20);
//!     let v: Vec<u32> = (0..n).map(|_| rng.next_u32()).collect();
//!     let mut w = v.clone();
//!     w.reverse();
//!     w.reverse();
//!     assert_eq!(v, w);
//! });
//! ```

use super::rng::Rng;

/// Environment knob: `TFGNN_PROPTEST_CASES` multiplies case counts
/// (e.g. set to 10 for a deep overnight run).
fn case_multiplier() -> usize {
    std::env::var("TFGNN_PROPTEST_CASES")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .unwrap_or(1)
        .max(1)
}

/// Run `prop` against `cases` random inputs. Each case gets an `Rng`
/// seeded from the property name and case index, so failures are
/// reproducible independent of execution order.
pub fn check<F: Fn(&mut Rng) + std::panic::RefUnwindSafe>(name: &str, cases: usize, prop: F) {
    let cases = cases * case_multiplier();
    for case in 0..cases {
        let seed = seed_for(name, case as u64);
        let result = std::panic::catch_unwind(|| {
            let mut rng = Rng::new(seed);
            prop(&mut rng);
        });
        if let Err(payload) = result {
            let msg = panic_message(&payload);
            // Re-raise with the replay context attached; resume_unwind
            // keeps this harness free of `panic!` in library code.
            std::panic::resume_unwind(Box::new(format!(
                "property {name:?} failed on case {case} (replay seed {seed:#x}): {msg}"
            )));
        }
    }
}

/// Replay a single failing case by seed (used while debugging).
pub fn replay<F: FnMut(&mut Rng)>(seed: u64, mut prop: F) {
    let mut rng = Rng::new(seed);
    prop(&mut rng);
}

fn seed_for(name: &str, case: u64) -> u64 {
    // FNV-1a over the name, mixed with the case index.
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    super::rng::mix64(h, case)
}

fn panic_message(payload: &Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        s.to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic>".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add commutes", 50, |rng| {
            let a = rng.uniform(1000) as i64;
            let b = rng.uniform(1000) as i64;
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    fn failing_property_reports_seed() {
        let r = std::panic::catch_unwind(|| {
            check("always fails", 3, |_rng| {
                panic!("boom");
            });
        });
        let msg = panic_message(&r.unwrap_err());
        assert!(msg.contains("replay seed"), "{msg}");
        assert!(msg.contains("boom"), "{msg}");
    }

    #[test]
    fn seeds_are_stable() {
        // Pin the derivation so failures stay replayable across refactors.
        assert_eq!(seed_for("x", 0), seed_for("x", 0));
        assert_ne!(seed_for("x", 0), seed_for("x", 1));
        assert_ne!(seed_for("x", 0), seed_for("y", 0));
    }

    #[test]
    fn replay_reproduces_case_stream() {
        let seed = seed_for("stream", 4);
        let mut first = Vec::new();
        replay(seed, |rng| {
            first.push(rng.next_u64());
        });
        let mut second = Vec::new();
        replay(seed, |rng| {
            second.push(rng.next_u64());
        });
        assert_eq!(first, second);
    }
}
