//! Summary statistics and a micro-bench timer.
//!
//! `cargo bench` targets in this repo use `harness = false` (criterion is
//! not available offline), so [`Bench`] provides the warmup → repeat →
//! summarize loop and prints rows that the bench binaries format into the
//! paper's tables.
//!
//! The perf-tracking CI lane drives two knobs here: [`smoke`] /
//! [`Bench::from_env`] cap iteration counts (`TFGNN_BENCH_SMOKE=1`) so
//! the bench binaries finish in seconds, and [`BenchReport`] records
//! every row machine-readably (`name`, `threads`, `ns_per_op`, …) and
//! writes `BENCH_<bench>.json` for upload as a per-PR artifact.

use std::path::PathBuf;
use std::time::{Duration, Instant};

use super::json::{obj, Json};

/// Summary of a sample of f64 observations.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    /// 99.9th percentile — the serving-tail figure of merit; with
    /// fewer than ~1000 samples it interpolates toward `max`.
    pub p999: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::of(empty)");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.total_cmp(b));
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            p50: percentile(&sorted, 0.50),
            p95: percentile(&sorted, 0.95),
            p99: percentile(&sorted, 0.99),
            p999: percentile(&sorted, 0.999),
            max: sorted[n - 1],
        }
    }
}

/// Percentile of an already-sorted sample (linear interpolation).
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Mean ± std of accuracy-like observations, formatted as the paper
/// prints Table 1 (four decimal places).
pub fn fmt_mean_std(samples: &[f64]) -> String {
    let s = Summary::of(samples);
    format!("{:.4} ± {:.4}", s.mean, s.std)
}

/// Format a duration human-readably for bench rows.
pub fn fmt_duration(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

/// Micro-bench runner: warms up, then measures `iters` runs of `f`.
pub struct Bench {
    pub warmup: usize,
    pub iters: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup: 3, iters: 10 }
    }
}

impl Bench {
    pub fn new(warmup: usize, iters: usize) -> Bench {
        Bench { warmup, iters }
    }

    /// `new(warmup, iters)`, collapsed to `(0, 2)` in smoke mode — the
    /// CI lane's env-capped iteration counts.
    pub fn from_env(warmup: usize, iters: usize) -> Bench {
        if smoke() {
            Bench::new(0, 2)
        } else {
            Bench::new(warmup, iters)
        }
    }

    /// Run and summarize wall time in seconds per iteration.
    pub fn run<F: FnMut()>(&self, mut f: F) -> Summary {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        Summary::of(&samples)
    }

    /// Run, report a throughput summary (`items / sec`) for a workload of
    /// `items` units per iteration.
    pub fn throughput<F: FnMut()>(&self, items: usize, f: F) -> Summary {
        let time = self.run(f);
        // Throughput distribution: items / time for each sample is not
        // recoverable from the summary, so convert mean/percentiles.
        Summary {
            n: time.n,
            mean: items as f64 / time.mean,
            std: items as f64 * time.std / (time.mean * time.mean),
            min: items as f64 / time.max,
            p50: items as f64 / time.p50,
            p95: items as f64 / time.min,
            p99: items as f64 / time.min,
            p999: items as f64 / time.min,
            max: items as f64 / time.min,
        }
    }
}

/// A labelled bench row printer producing aligned, greppable output:
/// `BENCH <group> <name> mean=… p50=… p95=…`.
pub fn print_row(group: &str, name: &str, s: &Summary, unit: &str) {
    println!(
        "BENCH {group:<24} {name:<32} mean={:>12} p50={:>12} p95={:>12} n={}",
        fmt_value(s.mean, unit),
        fmt_value(s.p50, unit),
        fmt_value(s.p95, unit),
        s.n
    );
}

/// True when the benches run in short "smoke" mode
/// (`TFGNN_BENCH_SMOKE=1`): workloads shrink and iteration counts
/// collapse so the CI job finishes fast while still emitting every
/// `BENCH_*.json` row.
pub fn smoke() -> bool {
    std::env::var("TFGNN_BENCH_SMOKE").map(|v| v == "1" || v == "true").unwrap_or(false)
}

/// One machine-readable bench row.
#[derive(Debug, Clone)]
pub struct BenchRow {
    /// `group/name` label, stable across PRs so rows can be diffed.
    pub name: String,
    /// Parallelism of the measured configuration (1 = serial).
    pub threads: usize,
    /// Nanoseconds per item (derived from the summary and unit).
    pub ns_per_op: f64,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub p999: f64,
    pub unit: String,
    /// Optional per-row observability delta: the compact form of the
    /// metrics-registry counters this row's workload moved (see
    /// [`crate::obs::metrics::MetricsSnapshot::to_compact_json`]).
    /// `None` keeps the field out of the JSON, so old baselines and
    /// new rows stay comparable.
    pub metrics: Option<Json>,
}

/// Collects bench rows, echoing each through [`print_row`], and writes
/// them as `BENCH_<bench>.json` — the artifact the `bench-smoke` CI job
/// uploads so the perf trajectory is tracked per PR.
pub struct BenchReport {
    bench: String,
    rows: Vec<BenchRow>,
}

impl BenchReport {
    pub fn new(bench: &str) -> BenchReport {
        BenchReport { bench: bench.to_string(), rows: Vec::new() }
    }

    /// Record and print one row. `threads` is the configuration's
    /// parallelism (1 for serial rows).
    pub fn row(&mut self, group: &str, name: &str, threads: usize, s: &Summary, unit: &str) {
        self.row_with_metrics(group, name, threads, s, unit, None);
    }

    /// [`row`](Self::row), attaching a per-row metrics delta (the
    /// compact snapshot of what the workload moved in the registry).
    pub fn row_with_metrics(
        &mut self,
        group: &str,
        name: &str,
        threads: usize,
        s: &Summary,
        unit: &str,
        metrics: Option<Json>,
    ) {
        print_row(group, name, s, unit);
        let ns_per_op = match unit {
            "items/s" if s.mean > 0.0 => 1e9 / s.mean,
            "s" => s.mean * 1e9,
            _ => f64::NAN, // serialized as null
        };
        self.rows.push(BenchRow {
            name: format!("{group}/{name}"),
            threads,
            ns_per_op,
            mean: s.mean,
            p50: s.p50,
            p95: s.p95,
            p99: s.p99,
            p999: s.p999,
            unit: unit.to_string(),
            metrics,
        });
    }

    /// Serialize to the artifact JSON document.
    pub fn to_json(&self) -> Json {
        let rows: Vec<Json> = self
            .rows
            .iter()
            .map(|r| {
                let mut fields = vec![
                    ("name", Json::Str(r.name.clone())),
                    ("threads", Json::Int(r.threads as i64)),
                    ("ns_per_op", Json::Num(r.ns_per_op)),
                    ("mean", Json::Num(r.mean)),
                    ("p50", Json::Num(r.p50)),
                    ("p95", Json::Num(r.p95)),
                    ("p99", Json::Num(r.p99)),
                    ("p999", Json::Num(r.p999)),
                    ("unit", Json::Str(r.unit.clone())),
                ];
                if let Some(m) = &r.metrics {
                    fields.push(("metrics", m.clone()));
                }
                obj(fields)
            })
            .collect();
        obj(vec![
            ("bench", Json::Str(self.bench.clone())),
            ("smoke", Json::Bool(smoke())),
            ("rows", Json::Arr(rows)),
        ])
    }

    /// Write the artifact to `path`.
    pub fn write_to(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_pretty())
    }

    /// Write to `$TFGNN_BENCH_JSON` if set, else `BENCH_<bench>.json`
    /// in the working directory; returns the path written.
    pub fn write(&self) -> std::io::Result<PathBuf> {
        let path = std::env::var("TFGNN_BENCH_JSON")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from(format!("BENCH_{}.json", self.bench)));
        self.write_to(&path)?;
        Ok(path)
    }
}

fn fmt_value(v: f64, unit: &str) -> String {
    match unit {
        "s" => fmt_duration(Duration::from_secs_f64(v.max(0.0))),
        "items/s" => {
            if v >= 1e6 {
                format!("{:.2}M/s", v / 1e6)
            } else if v >= 1e3 {
                format!("{:.2}K/s", v / 1e3)
            } else {
                format!("{v:.1}/s")
            }
        }
        _ => format!("{v:.4}{unit}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!((s.min - 1.0).abs() < 1e-12);
        assert!((s.max - 4.0).abs() < 1e-12);
        assert!((s.p50 - 2.5).abs() < 1e-12);
    }

    #[test]
    fn summary_single() {
        let s = Summary::of(&[5.0]);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.p50, 5.0);
        assert_eq!(s.p95, 5.0);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert!((percentile(&sorted, 0.5) - 5.0).abs() < 1e-12);
        assert!((percentile(&sorted, 0.95) - 9.5).abs() < 1e-12);
    }

    #[test]
    fn std_matches_hand_calc() {
        let s = Summary::of(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        // Sample std of this classic set is ~2.138.
        assert!((s.std - 2.138).abs() < 0.01, "std {}", s.std);
    }

    #[test]
    fn bench_runs_expected_iters() {
        let mut count = 0;
        let b = Bench::new(2, 5);
        let s = b.run(|| count += 1);
        assert_eq!(count, 7);
        assert_eq!(s.n, 5);
    }

    #[test]
    fn fmt_duration_scales() {
        assert_eq!(fmt_duration(Duration::from_nanos(500)), "500 ns");
        assert!(fmt_duration(Duration::from_micros(1500)).contains("ms"));
        assert!(fmt_duration(Duration::from_secs(2)).contains("s"));
    }

    #[test]
    fn fmt_mean_std_shape() {
        let s = fmt_mean_std(&[0.5, 0.51, 0.52]);
        assert!(s.contains('±'), "{s}");
    }

    #[test]
    fn bench_report_rows_and_ns_per_op() {
        let mut r = BenchReport::new("unit");
        // 1e6 items/s mean -> 1000 ns per item.
        let s = Summary {
            n: 3,
            mean: 1e6,
            std: 0.0,
            min: 1e6,
            p50: 1e6,
            p95: 1e6,
            p99: 1e6,
            p999: 1e6,
            max: 1e6,
        };
        r.row("g", "items", 4, &s, "items/s");
        // 2 ms per iteration -> 2e6 ns.
        let t = Summary {
            n: 3,
            mean: 2e-3,
            std: 0.0,
            min: 2e-3,
            p50: 2e-3,
            p95: 2e-3,
            p99: 2e-3,
            p999: 2e-3,
            max: 2e-3,
        };
        r.row("g", "time", 1, &t, "s");
        assert_eq!(r.rows.len(), 2);
        assert_eq!(r.rows[0].name, "g/items");
        assert_eq!(r.rows[0].threads, 4);
        assert!((r.rows[0].ns_per_op - 1000.0).abs() < 1e-9, "{}", r.rows[0].ns_per_op);
        assert!((r.rows[1].ns_per_op - 2e6).abs() < 1e-3, "{}", r.rows[1].ns_per_op);
    }

    #[test]
    fn bench_report_json_roundtrip() {
        let mut r = BenchReport::new("unit");
        let s = Summary {
            n: 1,
            mean: 500.0,
            std: 0.0,
            min: 500.0,
            p50: 500.0,
            p95: 500.0,
            p99: 500.0,
            p999: 500.0,
            max: 500.0,
        };
        r.row("sample", "seeds=8", 8, &s, "items/s");
        let dir = std::env::temp_dir().join(format!("tfgnn-bench-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_unit.json");
        r.write_to(&path).unwrap();
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(doc.get("bench").unwrap().as_str().unwrap(), "unit");
        let rows = doc.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].get("name").unwrap().as_str().unwrap(), "sample/seeds=8");
        assert_eq!(rows[0].get("threads").unwrap().as_i64().unwrap(), 8);
        assert!(rows[0].get("ns_per_op").unwrap().as_f64().unwrap() > 0.0);
        assert!((rows[0].get("p999").unwrap().as_f64().unwrap() - 500.0).abs() < 1e-9);
        assert!(rows[0].opt("metrics").is_none(), "no metrics attached -> no field");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn summary_p999_tracks_the_tail() {
        // 1000 samples: one large outlier must show in p99.9 but not p50.
        let mut v: Vec<f64> = (0..999).map(|i| 1.0 + i as f64 * 1e-6).collect();
        v.push(100.0);
        let s = Summary::of(&v);
        assert!(s.p50 < 2.0, "p50 {}", s.p50);
        assert!(s.p999 > 50.0, "p999 {}", s.p999);
        assert!(s.p999 <= s.max);
    }

    #[test]
    fn bench_row_metrics_delta_lands_in_json() {
        let mut r = BenchReport::new("unit");
        let s = Summary {
            n: 1,
            mean: 1.0,
            std: 0.0,
            min: 1.0,
            p50: 1.0,
            p95: 1.0,
            p99: 1.0,
            p999: 1.0,
            max: 1.0,
        };
        let delta = obj(vec![("counters", obj(vec![("serve_requests_total", Json::Int(9))]))]);
        r.row_with_metrics("g", "with-metrics", 1, &s, "s", Some(delta));
        let doc = r.to_json();
        let rows = doc.get("rows").unwrap().as_arr().unwrap();
        let m = rows[0].get("metrics").unwrap();
        assert_eq!(
            m.get("counters").unwrap().get("serve_requests_total").unwrap().as_i64().unwrap(),
            9
        );
    }
}
