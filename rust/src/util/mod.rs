//! From-scratch substrates.
//!
//! The build image is offline and only the `xla` crate's dependency
//! closure is vendored, so the generic infrastructure a project would
//! normally pull from crates.io is implemented here: a JSON codec
//! ([`json`]), deterministic RNGs ([`rng`]), a CLI argument parser
//! ([`cli`]), a thread pool ([`threadpool`]), summary statistics and a
//! bench timer ([`stats`]), and a miniature property-testing harness
//! ([`proptest`]).

pub mod cli;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod threadpool;

pub use threadpool::ThreadPool;
