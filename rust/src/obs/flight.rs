//! Incident flight recorder: dump metrics + trace snapshots when the
//! server misbehaves.
//!
//! Post-mortems usually start *after* the interesting window: nobody
//! had `--trace-out` on when the lane wedged at 3am. The flight
//! recorder closes that gap — when a server with `--incident-dir` set
//! hits a watchdog trip, an overload burst, or a failed batch, it
//! writes a self-contained JSON snapshot (full metrics registry plus
//! the most recent trace events) so the evidence survives without any
//! export flags having been on. The native trainer's gradient-health
//! sentinel fires the same recorder, embedding the recent event-journal
//! tail via [`FlightRecorder::record_with`].
//!
//! Dumps are **rate-limited** (one per [`DEFAULT_MIN_INTERVAL`] by
//! default; suppressed triggers are tallied in
//! `flight_rate_limited_total` and per recorder via
//! [`FlightRecorder::suppressed`]) so a misbehaving server cannot
//! flood the disk, and **atomic** (written to a dotted temp file, then
//! renamed) so a crash mid-dump never leaves a torn JSON document.
//! The trace snapshot uses the non-destructive
//! [`super::trace::snapshot`], so recording an incident never steals
//! events from a later `--trace-out` export.
//!
//! Dump layout (`incident-<start-epoch>-<seq>-<trigger>.json`, schema
//! `tfgnn_incident_v1`): `trigger`, `detail`, `seq`,
//! `unix_time_secs`, `metrics` (a `tfgnn_metrics_v1` document) and
//! `trace` (a Chrome `trace_event` document), plus any extra fields
//! the caller attached (e.g. `events` — the journal tail). The
//! `<start-epoch>` salt is the process start time in unix seconds:
//! a restarted process begins again at seq 0, and without the salt it
//! would clobber the previous incarnation's dumps — exactly the
//! incidents a post-mortem needs.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock, PoisonError};
use std::time::{Duration, Instant, SystemTime, UNIX_EPOCH};

use super::metrics::names;
use crate::util::json::{obj, Json};
use crate::{Error, Result};

/// Default minimum spacing between dumps.
pub const DEFAULT_MIN_INTERVAL: Duration = Duration::from_secs(5);

/// Most recent trace events captured per dump.
const TRACE_EVENT_CAP: usize = 2048;

/// The process start epoch (unix seconds, read once): the filename
/// salt that keeps dumps from different process incarnations distinct.
pub fn process_start_epoch() -> u64 {
    static EPOCH: OnceLock<u64> = OnceLock::new();
    *EPOCH.get_or_init(|| {
        SystemTime::now().duration_since(UNIX_EPOCH).map(|d| d.as_secs()).unwrap_or(0)
    })
}

/// Writes rate-limited incident snapshots into one directory.
pub struct FlightRecorder {
    dir: PathBuf,
    min_interval: Duration,
    last_dump: Mutex<Option<Instant>>,
    seq: AtomicU64,
    suppressed: AtomicU64,
    salt: u64,
}

impl FlightRecorder {
    /// A recorder dumping into `dir` (created if missing), at most one
    /// dump per [`DEFAULT_MIN_INTERVAL`].
    pub fn new(dir: &Path) -> Result<FlightRecorder> {
        FlightRecorder::with_min_interval(dir, DEFAULT_MIN_INTERVAL)
    }

    /// A recorder with an explicit rate limit (tests use short ones).
    pub fn with_min_interval(dir: &Path, min_interval: Duration) -> Result<FlightRecorder> {
        FlightRecorder::with_salt(dir, min_interval, process_start_epoch())
    }

    /// A recorder with an explicit filename salt — the restart-collision
    /// regression test simulates two process incarnations with it.
    pub fn with_salt(dir: &Path, min_interval: Duration, salt: u64) -> Result<FlightRecorder> {
        std::fs::create_dir_all(dir).map_err(|e| {
            Error::Runtime(format!("flight: cannot create {}: {e}", dir.display()))
        })?;
        Ok(FlightRecorder {
            dir: dir.to_path_buf(),
            min_interval,
            last_dump: Mutex::new(None),
            seq: AtomicU64::new(0),
            suppressed: AtomicU64::new(0),
            salt,
        })
    }

    /// Triggers this recorder suppressed via its rate limiter
    /// (surfaced on `/statusz` as `flight_suppressed`).
    pub fn suppressed(&self) -> u64 {
        self.suppressed.load(Ordering::Relaxed)
    }

    /// Record an incident: dump a metrics + trace snapshot unless the
    /// rate limiter suppresses it. Returns the dump path on success;
    /// `None` when rate-limited or when the write failed (recording an
    /// incident must never take the serving path down with it).
    pub fn record(&self, trigger: &str, detail: &str) -> Option<PathBuf> {
        self.record_with(trigger, detail, Vec::new())
    }

    /// [`FlightRecorder::record`] with extra top-level fields appended
    /// to the dump — the trainer attaches `("events", <journal tail>)`.
    pub fn record_with(
        &self,
        trigger: &str,
        detail: &str,
        extra: Vec<(&str, Json)>,
    ) -> Option<PathBuf> {
        {
            let mut g = self.last_dump.lock().unwrap_or_else(PoisonError::into_inner);
            if let Some(last) = *g {
                if last.elapsed() < self.min_interval {
                    crate::obs_counter!(names::FLIGHT_RATE_LIMITED).inc();
                    self.suppressed.fetch_add(1, Ordering::Relaxed);
                    return None;
                }
            }
            *g = Some(Instant::now());
        }
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        let unix_secs = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_secs())
            .unwrap_or(0);
        let (events, dropped) = super::trace::snapshot(TRACE_EVENT_CAP);
        let mut fields = vec![
            ("schema", Json::Str("tfgnn_incident_v1".to_string())),
            ("seq", Json::Int(i64::try_from(seq).unwrap_or(i64::MAX))),
            ("trigger", Json::Str(trigger.to_string())),
            ("detail", Json::Str(detail.to_string())),
            ("unix_time_secs", Json::Int(i64::try_from(unix_secs).unwrap_or(i64::MAX))),
            ("metrics", super::metrics::global().snapshot().to_json()),
            ("trace", super::trace::to_chrome_json(&events, dropped)),
        ];
        fields.extend(extra);
        let doc = obj(fields);
        let name = format!("incident-{}-{seq:04}-{}.json", self.salt, sanitize(trigger));
        let tmp = self.dir.join(format!(".{name}.tmp"));
        let dest = self.dir.join(&name);
        let mut body = doc.to_pretty();
        body.push('\n');
        match std::fs::write(&tmp, body).and_then(|()| std::fs::rename(&tmp, &dest)) {
            Ok(()) => {
                crate::obs_counter!(names::FLIGHT_DUMPS).inc();
                Some(dest)
            }
            Err(_) => {
                let _ = std::fs::remove_file(&tmp);
                None
            }
        }
    }
}

/// Keep trigger names filesystem-safe.
fn sanitize(s: &str) -> String {
    s.chars().map(|c| if c.is_ascii_alphanumeric() { c } else { '-' }).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_dir(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("tfgnn_flight_{tag}_{}", std::process::id()))
    }

    #[test]
    fn dump_is_parseable_and_rate_limited() {
        let dir = temp_dir("basic");
        let _ = std::fs::remove_dir_all(&dir);
        let rec = FlightRecorder::with_min_interval(&dir, Duration::from_secs(60)).unwrap();
        let path = rec.record("watchdog trip", "lane 0 wedged").expect("first dump");
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(doc.get("schema").unwrap().as_str().unwrap(), "tfgnn_incident_v1");
        assert_eq!(doc.get("trigger").unwrap().as_str().unwrap(), "watchdog trip");
        assert_eq!(
            doc.get("metrics").unwrap().get("schema").unwrap().as_str().unwrap(),
            "tfgnn_metrics_v1"
        );
        assert!(doc.get("trace").unwrap().get("traceEvents").is_ok());
        let want = format!("incident-{}-0000-watchdog-trip.json", process_start_epoch());
        assert!(path.file_name().is_some_and(|n| n == want.as_str()), "{path:?}");
        // Within the interval: suppressed, and the recorder tallies it.
        assert_eq!(rec.suppressed(), 0);
        assert!(rec.record("overload", "burst").is_none());
        assert_eq!(rec.suppressed(), 1);
        // No temp droppings.
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().ends_with(".tmp"))
            .collect();
        assert!(leftovers.is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn zero_interval_allows_consecutive_dumps() {
        let dir = temp_dir("seq");
        let _ = std::fs::remove_dir_all(&dir);
        let rec = FlightRecorder::with_min_interval(&dir, Duration::ZERO).unwrap();
        let a = rec.record("failed-batch", "a").expect("dump a");
        let b = rec.record("failed-batch", "b").expect("dump b");
        assert_ne!(a, b, "sequence number keeps dumps distinct");
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Regression: a restarted process begins again at seq 0; without
    /// the start-epoch salt its first dump would clobber the previous
    /// incarnation's `incident-0000-*.json`.
    #[test]
    fn restart_does_not_clobber_prior_incidents() {
        let dir = temp_dir("restart");
        let _ = std::fs::remove_dir_all(&dir);
        let first = FlightRecorder::with_salt(&dir, Duration::ZERO, 1_111).unwrap();
        let a = first.record("watchdog-trip", "incarnation one").expect("dump a");
        // "Restart": a fresh recorder, seq back at 0, different salt.
        let second = FlightRecorder::with_salt(&dir, Duration::ZERO, 2_222).unwrap();
        let b = second.record("watchdog-trip", "incarnation two").expect("dump b");
        assert_ne!(a, b, "same seq + same trigger must not collide across restarts");
        assert!(a.exists(), "first incarnation's dump survives");
        let doc = Json::parse(&std::fs::read_to_string(&a).unwrap()).unwrap();
        assert_eq!(doc.get("detail").unwrap().as_str().unwrap(), "incarnation one");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn record_with_appends_extra_fields() {
        let dir = temp_dir("extra");
        let _ = std::fs::remove_dir_all(&dir);
        let rec = FlightRecorder::with_min_interval(&dir, Duration::ZERO).unwrap();
        let tail = Json::Arr(vec![obj(vec![("kind", Json::Str("step".into()))])]);
        let path = rec.record_with("grad-nonfinite", "step 7", vec![("events", tail)]).unwrap();
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let events = doc.get("events").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].get("kind").unwrap().as_str().unwrap(), "step");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
