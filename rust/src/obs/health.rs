//! Health watchdog: lane heartbeats, wedge/stall detection, deadline
//! misses.
//!
//! A serving process is *unhealthy* when it is holding work it cannot
//! make progress on. The [`Watchdog`] detects the two shapes of that:
//!
//! * **Wedged lane** — a lane began a wave ([`LaneBeat::begin`]) and
//!   has not finished it ([`LaneBeat::end`]) within the threshold. The
//!   lane thread is stuck inside an executor (or an injected test
//!   stall) while its requests age.
//! * **Stalled queue** — the backlog is non-empty but no lane has made
//!   any begin/end progress within the threshold: every lane is either
//!   dead or wedged, so admitted requests will never be served.
//!
//! [`Watchdog::check`] computes a point-in-time [`HealthReport`] (what
//! the admin `/healthz` endpoint serves — 200 when healthy, 503
//! otherwise); [`Watchdog::evaluate`] additionally does the
//! transition bookkeeping: a healthy→unhealthy edge increments the
//! `health_watchdog_trips_total` counter and raises the
//! `health_unhealthy` gauge (which is what the flight recorder keys
//! its "watchdog trip" dumps on).
//!
//! Heartbeats are relaxed atomic stores of a microsecond clock offset
//! — no locks on the wave path — so the watchdog obeys the module's
//! inertness contract: lanes beat identically whether or not anything
//! is watching.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, PoisonError};
use std::time::{Duration, Instant, SystemTime, UNIX_EPOCH};

use super::metrics::names;

/// Per-lane heartbeat state shared between the lane's [`LaneBeat`] and
/// the watchdog's checker.
struct LaneState {
    lane: usize,
    /// Microseconds since the watchdog epoch, plus 1, at the current
    /// wave's begin; 0 while idle.
    busy_since: AtomicU64,
    /// Waves this lane has begun.
    waves: AtomicU64,
}

/// A lane's handle for heartbeating: call [`LaneBeat::begin`] when a
/// wave is picked up and [`LaneBeat::end`] when it is fully replied.
pub struct LaneBeat {
    state: Arc<LaneState>,
    progress: Arc<AtomicU64>,
    epoch: Instant,
}

impl LaneBeat {
    /// Mark this lane busy on a new wave.
    pub fn begin(&self) {
        let now = micros_since(self.epoch) + 1;
        self.state.busy_since.store(now, Ordering::Relaxed);
        self.state.waves.fetch_add(1, Ordering::Relaxed);
        self.progress.store(now, Ordering::Relaxed);
        crate::obs_counter!(names::HEALTH_HEARTBEATS).inc();
    }

    /// Mark this lane idle again; the wave was fully replied.
    pub fn end(&self) {
        let now = micros_since(self.epoch) + 1;
        self.state.busy_since.store(0, Ordering::Relaxed);
        self.progress.store(now, Ordering::Relaxed);
    }
}

/// One lane's line in a [`HealthReport`].
#[derive(Debug, Clone)]
pub struct LaneHealth {
    pub lane: usize,
    /// Currently mid-wave?
    pub busy: bool,
    /// How long the current wave has been running (zero when idle).
    pub busy_for: Duration,
    /// Waves begun so far.
    pub waves: u64,
}

/// Point-in-time health verdict; `reasons` is empty iff `healthy`.
#[derive(Debug, Clone)]
pub struct HealthReport {
    pub healthy: bool,
    pub reasons: Vec<String>,
    pub lanes: Vec<LaneHealth>,
    /// Backlog (queue depth) the caller passed in.
    pub backlog: i64,
    /// Requests whose deadline expired before execution, so far.
    pub deadline_misses: u64,
    /// Healthy→unhealthy transitions recorded so far.
    pub trips: u64,
}

impl HealthReport {
    /// Plain-text rendering for the `/healthz` body.
    pub fn to_text(&self) -> String {
        let mut out = String::new();
        if self.healthy {
            out.push_str("ok\n");
        } else {
            out.push_str("unhealthy\n");
            for r in &self.reasons {
                out.push_str("- ");
                out.push_str(r);
                out.push('\n');
            }
        }
        for l in &self.lanes {
            let state = if l.busy {
                format!("busy {}ms", l.busy_for.as_millis())
            } else {
                "idle".to_string()
            };
            out.push_str(&format!("lane {}: {} ({} waves)\n", l.lane, state, l.waves));
        }
        out.push_str(&format!(
            "backlog {} | deadline misses {} | trips {}\n",
            self.backlog, self.deadline_misses, self.trips
        ));
        out
    }
}

/// Watchdog over a set of heartbeating lanes. One per server.
pub struct Watchdog {
    epoch: Instant,
    threshold: Duration,
    lanes: Mutex<Vec<Arc<LaneState>>>,
    /// Latest begin/end heartbeat across all lanes (micros + 1;
    /// initialized to 1 = "progress at startup" so an idle new server
    /// is healthy).
    progress: Arc<AtomicU64>,
    deadline_misses: AtomicU64,
    trips: AtomicU64,
    healthy: AtomicBool,
    /// Unix seconds of the last [`Watchdog::evaluate`] call (0 =
    /// never): `/statusz` proof that the checker thread is alive.
    last_eval_unix: AtomicU64,
}

impl Watchdog {
    /// A watchdog that flags lanes silent past `threshold`.
    pub fn new(threshold: Duration) -> Watchdog {
        Watchdog {
            epoch: Instant::now(),
            threshold,
            lanes: Mutex::new(Vec::new()),
            progress: Arc::new(AtomicU64::new(1)),
            deadline_misses: AtomicU64::new(0),
            trips: AtomicU64::new(0),
            healthy: AtomicBool::new(true),
            last_eval_unix: AtomicU64::new(0),
        }
    }

    /// Register a lane (at server startup) and get its beat handle.
    pub fn register_lane(&self, lane: usize) -> LaneBeat {
        let state = Arc::new(LaneState {
            lane,
            busy_since: AtomicU64::new(0),
            waves: AtomicU64::new(0),
        });
        self.lanes
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .push(Arc::clone(&state));
        LaneBeat { state, progress: Arc::clone(&self.progress), epoch: self.epoch }
    }

    /// Count a request whose deadline expired before execution.
    pub fn note_deadline_miss(&self) {
        self.deadline_misses.fetch_add(1, Ordering::Relaxed);
    }

    /// Healthy→unhealthy transitions so far.
    pub fn trips(&self) -> u64 {
        self.trips.load(Ordering::Relaxed)
    }

    /// Unix seconds of the most recent [`Watchdog::evaluate`] call,
    /// `None` before the first one — a dead checker thread shows up as
    /// a stale (or missing) timestamp on `/statusz`.
    pub fn last_eval_unix_secs(&self) -> Option<u64> {
        match self.last_eval_unix.load(Ordering::Relaxed) {
            0 => None,
            t => Some(t),
        }
    }

    /// Point-in-time health check; pure (no transition bookkeeping).
    /// `backlog` is the server's current queue depth.
    pub fn check(&self, backlog: i64) -> HealthReport {
        let now = micros_since(self.epoch);
        let threshold_us = self.threshold.as_micros().min(u64::MAX as u128) as u64;
        let mut lanes_out = Vec::new();
        let mut reasons = Vec::new();
        {
            let g = self.lanes.lock().unwrap_or_else(PoisonError::into_inner);
            for lane in g.iter() {
                let busy = lane.busy_since.load(Ordering::Relaxed);
                let busy_for_us = if busy > 0 { now.saturating_sub(busy - 1) } else { 0 };
                if busy > 0 && busy_for_us > threshold_us {
                    reasons.push(format!(
                        "lane {} wedged mid-wave for {}ms (threshold {}ms)",
                        lane.lane,
                        busy_for_us / 1000,
                        threshold_us / 1000
                    ));
                }
                lanes_out.push(LaneHealth {
                    lane: lane.lane,
                    busy: busy > 0,
                    busy_for: Duration::from_micros(busy_for_us),
                    waves: lane.waves.load(Ordering::Relaxed),
                });
            }
        }
        let prog = self.progress.load(Ordering::Relaxed);
        let idle_for_us = now.saturating_sub(prog.saturating_sub(1));
        if backlog > 0 && idle_for_us > threshold_us {
            reasons.push(format!(
                "queue stalled: backlog {} with no lane progress for {}ms",
                backlog,
                idle_for_us / 1000
            ));
        }
        HealthReport {
            healthy: reasons.is_empty(),
            reasons,
            lanes: lanes_out,
            backlog,
            deadline_misses: self.deadline_misses.load(Ordering::Relaxed),
            trips: self.trips.load(Ordering::Relaxed),
        }
    }

    /// [`Watchdog::check`] plus transition bookkeeping: on a
    /// healthy→unhealthy edge, bump the trip counter and raise the
    /// unhealthy gauge; on recovery, clear the gauge. Returns the
    /// report and whether this call was the tripping edge (the flight
    /// recorder's cue).
    pub fn evaluate(&self, backlog: i64) -> (HealthReport, bool) {
        let unix_secs = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_secs().max(1))
            .unwrap_or(1);
        self.last_eval_unix.store(unix_secs, Ordering::Relaxed);
        let mut report = self.check(backlog);
        let was = self.healthy.swap(report.healthy, Ordering::Relaxed);
        let tripped = was && !report.healthy;
        if tripped {
            self.trips.fetch_add(1, Ordering::Relaxed);
            crate::obs_counter!(names::HEALTH_WATCHDOG_TRIPS).inc();
            crate::obs_gauge!(names::HEALTH_UNHEALTHY).set(1);
        } else if !was && report.healthy {
            crate::obs_gauge!(names::HEALTH_UNHEALTHY).set(0);
        }
        report.trips = self.trips.load(Ordering::Relaxed);
        (report, tripped)
    }
}

fn micros_since(epoch: Instant) -> u64 {
    Instant::now().saturating_duration_since(epoch).as_micros().min(u64::MAX as u128) as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn evaluate_stamps_last_eval_time() {
        let dog = Watchdog::new(Duration::from_millis(10));
        assert_eq!(dog.last_eval_unix_secs(), None, "no evaluation yet");
        let _ = dog.evaluate(0);
        assert!(dog.last_eval_unix_secs().is_some());
    }

    #[test]
    fn idle_server_is_healthy() {
        let dog = Watchdog::new(Duration::from_millis(10));
        let _beat = dog.register_lane(0);
        std::thread::sleep(Duration::from_millis(30));
        let report = dog.check(0);
        assert!(report.healthy, "{report:?}");
        assert_eq!(report.lanes.len(), 1);
        assert!(!report.lanes[0].busy);
    }

    #[test]
    fn wedged_lane_flips_unhealthy_and_recovers() {
        let dog = Watchdog::new(Duration::from_millis(10));
        let beat = dog.register_lane(3);
        beat.begin();
        std::thread::sleep(Duration::from_millis(40));
        let (report, tripped) = dog.evaluate(0);
        assert!(!report.healthy, "{report:?}");
        assert!(tripped);
        assert!(report.reasons.iter().any(|r| r.contains("lane 3 wedged")), "{report:?}");
        assert!(report.to_text().starts_with("unhealthy\n"));
        // Same trip is not double-counted.
        let (_, again) = dog.evaluate(0);
        assert!(!again);
        assert_eq!(dog.trips(), 1);
        // Finishing the wave recovers.
        beat.end();
        let (report, _) = dog.evaluate(0);
        assert!(report.healthy, "{report:?}");
        assert_eq!(report.lanes[0].waves, 1);
    }

    #[test]
    fn stalled_queue_needs_backlog() {
        let dog = Watchdog::new(Duration::from_millis(10));
        let beat = dog.register_lane(0);
        beat.begin();
        beat.end();
        std::thread::sleep(Duration::from_millis(40));
        // Progress is stale but there is no backlog: healthy.
        assert!(dog.check(0).healthy);
        // With a backlog, stale progress is a stall.
        let report = dog.check(5);
        assert!(!report.healthy, "{report:?}");
        assert!(report.reasons.iter().any(|r| r.contains("queue stalled")), "{report:?}");
    }

    #[test]
    fn deadline_misses_are_reported() {
        let dog = Watchdog::new(Duration::from_secs(1));
        dog.note_deadline_miss();
        dog.note_deadline_miss();
        assert_eq!(dog.check(0).deadline_misses, 2);
    }
}
