//! Training-run event journal (`tfgnn_events_v1`) and the `tfgnn
//! runs` experiment summaries built on top of it.
//!
//! PRs 8–9 gave the *serving* path deep observability; this module is
//! the training analog. A run started with `--events-out FILE` appends
//! one JSON object per line (JSONL — append-only, crash-tolerant:
//! every complete line is a valid record no matter where the process
//! died):
//!
//! * line 1 — a `run_start` header carrying `schema:
//!   "tfgnn_events_v1"` plus the run's identity (arch, engine, task,
//!   trainer threads, parameter count, hyper-parameters);
//! * one `step` record per optimizer step — step/epoch/split, mean
//!   loss, example weight, per-task metric sums, step wall-time, the
//!   sampler wave (data wait) time, and — when gradient telemetry is
//!   on — global and per-layer gradient/parameter L2 norms and the
//!   update ratio `‖Δθ‖/‖θ‖`;
//! * one `eval` record per validation/test pass with named per-task
//!   summary metrics ([`crate::tasks::summary_metrics`]);
//! * a final `run_end` record (total steps, wall-time, steps/s, best
//!   validation accuracy).
//!
//! **Inertness contract.** Journal writes and gradient probes are
//! read-only observers: norms are accumulated in f64 off to the side
//! and never fed back into the update, and all file I/O happens in the
//! runner's epoch loop outside the math. Training with events + probes
//! on is bit-identical to training with them off at 1/2/8 threads —
//! pinned by `tests/events.rs`.
//!
//! The journal also keeps a bounded in-memory tail
//! ([`TAIL_CAP`] most recent records) so the gradient-health sentinel
//! can embed the recent step history into a
//! [`FlightRecorder`](super::flight::FlightRecorder) incident dump —
//! the post-mortem shows the steps *leading into* the divergence, not
//! just the final explosion.
//!
//! Reading side: [`RunSummary`] parses + validates a journal and
//! powers `tfgnn runs list | show | diff` (rendering in the
//! [`super::report`] style).

use std::collections::{BTreeMap, VecDeque};
use std::fs::File;
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, PoisonError};
use std::time::{SystemTime, UNIX_EPOCH};

use super::flight::FlightRecorder;
use super::metrics::names;
use crate::train::metrics::TaskMetrics;
use crate::util::json::{obj, Json};
use crate::{Error, Result};

/// Schema tag carried by the `run_start` header line.
pub const SCHEMA: &str = "tfgnn_events_v1";

/// Most recent records kept in memory for incident dumps.
pub const TAIL_CAP: usize = 64;

/// A finite JSON number (`null` for NaN/Inf — JSON has neither, and a
/// torn record must never make the whole line unparseable).
fn num(v: f64) -> Json {
    if v.is_finite() {
        Json::Num(v)
    } else {
        Json::Null
    }
}

fn int(v: u64) -> Json {
    Json::Int(i64::try_from(v).unwrap_or(i64::MAX))
}

// ---- trainer-side telemetry types ---------------------------------------

/// Per-layer L2 norms, grouped by parameter-name prefix (`l0.`, `l1.`,
/// … for the trunk layers; everything else — encoders, embeddings, the
/// readout head — under its first name segment).
#[derive(Debug, Clone, PartialEq)]
pub struct LayerStats {
    pub name: String,
    pub grad_norm: f64,
    pub param_norm: f64,
}

/// One step's gradient-health probe results (read-only over the
/// reduced gradients and the parameters; never fed back).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct GradStats {
    /// The optimizer step these norms belong to (0-based).
    pub step: u64,
    /// Global gradient L2 norm over every parameter tensor.
    pub grad_norm: f64,
    /// Global parameter L2 norm (pre-update).
    pub param_norm: f64,
    /// L2 norm of the applied update `‖Δθ‖`.
    pub update_norm: f64,
    /// `‖Δθ‖ / ‖θ‖` — the classic learning-rate health signal.
    pub update_ratio: f64,
    /// Per-layer-group norms, in parameter creation order.
    pub layers: Vec<LayerStats>,
}

impl GradStats {
    /// The JSON fragment merged into a `step` record.
    pub fn to_json(&self) -> Vec<(&'static str, Json)> {
        let mut layers = BTreeMap::new();
        for l in &self.layers {
            layers.insert(
                l.name.clone(),
                obj(vec![("grad_norm", num(l.grad_norm)), ("param_norm", num(l.param_norm))]),
            );
        }
        vec![
            ("grad_norm", num(self.grad_norm)),
            ("param_norm", num(self.param_norm)),
            ("update_norm", num(self.update_norm)),
            ("update_ratio", num(self.update_ratio)),
            ("layers", Json::Obj(layers)),
        ]
    }
}

/// Trainer telemetry knobs — everything defaults to off, and the
/// default-off configuration is the exact pre-telemetry trainer.
#[derive(Clone, Default)]
pub struct Telemetry {
    /// Compute per-step gradient/parameter norms and the update ratio
    /// (surfaced via `take_grad_stats` and the metrics registry).
    pub grad_stats: bool,
    /// Gradient-explosion sentinel: error out (instead of silently
    /// diverging) when the global gradient norm exceeds this.
    pub grad_norm_limit: Option<f64>,
    /// Incident recorder fired when a sentinel trips.
    pub flight: Option<Arc<FlightRecorder>>,
    /// Journal whose recent tail is embedded into incident dumps.
    pub journal: Option<Arc<EventJournal>>,
}

impl Telemetry {
    /// Does any probe need the per-step norm computation?
    pub fn probes_on(&self) -> bool {
        self.grad_stats || self.grad_norm_limit.is_some()
    }
}

// ---- journal writer ------------------------------------------------------

struct Inner {
    file: File,
    tail: VecDeque<Json>,
}

/// Append-only JSONL writer with a bounded in-memory tail.
pub struct EventJournal {
    path: PathBuf,
    inner: Mutex<Inner>,
}

impl EventJournal {
    /// Create (truncate) the journal file; parent directories are
    /// created as needed.
    pub fn create(path: &Path) -> Result<EventJournal> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent).map_err(|e| {
                    Error::Runtime(format!("events: cannot create {}: {e}", parent.display()))
                })?;
            }
        }
        let file = File::create(path).map_err(|e| {
            Error::Runtime(format!("events: cannot create {}: {e}", path.display()))
        })?;
        Ok(EventJournal {
            path: path.to_path_buf(),
            inner: Mutex::new(Inner { file, tail: VecDeque::new() }),
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Append one record as a JSON line and remember it in the tail.
    pub fn write(&self, event: &Json) -> Result<()> {
        let mut line = event.to_string();
        line.push('\n');
        let mut g = self.inner.lock().unwrap_or_else(PoisonError::into_inner);
        g.file.write_all(line.as_bytes()).map_err(|e| {
            Error::Runtime(format!("events: cannot append to {}: {e}", self.path.display()))
        })?;
        if g.tail.len() == TAIL_CAP {
            g.tail.pop_front();
        }
        g.tail.push_back(event.clone());
        crate::obs_counter!(names::TRAINER_EVENTS).inc();
        Ok(())
    }

    /// The most recent records (oldest first), for incident dumps.
    pub fn tail(&self) -> Vec<Json> {
        let g = self.inner.lock().unwrap_or_else(PoisonError::into_inner);
        g.tail.iter().cloned().collect()
    }
}

// ---- event constructors --------------------------------------------------

/// The `run_start` header (journal line 1).
pub struct RunStart {
    pub arch: String,
    pub engine: String,
    pub task: String,
    pub trainer_threads: usize,
    pub param_count: usize,
    pub epochs: usize,
    pub learning_rate: f64,
    pub dropout: f64,
    pub weight_decay: f64,
    pub grad_norm_limit: Option<f64>,
}

impl RunStart {
    pub fn to_event(&self) -> Json {
        let unix_secs =
            SystemTime::now().duration_since(UNIX_EPOCH).map(|d| d.as_secs()).unwrap_or(0);
        obj(vec![
            ("schema", Json::Str(SCHEMA.to_string())),
            ("kind", Json::Str("run_start".to_string())),
            ("unix_time_secs", int(unix_secs)),
            ("arch", Json::Str(self.arch.clone())),
            ("engine", Json::Str(self.engine.clone())),
            ("task", Json::Str(self.task.clone())),
            ("trainer_threads", int(self.trainer_threads as u64)),
            ("param_count", int(self.param_count as u64)),
            ("epochs", int(self.epochs as u64)),
            ("learning_rate", num(self.learning_rate)),
            ("dropout", num(self.dropout)),
            ("weight_decay", num(self.weight_decay)),
            ("grad_norm_limit", self.grad_norm_limit.map_or(Json::Null, num)),
        ])
    }
}

/// One optimizer step's record.
pub struct StepEvent<'a> {
    pub step: u64,
    pub epoch: usize,
    pub split: &'a str,
    /// Mean loss over this step's real examples.
    pub loss: f64,
    /// Example weight (number of real, unmasked examples).
    pub examples: f64,
    pub task: &'a TaskMetrics,
    pub step_secs: f64,
    /// Time spent waiting on the sampler/pipeline for this wave.
    pub data_wait_secs: f64,
    pub grad: Option<&'a GradStats>,
}

impl StepEvent<'_> {
    pub fn to_event(&self) -> Json {
        let mut fields = vec![
            ("kind", Json::Str("step".to_string())),
            ("step", int(self.step)),
            ("epoch", int(self.epoch as u64)),
            ("split", Json::Str(self.split.to_string())),
            ("loss", num(self.loss)),
            ("examples", num(self.examples)),
            ("metrics", task_metrics_json(self.task)),
            ("step_secs", num(self.step_secs)),
            ("data_wait_secs", num(self.data_wait_secs)),
        ];
        if let Some(g) = self.grad {
            fields.extend(g.to_json());
        }
        obj(fields)
    }
}

/// The raw per-task metric *sums* for one step (divide by `scored` for
/// means; the eval records carry the derived means instead).
pub fn task_metrics_json(t: &TaskMetrics) -> Json {
    obj(vec![
        ("correct", num(t.correct)),
        ("rr_sum", num(t.rr_sum)),
        ("hits_sum", num(t.hits_sum)),
        ("se_sum", num(t.se_sum)),
        ("ae_sum", num(t.ae_sum)),
        ("scored", num(t.scored)),
    ])
}

/// A validation/test pass record; `metrics` are the task's *named*
/// summary means (see [`crate::tasks::summary_metrics`]).
pub fn eval_event(
    epoch: usize,
    split: &str,
    loss: f64,
    examples: f64,
    metrics: &[(&str, f64)],
) -> Json {
    let mut m = BTreeMap::new();
    for (k, v) in metrics {
        m.insert((*k).to_string(), num(*v));
    }
    obj(vec![
        ("kind", Json::Str("eval".to_string())),
        ("epoch", int(epoch as u64)),
        ("split", Json::Str(split.to_string())),
        ("loss", num(loss)),
        ("examples", num(examples)),
        ("metrics", Json::Obj(m)),
    ])
}

/// The closing record.
pub fn run_end_event(steps: u64, total_step_secs: f64, best_val_acc: f64) -> Json {
    let sps = if total_step_secs > 0.0 { steps as f64 / total_step_secs } else { 0.0 };
    obj(vec![
        ("kind", Json::Str("run_end".to_string())),
        ("steps", int(steps)),
        ("total_step_secs", num(total_step_secs)),
        ("train_steps_per_sec", num(sps)),
        ("best_val_acc", num(best_val_acc)),
    ])
}

// ---- reading side: run summaries and `tfgnn runs` ------------------------

/// One parsed `eval` record.
#[derive(Debug, Clone)]
pub struct EvalRecord {
    pub epoch: u64,
    pub split: String,
    pub loss: f64,
    pub metrics: Vec<(String, f64)>,
}

/// A parsed + validated journal, reduced to what `tfgnn runs` needs.
pub struct RunSummary {
    pub path: PathBuf,
    pub header: Json,
    pub steps: u64,
    pub total_step_secs: f64,
    /// `(step, train loss, cumulative step seconds)` per step record.
    pub step_losses: Vec<(u64, f64, f64)>,
    pub evals: Vec<EvalRecord>,
    pub end: Option<Json>,
}

impl RunSummary {
    /// Parse and validate one journal file. Every line must be a JSON
    /// object; line 1 must be a `run_start` header with the
    /// [`SCHEMA`] tag; later lines must be `step`/`eval`/`run_end`.
    pub fn from_path(path: &Path) -> Result<RunSummary> {
        let text = std::fs::read_to_string(path).map_err(|e| {
            Error::Runtime(format!("events: cannot read {}: {e}", path.display()))
        })?;
        let bad = |line: usize, why: String| {
            Error::Runtime(format!("events: {}:{line}: {why}", path.display()))
        };
        let mut header = None;
        let mut steps = 0u64;
        let mut total_step_secs = 0.0f64;
        let mut step_losses = Vec::new();
        let mut evals = Vec::new();
        let mut end = None;
        for (i, line) in text.lines().enumerate() {
            let lineno = i + 1;
            if line.trim().is_empty() {
                continue;
            }
            let rec = Json::parse(line)
                .map_err(|e| bad(lineno, format!("not a JSON record: {e}")))?;
            let kind = rec
                .get("kind")
                .and_then(Json::as_str)
                .map_err(|_| bad(lineno, "record has no \"kind\"".to_string()))?
                .to_string();
            if header.is_none() {
                if kind != "run_start" {
                    return Err(bad(lineno, format!("first record is {kind:?}, want run_start")));
                }
                let schema = rec
                    .get("schema")
                    .and_then(Json::as_str)
                    .map_err(|_| bad(lineno, "run_start has no \"schema\"".to_string()))?;
                if schema != SCHEMA {
                    return Err(bad(lineno, format!("schema {schema:?}, want {SCHEMA:?}")));
                }
                header = Some(rec);
                continue;
            }
            match kind.as_str() {
                "step" => {
                    let step = rec
                        .get("step")
                        .and_then(Json::as_i64)
                        .map_err(|_| bad(lineno, "step record has no \"step\"".to_string()))?;
                    let loss = rec
                        .get("loss")
                        .and_then(Json::as_f64)
                        .map_err(|_| bad(lineno, "step record has no \"loss\"".to_string()))?;
                    let secs = rec.get("step_secs").and_then(Json::as_f64).map_err(|_| {
                        bad(lineno, "step record has no \"step_secs\"".to_string())
                    })?;
                    steps += 1;
                    total_step_secs += secs;
                    step_losses.push((step.max(0) as u64, loss, total_step_secs));
                }
                "eval" => {
                    let epoch = rec
                        .get("epoch")
                        .and_then(Json::as_i64)
                        .map_err(|_| bad(lineno, "eval record has no \"epoch\"".to_string()))?;
                    let split = rec
                        .get("split")
                        .and_then(Json::as_str)
                        .map_err(|_| bad(lineno, "eval record has no \"split\"".to_string()))?
                        .to_string();
                    let loss = rec
                        .get("loss")
                        .and_then(Json::as_f64)
                        .map_err(|_| bad(lineno, "eval record has no \"loss\"".to_string()))?;
                    let mut metrics = Vec::new();
                    if let Some(m) = rec.opt("metrics") {
                        let m = m
                            .as_obj()
                            .map_err(|_| bad(lineno, "eval metrics not an object".to_string()))?;
                        for (k, v) in m {
                            if let Ok(v) = v.as_f64() {
                                metrics.push((k.clone(), v));
                            }
                        }
                    }
                    evals.push(EvalRecord { epoch: epoch.max(0) as u64, split, loss, metrics });
                }
                "run_end" => end = Some(rec),
                other => return Err(bad(lineno, format!("unknown record kind {other:?}"))),
            }
        }
        let header = header.ok_or_else(|| {
            Error::Runtime(format!("events: {}: empty journal (no run_start)", path.display()))
        })?;
        Ok(RunSummary {
            path: path.to_path_buf(),
            header,
            steps,
            total_step_secs,
            step_losses,
            evals,
            end,
        })
    }

    fn header_str(&self, key: &str) -> String {
        self.header.opt(key).and_then(|v| v.as_str().ok()).unwrap_or("?").to_string()
    }

    /// Steps per second — from `run_end` when present, else recomputed
    /// from the step records (a journal cut off mid-run still reports).
    pub fn steps_per_sec(&self) -> f64 {
        if let Some(end) = &self.end {
            if let Some(v) = end.opt("train_steps_per_sec").and_then(|v| v.as_f64().ok()) {
                return v;
            }
        }
        if self.total_step_secs > 0.0 {
            self.steps as f64 / self.total_step_secs
        } else {
            0.0
        }
    }

    /// The last training-step loss, if any step was recorded.
    pub fn final_train_loss(&self) -> Option<f64> {
        self.step_losses.last().map(|&(_, loss, _)| loss)
    }

    /// Latest eval record for `split`.
    pub fn final_eval(&self, split: &str) -> Option<&EvalRecord> {
        self.evals.iter().rev().find(|e| e.split == split)
    }

    /// Best (maximum) value of a named eval metric over `split`.
    pub fn best_eval(&self, split: &str, metric: &str) -> Option<f64> {
        self.evals
            .iter()
            .filter(|e| e.split == split)
            .flat_map(|e| e.metrics.iter())
            .filter(|(k, _)| k == metric)
            .map(|&(_, v)| v)
            .fold(None, |acc, v| Some(acc.map_or(v, |a: f64| a.max(v))))
    }

    /// Cumulative training seconds until the step loss first reaches
    /// `target` (`None` if the run never got there).
    pub fn time_to_loss(&self, target: f64) -> Option<f64> {
        self.step_losses.iter().find(|&&(_, loss, _)| loss <= target).map(|&(_, _, secs)| secs)
    }

    /// Ordered `(label, value)` summary rows — the diffable surface of
    /// a run. Labels are stable strings so two runs line up.
    pub fn summary_rows(&self) -> Vec<(String, f64)> {
        let mut rows = vec![
            ("train steps".to_string(), self.steps as f64),
            ("train steps/s".to_string(), self.steps_per_sec()),
            ("train wall secs".to_string(), self.total_step_secs),
        ];
        if let Some(loss) = self.final_train_loss() {
            rows.push(("final train loss".to_string(), loss));
        }
        for split in ["val", "test"] {
            if let Some(e) = self.final_eval(split) {
                rows.push((format!("final {split} loss"), e.loss));
                for (k, v) in &e.metrics {
                    rows.push((format!("final {split} {k}"), *v));
                }
            }
        }
        // Best-over-run rows for every val metric seen.
        let mut names: Vec<String> = Vec::new();
        for e in self.evals.iter().filter(|e| e.split == "val") {
            for (k, _) in &e.metrics {
                if !names.contains(k) {
                    names.push(k.clone());
                }
            }
        }
        for name in names {
            if let Some(v) = self.best_eval("val", &name) {
                rows.push((format!("best val {name}"), v));
            }
        }
        rows
    }

    /// One-line identity used by `runs list`.
    pub fn identity(&self) -> String {
        format!(
            "{} task={} engine={} threads={}",
            self.header_str("arch"),
            self.header_str("task"),
            self.header_str("engine"),
            self.header.opt("trainer_threads").and_then(|v| v.as_i64().ok()).unwrap_or(0),
        )
    }
}

fn fmt_val(v: f64) -> String {
    if v == 0.0 || (v.abs() >= 1e-3 && v.abs() < 1e6) {
        format!("{v:.4}")
    } else {
        format!("{v:.3e}")
    }
}

/// `tfgnn runs list` — one line per journal.
pub fn render_list(runs: &[RunSummary]) -> String {
    let mut out = String::new();
    out.push_str(&format!("{} run(s)\n", runs.len()));
    for r in runs {
        let loss = r.final_train_loss().map_or("n/a".to_string(), fmt_val);
        out.push_str(&format!(
            "  {:<32} {:<44} steps={:<6} steps/s={:<8.1} loss={}\n",
            r.path.file_name().map(|n| n.to_string_lossy().to_string()).unwrap_or_default(),
            r.identity(),
            r.steps,
            r.steps_per_sec(),
            loss,
        ));
    }
    out
}

/// `tfgnn runs show` — full summary of one journal. `loss_target`
/// adds a time-to-target row.
pub fn render_show(r: &RunSummary, loss_target: Option<f64>) -> String {
    let mut out = String::new();
    out.push_str(&format!("run {}\n", r.path.display()));
    out.push_str(&format!("  {}\n", r.identity()));
    out.push_str("summary:\n");
    for (label, v) in r.summary_rows() {
        out.push_str(&format!("  {label:<34} {}\n", fmt_val(v)));
    }
    if let Some(target) = loss_target {
        let row = match r.time_to_loss(target) {
            Some(secs) => format!("{secs:.3}s"),
            None => "never reached".to_string(),
        };
        out.push_str(&format!("  {:<34} {row}\n", format!("time to loss <= {target}")));
    }
    out
}

/// `tfgnn runs diff A B` — per-row deltas between two journals, in the
/// `report::render_diff` style (rows missing on one side show `n/a`).
pub fn render_diff(a: &RunSummary, b: &RunSummary) -> String {
    let mut out = String::new();
    out.push_str(&format!("runs diff\n  A: {}\n  B: {}\n", a.path.display(), b.path.display()));
    let ia = a.identity();
    let ib = b.identity();
    if ia != ib {
        out.push_str(&format!("  config differs:\n    A: {ia}\n    B: {ib}\n"));
    } else {
        out.push_str(&format!("  config: {ia}\n"));
    }
    let rows_a = a.summary_rows();
    let rows_b = b.summary_rows();
    let mut labels: Vec<&String> = rows_a.iter().map(|(l, _)| l).collect();
    for (l, _) in &rows_b {
        if !labels.contains(&l) {
            labels.push(l);
        }
    }
    let lookup = |rows: &[(String, f64)], label: &str| {
        rows.iter().find(|(l, _)| l == label).map(|&(_, v)| v)
    };
    for label in labels {
        let va = lookup(&rows_a, label);
        let vb = lookup(&rows_b, label);
        let line = match (va, vb) {
            (Some(va), Some(vb)) => {
                format!("{} -> {} ({:+.4})", fmt_val(va), fmt_val(vb), vb - va)
            }
            (Some(va), None) => format!("{} -> n/a", fmt_val(va)),
            (None, Some(vb)) => format!("n/a -> {}", fmt_val(vb)),
            (None, None) => continue,
        };
        out.push_str(&format!("  {label:<34} {line}\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_path(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("tfgnn_events_{tag}_{}.jsonl", std::process::id()))
    }

    fn header() -> RunStart {
        RunStart {
            arch: "mpnn".to_string(),
            engine: "native".to_string(),
            task: "root_classification".to_string(),
            trainer_threads: 2,
            param_count: 123,
            epochs: 1,
            learning_rate: 1e-3,
            dropout: 0.0,
            weight_decay: 0.0,
            grad_norm_limit: Some(100.0),
        }
    }

    fn write_run(tag: &str, losses: &[f64], val_acc: f64) -> PathBuf {
        let path = temp_path(tag);
        let j = EventJournal::create(&path).unwrap();
        j.write(&header().to_event()).unwrap();
        let task = TaskMetrics { correct: 2.0, scored: 4.0, ..TaskMetrics::default() };
        for (i, &loss) in losses.iter().enumerate() {
            let g = GradStats {
                step: i as u64,
                grad_norm: 1.5,
                param_norm: 10.0,
                update_norm: 0.01,
                update_ratio: 0.001,
                layers: vec![LayerStats {
                    name: "l0".to_string(),
                    grad_norm: 1.0,
                    param_norm: 5.0,
                }],
            };
            let ev = StepEvent {
                step: i as u64,
                epoch: 0,
                split: "train",
                loss,
                examples: 4.0,
                task: &task,
                step_secs: 0.5,
                data_wait_secs: 0.1,
                grad: Some(&g),
            };
            j.write(&ev.to_event()).unwrap();
        }
        j.write(&eval_event(0, "val", 1.0, 8.0, &[("accuracy", val_acc)])).unwrap();
        j.write(&eval_event(0, "test", 1.1, 8.0, &[("accuracy", val_acc - 0.05)])).unwrap();
        j.write(&run_end_event(losses.len() as u64, 0.5 * losses.len() as f64, val_acc))
            .unwrap();
        path
    }

    #[test]
    fn journal_roundtrips_and_summarizes() {
        let path = write_run("roundtrip", &[2.0, 1.5, 0.9], 0.5);
        let s = RunSummary::from_path(&path).unwrap();
        assert_eq!(s.steps, 3);
        assert_eq!(s.final_train_loss(), Some(0.9));
        assert!((s.total_step_secs - 1.5).abs() < 1e-9);
        assert!((s.steps_per_sec() - 2.0).abs() < 1e-9);
        let val = s.final_eval("val").unwrap();
        assert_eq!(val.metrics, vec![("accuracy".to_string(), 0.5)]);
        assert_eq!(s.best_eval("val", "accuracy"), Some(0.5));
        // Time-to-target walks cumulative step seconds.
        assert_eq!(s.time_to_loss(1.6), Some(1.0));
        assert_eq!(s.time_to_loss(0.1), None);
        let show = render_show(&s, Some(1.6));
        assert!(show.contains("final train loss"), "{show}");
        assert!(show.contains("time to loss <= 1.6"), "{show}");
        let list = render_list(&[s]);
        assert!(list.contains("task=root_classification"), "{list}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn tail_is_bounded_and_recent() {
        let path = temp_path("tail");
        let j = EventJournal::create(&path).unwrap();
        for i in 0..(TAIL_CAP + 10) {
            j.write(&obj(vec![("kind", Json::Str("step".into())), ("step", int(i as u64))]))
                .unwrap();
        }
        let tail = j.tail();
        assert_eq!(tail.len(), TAIL_CAP);
        let first = tail[0].get("step").unwrap().as_i64().unwrap();
        assert_eq!(first as usize, 10, "oldest retained record is record 10");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn diff_reports_metric_deltas() {
        let a = write_run("diff_a", &[2.0, 1.0], 0.4);
        let b = write_run("diff_b", &[2.0, 0.5], 0.6);
        let sa = RunSummary::from_path(&a).unwrap();
        let sb = RunSummary::from_path(&b).unwrap();
        let text = render_diff(&sa, &sb);
        assert!(text.contains("final train loss"), "{text}");
        assert!(text.contains("(-0.5000)"), "{text}");
        assert!(text.contains("best val accuracy"), "{text}");
        assert!(text.contains("(+0.2000)"), "{text}");
        assert!(text.contains("config: mpnn"), "{text}");
        let _ = std::fs::remove_file(&a);
        let _ = std::fs::remove_file(&b);
    }

    #[test]
    fn schema_violations_are_structured_errors() {
        // Missing header.
        let path = temp_path("bad_header");
        std::fs::write(&path, "{\"kind\":\"step\",\"step\":0}\n").unwrap();
        let err = RunSummary::from_path(&path).unwrap_err();
        assert!(err.to_string().contains("run_start"), "{err}");
        // Wrong schema tag.
        std::fs::write(&path, "{\"kind\":\"run_start\",\"schema\":\"nope\"}\n").unwrap();
        let err = RunSummary::from_path(&path).unwrap_err();
        assert!(err.to_string().contains("tfgnn_events_v1"), "{err}");
        // Torn line.
        std::fs::write(&path, "{\"kind\":\"run_start\",\"schema\":\"tfgnn_events_v1\"}\n{oops")
            .unwrap();
        let err = RunSummary::from_path(&path).unwrap_err();
        assert!(err.to_string().contains(":2:"), "{err}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn non_finite_values_serialize_as_null() {
        let task = TaskMetrics::default();
        let ev = StepEvent {
            step: 0,
            epoch: 0,
            split: "train",
            loss: f64::NAN,
            examples: 0.0,
            task: &task,
            step_secs: 0.0,
            data_wait_secs: 0.0,
            grad: None,
        };
        let line = ev.to_event().to_string();
        assert!(!line.contains("NaN"), "{line}");
        let rec = Json::parse(&line).unwrap();
        assert!(matches!(rec.get("loss").unwrap(), Json::Null));
    }
}
