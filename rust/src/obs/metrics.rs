//! Process-global metrics registry: counters, gauges, histograms.
//!
//! Handles ([`Counter`], [`Gauge`], [`Histogram`]) are cheap `Arc`
//! clones of shared atomic cells; the registry only holds the
//! name→handle map behind a mutex, so the hot path never touches a
//! lock. Counters are sharded over cache-line-padded cells so
//! concurrent increments from worker threads do not bounce one cache
//! line; a snapshot sums the shards.
//!
//! Histograms use a fixed log₂ bucket layout (no allocation, no
//! locks): bucket 0 is the underflow bucket (zero, negatives,
//! subnormals and anything ≤ 2⁻²¹ ≈ 0.48 µs), buckets 1..=42 each
//! cover one power of two, and the last bucket is overflow (anything
//! > 2²¹ s ≈ 24 days, including `+inf`). `NaN` is rejected into a
//! dedicated `nan_rejected` counter rather than poisoning the sum.
//! The running sum is kept in integer microseconds (`u64` fetch_add)
//! so concurrent recording stays associative — a float accumulator
//! would make snapshots order-dependent.
//!
//! Every well-known metric is declared in [`METRICS`], the single
//! source of truth behind the `docs/metrics.md` table
//! ([`render_markdown`], byte-pinned by `tests/obs.rs`) and the
//! pre-registration done by [`global`]. Exporters:
//! [`MetricsSnapshot::to_json`] (stable JSON, `METRICS_*.json`) and
//! [`MetricsSnapshot::to_prometheus`] (text exposition format).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock, PoisonError};

use crate::util::json::Json;
use crate::{Error, Result};

/// Number of sharded cells per counter. Eight covers the pool sizes
/// the benches run (1/2/8 threads) without making snapshots costly.
const COUNTER_SHARDS: usize = 8;

/// Histogram bucket count: underflow + 42 powers of two + overflow.
pub const NUM_BUCKETS: usize = 44;

/// Exponent of the underflow boundary: bucket 0 holds v ≤ 2^MIN_EXP.
const MIN_EXP: i32 = -21;

/// 2⁻²¹ exactly — the upper bound of the underflow bucket (~0.48 µs).
const UNDERFLOW_UPPER: f64 = 4.76837158203125e-7;

#[repr(align(64))]
struct PaddedU64(AtomicU64);

std::thread_local! {
    /// This thread's counter shard, assigned round-robin on first use.
    static SHARD: std::cell::Cell<usize> = const { std::cell::Cell::new(usize::MAX) };
}

fn shard_index() -> usize {
    SHARD.with(|c| {
        let v = c.get();
        if v != usize::MAX {
            return v;
        }
        static NEXT: AtomicUsize = AtomicUsize::new(0);
        let v = NEXT.fetch_add(1, Ordering::Relaxed) % COUNTER_SHARDS;
        c.set(v);
        v
    })
}

/// Monotonic counter, sharded over padded atomics. An increment is a
/// single relaxed `fetch_add` on this thread's shard.
#[derive(Clone)]
pub struct Counter {
    cells: Arc<[PaddedU64; COUNTER_SHARDS]>,
}

impl Counter {
    /// A counter not registered anywhere (unit tests, kind clashes).
    pub fn detached() -> Self {
        Counter { cells: Arc::new(std::array::from_fn(|_| PaddedU64(AtomicU64::new(0)))) }
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        if let Some(cell) = self.cells.get(shard_index()) {
            cell.0.fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Sum over shards. Concurrent increments may or may not be seen;
    /// all increments that happened-before the call are.
    pub fn get(&self) -> u64 {
        self.cells.iter().map(|c| c.0.load(Ordering::Relaxed)).sum()
    }
}

/// Instantaneous signed value (queue depth, generation).
#[derive(Clone)]
pub struct Gauge {
    cell: Arc<AtomicI64>,
}

impl Gauge {
    pub fn detached() -> Self {
        Gauge { cell: Arc::new(AtomicI64::new(0)) }
    }

    #[inline]
    pub fn set(&self, v: i64) {
        self.cell.store(v, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, n: i64) {
        self.cell.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn sub(&self, n: i64) {
        self.cell.fetch_sub(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.cell.load(Ordering::Relaxed)
    }
}

struct HistInner {
    buckets: [AtomicU64; NUM_BUCKETS],
    count: AtomicU64,
    /// Running sum in integer microseconds (micro-units for unitless
    /// histograms like wave size): u64 `fetch_add` keeps concurrent
    /// recording associative where a float accumulator would not be.
    sum_micros: AtomicU64,
    nan_rejected: AtomicU64,
}

/// Fixed log₂-bucket histogram; see the module docs for the layout.
#[derive(Clone)]
pub struct Histogram {
    inner: Arc<HistInner>,
}

impl Histogram {
    pub fn detached() -> Self {
        Histogram {
            inner: Arc::new(HistInner {
                buckets: std::array::from_fn(|_| AtomicU64::new(0)),
                count: AtomicU64::new(0),
                sum_micros: AtomicU64::new(0),
                nan_rejected: AtomicU64::new(0),
            }),
        }
    }

    /// Record one observation (seconds for `_seconds` metrics). NaN
    /// is rejected into the `nan_rejected` counter; everything else
    /// lands in exactly one bucket.
    pub fn record(&self, v: f64) {
        let Some(i) = bucket_index(v) else {
            self.inner.nan_rejected.fetch_add(1, Ordering::Relaxed);
            return;
        };
        if let Some(b) = self.inner.buckets.get(i) {
            b.fetch_add(1, Ordering::Relaxed);
        }
        self.inner.count.fetch_add(1, Ordering::Relaxed);
        let micros = v * 1e6;
        if micros > 0.0 {
            let m = if micros >= u64::MAX as f64 { u64::MAX } else { micros.round() as u64 };
            self.inner.sum_micros.fetch_add(m, Ordering::Relaxed);
        }
    }

    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            count: self.inner.count.load(Ordering::Relaxed),
            sum_micros: self.inner.sum_micros.load(Ordering::Relaxed),
            nan_rejected: self.inner.nan_rejected.load(Ordering::Relaxed),
            buckets: self.inner.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
        }
    }
}

/// Map a value to its bucket; `None` means NaN (rejected).
fn bucket_index(v: f64) -> Option<usize> {
    if v.is_nan() {
        return None;
    }
    if v <= UNDERFLOW_UPPER {
        // Zero, negatives, subnormals and sub-half-microsecond values.
        return Some(0);
    }
    if v == f64::INFINITY {
        return Some(NUM_BUCKETS - 1);
    }
    let bits = v.to_bits();
    let exp = (((bits >> 52) & 0x7ff) as i32) - 1023;
    let mantissa = bits & ((1u64 << 52) - 1);
    // v lies in [2^exp, 2^(exp+1)); bucket i covers (2^(MIN_EXP+i-1),
    // 2^(MIN_EXP+i)], so exact powers of two stay one bucket lower.
    let ub_exp = if mantissa == 0 { exp } else { exp + 1 };
    let i = (ub_exp - MIN_EXP).max(1) as usize;
    Some(i.min(NUM_BUCKETS - 1))
}

/// Inclusive upper bound of bucket `i` (`+inf` for the overflow
/// bucket). Export-path only.
pub fn bucket_upper(i: usize) -> f64 {
    if i == 0 {
        UNDERFLOW_UPPER
    } else if i >= NUM_BUCKETS - 1 {
        f64::INFINITY
    } else {
        2f64.powi(MIN_EXP + i as i32)
    }
}

// ---- registry --------------------------------------------------------------

#[derive(Default)]
struct Inner {
    counters: BTreeMap<String, Counter>,
    gauges: BTreeMap<String, Gauge>,
    histograms: BTreeMap<String, Histogram>,
}

/// Name→handle map. Lookup takes the mutex; the handles it returns
/// are lock-free, so call sites cache them (see `obs_counter!`).
#[derive(Default)]
pub struct MetricsRegistry {
    inner: Mutex<Inner>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// The counter registered under `name`, creating it on first use.
    /// A name already registered as another kind yields a detached
    /// handle (recorded values go nowhere) — never a panic.
    pub fn counter(&self, name: &str) -> Counter {
        let mut g = self.inner.lock().unwrap_or_else(PoisonError::into_inner);
        if let Some(c) = g.counters.get(name) {
            return c.clone();
        }
        if g.gauges.contains_key(name) || g.histograms.contains_key(name) {
            return Counter::detached();
        }
        let c = Counter::detached();
        g.counters.insert(name.to_string(), c.clone());
        c
    }

    /// See [`MetricsRegistry::counter`].
    pub fn gauge(&self, name: &str) -> Gauge {
        let mut g = self.inner.lock().unwrap_or_else(PoisonError::into_inner);
        if let Some(v) = g.gauges.get(name) {
            return v.clone();
        }
        if g.counters.contains_key(name) || g.histograms.contains_key(name) {
            return Gauge::detached();
        }
        let v = Gauge::detached();
        g.gauges.insert(name.to_string(), v.clone());
        v
    }

    /// See [`MetricsRegistry::counter`].
    pub fn histogram(&self, name: &str) -> Histogram {
        let mut g = self.inner.lock().unwrap_or_else(PoisonError::into_inner);
        if let Some(h) = g.histograms.get(name) {
            return h.clone();
        }
        if g.counters.contains_key(name) || g.gauges.contains_key(name) {
            return Histogram::detached();
        }
        let h = Histogram::detached();
        g.histograms.insert(name.to_string(), h.clone());
        h
    }

    /// Read every registered metric once into a coherent-per-metric
    /// snapshot (counters sum their shards at read time).
    pub fn snapshot(&self) -> MetricsSnapshot {
        let g = self.inner.lock().unwrap_or_else(PoisonError::into_inner);
        MetricsSnapshot {
            counters: g.counters.iter().map(|(k, c)| (k.clone(), c.get())).collect(),
            gauges: g.gauges.iter().map(|(k, v)| (k.clone(), v.get())).collect(),
            histograms: g.histograms.iter().map(|(k, h)| (k.clone(), h.snapshot())).collect(),
        }
    }
}

static GLOBAL: OnceLock<MetricsRegistry> = OnceLock::new();

/// The process-global registry, with every [`METRICS`] entry
/// pre-registered so snapshots have a stable shape from the start.
pub fn global() -> &'static MetricsRegistry {
    GLOBAL.get_or_init(|| {
        let r = MetricsRegistry::new();
        for def in METRICS {
            match def.kind {
                MetricKind::Counter => {
                    r.counter(def.name);
                }
                MetricKind::Gauge => {
                    r.gauge(def.name);
                }
                MetricKind::Histogram => {
                    r.histogram(def.name);
                }
            }
        }
        r
    })
}

// ---- snapshots -------------------------------------------------------------

/// Point-in-time copy of one histogram.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct HistogramSnapshot {
    pub count: u64,
    pub sum_micros: u64,
    pub nan_rejected: u64,
    /// `NUM_BUCKETS` per-bucket counts (not cumulative).
    pub buckets: Vec<u64>,
}

impl HistogramSnapshot {
    pub fn sum_seconds(&self) -> f64 {
        self.sum_micros as f64 / 1e6
    }

    pub fn mean_seconds(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_seconds() / self.count as f64
        }
    }
}

/// Point-in-time copy of the whole registry; the unit of export,
/// diffing (`delta_since`) and the `tfgnn stats` renderer.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct MetricsSnapshot {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, i64>,
    pub histograms: BTreeMap<String, HistogramSnapshot>,
}

fn int(v: u64) -> Json {
    Json::Int(i64::try_from(v).unwrap_or(i64::MAX))
}

impl MetricsSnapshot {
    /// Stable JSON document (the `METRICS_*.json` schema): three
    /// sorted maps under `counters` / `gauges` / `histograms`.
    pub fn to_json(&self) -> Json {
        let counters = Json::Obj(self.counters.iter().map(|(k, v)| (k.clone(), int(*v))).collect());
        let gauges =
            Json::Obj(self.gauges.iter().map(|(k, v)| (k.clone(), Json::Int(*v))).collect());
        let histograms = Json::Obj(
            self.histograms
                .iter()
                .map(|(k, h)| {
                    let mut m = BTreeMap::new();
                    m.insert("count".to_string(), int(h.count));
                    m.insert("sum_micros".to_string(), int(h.sum_micros));
                    m.insert("nan_rejected".to_string(), int(h.nan_rejected));
                    m.insert(
                        "bucket_counts".to_string(),
                        Json::Arr(h.buckets.iter().map(|&b| int(b)).collect()),
                    );
                    (k.clone(), Json::Obj(m))
                })
                .collect(),
        );
        let mut top = BTreeMap::new();
        top.insert("schema".to_string(), Json::Str("tfgnn_metrics_v1".to_string()));
        top.insert("counters".to_string(), counters);
        top.insert("gauges".to_string(), gauges);
        top.insert("histograms".to_string(), histograms);
        Json::Obj(top)
    }

    /// Parse a document produced by [`MetricsSnapshot::to_json`].
    pub fn from_json(doc: &Json) -> Result<MetricsSnapshot> {
        let mut snap = MetricsSnapshot::default();
        for (k, v) in doc.get("counters")?.as_obj()? {
            snap.counters.insert(k.clone(), u64::try_from(v.as_i64()?).unwrap_or(0));
        }
        for (k, v) in doc.get("gauges")?.as_obj()? {
            snap.gauges.insert(k.clone(), v.as_i64()?);
        }
        for (k, v) in doc.get("histograms")?.as_obj()? {
            let mut h = HistogramSnapshot {
                count: u64::try_from(v.get("count")?.as_i64()?).unwrap_or(0),
                sum_micros: u64::try_from(v.get("sum_micros")?.as_i64()?).unwrap_or(0),
                nan_rejected: u64::try_from(v.get("nan_rejected")?.as_i64()?).unwrap_or(0),
                buckets: Vec::with_capacity(NUM_BUCKETS),
            };
            for b in v.get("bucket_counts")?.as_arr()? {
                h.buckets.push(u64::try_from(b.as_i64()?).unwrap_or(0));
            }
            if h.buckets.len() != NUM_BUCKETS {
                return Err(Error::Codec(format!(
                    "histogram {k:?} has {} buckets, expected {NUM_BUCKETS}",
                    h.buckets.len()
                )));
            }
            snap.histograms.insert(k.clone(), h);
        }
        Ok(snap)
    }

    /// What happened between `earlier` and `self`: counters and
    /// histogram tallies subtract (saturating); gauges keep their
    /// current value (a delta of an instantaneous reading is
    /// meaningless).
    pub fn delta_since(&self, earlier: &MetricsSnapshot) -> MetricsSnapshot {
        let counters = self
            .counters
            .iter()
            .map(|(k, v)| {
                (k.clone(), v.saturating_sub(earlier.counters.get(k).copied().unwrap_or(0)))
            })
            .collect();
        let histograms = self
            .histograms
            .iter()
            .map(|(k, h)| {
                let e = earlier.histograms.get(k);
                let zero = HistogramSnapshot::default();
                let e = e.unwrap_or(&zero);
                let buckets = h
                    .buckets
                    .iter()
                    .enumerate()
                    .map(|(i, b)| b.saturating_sub(e.buckets.get(i).copied().unwrap_or(0)))
                    .collect();
                (
                    k.clone(),
                    HistogramSnapshot {
                        count: h.count.saturating_sub(e.count),
                        sum_micros: h.sum_micros.saturating_sub(e.sum_micros),
                        nan_rejected: h.nan_rejected.saturating_sub(e.nan_rejected),
                        buckets,
                    },
                )
            })
            .collect();
        MetricsSnapshot { counters, gauges: self.gauges.clone(), histograms }
    }

    /// Compact JSON for embedding in bench rows: nonzero counters,
    /// nonzero gauges, and `{count, sum_micros}` per touched
    /// histogram — small enough to diff by eye in `BENCH_*.json`.
    pub fn to_compact_json(&self) -> Json {
        let mut m = BTreeMap::new();
        for (k, v) in &self.counters {
            if *v != 0 {
                m.insert(k.clone(), int(*v));
            }
        }
        for (k, v) in &self.gauges {
            if *v != 0 {
                m.insert(k.clone(), Json::Int(*v));
            }
        }
        for (k, h) in &self.histograms {
            if h.count != 0 {
                let mut hm = BTreeMap::new();
                hm.insert("count".to_string(), int(h.count));
                hm.insert("sum_micros".to_string(), int(h.sum_micros));
                m.insert(k.clone(), Json::Obj(hm));
            }
        }
        Json::Obj(m)
    }

    /// Prometheus text exposition format (counters, gauges, then
    /// histograms with cumulative `le` buckets).
    pub fn to_prometheus(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        for (name, v) in &self.counters {
            if let Some(def) = lookup(name) {
                let _ = writeln!(out, "# HELP {name} {}", def.help);
            }
            let _ = writeln!(out, "# TYPE {name} counter");
            let _ = writeln!(out, "{name} {v}");
        }
        for (name, v) in &self.gauges {
            if let Some(def) = lookup(name) {
                let _ = writeln!(out, "# HELP {name} {}", def.help);
            }
            let _ = writeln!(out, "# TYPE {name} gauge");
            let _ = writeln!(out, "{name} {v}");
        }
        for (name, h) in &self.histograms {
            if let Some(def) = lookup(name) {
                let _ = writeln!(out, "# HELP {name} {}", def.help);
            }
            let _ = writeln!(out, "# TYPE {name} histogram");
            let mut cum: u64 = 0;
            for (i, b) in h.buckets.iter().enumerate() {
                cum = cum.saturating_add(*b);
                if i == NUM_BUCKETS - 1 {
                    let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {cum}");
                } else {
                    let _ = writeln!(out, "{name}_bucket{{le=\"{}\"}} {cum}", bucket_upper(i));
                }
            }
            let _ = writeln!(out, "{name}_sum {}", h.sum_seconds());
            let _ = writeln!(out, "{name}_count {}", h.count);
        }
        out
    }
}

// ---- the well-known metric table -------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MetricKind {
    Counter,
    Gauge,
    Histogram,
}

impl MetricKind {
    pub fn name(&self) -> &'static str {
        match self {
            MetricKind::Counter => "counter",
            MetricKind::Gauge => "gauge",
            MetricKind::Histogram => "histogram",
        }
    }
}

/// One row of the metric table: the contract between the wiring, the
/// exporters and `docs/metrics.md`.
pub struct MetricDef {
    pub name: &'static str,
    pub kind: MetricKind,
    pub stage: &'static str,
    pub help: &'static str,
}

/// Well-known metric names, so wiring sites cannot typo a string.
pub mod names {
    pub const ADMIN_REQUESTS: &str = "admin_requests_total";
    pub const FLIGHT_DUMPS: &str = "flight_dumps_total";
    pub const FLIGHT_RATE_LIMITED: &str = "flight_rate_limited_total";
    pub const HEALTH_HEARTBEATS: &str = "health_heartbeats_total";
    pub const HEALTH_UNHEALTHY: &str = "health_unhealthy";
    pub const HEALTH_WATCHDOG_TRIPS: &str = "health_watchdog_trips_total";
    pub const OBS_TRACE_DROPPED: &str = "obs_trace_dropped_total";
    pub const SAMPLER_RETRY_ATTEMPTS: &str = "sampler_retry_attempts_total";
    pub const SAMPLER_RETRY_EXHAUSTED: &str = "sampler_retry_exhausted_total";
    pub const SAMPLER_SHARD_FANOUT_SECONDS: &str = "sampler_shard_fanout_seconds";
    pub const SAMPLER_SUBGRAPHS: &str = "sampler_subgraphs_total";
    pub const SERVE_BATCHES: &str = "serve_batches_total";
    pub const SERVE_CACHE_EVICTIONS: &str = "serve_cache_evictions_total";
    pub const SERVE_CACHE_HITS: &str = "serve_cache_hits_total";
    pub const SERVE_CACHE_MISSES: &str = "serve_cache_misses_total";
    pub const SERVE_DEADLINE_EXPIRED: &str = "serve_deadline_expired_total";
    pub const SERVE_FAILED_BATCHES: &str = "serve_failed_batches_total";
    pub const SERVE_GENERATION: &str = "serve_generation";
    pub const SERVE_QUEUE_DEPTH: &str = "serve_queue_depth";
    pub const SERVE_REJECTED: &str = "serve_rejected_total";
    pub const SERVE_REQUEST_DEADLINE_SECONDS: &str = "serve_request_deadline_seconds";
    pub const SERVE_REQUEST_FAILED_SECONDS: &str = "serve_request_failed_seconds";
    pub const SERVE_REQUEST_OK_SECONDS: &str = "serve_request_ok_seconds";
    pub const SERVE_REQUEST_REJECTED_SECONDS: &str = "serve_request_rejected_seconds";
    pub const SERVE_REQUESTS: &str = "serve_requests_total";
    pub const SERVE_SWAPS: &str = "serve_swaps_total";
    pub const SERVE_WAVE_SECONDS: &str = "serve_wave_seconds";
    pub const SERVE_WAVE_SIZE: &str = "serve_wave_size";
    pub const THREADPOOL_EXECUTE_SECONDS: &str = "threadpool_execute_seconds";
    pub const THREADPOOL_JOBS: &str = "threadpool_jobs_total";
    pub const THREADPOOL_QUEUE_WAIT_SECONDS: &str = "threadpool_queue_wait_seconds";
    pub const TRAINER_ALLREDUCE_SECONDS: &str = "trainer_allreduce_seconds";
    pub const TRAINER_BACKWARD_SECONDS: &str = "trainer_backward_seconds";
    pub const TRAINER_DATA_WAIT_SECONDS: &str = "trainer_data_wait_seconds";
    pub const TRAINER_EVENTS: &str = "trainer_events_total";
    pub const TRAINER_FORWARD_SECONDS: &str = "trainer_forward_seconds";
    pub const TRAINER_GRAD_EXPLOSIONS: &str = "trainer_grad_explosions_total";
    pub const TRAINER_GRAD_NONFINITE: &str = "trainer_grad_nonfinite_total";
    pub const TRAINER_GRAD_NORM: &str = "trainer_grad_norm";
    pub const TRAINER_OPTIMIZER_SECONDS: &str = "trainer_optimizer_seconds";
    pub const TRAINER_STEPS: &str = "trainer_steps_total";
    pub const TRAINER_UPDATE_RATIO: &str = "trainer_update_ratio";
}

/// Every well-known metric, sorted by name. `docs/metrics.md` is
/// generated from this table; `tests/obs.rs` pins the two together.
pub const METRICS: &[MetricDef] = &[
    MetricDef {
        name: names::ADMIN_REQUESTS,
        kind: MetricKind::Counter,
        stage: "admin",
        help: "HTTP requests answered by the admin endpoint, across all paths.",
    },
    MetricDef {
        name: names::FLIGHT_DUMPS,
        kind: MetricKind::Counter,
        stage: "flight",
        help: "Incident snapshots written by the flight recorder.",
    },
    MetricDef {
        name: names::FLIGHT_RATE_LIMITED,
        kind: MetricKind::Counter,
        stage: "flight",
        help: "Flight-recorder triggers suppressed by the rate limiter.",
    },
    MetricDef {
        name: names::HEALTH_HEARTBEATS,
        kind: MetricKind::Counter,
        stage: "health",
        help: "Lane heartbeats recorded by watchdogs, one per wave begin.",
    },
    MetricDef {
        name: names::HEALTH_UNHEALTHY,
        kind: MetricKind::Gauge,
        stage: "health",
        help: "1 while a watchdog reports unhealthy, 0 otherwise.",
    },
    MetricDef {
        name: names::HEALTH_WATCHDOG_TRIPS,
        kind: MetricKind::Counter,
        stage: "health",
        help: "Healthy-to-unhealthy watchdog transitions (wedged lane or stalled queue).",
    },
    MetricDef {
        name: names::OBS_TRACE_DROPPED,
        kind: MetricKind::Counter,
        stage: "obs",
        help: "Trace-ring events overwritten before export; nonzero means the Chrome trace is incomplete.",
    },
    MetricDef {
        name: names::SAMPLER_RETRY_ATTEMPTS,
        kind: MetricKind::Counter,
        stage: "sampler",
        help: "RPC attempts made under RetryPolicy::run_lazy, including each first try.",
    },
    MetricDef {
        name: names::SAMPLER_RETRY_EXHAUSTED,
        kind: MetricKind::Counter,
        stage: "sampler",
        help: "run_lazy calls that exhausted max_attempts and returned the tallied error.",
    },
    MetricDef {
        name: names::SAMPLER_SHARD_FANOUT_SECONDS,
        kind: MetricKind::Histogram,
        stage: "sampler",
        help: "Per-shard fanout latency of sample_batch_parallel, one observation per shard task.",
    },
    MetricDef {
        name: names::SAMPLER_SUBGRAPHS,
        kind: MetricKind::Counter,
        stage: "sampler",
        help: "Rooted subgraphs assembled; the serial and parallel paths share this tail.",
    },
    MetricDef {
        name: names::SERVE_BATCHES,
        kind: MetricKind::Counter,
        stage: "serve",
        help: "Waves executed by batcher lanes.",
    },
    MetricDef {
        name: names::SERVE_CACHE_EVICTIONS,
        kind: MetricKind::Counter,
        stage: "serve",
        help: "LRU subgraph cache evictions.",
    },
    MetricDef {
        name: names::SERVE_CACHE_HITS,
        kind: MetricKind::Counter,
        stage: "serve",
        help: "Subgraph cache hits.",
    },
    MetricDef {
        name: names::SERVE_CACHE_MISSES,
        kind: MetricKind::Counter,
        stage: "serve",
        help: "Subgraph cache misses.",
    },
    MetricDef {
        name: names::SERVE_DEADLINE_EXPIRED,
        kind: MetricKind::Counter,
        stage: "serve",
        help: "Requests answered DeadlineExceeded; they never reach a model forward pass.",
    },
    MetricDef {
        name: names::SERVE_FAILED_BATCHES,
        kind: MetricKind::Counter,
        stage: "serve",
        help: "Waves that failed as a unit and rejected their requests.",
    },
    MetricDef {
        name: names::SERVE_GENERATION,
        kind: MetricKind::Gauge,
        stage: "serve",
        help: "Model generation currently serving; bumped by each hot swap.",
    },
    MetricDef {
        name: names::SERVE_QUEUE_DEPTH,
        kind: MetricKind::Gauge,
        stage: "serve",
        help: "Requests admitted but not yet replied to, across all lanes.",
    },
    MetricDef {
        name: names::SERVE_REJECTED,
        kind: MetricKind::Counter,
        stage: "serve",
        help: "Requests rejected by admission control with Overloaded.",
    },
    MetricDef {
        name: names::SERVE_REQUEST_DEADLINE_SECONDS,
        kind: MetricKind::Histogram,
        stage: "serve",
        help: "End-to-end latency of requests answered DeadlineExceeded.",
    },
    MetricDef {
        name: names::SERVE_REQUEST_FAILED_SECONDS,
        kind: MetricKind::Histogram,
        stage: "serve",
        help: "End-to-end latency of requests answered with an execution error.",
    },
    MetricDef {
        name: names::SERVE_REQUEST_OK_SECONDS,
        kind: MetricKind::Histogram,
        stage: "serve",
        help: "End-to-end latency of successfully answered requests.",
    },
    MetricDef {
        name: names::SERVE_REQUEST_REJECTED_SECONDS,
        kind: MetricKind::Histogram,
        stage: "serve",
        help: "End-to-end latency of requests rejected by admission control.",
    },
    MetricDef {
        name: names::SERVE_REQUESTS,
        kind: MetricKind::Counter,
        stage: "serve",
        help: "Requests pulled into an executed wave (rejections excluded).",
    },
    MetricDef {
        name: names::SERVE_SWAPS,
        kind: MetricKind::Counter,
        stage: "serve",
        help: "Hot swaps applied to the model slot.",
    },
    MetricDef {
        name: names::SERVE_WAVE_SECONDS,
        kind: MetricKind::Histogram,
        stage: "serve",
        help: "Wall time of one batcher wave: collect, execute and reply.",
    },
    MetricDef {
        name: names::SERVE_WAVE_SIZE,
        kind: MetricKind::Histogram,
        stage: "serve",
        help: "Requests per batcher wave (unitless; sum_micros is size times 1e6).",
    },
    MetricDef {
        name: names::THREADPOOL_EXECUTE_SECONDS,
        kind: MetricKind::Histogram,
        stage: "threadpool",
        help: "Job body execution time on a worker thread.",
    },
    MetricDef {
        name: names::THREADPOOL_JOBS,
        kind: MetricKind::Counter,
        stage: "threadpool",
        help: "Jobs submitted through ThreadPool::execute.",
    },
    MetricDef {
        name: names::THREADPOOL_QUEUE_WAIT_SECONDS,
        kind: MetricKind::Histogram,
        stage: "threadpool",
        help: "Time a job waited in the queue before a worker picked it up.",
    },
    MetricDef {
        name: names::TRAINER_ALLREDUCE_SECONDS,
        kind: MetricKind::Histogram,
        stage: "trainer",
        help: "Deterministic in-order gradient all-reduce time per step.",
    },
    MetricDef {
        name: names::TRAINER_BACKWARD_SECONDS,
        kind: MetricKind::Histogram,
        stage: "trainer",
        help: "Backward (VJP) time per trunk backward call.",
    },
    MetricDef {
        name: names::TRAINER_DATA_WAIT_SECONDS,
        kind: MetricKind::Histogram,
        stage: "trainer",
        help: "Time the epoch loop waited on the sampler/pipeline for the next padded wave.",
    },
    MetricDef {
        name: names::TRAINER_EVENTS,
        kind: MetricKind::Counter,
        stage: "trainer",
        help: "Records appended to a training-run event journal (--events-out).",
    },
    MetricDef {
        name: names::TRAINER_FORWARD_SECONDS,
        kind: MetricKind::Histogram,
        stage: "trainer",
        help: "Forward (tape-recording) time per trunk forward call.",
    },
    MetricDef {
        name: names::TRAINER_GRAD_EXPLOSIONS,
        kind: MetricKind::Counter,
        stage: "trainer",
        help: "Gradient-health sentinel trips on a global norm above --grad-norm-limit.",
    },
    MetricDef {
        name: names::TRAINER_GRAD_NONFINITE,
        kind: MetricKind::Counter,
        stage: "trainer",
        help: "Gradient-health sentinel trips on a NaN/Inf gradient tensor.",
    },
    MetricDef {
        name: names::TRAINER_GRAD_NORM,
        kind: MetricKind::Histogram,
        stage: "trainer",
        help: "Global gradient L2 norm per step (unitless; recorded when probes are on).",
    },
    MetricDef {
        name: names::TRAINER_OPTIMIZER_SECONDS,
        kind: MetricKind::Histogram,
        stage: "trainer",
        help: "Optimizer (Adam) update time per step.",
    },
    MetricDef {
        name: names::TRAINER_STEPS,
        kind: MetricKind::Counter,
        stage: "trainer",
        help: "Training steps completed by NativeTrainer::train_batch.",
    },
    MetricDef {
        name: names::TRAINER_UPDATE_RATIO,
        kind: MetricKind::Histogram,
        stage: "trainer",
        help: "Per-step update ratio (delta-param norm over param norm, unitless).",
    },
];

/// The [`METRICS`] row for `name`, if it is a well-known metric.
pub fn lookup(name: &str) -> Option<&'static MetricDef> {
    METRICS.iter().find(|d| d.name == name)
}

/// Generate `docs/metrics.md` from [`METRICS`] (pinned to the
/// checked-in file by `tests/obs.rs`).
pub fn render_markdown() -> String {
    let mut out = String::new();
    out.push_str("# Metrics reference\n\n");
    out.push_str(
        "Generated from the single source-of-truth table in \
         `rust/src/obs/metrics.rs` — edit `METRICS`, not this file \
         (`tests/obs.rs` pins the two together).\n\n",
    );
    out.push_str(
        "All metrics are process-global and live in the `obs::metrics` \
         registry. Counters and gauges are always on; histograms only \
         record while recording is enabled (`--metrics-out`, a bench, or \
         `obs::set_recording`). Histograms use 44 fixed log2 buckets \
         spanning ~0.5us to ~24 days with underflow and overflow buckets \
         at the ends; NaN observations are rejected into a nan_rejected \
         counter. Export formats: stable JSON (`METRICS_*.json`) and \
         Prometheus text, rendered by `tfgnn stats`.\n\n",
    );
    out.push_str("| Name | Kind | Stage | Description |\n");
    out.push_str("|---|---|---|---|\n");
    for m in METRICS {
        out.push_str(&format!("| `{}` | {} | {} | {} |\n", m.name, m.kind.name(), m.stage, m.help));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_sums_across_threads() {
        let c = Counter::detached();
        std::thread::scope(|s| {
            for _ in 0..4 {
                let c = c.clone();
                s.spawn(move || {
                    for _ in 0..1000 {
                        c.inc();
                    }
                });
            }
        });
        assert_eq!(c.get(), 4000);
    }

    #[test]
    fn gauge_add_sub_set() {
        let g = Gauge::detached();
        g.add(5);
        g.sub(2);
        assert_eq!(g.get(), 3);
        g.set(-7);
        assert_eq!(g.get(), -7);
    }

    #[test]
    fn bucket_boundaries() {
        // Underflow: zero, negatives, subnormals, the boundary itself.
        assert_eq!(bucket_index(0.0), Some(0));
        assert_eq!(bucket_index(-1.0), Some(0));
        assert_eq!(bucket_index(f64::MIN_POSITIVE / 2.0), Some(0), "subnormal");
        assert_eq!(bucket_index(UNDERFLOW_UPPER), Some(0));
        // Just above the boundary lands in bucket 1.
        assert_eq!(bucket_index(UNDERFLOW_UPPER * 1.0001), Some(1));
        // Exact powers of two are inclusive upper bounds.
        assert_eq!(bucket_index(UNDERFLOW_UPPER * 2.0), Some(1));
        assert_eq!(bucket_index(UNDERFLOW_UPPER * 2.0001), Some(2));
        // 1.0s: (2^-1, 2^0] is bucket 21 - MIN_EXP offset.
        assert_eq!(bucket_index(1.0), Some((-MIN_EXP) as usize));
        assert_eq!(bucket_index(0.75), Some((-MIN_EXP) as usize));
        // Overflow: max, infinity.
        assert_eq!(bucket_index(f64::MAX), Some(NUM_BUCKETS - 1));
        assert_eq!(bucket_index(f64::INFINITY), Some(NUM_BUCKETS - 1));
        // NaN is rejected, not bucketed.
        assert_eq!(bucket_index(f64::NAN), None);
    }

    #[test]
    fn histogram_rejects_nan_and_sums() {
        let h = Histogram::detached();
        h.record(1.0);
        h.record(0.5);
        h.record(f64::NAN);
        let s = h.snapshot();
        assert_eq!(s.count, 2);
        assert_eq!(s.nan_rejected, 1);
        assert_eq!(s.sum_micros, 1_500_000);
        assert!((s.sum_seconds() - 1.5).abs() < 1e-9);
        assert_eq!(s.buckets.iter().sum::<u64>(), 2);
    }

    #[test]
    fn bucket_uppers_are_monotonic() {
        for i in 1..NUM_BUCKETS {
            assert!(bucket_upper(i) > bucket_upper(i - 1), "bucket {i}");
        }
        assert_eq!(bucket_upper(NUM_BUCKETS - 1), f64::INFINITY);
    }

    #[test]
    fn registry_same_name_same_handle_kind_clash_detached() {
        let r = MetricsRegistry::new();
        let a = r.counter("x_total");
        let b = r.counter("x_total");
        a.inc();
        b.inc();
        assert_eq!(a.get(), 2, "same name must share cells");
        // Registering the same name as a different kind never panics
        // and never aliases: the clashing handle is detached.
        let h = r.histogram("x_total");
        h.record(1.0);
        assert_eq!(r.snapshot().counters.get("x_total"), Some(&2));
        assert!(!r.snapshot().histograms.contains_key("x_total"));
    }

    #[test]
    fn snapshot_json_roundtrip_and_delta() {
        let r = MetricsRegistry::new();
        r.counter("a_total").add(3);
        r.gauge("depth").set(-2);
        r.histogram("lat_seconds").record(0.25);
        let s1 = r.snapshot();
        let parsed = MetricsSnapshot::from_json(&s1.to_json()).expect("roundtrip");
        assert_eq!(parsed, s1);
        r.counter("a_total").add(4);
        r.histogram("lat_seconds").record(0.5);
        let d = r.snapshot().delta_since(&s1);
        assert_eq!(d.counters.get("a_total"), Some(&4));
        let h = d.histograms.get("lat_seconds").expect("hist");
        assert_eq!(h.count, 1);
        assert_eq!(h.buckets.iter().sum::<u64>(), 1);
    }

    #[test]
    fn prometheus_render_shape() {
        let r = MetricsRegistry::new();
        r.counter(names::SERVE_REQUESTS).add(7);
        r.histogram(names::SERVE_WAVE_SECONDS).record(0.001);
        let text = r.snapshot().to_prometheus();
        assert!(text.contains("# TYPE serve_requests_total counter"));
        assert!(text.contains("serve_requests_total 7"));
        assert!(text.contains("# HELP serve_requests_total"));
        assert!(text.contains("# TYPE serve_wave_seconds histogram"));
        assert!(text.contains("serve_wave_seconds_bucket{le=\"+Inf\"} 1"));
        assert!(text.contains("serve_wave_seconds_count 1"));
    }

    #[test]
    fn metric_table_is_sorted_and_named_consistently() {
        for w in METRICS.windows(2) {
            assert!(w[0].name < w[1].name, "METRICS must stay sorted: {}", w[1].name);
        }
        for m in METRICS {
            match m.kind {
                MetricKind::Counter => {
                    assert!(m.name.ends_with("_total"), "{}", m.name);
                }
                MetricKind::Histogram => {
                    // Histograms are seconds-valued except the listed
                    // unitless distributions.
                    let unitless = [
                        names::SERVE_WAVE_SIZE,
                        names::TRAINER_GRAD_NORM,
                        names::TRAINER_UPDATE_RATIO,
                    ];
                    assert!(
                        m.name.ends_with("_seconds") || unitless.contains(&m.name),
                        "{}",
                        m.name
                    );
                }
                MetricKind::Gauge => {}
            }
            assert!(!m.help.contains('|'), "help must stay table-safe: {}", m.name);
        }
    }

    #[test]
    fn markdown_covers_every_metric() {
        let md = render_markdown();
        assert!(md.starts_with("# Metrics reference"));
        for m in METRICS {
            assert!(md.contains(m.name), "{} missing from markdown", m.name);
        }
    }

    #[test]
    fn global_registry_preregisters_the_table() {
        let snap = global().snapshot();
        for m in METRICS {
            let present = match m.kind {
                MetricKind::Counter => snap.counters.contains_key(m.name),
                MetricKind::Gauge => snap.gauges.contains_key(m.name),
                MetricKind::Histogram => snap.histograms.contains_key(m.name),
            };
            assert!(present, "{} not pre-registered", m.name);
        }
    }
}
