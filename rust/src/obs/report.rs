//! Export and rendering: `--metrics-out` / `--trace-out` plumbing and
//! the `tfgnn stats` renderer.
//!
//! The CLI entry points call [`enable`] before the workload (turning
//! on timed recording and, if a trace path was given, span recording)
//! and [`finish`] after it (writing the metrics snapshot and the
//! Chrome trace to the requested paths). `tfgnn stats FILE` reads a
//! written `METRICS_*.json` back and renders it with [`render_stats`].

use std::collections::BTreeMap;

use super::metrics::{self, HistogramSnapshot, MetricsSnapshot};
use super::trace;
use crate::Result;

/// Turn on recording for the requested outputs: any output enables
/// timed metrics; a trace output additionally enables span recording.
/// With both `None` this is a no-op and everything stays inert.
pub fn enable(metrics_out: Option<&str>, trace_out: Option<&str>) {
    if metrics_out.is_some() || trace_out.is_some() {
        super::set_recording(true);
    }
    if trace_out.is_some() {
        trace::set_enabled(true);
    }
}

/// Write the requested outputs after the workload. Recording stays on
/// (the process is about to exit; repeated calls just re-snapshot).
pub fn finish(metrics_out: Option<&str>, trace_out: Option<&str>) -> Result<()> {
    if let Some(path) = metrics_out {
        write_metrics(path)?;
    }
    if let Some(path) = trace_out {
        write_trace(path)?;
    }
    Ok(())
}

/// Write the global registry snapshot as pretty JSON to `path`.
pub fn write_metrics(path: &str) -> Result<()> {
    let doc = metrics::global().snapshot().to_json();
    std::fs::write(path, doc.to_pretty() + "\n")?;
    Ok(())
}

/// Drain all trace rings and write the Chrome trace document to `path`.
pub fn write_trace(path: &str) -> Result<()> {
    let doc = trace::export_chrome();
    std::fs::write(path, doc.to_string() + "\n")?;
    Ok(())
}

/// Upper bound of the bucket holding the `q`-quantile observation —
/// a conservative estimate (the true value is at most this), which is
/// what a log-bucket histogram can honestly report.
pub fn approx_percentile(h: &HistogramSnapshot, q: f64) -> f64 {
    if h.count == 0 {
        return 0.0;
    }
    let rank = (q.clamp(0.0, 1.0) * h.count as f64).ceil().max(1.0) as u64;
    let mut cum = 0u64;
    for (i, b) in h.buckets.iter().enumerate() {
        cum += b;
        if cum >= rank {
            return metrics::bucket_upper(i);
        }
    }
    f64::INFINITY
}

fn fmt_seconds(v: f64) -> String {
    if v.is_nan() {
        // A statistic over zero observations has no value; never print
        // a literal NaN.
        "n/a".to_string()
    } else if v == f64::INFINITY {
        "inf".to_string()
    } else if v >= 1.0 {
        format!("{v:.3}s")
    } else if v >= 1e-3 {
        format!("{:.3}ms", v * 1e3)
    } else {
        format!("{:.3}us", v * 1e6)
    }
}

/// Mean cell for a histogram line — `n/a` when there is nothing to
/// average (a zero-count window still renders when NaNs were
/// rejected, and `0.000us` would misread as "fast").
fn fmt_mean(h: &HistogramSnapshot) -> String {
    if h.count == 0 {
        "n/a".to_string()
    } else {
        fmt_seconds(h.mean_seconds())
    }
}

/// Percentile cell — `n/a` for an empty histogram window.
fn fmt_pct(h: &HistogramSnapshot, q: f64) -> String {
    if h.count == 0 {
        "n/a".to_string()
    } else {
        fmt_seconds(approx_percentile(h, q))
    }
}

/// Render a snapshot as grouped human-readable text (the body of
/// `tfgnn stats`). Zero-valued counters are elided; histograms show
/// count, mean and conservative p50/p95/p99/p99.9 bucket bounds.
pub fn render_stats(snap: &MetricsSnapshot) -> String {
    // Group by stage prefix (the part before the first '_').
    let mut groups: BTreeMap<&str, Vec<String>> = BTreeMap::new();
    let stage_of = |name: &str| {
        metrics::lookup(name).map(|d| d.stage).unwrap_or("other")
    };
    for (name, v) in &snap.counters {
        if *v != 0 {
            groups.entry(stage_of(name)).or_default().push(format!("  {name:<34} {v}"));
        }
    }
    for (name, v) in &snap.gauges {
        groups.entry(stage_of(name)).or_default().push(format!("  {name:<34} {v}"));
    }
    for (name, h) in &snap.histograms {
        if h.count == 0 {
            continue;
        }
        let mut line = format!(
            "  {name:<34} count={} mean={} p50<={} p95<={} p99<={} p99.9<={}",
            h.count,
            fmt_mean(h),
            fmt_pct(h, 0.50),
            fmt_pct(h, 0.95),
            fmt_pct(h, 0.99),
            fmt_pct(h, 0.999),
        );
        if h.nan_rejected > 0 {
            line.push_str(&format!(" nan_rejected={}", h.nan_rejected));
        }
        groups.entry(stage_of(name)).or_default().push(line);
    }
    let mut out = String::new();
    for (stage, lines) in &groups {
        out.push_str(&format!("{stage}:\n"));
        for line in lines {
            out.push_str(line);
            out.push('\n');
        }
    }
    if out.is_empty() {
        out.push_str("(no nonzero metrics)\n");
    }
    out
}

/// Render the change between two exported snapshots (the body of
/// `tfgnn stats --diff OLD.json NEW.json`). Counters and histograms
/// show the `new - old` movement (unchanged entries elided); gauges
/// show `old -> new` where the value changed. Metrics present only in
/// the old export are skipped — a run-over-run diff cares about what
/// the new run did.
pub fn render_diff(old: &MetricsSnapshot, new: &MetricsSnapshot) -> String {
    let delta = new.delta_since(old);
    let mut out = String::new();

    let mut counter_lines = Vec::new();
    for (name, d) in &delta.counters {
        if *d == 0 {
            continue;
        }
        let prev = old.counters.get(name).copied().unwrap_or(0);
        counter_lines.push(format!("  {name:<34} {prev} -> {} (+{d})", prev + d));
    }
    if !counter_lines.is_empty() {
        out.push_str("counters:\n");
        for line in counter_lines {
            out.push_str(&line);
            out.push('\n');
        }
    }

    let mut gauge_lines = Vec::new();
    for (name, v) in &new.gauges {
        let prev = old.gauges.get(name).copied();
        if prev != Some(*v) {
            let shown = prev.map(|p| p.to_string()).unwrap_or_else(|| "-".to_string());
            gauge_lines.push(format!("  {name:<34} {shown} -> {v}"));
        }
    }
    if !gauge_lines.is_empty() {
        out.push_str("gauges:\n");
        for line in gauge_lines {
            out.push_str(&line);
            out.push('\n');
        }
    }

    let mut hist_lines = Vec::new();
    for (name, h) in &delta.histograms {
        if h.count == 0 && h.nan_rejected == 0 {
            continue;
        }
        let mut line = format!(
            "  {name:<34} count=+{} mean={} p50<={} p95<={} p99<={}",
            h.count,
            fmt_mean(h),
            fmt_pct(h, 0.50),
            fmt_pct(h, 0.95),
            fmt_pct(h, 0.99),
        );
        if h.nan_rejected > 0 {
            line.push_str(&format!(" nan_rejected=+{}", h.nan_rejected));
        }
        hist_lines.push(line);
    }
    if !hist_lines.is_empty() {
        out.push_str("histograms (delta window):\n");
        for line in hist_lines {
            out.push_str(&line);
            out.push('\n');
        }
    }

    if out.is_empty() {
        out.push_str("(no differences)\n");
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_of_empty_is_zero() {
        let h = HistogramSnapshot::default();
        assert_eq!(approx_percentile(&h, 0.99), 0.0);
    }

    #[test]
    fn percentile_walks_buckets() {
        let h = metrics::Histogram::detached();
        for _ in 0..99 {
            h.record(1e-3);
        }
        h.record(1.0);
        let s = h.snapshot();
        let p50 = approx_percentile(&s, 0.50);
        let p999 = approx_percentile(&s, 0.999);
        assert!(p50 >= 1e-3 && p50 < 0.5, "p50 bound {p50}");
        assert!(p999 >= 1.0, "p99.9 bound {p999} must cover the slow outlier");
        assert!(p999 < f64::INFINITY);
    }

    #[test]
    fn render_groups_by_stage() {
        let mut snap = MetricsSnapshot::default();
        snap.counters.insert(metrics::names::SERVE_REQUESTS.to_string(), 5);
        snap.counters.insert(metrics::names::TRAINER_STEPS.to_string(), 2);
        snap.counters.insert("zero_total".to_string(), 0);
        let text = render_stats(&snap);
        assert!(text.contains("serve:\n"));
        assert!(text.contains("trainer:\n"));
        assert!(text.contains("serve_requests_total"));
        assert!(!text.contains("zero_total"), "zero counters are elided");
    }

    #[test]
    fn diff_shows_only_movement() {
        let mut old = MetricsSnapshot::default();
        old.counters.insert("serve_requests_total".to_string(), 10);
        old.counters.insert("serve_rejected_total".to_string(), 4);
        old.gauges.insert("serve_queue_depth".to_string(), 2);
        let mut new = old.clone();
        new.counters.insert("serve_requests_total".to_string(), 25);
        new.gauges.insert("serve_queue_depth".to_string(), 0);
        let h = metrics::Histogram::detached();
        h.record(1e-3);
        new.histograms.insert("serve_wave_seconds".to_string(), h.snapshot());
        let text = render_diff(&old, &new);
        assert!(text.contains("serve_requests_total"), "{text}");
        assert!(text.contains("10 -> 25 (+15)"), "{text}");
        assert!(!text.contains("serve_rejected_total"), "unchanged counters elided: {text}");
        assert!(text.contains("2 -> 0"), "{text}");
        assert!(text.contains("serve_wave_seconds"), "{text}");
        assert!(text.contains("count=+1"), "{text}");
        // Identical snapshots diff to nothing.
        assert_eq!(render_diff(&new, &new), "(no differences)\n");
    }

    /// Regression: a histogram window with zero observations (e.g. a
    /// delta window where only NaNs were rejected) must render `n/a`
    /// statistics, never `NaN` or a misleading `0.000us`.
    #[test]
    fn zero_count_histogram_renders_na() {
        let old = MetricsSnapshot::default();
        let mut new = MetricsSnapshot::default();
        let h = metrics::Histogram::detached();
        h.record(f64::NAN);
        new.histograms.insert("serve_wave_seconds".to_string(), h.snapshot());
        let text = render_diff(&old, &new);
        assert!(text.contains("serve_wave_seconds"), "{text}");
        assert!(text.contains("nan_rejected=+1"), "{text}");
        assert!(text.contains("mean=n/a"), "{text}");
        assert!(text.contains("p50<=n/a"), "{text}");
        assert!(!text.contains("NaN"), "{text}");
        assert!(!text.contains("0.000us"), "{text}");
    }

    #[test]
    fn write_and_reread_metrics_file() {
        metrics::global().counter("report_unit_total").inc();
        let path = std::env::temp_dir().join("tfgnn_report_unit_metrics.json");
        let path = path.to_string_lossy().to_string();
        write_metrics(&path).expect("write");
        let text = std::fs::read_to_string(&path).expect("read back");
        let doc = crate::util::json::Json::parse(&text).expect("valid json");
        let snap = MetricsSnapshot::from_json(&doc).expect("schema");
        assert!(snap.counters.get("report_unit_total").copied().unwrap_or(0) >= 1);
        let _ = std::fs::remove_file(&path);
    }
}
