//! Scoped tracing spans recorded into per-thread ring buffers.
//!
//! A span is opened with the [`crate::span!`] macro (or [`span`] /
//! [`span_arg`]) and records itself when the guard drops. Recording is
//! gated on a single relaxed load of the global enable flag: with
//! tracing off a span is a `None` — no clock read, no allocation, no
//! shared-state traffic — which is what keeps instrumented hot paths
//! bit-identical to the uninstrumented oracles.
//!
//! Each thread writes into its own fixed-capacity ring (oldest events
//! overwritten past [`RING_CAP`]; the drop tally is reported in the
//! export), registered globally on first use so [`drain`] can collect
//! everything. The export format is the Chrome `trace_event` JSON
//! ([`to_chrome_json`]): complete events (`"ph":"X"`) with
//! microsecond `ts`/`dur` relative to a process-wide epoch, loadable
//! in `about:tracing` / Perfetto / `chrome://tracing`.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock, PoisonError};
use std::time::Instant;

use crate::util::json::Json;

/// Per-thread ring capacity. At ~64 bytes an event this bounds a
/// thread's trace memory to ~4 MiB.
pub const RING_CAP: usize = 65536;

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Enable or disable span recording. Enabling also pins the process
/// epoch that all `ts` values are relative to.
pub fn set_enabled(on: bool) {
    if on {
        epoch();
    }
    ENABLED.store(on, Ordering::Relaxed);
}

/// True when spans are being recorded (one relaxed load).
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

static EPOCH: OnceLock<Instant> = OnceLock::new();

fn epoch() -> Instant {
    *EPOCH.get_or_init(Instant::now)
}

/// One completed span, ready for export.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Event {
    pub name: &'static str,
    /// Small per-thread integer (assigned on first span), not an OS id.
    pub tid: u64,
    /// Start offset from the process epoch, microseconds.
    pub ts_micros: u64,
    /// Duration, microseconds.
    pub dur_micros: u64,
    /// Optional single integer argument (`span!(name, key = v)`).
    pub arg: Option<(&'static str, i64)>,
}

struct Ring {
    slots: Vec<Event>,
    /// Next write position (wraps at RING_CAP).
    head: usize,
    /// Total events ever pushed; `total - slots.len()` were dropped.
    total: u64,
}

impl Ring {
    fn new() -> Self {
        Ring { slots: Vec::new(), head: 0, total: 0 }
    }

    fn push(&mut self, ev: Event) {
        self.total += 1;
        if self.slots.len() < RING_CAP {
            self.slots.push(ev);
            self.head = self.slots.len() % RING_CAP;
        } else if let Some(slot) = self.slots.get_mut(self.head) {
            // Overwriting the oldest event: surface the loss in the
            // registry so `tfgnn stats` can warn that the Chrome
            // export is incomplete.
            crate::obs_counter!(super::metrics::names::OBS_TRACE_DROPPED).inc();
            *slot = ev;
            self.head = (self.head + 1) % RING_CAP;
        }
    }

    fn drain(&mut self) -> (Vec<Event>, u64) {
        let dropped = self.total.saturating_sub(self.slots.len() as u64);
        self.head = 0;
        self.total = 0;
        (std::mem::take(&mut self.slots), dropped)
    }
}

/// All live thread rings, so `drain` can reach every thread's events
/// (including threads that have since exited — the Arc keeps them).
static RINGS: OnceLock<Mutex<Vec<Arc<Mutex<Ring>>>>> = OnceLock::new();

static NEXT_TID: AtomicU64 = AtomicU64::new(1);

std::thread_local! {
    static LOCAL: std::cell::OnceCell<(u64, Arc<Mutex<Ring>>)> =
        const { std::cell::OnceCell::new() };
}

fn with_local_ring(f: impl FnOnce(u64, &Mutex<Ring>)) {
    LOCAL.with(|cell| {
        let (tid, ring) = cell.get_or_init(|| {
            let tid = NEXT_TID.fetch_add(1, Ordering::Relaxed);
            let ring = Arc::new(Mutex::new(Ring::new()));
            let rings = RINGS.get_or_init(|| Mutex::new(Vec::new()));
            rings.lock().unwrap_or_else(PoisonError::into_inner).push(ring.clone());
            (tid, ring)
        });
        f(*tid, ring);
    });
}

/// RAII span guard; records on drop if tracing was enabled when it
/// was opened. Inert (`None` inside) otherwise.
pub struct Span {
    live: Option<SpanStart>,
}

struct SpanStart {
    name: &'static str,
    arg: Option<(&'static str, i64)>,
    t0: Instant,
}

/// Open a span; prefer the [`crate::span!`] macro.
#[inline]
pub fn span(name: &'static str) -> Span {
    if !enabled() {
        return Span { live: None };
    }
    Span { live: Some(SpanStart { name, arg: None, t0: Instant::now() }) }
}

/// Open a span carrying one integer argument.
#[inline]
pub fn span_arg(name: &'static str, key: &'static str, val: i64) -> Span {
    if !enabled() {
        return Span { live: None };
    }
    Span { live: Some(SpanStart { name, arg: Some((key, val)), t0: Instant::now() }) }
}

impl Drop for Span {
    fn drop(&mut self) {
        let Some(start) = self.live.take() else {
            return;
        };
        let dur = start.t0.elapsed();
        let ts = start.t0.saturating_duration_since(epoch());
        with_local_ring(move |tid, ring| {
            let mut g = ring.lock().unwrap_or_else(PoisonError::into_inner);
            g.push(Event {
                name: start.name,
                tid,
                ts_micros: ts.as_micros().min(u64::MAX as u128) as u64,
                dur_micros: dur.as_micros().min(u64::MAX as u128) as u64,
                arg: start.arg,
            });
        });
    }
}

/// Collect (and clear) every thread's events plus the total dropped
/// count. Events are sorted by `(ts, tid)` for a stable export.
pub fn drain() -> (Vec<Event>, u64) {
    let mut events = Vec::new();
    let mut dropped = 0u64;
    if let Some(rings) = RINGS.get() {
        let g = rings.lock().unwrap_or_else(PoisonError::into_inner);
        for ring in g.iter() {
            let (mut evs, d) = ring.lock().unwrap_or_else(PoisonError::into_inner).drain();
            events.append(&mut evs);
            dropped += d;
        }
    }
    events.sort_by_key(|e| (e.ts_micros, e.tid));
    (events, dropped)
}

/// Non-destructively copy every thread's buffered events, sorted by
/// `(ts, tid)`, keeping only the `limit` most recent. Unlike
/// [`drain`] the rings keep their contents, so a live scraper (the
/// admin `/tracez` endpoint, the incident flight recorder) never
/// steals events from a later `--trace-out` export. The second value
/// is the cumulative overwrite tally across rings.
pub fn snapshot(limit: usize) -> (Vec<Event>, u64) {
    let mut events = Vec::new();
    let mut dropped = 0u64;
    if let Some(rings) = RINGS.get() {
        let g = rings.lock().unwrap_or_else(PoisonError::into_inner);
        for ring in g.iter() {
            let r = ring.lock().unwrap_or_else(PoisonError::into_inner);
            dropped += r.total.saturating_sub(r.slots.len() as u64);
            events.extend(r.slots.iter().cloned());
        }
    }
    events.sort_by_key(|e| (e.ts_micros, e.tid));
    if events.len() > limit {
        events.drain(..events.len() - limit);
    }
    (events, dropped)
}

/// Render events as a Chrome `trace_event` JSON object document.
pub fn to_chrome_json(events: &[Event], dropped: u64) -> Json {
    let trace_events: Vec<Json> = events
        .iter()
        .map(|e| {
            let mut m = BTreeMap::new();
            m.insert("name".to_string(), Json::Str(e.name.to_string()));
            m.insert("cat".to_string(), Json::Str("tfgnn".to_string()));
            m.insert("ph".to_string(), Json::Str("X".to_string()));
            m.insert("ts".to_string(), Json::Int(i64::try_from(e.ts_micros).unwrap_or(i64::MAX)));
            m.insert("dur".to_string(), Json::Int(i64::try_from(e.dur_micros).unwrap_or(i64::MAX)));
            m.insert("pid".to_string(), Json::Int(1));
            m.insert("tid".to_string(), Json::Int(i64::try_from(e.tid).unwrap_or(i64::MAX)));
            let args = match e.arg {
                Some((k, v)) => {
                    let mut a = BTreeMap::new();
                    a.insert(k.to_string(), Json::Int(v));
                    Json::Obj(a)
                }
                None => Json::Obj(BTreeMap::new()),
            };
            m.insert("args".to_string(), args);
            Json::Obj(m)
        })
        .collect();
    let mut other = BTreeMap::new();
    other.insert("dropped_events".to_string(), Json::Int(i64::try_from(dropped).unwrap_or(i64::MAX)));
    let mut top = BTreeMap::new();
    top.insert("traceEvents".to_string(), Json::Arr(trace_events));
    top.insert("displayTimeUnit".to_string(), Json::Str("ms".to_string()));
    top.insert("otherData".to_string(), Json::Obj(other));
    Json::Obj(top)
}

/// Drain all rings and render the Chrome trace document in one step.
pub fn export_chrome() -> Json {
    let (events, dropped) = drain();
    to_chrome_json(&events, dropped)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_spans_record_nothing() {
        set_enabled(false);
        {
            let _s = span("trace_unit/disabled");
        }
        let (events, _) = drain();
        assert!(
            !events.iter().any(|e| e.name == "trace_unit/disabled"),
            "disabled span must not record"
        );
    }

    #[test]
    fn enabled_spans_are_drained_with_args() {
        set_enabled(true);
        {
            let _s = span_arg("trace_unit/enabled", "shard", 3);
        }
        set_enabled(false);
        let (events, _) = drain();
        let ev = events
            .iter()
            .find(|e| e.name == "trace_unit/enabled")
            .expect("span recorded while enabled");
        assert_eq!(ev.arg, Some(("shard", 3)));
        assert!(ev.tid >= 1);
        // Drain clears: a second drain must not see it again.
        let (events, _) = drain();
        assert!(!events.iter().any(|e| e.name == "trace_unit/enabled"));
    }

    #[test]
    fn ring_wraps_and_counts_drops() {
        let mut ring = Ring::new();
        for i in 0..(RING_CAP as u64 + 10) {
            ring.push(Event {
                name: "x",
                tid: 1,
                ts_micros: i,
                dur_micros: 0,
                arg: None,
            });
        }
        let (events, dropped) = ring.drain();
        assert_eq!(events.len(), RING_CAP);
        assert_eq!(dropped, 10);
        // The oldest 10 were overwritten.
        assert!(!events.iter().any(|e| e.ts_micros < 10));
    }

    #[test]
    fn snapshot_is_non_destructive_and_bounded() {
        set_enabled(true);
        for _ in 0..3 {
            let _s = span("trace_unit/snapshot");
        }
        set_enabled(false);
        let (snap, _) = snapshot(usize::MAX);
        let seen = snap.iter().filter(|e| e.name == "trace_unit/snapshot").count();
        assert!(seen >= 3, "snapshot sees buffered events (saw {seen})");
        // Bounded snapshots keep the most recent events.
        let (bounded, _) = snapshot(1);
        assert!(bounded.len() <= 1);
        // The rings still hold everything for a later drain.
        let (drained, _) = drain();
        let still = drained.iter().filter(|e| e.name == "trace_unit/snapshot").count();
        assert!(still >= 3, "snapshot must not consume ring contents (saw {still})");
    }

    #[test]
    fn chrome_json_schema() {
        let events = vec![Event {
            name: "sampler/expand",
            tid: 2,
            ts_micros: 10,
            dur_micros: 5,
            arg: Some(("shard", 1)),
        }];
        let doc = to_chrome_json(&events, 7);
        let evs = doc.get("traceEvents").expect("traceEvents").as_arr().expect("array");
        assert_eq!(evs.len(), 1);
        let e = &evs[0];
        assert_eq!(e.get("ph").expect("ph").as_str().expect("str"), "X");
        assert_eq!(e.get("name").expect("name").as_str().expect("str"), "sampler/expand");
        assert_eq!(e.get("ts").expect("ts").as_i64().expect("int"), 10);
        assert_eq!(e.get("dur").expect("dur").as_i64().expect("int"), 5);
        assert_eq!(e.get("pid").expect("pid").as_i64().expect("int"), 1);
        assert_eq!(e.get("tid").expect("tid").as_i64().expect("int"), 2);
        assert_eq!(
            e.get("args").expect("args").get("shard").expect("shard").as_i64().expect("int"),
            1
        );
        assert_eq!(
            doc.get("otherData")
                .expect("otherData")
                .get("dropped_events")
                .expect("dropped")
                .as_i64()
                .expect("int"),
            7
        );
        // Round-trips through the serializer.
        let text = doc.to_string();
        assert_eq!(Json::parse(&text).expect("parse"), doc);
    }
}
