//! Opt-in TCP admin endpoint: live `/metrics`, `/healthz`, `/tracez`,
//! `/statusz` over hand-rolled HTTP/1.0.
//!
//! The image is offline, so there is no HTTP crate to lean on — and
//! none is needed: the endpoint answers `GET` requests one connection
//! at a time with `Connection: close`, which every scraper
//! (Prometheus, curl, a browser) speaks. Off by default; a server
//! starts one only when `ServeConfig::admin_addr` (the `--admin-addr`
//! flag) is set. Binding `127.0.0.1:0` picks an ephemeral port —
//! [`AdminServer::local_addr`] reports the real one, which is how the
//! tests avoid port collisions.
//!
//! | Path | Content | Source |
//! |---|---|---|
//! | `/metrics` | Prometheus text | registry snapshot |
//! | `/metrics.json` | `tfgnn_metrics_v1` JSON | registry snapshot |
//! | `/healthz` | `200 ok` / `503` + report | [`super::health::Watchdog`] |
//! | `/tracez` | Chrome trace JSON | [`super::trace::snapshot`] |
//! | `/statusz` | uptime/config/generation/occupancy JSON | server closure |
//!
//! Every handler only *reads* snapshots — `/tracez` uses the
//! non-destructive [`super::trace::snapshot`], never [`super::trace::drain`]
//! — so a concurrent scraper cannot change served bits or steal
//! events from a later `--trace-out` export (the inertness contract;
//! pinned by `tests/admin_live.rs`).

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, PoisonError};
use std::time::Duration;

use super::health::HealthReport;
use super::metrics::names;
use crate::util::json::Json;
use crate::{Error, Result};

/// Most recent events returned by `/tracez` (keeps responses bounded;
/// the rings hold [`super::trace::RING_CAP`] per thread).
pub const TRACEZ_EVENT_CAP: usize = 4096;

/// Cap on request bytes read before responding (headers only; GET has
/// no body we care about).
const MAX_REQUEST_BYTES: usize = 8192;

/// The closures an admin server consults per request; they keep `obs`
/// decoupled from `serve` (the server wires them up at startup).
pub struct AdminState {
    /// Fresh health verdict for `/healthz`.
    pub healthz: Arc<dyn Fn() -> HealthReport + Send + Sync>,
    /// Fresh status document for `/statusz`.
    pub statusz: Arc<dyn Fn() -> Json + Send + Sync>,
}

/// A running admin endpoint; `stop` (or drop) shuts it down.
pub struct AdminServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    thread: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl AdminServer {
    /// Bind `addr` (e.g. `127.0.0.1:9100`, or port 0 for ephemeral)
    /// and start the accept loop on its own thread.
    pub fn start(addr: &str, state: AdminState) -> Result<AdminServer> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| Error::Runtime(format!("admin: cannot bind {addr}: {e}")))?;
        let local = listener
            .local_addr()
            .map_err(|e| Error::Runtime(format!("admin: no local addr: {e}")))?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let thread = std::thread::Builder::new()
            .name("tfgnn-admin".to_string())
            .spawn(move || accept_loop(&listener, &state, &stop2))
            .map_err(|e| Error::Runtime(format!("admin: cannot spawn thread: {e}")))?;
        Ok(AdminServer { addr: local, stop, thread: Mutex::new(Some(thread)) })
    }

    /// The actually-bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting and join the thread. Idempotent.
    pub fn stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
        // The accept loop blocks in `accept`; poke it awake with a
        // throwaway connection so it sees the flag.
        let _ = TcpStream::connect(self.addr);
        let mut g = self.thread.lock().unwrap_or_else(PoisonError::into_inner);
        if let Some(h) = g.take() {
            let _ = h.join();
        }
    }
}

impl Drop for AdminServer {
    fn drop(&mut self) {
        self.stop();
    }
}

fn accept_loop(listener: &TcpListener, state: &AdminState, stop: &AtomicBool) {
    for conn in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            return;
        }
        let Ok(mut stream) = conn else { continue };
        // A stuck client must not wedge the admin thread.
        let _ = stream.set_read_timeout(Some(Duration::from_millis(500)));
        let _ = stream.set_write_timeout(Some(Duration::from_millis(2000)));
        let _ = handle_connection(&mut stream, state);
    }
}

/// Read the request head, route it, write an HTTP/1.0 response. The
/// full header block is consumed before replying so closing the
/// socket cannot RST an in-flight response off the wire.
fn handle_connection(stream: &mut TcpStream, state: &AdminState) -> std::io::Result<()> {
    let mut buf = vec![0u8; MAX_REQUEST_BYTES];
    let mut filled = 0usize;
    loop {
        if filled == buf.len() {
            break;
        }
        let n = stream.read(&mut buf[filled..])?;
        if n == 0 {
            break;
        }
        filled += n;
        let head = &buf[..filled];
        if head.windows(4).any(|w| w == b"\r\n\r\n") || head.windows(2).any(|w| w == b"\n\n") {
            break;
        }
    }
    let text = String::from_utf8_lossy(&buf[..filled]);
    let line = text.lines().next().unwrap_or("");
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("");
    let path = parts.next().unwrap_or("/");
    crate::obs_counter!(names::ADMIN_REQUESTS).inc();
    let (status, content_type, body) = route(method, path, state);
    let reason = match status {
        200 => "OK",
        404 => "Not Found",
        405 => "Method Not Allowed",
        503 => "Service Unavailable",
        _ => "Error",
    };
    let header = format!(
        "HTTP/1.0 {status} {reason}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(header.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

const INDEX: &str = "tfgnn admin endpoint\n\
    /metrics       Prometheus text\n\
    /metrics.json  tfgnn_metrics_v1 JSON\n\
    /healthz       200 ok / 503 + watchdog report\n\
    /tracez        Chrome trace JSON (recent spans)\n\
    /statusz       uptime, config, generation, occupancy\n";

fn route(method: &str, path: &str, state: &AdminState) -> (u16, &'static str, String) {
    if method != "GET" {
        return (405, "text/plain", "only GET is supported\n".to_string());
    }
    let path = path.split('?').next().unwrap_or(path);
    match path {
        "/" => (200, "text/plain", INDEX.to_string()),
        "/metrics" => {
            (200, "text/plain; version=0.0.4", super::metrics::global().snapshot().to_prometheus())
        }
        "/metrics.json" => {
            let mut body = super::metrics::global().snapshot().to_json().to_pretty();
            body.push('\n');
            (200, "application/json", body)
        }
        "/healthz" => {
            let report = (state.healthz)();
            if report.healthy {
                (200, "text/plain", report.to_text())
            } else {
                (503, "text/plain", report.to_text())
            }
        }
        "/tracez" => {
            let (events, dropped) = super::trace::snapshot(TRACEZ_EVENT_CAP);
            let mut body = super::trace::to_chrome_json(&events, dropped).to_string();
            body.push('\n');
            (200, "application/json", body)
        }
        "/statusz" => {
            let mut body = (state.statusz)().to_pretty();
            body.push('\n');
            (200, "application/json", body)
        }
        _ => (
            404,
            "text/plain",
            "not found; try / /metrics /metrics.json /healthz /tracez /statusz\n".to_string(),
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::obj;

    fn healthy_state() -> AdminState {
        AdminState {
            healthz: Arc::new(|| HealthReport {
                healthy: true,
                reasons: Vec::new(),
                lanes: Vec::new(),
                backlog: 0,
                deadline_misses: 0,
                trips: 0,
            }),
            statusz: Arc::new(|| obj(vec![("schema", Json::Str("tfgnn_statusz_v1".into()))])),
        }
    }

    fn get(addr: SocketAddr, path: &str) -> (u16, String) {
        let mut s = TcpStream::connect(addr).unwrap();
        write!(s, "GET {path} HTTP/1.0\r\nHost: admin\r\n\r\n").unwrap();
        let mut text = String::new();
        s.read_to_string(&mut text).unwrap();
        let status = text.split_whitespace().nth(1).unwrap_or("0").parse().unwrap_or(0);
        let body = text.split_once("\r\n\r\n").map(|(_, b)| b.to_string()).unwrap_or_default();
        (status, body)
    }

    #[test]
    fn serves_metrics_and_statusz() {
        let server = AdminServer::start("127.0.0.1:0", healthy_state()).unwrap();
        let addr = server.local_addr();
        let (status, body) = get(addr, "/metrics");
        assert_eq!(status, 200);
        assert!(body.contains("serve_requests_total"), "prometheus body: {body}");
        let (status, body) = get(addr, "/metrics.json");
        assert_eq!(status, 200);
        let doc = Json::parse(&body).unwrap();
        assert_eq!(doc.get("schema").unwrap().as_str().unwrap(), "tfgnn_metrics_v1");
        let (status, body) = get(addr, "/statusz");
        assert_eq!(status, 200);
        let doc = Json::parse(&body).unwrap();
        assert_eq!(doc.get("schema").unwrap().as_str().unwrap(), "tfgnn_statusz_v1");
        let (status, body) = get(addr, "/tracez");
        assert_eq!(status, 200);
        assert!(Json::parse(&body).unwrap().get("traceEvents").is_ok());
        server.stop();
    }

    #[test]
    fn healthz_follows_the_closure() {
        let server = AdminServer::start("127.0.0.1:0", healthy_state()).unwrap();
        let (status, body) = get(server.local_addr(), "/healthz");
        assert_eq!(status, 200);
        assert!(body.starts_with("ok"));
        server.stop();

        let sick = AdminState {
            healthz: Arc::new(|| HealthReport {
                healthy: false,
                reasons: vec!["lane 0 wedged mid-wave for 999ms".to_string()],
                lanes: Vec::new(),
                backlog: 3,
                deadline_misses: 1,
                trips: 1,
            }),
            statusz: Arc::new(|| Json::Null),
        };
        let server = AdminServer::start("127.0.0.1:0", sick).unwrap();
        let (status, body) = get(server.local_addr(), "/healthz");
        assert_eq!(status, 503);
        assert!(body.contains("wedged"), "{body}");
        server.stop();
    }

    #[test]
    fn unknown_path_and_method_are_structured_errors() {
        let server = AdminServer::start("127.0.0.1:0", healthy_state()).unwrap();
        let addr = server.local_addr();
        let (status, _) = get(addr, "/nope");
        assert_eq!(status, 404);
        let mut s = TcpStream::connect(addr).unwrap();
        write!(s, "POST /metrics HTTP/1.0\r\n\r\n").unwrap();
        let mut text = String::new();
        s.read_to_string(&mut text).unwrap();
        assert!(text.starts_with("HTTP/1.0 405"), "{text}");
        // Stop is idempotent (drop will call it again).
        server.stop();
        server.stop();
    }
}
