//! Unified observability: metrics registry, tracing spans, profiling.
//!
//! The paper's production story (§6–7) assumes operators can see what
//! the sampler, trainer and server are doing — queue depths, wave
//! latencies, cache behavior, per-stage time. This module is that
//! layer, in three pillars:
//!
//! * **[`metrics`]** — a process-global [`metrics::MetricsRegistry`] of
//!   named counters, gauges and fixed-log-bucket histograms. Counters
//!   are sharded over cache-padded atomics so a hot-path increment is a
//!   single relaxed atomic op; [`metrics::MetricsRegistry::snapshot`]
//!   reads every metric once for export to a stable JSON document or
//!   Prometheus-style text. Every metric name is declared in
//!   [`metrics::METRICS`]; `docs/metrics.md` is generated from that
//!   table and byte-pinned by `tests/obs.rs`.
//! * **[`trace`]** — lightweight scoped spans
//!   (`span!("sampler/expand", shard = 3)`) recorded into per-thread
//!   ring buffers and exported as Chrome `trace_event` JSON, so a whole
//!   `tfgnn loadgen` or training run opens in `about:tracing`/Perfetto.
//! * **Wiring** — the sampler (per-shard fanout latency, retry
//!   counters), [`crate::util::ThreadPool`] (queue wait vs. execute
//!   time), the native trainer (forward/backward/all-reduce/optimizer
//!   breakdown) and the serve path (registry-backed
//!   [`crate::serve::ServeStats`], queue-depth gauge, wave-size and
//!   wave-latency histograms, swap counters), surfaced via
//!   `tfgnn train/serve-bench/loadgen --metrics-out/--trace-out` and
//!   the `tfgnn stats` renderer ([`report`]).
//!
//! PR 9 adds the *live* half — introspection of a running server
//! rather than end-of-run file dumps:
//!
//! * **[`admin`]** — an opt-in, std-only TCP admin endpoint
//!   (`--admin-addr`) serving `/metrics`, `/metrics.json`, `/healthz`,
//!   `/tracez` and `/statusz` over hand-rolled HTTP/1.0.
//! * **[`health`]** — watchdog with per-lane heartbeats, wedged-lane
//!   and queue-stall detection, and deadline-miss tracking; it is what
//!   flips `/healthz` to 503.
//! * **[`flight`]** — an incident flight recorder that dumps a
//!   rate-limited metrics + trace snapshot to `--incident-dir` on
//!   watchdog trips, overload bursts and failed batches.
//!
//! PR 10 adds the *training* half — run telemetry rather than serving
//! introspection:
//!
//! * **[`events`]** — an append-only per-step event journal
//!   (`tfgnn_events_v1` JSONL, `--events-out`), gradient-health probe
//!   types ([`events::GradStats`], [`events::Telemetry`]) and the
//!   `tfgnn runs list|show|diff` summaries built over journals.
//!
//! ## Inertness contract
//!
//! Observability must never perturb the oracles the rest of the crate
//! is tested against:
//!
//! * **Plain counters and gauges are always on.** They are relaxed
//!   atomic arithmetic — no allocation, no syscall, no branch on shared
//!   state beyond the add itself.
//! * **Timers and spans are gated.** [`timed`] observes wall time only
//!   when [`recording`] is enabled, and [`trace::span`] records only
//!   when [`trace::enabled`] — both gates are a single relaxed load.
//!   With recording disabled there are **zero allocations and zero
//!   clock reads** on any hot path.
//! * **Enabling changes nothing observable.** Timing never feeds back
//!   into computation: with recording and tracing on, every float
//!   sequence, sampled subgraph and served output is bit-identical to
//!   the uninstrumented run (pinned at 1/2/8 threads by
//!   `tests/obs.rs`).
//!
//! All of this is std-only and panic-free (the clippy no-panic gate
//! covers it): poisoned locks are taken via `PoisonError::into_inner`,
//! and no lookup ever unwraps.

pub mod admin;
pub mod events;
pub mod flight;
pub mod health;
pub mod metrics;
pub mod report;
pub mod trace;

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

static RECORDING: AtomicBool = AtomicBool::new(false);

/// Enable or disable timed instrumentation (histogram timers). Plain
/// counters and gauges are always on; see the module docs for the
/// gating tiers. [`trace::set_enabled`] gates spans separately.
pub fn set_recording(on: bool) {
    RECORDING.store(on, Ordering::Relaxed);
}

/// True when timed instrumentation is recording (one relaxed load).
#[inline]
pub fn recording() -> bool {
    RECORDING.load(Ordering::Relaxed)
}

/// The process-global metrics registry.
pub fn metrics() -> &'static metrics::MetricsRegistry {
    metrics::global()
}

/// Scope guard that records its lifetime into a histogram on drop —
/// but only when [`recording`] was enabled at construction; otherwise
/// it never reads the clock at all.
pub struct Timer<'a> {
    hist: &'a metrics::Histogram,
    start: Option<Instant>,
}

/// Start timing a stage into `hist` (seconds). Inert unless
/// [`recording`] is on.
#[inline]
pub fn timed(hist: &metrics::Histogram) -> Timer<'_> {
    Timer { hist, start: recording().then(Instant::now) }
}

impl Drop for Timer<'_> {
    fn drop(&mut self) {
        if let Some(t0) = self.start {
            self.hist.record(t0.elapsed().as_secs_f64());
        }
    }
}

/// A `&'static` [`metrics::Counter`] handle for a well-known name,
/// registered once per use site (the `static OnceLock` lives at the
/// macro expansion). Hot-path cost after the first call: one atomic
/// load for the `OnceLock`, then the counter's relaxed add.
#[macro_export]
macro_rules! obs_counter {
    ($name:expr) => {{
        static HANDLE: std::sync::OnceLock<$crate::obs::metrics::Counter> =
            std::sync::OnceLock::new();
        HANDLE.get_or_init(|| $crate::obs::metrics::global().counter($name))
    }};
}

/// A `&'static` [`metrics::Gauge`] handle; see [`obs_counter!`].
#[macro_export]
macro_rules! obs_gauge {
    ($name:expr) => {{
        static HANDLE: std::sync::OnceLock<$crate::obs::metrics::Gauge> =
            std::sync::OnceLock::new();
        HANDLE.get_or_init(|| $crate::obs::metrics::global().gauge($name))
    }};
}

/// A `&'static` [`metrics::Histogram`] handle; see [`obs_counter!`].
#[macro_export]
macro_rules! obs_histogram {
    ($name:expr) => {{
        static HANDLE: std::sync::OnceLock<$crate::obs::metrics::Histogram> =
            std::sync::OnceLock::new();
        HANDLE.get_or_init(|| $crate::obs::metrics::global().histogram($name))
    }};
}

/// Open a scoped trace span: `let _s = span!("sampler/expand");` or
/// `let _s = span!("sampler/expand", shard = 3);` (one integer
/// argument, shown under `args` in the Chrome trace). The span closes
/// — and records, if tracing is enabled — when the guard drops.
#[macro_export]
macro_rules! span {
    ($name:expr) => {
        $crate::obs::trace::span($name)
    };
    ($name:expr, $key:ident = $val:expr) => {
        $crate::obs::trace::span_arg($name, stringify!($key), ($val) as i64)
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_records_only_when_recording() {
        let h = metrics::Histogram::detached();
        set_recording(false);
        {
            let _t = timed(&h);
        }
        assert_eq!(h.snapshot().count, 0, "disabled timer must not record");
        set_recording(true);
        {
            let _t = timed(&h);
        }
        set_recording(false);
        assert_eq!(h.snapshot().count, 1, "enabled timer records once");
    }

    #[test]
    fn macro_handles_are_stable() {
        let a = obs_counter!("obs_unit_macro_counter_total");
        let b = obs_counter!("obs_unit_macro_counter_total");
        a.add(2);
        b.add(3);
        // Two expansion sites, one underlying metric.
        assert_eq!(a.get(), b.get());
        assert!(a.get() >= 5);
    }
}
