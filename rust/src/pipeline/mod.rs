//! Streaming input pipeline (paper §6.2, Fig. 4 right half).
//!
//! Mirrors the TF-GNN Runner's input path: a [`DatasetProvider`] yields
//! GraphTensors (from shard files on disk, or sampled on demand by the
//! in-memory sampler); a shuffle buffer randomizes order; batches of
//! `batch_size` graphs are merged to a single scalar GraphTensor
//! (§3.2) and padded to the static [`PadSpec`] (`FitOrSkipPadding` —
//! oversized batches are skipped and counted); a bounded prefetch
//! channel decouples producer and consumer with real **backpressure**
//! (the producer blocks when the trainer falls behind, capping memory).
//! The parallel-preparation stage stands in for the `tf.data service`
//! CPU cluster (§6.2.1): merge+pad for consecutive batches runs on a
//! thread pool.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver};
use std::sync::Arc;

use crate::graph::pad::{fit_or_skip, PadSpec, Padded};
use crate::graph::{batch::merge, io::ShardSet, GraphTensor};
use crate::ops::{broadcast_pool_fused, Reduce, Tag};
use crate::sampler::inmem::InMemorySampler;
use crate::sampler::SamplerConfig;
use crate::util::rng::Rng;
use crate::util::threadpool::ThreadPool;
use crate::{Error, Result};

/// A source of example GraphTensors (the Runner's `DatasetProvider`).
pub trait DatasetProvider: Send + Sync {
    /// A fresh pass over the data for `epoch`. Implementations reshuffle
    /// per epoch where applicable.
    fn get_dataset(&self, epoch: u64) -> Result<Box<dyn Iterator<Item = Result<GraphTensor>> + Send>>;

    /// Number of examples per epoch, if known.
    fn len_hint(&self) -> Option<usize> {
        None
    }
}

/// Reads sampled subgraphs from shard files (`TFRecordDatasetProvider`
/// analog). Shard order is rotated per epoch.
pub struct ShardProvider {
    pub shards: ShardSet,
}

impl ShardProvider {
    pub fn new(shards: ShardSet) -> ShardProvider {
        ShardProvider { shards }
    }
}

impl DatasetProvider for ShardProvider {
    fn get_dataset(&self, epoch: u64) -> Result<Box<dyn Iterator<Item = Result<GraphTensor>> + Send>> {
        let mut paths = self.shards.paths.clone();
        if !paths.is_empty() {
            let n = paths.len();
            paths.rotate_left((epoch as usize) % n);
        }
        let iter = paths.into_iter().flat_map(|p| {
            match crate::graph::io::ShardReader::open(&p) {
                Ok(reader) => Box::new(reader) as Box<dyn Iterator<Item = Result<GraphTensor>> + Send>,
                Err(e) => Box::new(std::iter::once(Err(e))),
            }
        });
        Ok(Box::new(iter))
    }
}

/// Samples subgraphs on demand (§6.1.2: samples "are used on-demand
/// during training", not persisted). Seeds are reshuffled every epoch.
///
/// With `sampling.threads > 1` the sampling stage fans out: the
/// epoch's iterator owns a thread pool and samples each wave of
/// `sampling.chunk_size` seeds in parallel across it (the producer
/// thread as a whole is already decoupled from the consumer by the
/// bounded prefetch channel). Per-`(plan_seed, seed, op, node)` RNG
/// keying plus the pool's order-preserving map make the stream
/// bit-for-bit identical to serial sampling — only faster.
pub struct SamplingProvider {
    pub sampler: Arc<InMemorySampler>,
    pub seeds: Vec<u32>,
    pub shuffle_seed: u64,
    /// Sampling-stage execution knobs (threads, wave size).
    pub sampling: SamplerConfig,
}

impl SamplingProvider {
    pub fn new(
        sampler: Arc<InMemorySampler>,
        seeds: Vec<u32>,
        shuffle_seed: u64,
    ) -> SamplingProvider {
        SamplingProvider { sampler, seeds, shuffle_seed, sampling: SamplerConfig::default() }
    }
}

/// Wave-parallel sampling iterator — the pipeline's sampling stage
/// when `SamplerConfig::threads > 1`. Each refill blocks on one
/// `map` over the next `chunk` seeds (within-wave parallelism, not
/// read-ahead). Owns its pool; dropping the epoch stream drops the
/// pool and joins the workers.
struct ParallelSampleIter {
    sampler: Arc<InMemorySampler>,
    pool: ThreadPool,
    seeds: std::vec::IntoIter<u32>,
    chunk: usize,
    buf: std::collections::VecDeque<Result<GraphTensor>>,
}

impl Iterator for ParallelSampleIter {
    type Item = Result<GraphTensor>;

    fn next(&mut self) -> Option<Result<GraphTensor>> {
        if self.buf.is_empty() {
            let wave: Vec<u32> = self.seeds.by_ref().take(self.chunk).collect();
            if wave.is_empty() {
                return None;
            }
            let sampler = Arc::clone(&self.sampler);
            self.buf = self.pool.map(wave, move |s| sampler.sample(s)).into();
        }
        self.buf.pop_front()
    }
}

impl DatasetProvider for SamplingProvider {
    fn get_dataset(&self, epoch: u64) -> Result<Box<dyn Iterator<Item = Result<GraphTensor>> + Send>> {
        let mut seeds = self.seeds.clone();
        let mut rng = Rng::new(self.shuffle_seed ^ epoch.wrapping_mul(0x9E3779B97F4A7C15));
        rng.shuffle(&mut seeds);
        if self.sampling.parallel() {
            return Ok(Box::new(ParallelSampleIter {
                sampler: Arc::clone(&self.sampler),
                pool: ThreadPool::new(self.sampling.threads),
                seeds: seeds.into_iter(),
                chunk: self.sampling.chunk_size.max(1),
                buf: std::collections::VecDeque::new(),
            }));
        }
        let sampler = Arc::clone(&self.sampler);
        Ok(Box::new(seeds.into_iter().map(move |s| sampler.sample(s))))
    }

    fn len_hint(&self) -> Option<usize> {
        Some(self.seeds.len())
    }
}

/// Streaming shuffle buffer (like `tf.data.Dataset.shuffle`): keeps a
/// reservoir of `capacity` items; each pull swaps a random slot out.
pub struct ShuffleBuffer<I: Iterator> {
    inner: I,
    buffer: Vec<I::Item>,
    rng: Rng,
    capacity: usize,
}

impl<I: Iterator> ShuffleBuffer<I> {
    pub fn new(inner: I, capacity: usize, seed: u64) -> ShuffleBuffer<I> {
        ShuffleBuffer { inner, buffer: Vec::new(), rng: Rng::new(seed), capacity: capacity.max(1) }
    }
}

impl<I: Iterator> Iterator for ShuffleBuffer<I> {
    type Item = I::Item;

    fn next(&mut self) -> Option<I::Item> {
        while self.buffer.len() < self.capacity {
            match self.inner.next() {
                Some(item) => self.buffer.push(item),
                None => break,
            }
        }
        if self.buffer.is_empty() {
            return None;
        }
        let idx = self.rng.uniform(self.buffer.len());
        Some(self.buffer.swap_remove(idx))
    }
}

/// A per-example feature-engineering transform (the A.3 flow as a
/// pipeline stage): applied to each GraphTensor after reading and
/// before shuffling/batching. Cheap to clone; a transform that fails
/// drops the example and counts a read error.
#[derive(Clone)]
pub struct FeatureMap(Arc<dyn Fn(GraphTensor) -> Result<GraphTensor> + Send + Sync>);

impl FeatureMap {
    pub fn new(
        f: impl Fn(GraphTensor) -> Result<GraphTensor> + Send + Sync + 'static,
    ) -> FeatureMap {
        FeatureMap(Arc::new(f))
    }

    pub fn apply(&self, g: GraphTensor) -> Result<GraphTensor> {
        (self.0)(g)
    }
}

impl std::fmt::Debug for FeatureMap {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "FeatureMap(..)")
    }
}

/// The canonical engineered feature: pool a `send_tag`-node feature
/// across `edge_set` onto the `recv_tag` nodes (e.g. "sum of purchase
/// prices per user", "mean cited-paper embedding"). Runs on the fused
/// broadcast→pool fast path — no `[num_edges, d]` intermediate per
/// example — and stores the result as `out_feature` on the receiver
/// node set.
pub fn pooled_neighbor_feature(
    edge_set: &str,
    send_tag: Tag,
    recv_tag: Tag,
    reduce: Reduce,
    src_feature: &str,
    out_feature: &str,
) -> FeatureMap {
    let edge_set = edge_set.to_string();
    let src_feature = src_feature.to_string();
    let out_feature = out_feature.to_string();
    FeatureMap::new(move |mut g: GraphTensor| {
        let adj = &g.edge_set(&edge_set)?.adjacency;
        let send_set = match send_tag {
            Tag::Source => adj.source_set.clone(),
            Tag::Target => adj.target_set.clone(),
        };
        let recv_set = match recv_tag {
            Tag::Source => adj.source_set.clone(),
            Tag::Target => adj.target_set.clone(),
        };
        let value = g.node_set(&send_set)?.feature(&src_feature)?;
        let pooled = broadcast_pool_fused(&g, &edge_set, send_tag, recv_tag, reduce, value)?;
        // The closure owns the graph: insert in place (no
        // replace_node_features, which deep-clones every feature), then
        // re-validate the touched set's invariant directly.
        let ns = g
            .node_sets
            .get_mut(&recv_set)
            .ok_or_else(|| Error::Graph(format!("unknown node set {recv_set:?}")))?;
        pooled.validate(ns.total(), &format!("{recv_set}/{out_feature}"))?;
        ns.features.insert(out_feature.clone(), pooled);
        Ok(g)
    })
}

/// Pipeline configuration.
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    pub batch_size: usize,
    /// Shuffle buffer capacity (0 disables shuffling).
    pub shuffle_buffer: usize,
    pub shuffle_seed: u64,
    pub pad: PadSpec,
    /// Bounded prefetch depth (backpressure window).
    pub prefetch_depth: usize,
    /// Drop a trailing partial batch (standard for training).
    pub drop_remainder: bool,
    /// Threads for the merge+pad preparation stage (tf.data-service
    /// analog); 0 or 1 = prepare inline on the producer thread.
    pub prep_threads: usize,
    /// Optional feature-engineering stage applied per example before
    /// shuffling/batching (see [`FeatureMap`]).
    pub feature_map: Option<FeatureMap>,
}

impl PipelineConfig {
    pub fn new(batch_size: usize, pad: PadSpec) -> PipelineConfig {
        PipelineConfig {
            batch_size,
            shuffle_buffer: 0,
            shuffle_seed: 0,
            pad,
            prefetch_depth: 4,
            drop_remainder: true,
            prep_threads: 0,
            feature_map: None,
        }
    }
}

/// Counters exposed while the pipeline runs.
#[derive(Debug, Default)]
pub struct PipelineStats {
    pub graphs_read: AtomicU64,
    pub batches_emitted: AtomicU64,
    pub batches_skipped: AtomicU64,
    pub read_errors: AtomicU64,
}

/// A running pipeline for one epoch: a bounded receiver of padded
/// batches plus live stats. Dropping the handle stops the producer
/// (its sends fail once the receiver is gone).
pub struct EpochStream {
    pub rx: Receiver<Padded>,
    pub stats: Arc<PipelineStats>,
    producer: Option<std::thread::JoinHandle<()>>,
}

impl EpochStream {
    /// Iterate over batches (blocking on the bounded channel).
    pub fn iter(&self) -> impl Iterator<Item = Padded> + '_ {
        self.rx.iter()
    }
}

impl Drop for EpochStream {
    fn drop(&mut self) {
        if let Some(h) = self.producer.take() {
            // Replace the receiver with a dummy so the real one is
            // dropped; the producer's next send fails and it exits.
            let (_tx, dummy) = sync_channel(1);
            let real = std::mem::replace(&mut self.rx, dummy);
            drop(real);
            let _ = h.join();
        }
    }
}

/// Launch the pipeline for one epoch.
pub fn epoch_stream(
    provider: Arc<dyn DatasetProvider>,
    cfg: PipelineConfig,
    epoch: u64,
) -> Result<EpochStream> {
    if cfg.batch_size == 0 {
        return Err(Error::Pipeline("batch_size 0".into()));
    }
    let stats = Arc::new(PipelineStats::default());
    let (tx, rx) = sync_channel::<Padded>(cfg.prefetch_depth.max(1));
    let stats_p = Arc::clone(&stats);
    let producer = std::thread::Builder::new()
        .name("tfgnn-pipeline".into())
        .spawn(move || {
            let source = match provider.get_dataset(epoch) {
                Ok(s) => s,
                Err(_) => {
                    stats_p.read_errors.fetch_add(1, Ordering::Relaxed);
                    return;
                }
            };
            let stats_c = Arc::clone(&stats_p);
            let counted = source.filter_map(move |r| match r {
                Ok(g) => {
                    stats_c.graphs_read.fetch_add(1, Ordering::Relaxed);
                    Some(g)
                }
                Err(_) => {
                    stats_c.read_errors.fetch_add(1, Ordering::Relaxed);
                    None
                }
            });
            // Feature-engineering stage (fused broadcast→pool fast
            // path): per-example, before shuffling/batching. Failures
            // drop the example and count as read errors.
            let engineered: Box<dyn Iterator<Item = GraphTensor>> =
                match cfg.feature_map.clone() {
                    Some(fm) => {
                        let stats_f = Arc::clone(&stats_p);
                        Box::new(counted.filter_map(move |g| match fm.apply(g) {
                            Ok(g) => Some(g),
                            Err(_) => {
                                stats_f.read_errors.fetch_add(1, Ordering::Relaxed);
                                None
                            }
                        }))
                    }
                    None => Box::new(counted),
                };
            let shuffled: Box<dyn Iterator<Item = GraphTensor>> = if cfg.shuffle_buffer > 0 {
                Box::new(ShuffleBuffer::new(engineered, cfg.shuffle_buffer, cfg.shuffle_seed))
            } else {
                Box::new(engineered)
            };

            // Batch → merge → pad, optionally on a prep pool.
            let prep = |graphs: Vec<GraphTensor>| -> Option<Padded> {
                let merged = merge(&graphs).ok()?;
                fit_or_skip(&merged, &cfg.pad)
            };

            if cfg.prep_threads > 1 {
                let pool = crate::util::threadpool::ThreadPool::new(cfg.prep_threads);
                // Prepare in waves of pool-size batches to bound memory.
                let mut wave: Vec<Vec<GraphTensor>> = Vec::new();
                let mut batch: Vec<GraphTensor> = Vec::new();
                let flush = |wave: &mut Vec<Vec<GraphTensor>>| -> bool {
                    let items = std::mem::take(wave);
                    let pad = cfg.pad.clone();
                    let results = pool.map(items, move |graphs| {
                        let merged = merge(&graphs).ok()?;
                        fit_or_skip(&merged, &pad)
                    });
                    for r in results {
                        match r {
                            Some(p) => {
                                stats_p.batches_emitted.fetch_add(1, Ordering::Relaxed);
                                if tx.send(p).is_err() {
                                    return false; // consumer gone
                                }
                            }
                            None => {
                                stats_p.batches_skipped.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    }
                    true
                };
                for g in shuffled {
                    batch.push(g);
                    if batch.len() == cfg.batch_size {
                        wave.push(std::mem::take(&mut batch));
                        if wave.len() == cfg.prep_threads && !flush(&mut wave) {
                            return;
                        }
                    }
                }
                if !cfg.drop_remainder && !batch.is_empty() {
                    wave.push(batch);
                }
                flush(&mut wave);
            } else {
                let mut batch: Vec<GraphTensor> = Vec::with_capacity(cfg.batch_size);
                let emit = |graphs: Vec<GraphTensor>| -> bool {
                    match prep(graphs) {
                        Some(p) => {
                            stats_p.batches_emitted.fetch_add(1, Ordering::Relaxed);
                            tx.send(p).is_ok()
                        }
                        None => {
                            stats_p.batches_skipped.fetch_add(1, Ordering::Relaxed);
                            true
                        }
                    }
                };
                for g in shuffled {
                    batch.push(g);
                    if batch.len() == cfg.batch_size {
                        if !emit(std::mem::take(&mut batch)) {
                            return;
                        }
                    }
                }
                if !cfg.drop_remainder && !batch.is_empty() {
                    emit(batch);
                }
            }
        })?;
    Ok(EpochStream { rx, stats, producer: Some(producer) })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampler::spec::mag_sampling_spec_scaled;
    use crate::synth::mag::{generate, MagConfig, Split};

    fn mag_provider() -> (Arc<SamplingProvider>, PadSpec) {
        let ds = generate(&MagConfig::tiny());
        let seeds = ds.papers_in_split(Split::Train);
        let store = Arc::new(ds.store);
        let spec = mag_sampling_spec_scaled(&store.schema, 0.2).unwrap();
        let sampler = Arc::new(InMemorySampler::new(store, spec, 3).unwrap());
        // Derive a pad spec from a sample prefix, like the Runner does.
        let probe: Vec<_> = seeds.iter().take(8).map(|&s| sampler.sample(s).unwrap()).collect();
        let pad = PadSpec::fit(&probe.iter().collect::<Vec<_>>(), 4, 2.0);
        (Arc::new(SamplingProvider::new(sampler, seeds, 5)), pad)
    }

    #[test]
    fn epoch_yields_padded_batches() {
        let (provider, pad) = mag_provider();
        let n = provider.len_hint().unwrap();
        let cfg = PipelineConfig { shuffle_buffer: 16, ..PipelineConfig::new(4, pad.clone()) };
        let stream = epoch_stream(provider, cfg, 0).unwrap();
        let batches: Vec<Padded> = stream.iter().collect();
        let emitted = stream.stats.batches_emitted.load(Ordering::Relaxed) as usize;
        let skipped = stream.stats.batches_skipped.load(Ordering::Relaxed) as usize;
        assert_eq!(batches.len(), emitted);
        assert_eq!(emitted + skipped, n / 4);
        assert!(emitted > 0, "most batches fit");
        for b in &batches {
            // Static shapes: every batch padded to identical sizes.
            for (set, cap) in &pad.node_caps {
                assert_eq!(b.graph.num_nodes(set).unwrap(), *cap);
            }
            for (set, cap) in &pad.edge_caps {
                assert_eq!(b.graph.num_edges(set).unwrap(), *cap);
            }
            assert_eq!(b.num_real_components, 4);
        }
    }

    #[test]
    fn epochs_reshuffle() {
        let (provider, pad) = mag_provider();
        let cfg = PipelineConfig::new(2, pad);
        let order = |epoch: u64| -> Vec<i64> {
            let stream = epoch_stream(Arc::clone(&provider) as Arc<dyn DatasetProvider>, cfg.clone(), epoch).unwrap();
            stream
                .iter()
                .map(|p| p.graph.context.feature("seed").unwrap().as_i64().unwrap().1[0])
                .collect()
        };
        let e0 = order(0);
        let e0b = order(0);
        let e1 = order(1);
        assert_eq!(e0, e0b, "same epoch deterministic");
        assert_ne!(e0, e1, "different epochs reshuffled");
    }

    #[test]
    fn parallel_prep_matches_inline() {
        let (provider, pad) = mag_provider();
        let mut cfg = PipelineConfig::new(4, pad);
        cfg.shuffle_buffer = 0;
        let inline: Vec<Padded> =
            epoch_stream(Arc::clone(&provider) as Arc<dyn DatasetProvider>, cfg.clone(), 0)
                .unwrap()
                .iter()
                .collect();
        cfg.prep_threads = 4;
        let parallel: Vec<Padded> =
            epoch_stream(provider, cfg, 0).unwrap().iter().collect();
        assert_eq!(inline.len(), parallel.len());
        for (a, b) in inline.iter().zip(&parallel) {
            assert_eq!(a.graph, b.graph, "prep pool must not reorder or alter batches");
        }
    }

    #[test]
    fn parallel_sampling_stage_matches_serial() {
        // The sampling stage at threads > 1 must feed the pipeline the
        // exact same example stream (order and bits) as serial.
        let (provider, pad) = mag_provider();
        let cfg = PipelineConfig { shuffle_buffer: 16, ..PipelineConfig::new(4, pad) };
        let serial: Vec<Padded> =
            epoch_stream(Arc::clone(&provider) as Arc<dyn DatasetProvider>, cfg.clone(), 0)
                .unwrap()
                .iter()
                .collect();
        for threads in [2usize, 8] {
            let par_provider = Arc::new(SamplingProvider {
                sampler: Arc::clone(&provider.sampler),
                seeds: provider.seeds.clone(),
                shuffle_seed: provider.shuffle_seed,
                sampling: SamplerConfig { threads, chunk_size: 7, ..SamplerConfig::default() },
            });
            let parallel: Vec<Padded> =
                epoch_stream(par_provider, cfg.clone(), 0).unwrap().iter().collect();
            assert_eq!(serial.len(), parallel.len());
            for (a, b) in serial.iter().zip(&parallel) {
                assert_eq!(a.graph, b.graph, "threads={threads}");
            }
        }
    }

    #[test]
    fn backpressure_bounds_producer() {
        let (provider, pad) = mag_provider();
        let mut cfg = PipelineConfig::new(2, pad);
        cfg.prefetch_depth = 2;
        let stream = epoch_stream(provider, cfg, 0).unwrap();
        // Without consuming, the producer can buffer at most depth
        // batches (+1 in flight).
        std::thread::sleep(std::time::Duration::from_millis(200));
        let emitted = stream.stats.batches_emitted.load(Ordering::Relaxed);
        assert!(emitted <= 4, "producer blocked by backpressure, emitted {emitted}");
        // Now drain fully.
        let rest: Vec<_> = stream.iter().collect();
        assert!(rest.len() as u64 >= emitted);
    }

    #[test]
    fn early_drop_stops_producer() {
        let (provider, pad) = mag_provider();
        let cfg = PipelineConfig::new(2, pad);
        let stream = epoch_stream(provider, cfg, 0).unwrap();
        let first = stream.rx.recv().unwrap();
        assert!(first.num_real_components > 0);
        drop(stream); // must join the producer without deadlock
    }

    #[test]
    fn shard_provider_roundtrip() {
        let (provider, pad) = mag_provider();
        // Materialize one epoch to shards, then stream it back.
        let dir = std::env::temp_dir().join(format!("tfgnn-pipe-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let graphs: Vec<GraphTensor> = provider
            .get_dataset(0)
            .unwrap()
            .map(|g| g.unwrap())
            .take(10)
            .collect();
        let set = ShardSet::write_all(&dir, "t", 2, graphs.clone().into_iter()).unwrap();
        let sp = ShardProvider::new(set);
        let back: Vec<GraphTensor> =
            sp.get_dataset(0).unwrap().map(|g| g.unwrap()).collect();
        assert_eq!(back.len(), 10);
        // Round-robin sharding interleaves; same multiset of graphs.
        assert_eq!(back.len(), graphs.len());
        for g in &graphs {
            assert!(back.contains(g));
        }
        let cfg = PipelineConfig::new(2, pad);
        let stream = epoch_stream(Arc::new(sp), cfg, 0).unwrap();
        assert!(stream.iter().count() > 0);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn feature_map_engineers_each_example() {
        // Pool each paper's cited-paper embeddings (mean) into a new
        // node feature, per example, on the fused fast path.
        let (provider, pad) = mag_provider();
        let fm = pooled_neighbor_feature(
            "cites",
            Tag::Source,
            Tag::Target,
            Reduce::Mean,
            "feat",
            "cited_feat_mean",
        );
        // Unit-level: the transform matches the unfused oracle on one
        // raw example.
        let g = provider.sampler.sample(provider.seeds[0]).unwrap();
        let engineered = fm.apply(g.clone()).unwrap();
        let got = engineered
            .node_set("paper")
            .unwrap()
            .feature("cited_feat_mean")
            .unwrap()
            .clone();
        let feat = g.node_set("paper").unwrap().feature("feat").unwrap().clone();
        let on_edges =
            crate::ops::broadcast_node_to_edges(&g, "cites", Tag::Source, &feat).unwrap();
        let want =
            crate::ops::pool_edges_to_node(&g, "cites", Tag::Target, Reduce::Mean, &on_edges)
                .unwrap();
        assert_eq!(got, want, "fused pipeline stage == unfused oracle");

        // Pipeline-level: every emitted batch carries the new feature
        // (padded to the static cap like any other feature).
        let mut cfg = PipelineConfig::new(2, pad);
        cfg.feature_map = Some(fm);
        let stream = epoch_stream(provider, cfg, 0).unwrap();
        let batches: Vec<Padded> = stream.iter().collect();
        assert!(!batches.is_empty());
        for b in &batches {
            let ns = b.graph.node_set("paper").unwrap();
            let f = ns.feature("cited_feat_mean").unwrap();
            assert_eq!(f.len(), ns.total(), "engineered feature padded with the batch");
        }
    }

    #[test]
    fn failing_feature_map_drops_examples_not_pipeline() {
        let (provider, pad) = mag_provider();
        let n = provider.len_hint().unwrap();
        let mut cfg = PipelineConfig::new(2, pad);
        cfg.feature_map =
            Some(FeatureMap::new(|_g| Err(Error::Feature("engineered to fail".into()))));
        let stream = epoch_stream(provider, cfg, 0).unwrap();
        let batches: Vec<Padded> = stream.iter().collect();
        assert!(batches.is_empty(), "every example dropped");
        assert_eq!(stream.stats.read_errors.load(Ordering::Relaxed) as usize, n);
    }

    #[test]
    fn shuffle_buffer_yields_all_items() {
        let items: Vec<u32> = (0..100).collect();
        let out: Vec<u32> = ShuffleBuffer::new(items.clone().into_iter(), 16, 7).collect();
        assert_eq!(out.len(), 100);
        let mut sorted = out.clone();
        sorted.sort();
        assert_eq!(sorted, items);
        assert_ne!(out, items, "order changed");
    }

    /// Property: for any input length and capacity ∈ {1, n/2, n, ≥n},
    /// the shuffle buffer emits an **exact permutation** of its input —
    /// no drops, no duplicates — and a fixed seed reproduces the exact
    /// output order across runs. Capacity 1 degenerates to a
    /// pass-through; capacity ≥ n must actually permute (for inputs big
    /// enough that a fixed-point shuffle is implausible).
    #[test]
    fn prop_shuffle_buffer_exact_permutation_and_seeded() {
        use crate::util::proptest::check;
        check("shuffle buffer is a seeded exact permutation", 40, |rng| {
            let n = 1 + rng.uniform(200);
            let items: Vec<u32> = (0..n as u32).collect();
            for capacity in [1usize, (n / 2).max(1), n, n + 7] {
                let seed = rng.next_u64();
                let out: Vec<u32> =
                    ShuffleBuffer::new(items.clone().into_iter(), capacity, seed).collect();
                assert_eq!(out.len(), n, "capacity {capacity}: dropped items");
                let mut sorted = out.clone();
                sorted.sort_unstable();
                assert_eq!(sorted, items, "capacity {capacity}: not a permutation");
                let again: Vec<u32> =
                    ShuffleBuffer::new(items.clone().into_iter(), capacity, seed).collect();
                assert_eq!(out, again, "capacity {capacity}: seed {seed} not reproducible");
                if capacity == 1 {
                    assert_eq!(out, items, "capacity 1 is a pass-through");
                }
                if capacity >= n && n >= 32 {
                    assert_ne!(out, items, "capacity {capacity}: full buffer must shuffle");
                }
            }
        });
    }
}
