//! `tfgnn` — the command-line launcher.
//!
//! ```text
//! tfgnn info                          # inspect artifacts + manifest
//! tfgnn check    CONFIG...            # static analysis: shapes, dead
//!                [--against-checkpoint PATH]   # sets, reachability,
//!                                              # params (TFGNN0xx codes)
//! tfgnn generate --out DIR            # synth-MAG -> stats + schema file
//! tfgnn sample   --out DIR [--workers N] [--shards K] [--crash-rate P]
//! tfgnn train    [--arch mpnn] [--epochs N] [--ckpt PATH]
//!                [--engine aot|native] [--trainer-threads N] [--config PATH]
//! tfgnn eval     --ckpt PATH [--arch mpnn]
//! tfgnn sweep    [--arch mpnn] [--epochs N] [--top K]
//! tfgnn serve-bench [--requests N] [--max-batch B]
//! tfgnn loadgen  [--lanes N] [--queue N] [--cache N] [--arch mpnn]
//!                [--concurrency 1,4,16] [--requests N] [--swap]
//!                [--json PATH]         # closed-loop serving load test
//! tfgnn stats    METRICS.json [--prometheus]   # pretty-print a
//!                                              # metrics snapshot
//! tfgnn stats    --diff OLD.json NEW.json      # run-over-run delta
//! tfgnn runs     list EVENTS.jsonl...          # training-journal
//! tfgnn runs     show EVENTS.jsonl [--loss-target X]  # summaries
//! tfgnn runs     diff A.jsonl B.jsonl          # experiment compare
//! ```
//!
//! `train` additionally accepts the training-telemetry flags (see
//! `docs/observability.md`): `--events-out PATH` (append the
//! `tfgnn_events_v1` step journal — per-step loss, task metric sums,
//! gradient/parameter norms, update ratio, step + data-wait timing),
//! `--grad-norm-limit X` (gradient-explosion sentinel: fail the run
//! with a structured error instead of silently diverging; non-finite
//! gradients always trip) and `--incident-dir DIR` (where a tripped
//! sentinel writes its flight-recorder dump, with the recent journal
//! tail embedded). `sweep --events-out PATH` writes one journal per
//! trial (`PATH-trial000.jsonl`, ...).
//!
//! `train`, `serve-bench` and `loadgen` also accept
//! `--metrics-out PATH` (write a `tfgnn_metrics_v1` JSON snapshot on
//! exit) and `--trace-out PATH` (write a Chrome `trace_event` JSON —
//! load it at `chrome://tracing` or <https://ui.perfetto.dev>). Either
//! flag turns on histogram recording; `--trace-out` additionally turns
//! on span capture. With neither flag the observability layer is inert.
//!
//! `serve-bench` and `loadgen` additionally accept the live
//! introspection flags (see `docs/observability.md`):
//! `--admin-addr HOST:PORT` (serve `/metrics`, `/metrics.json`,
//! `/healthz`, `/tracez`, `/statusz` while running),
//! `--deadline-ms N` (default request deadline; expired requests are
//! answered `DeadlineExceeded` without reaching the model) and
//! `--incident-dir DIR` (flight-recorder dumps on watchdog trips,
//! overload bursts and failed batches). `loadgen --linger-ms N` keeps
//! the server (and its admin endpoint) alive after the load phase so
//! external scrapers can be pointed at it.
//!
//! All subcommands read `artifacts/manifest.json` (written by
//! `make artifacts`), so the Rust binary is self-contained after the
//! one-time AOT build. Exception: `train --engine native` needs no
//! artifacts at all — point `--config` at a raw `configs/*.json`
//! (e.g. `configs/mag_small.json`) and the pure-Rust reverse-mode
//! engine trains data-parallel over `--trainer-threads` replicas.

use std::path::PathBuf;
use std::sync::Arc;

use tfgnn::runner::sweep::{format_top, sweep, SweepConfig};
use tfgnn::runner::{run, MagEnv, RunConfig};
use tfgnn::runtime::batch::RootTask;
use tfgnn::runtime::manifest::Manifest;
use tfgnn::runtime::Runtime;
use tfgnn::train::Hyperparams;
use tfgnn::util::cli::Args;
use tfgnn::util::stats::Summary;
use tfgnn::Result;

fn main() {
    let args = Args::from_env();
    let code = match dispatch(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.get("artifacts").unwrap_or("artifacts"))
}

fn dispatch(args: &Args) -> Result<()> {
    match args.subcommand() {
        Some("info") => info(args),
        Some("check") => check(args),
        Some("generate") => generate(args),
        Some("sample") => sample(args),
        Some("train") => train(args),
        Some("eval") => eval(args),
        Some("sweep") => run_sweep(args),
        Some("serve-bench") => serve_bench(args),
        Some("loadgen") => loadgen(args),
        Some("stats") => stats(args),
        Some("runs") => runs(args),
        _ => {
            eprintln!(
                "usage: tfgnn <info|check|generate|sample|train|eval|sweep|serve-bench|\
                 loadgen|stats|runs> [--help]"
            );
            Ok(())
        }
    }
}

/// Shared `--metrics-out` / `--trace-out` handling: arm the
/// observability layer before the workload, export after it. Both
/// steps are no-ops when neither flag is given.
fn obs_enable(args: &Args) {
    tfgnn::obs::report::enable(args.get("metrics-out"), args.get("trace-out"));
}

fn obs_finish(args: &Args) -> Result<()> {
    tfgnn::obs::report::finish(args.get("metrics-out"), args.get("trace-out"))?;
    if let Some(p) = args.get("metrics-out") {
        println!("metrics written to {p}");
    }
    if let Some(p) = args.get("trace-out") {
        println!("trace written to {p} (load in chrome://tracing or ui.perfetto.dev)");
    }
    Ok(())
}

/// Read a `tfgnn_metrics_v1` export back from disk.
fn load_snapshot(path: &str) -> Result<tfgnn::obs::metrics::MetricsSnapshot> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| tfgnn::Error::Pipeline(format!("{path}: {e}")))?;
    tfgnn::obs::metrics::MetricsSnapshot::from_json(&tfgnn::util::json::Json::parse(&text)?)
}

/// A nonzero trace-drop tally means the per-thread rings wrapped
/// before export — warn so nobody debugs from a silently truncated
/// trace.
fn warn_on_trace_drops(snap: &tfgnn::obs::metrics::MetricsSnapshot) {
    let dropped = snap.counters.get("obs_trace_dropped_total").copied().unwrap_or(0);
    if dropped > 0 {
        eprintln!(
            "WARNING: obs_trace_dropped_total = {dropped}: the trace ring overwrote \
             events before export; the Chrome trace is incomplete"
        );
    }
}

/// `tfgnn stats METRICS.json [--prometheus]`: pretty-print a metrics
/// snapshot exported by `--metrics-out` (or dump it in Prometheus text
/// exposition format). `tfgnn stats --diff OLD.json NEW.json` renders
/// the run-over-run movement between two exports instead.
fn stats(args: &Args) -> Result<()> {
    if let Some(old_path) = args.get("diff") {
        let [new_path] = args.rest() else {
            return Err(tfgnn::Error::Pipeline(
                "usage: tfgnn stats --diff <OLD.json> <NEW.json>".into(),
            ));
        };
        let old = load_snapshot(old_path)?;
        let new = load_snapshot(new_path)?;
        warn_on_trace_drops(&new);
        print!("{}", tfgnn::obs::report::render_diff(&old, &new));
        return Ok(());
    }
    let [path] = args.rest() else {
        return Err(tfgnn::Error::Pipeline(
            "usage: tfgnn stats <METRICS.json> [--prometheus] | \
             tfgnn stats --diff <OLD.json> <NEW.json>"
                .into(),
        ));
    };
    let snap = load_snapshot(path)?;
    warn_on_trace_drops(&snap);
    if args.flag("prometheus") {
        print!("{}", snap.to_prometheus());
    } else {
        print!("{}", tfgnn::obs::report::render_stats(&snap));
    }
    Ok(())
}

/// `tfgnn check CONFIG... [--against-checkpoint PATH]`: run the static
/// model-plan analyzer over each config and print every diagnostic —
/// stable `TFGNN0xx` code, severity, JSON path, fix hint. Exits
/// non-zero iff any config has errors (warnings are report-only), so
/// the command doubles as the CI gate over `configs/*.json`.
fn check(args: &Args) -> Result<()> {
    let paths = args.rest();
    if paths.is_empty() {
        return Err(tfgnn::Error::Pipeline(
            "usage: tfgnn check <config.json>... [--against-checkpoint PATH]".into(),
        ));
    }
    let ckpt = match args.get("against-checkpoint") {
        Some(p) => Some(tfgnn::train::checkpoint::load(&PathBuf::from(p))?),
        None => None,
    };
    let mut failed = 0usize;
    for path in paths {
        let text = std::fs::read_to_string(path)
            .map_err(|e| tfgnn::Error::Pipeline(format!("{path}: {e}")))?;
        let cfg = tfgnn::util::json::Json::parse(&text)?;
        let d = match &ckpt {
            Some(t) => tfgnn::analysis::analyze_against_checkpoint(&cfg, t),
            None => tfgnn::analysis::analyze(&cfg),
        };
        for diag in d.iter() {
            println!("{path}: {diag}");
        }
        if d.has_errors() {
            failed += 1;
        } else if d.is_empty() {
            println!("{path}: ok");
        } else {
            println!("{path}: ok ({} warning(s))", d.len());
        }
    }
    if failed > 0 {
        return Err(tfgnn::Error::Schema(format!("{failed} config(s) failed check")));
    }
    Ok(())
}

fn info(args: &Args) -> Result<()> {
    let m = Manifest::load(&artifacts_dir(args))?;
    println!("artifacts: {}", artifacts_dir(args).display());
    let pad = m.pad_spec()?;
    println!("batch_size {} | component cap {}", m.batch_size()?, pad.component_cap);
    println!("node caps: {:?}", pad.node_caps);
    println!("edge caps: {:?}", pad.edge_caps);
    for (arch, entry) in &m.models {
        println!(
            "model {arch}: hidden {} message {} layers {} params {}",
            entry.hidden_dim, entry.message_dim, entry.num_layers, entry.param_count
        );
        for (prog, p) in &entry.programs {
            println!("  {prog:<12} {} ({} in, {} out)", p.file, p.inputs.len(), p.outputs.len());
        }
    }
    Ok(())
}

fn generate(args: &Args) -> Result<()> {
    let m = Manifest::load(&artifacts_dir(args))?;
    let cfg = m.mag_config()?;
    let ds = tfgnn::synth::mag::generate(&cfg);
    println!("synth-MAG (seed {}):", cfg.seed);
    for (name, col) in &ds.store.nodes {
        println!("  node set {name:<16} {:>8} nodes", col.count);
    }
    for (name, col) in &ds.store.edges {
        println!("  edge set {name:<16} {:>8} edges", col.num_edges());
    }
    for split in [
        tfgnn::synth::mag::Split::Train,
        tfgnn::synth::mag::Split::Validation,
        tfgnn::synth::mag::Split::Test,
    ] {
        println!("  split {split:?}: {} papers", ds.papers_in_split(split).len());
    }
    if let Some(out) = args.get("out") {
        let dir = PathBuf::from(out);
        std::fs::create_dir_all(&dir)?;
        let schema_path = dir.join("schema.json");
        tfgnn::schema::parse::write_schema(&ds.store.schema, &schema_path)?;
        println!("schema written to {}", schema_path.display());
    }
    Ok(())
}

fn sample(args: &Args) -> Result<()> {
    let env = MagEnv::from_artifacts(&artifacts_dir(args))?;
    let out = PathBuf::from(args.get("out").unwrap_or("data/shards"));
    let workers: usize = args.get_or("workers", 4)?;
    let shards: usize = args.get_or("shards", 8)?;
    let crash_rate: f64 = args.get_or("crash-rate", 0.0)?;
    let store_shards: usize = args.get_or("store-shards", 16)?;
    let seeds = env.dataset.papers_in_split(tfgnn::synth::mag::Split::Train);
    let sharded = Arc::new(tfgnn::store::sharded::ShardedStore::new(
        Arc::clone(&env.store),
        store_shards,
    ));
    let spec = env.sampler.spec().clone();
    let cfg = tfgnn::coordinator::CoordinatorConfig {
        num_workers: workers,
        worker_crash_rate: crash_rate,
        crash_seed: 7,
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let (set, report) = tfgnn::coordinator::run_sampling_to_shards(
        sharded,
        &spec,
        env.manifest.plan_seed()?,
        &seeds,
        &cfg,
        &out,
        "train",
        shards,
    )?;
    let secs = t0.elapsed().as_secs_f64();
    println!(
        "sampled {} subgraphs in {:.2}s ({:.0}/s) with {} workers",
        report.stats.subgraphs,
        secs,
        report.stats.subgraphs as f64 / secs,
        workers
    );
    println!(
        "  adjacency RPCs {} (retried {}), worker crashes {} (requeued {})",
        report.stats.adjacency_rpcs,
        report.stats.retried_rpcs,
        report.worker_crashes,
        report.requeues
    );
    println!("  {} shards under {}", set.paths.len(), out.display());
    Ok(())
}

fn train(args: &Args) -> Result<()> {
    let mut cfg = RunConfig::new(artifacts_dir(args), args.get("arch").unwrap_or("mpnn"));
    cfg.epochs = args.get_or("epochs", 3)?;
    cfg.max_steps_per_epoch = match args.get("max-steps") {
        Some(_) => Some(args.get_or("max-steps", 0usize)?),
        None => None,
    };
    cfg.max_eval_batches = match args.get("max-eval-batches") {
        Some(_) => Some(args.get_or("max-eval-batches", 0usize)?),
        None => None,
    };
    cfg.prep_threads = args.get_or("prep-threads", 2)?;
    cfg.sampler_threads = args.get_or("sampler-threads", 0)?;
    cfg.engine = match args.get("engine") {
        Some(e) => tfgnn::runner::EngineKind::parse(e)?,
        None => tfgnn::runner::EngineKind::Aot,
    };
    cfg.trainer_threads = args.get_or("trainer-threads", 0)?;
    if let Some(p) = args.get("config") {
        cfg.config_path = Some(PathBuf::from(p));
    }
    cfg.verbose = true;
    if let Some(p) = args.get("ckpt") {
        cfg.checkpoint = Some(PathBuf::from(p));
    }
    if let Some(p) = args.get("events-out") {
        cfg.events_out = Some(PathBuf::from(p));
    }
    if args.get("grad-norm-limit").is_some() {
        cfg.grad_norm_limit = Some(args.get_or("grad-norm-limit", 0.0f64)?);
    }
    if let Some(p) = args.get("incident-dir") {
        cfg.incident_dir = Some(PathBuf::from(p));
    }
    if args.get("lr").is_some() || args.get("dropout").is_some() || args.get("wd").is_some() {
        let m = match (&cfg.engine, &cfg.config_path) {
            (tfgnn::runner::EngineKind::Native, Some(p)) => {
                tfgnn::runner::manifest_from_config_file(p)?
            }
            _ => Manifest::load(&cfg.artifacts_dir)?,
        };
        let mut hp = Hyperparams::from_manifest(&m)?;
        hp.learning_rate = args.get_or("lr", hp.learning_rate)?;
        hp.dropout = args.get_or("dropout", hp.dropout)?;
        hp.weight_decay = args.get_or("wd", hp.weight_decay)?;
        cfg.hp = Some(hp);
    }
    obs_enable(args);
    let report = run(&cfg)?;
    println!(
        "done: best val acc {:.4}, test {}, {:.1} steps/s",
        report.best_val_acc, report.test, report.train_steps_per_sec
    );
    if let Some(p) = &cfg.events_out {
        println!("event journal written to {}", p.display());
    }
    obs_finish(args)
}

/// `tfgnn runs` — summarize and compare `tfgnn_events_v1` training
/// journals written by `train --events-out`: `runs list FILE...` (one
/// line per run), `runs show FILE [--loss-target X]` (full summary,
/// optionally with a time-to-loss-target row) and `runs diff A B`
/// (per-metric deltas between two runs).
fn runs(args: &Args) -> Result<()> {
    use tfgnn::obs::events::{render_diff, render_list, render_show, RunSummary};
    let usage = "usage: tfgnn runs <list FILE...|show FILE [--loss-target X]|diff A B>";
    let bad = || tfgnn::Error::Pipeline(usage.into());
    let Some((verb, files)) = args.rest().split_first() else {
        return Err(bad());
    };
    match (verb.as_str(), files) {
        ("list", files) if !files.is_empty() => {
            let mut summaries = Vec::new();
            for f in files {
                summaries.push(RunSummary::from_path(std::path::Path::new(f))?);
            }
            print!("{}", render_list(&summaries));
            Ok(())
        }
        ("show", [file]) => {
            let s = RunSummary::from_path(std::path::Path::new(file))?;
            let target = match args.get("loss-target") {
                Some(_) => Some(args.get_or("loss-target", 0.0f64)?),
                None => None,
            };
            print!("{}", render_show(&s, target));
            Ok(())
        }
        ("diff", [a, b]) => {
            let sa = RunSummary::from_path(std::path::Path::new(a))?;
            let sb = RunSummary::from_path(std::path::Path::new(b))?;
            print!("{}", render_diff(&sa, &sb));
            Ok(())
        }
        _ => Err(bad()),
    }
}

fn eval(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let env = MagEnv::from_artifacts(&dir)?;
    let arch = args.get("arch").unwrap_or("mpnn");
    let entry = env.manifest.model(arch)?.clone();
    let ckpt = PathBuf::from(args.req("ckpt")?);
    let params = tfgnn::train::checkpoint::load(&ckpt)?;
    let rt = Runtime::cpu()?;
    let hp = Hyperparams::from_manifest(&env.manifest)?;
    let mut trainer = tfgnn::train::Trainer::new(rt, &dir, &entry, RootTask::default(), hp)?;
    trainer.params_from_host(&params)?;
    for (name, split) in [
        ("validation", tfgnn::synth::mag::Split::Validation),
        ("test", tfgnn::synth::mag::Split::Test),
    ] {
        let seeds = env.dataset.papers_in_split(split);
        let mut metrics = tfgnn::train::metrics::EpochMetrics::default();
        for padded in env.eval_batches(&seeds, None) {
            if let Some(p) = padded? {
                metrics.add(trainer.eval_batch(&p)?);
            }
        }
        println!("{name}: {metrics}");
    }
    Ok(())
}

fn run_sweep(args: &Args) -> Result<()> {
    let mut base = RunConfig::new(artifacts_dir(args), args.get("arch").unwrap_or("mpnn"));
    base.epochs = args.get_or("epochs", 2)?;
    base.max_steps_per_epoch = Some(args.get_or("max-steps", 40)?);
    base.max_eval_batches = Some(args.get_or("max-eval-batches", 10)?);
    base.verbose = args.flag("verbose");
    if let Some(p) = args.get("events-out") {
        base.events_out = Some(PathBuf::from(p));
    }
    let cfg = SweepConfig::default_grid(base);
    println!("sweep: {} trials", cfg.num_trials());
    let trials = sweep(&cfg)?;
    let top: usize = args.get_or("top", 3)?;
    println!("{}", format_top(&trials, top));
    Ok(())
}

/// Apply the shared live-introspection flags (`--admin-addr`,
/// `--deadline-ms`, `--incident-dir`) to a serving config.
fn introspection_cfg(
    args: &Args,
    mut cfg: tfgnn::serve::ServeConfig,
    label: String,
) -> Result<tfgnn::serve::ServeConfig> {
    cfg.admin_addr = args.get("admin-addr").map(str::to_string);
    cfg.default_deadline_ms = args.get_or("deadline-ms", 0u64)?;
    cfg.incident_dir = args.get("incident-dir").map(PathBuf::from);
    cfg.config_label = Some(label);
    Ok(cfg)
}

fn serve_bench(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let env = MagEnv::from_artifacts(&dir)?;
    let arch = args.get("arch").unwrap_or("mpnn");
    let entry = env.manifest.model(arch)?.clone();
    // Fresh params (or checkpoint if provided).
    let params = match args.get("ckpt") {
        Some(p) => tfgnn::train::checkpoint::load(&PathBuf::from(p))?,
        None => {
            let hp = Hyperparams::from_manifest(&env.manifest)?;
            let trainer =
                tfgnn::train::Trainer::new(Runtime::cpu()?, &dir, &entry, RootTask::default(), hp)?;
            trainer.params_to_host()?
        }
    };
    let max_batch: usize = args.get_or("max-batch", env.batch_size)?;
    let n_requests: usize = args.get_or("requests", 64)?;
    obs_enable(args);
    let serve_cfg = introspection_cfg(
        args,
        tfgnn::serve::ServeConfig {
            max_batch,
            max_wait: std::time::Duration::from_millis(args.get_or("max-wait-ms", 5u64)?),
            sampler: tfgnn::sampler::SamplerConfig::with_threads(
                args.get_or("sampler-threads", 1usize)?,
            ),
            ..Default::default()
        },
        format!("serve-bench arch={arch} max_batch={max_batch}"),
    )?;
    let handle = tfgnn::serve::serve(
        &dir,
        &entry,
        params,
        Arc::clone(&env.sampler),
        env.pad.clone(),
        RootTask::default(),
        serve_cfg,
    )?;
    if let Some(addr) = handle.admin_addr() {
        println!("admin endpoint: http://{addr}/");
    }
    let seeds = env.dataset.papers_in_split(tfgnn::synth::mag::Split::Test);
    let t0 = std::time::Instant::now();
    let pending: Vec<_> =
        (0..n_requests).map(|i| handle.submit(seeds[i % seeds.len()])).collect();
    let mut latencies = Vec::new();
    for rx in pending {
        let resp = rx.recv().map_err(|_| tfgnn::Error::Runtime("server died".into()))??;
        latencies.push(resp.latency.as_secs_f64());
    }
    let total = t0.elapsed().as_secs_f64();
    let s = Summary::of(&latencies);
    println!(
        "served {n_requests} requests in {total:.2}s ({:.1} req/s), \
         latency p50 {:.1}ms p95 {:.1}ms p99.9 {:.1}ms",
        n_requests as f64 / total,
        s.p50 * 1e3,
        s.p95 * 1e3,
        s.p999 * 1e3
    );
    handle.shutdown();
    obs_finish(args)
}

/// `tfgnn loadgen`: closed-loop load generation against an in-process
/// multi-lane native task server on a synthetic MAG graph — no
/// artifacts needed. Response parity against a single-lane cache-off
/// oracle is gated *before* any timing; then client concurrency steps
/// through `--concurrency` and each level reports p50/p95/p99 latency,
/// throughput, and admission-control rejections. `--swap` hot-swaps to
/// freshly initialized weights between the parity gate and the load
/// phase to exercise the zero-downtime swap path under traffic.
fn loadgen(args: &Args) -> Result<()> {
    use tfgnn::sampler::inmem::InMemorySampler;
    use tfgnn::sampler::spec::mag_sampling_spec_scaled;
    use tfgnn::serve::loadgen::{parity_gate, LoadGenConfig};
    use tfgnn::serve::{serve_task, ServeConfig};
    use tfgnn::synth::mag::{generate, MagConfig, Split};
    use tfgnn::train::native::NativeModel;

    let papers: usize = args.get_or("papers", 800)?;
    let authors: usize = args.get_or("authors", 1_200)?;
    let hidden: usize = args.get_or("hidden", 8)?;
    let layers: usize = args.get_or("layers", 1)?;
    let arch = args.get("arch").unwrap_or("mpnn");
    let lanes: usize = args.get_or("lanes", 2)?;
    let queue: usize = args.get_or("queue", 1024)?;
    let cache: usize = args.get_or("cache", 0)?;
    let max_batch: usize = args.get_or("max-batch", 8)?;
    let requests: usize = args.get_or("requests", 32)?;
    let concurrency = args
        .get("concurrency")
        .unwrap_or("1,4,16")
        .split(',')
        .filter(|s| !s.trim().is_empty())
        .map(|s| {
            s.trim().parse::<usize>().map_err(|e| {
                tfgnn::Error::Pipeline(format!("bad --concurrency entry {s:?}: {e}"))
            })
        })
        .collect::<Result<Vec<usize>>>()?;

    obs_enable(args);
    let mag = MagConfig {
        num_papers: papers,
        num_authors: authors,
        num_institutions: 100,
        num_fields: 60,
        ..MagConfig::default()
    };
    let ds = generate(&mag);
    let seeds = ds.papers_in_split(Split::Train);
    let store = Arc::new(ds.store);
    let spec = mag_sampling_spec_scaled(&store.schema, 0.25)?;
    let sampler = Arc::new(InMemorySampler::new(store, spec, 42)?);
    let cfg = tfgnn::ops::model_ref::ModelConfig::for_mag(&mag, hidden, hidden, layers)
        .with_arch(arch);
    let swap_cfg = cfg.clone();
    let task = tfgnn::tasks::build(&cfg)?;
    let model = Arc::new(NativeModel::init(cfg, 7)?);

    let serve_cfg = introspection_cfg(
        args,
        ServeConfig {
            lanes,
            queue_capacity: queue,
            cache_capacity: cache,
            max_batch,
            ..ServeConfig::default()
        },
        format!("loadgen arch={arch} lanes={lanes} queue={queue} cache={cache}"),
    )?;
    let server = serve_task(
        Arc::clone(&model),
        Arc::clone(&sampler),
        Arc::clone(&task),
        serve_cfg,
    )?;
    if let Some(addr) = server.admin_addr() {
        println!("admin endpoint: http://{addr}/");
    }
    let oracle = serve_task(
        model,
        sampler,
        task,
        ServeConfig { lanes: 1, max_batch: 1, ..ServeConfig::default() },
    )?;
    let probe: Vec<Vec<u32>> =
        seeds.iter().take(64.min(seeds.len())).map(|&s| vec![s]).collect();
    parity_gate(&server, &oracle, &probe)?;
    oracle.shutdown();
    println!(
        "parity: {} probes bit-identical to the single-lane oracle (lanes={lanes} cache={cache})",
        probe.len()
    );

    if args.flag("swap") {
        let next = Arc::new(NativeModel::init(swap_cfg, 8)?);
        let generation = server.swap_model(next)?;
        println!("hot-swap: serving generation {generation}");
    }

    let lg = LoadGenConfig { concurrency, requests_per_client: requests };
    let report = tfgnn::serve::loadgen::run(&server, &probe, &lg)?;
    for level in &report.levels {
        println!(
            "conc {:>4}: {:>8.1} req/s | p50 {:.2}ms p95 {:.2}ms p99 {:.2}ms p99.9 {:.2}ms | \
             ok {} rejected {} deadline {} failed {}",
            level.concurrency,
            level.throughput,
            level.latency.p50 * 1e3,
            level.latency.p95 * 1e3,
            level.latency.p99 * 1e3,
            level.latency.p999 * 1e3,
            level.ok,
            level.rejected,
            level.deadline,
            level.failed,
        );
    }
    println!("saturation: {:.1} req/s", report.saturation_throughput());
    let snap = server.stats.snapshot();
    println!(
        "server: {} executed, {} batches, {} rejected, {} deadline-expired, \
         cache {} hit / {} miss / {} evicted, generation {}",
        snap.requests,
        snap.batches,
        snap.rejected,
        snap.deadline_expired,
        snap.cache_hits,
        snap.cache_misses,
        snap.cache_evictions,
        server.generation(),
    );

    if let Some(path) = args.get("json") {
        use tfgnn::util::json::{obj, Json};
        let levels: Vec<Json> = report
            .levels
            .iter()
            .map(|l| {
                obj(vec![
                    ("concurrency", Json::Int(l.concurrency as i64)),
                    ("throughput", Json::Num(l.throughput)),
                    ("p50", Json::Num(l.latency.p50)),
                    ("p95", Json::Num(l.latency.p95)),
                    ("p99", Json::Num(l.latency.p99)),
                    ("p999", Json::Num(l.latency.p999)),
                    ("ok", Json::Int(l.ok as i64)),
                    ("rejected", Json::Int(l.rejected as i64)),
                    ("deadline", Json::Int(l.deadline as i64)),
                    ("failed", Json::Int(l.failed as i64)),
                ])
            })
            .collect();
        let doc = obj(vec![
            ("saturation_throughput", Json::Num(report.saturation_throughput())),
            ("generation", Json::Int(server.generation() as i64)),
            ("levels", Json::Arr(levels)),
        ]);
        std::fs::write(path, doc.to_pretty())?;
        println!("wrote {path}");
    }
    // Keep the server (and its admin endpoint) alive so an external
    // scraper — CI curls /healthz and /metrics here — can observe it.
    let linger_ms: u64 = args.get_or("linger-ms", 0u64)?;
    if linger_ms > 0 {
        println!("lingering {linger_ms}ms before shutdown");
        std::thread::sleep(std::time::Duration::from_millis(linger_ms));
    }
    server.shutdown();
    obs_finish(args)
}
