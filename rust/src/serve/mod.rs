//! Online inference (paper §6.3).
//!
//! The paper's serving story: host the exported model behind a service;
//! the caller provides GraphTensors "perhaps via the in-memory
//! sampler". [`InferenceServer`] implements exactly that shape — a
//! vLLM-router-style dynamic batcher in front of a forward program:
//!
//! * clients submit root node ids ([`ServerHandle::submit`]);
//! * the batcher thread collects up to `max_batch` requests or until
//!   `max_wait` elapses, samples the whole wave of roots — **in
//!   parallel** over the server's sampling pool when
//!   [`ServeConfig::sampler`] asks for threads — and runs one forward
//!   execution;
//! * each request gets back its logits row, predicted class, and
//!   timing (queue + batch + execute breakdown for the benches).
//!
//! The batcher loop is generic over the executor, with two backends:
//! [`serve`] runs the AOT `forward` program on PJRT (merge + pad to the
//! static shape first), [`serve_native`] runs the pure-Rust
//! [`NativeModel`] forward per sampled subgraph — no padding, no
//! artifacts, fully offline. [`serve_task`] generalizes the native
//! backend across the task subsystem: requests are *seed lists*
//! (`[root]` for root tasks, `[source, target]` for link prediction)
//! and responses are task-shaped ([`crate::tasks::TaskOutput`] —
//! logits, a pair's link score, or a regression value).
//!
//! Shutdown contract: dropping the client side stops *accepting*
//! requests, but the batcher drains every already-submitted request
//! before exiting — no response is silently dropped (regression-tested
//! below).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::graph::pad::{fit_or_skip, PadSpec};
use crate::runtime::batch::{build_batch, is_batch_slot, RootTask};
use crate::runtime::manifest::ModelEntry;
use crate::runtime::{host_to_literal, literal_to_host, HostTensor, Program, Runtime};
use crate::sampler::inmem::InMemorySampler;
use crate::sampler::SamplerConfig;
use crate::train::native::NativeModel;
use crate::util::threadpool::ThreadPool;
use crate::{Error, Result};

/// A completed prediction.
#[derive(Debug, Clone)]
pub struct Response {
    pub seed: u32,
    pub predicted: usize,
    pub logits: Vec<f32>,
    /// Time from submit to response.
    pub latency: Duration,
    /// Requests in the same executed batch.
    pub batch_size: usize,
}

struct Request {
    seed: u32,
    submitted: Instant,
    reply: Sender<Result<Response>>,
}

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Max roots per forward execution (≤ the model's component cap - 1).
    pub max_batch: usize,
    /// Max time the batcher waits to fill a batch.
    pub max_wait: Duration,
    /// Sampling-stage knobs: with `threads > 1` the batcher samples a
    /// whole wave of roots concurrently on a pool it owns (spawned once
    /// at startup), before padding. Results are bit-for-bit those of
    /// serial sampling.
    pub sampler: SamplerConfig,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            sampler: SamplerConfig::default(),
        }
    }
}

/// Aggregate server counters.
#[derive(Debug, Default)]
pub struct ServeStats {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    /// Waves whose executor failed — every request in the wave got an
    /// error reply. On the AOT backend the usual cause is a wave
    /// exceeding the pad caps; the native backend never pads, so here
    /// it means a sampling or forward error.
    pub failed_batches: AtomicU64,
}

/// Client handle: submit requests, then `shutdown()`.
pub struct ServerHandle {
    tx: Option<Sender<Request>>,
    worker: Option<std::thread::JoinHandle<()>>,
    pub stats: Arc<ServeStats>,
}

impl ServerHandle {
    /// Submit a request; returns the channel the response arrives on.
    /// If the batcher is gone the reply sender is dropped with the
    /// request, so the caller's `recv` fails instead of panicking here.
    pub fn submit(&self, seed: u32) -> Receiver<Result<Response>> {
        let (reply_tx, reply_rx) = channel();
        let req = Request { seed, submitted: Instant::now(), reply: reply_tx };
        if let Some(tx) = self.tx.as_ref() {
            let _ = tx.send(req);
        }
        reply_rx
    }

    /// Convenience: submit and wait.
    pub fn predict(&self, seed: u32) -> Result<Response> {
        self.submit(seed)
            .recv()
            .map_err(|_| Error::Runtime("server dropped request".into()))?
    }

    /// Stop accepting requests and join the worker. Requests submitted
    /// before the call are still executed and answered (the batcher
    /// drains its queue before exiting).
    pub fn shutdown(mut self) {
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// The dynamic batcher: collect a wave (first request blocks, then fill
/// until `max_batch` or `max_wait`), execute it, fan the logits rows
/// back out to the requesters.
///
/// `exec` maps an ordered wave of seeds to `(flat logits, classes)` —
/// the one backend-specific step. Draining guarantee: `rx.recv()`
/// keeps returning buffered requests after every sender is dropped, so
/// shutdown only terminates the loop once the queue is empty.
fn batcher_loop<E>(
    rx: Receiver<Request>,
    max_batch: usize,
    max_wait: Duration,
    stats: Arc<ServeStats>,
    mut exec: E,
) where
    E: FnMut(&[u32]) -> Result<(Vec<f32>, usize)>,
{
    loop {
        // Block for the first request of a batch.
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return, // all senders gone AND queue empty: shutdown
        };
        let mut wave = vec![first];
        let deadline = Instant::now() + max_wait;
        while wave.len() < max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => wave.push(r),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        stats.requests.fetch_add(wave.len() as u64, Ordering::Relaxed);
        stats.batches.fetch_add(1, Ordering::Relaxed);
        let batch_size = wave.len();
        let seeds: Vec<u32> = wave.iter().map(|r| r.seed).collect();
        match exec(&seeds) {
            Ok((flat, classes)) => {
                for (k, req) in wave.into_iter().enumerate() {
                    let row = flat[k * classes..(k + 1) * classes].to_vec();
                    let predicted = row
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.total_cmp(b.1))
                        .map(|(i, _)| i)
                        .unwrap_or(0);
                    let resp = Response {
                        seed: req.seed,
                        predicted,
                        logits: row,
                        latency: req.submitted.elapsed(),
                        batch_size,
                    };
                    let _ = req.reply.send(Ok(resp));
                }
            }
            Err(e) => {
                stats.failed_batches.fetch_add(1, Ordering::Relaxed);
                let msg = e.to_string();
                for req in wave {
                    let _ = req.reply.send(Err(Error::Runtime(msg.clone())));
                }
            }
        }
    }
}

/// Build and start the AOT server.
///
/// PJRT handles are not `Send`, so the worker thread constructs its own
/// client, compiles `forward`, and uploads the params itself; this
/// function only passes plain data (paths, specs, host tensors) across
/// the thread boundary and waits for the worker's startup report.
pub fn serve(
    artifacts_dir: &std::path::Path,
    entry: &ModelEntry,
    params: Vec<(String, HostTensor)>,
    sampler: Arc<InMemorySampler>,
    pad: PadSpec,
    task: RootTask,
    cfg: ServeConfig,
) -> Result<ServerHandle> {
    let forward_spec = entry.program("forward")?.clone();
    let dir = artifacts_dir.to_path_buf();
    let stats = Arc::new(ServeStats::default());
    let (tx, rx) = channel::<Request>();
    let (ready_tx, ready_rx) = channel::<Result<()>>();
    let stats_w = Arc::clone(&stats);
    let max_batch = cfg.max_batch;
    let max_wait = cfg.max_wait;
    let sampler_cfg = cfg.sampler.clone();
    let worker = std::thread::Builder::new()
        .name("tfgnn-serve".into())
        .spawn(move || {
            // Build the PJRT world inside the thread (handles are !Send).
            let setup = (|| -> Result<(Runtime, Program, Vec<xla::Literal>)> {
                let rt = Runtime::cpu()?;
                let forward = rt.load_program(&dir, &forward_spec)?;
                // Forward may have a pruned signature (dead params
                // dropped by jax); resolve each param slot by name from
                // the full checkpoint/trainer param list.
                let by_name: std::collections::BTreeMap<&str, &HostTensor> =
                    params.iter().map(|(n, t)| (n.as_str(), t)).collect();
                let mut param_lits = Vec::new();
                for spec in &forward.spec.inputs {
                    if !spec.name.starts_with("param.") {
                        continue;
                    }
                    let t = by_name.get(spec.name.as_str()).ok_or_else(|| {
                        Error::Runtime(format!("server params missing slot {}", spec.name))
                    })?;
                    if !t.matches(spec) {
                        return Err(Error::Runtime(format!(
                            "param {} does not match forward slot shape",
                            spec.name
                        )));
                    }
                    param_lits.push(host_to_literal(t)?);
                }
                Ok((rt, forward, param_lits))
            })();
            match setup {
                Ok((rt, forward, param_bufs)) => {
                    let _ = ready_tx.send(Ok(()));
                    // The sampling pool outlives every wave: spawn once.
                    let pool = if sampler_cfg.parallel() {
                        Some(ThreadPool::new(sampler_cfg.threads))
                    } else {
                        None
                    };
                    batcher_loop(rx, max_batch, max_wait, stats_w, move |seeds| {
                        execute_wave(
                            &rt,
                            &forward,
                            &param_bufs,
                            &sampler,
                            pool.as_ref(),
                            &pad,
                            &task,
                            seeds,
                        )
                    });
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                }
            }
        })?;
    ready_rx
        .recv()
        .map_err(|_| Error::Runtime("server thread died during startup".into()))??;
    Ok(ServerHandle { tx: Some(tx), worker: Some(worker), stats })
}

/// Start a server over the pure-Rust native model — no AOT artifacts,
/// no PJRT, no padding: each sampled subgraph runs the fused forward
/// directly and contributes its root's logits row.
///
/// The model config is re-checked through the static analyzer
/// ([`crate::analysis::check_model`]) before the batcher spawns, so a
/// bad config is rejected with the same `TFGNN0xx` diagnostics the
/// `tfgnn check` CLI prints.
pub fn serve_native(
    model: Arc<NativeModel>,
    sampler: Arc<InMemorySampler>,
    task: RootTask,
    cfg: ServeConfig,
) -> Result<ServerHandle> {
    crate::analysis::check_model(&model.cfg).into_result()?;
    let stats = Arc::new(ServeStats::default());
    let (tx, rx) = channel::<Request>();
    let stats_w = Arc::clone(&stats);
    let worker = std::thread::Builder::new()
        .name("tfgnn-serve-native".into())
        .spawn(move || {
            let pool = if cfg.sampler.parallel() {
                Some(ThreadPool::new(cfg.sampler.threads))
            } else {
                None
            };
            let num_classes = model.cfg.num_classes;
            batcher_loop(rx, cfg.max_batch, cfg.max_wait, stats_w, move |seeds| {
                let graphs = match &pool {
                    Some(p) => sampler.sample_batch_with_pool(seeds, p)?,
                    None => seeds
                        .iter()
                        .map(|&s| sampler.sample(s))
                        .collect::<Result<Vec<_>>>()?,
                };
                let mut flat = Vec::with_capacity(seeds.len() * num_classes);
                for g in &graphs {
                    let logits = model.forward_logits(g, &task.root_set, &[0])?;
                    flat.extend_from_slice(&logits.data);
                }
                Ok((flat, num_classes))
            });
        })?;
    Ok(ServerHandle { tx: Some(tx), worker: Some(worker), stats })
}

/// A completed task-shaped prediction (see [`serve_task`]).
#[derive(Debug, Clone)]
pub struct TaskResponse {
    /// The request's seed list (`[root]` for root tasks, `[source,
    /// target]` for link prediction).
    pub seeds: Vec<u32>,
    pub output: crate::tasks::TaskOutput,
    /// Time from submit to response.
    pub latency: Duration,
    /// Requests in the same executed batch.
    pub batch_size: usize,
}

struct TaskRequest {
    seeds: Vec<u32>,
    submitted: Instant,
    reply: Sender<Result<TaskResponse>>,
}

/// Client handle for a task server: submit seed lists, then
/// `shutdown()`. Same draining contract as [`ServerHandle`].
pub struct TaskServerHandle {
    tx: Option<Sender<TaskRequest>>,
    worker: Option<std::thread::JoinHandle<()>>,
    pub stats: Arc<ServeStats>,
}

impl TaskServerHandle {
    /// Submit a request; returns the channel the response arrives on.
    /// If the batcher is gone the reply sender is dropped with the
    /// request, so the caller's `recv` fails instead of panicking here.
    pub fn submit(&self, seeds: Vec<u32>) -> Receiver<Result<TaskResponse>> {
        let (reply_tx, reply_rx) = channel();
        let req = TaskRequest { seeds, submitted: Instant::now(), reply: reply_tx };
        if let Some(tx) = self.tx.as_ref() {
            let _ = tx.send(req);
        }
        reply_rx
    }

    /// Convenience: submit and wait.
    pub fn predict(&self, seeds: &[u32]) -> Result<TaskResponse> {
        self.submit(seeds.to_vec())
            .recv()
            .map_err(|_| Error::Runtime("server dropped request".into()))?
    }

    /// Stop accepting requests and join the worker; already-submitted
    /// requests are still answered.
    pub fn shutdown(mut self) {
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for TaskServerHandle {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// Start a task-shaped native server: each request names a seed list,
/// the batcher samples the wave's subgraphs (in parallel over the
/// sampling pool when configured) and the [`Task`](crate::tasks::Task)
/// maps each to its response — classification logits, a pair's link
/// score, or a regression value. Errors are per-request: one bad pair
/// does not fail its wave-mates (a wave with any error still counts
/// one `failed_batches`).
///
/// Like [`serve_native`], the model config is gated through
/// [`crate::analysis::check_model`] before anything spawns.
pub fn serve_task(
    model: Arc<NativeModel>,
    sampler: Arc<InMemorySampler>,
    task: Arc<dyn crate::tasks::Task>,
    cfg: ServeConfig,
) -> Result<TaskServerHandle> {
    crate::analysis::check_model(&model.cfg).into_result()?;
    let stats = Arc::new(ServeStats::default());
    let (tx, rx) = channel::<TaskRequest>();
    let stats_w = Arc::clone(&stats);
    let worker = std::thread::Builder::new()
        .name("tfgnn-serve-task".into())
        .spawn(move || {
            let pool = if cfg.sampler.parallel() {
                Some(ThreadPool::new(cfg.sampler.threads))
            } else {
                None
            };
            loop {
                // Block for the first request of a wave.
                let first = match rx.recv() {
                    Ok(r) => r,
                    Err(_) => return, // all senders gone AND queue empty
                };
                let mut wave = vec![first];
                let deadline = Instant::now() + cfg.max_wait;
                while wave.len() < cfg.max_batch {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match rx.recv_timeout(deadline - now) {
                        Ok(r) => wave.push(r),
                        Err(_) => break,
                    }
                }
                stats_w.requests.fetch_add(wave.len() as u64, Ordering::Relaxed);
                stats_w.batches.fetch_add(1, Ordering::Relaxed);
                let batch_size = wave.len();
                // Sample every request's subgraph — fanned out over the
                // pool when configured — then run the task's readout.
                let seed_lists: Vec<Vec<u32>> = wave.iter().map(|r| r.seeds.clone()).collect();
                let graphs: Vec<Result<crate::graph::GraphTensor>> = match &pool {
                    Some(p) => {
                        let s = Arc::clone(&sampler);
                        p.map(seed_lists, move |seeds| s.sample_seeds(&seeds))
                    }
                    None => seed_lists.iter().map(|s| sampler.sample_seeds(s)).collect(),
                };
                let mut any_failed = false;
                for (req, g) in wave.into_iter().zip(graphs) {
                    let out = g.and_then(|g| task.infer(&model, &g));
                    match out {
                        Ok(output) => {
                            let _ = req.reply.send(Ok(TaskResponse {
                                seeds: req.seeds,
                                output,
                                latency: req.submitted.elapsed(),
                                batch_size,
                            }));
                        }
                        Err(e) => {
                            any_failed = true;
                            let _ = req.reply.send(Err(Error::Runtime(e.to_string())));
                        }
                    }
                }
                if any_failed {
                    stats_w.failed_batches.fetch_add(1, Ordering::Relaxed);
                }
            }
        })?;
    Ok(TaskServerHandle { tx: Some(tx), worker: Some(worker), stats })
}

/// Sample, merge, pad, execute one wave on the AOT program; returns
/// (flat logits, classes).
#[allow(clippy::too_many_arguments)]
fn execute_wave(
    rt: &Runtime,
    forward: &Program,
    param_bufs: &[xla::Literal],
    sampler: &InMemorySampler,
    pool: Option<&ThreadPool>,
    pad: &PadSpec,
    task: &RootTask,
    seeds: &[u32],
) -> Result<(Vec<f32>, usize)> {
    // The whole wave of roots samples as one batch — fanned out over
    // the sampling pool when configured, serially otherwise; either
    // way the subgraphs are identical, in request order.
    let graphs = match pool {
        Some(p) => sampler.sample_batch_with_pool(seeds, p)?,
        None => seeds
            .iter()
            .map(|&s| sampler.sample(s))
            .collect::<Result<Vec<_>>>()?,
    };
    let merged = crate::graph::batch::merge(&graphs)?;
    let padded = fit_or_skip(&merged, pad)
        .ok_or_else(|| Error::Runtime("request wave exceeds pad caps".into()))?;
    let inputs = &forward.spec.inputs;
    let batch = build_batch(&padded, task, inputs)?;
    let mut batch_lits = Vec::with_capacity(batch.len());
    for (idx, t) in &batch {
        batch_lits.push((*idx, host_to_literal(t)?));
    }
    let _ = rt;
    let mut args: Vec<&xla::Literal> = Vec::with_capacity(inputs.len());
    let mut it = batch_lits.iter();
    for (i, spec) in inputs.iter().enumerate() {
        if i < param_bufs.len() {
            args.push(&param_bufs[i]);
        } else if is_batch_slot(&spec.name) {
            let (idx, lit) =
                it.next().ok_or_else(|| Error::Runtime("slots exhausted".into()))?;
            debug_assert_eq!(*idx, i);
            args.push(lit);
        } else {
            return Err(Error::Runtime(format!("unhandled forward slot {:?}", spec.name)));
        }
    }
    let outputs = forward.execute_literals(&args)?;
    let logits = literal_to_host(&outputs[0])?;
    let shape = logits.shape().to_vec();
    let HostTensor::F32(_, data) = logits else {
        return Err(Error::Runtime("logits not f32".into()));
    };
    Ok((data, shape[1]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::model_ref::ModelConfig;
    use crate::sampler::spec::mag_sampling_spec_scaled;
    use crate::synth::mag::{generate, MagConfig, Split};

    fn native_server_for(
        arch: &str,
        max_batch: usize,
        max_wait: Duration,
    ) -> (ServerHandle, Vec<u32>, usize) {
        let mag = MagConfig::tiny();
        let ds = generate(&mag);
        let seeds = ds.papers_in_split(Split::Train);
        let store = Arc::new(ds.store);
        let spec = mag_sampling_spec_scaled(&store.schema, 0.2).unwrap();
        let sampler = Arc::new(InMemorySampler::new(store, spec, 3).unwrap());
        let cfg = ModelConfig::for_mag(&mag, 8, 8, 1).with_arch(arch);
        let num_classes = cfg.num_classes;
        let model = Arc::new(NativeModel::init(cfg, 7).unwrap());
        let handle = serve_native(
            model,
            sampler,
            RootTask::default(),
            ServeConfig { max_batch, max_wait, sampler: SamplerConfig::default() },
        )
        .unwrap();
        (handle, seeds, num_classes)
    }

    fn native_server(max_batch: usize, max_wait: Duration) -> (ServerHandle, Vec<u32>, usize) {
        native_server_for("mpnn", max_batch, max_wait)
    }

    #[test]
    fn native_server_predicts() {
        let (handle, seeds, classes) = native_server(4, Duration::from_millis(2));
        for &s in seeds.iter().take(6) {
            let resp = handle.predict(s).unwrap();
            assert_eq!(resp.seed, s);
            assert_eq!(resp.logits.len(), classes);
            assert!(resp.predicted < classes);
            assert!(resp.logits.iter().all(|v| v.is_finite()));
        }
        assert!(handle.stats.requests.load(Ordering::Relaxed) >= 6);
        handle.shutdown();
    }

    /// `serve_native` hosts any built model, not just the mpnn: every
    /// convolution of the zoo serves predictions through the same
    /// batcher.
    #[test]
    fn native_server_hosts_the_whole_zoo() {
        for arch in ["gcn", "sage", "gatv2"] {
            let (handle, seeds, classes) =
                native_server_for(arch, 3, Duration::from_millis(2));
            for &s in seeds.iter().take(3) {
                let resp = handle.predict(s).unwrap();
                assert_eq!(resp.logits.len(), classes, "{arch}");
                assert!(resp.logits.iter().all(|v| v.is_finite()), "{arch}");
                assert!(resp.predicted < classes, "{arch}");
            }
            handle.shutdown();
        }
    }

    /// `serve_task` answers with task-shaped responses for all three
    /// objectives — classification logits, pair link scores, regression
    /// values — over the same batcher/sampler machinery.
    #[test]
    fn task_server_serves_all_three_tasks() {
        use crate::ops::model_ref::TaskConfig;
        use crate::synth::mag::edge_holdout;
        use crate::tasks::{self, TaskOutput};

        let mag = MagConfig::tiny();
        let ds = generate(&mag);
        let seeds = ds.papers_in_split(Split::Train);
        let holdout = edge_holdout(&ds, "cites", 0.2, 9).unwrap();
        let store = Arc::new(ds.store);
        let spec = mag_sampling_spec_scaled(&store.schema, 0.2).unwrap();
        let sampler = Arc::new(InMemorySampler::new(store, spec, 3).unwrap());
        let serve_cfg = || ServeConfig {
            max_batch: 3,
            max_wait: Duration::from_millis(2),
            sampler: SamplerConfig::default(),
        };

        // Root classification.
        let cfg = ModelConfig::for_mag(&mag, 8, 8, 1);
        let task = tasks::build(&cfg).unwrap();
        let model = Arc::new(NativeModel::init(cfg, 7).unwrap());
        let handle = serve_task(model, Arc::clone(&sampler), task, serve_cfg()).unwrap();
        let resp = handle.predict(&[seeds[0]]).unwrap();
        let TaskOutput::Classification { logits, predicted } = resp.output else {
            panic!("want classification output");
        };
        assert_eq!(logits.len(), mag.num_classes);
        assert!(predicted < mag.num_classes);
        handle.shutdown();

        // Link prediction (pair requests; sampler over the holdout
        // store so held-out edges stay unseen).
        let lp_store = Arc::new(holdout.store);
        let lp_spec = mag_sampling_spec_scaled(&lp_store.schema, 0.2).unwrap();
        let lp_sampler = Arc::new(InMemorySampler::new(lp_store, lp_spec, 3).unwrap());
        let cfg = ModelConfig::for_mag(&mag, 8, 8, 1).with_task(TaskConfig {
            kind: "link_prediction".into(),
            readout: "dot".into(),
            ..TaskConfig::default()
        });
        let task = tasks::build(&cfg).unwrap();
        let model = Arc::new(NativeModel::init(cfg, 7).unwrap());
        let handle = serve_task(model, lp_sampler, task, serve_cfg()).unwrap();
        let (u, v) = holdout.test[0];
        let resp = handle.predict(&[u, v]).unwrap();
        let TaskOutput::LinkScore { score } = resp.output else {
            panic!("want link score output");
        };
        assert!(score.is_finite());
        assert_eq!(resp.seeds, vec![u, v]);
        // A degenerate pair fails its request, not the server.
        assert!(handle.predict(&[u, u]).is_err());
        let again = handle.predict(&[u, v]).unwrap();
        let TaskOutput::LinkScore { score: s2 } = again.output else { panic!() };
        assert_eq!(s2.to_bits(), score.to_bits(), "deterministic rescoring");
        assert!(handle.stats.failed_batches.load(Ordering::Relaxed) >= 1);
        handle.shutdown();

        // Graph regression.
        let cfg = ModelConfig::for_mag(&mag, 8, 8, 1).with_task(TaskConfig {
            kind: "graph_regression".into(),
            target_shift: 2010.0,
            target_scale: 0.1,
            ..TaskConfig::default()
        });
        let task = tasks::build(&cfg).unwrap();
        let model = Arc::new(NativeModel::init(cfg, 7).unwrap());
        let handle = serve_task(model, sampler, task, serve_cfg()).unwrap();
        let resp = handle.predict(&[seeds[1]]).unwrap();
        let TaskOutput::Regression { value } = resp.output else {
            panic!("want regression output");
        };
        assert!(value.is_finite());
        handle.shutdown();
    }

    /// Regression: shutting the server down must NOT drop requests that
    /// were already submitted — the batcher drains its queue before the
    /// worker exits, so every pending reply channel gets a response.
    #[test]
    fn shutdown_drains_already_submitted_requests() {
        // A long max_wait so most requests are still queued (or mid
        // wave-collection) when shutdown drops the client sender.
        let (handle, seeds, classes) = native_server(2, Duration::from_millis(50));
        let n = 16usize;
        let pending: Vec<_> =
            (0..n).map(|i| handle.submit(seeds[i % seeds.len()])).collect();
        // Drop the sender and join the batcher immediately.
        handle.shutdown();
        // Every submitted request must still have been answered.
        for (i, rx) in pending.into_iter().enumerate() {
            let resp = rx
                .recv()
                .unwrap_or_else(|_| panic!("request {i} dropped at shutdown"))
                .unwrap_or_else(|e| panic!("request {i} failed: {e}"));
            assert_eq!(resp.logits.len(), classes);
        }
    }
}
