//! Online inference (paper §6.3) — the production serving subsystem.
//!
//! The paper's serving story is models in front of heavy traffic; this
//! module implements the full request path as four cooperating pieces:
//!
//! * **Admission + lanes** ([`batcher`]) — clients submit into a
//!   *bounded* MPMC queue; a full queue rejects the request immediately
//!   with [`Error::Overloaded`] (admission control, not an unbounded
//!   backlog). [`ServeConfig::lanes`] batcher threads pull from the
//!   shared queue, each gathering up to `max_batch` requests (waiting
//!   at most `max_wait` for stragglers) and executing the wave.
//! * **Subgraph cache** ([`cache`]) — the task server can memoize
//!   sampled subgraphs keyed by the request's seed list
//!   ([`ServeConfig::cache_capacity`]). The sampler is a pure function
//!   of `(store, spec, plan_seed, seeds)`, so a hit is bit-identical to
//!   a re-sample; hit/miss/eviction counters land in [`ServeStats`].
//! * **Hot-swap** ([`swap`]) — the native model lives behind an
//!   atomically swappable [`swap::ModelSlot`]. Each lane snapshots the
//!   model `Arc` once per wave, so a batch never mixes parameters from
//!   two models; responses carry the snapshot's `generation` so
//!   clients (and the concurrency tests) can tell which weights
//!   answered.
//! * **Load generator** ([`loadgen`]) — a closed-loop driver that
//!   steps client concurrency against a running server and summarizes
//!   p50/p95/p99 latency, saturation throughput and rejection counts
//!   (the `benches/serving.rs` + `tfgnn loadgen` entry points).
//!
//! Three server constructors share the machinery: [`serve`] runs the
//! AOT `forward` program on PJRT (single execution lane — PJRT handles
//! are not `Send` — but the same bounded-admission front door),
//! [`serve_native`] runs the pure-Rust [`NativeModel`] forward per
//! sampled subgraph across N lanes, and [`serve_task`] generalizes the
//! native backend across the task subsystem (requests are *seed
//! lists*, responses are [`crate::tasks::TaskOutput`]).
//!
//! Contracts, pinned by `tests/serve_concurrency.rs` at 1/2/8 lanes
//! (and under the nightly TSan lane):
//!
//! * per-request structured errors — one bad request never fails its
//!   wave-mates on the task server, and an executor error replies to
//!   every request in the wave;
//! * drain-on-shutdown — [`ServerHandle::shutdown`] stops *admissions*
//!   but every already-admitted request is still answered; submitting
//!   after shutdown returns a structured error instead of hanging;
//! * determinism — each individual response is bit-identical at any
//!   lane count, with caching on or off.

pub mod batcher;
pub mod cache;
pub mod loadgen;
pub mod swap;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::graph::pad::{fit_or_skip, PadSpec};
use crate::graph::GraphTensor;
use crate::runtime::batch::{build_batch, is_batch_slot, RootTask};
use crate::runtime::manifest::ModelEntry;
use crate::runtime::{host_to_literal, literal_to_host, HostTensor, Program, Runtime};
use crate::sampler::inmem::InMemorySampler;
use crate::sampler::SamplerConfig;
use crate::train::native::NativeModel;
use crate::util::threadpool::ThreadPool;
use crate::{Error, Result};

use batcher::{lane_loop, BoundedQueue, PushError};
use cache::LruCache;
use swap::ModelSlot;

/// A completed prediction.
#[derive(Debug, Clone)]
pub struct Response {
    pub seed: u32,
    pub predicted: usize,
    pub logits: Vec<f32>,
    /// Time from submit to response.
    pub latency: Duration,
    /// Requests in the same executed batch.
    pub batch_size: usize,
    /// Which model answered: the serving slot's swap generation
    /// (1 until the first hot-swap; always 1 on the AOT backend).
    pub generation: u64,
}

struct Request {
    seed: u32,
    submitted: Instant,
    reply: Sender<Result<Response>>,
}

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Max roots per forward execution (≤ the model's component cap - 1).
    pub max_batch: usize,
    /// Max time a lane waits to fill a batch.
    pub max_wait: Duration,
    /// Concurrent batcher lanes pulling from the shared queue. The AOT
    /// backend always runs one execution lane (PJRT handles are not
    /// `Send`); native backends spawn exactly this many.
    pub lanes: usize,
    /// Admission-control bound: requests beyond this backlog are
    /// rejected with [`Error::Overloaded`] instead of queued.
    pub queue_capacity: usize,
    /// Seed-keyed LRU subgraph cache entries on the task server
    /// (0 disables caching). Hits skip re-sampling; responses are
    /// bit-identical either way because sampling is deterministic.
    pub cache_capacity: usize,
    /// Synthetic extra latency added to every executed wave. Zero in
    /// production; the overload tests and backpressure experiments use
    /// it to make saturation deterministic.
    pub wave_delay: Duration,
    /// Sampling-stage knobs: with `threads > 1` each lane samples its
    /// wave concurrently on a pool it owns (spawned once at startup).
    /// Results are bit-for-bit those of serial sampling.
    pub sampler: SamplerConfig,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            lanes: 1,
            queue_capacity: 1024,
            cache_capacity: 0,
            wave_delay: Duration::ZERO,
            sampler: SamplerConfig::default(),
        }
    }
}

/// Aggregate server counters.
///
/// The fields are private atomics; readers take a coherent-enough
/// [`snapshot`](Self::snapshot) (each field is an independent relaxed
/// load — fine for monitoring, and the tests only assert after
/// quiescence). Every mutation also mirrors into the process-global
/// [`crate::obs::metrics`] registry under the `serve_*` names, so
/// `tfgnn stats` and the Prometheus exporter see the same counts
/// without a second bookkeeping path in the hot loop.
#[derive(Debug, Default)]
pub struct ServeStats {
    requests: AtomicU64,
    batches: AtomicU64,
    failed_batches: AtomicU64,
    rejected: AtomicU64,
    cache_hits: AtomicU64,
    cache_misses: AtomicU64,
    cache_evictions: AtomicU64,
    swaps: AtomicU64,
}

/// Plain-data view of [`ServeStats`] at one point in time.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServeStatsSnapshot {
    /// Requests pulled into an executed wave (rejections not included).
    pub requests: u64,
    /// Waves executed by batcher lanes.
    pub batches: u64,
    /// Waves whose executor failed — every request in the wave got an
    /// error reply. On the AOT backend the usual cause is a wave
    /// exceeding the pad caps; the native backend never pads, so here
    /// it means a sampling or forward error.
    pub failed_batches: u64,
    /// Requests rejected by admission control ([`Error::Overloaded`]).
    pub rejected: u64,
    /// Task-server subgraph cache hits (0 when the cache is disabled).
    pub cache_hits: u64,
    /// Task-server subgraph cache misses (0 when the cache is disabled).
    pub cache_misses: u64,
    /// Entries evicted from the subgraph cache by capacity pressure.
    pub cache_evictions: u64,
    /// Successful model hot-swaps.
    pub swaps: u64,
}

impl ServeStatsSnapshot {
    /// Total subgraph-cache lookups; by construction every lookup is
    /// exactly one hit or one miss, so `hits + misses` is an identity,
    /// not an approximation.
    pub fn cache_lookups(&self) -> u64 {
        self.cache_hits + self.cache_misses
    }
}

impl ServeStats {
    /// Read every counter (relaxed loads).
    pub fn snapshot(&self) -> ServeStatsSnapshot {
        ServeStatsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            failed_batches: self.failed_batches.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            cache_hits: self.cache_hits.load(Ordering::Relaxed),
            cache_misses: self.cache_misses.load(Ordering::Relaxed),
            cache_evictions: self.cache_evictions.load(Ordering::Relaxed),
            swaps: self.swaps.load(Ordering::Relaxed),
        }
    }

    fn wave_start(&self, size: u64) {
        self.requests.fetch_add(size, Ordering::Relaxed);
        self.batches.fetch_add(1, Ordering::Relaxed);
        crate::obs_counter!(crate::obs::metrics::names::SERVE_REQUESTS).add(size);
        crate::obs_counter!(crate::obs::metrics::names::SERVE_BATCHES).inc();
        if crate::obs::recording() {
            crate::obs_histogram!(crate::obs::metrics::names::SERVE_WAVE_SIZE)
                .record(size as f64);
        }
    }

    fn wave_failed(&self) {
        self.failed_batches.fetch_add(1, Ordering::Relaxed);
        crate::obs_counter!(crate::obs::metrics::names::SERVE_FAILED_BATCHES).inc();
    }

    fn rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
        crate::obs_counter!(crate::obs::metrics::names::SERVE_REJECTED).inc();
    }

    fn cache_hit(&self) {
        self.cache_hits.fetch_add(1, Ordering::Relaxed);
        crate::obs_counter!(crate::obs::metrics::names::SERVE_CACHE_HITS).inc();
    }

    fn cache_miss(&self) {
        self.cache_misses.fetch_add(1, Ordering::Relaxed);
        crate::obs_counter!(crate::obs::metrics::names::SERVE_CACHE_MISSES).inc();
    }

    fn cache_evicted(&self, n: u64) {
        if n > 0 {
            self.cache_evictions.fetch_add(n, Ordering::Relaxed);
            crate::obs_counter!(crate::obs::metrics::names::SERVE_CACHE_EVICTIONS).add(n);
        }
    }

    fn swapped(&self, generation: u64) {
        self.swaps.fetch_add(1, Ordering::Relaxed);
        crate::obs_counter!(crate::obs::metrics::names::SERVE_SWAPS).inc();
        crate::obs_gauge!(crate::obs::metrics::names::SERVE_GENERATION)
            .set(i64::try_from(generation).unwrap_or(i64::MAX));
    }
}

/// Queue-depth gauge: +1 per admitted request, -1 per reply. The lanes
/// drain the queue on shutdown, so the gauge returns to zero for every
/// request that was ever admitted.
fn queue_depth() -> &'static crate::obs::metrics::Gauge {
    crate::obs_gauge!(crate::obs::metrics::names::SERVE_QUEUE_DEPTH)
}

/// Client handle: submit requests, then [`shutdown`](Self::shutdown).
///
/// The handle is `Sync` — closed-loop clients share one handle across
/// threads (`std::thread::scope`) — and dropping it shuts the server
/// down with the same draining contract as an explicit `shutdown()`.
pub struct ServerHandle {
    queue: Arc<BoundedQueue<Request>>,
    lanes: Mutex<Vec<std::thread::JoinHandle<()>>>,
    pub stats: Arc<ServeStats>,
    /// The swappable model slot (`None` on the AOT backend, whose
    /// params are uploaded to the device once at startup).
    slot: Option<Arc<ModelSlot>>,
}

impl ServerHandle {
    /// Submit a request; returns the channel the response arrives on.
    /// Admission control replies immediately with
    /// [`Error::Overloaded`] when the queue is full, and with a
    /// structured runtime error after shutdown — the caller's `recv`
    /// always gets an answer, it never hangs on a dead channel.
    pub fn submit(&self, seed: u32) -> Receiver<Result<Response>> {
        let (reply_tx, reply_rx) = channel();
        let req = Request { seed, submitted: Instant::now(), reply: reply_tx };
        match self.queue.push(req) {
            Ok(()) => queue_depth().add(1),
            Err(PushError::Full(req)) => {
                self.stats.rejected();
                let _ = req.reply.send(Err(Error::Overloaded(format!(
                    "serving queue full ({} pending); retry with backoff",
                    self.queue.capacity()
                ))));
            }
            Err(PushError::Closed(req)) => {
                let _ = req
                    .reply
                    .send(Err(Error::Runtime("server is shut down".into())));
            }
        }
        reply_rx
    }

    /// Convenience: submit and wait.
    pub fn predict(&self, seed: u32) -> Result<Response> {
        self.submit(seed)
            .recv()
            .map_err(|_| Error::Runtime("server dropped request".into()))?
    }

    /// Stop accepting requests and join the lanes. Requests admitted
    /// before the call are still executed and answered (lanes drain
    /// the queue before exiting). Idempotent; later `submit`s get a
    /// structured error.
    pub fn shutdown(&self) {
        close_and_join(&self.queue, &self.lanes);
    }

    /// Hot-swap the served model (native backends only). In-flight
    /// waves finish on the old weights; later waves pick up the new
    /// ones — no batch ever mixes the two. Returns the new generation.
    pub fn swap_model(&self, model: Arc<NativeModel>) -> Result<u64> {
        let slot = self.require_slot()?;
        let generation = slot.swap_model(model)?;
        self.stats.swapped(generation);
        Ok(generation)
    }

    /// Hot-swap to the weights in a checkpoint file (native only).
    pub fn swap_checkpoint(&self, path: &std::path::Path) -> Result<u64> {
        let slot = self.require_slot()?;
        let generation = slot.swap_checkpoint(path)?;
        self.stats.swapped(generation);
        Ok(generation)
    }

    /// Current model generation (1 until the first swap; the AOT
    /// backend is pinned at 1).
    pub fn generation(&self) -> u64 {
        self.slot.as_ref().map(|s| s.generation()).unwrap_or(1)
    }

    fn require_slot(&self) -> Result<&Arc<ModelSlot>> {
        self.slot.as_ref().ok_or_else(|| {
            Error::Runtime(
                "hot-swap is only supported on native servers (AOT params \
                 are uploaded to the device at startup)"
                    .into(),
            )
        })
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        close_and_join(&self.queue, &self.lanes);
    }
}

/// Shared shutdown path: close admissions, then join every lane
/// exactly once (the vec is drained under its lock, so concurrent
/// `shutdown()` + `Drop` cannot double-join).
fn close_and_join<T>(
    queue: &BoundedQueue<T>,
    lanes: &Mutex<Vec<std::thread::JoinHandle<()>>>,
) {
    queue.close();
    let mut joined = match lanes.lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    };
    for h in joined.drain(..) {
        let _ = h.join();
    }
}

/// Fan one executed logits wave back out to its requesters (or fan the
/// wave's error to every request), updating failure counters.
fn reply_logits_wave(
    wave: Vec<Request>,
    result: Result<(Vec<f32>, usize)>,
    generation: u64,
    stats: &ServeStats,
) {
    let batch_size = wave.len();
    match result {
        Ok((flat, classes)) => {
            let has_all_rows = flat.len() >= batch_size * classes && classes > 0;
            if !has_all_rows {
                queue_depth().sub(batch_size as i64);
                stats.wave_failed();
                let msg = format!(
                    "executor returned {} logits for {batch_size} requests x {classes} classes",
                    flat.len()
                );
                for req in wave {
                    let _ = req.reply.send(Err(Error::Runtime(msg.clone())));
                }
                return;
            }
            for (k, req) in wave.into_iter().enumerate() {
                let row = flat[k * classes..(k + 1) * classes].to_vec();
                let predicted = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(i, _)| i)
                    .unwrap_or(0);
                let resp = Response {
                    seed: req.seed,
                    predicted,
                    logits: row,
                    latency: req.submitted.elapsed(),
                    batch_size,
                    generation,
                };
                let _ = req.reply.send(Ok(resp));
            }
        }
        Err(e) => {
            stats.wave_failed();
            let msg = e.to_string();
            for req in wave {
                let _ = req.reply.send(Err(Error::Runtime(msg.clone())));
            }
        }
    }
    queue_depth().sub(batch_size as i64);
}

/// Build and start the AOT server.
///
/// PJRT handles are not `Send`, so the single execution lane constructs
/// its own client, compiles `forward`, and uploads the params itself;
/// this function only passes plain data (paths, specs, host tensors)
/// across the thread boundary and waits for the lane's startup report.
/// Admission control (bounded queue, `Error::Overloaded`) applies the
/// same as on the native backends; `cfg.lanes` is ignored.
pub fn serve(
    artifacts_dir: &std::path::Path,
    entry: &ModelEntry,
    params: Vec<(String, HostTensor)>,
    sampler: Arc<InMemorySampler>,
    pad: PadSpec,
    task: RootTask,
    cfg: ServeConfig,
) -> Result<ServerHandle> {
    let forward_spec = entry.program("forward")?.clone();
    let dir = artifacts_dir.to_path_buf();
    let stats = Arc::new(ServeStats::default());
    let queue: Arc<BoundedQueue<Request>> = Arc::new(BoundedQueue::new(cfg.queue_capacity));
    let (ready_tx, ready_rx) = channel::<Result<()>>();
    let stats_w = Arc::clone(&stats);
    let queue_w = Arc::clone(&queue);
    let max_batch = cfg.max_batch;
    let max_wait = cfg.max_wait;
    let wave_delay = cfg.wave_delay;
    let sampler_cfg = cfg.sampler.clone();
    let worker = std::thread::Builder::new()
        .name("tfgnn-serve".into())
        .spawn(move || {
            // Build the PJRT world inside the thread (handles are !Send).
            let setup = (|| -> Result<(Runtime, Program, Vec<xla::Literal>)> {
                let rt = Runtime::cpu()?;
                let forward = rt.load_program(&dir, &forward_spec)?;
                // Forward may have a pruned signature (dead params
                // dropped by jax); resolve each param slot by name from
                // the full checkpoint/trainer param list.
                let by_name: std::collections::BTreeMap<&str, &HostTensor> =
                    params.iter().map(|(n, t)| (n.as_str(), t)).collect();
                let mut param_lits = Vec::new();
                for spec in &forward.spec.inputs {
                    if !spec.name.starts_with("param.") {
                        continue;
                    }
                    let t = by_name.get(spec.name.as_str()).ok_or_else(|| {
                        Error::Runtime(format!("server params missing slot {}", spec.name))
                    })?;
                    if !t.matches(spec) {
                        return Err(Error::Runtime(format!(
                            "param {} does not match forward slot shape",
                            spec.name
                        )));
                    }
                    param_lits.push(host_to_literal(t)?);
                }
                Ok((rt, forward, param_lits))
            })();
            match setup {
                Ok((rt, forward, param_bufs)) => {
                    let _ = ready_tx.send(Ok(()));
                    // The sampling pool outlives every wave: spawn once.
                    let pool = if sampler_cfg.parallel() {
                        Some(ThreadPool::new(sampler_cfg.threads))
                    } else {
                        None
                    };
                    lane_loop(&queue_w, max_batch, max_wait, |wave| {
                        let _wave_span = crate::span!("serve/wave", size = wave.len());
                        let _wave_timer = crate::obs::timed(crate::obs_histogram!(
                            crate::obs::metrics::names::SERVE_WAVE_SECONDS
                        ));
                        stats_w.wave_start(wave.len() as u64);
                        if !wave_delay.is_zero() {
                            std::thread::sleep(wave_delay);
                        }
                        let seeds: Vec<u32> = wave.iter().map(|r| r.seed).collect();
                        let result = execute_wave(
                            &rt,
                            &forward,
                            &param_bufs,
                            &sampler,
                            pool.as_ref(),
                            &pad,
                            &task,
                            &seeds,
                        );
                        reply_logits_wave(wave, result, 1, &stats_w);
                    });
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                }
            }
        })?;
    ready_rx
        .recv()
        .map_err(|_| Error::Runtime("server thread died during startup".into()))??;
    Ok(ServerHandle { queue, lanes: Mutex::new(vec![worker]), stats, slot: None })
}

/// Start a server over the pure-Rust native model — no AOT artifacts,
/// no PJRT, no padding: each sampled subgraph runs the fused forward
/// directly and contributes its root's logits row. `cfg.lanes` batcher
/// threads pull from the shared bounded queue; each lane snapshots the
/// hot-swappable model once per wave.
///
/// The model config is re-checked through the static analyzer
/// ([`crate::analysis::check_model`]) before the lanes spawn, so a
/// bad config is rejected with the same `TFGNN0xx` diagnostics the
/// `tfgnn check` CLI prints.
pub fn serve_native(
    model: Arc<NativeModel>,
    sampler: Arc<InMemorySampler>,
    task: RootTask,
    cfg: ServeConfig,
) -> Result<ServerHandle> {
    crate::analysis::check_model(&model.cfg).into_result()?;
    let num_classes = model.cfg.num_classes;
    let stats = Arc::new(ServeStats::default());
    let queue: Arc<BoundedQueue<Request>> = Arc::new(BoundedQueue::new(cfg.queue_capacity));
    let slot = Arc::new(ModelSlot::new(model));
    let mut lanes = Vec::new();
    for lane in 0..cfg.lanes.max(1) {
        let queue = Arc::clone(&queue);
        let stats = Arc::clone(&stats);
        let slot = Arc::clone(&slot);
        let sampler = Arc::clone(&sampler);
        let task = task.clone();
        let sampler_cfg = cfg.sampler.clone();
        let (max_batch, max_wait, wave_delay) = (cfg.max_batch, cfg.max_wait, cfg.wave_delay);
        lanes.push(
            std::thread::Builder::new()
                .name(format!("tfgnn-serve-native-{lane}"))
                .spawn(move || {
                    let pool = if sampler_cfg.parallel() {
                        Some(ThreadPool::new(sampler_cfg.threads))
                    } else {
                        None
                    };
                    lane_loop(&queue, max_batch, max_wait, |wave| {
                        let _wave_span = crate::span!("serve/wave", size = wave.len());
                        let _wave_timer = crate::obs::timed(crate::obs_histogram!(
                            crate::obs::metrics::names::SERVE_WAVE_SECONDS
                        ));
                        stats.wave_start(wave.len() as u64);
                        if !wave_delay.is_zero() {
                            std::thread::sleep(wave_delay);
                        }
                        // One model snapshot for the whole wave: a batch
                        // never mixes params from two generations.
                        let vm = slot.load();
                        let seeds: Vec<u32> = wave.iter().map(|r| r.seed).collect();
                        let result = (|| -> Result<(Vec<f32>, usize)> {
                            let graphs = match &pool {
                                Some(p) => sampler.sample_batch_with_pool(&seeds, p)?,
                                None => seeds
                                    .iter()
                                    .map(|&s| sampler.sample(s))
                                    .collect::<Result<Vec<_>>>()?,
                            };
                            let mut flat = Vec::with_capacity(seeds.len() * num_classes);
                            for g in &graphs {
                                let logits =
                                    vm.model.forward_logits(g, &task.root_set, &[0])?;
                                flat.extend_from_slice(&logits.data);
                            }
                            Ok((flat, num_classes))
                        })();
                        reply_logits_wave(wave, result, vm.generation, &stats);
                    });
                })?,
        );
    }
    Ok(ServerHandle { queue, lanes: Mutex::new(lanes), stats, slot: Some(slot) })
}

/// A completed task-shaped prediction (see [`serve_task`]).
#[derive(Debug, Clone)]
pub struct TaskResponse {
    /// The request's seed list (`[root]` for root tasks, `[source,
    /// target]` for link prediction).
    pub seeds: Vec<u32>,
    pub output: crate::tasks::TaskOutput,
    /// Time from submit to response.
    pub latency: Duration,
    /// Requests in the same executed batch.
    pub batch_size: usize,
    /// Which model answered: the serving slot's swap generation
    /// (1 until the first hot-swap).
    pub generation: u64,
}

struct TaskRequest {
    seeds: Vec<u32>,
    submitted: Instant,
    reply: Sender<Result<TaskResponse>>,
}

/// Client handle for a task server: submit seed lists, then
/// [`shutdown`](Self::shutdown). Same admission, draining and hot-swap
/// contracts as [`ServerHandle`].
pub struct TaskServerHandle {
    queue: Arc<BoundedQueue<TaskRequest>>,
    lanes: Mutex<Vec<std::thread::JoinHandle<()>>>,
    pub stats: Arc<ServeStats>,
    slot: Arc<ModelSlot>,
}

impl TaskServerHandle {
    /// Submit a request; returns the channel the response arrives on.
    /// A full queue replies [`Error::Overloaded`] immediately; a
    /// shut-down server replies a structured runtime error — `recv`
    /// never hangs on a dead channel.
    pub fn submit(&self, seeds: Vec<u32>) -> Receiver<Result<TaskResponse>> {
        let (reply_tx, reply_rx) = channel();
        let req = TaskRequest { seeds, submitted: Instant::now(), reply: reply_tx };
        match self.queue.push(req) {
            Ok(()) => queue_depth().add(1),
            Err(PushError::Full(req)) => {
                self.stats.rejected();
                let _ = req.reply.send(Err(Error::Overloaded(format!(
                    "serving queue full ({} pending); retry with backoff",
                    self.queue.capacity()
                ))));
            }
            Err(PushError::Closed(req)) => {
                let _ = req
                    .reply
                    .send(Err(Error::Runtime("server is shut down".into())));
            }
        }
        reply_rx
    }

    /// Convenience: submit and wait.
    pub fn predict(&self, seeds: &[u32]) -> Result<TaskResponse> {
        self.submit(seeds.to_vec())
            .recv()
            .map_err(|_| Error::Runtime("server dropped request".into()))?
    }

    /// Stop accepting requests and join the lanes; already-admitted
    /// requests are still answered. Idempotent.
    pub fn shutdown(&self) {
        close_and_join(&self.queue, &self.lanes);
    }

    /// Hot-swap the served model; see [`ServerHandle::swap_model`].
    pub fn swap_model(&self, model: Arc<NativeModel>) -> Result<u64> {
        let generation = self.slot.swap_model(model)?;
        self.stats.swapped(generation);
        Ok(generation)
    }

    /// Hot-swap to the weights in a checkpoint file.
    pub fn swap_checkpoint(&self, path: &std::path::Path) -> Result<u64> {
        let generation = self.slot.swap_checkpoint(path)?;
        self.stats.swapped(generation);
        Ok(generation)
    }

    /// Current model generation (1 until the first swap).
    pub fn generation(&self) -> u64 {
        self.slot.generation()
    }
}

impl Drop for TaskServerHandle {
    fn drop(&mut self) {
        close_and_join(&self.queue, &self.lanes);
    }
}

/// Start a task-shaped native server: each request names a seed list,
/// a lane samples the wave's subgraphs (through the seed-keyed LRU
/// cache when `cfg.cache_capacity > 0`, fanned over the lane's
/// sampling pool when configured) and the [`Task`](crate::tasks::Task)
/// maps each to its response — classification logits, a pair's link
/// score, or a regression value. Errors are per-request: one bad pair
/// does not fail its wave-mates (a wave with any error still counts
/// one `failed_batches`).
///
/// Like [`serve_native`], the model config is gated through
/// [`crate::analysis::check_model`] before anything spawns.
pub fn serve_task(
    model: Arc<NativeModel>,
    sampler: Arc<InMemorySampler>,
    task: Arc<dyn crate::tasks::Task>,
    cfg: ServeConfig,
) -> Result<TaskServerHandle> {
    crate::analysis::check_model(&model.cfg).into_result()?;
    let stats = Arc::new(ServeStats::default());
    let queue: Arc<BoundedQueue<TaskRequest>> = Arc::new(BoundedQueue::new(cfg.queue_capacity));
    let slot = Arc::new(ModelSlot::new(model));
    // The subgraph cache is shared by all lanes (it is seed-keyed and
    // model-independent, so it survives hot-swaps too).
    let cache: Arc<LruCache<Vec<u32>, Arc<GraphTensor>>> =
        Arc::new(LruCache::new(cfg.cache_capacity));
    let mut lanes = Vec::new();
    for lane in 0..cfg.lanes.max(1) {
        let queue = Arc::clone(&queue);
        let stats = Arc::clone(&stats);
        let slot = Arc::clone(&slot);
        let sampler = Arc::clone(&sampler);
        let task = Arc::clone(&task);
        let cache = Arc::clone(&cache);
        let sampler_cfg = cfg.sampler.clone();
        let (max_batch, max_wait, wave_delay) = (cfg.max_batch, cfg.max_wait, cfg.wave_delay);
        lanes.push(
            std::thread::Builder::new()
                .name(format!("tfgnn-serve-task-{lane}"))
                .spawn(move || {
                    let pool = if sampler_cfg.parallel() {
                        Some(ThreadPool::new(sampler_cfg.threads))
                    } else {
                        None
                    };
                    lane_loop(&queue, max_batch, max_wait, |wave| {
                        run_task_wave(
                            wave,
                            &slot,
                            &sampler,
                            task.as_ref(),
                            &cache,
                            pool.as_ref(),
                            wave_delay,
                            &stats,
                        );
                    });
                })?,
        );
    }
    Ok(TaskServerHandle { queue, lanes: Mutex::new(lanes), stats, slot })
}

/// Execute one task-server wave: cache-checked sampling, one model
/// snapshot for the whole wave, per-request structured errors.
#[allow(clippy::too_many_arguments)]
fn run_task_wave(
    wave: Vec<TaskRequest>,
    slot: &ModelSlot,
    sampler: &Arc<InMemorySampler>,
    task: &dyn crate::tasks::Task,
    cache: &LruCache<Vec<u32>, Arc<GraphTensor>>,
    pool: Option<&ThreadPool>,
    wave_delay: Duration,
    stats: &ServeStats,
) {
    let _wave_span = crate::span!("serve/wave", size = wave.len());
    let _wave_timer =
        crate::obs::timed(crate::obs_histogram!(crate::obs::metrics::names::SERVE_WAVE_SECONDS));
    stats.wave_start(wave.len() as u64);
    if !wave_delay.is_zero() {
        std::thread::sleep(wave_delay);
    }
    // One model snapshot for the whole wave: a batch never mixes
    // params from two generations.
    let vm = slot.load();
    let batch_size = wave.len();

    // Resolve each request's subgraph: cache hit, or queued for a
    // (possibly pooled) sampling fan-out. Slots start as placeholder
    // errors and every index is overwritten below.
    let mut graphs: Vec<Result<Arc<GraphTensor>>> = wave
        .iter()
        .map(|_| Err(Error::Runtime("internal: subgraph slot unfilled".into())))
        .collect();
    let mut miss_idx: Vec<usize> = Vec::new();
    let mut miss_lists: Vec<Vec<u32>> = Vec::new();
    let cache_enabled = cache.is_enabled();
    for (i, req) in wave.iter().enumerate() {
        if let Some(g) = cache.get(&req.seeds) {
            stats.cache_hit();
            graphs[i] = Ok(g);
        } else {
            if cache_enabled {
                stats.cache_miss();
            }
            miss_idx.push(i);
            miss_lists.push(req.seeds.clone());
        }
    }
    let sampled: Vec<Result<GraphTensor>> = match pool {
        Some(p) => {
            let s = Arc::clone(sampler);
            p.map(miss_lists.clone(), move |seeds| s.sample_seeds(&seeds))
        }
        None => miss_lists.iter().map(|s| sampler.sample_seeds(s)).collect(),
    };
    for (k, res) in sampled.into_iter().enumerate() {
        let i = miss_idx[k];
        match res {
            Ok(g) => {
                let g = Arc::new(g);
                if cache_enabled {
                    let evicted = cache.put(miss_lists[k].clone(), Arc::clone(&g));
                    stats.cache_evicted(evicted as u64);
                }
                graphs[i] = Ok(g);
            }
            Err(e) => graphs[i] = Err(e),
        }
    }

    // Readout + per-request replies.
    let mut any_failed = false;
    for (req, g) in wave.into_iter().zip(graphs) {
        let out = g.and_then(|g| task.infer(&vm.model, &g));
        match out {
            Ok(output) => {
                let _ = req.reply.send(Ok(TaskResponse {
                    seeds: req.seeds,
                    output,
                    latency: req.submitted.elapsed(),
                    batch_size,
                    generation: vm.generation,
                }));
            }
            Err(e) => {
                any_failed = true;
                let _ = req.reply.send(Err(Error::Runtime(e.to_string())));
            }
        }
    }
    queue_depth().sub(batch_size as i64);
    if any_failed {
        stats.wave_failed();
    }
}

/// Sample, merge, pad, execute one wave on the AOT program; returns
/// (flat logits, classes).
#[allow(clippy::too_many_arguments)]
fn execute_wave(
    rt: &Runtime,
    forward: &Program,
    param_bufs: &[xla::Literal],
    sampler: &InMemorySampler,
    pool: Option<&ThreadPool>,
    pad: &PadSpec,
    task: &RootTask,
    seeds: &[u32],
) -> Result<(Vec<f32>, usize)> {
    // The whole wave of roots samples as one batch — fanned out over
    // the sampling pool when configured, serially otherwise; either
    // way the subgraphs are identical, in request order.
    let graphs = match pool {
        Some(p) => sampler.sample_batch_with_pool(seeds, p)?,
        None => seeds
            .iter()
            .map(|&s| sampler.sample(s))
            .collect::<Result<Vec<_>>>()?,
    };
    let merged = crate::graph::batch::merge(&graphs)?;
    let padded = fit_or_skip(&merged, pad)
        .ok_or_else(|| Error::Runtime("request wave exceeds pad caps".into()))?;
    let inputs = &forward.spec.inputs;
    let batch = build_batch(&padded, task, inputs)?;
    let mut batch_lits = Vec::with_capacity(batch.len());
    for (idx, t) in &batch {
        batch_lits.push((*idx, host_to_literal(t)?));
    }
    let _ = rt;
    let mut args: Vec<&xla::Literal> = Vec::with_capacity(inputs.len());
    let mut it = batch_lits.iter();
    for (i, spec) in inputs.iter().enumerate() {
        if i < param_bufs.len() {
            args.push(&param_bufs[i]);
        } else if is_batch_slot(&spec.name) {
            let (idx, lit) =
                it.next().ok_or_else(|| Error::Runtime("slots exhausted".into()))?;
            debug_assert_eq!(*idx, i);
            args.push(lit);
        } else {
            return Err(Error::Runtime(format!("unhandled forward slot {:?}", spec.name)));
        }
    }
    let outputs = forward.execute_literals(&args)?;
    let logits = literal_to_host(&outputs[0])?;
    let shape = logits.shape().to_vec();
    let HostTensor::F32(_, data) = logits else {
        return Err(Error::Runtime("logits not f32".into()));
    };
    Ok((data, shape[1]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::model_ref::ModelConfig;
    use crate::sampler::spec::mag_sampling_spec_scaled;
    use crate::synth::mag::{generate, MagConfig, Split};

    fn native_server_for(
        arch: &str,
        max_batch: usize,
        max_wait: Duration,
    ) -> (ServerHandle, Vec<u32>, usize) {
        let mag = MagConfig::tiny();
        let ds = generate(&mag);
        let seeds = ds.papers_in_split(Split::Train);
        let store = Arc::new(ds.store);
        let spec = mag_sampling_spec_scaled(&store.schema, 0.2).unwrap();
        let sampler = Arc::new(InMemorySampler::new(store, spec, 3).unwrap());
        let cfg = ModelConfig::for_mag(&mag, 8, 8, 1).with_arch(arch);
        let num_classes = cfg.num_classes;
        let model = Arc::new(NativeModel::init(cfg, 7).unwrap());
        let handle = serve_native(
            model,
            sampler,
            RootTask::default(),
            ServeConfig { max_batch, max_wait, ..ServeConfig::default() },
        )
        .unwrap();
        (handle, seeds, num_classes)
    }

    fn native_server(max_batch: usize, max_wait: Duration) -> (ServerHandle, Vec<u32>, usize) {
        native_server_for("mpnn", max_batch, max_wait)
    }

    #[test]
    fn native_server_predicts() {
        let (handle, seeds, classes) = native_server(4, Duration::from_millis(2));
        for &s in seeds.iter().take(6) {
            let resp = handle.predict(s).unwrap();
            assert_eq!(resp.seed, s);
            assert_eq!(resp.logits.len(), classes);
            assert!(resp.predicted < classes);
            assert!(resp.logits.iter().all(|v| v.is_finite()));
            assert_eq!(resp.generation, 1, "no swap happened");
        }
        let snap = handle.stats.snapshot();
        assert!(snap.requests >= 6);
        assert_eq!(snap.rejected, 0);
        assert_eq!(
            snap.cache_lookups(),
            snap.cache_hits + snap.cache_misses,
            "lookup identity"
        );
        handle.shutdown();
    }

    /// `serve_native` hosts any built model, not just the mpnn: every
    /// convolution of the zoo serves predictions through the same
    /// batcher.
    #[test]
    fn native_server_hosts_the_whole_zoo() {
        for arch in ["gcn", "sage", "gatv2"] {
            let (handle, seeds, classes) =
                native_server_for(arch, 3, Duration::from_millis(2));
            for &s in seeds.iter().take(3) {
                let resp = handle.predict(s).unwrap();
                assert_eq!(resp.logits.len(), classes, "{arch}");
                assert!(resp.logits.iter().all(|v| v.is_finite()), "{arch}");
                assert!(resp.predicted < classes, "{arch}");
            }
            handle.shutdown();
        }
    }

    /// `serve_task` answers with task-shaped responses for all three
    /// objectives — classification logits, pair link scores, regression
    /// values — over the same batcher/sampler machinery.
    #[test]
    fn task_server_serves_all_three_tasks() {
        use crate::ops::model_ref::TaskConfig;
        use crate::synth::mag::edge_holdout;
        use crate::tasks::{self, TaskOutput};

        let mag = MagConfig::tiny();
        let ds = generate(&mag);
        let seeds = ds.papers_in_split(Split::Train);
        let holdout = edge_holdout(&ds, "cites", 0.2, 9).unwrap();
        let store = Arc::new(ds.store);
        let spec = mag_sampling_spec_scaled(&store.schema, 0.2).unwrap();
        let sampler = Arc::new(InMemorySampler::new(store, spec, 3).unwrap());
        let serve_cfg = || ServeConfig {
            max_batch: 3,
            max_wait: Duration::from_millis(2),
            ..ServeConfig::default()
        };

        // Root classification.
        let cfg = ModelConfig::for_mag(&mag, 8, 8, 1);
        let task = tasks::build(&cfg).unwrap();
        let model = Arc::new(NativeModel::init(cfg, 7).unwrap());
        let handle = serve_task(model, Arc::clone(&sampler), task, serve_cfg()).unwrap();
        let resp = handle.predict(&[seeds[0]]).unwrap();
        let TaskOutput::Classification { logits, predicted } = resp.output else {
            panic!("want classification output");
        };
        assert_eq!(logits.len(), mag.num_classes);
        assert!(predicted < mag.num_classes);
        handle.shutdown();

        // Link prediction (pair requests; sampler over the holdout
        // store so held-out edges stay unseen).
        let lp_store = Arc::new(holdout.store);
        let lp_spec = mag_sampling_spec_scaled(&lp_store.schema, 0.2).unwrap();
        let lp_sampler = Arc::new(InMemorySampler::new(lp_store, lp_spec, 3).unwrap());
        let cfg = ModelConfig::for_mag(&mag, 8, 8, 1).with_task(TaskConfig {
            kind: "link_prediction".into(),
            readout: "dot".into(),
            ..TaskConfig::default()
        });
        let task = tasks::build(&cfg).unwrap();
        let model = Arc::new(NativeModel::init(cfg, 7).unwrap());
        let handle = serve_task(model, lp_sampler, task, serve_cfg()).unwrap();
        let (u, v) = holdout.test[0];
        let resp = handle.predict(&[u, v]).unwrap();
        let TaskOutput::LinkScore { score } = resp.output else {
            panic!("want link score output");
        };
        assert!(score.is_finite());
        assert_eq!(resp.seeds, vec![u, v]);
        // A degenerate pair fails its request, not the server.
        assert!(handle.predict(&[u, u]).is_err());
        let again = handle.predict(&[u, v]).unwrap();
        let TaskOutput::LinkScore { score: s2 } = again.output else { panic!() };
        assert_eq!(s2.to_bits(), score.to_bits(), "deterministic rescoring");
        assert!(handle.stats.snapshot().failed_batches >= 1);
        handle.shutdown();

        // Graph regression.
        let cfg = ModelConfig::for_mag(&mag, 8, 8, 1).with_task(TaskConfig {
            kind: "graph_regression".into(),
            target_shift: 2010.0,
            target_scale: 0.1,
            ..TaskConfig::default()
        });
        let task = tasks::build(&cfg).unwrap();
        let model = Arc::new(NativeModel::init(cfg, 7).unwrap());
        let handle = serve_task(model, sampler, task, serve_cfg()).unwrap();
        let resp = handle.predict(&[seeds[1]]).unwrap();
        let TaskOutput::Regression { value } = resp.output else {
            panic!("want regression output");
        };
        assert!(value.is_finite());
        handle.shutdown();
    }

    /// Regression: shutting the server down must NOT drop requests that
    /// were already admitted — the lanes drain the queue before the
    /// workers exit, so every pending reply channel gets a response.
    #[test]
    fn shutdown_drains_already_submitted_requests() {
        // A long max_wait so most requests are still queued (or mid
        // wave-collection) when shutdown closes the queue.
        let (handle, seeds, classes) = native_server(2, Duration::from_millis(50));
        let n = 16usize;
        let pending: Vec<_> =
            (0..n).map(|i| handle.submit(seeds[i % seeds.len()])).collect();
        // Close admissions and join the lanes immediately.
        handle.shutdown();
        // Every submitted request must still have been answered.
        for (i, rx) in pending.into_iter().enumerate() {
            let resp = rx
                .recv()
                .unwrap_or_else(|_| panic!("request {i} dropped at shutdown"))
                .unwrap_or_else(|e| panic!("request {i} failed: {e}"));
            assert_eq!(resp.logits.len(), classes);
        }
    }

    /// Submitting after shutdown returns a structured error instead of
    /// hanging on a dead channel — on both handle types.
    #[test]
    fn submit_after_shutdown_is_a_structured_error() {
        let (handle, seeds, _) = native_server(4, Duration::from_millis(2));
        handle.predict(seeds[0]).unwrap();
        handle.shutdown();
        let err = handle.predict(seeds[0]).unwrap_err();
        assert!(
            err.to_string().contains("shut down"),
            "want a shutdown error, got: {err}"
        );
    }
}
