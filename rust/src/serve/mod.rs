//! Online inference (paper §6.3).
//!
//! The paper's serving story: host the exported model behind a service;
//! the caller provides GraphTensors "perhaps via the in-memory
//! sampler". [`InferenceServer`] implements exactly that shape — a
//! vLLM-router-style dynamic batcher in front of the AOT `forward`
//! program:
//!
//! * clients submit root node ids ([`ServerHandle::submit`]);
//! * the batcher thread collects up to `max_batch` requests or until
//!   `max_wait` elapses, samples the whole wave of roots — **in
//!   parallel** over the server's sampling pool when
//!   [`ServeConfig::sampler`] asks for threads — merges + pads to the
//!   static shape, and runs one `forward` execution;
//! * each request gets back its logits row, predicted class, and
//!   timing (queue + batch + execute breakdown for the benches).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::graph::pad::{fit_or_skip, PadSpec};
use crate::runtime::batch::{build_batch, is_batch_slot, RootTask};
use crate::runtime::manifest::ModelEntry;
use crate::runtime::{host_to_literal, literal_to_host, HostTensor, Program, Runtime};
use crate::sampler::inmem::InMemorySampler;
use crate::sampler::SamplerConfig;
use crate::util::threadpool::ThreadPool;
use crate::{Error, Result};

/// A completed prediction.
#[derive(Debug, Clone)]
pub struct Response {
    pub seed: u32,
    pub predicted: usize,
    pub logits: Vec<f32>,
    /// Time from submit to response.
    pub latency: Duration,
    /// Requests in the same executed batch.
    pub batch_size: usize,
}

struct Request {
    seed: u32,
    submitted: Instant,
    reply: Sender<Result<Response>>,
}

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Max roots per forward execution (≤ the model's component cap - 1).
    pub max_batch: usize,
    /// Max time the batcher waits to fill a batch.
    pub max_wait: Duration,
    /// Sampling-stage knobs: with `threads > 1` the batcher samples a
    /// whole wave of roots concurrently on a pool it owns (spawned once
    /// at startup), before padding. Results are bit-for-bit those of
    /// serial sampling.
    pub sampler: SamplerConfig,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            sampler: SamplerConfig::default(),
        }
    }
}

/// Aggregate server counters.
#[derive(Debug, Default)]
pub struct ServeStats {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub skipped_oversize: AtomicU64,
}

/// Client handle: submit requests, then `shutdown()`.
pub struct ServerHandle {
    tx: Option<Sender<Request>>,
    worker: Option<std::thread::JoinHandle<()>>,
    pub stats: Arc<ServeStats>,
}

impl ServerHandle {
    /// Submit a request; returns the channel the response arrives on.
    pub fn submit(&self, seed: u32) -> Receiver<Result<Response>> {
        let (reply_tx, reply_rx) = channel();
        let req = Request { seed, submitted: Instant::now(), reply: reply_tx };
        self.tx.as_ref().expect("server running").send(req).expect("server alive");
        reply_rx
    }

    /// Convenience: submit and wait.
    pub fn predict(&self, seed: u32) -> Result<Response> {
        self.submit(seed)
            .recv()
            .map_err(|_| Error::Runtime("server dropped request".into()))?
    }

    /// Stop accepting requests and join the worker.
    pub fn shutdown(mut self) {
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// Build and start the server.
///
/// PJRT handles are not `Send`, so the worker thread constructs its own
/// client, compiles `forward`, and uploads the params itself; this
/// function only passes plain data (paths, specs, host tensors) across
/// the thread boundary and waits for the worker's startup report.
pub fn serve(
    artifacts_dir: &std::path::Path,
    entry: &ModelEntry,
    params: Vec<(String, HostTensor)>,
    sampler: Arc<InMemorySampler>,
    pad: PadSpec,
    task: RootTask,
    cfg: ServeConfig,
) -> Result<ServerHandle> {
    let forward_spec = entry.program("forward")?.clone();
    let dir = artifacts_dir.to_path_buf();
    let stats = Arc::new(ServeStats::default());
    let (tx, rx) = channel::<Request>();
    let (ready_tx, ready_rx) = channel::<Result<()>>();
    let stats_w = Arc::clone(&stats);
    let max_batch = cfg.max_batch;
    let max_wait = cfg.max_wait;
    let sampler_cfg = cfg.sampler.clone();
    let worker = std::thread::Builder::new()
        .name("tfgnn-serve".into())
        .spawn(move || {
            // Build the PJRT world inside the thread (handles are !Send).
            let setup = (|| -> Result<(Runtime, Program, Vec<xla::Literal>)> {
                let rt = Runtime::cpu()?;
                let forward = rt.load_program(&dir, &forward_spec)?;
                // Forward may have a pruned signature (dead params
                // dropped by jax); resolve each param slot by name from
                // the full checkpoint/trainer param list.
                let by_name: std::collections::BTreeMap<&str, &HostTensor> =
                    params.iter().map(|(n, t)| (n.as_str(), t)).collect();
                let mut param_lits = Vec::new();
                for spec in &forward.spec.inputs {
                    if !spec.name.starts_with("param.") {
                        continue;
                    }
                    let t = by_name.get(spec.name.as_str()).ok_or_else(|| {
                        Error::Runtime(format!("server params missing slot {}", spec.name))
                    })?;
                    if !t.matches(spec) {
                        return Err(Error::Runtime(format!(
                            "param {} does not match forward slot shape",
                            spec.name
                        )));
                    }
                    param_lits.push(host_to_literal(t)?);
                }
                Ok((rt, forward, param_lits))
            })();
            match setup {
                Ok((rt, forward, param_bufs)) => {
                    let _ = ready_tx.send(Ok(()));
                    serve_loop(
                        rx, rt, forward, param_bufs, sampler, pad, task, max_batch, max_wait,
                        sampler_cfg, stats_w,
                    );
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                }
            }
        })
        .expect("spawn server");
    ready_rx
        .recv()
        .map_err(|_| Error::Runtime("server thread died during startup".into()))??;
    Ok(ServerHandle { tx: Some(tx), worker: Some(worker), stats })
}

#[allow(clippy::too_many_arguments)]
fn serve_loop(
    rx: Receiver<Request>,
    rt: Runtime,
    forward: Program,
    param_bufs: Vec<xla::Literal>,
    sampler: Arc<InMemorySampler>,
    pad: PadSpec,
    task: RootTask,
    max_batch: usize,
    max_wait: Duration,
    sampler_cfg: SamplerConfig,
    stats: Arc<ServeStats>,
) {
    // The sampling pool outlives every wave: spawn once at startup.
    let pool = if sampler_cfg.parallel() {
        Some(ThreadPool::new(sampler_cfg.threads))
    } else {
        None
    };
    loop {
        // Block for the first request of a batch.
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return, // all senders gone: shutdown
        };
        let mut wave = vec![first];
        let deadline = Instant::now() + max_wait;
        while wave.len() < max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => wave.push(r),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        stats.requests.fetch_add(wave.len() as u64, Ordering::Relaxed);
        stats.batches.fetch_add(1, Ordering::Relaxed);
        let batch_size = wave.len();
        let result =
            execute_wave(&rt, &forward, &param_bufs, &sampler, pool.as_ref(), &pad, &task, &wave);
        match result {
            Ok(logits) => {
                let classes = logits.1;
                for (k, req) in wave.into_iter().enumerate() {
                    let row = logits.0[k * classes..(k + 1) * classes].to_vec();
                    let predicted = row
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .map(|(i, _)| i)
                        .unwrap_or(0);
                    let resp = Response {
                        seed: req.seed,
                        predicted,
                        logits: row,
                        latency: req.submitted.elapsed(),
                        batch_size,
                    };
                    let _ = req.reply.send(Ok(resp));
                }
            }
            Err(e) => {
                stats.skipped_oversize.fetch_add(1, Ordering::Relaxed);
                let msg = e.to_string();
                for req in wave {
                    let _ = req.reply.send(Err(Error::Runtime(msg.clone())));
                }
            }
        }
    }
}

/// Sample, merge, pad, execute one wave; returns (flat logits, classes).
#[allow(clippy::too_many_arguments)]
fn execute_wave(
    rt: &Runtime,
    forward: &Program,
    param_bufs: &[xla::Literal],
    sampler: &InMemorySampler,
    pool: Option<&ThreadPool>,
    pad: &PadSpec,
    task: &RootTask,
    wave: &[Request],
) -> Result<(Vec<f32>, usize)> {
    // The whole wave of roots samples as one batch — fanned out over
    // the sampling pool when configured, serially otherwise; either
    // way the subgraphs are identical, in request order.
    let seeds: Vec<u32> = wave.iter().map(|r| r.seed).collect();
    let graphs = match pool {
        Some(p) => sampler.sample_batch_with_pool(&seeds, p)?,
        None => seeds
            .iter()
            .map(|&s| sampler.sample(s))
            .collect::<Result<Vec<_>>>()?,
    };
    let merged = crate::graph::batch::merge(&graphs)?;
    let padded = fit_or_skip(&merged, pad)
        .ok_or_else(|| Error::Runtime("request wave exceeds pad caps".into()))?;
    let inputs = &forward.spec.inputs;
    let batch = build_batch(&padded, task, inputs)?;
    let mut batch_lits = Vec::with_capacity(batch.len());
    for (idx, t) in &batch {
        batch_lits.push((*idx, host_to_literal(t)?));
    }
    let _ = rt;
    let mut args: Vec<&xla::Literal> = Vec::with_capacity(inputs.len());
    let mut it = batch_lits.iter();
    for (i, spec) in inputs.iter().enumerate() {
        if i < param_bufs.len() {
            args.push(&param_bufs[i]);
        } else if is_batch_slot(&spec.name) {
            let (idx, lit) =
                it.next().ok_or_else(|| Error::Runtime("slots exhausted".into()))?;
            debug_assert_eq!(*idx, i);
            args.push(lit);
        } else {
            return Err(Error::Runtime(format!("unhandled forward slot {:?}", spec.name)));
        }
    }
    let outputs = forward.execute_literals(&args)?;
    let logits = literal_to_host(&outputs[0])?;
    let shape = logits.shape().to_vec();
    let HostTensor::F32(_, data) = logits else {
        return Err(Error::Runtime("logits not f32".into()));
    };
    Ok((data, shape[1]))
}
