//! Online inference (paper §6.3) — the production serving subsystem.
//!
//! The paper's serving story is models in front of heavy traffic; this
//! module implements the full request path as four cooperating pieces:
//!
//! * **Admission + lanes** ([`batcher`]) — clients submit into a
//!   *bounded* MPMC queue; a full queue rejects the request immediately
//!   with [`Error::Overloaded`] (admission control, not an unbounded
//!   backlog). [`ServeConfig::lanes`] batcher threads pull from the
//!   shared queue, each gathering up to `max_batch` requests (waiting
//!   at most `max_wait` for stragglers) and executing the wave.
//! * **Subgraph cache** ([`cache`]) — the task server can memoize
//!   sampled subgraphs keyed by the request's seed list
//!   ([`ServeConfig::cache_capacity`]). The sampler is a pure function
//!   of `(store, spec, plan_seed, seeds)`, so a hit is bit-identical to
//!   a re-sample; hit/miss/eviction counters land in [`ServeStats`].
//! * **Hot-swap** ([`swap`]) — the native model lives behind an
//!   atomically swappable [`swap::ModelSlot`]. Each lane snapshots the
//!   model `Arc` once per wave, so a batch never mixes parameters from
//!   two models; responses carry the snapshot's `generation` so
//!   clients (and the concurrency tests) can tell which weights
//!   answered.
//! * **Load generator** ([`loadgen`]) — a closed-loop driver that
//!   steps client concurrency against a running server and summarizes
//!   p50/p95/p99 latency, saturation throughput and rejection counts
//!   (the `benches/serving.rs` + `tfgnn loadgen` entry points).
//!
//! Three server constructors share the machinery: [`serve`] runs the
//! AOT `forward` program on PJRT (single execution lane — PJRT handles
//! are not `Send` — but the same bounded-admission front door),
//! [`serve_native`] runs the pure-Rust [`NativeModel`] forward per
//! sampled subgraph across N lanes, and [`serve_task`] generalizes the
//! native backend across the task subsystem (requests are *seed
//! lists*, responses are [`crate::tasks::TaskOutput`]).
//!
//! Contracts, pinned by `tests/serve_concurrency.rs` at 1/2/8 lanes
//! (and under the nightly TSan lane):
//!
//! * per-request structured errors — one bad request never fails its
//!   wave-mates on the task server, and an executor error replies to
//!   every request in the wave;
//! * drain-on-shutdown — [`ServerHandle::shutdown`] stops *admissions*
//!   but every already-admitted request is still answered; submitting
//!   after shutdown returns a structured error instead of hanging;
//! * determinism — each individual response is bit-identical at any
//!   lane count, with caching on or off.
//!
//! ## Live introspection and deadlines
//!
//! Every server owns a [`crate::obs::health::Watchdog`]: lanes
//! heartbeat per wave, and a wedged lane or a stalled non-empty queue
//! flips the health verdict (served as 200/503 on `/healthz`). Three
//! opt-in [`ServeConfig`] knobs complete the live story:
//! `admin_addr` starts a [`crate::obs::admin::AdminServer`]
//! (`/metrics`, `/metrics.json`, `/healthz`, `/tracez`, `/statusz`),
//! `incident_dir` arms a [`crate::obs::flight::FlightRecorder`]
//! (watchdog trips, overload bursts and failed batches dump
//! rate-limited metrics + trace snapshots), and `default_deadline_ms`
//! (or [`ServerHandle::submit_with_deadline`] per request) bounds how
//! long a request may wait: an expired request is answered with a
//! structured [`Error::DeadlineExceeded`] — counted in
//! `serve_deadline_expired_total` — and never reaches a model forward
//! pass. All of it obeys the observability inertness contract: admin
//! off by default, and a concurrent scraper never changes served bits
//! (pinned by `tests/admin_live.rs`).

pub mod batcher;
pub mod cache;
pub mod loadgen;
pub mod swap;

use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::graph::pad::{fit_or_skip, PadSpec};
use crate::graph::GraphTensor;
use crate::obs::admin::{AdminServer, AdminState};
use crate::obs::flight::FlightRecorder;
use crate::obs::health::{HealthReport, Watchdog};
use crate::runtime::batch::{build_batch, is_batch_slot, RootTask};
use crate::runtime::manifest::ModelEntry;
use crate::runtime::{host_to_literal, literal_to_host, HostTensor, Program, Runtime};
use crate::sampler::inmem::InMemorySampler;
use crate::sampler::SamplerConfig;
use crate::train::native::NativeModel;
use crate::util::json::{obj, Json};
use crate::util::threadpool::ThreadPool;
use crate::{Error, Result};

use batcher::{lane_loop, BoundedQueue, PushError};
use cache::LruCache;
use swap::ModelSlot;

/// A completed prediction.
#[derive(Debug, Clone)]
pub struct Response {
    pub seed: u32,
    pub predicted: usize,
    pub logits: Vec<f32>,
    /// Time from submit to response.
    pub latency: Duration,
    /// Requests in the same executed batch.
    pub batch_size: usize,
    /// Which model answered: the serving slot's swap generation
    /// (1 until the first hot-swap; always 1 on the AOT backend).
    pub generation: u64,
}

struct Request {
    seed: u32,
    submitted: Instant,
    /// Absolute expiry; a lane answers `DeadlineExceeded` instead of
    /// executing once this passes.
    deadline: Option<Instant>,
    reply: Sender<Result<Response>>,
}

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Max roots per forward execution (≤ the model's component cap - 1).
    pub max_batch: usize,
    /// Max time a lane waits to fill a batch.
    pub max_wait: Duration,
    /// Concurrent batcher lanes pulling from the shared queue. The AOT
    /// backend always runs one execution lane (PJRT handles are not
    /// `Send`); native backends spawn exactly this many.
    pub lanes: usize,
    /// Admission-control bound: requests beyond this backlog are
    /// rejected with [`Error::Overloaded`] instead of queued.
    pub queue_capacity: usize,
    /// Seed-keyed LRU subgraph cache entries on the task server
    /// (0 disables caching). Hits skip re-sampling; responses are
    /// bit-identical either way because sampling is deterministic.
    pub cache_capacity: usize,
    /// Synthetic extra latency added to every executed wave. Zero in
    /// production; the overload tests and backpressure experiments use
    /// it to make saturation deterministic.
    pub wave_delay: Duration,
    /// Sampling-stage knobs: with `threads > 1` each lane samples its
    /// wave concurrently on a pool it owns (spawned once at startup).
    /// Results are bit-for-bit those of serial sampling.
    pub sampler: SamplerConfig,
    /// Default request deadline in milliseconds (0 = no deadline). A
    /// request whose deadline passes before a lane executes it is
    /// answered [`Error::DeadlineExceeded`] — counted in
    /// `serve_deadline_expired_total`, never run through the model.
    /// `submit_with_deadline` overrides this per request.
    pub default_deadline_ms: u64,
    /// Opt-in live admin endpoint bind address (the `--admin-addr`
    /// flag), e.g. `127.0.0.1:9100`; port 0 picks an ephemeral port
    /// (read it back via `admin_addr()` on the handle). `None` — the
    /// default — starts no listener at all.
    pub admin_addr: Option<String>,
    /// Incident flight-recorder directory (the `--incident-dir`
    /// flag): watchdog trips, overload bursts and failed batches dump
    /// rate-limited metrics + trace snapshots here. `None` disables.
    pub incident_dir: Option<std::path::PathBuf>,
    /// Watchdog threshold: a lane stuck mid-wave longer than this, or
    /// a non-empty queue with no lane progress for this long, flips
    /// `/healthz` to 503.
    pub watchdog_threshold: Duration,
    /// Human-readable configuration label surfaced in `/statusz`
    /// (the CLI sets it to a summary of the invocation).
    pub config_label: Option<String>,
    /// TEST HOOK: the named lane sleeps this long at the start of
    /// every wave it picks up, making wedged-lane detection and
    /// in-queue deadline expiry deterministic in tests. Always `None`
    /// in production configurations.
    pub debug_stall: Option<(usize, Duration)>,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            lanes: 1,
            queue_capacity: 1024,
            cache_capacity: 0,
            wave_delay: Duration::ZERO,
            sampler: SamplerConfig::default(),
            default_deadline_ms: 0,
            admin_addr: None,
            incident_dir: None,
            watchdog_threshold: Duration::from_secs(1),
            config_label: None,
            debug_stall: None,
        }
    }
}

/// Aggregate server counters.
///
/// The fields are private atomics; readers take a coherent-enough
/// [`snapshot`](Self::snapshot) (each field is an independent relaxed
/// load — fine for monitoring, and the tests only assert after
/// quiescence). Every mutation also mirrors into the process-global
/// [`crate::obs::metrics`] registry under the `serve_*` names, so
/// `tfgnn stats` and the Prometheus exporter see the same counts
/// without a second bookkeeping path in the hot loop.
#[derive(Debug, Default)]
pub struct ServeStats {
    requests: AtomicU64,
    batches: AtomicU64,
    failed_batches: AtomicU64,
    rejected: AtomicU64,
    cache_hits: AtomicU64,
    cache_misses: AtomicU64,
    cache_evictions: AtomicU64,
    swaps: AtomicU64,
    deadline_expired: AtomicU64,
    /// Requests admitted but not yet replied to, on *this* server (the
    /// process-global `serve_queue_depth` gauge aggregates across
    /// servers, which the depth-regression test cannot key on).
    depth: AtomicI64,
}

/// Plain-data view of [`ServeStats`] at one point in time.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServeStatsSnapshot {
    /// Requests pulled into an executed wave (rejections not included).
    pub requests: u64,
    /// Waves executed by batcher lanes.
    pub batches: u64,
    /// Waves whose executor failed — every request in the wave got an
    /// error reply. On the AOT backend the usual cause is a wave
    /// exceeding the pad caps; the native backend never pads, so here
    /// it means a sampling or forward error.
    pub failed_batches: u64,
    /// Requests rejected by admission control ([`Error::Overloaded`]).
    pub rejected: u64,
    /// Task-server subgraph cache hits (0 when the cache is disabled).
    pub cache_hits: u64,
    /// Task-server subgraph cache misses (0 when the cache is disabled).
    pub cache_misses: u64,
    /// Entries evicted from the subgraph cache by capacity pressure.
    pub cache_evictions: u64,
    /// Successful model hot-swaps.
    pub swaps: u64,
    /// Requests answered [`Error::DeadlineExceeded`]; they never
    /// reached a model forward pass.
    pub deadline_expired: u64,
    /// Requests admitted but not yet replied to on this server. Zero
    /// at quiescence: every admitted request — served, failed or
    /// expired — is replied exactly once.
    pub queue_depth: i64,
}

impl ServeStatsSnapshot {
    /// Total subgraph-cache lookups; by construction every lookup is
    /// exactly one hit or one miss, so `hits + misses` is an identity,
    /// not an approximation.
    pub fn cache_lookups(&self) -> u64 {
        self.cache_hits + self.cache_misses
    }
}

impl ServeStats {
    /// Read every counter (relaxed loads).
    pub fn snapshot(&self) -> ServeStatsSnapshot {
        ServeStatsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            failed_batches: self.failed_batches.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            cache_hits: self.cache_hits.load(Ordering::Relaxed),
            cache_misses: self.cache_misses.load(Ordering::Relaxed),
            cache_evictions: self.cache_evictions.load(Ordering::Relaxed),
            swaps: self.swaps.load(Ordering::Relaxed),
            deadline_expired: self.deadline_expired.load(Ordering::Relaxed),
            queue_depth: self.depth.load(Ordering::Relaxed),
        }
    }

    /// One request admitted into the queue: +1 on this server's depth
    /// and the global gauge.
    fn admitted(&self) {
        self.depth.fetch_add(1, Ordering::Relaxed);
        queue_depth().add(1);
    }

    /// `n` admitted requests replied (served, failed or expired): the
    /// exact inverse of [`admitted`](Self::admitted).
    fn replied(&self, n: usize) {
        let n = n as i64;
        self.depth.fetch_sub(n, Ordering::Relaxed);
        queue_depth().sub(n);
    }

    /// One request expired before execution.
    fn deadline_miss(&self) {
        self.deadline_expired.fetch_add(1, Ordering::Relaxed);
        crate::obs_counter!(crate::obs::metrics::names::SERVE_DEADLINE_EXPIRED).inc();
    }

    fn wave_start(&self, size: u64) {
        self.requests.fetch_add(size, Ordering::Relaxed);
        self.batches.fetch_add(1, Ordering::Relaxed);
        crate::obs_counter!(crate::obs::metrics::names::SERVE_REQUESTS).add(size);
        crate::obs_counter!(crate::obs::metrics::names::SERVE_BATCHES).inc();
        if crate::obs::recording() {
            crate::obs_histogram!(crate::obs::metrics::names::SERVE_WAVE_SIZE)
                .record(size as f64);
        }
    }

    fn wave_failed(&self) {
        self.failed_batches.fetch_add(1, Ordering::Relaxed);
        crate::obs_counter!(crate::obs::metrics::names::SERVE_FAILED_BATCHES).inc();
    }

    fn rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
        crate::obs_counter!(crate::obs::metrics::names::SERVE_REJECTED).inc();
    }

    fn cache_hit(&self) {
        self.cache_hits.fetch_add(1, Ordering::Relaxed);
        crate::obs_counter!(crate::obs::metrics::names::SERVE_CACHE_HITS).inc();
    }

    fn cache_miss(&self) {
        self.cache_misses.fetch_add(1, Ordering::Relaxed);
        crate::obs_counter!(crate::obs::metrics::names::SERVE_CACHE_MISSES).inc();
    }

    fn cache_evicted(&self, n: u64) {
        if n > 0 {
            self.cache_evictions.fetch_add(n, Ordering::Relaxed);
            crate::obs_counter!(crate::obs::metrics::names::SERVE_CACHE_EVICTIONS).add(n);
        }
    }

    fn swapped(&self, generation: u64) {
        self.swaps.fetch_add(1, Ordering::Relaxed);
        crate::obs_counter!(crate::obs::metrics::names::SERVE_SWAPS).inc();
        crate::obs_gauge!(crate::obs::metrics::names::SERVE_GENERATION)
            .set(i64::try_from(generation).unwrap_or(i64::MAX));
    }
}

/// Process-global queue-depth gauge: +1 per admitted request, -1 per
/// reply. The lanes drain the queue on shutdown, so the gauge returns
/// to zero for every request that was ever admitted. Mirrored by the
/// per-server `ServeStats::depth` (see [`ServeStatsSnapshot::queue_depth`]).
fn queue_depth() -> &'static crate::obs::metrics::Gauge {
    crate::obs_gauge!(crate::obs::metrics::names::SERVE_QUEUE_DEPTH)
}

/// Request outcome classes for the end-to-end latency histograms.
#[derive(Clone, Copy)]
enum Outcome {
    Ok,
    Rejected,
    Deadline,
    Failed,
}

fn outcome_histogram(outcome: Outcome) -> &'static crate::obs::metrics::Histogram {
    use crate::obs::metrics::names;
    match outcome {
        Outcome::Ok => crate::obs_histogram!(names::SERVE_REQUEST_OK_SECONDS),
        Outcome::Rejected => crate::obs_histogram!(names::SERVE_REQUEST_REJECTED_SECONDS),
        Outcome::Deadline => crate::obs_histogram!(names::SERVE_REQUEST_DEADLINE_SECONDS),
        Outcome::Failed => crate::obs_histogram!(names::SERVE_REQUEST_FAILED_SECONDS),
    }
}

/// Record a request's end-to-end latency keyed by outcome. Gated on
/// `recording()` before the clock read (histograms are off-by-default
/// per the inertness contract).
fn record_outcome(outcome: Outcome, submitted: Instant) {
    if crate::obs::recording() {
        outcome_histogram(outcome).record(submitted.elapsed().as_secs_f64());
    }
}

/// Like [`record_outcome`] but for paths that already computed the
/// latency for the response itself.
fn record_outcome_latency(outcome: Outcome, latency: Duration) {
    if crate::obs::recording() {
        outcome_histogram(outcome).record(latency.as_secs_f64());
    }
}

/// Answer one deadline-expired request: bump the counters, the
/// watchdog's miss tally and the deadline-outcome histogram, then
/// reply a structured [`Error::DeadlineExceeded`]. Depth bookkeeping
/// stays at the call site — submit-time expiries were never admitted.
fn reply_deadline<T>(
    reply: &Sender<Result<T>>,
    submitted: Instant,
    stats: &ServeStats,
    watchdog: &Watchdog,
    place: &str,
) {
    stats.deadline_miss();
    watchdog.note_deadline_miss();
    record_outcome(Outcome::Deadline, submitted);
    let _ = reply.send(Err(Error::DeadlineExceeded(format!(
        "deadline passed after {}ms {place}; the request was never executed",
        submitted.elapsed().as_millis()
    ))));
}

/// Partition a popped logits wave: every request whose deadline has
/// already passed is answered `DeadlineExceeded` (counted, depth -1 —
/// it never reaches the model); the still-live remainder is returned.
/// A wave with no deadlines set costs one iterator scan and no clock
/// read.
fn expire_overdue_logits(
    wave: Vec<Request>,
    stats: &ServeStats,
    watchdog: &Watchdog,
) -> Vec<Request> {
    if wave.iter().all(|r| r.deadline.is_none()) {
        return wave;
    }
    let now = Instant::now();
    let mut live = Vec::with_capacity(wave.len());
    for req in wave {
        if req.deadline.is_some_and(|d| now >= d) {
            stats.replied(1);
            reply_deadline(&req.reply, req.submitted, stats, watchdog, "in queue");
        } else {
            live.push(req);
        }
    }
    live
}

/// Task-server twin of [`expire_overdue_logits`].
fn expire_overdue_task(
    wave: Vec<TaskRequest>,
    stats: &ServeStats,
    watchdog: &Watchdog,
) -> Vec<TaskRequest> {
    if wave.iter().all(|r| r.deadline.is_none()) {
        return wave;
    }
    let now = Instant::now();
    let mut live = Vec::with_capacity(wave.len());
    for req in wave {
        if req.deadline.is_some_and(|d| now >= d) {
            stats.replied(1);
            reply_deadline(&req.reply, req.submitted, stats, watchdog, "in queue");
        } else {
            live.push(req);
        }
    }
    live
}

/// The live-introspection pieces one server owns: the watchdog is
/// always there (lanes heartbeat through it); admin endpoint, flight
/// recorder and the background checker thread are opt-in via
/// [`ServeConfig`].
struct Introspection {
    watchdog: Arc<Watchdog>,
    admin: Option<AdminServer>,
    flight: Option<Arc<FlightRecorder>>,
    checker: Option<Checker>,
}

/// Background watchdog-evaluation thread; owns the trip→flight and
/// overload→flight hooks so incidents are captured even when nobody
/// polls `/healthz`.
struct Checker {
    stop: Arc<AtomicBool>,
    thread: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl Checker {
    /// Stop and join; idempotent (`shutdown()` + `Drop` both call it).
    fn stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
        let mut g = match self.thread.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        if let Some(h) = g.take() {
            let _ = h.join();
        }
    }
}

/// Watchdog evaluation cadence: a fraction of the threshold so trips
/// are detected promptly, clamped so shutdown join latency and idle
/// wakeups both stay bounded.
fn checker_interval(threshold: Duration) -> Duration {
    (threshold / 4).clamp(Duration::from_millis(10), Duration::from_millis(250))
}

fn spawn_checker(
    watchdog: Arc<Watchdog>,
    stats: Arc<ServeStats>,
    flight: Option<Arc<FlightRecorder>>,
    threshold: Duration,
) -> Checker {
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = Arc::clone(&stop);
    let interval = checker_interval(threshold);
    let thread = std::thread::Builder::new()
        .name("tfgnn-watchdog".to_string())
        .spawn(move || {
            let mut last_rejected = stats.snapshot().rejected;
            while !stop2.load(Ordering::SeqCst) {
                std::thread::sleep(interval);
                if stop2.load(Ordering::SeqCst) {
                    break;
                }
                let snap = stats.snapshot();
                let (report, tripped) = watchdog.evaluate(snap.queue_depth);
                if let Some(f) = &flight {
                    if tripped {
                        f.record("watchdog-trip", &report.reasons.join("; "));
                    }
                    if snap.rejected > last_rejected {
                        f.record(
                            "overload",
                            &format!(
                                "{} requests rejected by admission control since \
                                 the last watchdog tick",
                                snap.rejected - last_rejected
                            ),
                        );
                    }
                }
                last_rejected = snap.rejected;
            }
        });
    match thread {
        Ok(h) => Checker { stop, thread: Mutex::new(Some(h)) },
        // Spawn failure (resource exhaustion): serve without the
        // checker rather than failing the server.
        Err(_) => Checker { stop, thread: Mutex::new(None) },
    }
}

fn status_closure(
    cfg: &ServeConfig,
    lanes: usize,
    stats: &Arc<ServeStats>,
    watchdog: &Arc<Watchdog>,
    generation: &Arc<dyn Fn() -> u64 + Send + Sync>,
    flight: Option<&Arc<FlightRecorder>>,
) -> Arc<dyn Fn() -> Json + Send + Sync> {
    let start = Instant::now();
    let stats = Arc::clone(stats);
    let watchdog = Arc::clone(watchdog);
    let generation = Arc::clone(generation);
    let flight = flight.map(Arc::clone);
    let label = cfg.config_label.clone();
    let queue_capacity = cfg.queue_capacity;
    let deadline_ms = cfg.default_deadline_ms;
    Arc::new(move || {
        let snap = stats.snapshot();
        let report = watchdog.check(snap.queue_depth);
        let int = |v: u64| Json::Int(i64::try_from(v).unwrap_or(i64::MAX));
        obj(vec![
            ("schema", Json::Str("tfgnn_statusz_v1".to_string())),
            ("uptime_secs", Json::Num(start.elapsed().as_secs_f64())),
            ("config", label.clone().map(Json::Str).unwrap_or(Json::Null)),
            ("generation", int(generation())),
            ("lanes", int(lanes as u64)),
            ("queue_capacity", int(queue_capacity as u64)),
            ("queue_depth", Json::Int(snap.queue_depth)),
            ("default_deadline_ms", int(deadline_ms)),
            ("requests", int(snap.requests)),
            ("batches", int(snap.batches)),
            ("failed_batches", int(snap.failed_batches)),
            ("rejected", int(snap.rejected)),
            ("deadline_expired", int(snap.deadline_expired)),
            ("cache_hits", int(snap.cache_hits)),
            ("cache_misses", int(snap.cache_misses)),
            ("swaps", int(snap.swaps)),
            ("healthy", Json::Bool(report.healthy)),
            ("watchdog_trips", int(report.trips)),
            ("deadline_misses", int(report.deadline_misses)),
            // When did the checker thread last *evaluate* the watchdog
            // (vs this poll's `check`)? Null until the first tick — a
            // stale stamp here means the checker itself wedged.
            (
                "watchdog_last_eval_unix_secs",
                watchdog.last_eval_unix_secs().map_or(Json::Null, int),
            ),
            // Incident dumps the rate limiter swallowed (null = no
            // flight recorder armed). A growing count with no new
            // files on disk is the "storm behind one dump" signal.
            (
                "flight_suppressed",
                flight.as_ref().map_or(Json::Null, |f| int(f.suppressed())),
            ),
        ])
    })
}

/// Start the live-introspection pieces for one server: the watchdog
/// (always), the admin endpoint (`cfg.admin_addr`), the flight
/// recorder (`cfg.incident_dir`), and — whenever either of the latter
/// is on — a checker thread that periodically evaluates the watchdog
/// (so trips are counted even when nobody polls `/healthz`) and
/// triggers flight dumps on trips and overload bursts.
fn start_introspection(
    cfg: &ServeConfig,
    lanes: usize,
    stats: &Arc<ServeStats>,
    generation: Arc<dyn Fn() -> u64 + Send + Sync>,
) -> Result<Introspection> {
    let watchdog = Arc::new(Watchdog::new(cfg.watchdog_threshold));
    crate::obs_gauge!(crate::obs::metrics::names::SERVE_GENERATION)
        .set(i64::try_from(generation()).unwrap_or(i64::MAX));
    let flight = match &cfg.incident_dir {
        Some(dir) => Some(Arc::new(FlightRecorder::new(dir)?)),
        None => None,
    };
    let admin = match &cfg.admin_addr {
        Some(addr) => {
            let healthz: Arc<dyn Fn() -> HealthReport + Send + Sync> = {
                let watchdog = Arc::clone(&watchdog);
                let stats = Arc::clone(stats);
                Arc::new(move || watchdog.check(stats.snapshot().queue_depth))
            };
            let statusz =
                status_closure(cfg, lanes, stats, &watchdog, &generation, flight.as_ref());
            Some(AdminServer::start(addr, AdminState { healthz, statusz })?)
        }
        None => None,
    };
    let checker = if admin.is_some() || flight.is_some() {
        Some(spawn_checker(
            Arc::clone(&watchdog),
            Arc::clone(stats),
            flight.clone(),
            cfg.watchdog_threshold,
        ))
    } else {
        None
    };
    Ok(Introspection { watchdog, admin, flight, checker })
}

/// Which lane (if any) should inject the configured test stall.
fn stall_for_lane(cfg: &ServeConfig, lane: usize) -> Option<Duration> {
    match cfg.debug_stall {
        Some((l, d)) if l == lane => Some(d),
        _ => None,
    }
}

/// The configured default deadline as a `Duration` (0 ms = none).
fn default_deadline(cfg: &ServeConfig) -> Option<Duration> {
    if cfg.default_deadline_ms > 0 {
        Some(Duration::from_millis(cfg.default_deadline_ms))
    } else {
        None
    }
}

/// Client handle: submit requests, then [`shutdown`](Self::shutdown).
///
/// The handle is `Sync` — closed-loop clients share one handle across
/// threads (`std::thread::scope`) — and dropping it shuts the server
/// down with the same draining contract as an explicit `shutdown()`.
pub struct ServerHandle {
    queue: Arc<BoundedQueue<Request>>,
    lanes: Mutex<Vec<std::thread::JoinHandle<()>>>,
    pub stats: Arc<ServeStats>,
    /// The swappable model slot (`None` on the AOT backend, whose
    /// params are uploaded to the device once at startup).
    slot: Option<Arc<ModelSlot>>,
    default_deadline: Option<Duration>,
    watchdog: Arc<Watchdog>,
    admin: Option<AdminServer>,
    #[allow(dead_code)]
    flight: Option<Arc<FlightRecorder>>,
    checker: Option<Checker>,
}

impl ServerHandle {
    /// Submit a request; returns the channel the response arrives on.
    /// Admission control replies immediately with
    /// [`Error::Overloaded`] when the queue is full, and with a
    /// structured runtime error after shutdown — the caller's `recv`
    /// always gets an answer, it never hangs on a dead channel.
    pub fn submit(&self, seed: u32) -> Receiver<Result<Response>> {
        self.submit_with_deadline(seed, None)
    }

    /// [`submit`](Self::submit) with a per-request deadline override
    /// (`None` falls back to `ServeConfig::default_deadline_ms`). A
    /// request whose budget runs out before a lane executes it is
    /// answered [`Error::DeadlineExceeded`]; `Duration::ZERO` expires
    /// deterministically at admission, without ever being queued.
    pub fn submit_with_deadline(
        &self,
        seed: u32,
        deadline: Option<Duration>,
    ) -> Receiver<Result<Response>> {
        let submitted = Instant::now();
        let deadline = deadline.or(self.default_deadline).map(|d| submitted + d);
        let (reply_tx, reply_rx) = channel();
        let req = Request { seed, submitted, deadline, reply: reply_tx };
        if req.deadline.is_some_and(|d| Instant::now() >= d) {
            // Dead on arrival: answered without ever being admitted,
            // so no depth bookkeeping.
            reply_deadline(&req.reply, req.submitted, &self.stats, &self.watchdog, "at admission");
            return reply_rx;
        }
        match self.queue.push(req) {
            Ok(()) => self.stats.admitted(),
            Err(PushError::Full(req)) => {
                self.stats.rejected();
                record_outcome(Outcome::Rejected, req.submitted);
                let _ = req.reply.send(Err(Error::Overloaded(format!(
                    "serving queue full ({} pending); retry with backoff",
                    self.queue.capacity()
                ))));
            }
            Err(PushError::Closed(req)) => {
                let _ = req
                    .reply
                    .send(Err(Error::Runtime("server is shut down".into())));
            }
        }
        reply_rx
    }

    /// Convenience: submit and wait.
    pub fn predict(&self, seed: u32) -> Result<Response> {
        self.submit(seed)
            .recv()
            .map_err(|_| Error::Runtime("server dropped request".into()))?
    }

    /// Stop accepting requests and join the lanes. Requests admitted
    /// before the call are still executed and answered (lanes drain
    /// the queue before exiting). Idempotent; later `submit`s get a
    /// structured error.
    pub fn shutdown(&self) {
        close_and_join(&self.queue, &self.lanes);
        if let Some(c) = &self.checker {
            c.stop();
        }
        if let Some(a) = &self.admin {
            a.stop();
        }
    }

    /// The admin endpoint's actually-bound address, when one was
    /// configured (`None` otherwise). Resolves port 0.
    pub fn admin_addr(&self) -> Option<std::net::SocketAddr> {
        self.admin.as_ref().map(|a| a.local_addr())
    }

    /// Point-in-time watchdog verdict — the same report `/healthz`
    /// serves, available without an admin endpoint.
    pub fn health(&self) -> HealthReport {
        self.watchdog.check(self.stats.snapshot().queue_depth)
    }

    /// Hot-swap the served model (native backends only). In-flight
    /// waves finish on the old weights; later waves pick up the new
    /// ones — no batch ever mixes the two. Returns the new generation.
    pub fn swap_model(&self, model: Arc<NativeModel>) -> Result<u64> {
        let slot = self.require_slot()?;
        let generation = slot.swap_model(model)?;
        self.stats.swapped(generation);
        Ok(generation)
    }

    /// Hot-swap to the weights in a checkpoint file (native only).
    pub fn swap_checkpoint(&self, path: &std::path::Path) -> Result<u64> {
        let slot = self.require_slot()?;
        let generation = slot.swap_checkpoint(path)?;
        self.stats.swapped(generation);
        Ok(generation)
    }

    /// Current model generation (1 until the first swap; the AOT
    /// backend is pinned at 1).
    pub fn generation(&self) -> u64 {
        self.slot.as_ref().map(|s| s.generation()).unwrap_or(1)
    }

    fn require_slot(&self) -> Result<&Arc<ModelSlot>> {
        self.slot.as_ref().ok_or_else(|| {
            Error::Runtime(
                "hot-swap is only supported on native servers (AOT params \
                 are uploaded to the device at startup)"
                    .into(),
            )
        })
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Shared shutdown path: close admissions, then join every lane
/// exactly once (the vec is drained under its lock, so concurrent
/// `shutdown()` + `Drop` cannot double-join).
fn close_and_join<T>(
    queue: &BoundedQueue<T>,
    lanes: &Mutex<Vec<std::thread::JoinHandle<()>>>,
) {
    queue.close();
    let mut joined = match lanes.lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    };
    for h in joined.drain(..) {
        let _ = h.join();
    }
}

/// Fan one executed logits wave back out to its requesters (or fan the
/// wave's error to every request), updating failure counters, outcome
/// histograms and — when armed — the incident flight recorder.
fn reply_logits_wave(
    wave: Vec<Request>,
    result: Result<(Vec<f32>, usize)>,
    generation: u64,
    stats: &ServeStats,
    flight: Option<&Arc<FlightRecorder>>,
) {
    let batch_size = wave.len();
    match result {
        Ok((flat, classes)) => {
            let has_all_rows = flat.len() >= batch_size * classes && classes > 0;
            if !has_all_rows {
                stats.replied(batch_size);
                stats.wave_failed();
                let msg = format!(
                    "executor returned {} logits for {batch_size} requests x {classes} classes",
                    flat.len()
                );
                if let Some(f) = flight {
                    f.record("failed-batch", &msg);
                }
                for req in wave {
                    record_outcome(Outcome::Failed, req.submitted);
                    let _ = req.reply.send(Err(Error::Runtime(msg.clone())));
                }
                return;
            }
            for (k, req) in wave.into_iter().enumerate() {
                let row = flat[k * classes..(k + 1) * classes].to_vec();
                let predicted = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(i, _)| i)
                    .unwrap_or(0);
                let latency = req.submitted.elapsed();
                record_outcome_latency(Outcome::Ok, latency);
                let resp = Response {
                    seed: req.seed,
                    predicted,
                    logits: row,
                    latency,
                    batch_size,
                    generation,
                };
                let _ = req.reply.send(Ok(resp));
            }
        }
        Err(e) => {
            stats.wave_failed();
            let msg = e.to_string();
            if let Some(f) = flight {
                f.record("failed-batch", &msg);
            }
            for req in wave {
                record_outcome(Outcome::Failed, req.submitted);
                let _ = req.reply.send(Err(Error::Runtime(msg.clone())));
            }
        }
    }
    stats.replied(batch_size);
}

/// Build and start the AOT server.
///
/// PJRT handles are not `Send`, so the single execution lane constructs
/// its own client, compiles `forward`, and uploads the params itself;
/// this function only passes plain data (paths, specs, host tensors)
/// across the thread boundary and waits for the lane's startup report.
/// Admission control (bounded queue, `Error::Overloaded`) applies the
/// same as on the native backends; `cfg.lanes` is ignored.
pub fn serve(
    artifacts_dir: &std::path::Path,
    entry: &ModelEntry,
    params: Vec<(String, HostTensor)>,
    sampler: Arc<InMemorySampler>,
    pad: PadSpec,
    task: RootTask,
    cfg: ServeConfig,
) -> Result<ServerHandle> {
    let forward_spec = entry.program("forward")?.clone();
    let dir = artifacts_dir.to_path_buf();
    let stats = Arc::new(ServeStats::default());
    let queue: Arc<BoundedQueue<Request>> = Arc::new(BoundedQueue::new(cfg.queue_capacity));
    // AOT generation is pinned at 1 (no hot-swap slot).
    let intro = start_introspection(&cfg, 1, &stats, Arc::new(|| 1))?;
    let beat = intro.watchdog.register_lane(0);
    let watchdog_w = Arc::clone(&intro.watchdog);
    let flight_w = intro.flight.clone();
    let stall = stall_for_lane(&cfg, 0);
    let (ready_tx, ready_rx) = channel::<Result<()>>();
    let stats_w = Arc::clone(&stats);
    let queue_w = Arc::clone(&queue);
    let max_batch = cfg.max_batch;
    let max_wait = cfg.max_wait;
    let wave_delay = cfg.wave_delay;
    let sampler_cfg = cfg.sampler.clone();
    let worker = std::thread::Builder::new()
        .name("tfgnn-serve".into())
        .spawn(move || {
            // Build the PJRT world inside the thread (handles are !Send).
            let setup = (|| -> Result<(Runtime, Program, Vec<xla::Literal>)> {
                let rt = Runtime::cpu()?;
                let forward = rt.load_program(&dir, &forward_spec)?;
                // Forward may have a pruned signature (dead params
                // dropped by jax); resolve each param slot by name from
                // the full checkpoint/trainer param list.
                let by_name: std::collections::BTreeMap<&str, &HostTensor> =
                    params.iter().map(|(n, t)| (n.as_str(), t)).collect();
                let mut param_lits = Vec::new();
                for spec in &forward.spec.inputs {
                    if !spec.name.starts_with("param.") {
                        continue;
                    }
                    let t = by_name.get(spec.name.as_str()).ok_or_else(|| {
                        Error::Runtime(format!("server params missing slot {}", spec.name))
                    })?;
                    if !t.matches(spec) {
                        return Err(Error::Runtime(format!(
                            "param {} does not match forward slot shape",
                            spec.name
                        )));
                    }
                    param_lits.push(host_to_literal(t)?);
                }
                Ok((rt, forward, param_lits))
            })();
            match setup {
                Ok((rt, forward, param_bufs)) => {
                    let _ = ready_tx.send(Ok(()));
                    // The sampling pool outlives every wave: spawn once.
                    let pool = if sampler_cfg.parallel() {
                        Some(ThreadPool::new(sampler_cfg.threads))
                    } else {
                        None
                    };
                    lane_loop(&queue_w, max_batch, max_wait, |wave| {
                        beat.begin();
                        if let Some(d) = stall {
                            std::thread::sleep(d);
                        }
                        let wave = expire_overdue_logits(wave, &stats_w, &watchdog_w);
                        if !wave.is_empty() {
                            let _wave_span = crate::span!("serve/wave", size = wave.len());
                            let _wave_timer = crate::obs::timed(crate::obs_histogram!(
                                crate::obs::metrics::names::SERVE_WAVE_SECONDS
                            ));
                            stats_w.wave_start(wave.len() as u64);
                            if !wave_delay.is_zero() {
                                std::thread::sleep(wave_delay);
                            }
                            let seeds: Vec<u32> = wave.iter().map(|r| r.seed).collect();
                            let result = execute_wave(
                                &rt,
                                &forward,
                                &param_bufs,
                                &sampler,
                                pool.as_ref(),
                                &pad,
                                &task,
                                &seeds,
                            );
                            reply_logits_wave(wave, result, 1, &stats_w, flight_w.as_ref());
                        }
                        beat.end();
                    });
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                }
            }
        })?;
    ready_rx
        .recv()
        .map_err(|_| Error::Runtime("server thread died during startup".into()))??;
    Ok(ServerHandle {
        queue,
        lanes: Mutex::new(vec![worker]),
        stats,
        slot: None,
        default_deadline: default_deadline(&cfg),
        watchdog: intro.watchdog,
        admin: intro.admin,
        flight: intro.flight,
        checker: intro.checker,
    })
}

/// Start a server over the pure-Rust native model — no AOT artifacts,
/// no PJRT, no padding: each sampled subgraph runs the fused forward
/// directly and contributes its root's logits row. `cfg.lanes` batcher
/// threads pull from the shared bounded queue; each lane snapshots the
/// hot-swappable model once per wave.
///
/// The model config is re-checked through the static analyzer
/// ([`crate::analysis::check_model`]) before the lanes spawn, so a
/// bad config is rejected with the same `TFGNN0xx` diagnostics the
/// `tfgnn check` CLI prints.
pub fn serve_native(
    model: Arc<NativeModel>,
    sampler: Arc<InMemorySampler>,
    task: RootTask,
    cfg: ServeConfig,
) -> Result<ServerHandle> {
    crate::analysis::check_model(&model.cfg).into_result()?;
    let num_classes = model.cfg.num_classes;
    let stats = Arc::new(ServeStats::default());
    let queue: Arc<BoundedQueue<Request>> = Arc::new(BoundedQueue::new(cfg.queue_capacity));
    let slot = Arc::new(ModelSlot::new(model));
    let generation: Arc<dyn Fn() -> u64 + Send + Sync> = {
        let slot = Arc::clone(&slot);
        Arc::new(move || slot.generation())
    };
    let intro = start_introspection(&cfg, cfg.lanes.max(1), &stats, generation)?;
    let mut lanes = Vec::new();
    for lane in 0..cfg.lanes.max(1) {
        let queue = Arc::clone(&queue);
        let stats = Arc::clone(&stats);
        let slot = Arc::clone(&slot);
        let sampler = Arc::clone(&sampler);
        let task = task.clone();
        let sampler_cfg = cfg.sampler.clone();
        let (max_batch, max_wait, wave_delay) = (cfg.max_batch, cfg.max_wait, cfg.wave_delay);
        let beat = intro.watchdog.register_lane(lane);
        let watchdog = Arc::clone(&intro.watchdog);
        let flight = intro.flight.clone();
        let stall = stall_for_lane(&cfg, lane);
        lanes.push(
            std::thread::Builder::new()
                .name(format!("tfgnn-serve-native-{lane}"))
                .spawn(move || {
                    let pool = if sampler_cfg.parallel() {
                        Some(ThreadPool::new(sampler_cfg.threads))
                    } else {
                        None
                    };
                    lane_loop(&queue, max_batch, max_wait, |wave| {
                        beat.begin();
                        if let Some(d) = stall {
                            std::thread::sleep(d);
                        }
                        let wave = expire_overdue_logits(wave, &stats, &watchdog);
                        if wave.is_empty() {
                            beat.end();
                            return;
                        }
                        let _wave_span = crate::span!("serve/wave", size = wave.len());
                        let _wave_timer = crate::obs::timed(crate::obs_histogram!(
                            crate::obs::metrics::names::SERVE_WAVE_SECONDS
                        ));
                        stats.wave_start(wave.len() as u64);
                        if !wave_delay.is_zero() {
                            std::thread::sleep(wave_delay);
                        }
                        // One model snapshot for the whole wave: a batch
                        // never mixes params from two generations.
                        let vm = slot.load();
                        let seeds: Vec<u32> = wave.iter().map(|r| r.seed).collect();
                        let result = (|| -> Result<(Vec<f32>, usize)> {
                            let graphs = match &pool {
                                Some(p) => sampler.sample_batch_with_pool(&seeds, p)?,
                                None => seeds
                                    .iter()
                                    .map(|&s| sampler.sample(s))
                                    .collect::<Result<Vec<_>>>()?,
                            };
                            let mut flat = Vec::with_capacity(seeds.len() * num_classes);
                            for g in &graphs {
                                let logits =
                                    vm.model.forward_logits(g, &task.root_set, &[0])?;
                                flat.extend_from_slice(&logits.data);
                            }
                            Ok((flat, num_classes))
                        })();
                        reply_logits_wave(wave, result, vm.generation, &stats, flight.as_ref());
                        beat.end();
                    });
                })?,
        );
    }
    Ok(ServerHandle {
        queue,
        lanes: Mutex::new(lanes),
        stats,
        slot: Some(slot),
        default_deadline: default_deadline(&cfg),
        watchdog: intro.watchdog,
        admin: intro.admin,
        flight: intro.flight,
        checker: intro.checker,
    })
}

/// A completed task-shaped prediction (see [`serve_task`]).
#[derive(Debug, Clone)]
pub struct TaskResponse {
    /// The request's seed list (`[root]` for root tasks, `[source,
    /// target]` for link prediction).
    pub seeds: Vec<u32>,
    pub output: crate::tasks::TaskOutput,
    /// Time from submit to response.
    pub latency: Duration,
    /// Requests in the same executed batch.
    pub batch_size: usize,
    /// Which model answered: the serving slot's swap generation
    /// (1 until the first hot-swap).
    pub generation: u64,
}

struct TaskRequest {
    seeds: Vec<u32>,
    submitted: Instant,
    /// Absolute expiry; a lane answers `DeadlineExceeded` instead of
    /// executing once this passes.
    deadline: Option<Instant>,
    reply: Sender<Result<TaskResponse>>,
}

/// Client handle for a task server: submit seed lists, then
/// [`shutdown`](Self::shutdown). Same admission, draining, deadline,
/// introspection and hot-swap contracts as [`ServerHandle`].
pub struct TaskServerHandle {
    queue: Arc<BoundedQueue<TaskRequest>>,
    lanes: Mutex<Vec<std::thread::JoinHandle<()>>>,
    pub stats: Arc<ServeStats>,
    slot: Arc<ModelSlot>,
    default_deadline: Option<Duration>,
    watchdog: Arc<Watchdog>,
    admin: Option<AdminServer>,
    #[allow(dead_code)]
    flight: Option<Arc<FlightRecorder>>,
    checker: Option<Checker>,
}

impl TaskServerHandle {
    /// Submit a request; returns the channel the response arrives on.
    /// A full queue replies [`Error::Overloaded`] immediately; a
    /// shut-down server replies a structured runtime error — `recv`
    /// never hangs on a dead channel.
    pub fn submit(&self, seeds: Vec<u32>) -> Receiver<Result<TaskResponse>> {
        self.submit_with_deadline(seeds, None)
    }

    /// [`submit`](Self::submit) with a per-request deadline override;
    /// see [`ServerHandle::submit_with_deadline`].
    pub fn submit_with_deadline(
        &self,
        seeds: Vec<u32>,
        deadline: Option<Duration>,
    ) -> Receiver<Result<TaskResponse>> {
        let submitted = Instant::now();
        let deadline = deadline.or(self.default_deadline).map(|d| submitted + d);
        let (reply_tx, reply_rx) = channel();
        let req = TaskRequest { seeds, submitted, deadline, reply: reply_tx };
        if req.deadline.is_some_and(|d| Instant::now() >= d) {
            // Dead on arrival: answered without ever being admitted,
            // so no depth bookkeeping.
            reply_deadline(&req.reply, req.submitted, &self.stats, &self.watchdog, "at admission");
            return reply_rx;
        }
        match self.queue.push(req) {
            Ok(()) => self.stats.admitted(),
            Err(PushError::Full(req)) => {
                self.stats.rejected();
                record_outcome(Outcome::Rejected, req.submitted);
                let _ = req.reply.send(Err(Error::Overloaded(format!(
                    "serving queue full ({} pending); retry with backoff",
                    self.queue.capacity()
                ))));
            }
            Err(PushError::Closed(req)) => {
                let _ = req
                    .reply
                    .send(Err(Error::Runtime("server is shut down".into())));
            }
        }
        reply_rx
    }

    /// Convenience: submit and wait.
    pub fn predict(&self, seeds: &[u32]) -> Result<TaskResponse> {
        self.submit(seeds.to_vec())
            .recv()
            .map_err(|_| Error::Runtime("server dropped request".into()))?
    }

    /// Stop accepting requests and join the lanes; already-admitted
    /// requests are still answered. Idempotent.
    pub fn shutdown(&self) {
        close_and_join(&self.queue, &self.lanes);
        if let Some(c) = &self.checker {
            c.stop();
        }
        if let Some(a) = &self.admin {
            a.stop();
        }
    }

    /// The admin endpoint's actually-bound address, when one was
    /// configured (`None` otherwise). Resolves port 0.
    pub fn admin_addr(&self) -> Option<std::net::SocketAddr> {
        self.admin.as_ref().map(|a| a.local_addr())
    }

    /// Point-in-time watchdog verdict — the same report `/healthz`
    /// serves, available without an admin endpoint.
    pub fn health(&self) -> HealthReport {
        self.watchdog.check(self.stats.snapshot().queue_depth)
    }

    /// Hot-swap the served model; see [`ServerHandle::swap_model`].
    pub fn swap_model(&self, model: Arc<NativeModel>) -> Result<u64> {
        let generation = self.slot.swap_model(model)?;
        self.stats.swapped(generation);
        Ok(generation)
    }

    /// Hot-swap to the weights in a checkpoint file.
    pub fn swap_checkpoint(&self, path: &std::path::Path) -> Result<u64> {
        let generation = self.slot.swap_checkpoint(path)?;
        self.stats.swapped(generation);
        Ok(generation)
    }

    /// Current model generation (1 until the first swap).
    pub fn generation(&self) -> u64 {
        self.slot.generation()
    }
}

impl Drop for TaskServerHandle {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Start a task-shaped native server: each request names a seed list,
/// a lane samples the wave's subgraphs (through the seed-keyed LRU
/// cache when `cfg.cache_capacity > 0`, fanned over the lane's
/// sampling pool when configured) and the [`Task`](crate::tasks::Task)
/// maps each to its response — classification logits, a pair's link
/// score, or a regression value. Errors are per-request: one bad pair
/// does not fail its wave-mates (a wave with any error still counts
/// one `failed_batches`).
///
/// Like [`serve_native`], the model config is gated through
/// [`crate::analysis::check_model`] before anything spawns.
pub fn serve_task(
    model: Arc<NativeModel>,
    sampler: Arc<InMemorySampler>,
    task: Arc<dyn crate::tasks::Task>,
    cfg: ServeConfig,
) -> Result<TaskServerHandle> {
    crate::analysis::check_model(&model.cfg).into_result()?;
    let stats = Arc::new(ServeStats::default());
    let queue: Arc<BoundedQueue<TaskRequest>> = Arc::new(BoundedQueue::new(cfg.queue_capacity));
    let slot = Arc::new(ModelSlot::new(model));
    let generation: Arc<dyn Fn() -> u64 + Send + Sync> = {
        let slot = Arc::clone(&slot);
        Arc::new(move || slot.generation())
    };
    let intro = start_introspection(&cfg, cfg.lanes.max(1), &stats, generation)?;
    // The subgraph cache is shared by all lanes (it is seed-keyed and
    // model-independent, so it survives hot-swaps too).
    let cache: Arc<LruCache<Vec<u32>, Arc<GraphTensor>>> =
        Arc::new(LruCache::new(cfg.cache_capacity));
    let mut lanes = Vec::new();
    for lane in 0..cfg.lanes.max(1) {
        let queue = Arc::clone(&queue);
        let stats = Arc::clone(&stats);
        let slot = Arc::clone(&slot);
        let sampler = Arc::clone(&sampler);
        let task = Arc::clone(&task);
        let cache = Arc::clone(&cache);
        let sampler_cfg = cfg.sampler.clone();
        let (max_batch, max_wait, wave_delay) = (cfg.max_batch, cfg.max_wait, cfg.wave_delay);
        let beat = intro.watchdog.register_lane(lane);
        let watchdog = Arc::clone(&intro.watchdog);
        let flight = intro.flight.clone();
        let stall = stall_for_lane(&cfg, lane);
        lanes.push(
            std::thread::Builder::new()
                .name(format!("tfgnn-serve-task-{lane}"))
                .spawn(move || {
                    let pool = if sampler_cfg.parallel() {
                        Some(ThreadPool::new(sampler_cfg.threads))
                    } else {
                        None
                    };
                    lane_loop(&queue, max_batch, max_wait, |wave| {
                        beat.begin();
                        if let Some(d) = stall {
                            std::thread::sleep(d);
                        }
                        let wave = expire_overdue_task(wave, &stats, &watchdog);
                        if !wave.is_empty() {
                            run_task_wave(
                                wave,
                                &slot,
                                &sampler,
                                task.as_ref(),
                                &cache,
                                pool.as_ref(),
                                wave_delay,
                                &stats,
                                flight.as_ref(),
                            );
                        }
                        beat.end();
                    });
                })?,
        );
    }
    Ok(TaskServerHandle {
        queue,
        lanes: Mutex::new(lanes),
        stats,
        slot,
        default_deadline: default_deadline(&cfg),
        watchdog: intro.watchdog,
        admin: intro.admin,
        flight: intro.flight,
        checker: intro.checker,
    })
}

/// Execute one task-server wave: cache-checked sampling, one model
/// snapshot for the whole wave, per-request structured errors.
#[allow(clippy::too_many_arguments)]
fn run_task_wave(
    wave: Vec<TaskRequest>,
    slot: &ModelSlot,
    sampler: &Arc<InMemorySampler>,
    task: &dyn crate::tasks::Task,
    cache: &LruCache<Vec<u32>, Arc<GraphTensor>>,
    pool: Option<&ThreadPool>,
    wave_delay: Duration,
    stats: &ServeStats,
    flight: Option<&Arc<FlightRecorder>>,
) {
    let _wave_span = crate::span!("serve/wave", size = wave.len());
    let _wave_timer =
        crate::obs::timed(crate::obs_histogram!(crate::obs::metrics::names::SERVE_WAVE_SECONDS));
    stats.wave_start(wave.len() as u64);
    if !wave_delay.is_zero() {
        std::thread::sleep(wave_delay);
    }
    // One model snapshot for the whole wave: a batch never mixes
    // params from two generations.
    let vm = slot.load();
    let batch_size = wave.len();

    // Resolve each request's subgraph: cache hit, or queued for a
    // (possibly pooled) sampling fan-out. Slots start as placeholder
    // errors and every index is overwritten below.
    let mut graphs: Vec<Result<Arc<GraphTensor>>> = wave
        .iter()
        .map(|_| Err(Error::Runtime("internal: subgraph slot unfilled".into())))
        .collect();
    let mut miss_idx: Vec<usize> = Vec::new();
    let mut miss_lists: Vec<Vec<u32>> = Vec::new();
    let cache_enabled = cache.is_enabled();
    for (i, req) in wave.iter().enumerate() {
        if let Some(g) = cache.get(&req.seeds) {
            stats.cache_hit();
            graphs[i] = Ok(g);
        } else {
            if cache_enabled {
                stats.cache_miss();
            }
            miss_idx.push(i);
            miss_lists.push(req.seeds.clone());
        }
    }
    let sampled: Vec<Result<GraphTensor>> = match pool {
        Some(p) => {
            let s = Arc::clone(sampler);
            p.map(miss_lists.clone(), move |seeds| s.sample_seeds(&seeds))
        }
        None => miss_lists.iter().map(|s| sampler.sample_seeds(s)).collect(),
    };
    for (k, res) in sampled.into_iter().enumerate() {
        let i = miss_idx[k];
        match res {
            Ok(g) => {
                let g = Arc::new(g);
                if cache_enabled {
                    let evicted = cache.put(miss_lists[k].clone(), Arc::clone(&g));
                    stats.cache_evicted(evicted as u64);
                }
                graphs[i] = Ok(g);
            }
            Err(e) => graphs[i] = Err(e),
        }
    }

    // Readout + per-request replies. The first failure's message is
    // kept as the flight-recorder detail.
    let mut first_failure: Option<String> = None;
    for (req, g) in wave.into_iter().zip(graphs) {
        let out = g.and_then(|g| task.infer(&vm.model, &g));
        match out {
            Ok(output) => {
                let latency = req.submitted.elapsed();
                record_outcome_latency(Outcome::Ok, latency);
                let _ = req.reply.send(Ok(TaskResponse {
                    seeds: req.seeds,
                    output,
                    latency,
                    batch_size,
                    generation: vm.generation,
                }));
            }
            Err(e) => {
                let msg = e.to_string();
                if first_failure.is_none() {
                    first_failure = Some(msg.clone());
                }
                record_outcome(Outcome::Failed, req.submitted);
                let _ = req.reply.send(Err(Error::Runtime(msg)));
            }
        }
    }
    stats.replied(batch_size);
    if let Some(msg) = first_failure {
        stats.wave_failed();
        if let Some(f) = flight {
            f.record("failed-batch", &msg);
        }
    }
}

/// Sample, merge, pad, execute one wave on the AOT program; returns
/// (flat logits, classes).
#[allow(clippy::too_many_arguments)]
fn execute_wave(
    rt: &Runtime,
    forward: &Program,
    param_bufs: &[xla::Literal],
    sampler: &InMemorySampler,
    pool: Option<&ThreadPool>,
    pad: &PadSpec,
    task: &RootTask,
    seeds: &[u32],
) -> Result<(Vec<f32>, usize)> {
    // The whole wave of roots samples as one batch — fanned out over
    // the sampling pool when configured, serially otherwise; either
    // way the subgraphs are identical, in request order.
    let graphs = match pool {
        Some(p) => sampler.sample_batch_with_pool(seeds, p)?,
        None => seeds
            .iter()
            .map(|&s| sampler.sample(s))
            .collect::<Result<Vec<_>>>()?,
    };
    let merged = crate::graph::batch::merge(&graphs)?;
    let padded = fit_or_skip(&merged, pad)
        .ok_or_else(|| Error::Runtime("request wave exceeds pad caps".into()))?;
    let inputs = &forward.spec.inputs;
    let batch = build_batch(&padded, task, inputs)?;
    let mut batch_lits = Vec::with_capacity(batch.len());
    for (idx, t) in &batch {
        batch_lits.push((*idx, host_to_literal(t)?));
    }
    let _ = rt;
    let mut args: Vec<&xla::Literal> = Vec::with_capacity(inputs.len());
    let mut it = batch_lits.iter();
    for (i, spec) in inputs.iter().enumerate() {
        if i < param_bufs.len() {
            args.push(&param_bufs[i]);
        } else if is_batch_slot(&spec.name) {
            let (idx, lit) =
                it.next().ok_or_else(|| Error::Runtime("slots exhausted".into()))?;
            debug_assert_eq!(*idx, i);
            args.push(lit);
        } else {
            return Err(Error::Runtime(format!("unhandled forward slot {:?}", spec.name)));
        }
    }
    let outputs = forward.execute_literals(&args)?;
    let logits = literal_to_host(&outputs[0])?;
    let shape = logits.shape().to_vec();
    let HostTensor::F32(_, data) = logits else {
        return Err(Error::Runtime("logits not f32".into()));
    };
    Ok((data, shape[1]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::model_ref::ModelConfig;
    use crate::sampler::spec::mag_sampling_spec_scaled;
    use crate::synth::mag::{generate, MagConfig, Split};

    fn native_server_for(
        arch: &str,
        max_batch: usize,
        max_wait: Duration,
    ) -> (ServerHandle, Vec<u32>, usize) {
        let mag = MagConfig::tiny();
        let ds = generate(&mag);
        let seeds = ds.papers_in_split(Split::Train);
        let store = Arc::new(ds.store);
        let spec = mag_sampling_spec_scaled(&store.schema, 0.2).unwrap();
        let sampler = Arc::new(InMemorySampler::new(store, spec, 3).unwrap());
        let cfg = ModelConfig::for_mag(&mag, 8, 8, 1).with_arch(arch);
        let num_classes = cfg.num_classes;
        let model = Arc::new(NativeModel::init(cfg, 7).unwrap());
        let handle = serve_native(
            model,
            sampler,
            RootTask::default(),
            ServeConfig { max_batch, max_wait, ..ServeConfig::default() },
        )
        .unwrap();
        (handle, seeds, num_classes)
    }

    fn native_server(max_batch: usize, max_wait: Duration) -> (ServerHandle, Vec<u32>, usize) {
        native_server_for("mpnn", max_batch, max_wait)
    }

    #[test]
    fn native_server_predicts() {
        let (handle, seeds, classes) = native_server(4, Duration::from_millis(2));
        for &s in seeds.iter().take(6) {
            let resp = handle.predict(s).unwrap();
            assert_eq!(resp.seed, s);
            assert_eq!(resp.logits.len(), classes);
            assert!(resp.predicted < classes);
            assert!(resp.logits.iter().all(|v| v.is_finite()));
            assert_eq!(resp.generation, 1, "no swap happened");
        }
        let snap = handle.stats.snapshot();
        assert!(snap.requests >= 6);
        assert_eq!(snap.rejected, 0);
        assert_eq!(
            snap.cache_lookups(),
            snap.cache_hits + snap.cache_misses,
            "lookup identity"
        );
        handle.shutdown();
    }

    /// `serve_native` hosts any built model, not just the mpnn: every
    /// convolution of the zoo serves predictions through the same
    /// batcher.
    #[test]
    fn native_server_hosts_the_whole_zoo() {
        for arch in ["gcn", "sage", "gatv2"] {
            let (handle, seeds, classes) =
                native_server_for(arch, 3, Duration::from_millis(2));
            for &s in seeds.iter().take(3) {
                let resp = handle.predict(s).unwrap();
                assert_eq!(resp.logits.len(), classes, "{arch}");
                assert!(resp.logits.iter().all(|v| v.is_finite()), "{arch}");
                assert!(resp.predicted < classes, "{arch}");
            }
            handle.shutdown();
        }
    }

    /// `serve_task` answers with task-shaped responses for all three
    /// objectives — classification logits, pair link scores, regression
    /// values — over the same batcher/sampler machinery.
    #[test]
    fn task_server_serves_all_three_tasks() {
        use crate::ops::model_ref::TaskConfig;
        use crate::synth::mag::edge_holdout;
        use crate::tasks::{self, TaskOutput};

        let mag = MagConfig::tiny();
        let ds = generate(&mag);
        let seeds = ds.papers_in_split(Split::Train);
        let holdout = edge_holdout(&ds, "cites", 0.2, 9).unwrap();
        let store = Arc::new(ds.store);
        let spec = mag_sampling_spec_scaled(&store.schema, 0.2).unwrap();
        let sampler = Arc::new(InMemorySampler::new(store, spec, 3).unwrap());
        let serve_cfg = || ServeConfig {
            max_batch: 3,
            max_wait: Duration::from_millis(2),
            ..ServeConfig::default()
        };

        // Root classification.
        let cfg = ModelConfig::for_mag(&mag, 8, 8, 1);
        let task = tasks::build(&cfg).unwrap();
        let model = Arc::new(NativeModel::init(cfg, 7).unwrap());
        let handle = serve_task(model, Arc::clone(&sampler), task, serve_cfg()).unwrap();
        let resp = handle.predict(&[seeds[0]]).unwrap();
        let TaskOutput::Classification { logits, predicted } = resp.output else {
            panic!("want classification output");
        };
        assert_eq!(logits.len(), mag.num_classes);
        assert!(predicted < mag.num_classes);
        handle.shutdown();

        // Link prediction (pair requests; sampler over the holdout
        // store so held-out edges stay unseen).
        let lp_store = Arc::new(holdout.store);
        let lp_spec = mag_sampling_spec_scaled(&lp_store.schema, 0.2).unwrap();
        let lp_sampler = Arc::new(InMemorySampler::new(lp_store, lp_spec, 3).unwrap());
        let cfg = ModelConfig::for_mag(&mag, 8, 8, 1).with_task(TaskConfig {
            kind: "link_prediction".into(),
            readout: "dot".into(),
            ..TaskConfig::default()
        });
        let task = tasks::build(&cfg).unwrap();
        let model = Arc::new(NativeModel::init(cfg, 7).unwrap());
        let handle = serve_task(model, lp_sampler, task, serve_cfg()).unwrap();
        let (u, v) = holdout.test[0];
        let resp = handle.predict(&[u, v]).unwrap();
        let TaskOutput::LinkScore { score } = resp.output else {
            panic!("want link score output");
        };
        assert!(score.is_finite());
        assert_eq!(resp.seeds, vec![u, v]);
        // A degenerate pair fails its request, not the server.
        assert!(handle.predict(&[u, u]).is_err());
        let again = handle.predict(&[u, v]).unwrap();
        let TaskOutput::LinkScore { score: s2 } = again.output else { panic!() };
        assert_eq!(s2.to_bits(), score.to_bits(), "deterministic rescoring");
        assert!(handle.stats.snapshot().failed_batches >= 1);
        handle.shutdown();

        // Graph regression.
        let cfg = ModelConfig::for_mag(&mag, 8, 8, 1).with_task(TaskConfig {
            kind: "graph_regression".into(),
            target_shift: 2010.0,
            target_scale: 0.1,
            ..TaskConfig::default()
        });
        let task = tasks::build(&cfg).unwrap();
        let model = Arc::new(NativeModel::init(cfg, 7).unwrap());
        let handle = serve_task(model, sampler, task, serve_cfg()).unwrap();
        let resp = handle.predict(&[seeds[1]]).unwrap();
        let TaskOutput::Regression { value } = resp.output else {
            panic!("want regression output");
        };
        assert!(value.is_finite());
        handle.shutdown();
    }

    /// Regression: shutting the server down must NOT drop requests that
    /// were already admitted — the lanes drain the queue before the
    /// workers exit, so every pending reply channel gets a response.
    #[test]
    fn shutdown_drains_already_submitted_requests() {
        // A long max_wait so most requests are still queued (or mid
        // wave-collection) when shutdown closes the queue.
        let (handle, seeds, classes) = native_server(2, Duration::from_millis(50));
        let n = 16usize;
        let pending: Vec<_> =
            (0..n).map(|i| handle.submit(seeds[i % seeds.len()])).collect();
        // Close admissions and join the lanes immediately.
        handle.shutdown();
        // Every submitted request must still have been answered.
        for (i, rx) in pending.into_iter().enumerate() {
            let resp = rx
                .recv()
                .unwrap_or_else(|_| panic!("request {i} dropped at shutdown"))
                .unwrap_or_else(|e| panic!("request {i} failed: {e}"));
            assert_eq!(resp.logits.len(), classes);
        }
    }

    /// Submitting after shutdown returns a structured error instead of
    /// hanging on a dead channel — on both handle types.
    #[test]
    fn submit_after_shutdown_is_a_structured_error() {
        let (handle, seeds, _) = native_server(4, Duration::from_millis(2));
        handle.predict(seeds[0]).unwrap();
        handle.shutdown();
        let err = handle.predict(seeds[0]).unwrap_err();
        assert!(
            err.to_string().contains("shut down"),
            "want a shutdown error, got: {err}"
        );
    }
}
