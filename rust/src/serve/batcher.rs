//! Bounded MPMC request queue + the multi-lane batcher loop.
//!
//! The serving path decouples *admission* from *execution*:
//!
//! * [`BoundedQueue`] is the admission point. `push` never blocks — a
//!   full queue rejects the item immediately ([`PushError::Full`], which
//!   the server surfaces as [`crate::Error::Overloaded`]) so heavy
//!   traffic produces fast structured rejections instead of an unbounded
//!   backlog with unbounded latency.
//! * N batcher *lanes* (one OS thread each) pop from the shared queue,
//!   gather requests into a wave (up to `max_batch`, waiting at most
//!   `max_wait` for stragglers) and hand the wave to the caller's
//!   executor. Lanes drain the queue after close: `close()` stops new
//!   admissions, but every already-admitted request is still answered —
//!   the drain-on-shutdown contract.
//!
//! The queue is a plain `Mutex<VecDeque>` + `Condvar` — std-only, no
//! lock-free cleverness, which keeps it obviously correct under TSan.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Why a non-blocking [`BoundedQueue::push`] did not enqueue. The
/// rejected item is handed back so the caller can reply to it.
#[derive(Debug)]
pub enum PushError<T> {
    /// Queue is at capacity — admission control rejects the item.
    Full(T),
    /// Queue was closed by shutdown — no new admissions.
    Closed(T),
}

/// Outcome of a timed pop (used by lanes to gather a wave).
pub enum PopTimeout<T> {
    /// An item arrived within the deadline.
    Item(T),
    /// Deadline elapsed with the queue open but empty.
    Timeout,
    /// Queue closed and fully drained — the lane should exit.
    Drained,
}

struct Inner<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// Bounded multi-producer multi-consumer queue with non-blocking
/// admission and drain-after-close pops.
pub struct BoundedQueue<T> {
    inner: Mutex<Inner<T>>,
    not_empty: Condvar,
    capacity: usize,
}

impl<T> BoundedQueue<T> {
    /// `capacity` is clamped to at least 1 — a zero-capacity queue
    /// would reject everything.
    pub fn new(capacity: usize) -> BoundedQueue<T> {
        BoundedQueue {
            inner: Mutex::new(Inner { items: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Non-blocking admission: enqueue or hand the item straight back.
    pub fn push(&self, item: T) -> Result<(), PushError<T>> {
        let mut inner = match self.inner.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        if inner.closed {
            return Err(PushError::Closed(item));
        }
        if inner.items.len() >= self.capacity {
            return Err(PushError::Full(item));
        }
        inner.items.push_back(item);
        drop(inner);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking pop. Returns `None` only when the queue is closed AND
    /// empty — items admitted before `close()` are always delivered.
    pub fn pop_wait(&self) -> Option<T> {
        let mut inner = match self.inner.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        loop {
            if let Some(item) = inner.items.pop_front() {
                return Some(item);
            }
            if inner.closed {
                return None;
            }
            inner = match self.not_empty.wait(inner) {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
        }
    }

    /// Pop with a deadline, used to gather batch stragglers.
    pub fn pop_timeout(&self, timeout: Duration) -> PopTimeout<T> {
        let deadline = Instant::now() + timeout;
        let mut inner = match self.inner.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        loop {
            if let Some(item) = inner.items.pop_front() {
                return PopTimeout::Item(item);
            }
            if inner.closed {
                return PopTimeout::Drained;
            }
            let now = Instant::now();
            if now >= deadline {
                return PopTimeout::Timeout;
            }
            let (g, res) = match self.not_empty.wait_timeout(inner, deadline - now) {
                Ok(ok) => ok,
                Err(p) => p.into_inner(),
            };
            inner = g;
            if res.timed_out() && inner.items.is_empty() {
                if inner.closed {
                    return PopTimeout::Drained;
                }
                return PopTimeout::Timeout;
            }
        }
    }

    /// Close admissions and wake every waiting lane. Idempotent.
    pub fn close(&self) {
        let mut inner = match self.inner.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        inner.closed = true;
        drop(inner);
        self.not_empty.notify_all();
    }

    /// True once `close()` has been called (new pushes are rejected).
    pub fn is_closed(&self) -> bool {
        match self.inner.lock() {
            Ok(g) => g.closed,
            Err(p) => p.into_inner().closed,
        }
    }

    /// Current backlog length (for stats / tests).
    pub fn len(&self) -> usize {
        match self.inner.lock() {
            Ok(g) => g.items.len(),
            Err(p) => p.into_inner().items.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One batcher lane: block for the first request, gather up to
/// `max_batch` requests waiting at most `max_wait` for stragglers, hand
/// the wave to `handle_wave`, repeat. Returns when the queue is closed
/// and drained. Every popped request is passed to `handle_wave` exactly
/// once — the executor owns replying to each request (success or
/// structured error), preserving the drain-on-shutdown contract.
pub fn lane_loop<T, F>(queue: &BoundedQueue<T>, max_batch: usize, max_wait: Duration, mut handle_wave: F)
where
    F: FnMut(Vec<T>),
{
    let max_batch = max_batch.max(1);
    loop {
        let first = match queue.pop_wait() {
            Some(item) => item,
            None => return, // closed + drained
        };
        let mut wave = Vec::with_capacity(max_batch);
        wave.push(first);
        let deadline = Instant::now() + max_wait;
        while wave.len() < max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match queue.pop_timeout(deadline - now) {
                PopTimeout::Item(item) => wave.push(item),
                PopTimeout::Timeout => break,
                PopTimeout::Drained => break, // flush what we have
            }
        }
        handle_wave(wave);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn push_pop_fifo() {
        let q = BoundedQueue::new(4);
        q.push(1).unwrap();
        q.push(2).unwrap();
        assert_eq!(q.pop_wait(), Some(1));
        assert_eq!(q.pop_wait(), Some(2));
    }

    #[test]
    fn full_queue_rejects_without_blocking() {
        let q = BoundedQueue::new(2);
        q.push(1).unwrap();
        q.push(2).unwrap();
        match q.push(3) {
            Err(PushError::Full(item)) => assert_eq!(item, 3),
            other => panic!("expected Full rejection, got {other:?}"),
        }
        // Pop one slot free and admission resumes.
        assert_eq!(q.pop_wait(), Some(1));
        q.push(3).unwrap();
    }

    #[test]
    fn closed_queue_rejects_new_but_drains_old() {
        let q = BoundedQueue::new(8);
        q.push(1).unwrap();
        q.push(2).unwrap();
        q.close();
        match q.push(3) {
            Err(PushError::Closed(item)) => assert_eq!(item, 3),
            other => panic!("expected Closed rejection, got {other:?}"),
        }
        assert_eq!(q.pop_wait(), Some(1));
        assert_eq!(q.pop_wait(), Some(2));
        assert_eq!(q.pop_wait(), None);
    }

    #[test]
    fn pop_timeout_times_out_when_open_and_empty() {
        let q: BoundedQueue<u32> = BoundedQueue::new(4);
        match q.pop_timeout(Duration::from_millis(5)) {
            PopTimeout::Timeout => {}
            PopTimeout::Item(_) => panic!("unexpected item"),
            PopTimeout::Drained => panic!("queue is open"),
        }
    }

    #[test]
    fn close_wakes_blocked_consumers() {
        let q: Arc<BoundedQueue<u32>> = Arc::new(BoundedQueue::new(4));
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || q2.pop_wait());
        std::thread::sleep(Duration::from_millis(10));
        q.close();
        assert_eq!(h.join().unwrap(), None);
    }

    #[test]
    fn lane_loop_batches_up_to_max() {
        let q: Arc<BoundedQueue<u32>> = Arc::new(BoundedQueue::new(64));
        for i in 0..10 {
            q.push(i).unwrap();
        }
        q.close();
        let q2 = Arc::clone(&q);
        let waves: Vec<Vec<u32>> = {
            let mut collected = Vec::new();
            lane_loop(&q2, 4, Duration::from_millis(1), |wave| collected.push(wave));
            collected
        };
        let total: usize = waves.iter().map(|w| w.len()).sum();
        assert_eq!(total, 10, "every request handled exactly once");
        assert!(waves.iter().all(|w| w.len() <= 4), "wave exceeded max_batch: {waves:?}");
        let mut flat: Vec<u32> = waves.into_iter().flatten().collect();
        flat.sort_unstable();
        assert_eq!(flat, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn concurrent_producers_and_lanes_conserve_items() {
        let q: Arc<BoundedQueue<u32>> = Arc::new(BoundedQueue::new(1024));
        let handled = Arc::new(Mutex::new(Vec::new()));
        let mut lanes = Vec::new();
        for _ in 0..3 {
            let q = Arc::clone(&q);
            let handled = Arc::clone(&handled);
            lanes.push(std::thread::spawn(move || {
                lane_loop(&q, 8, Duration::from_micros(200), |wave| {
                    let mut g = handled.lock().unwrap();
                    g.extend(wave);
                });
            }));
        }
        let mut producers = Vec::new();
        for p in 0..4u32 {
            let q = Arc::clone(&q);
            producers.push(std::thread::spawn(move || {
                for i in 0..50u32 {
                    // Capacity is ample, so push never rejects here.
                    q.push(p * 1000 + i).unwrap();
                }
            }));
        }
        for h in producers {
            h.join().unwrap();
        }
        q.close();
        for h in lanes {
            h.join().unwrap();
        }
        let mut got = handled.lock().unwrap().clone();
        got.sort_unstable();
        let mut want: Vec<u32> =
            (0..4u32).flat_map(|p| (0..50u32).map(move |i| p * 1000 + i)).collect();
        want.sort_unstable();
        assert_eq!(got, want, "items lost or duplicated across lanes");
    }
}
