//! Seed-keyed LRU cache for sampled subgraphs.
//!
//! Serving traffic is zipfian — hot entities get re-queried — so the
//! task server can skip re-sampling a seed's rooted subgraph when an
//! identical request was served recently. Correctness rests on the
//! sampler's determinism contract (`sample_seeds` is a pure function of
//! `(store, spec, plan_seed, seeds)`), which makes a cached subgraph
//! bit-identical to a re-sampled one; the cache property test in
//! `tests/serve_concurrency.rs` pins exactly that (cache-on vs
//! cache-off responses bit-identical across hit/miss interleavings).
//!
//! std-only LRU: a `HashMap` for lookup plus a `BTreeMap<stamp, key>`
//! recency index (monotone tick counter) — O(log n) per touch, no
//! intrusive lists, no unsafe.

use std::collections::{BTreeMap, HashMap};
use std::hash::Hash;
use std::sync::Mutex;

struct LruInner<K, V> {
    map: HashMap<K, (V, u64)>,
    order: BTreeMap<u64, K>,
    tick: u64,
}

/// Thread-safe least-recently-used cache. `capacity == 0` disables the
/// cache (every `get` misses, every `put` is dropped).
pub struct LruCache<K, V> {
    inner: Mutex<LruInner<K, V>>,
    capacity: usize,
}

impl<K: Eq + Hash + Clone, V: Clone> LruCache<K, V> {
    pub fn new(capacity: usize) -> LruCache<K, V> {
        LruCache {
            inner: Mutex::new(LruInner { map: HashMap::new(), order: BTreeMap::new(), tick: 0 }),
            capacity,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn is_enabled(&self) -> bool {
        self.capacity > 0
    }

    pub fn len(&self) -> usize {
        match self.inner.lock() {
            Ok(g) => g.map.len(),
            Err(p) => p.into_inner().map.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Lookup; a hit refreshes the entry's recency.
    pub fn get(&self, key: &K) -> Option<V> {
        if self.capacity == 0 {
            return None;
        }
        let mut g = match self.inner.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        g.tick += 1;
        let stamp = g.tick;
        let old_stamp = match g.map.get_mut(key) {
            Some((_, s)) => {
                let old = *s;
                *s = stamp;
                old
            }
            None => return None,
        };
        g.order.remove(&old_stamp);
        g.order.insert(stamp, key.clone());
        g.map.get(key).map(|(v, _)| v.clone())
    }

    /// Insert (or refresh) an entry, evicting the least-recently-used
    /// entries past capacity. Returns how many entries were evicted.
    pub fn put(&self, key: K, value: V) -> usize {
        if self.capacity == 0 {
            return 0;
        }
        let mut g = match self.inner.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        g.tick += 1;
        let stamp = g.tick;
        if let Some((_, old_stamp)) = g.map.insert(key.clone(), (value, stamp)) {
            g.order.remove(&old_stamp);
        }
        g.order.insert(stamp, key);
        let mut evicted = 0;
        while g.map.len() > self.capacity {
            // BTreeMap iterates in stamp order, so the first entry is
            // the least recently used.
            let oldest = match g.order.iter().next() {
                Some((&s, k)) => (s, k.clone()),
                None => break,
            };
            g.order.remove(&oldest.0);
            g.map.remove(&oldest.1);
            evicted += 1;
        }
        evicted
    }

    /// Drop every entry (used after a model hot-swap when the cached
    /// values depend on model parameters; subgraph caches survive swaps
    /// because sampling does not read the model).
    pub fn clear(&self) {
        let mut g = match self.inner.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        g.map.clear();
        g.order.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_capacity_is_disabled() {
        let c: LruCache<u32, u32> = LruCache::new(0);
        assert!(!c.is_enabled());
        assert_eq!(c.put(1, 10), 0);
        assert_eq!(c.get(&1), None);
        assert_eq!(c.len(), 0);
    }

    #[test]
    fn hit_and_miss() {
        let c = LruCache::new(4);
        assert_eq!(c.get(&1), None);
        c.put(1, 10);
        assert_eq!(c.get(&1), Some(10));
        assert_eq!(c.get(&2), None);
    }

    #[test]
    fn evicts_least_recently_used() {
        let c = LruCache::new(2);
        c.put(1, 10);
        c.put(2, 20);
        // Touch 1 so 2 becomes the LRU entry.
        assert_eq!(c.get(&1), Some(10));
        let evicted = c.put(3, 30);
        assert_eq!(evicted, 1);
        assert_eq!(c.get(&2), None, "LRU entry evicted");
        assert_eq!(c.get(&1), Some(10));
        assert_eq!(c.get(&3), Some(30));
    }

    #[test]
    fn reinsert_refreshes_not_duplicates() {
        let c = LruCache::new(2);
        c.put(1, 10);
        c.put(1, 11);
        assert_eq!(c.len(), 1);
        assert_eq!(c.get(&1), Some(11));
        c.put(2, 20);
        // 1 was refreshed by the second put, so inserting 3 evicts 2?
        // No: order after puts is [1(refreshed), 2]; get(1) above made
        // 1 most recent again, so 2 is LRU.
        assert_eq!(c.get(&1), Some(11));
        c.put(3, 30);
        assert_eq!(c.get(&2), None);
    }

    #[test]
    fn clear_empties() {
        let c = LruCache::new(4);
        c.put(1, 10);
        c.put(2, 20);
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.get(&1), None);
    }

    #[test]
    fn concurrent_access_is_consistent() {
        use std::sync::Arc;
        let c: Arc<LruCache<u32, u32>> = Arc::new(LruCache::new(16));
        let mut handles = Vec::new();
        for t in 0..4u32 {
            let c = Arc::clone(&c);
            handles.push(std::thread::spawn(move || {
                for i in 0..200u32 {
                    let k = (t * 7 + i) % 32;
                    if let Some(v) = c.get(&k) {
                        assert_eq!(v, k * 2, "value corrupted for key {k}");
                    } else {
                        c.put(k, k * 2);
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(c.len() <= 16, "capacity exceeded: {}", c.len());
    }
}
