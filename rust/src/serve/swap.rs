//! Zero-downtime model hot-swap.
//!
//! The serving model lives behind a [`ModelSlot`]: an `RwLock` holding
//! an `Arc<VersionedModel>`. Each batcher lane takes exactly one
//! `load()` snapshot per wave and runs the entire wave against that
//! snapshot, so a batch can never mix parameters from two models — the
//! old `Arc` stays alive until the last in-flight wave drops it, and
//! new waves pick up the new `Arc` on their next `load()`. Swapping is
//! a short write-lock over a pointer store, not over inference, so
//! in-flight requests never stall behind a checkpoint load.
//!
//! Every response is tagged with the snapshot's [`generation`] counter;
//! the hot-swap concurrency test uses the tag to prove each response is
//! bit-identical to the oracle for *its* generation.
//!
//! [`generation`]: VersionedModel::generation

use std::sync::{Arc, RwLock};

use crate::runtime::HostTensor;
use crate::train::checkpoint;
use crate::train::native::NativeModel;
use crate::{Error, Result};

/// An immutable model snapshot plus its swap-generation number
/// (starts at 1; each successful swap increments it).
pub struct VersionedModel {
    pub generation: u64,
    pub model: Arc<NativeModel>,
}

/// The atomically swappable model pointer shared by all lanes.
pub struct ModelSlot {
    current: RwLock<Arc<VersionedModel>>,
}

impl ModelSlot {
    pub fn new(model: Arc<NativeModel>) -> ModelSlot {
        ModelSlot { current: RwLock::new(Arc::new(VersionedModel { generation: 1, model })) }
    }

    /// Snapshot the current model. Lanes call this once per wave and
    /// use the returned `Arc` for every request in the wave.
    pub fn load(&self) -> Arc<VersionedModel> {
        match self.current.read() {
            Ok(g) => Arc::clone(&g),
            Err(p) => Arc::clone(&p.into_inner()),
        }
    }

    /// Current generation (1 until the first swap).
    pub fn generation(&self) -> u64 {
        self.load().generation
    }

    /// Swap in a replacement model. The replacement must be
    /// architecturally identical to the resident one (same parameter
    /// names and shapes, in order) — a serving swap changes weights,
    /// never the model family. Returns the new generation.
    pub fn swap_model(&self, model: Arc<NativeModel>) -> Result<u64> {
        let mut g = match self.current.write() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        let old = &g.model;
        if old.names != model.names {
            return Err(Error::Runtime(format!(
                "hot-swap rejected: parameter names differ (resident {} params, \
                 replacement {})",
                old.names.len(),
                model.names.len()
            )));
        }
        for ((name, a), b) in old.names.iter().zip(&old.params).zip(&model.params) {
            if a.rows != b.rows || a.cols != b.cols {
                return Err(Error::Runtime(format!(
                    "hot-swap rejected: parameter {name:?} is [{}, {}], \
                     replacement is [{}, {}]",
                    a.rows, a.cols, b.rows, b.cols
                )));
            }
        }
        let generation = g.generation + 1;
        *g = Arc::new(VersionedModel { generation, model });
        Ok(generation)
    }

    /// Swap to new weights given as named checkpoint tensors (the
    /// on-disk codec's in-memory form). Validation is all-or-nothing
    /// via [`NativeModel::with_tensors`].
    pub fn swap_tensors(&self, tensors: &[(String, HostTensor)]) -> Result<u64> {
        let next = self.load().model.with_tensors(tensors)?;
        self.swap_model(Arc::new(next))
    }

    /// Swap to the weights stored in a checkpoint file.
    pub fn swap_checkpoint(&self, path: &std::path::Path) -> Result<u64> {
        let tensors = checkpoint::load(path)?;
        self.swap_tensors(&tensors)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::model_ref::ModelConfig;
    use crate::synth::mag::MagConfig;

    fn small_model(seed: u64) -> Arc<NativeModel> {
        let mag = MagConfig {
            num_papers: 50,
            num_authors: 60,
            num_institutions: 8,
            num_fields: 6,
            ..MagConfig::default()
        };
        let cfg = ModelConfig::for_mag(&mag, 4, 4, 1);
        Arc::new(NativeModel::init(cfg, seed).unwrap())
    }

    #[test]
    fn swap_increments_generation_and_replaces_weights() {
        let a = small_model(1);
        let b = small_model(2);
        let slot = ModelSlot::new(Arc::clone(&a));
        assert_eq!(slot.generation(), 1);
        let generation = slot.swap_model(Arc::clone(&b)).unwrap();
        assert_eq!(generation, 2);
        let loaded = slot.load();
        assert_eq!(loaded.generation, 2);
        assert_eq!(
            loaded.model.params[0].data[0].to_bits(),
            b.params[0].data[0].to_bits(),
            "slot serves the swapped-in weights"
        );
    }

    #[test]
    fn old_snapshot_survives_a_swap() {
        let a = small_model(1);
        let slot = ModelSlot::new(Arc::clone(&a));
        let before = slot.load();
        slot.swap_model(small_model(2)).unwrap();
        // The pre-swap snapshot still points at the old weights — this
        // is what keeps an in-flight wave on one consistent model.
        assert_eq!(before.generation, 1);
        assert_eq!(
            before.model.params[0].data[0].to_bits(),
            a.params[0].data[0].to_bits()
        );
        assert_eq!(slot.load().generation, 2);
    }

    #[test]
    fn mismatched_architecture_is_rejected() {
        let a = small_model(1);
        let slot = ModelSlot::new(a);
        let mag = MagConfig {
            num_papers: 50,
            num_authors: 60,
            num_institutions: 8,
            num_fields: 6,
            ..MagConfig::default()
        };
        // Different hidden width => different parameter shapes.
        let other = ModelConfig::for_mag(&mag, 8, 8, 1);
        let wrong = Arc::new(NativeModel::init(other, 3).unwrap());
        assert!(slot.swap_model(wrong).is_err());
        assert_eq!(slot.generation(), 1, "failed swap must not bump the generation");
    }

    #[test]
    fn swap_tensors_roundtrips_a_checkpoint_image() {
        let a = small_model(1);
        let b = small_model(2);
        let slot = ModelSlot::new(Arc::clone(&a));
        // `param.`-prefixed names exercise the codec-path normalization.
        let tensors: Vec<(String, HostTensor)> = b
            .params_as_tensors()
            .into_iter()
            .map(|(n, t)| (format!("param.{n}"), t))
            .collect();
        slot.swap_tensors(&tensors).unwrap();
        let loaded = slot.load();
        for (x, y) in loaded.model.params.iter().zip(&b.params) {
            for (u, v) in x.data.iter().zip(&y.data) {
                assert_eq!(u.to_bits(), v.to_bits());
            }
        }
    }

    #[test]
    fn swap_tensors_rejects_missing_params() {
        let a = small_model(1);
        let slot = ModelSlot::new(Arc::clone(&a));
        let mut tensors = a.params_as_tensors();
        tensors.pop();
        assert!(slot.swap_tensors(&tensors).is_err());
        assert_eq!(slot.generation(), 1);
    }
}
