//! Closed-loop load generator for the serving benches.
//!
//! Drives a running [`TaskServerHandle`] at stepped client
//! concurrency: each client is a closed loop (it waits for its
//! response before issuing the next request), so offered load tracks
//! the server's actual capacity instead of running away from it — the
//! classic way to find the latency/throughput knee without open-loop
//! coordinated omission. [`Error::Overloaded`] rejections count
//! separately from real failures, so admission control shows up as a
//! rejection rate, not as an error.
//!
//! [`parity_gate`] is the correctness precondition: before any timing,
//! the server under test must answer a probe set bit-identically to a
//! single-lane cache-off oracle server. A fast wrong server never
//! produces a bench row.

use std::time::{Duration, Instant};

use crate::tasks::TaskOutput;
use crate::util::stats::Summary;
use crate::{Error, Result};

use super::TaskServerHandle;

/// Load-generation schedule.
#[derive(Debug, Clone)]
pub struct LoadGenConfig {
    /// Stepped client counts, driven in order (e.g. `[1, 4, 16]`).
    pub concurrency: Vec<usize>,
    /// Requests each client issues per level.
    pub requests_per_client: usize,
}

impl Default for LoadGenConfig {
    fn default() -> LoadGenConfig {
        LoadGenConfig { concurrency: vec![1, 4, 16], requests_per_client: 32 }
    }
}

/// Measured outcome of one concurrency level.
#[derive(Debug, Clone)]
pub struct LoadGenLevel {
    pub concurrency: usize,
    /// Successfully answered requests.
    pub ok: usize,
    /// Requests rejected by admission control ([`Error::Overloaded`]).
    pub rejected: usize,
    /// Requests answered [`Error::DeadlineExceeded`] (they never
    /// reached a model forward pass).
    pub deadline: usize,
    /// Requests that failed for any other reason.
    pub failed: usize,
    /// Wall-clock time for the whole level.
    pub elapsed: Duration,
    /// Successful responses per second of wall clock.
    pub throughput: f64,
    /// Per-request latency summary in seconds (successful responses
    /// only — p50/p95/p99/p99.9 are the bench's headline rows).
    pub latency: Summary,
}

/// All levels of one load-generation run.
#[derive(Debug, Clone)]
pub struct LoadGenReport {
    pub levels: Vec<LoadGenLevel>,
}

impl LoadGenReport {
    /// Saturation throughput: the best successful-responses/sec
    /// observed across the stepped levels.
    pub fn saturation_throughput(&self) -> f64 {
        self.levels.iter().map(|l| l.throughput).fold(0.0, f64::max)
    }
}

/// Drive the server through every concurrency level of `cfg`. Client
/// `c` of a level walks `seed_lists` round-robin starting at a
/// client-specific offset, so levels re-use the same request
/// population while clients spread across it.
pub fn run(
    handle: &TaskServerHandle,
    seed_lists: &[Vec<u32>],
    cfg: &LoadGenConfig,
) -> Result<LoadGenReport> {
    if seed_lists.is_empty() {
        return Err(Error::Runtime("loadgen: empty seed-list population".into()));
    }
    let mut levels = Vec::new();
    for &clients in &cfg.concurrency {
        let clients = clients.max(1);
        let _span = crate::span!("loadgen/level", clients = clients);
        let n = cfg.requests_per_client.max(1);
        let mut results: Vec<(Vec<f64>, usize, usize, usize)> = Vec::new();
        let t0 = Instant::now();
        std::thread::scope(|s| {
            let mut workers = Vec::new();
            for c in 0..clients {
                workers.push(s.spawn(move || {
                    let mut lat = Vec::with_capacity(n);
                    let (mut rejected, mut deadline, mut failed) = (0usize, 0usize, 0usize);
                    for i in 0..n {
                        let seeds = &seed_lists[(c * n + i) % seed_lists.len()];
                        match handle.predict(seeds) {
                            Ok(r) => lat.push(r.latency.as_secs_f64()),
                            Err(Error::Overloaded(_)) => rejected += 1,
                            Err(Error::DeadlineExceeded(_)) => deadline += 1,
                            Err(_) => failed += 1,
                        }
                    }
                    (lat, rejected, deadline, failed)
                }));
            }
            for w in workers {
                match w.join() {
                    Ok(r) => results.push(r),
                    // A panicked client counts its whole quota failed.
                    Err(_) => results.push((Vec::new(), 0, 0, n)),
                }
            }
        });
        let elapsed = t0.elapsed();
        let mut lat: Vec<f64> = Vec::new();
        let (mut rejected, mut deadline, mut failed) = (0usize, 0usize, 0usize);
        for (l, r, d, f) in results {
            lat.extend(l);
            rejected += r;
            deadline += d;
            failed += f;
        }
        let ok = lat.len();
        if ok == 0 {
            return Err(Error::Runtime(format!(
                "loadgen: no successful responses at concurrency {clients} \
                 ({rejected} rejected, {deadline} deadline-expired, {failed} failed)"
            )));
        }
        levels.push(LoadGenLevel {
            concurrency: clients,
            ok,
            rejected,
            deadline,
            failed,
            elapsed,
            throughput: ok as f64 / elapsed.as_secs_f64().max(1e-9),
            latency: Summary::of(&lat),
        });
    }
    Ok(LoadGenReport { levels })
}

/// Assert that `server` answers every probe bit-identically to
/// `oracle` (a single-lane, cache-off reference). Run this before
/// timing: a fast wrong server must never produce a bench row.
pub fn parity_gate(
    server: &TaskServerHandle,
    oracle: &TaskServerHandle,
    seed_lists: &[Vec<u32>],
) -> Result<()> {
    for seeds in seed_lists {
        let got = server.predict(seeds)?;
        let want = oracle.predict(seeds)?;
        if !outputs_bit_identical(&got.output, &want.output) {
            return Err(Error::Runtime(format!(
                "parity violation for seeds {seeds:?}: {:?} != oracle {:?}",
                got.output, want.output
            )));
        }
    }
    Ok(())
}

/// Bit-level equality of task outputs (f32 compared via `to_bits`),
/// the determinism contract the serving tests and benches pin.
pub fn outputs_bit_identical(a: &TaskOutput, b: &TaskOutput) -> bool {
    match (a, b) {
        (
            TaskOutput::Classification { logits: la, predicted: pa },
            TaskOutput::Classification { logits: lb, predicted: pb },
        ) => {
            pa == pb
                && la.len() == lb.len()
                && la.iter().zip(lb).all(|(x, y)| x.to_bits() == y.to_bits())
        }
        (TaskOutput::LinkScore { score: a }, TaskOutput::LinkScore { score: b }) => {
            a.to_bits() == b.to_bits()
        }
        (TaskOutput::Regression { value: a }, TaskOutput::Regression { value: b }) => {
            a.to_bits() == b.to_bits()
        }
        _ => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::model_ref::ModelConfig;
    use crate::sampler::inmem::InMemorySampler;
    use crate::sampler::spec::mag_sampling_spec_scaled;
    use crate::serve::{serve_task, ServeConfig};
    use crate::synth::mag::{generate, MagConfig, Split};
    use crate::train::native::NativeModel;
    use std::sync::Arc;

    fn tiny_task_server(lanes: usize) -> (TaskServerHandle, Vec<Vec<u32>>) {
        let mag = MagConfig::tiny();
        let ds = generate(&mag);
        let seeds = ds.papers_in_split(Split::Train);
        let store = Arc::new(ds.store);
        let spec = mag_sampling_spec_scaled(&store.schema, 0.2).unwrap();
        let sampler = Arc::new(InMemorySampler::new(store, spec, 3).unwrap());
        let cfg = ModelConfig::for_mag(&mag, 8, 8, 1);
        let task = crate::tasks::build(&cfg).unwrap();
        let model = Arc::new(NativeModel::init(cfg, 7).unwrap());
        let handle = serve_task(
            model,
            sampler,
            task,
            ServeConfig { lanes, ..ServeConfig::default() },
        )
        .unwrap();
        let lists: Vec<Vec<u32>> = seeds.iter().take(6).map(|&s| vec![s]).collect();
        (handle, lists)
    }

    #[test]
    fn closed_loop_counts_and_latency() {
        let (handle, lists) = tiny_task_server(2);
        let cfg = LoadGenConfig { concurrency: vec![1, 2], requests_per_client: 4 };
        let report = run(&handle, &lists, &cfg).unwrap();
        assert_eq!(report.levels.len(), 2);
        for level in &report.levels {
            assert_eq!(
                level.ok + level.rejected + level.deadline + level.failed,
                level.concurrency * 4,
                "every request has exactly one outcome"
            );
            assert!(level.throughput > 0.0);
            assert!(level.latency.p50 > 0.0);
            assert!(level.latency.p99 >= level.latency.p50);
            assert!(level.latency.p999 >= level.latency.p99);
            assert!(level.latency.max >= level.latency.p999);
        }
        assert!(report.saturation_throughput() > 0.0);
        handle.shutdown();
    }

    #[test]
    fn parity_gate_passes_against_an_identical_oracle() {
        let (server, lists) = tiny_task_server(2);
        let (oracle, _) = tiny_task_server(1);
        parity_gate(&server, &oracle, &lists).unwrap();
        server.shutdown();
        oracle.shutdown();
    }

    #[test]
    fn outputs_bit_identical_discriminates() {
        let a = TaskOutput::LinkScore { score: 1.25 };
        let b = TaskOutput::LinkScore { score: 1.25 };
        let c = TaskOutput::LinkScore { score: 1.250001 };
        assert!(outputs_bit_identical(&a, &b));
        assert!(!outputs_bit_identical(&a, &c));
        assert!(!outputs_bit_identical(&a, &TaskOutput::Regression { value: 1.25 }));
    }
}
