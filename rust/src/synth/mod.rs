//! Synthetic dataset generators.
//!
//! The paper's running examples are (a) the recommendation-system graph
//! of Figure 2 and (b) OGBN-MAG (§8). OGBN-MAG itself is not available
//! in this offline environment, so [`mag`] generates **synth-MAG**: a
//! stochastic-block heterogeneous academic graph with the exact §8
//! schema (paper / author / institution / field_of_study node sets and
//! cites / writes / written / affiliated_with / has_topic edge sets),
//! 128-d paper features correlated with venue labels, and a temporal
//! train/validation/test split by paper year — the same protocol the
//! paper describes. See DESIGN.md §Substitutions.

pub mod mag;
pub mod recsys;
